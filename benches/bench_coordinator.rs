//! Hot-path benchmark: coordinator logic (batch planning, request packing,
//! stats) and the end-to-end serving rate through the PJRT runtime.

use std::path::PathBuf;

use descnet::coordinator::server::{synthetic_image, ServeOptions, Server};
use descnet::coordinator::BatchPolicy;
use descnet::util::bench::{throughput, time};
use descnet::util::prng::Prng;

fn main() {
    // Pure policy throughput.
    let policy = BatchPolicy::new(vec![1, 4], 2e-3).expect("valid sizes");
    let r = time("batch planning x10k queues", 50, || {
        let mut acc = 0usize;
        for pending in 0..10_000usize {
            acc += policy.plan(pending % 64, pending % 7 == 0).len();
        }
        std::hint::black_box(acc);
    });
    println!("    -> {}", throughput(&r, 10_000));

    let mut rng = Prng::new(2);
    time("synthetic image generation x100", 20, || {
        for _ in 0..100 {
            std::hint::black_box(synthetic_image(&mut rng, 28));
        }
    });

    // End-to-end serving rate (needs artifacts).
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("artifacts not built; skipping end-to-end serve bench");
        return;
    }
    for (label, staged) in [("serve 32 reqs (full)", false), ("serve 32 reqs (staged)", true)] {
        let opts = ServeOptions {
            artifacts_dir: dir.clone(),
            requests: 32,
            batch_max: 4,
            stage_pipeline: staged,
            seed: 3,
            slo_s: None,
        };
        let r = time(label, 2, || {
            std::hint::black_box(Server::run_synthetic(&opts).expect("serve"));
        });
        println!("    -> {:.1} req/s end-to-end", 32.0 / r.mean_s);
    }
}
