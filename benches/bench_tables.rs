//! One benchmark per paper table/figure: times the regeneration of every
//! artifact in the DESIGN.md E-index (the same code paths `descnet report
//! all` runs), so `cargo bench` both re-produces the paper's evaluation and
//! reports how long each piece takes.

use descnet::config::SystemConfig;
use descnet::ctx::EvalCtx;
use descnet::report::{self, ReportCtx};
use descnet::util::bench::time;

fn main() {
    let dir = std::env::temp_dir().join("descnet_bench_tables");
    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4);
    let eval = EvalCtx::for_config(&SystemConfig::default()).threads(threads);
    let ctx = ReportCtx::new(eval, &dir);

    println!("== per-figure/table regeneration (E01-E18) ==");
    time("E01 fig1  memory utilization (CapsAcc vs TPU)", 20, || {
        report::fig1(&ctx);
    });
    time("E02 fig7  params vs time", 20, || {
        report::fig7(&ctx);
    });
    time("E03 fig9  per-op cycles", 20, || {
        report::fig9(&ctx);
    });
    time("E04 fig10 capsnet usage/accesses", 20, || {
        report::fig10(&ctx);
    });
    time("E05 fig11 deepcaps usage/accesses", 20, || {
        report::fig11(&ctx);
    });
    time("E06 fig12 version (a)/(b) energy", 20, || {
        report::fig12(&ctx).expect("report generator");
    });
    time("E07 fig18+table1 capsnet DSE", 3, || {
        report::dse_scatter(&ctx, "capsnet").expect("report generator");
    });
    time("E08 fig19 capsnet breakdowns", 3, || {
        report::breakdowns(&ctx, "capsnet").expect("report generator");
    });
    time("E09 fig20+table2 deepcaps DSE", 2, || {
        report::dse_scatter(&ctx, "deepcaps").expect("report generator");
    });
    time("E10 fig21 deepcaps breakdowns", 2, || {
        report::breakdowns(&ctx, "deepcaps").expect("report generator");
    });
    time("E11 fig22 port-constrained HY-PG DSE", 2, || {
        report::fig22(&ctx).expect("report generator");
    });
    time("E12 fig23/24 capsnet whole accelerator", 3, || {
        report::whole_accelerator(&ctx, "capsnet").expect("report generator");
    });
    time("E13 fig25/26 deepcaps whole accelerator", 2, || {
        report::whole_accelerator(&ctx, "deepcaps").expect("report generator");
    });
    time("E14 table3 full area/energy table", 2, || {
        report::table3(&ctx).expect("report generator");
    });
    time("E15 fig27/28 off-chip accesses", 20, || {
        report::fig27_28(&ctx);
    });
    time("E16 fig29/31 memory breakdowns", 3, || {
        report::memory_breakdown(&ctx, "capsnet").expect("report generator");
        report::memory_breakdown(&ctx, "deepcaps").expect("report generator");
    });
    time("E17 fig30 HY-PG sector schedule", 3, || {
        report::fig30(&ctx).expect("report generator");
    });
    time("E18 headline summary", 3, || {
        report::headline(&ctx).expect("report generator");
    });
    time("E19 multi-network co-design DSE", 2, || {
        let (set, names) = report::default_serving_mix(&ctx).expect("serving mix");
        report::multi_dse(&ctx, &set, &names).expect("report generator");
    });
    time("E22 fleet serving (co-design + simulation)", 2, || {
        report::fleet_default(&ctx).expect("report generator");
    });
}
