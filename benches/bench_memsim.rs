//! Hot-path benchmark: the per-configuration evaluation pipeline — workload
//! profiling, SRAM model, coverage, PMU scheduling and the energy rollup —
//! each timed in isolation so the profile tells which stage dominates the
//! DSE inner loop.

use descnet::cacti::{Sram, SramConfig};
use descnet::config::{Accelerator, Technology};
use descnet::dataflow::profile_network;
use descnet::dse;
use descnet::energy;
use descnet::memory::{cover_op, MemSpec, Organization};
use descnet::model::{capsnet_mnist, deepcaps_cifar10};
use descnet::pmu;
use descnet::util::bench::{throughput, time};
use descnet::util::units::KIB;

fn main() {
    let accel = Accelerator::default();
    let tech = Technology::default();

    time("profile capsnet (9 ops)", 50, || {
        std::hint::black_box(profile_network(&capsnet_mnist(), &accel));
    });
    time("profile deepcaps (31 ops)", 50, || {
        std::hint::black_box(profile_network(&deepcaps_cifar10(), &accel));
    });

    let profile = profile_network(&capsnet_mnist(), &accel);
    let sram = Sram::new(&tech);
    let r = time("sram evaluate x1000 configs", 20, || {
        for i in 0..1000u32 {
            let size = 8 * KIB << (i % 8);
            std::hint::black_box(sram.evaluate(&SramConfig::new(size, 1 + (i % 3) as usize, 1)));
        }
    });
    println!("    -> {}", throughput(&r, 1000));

    let org = Organization::hy(
        MemSpec::new(32 * KIB, 2),
        MemSpec::new(25 * KIB, 2),
        MemSpec::new(25 * KIB, 4),
        MemSpec::new(32 * KIB, 2),
        3,
    );
    time("cover_op x9 (one HY org)", 200, || {
        for op in &profile.ops {
            std::hint::black_box(cover_op(&org, op));
        }
    });
    time("pmu::evaluate (HY-PG, capsnet)", 100, || {
        std::hint::black_box(pmu::evaluate(&org, &profile, &tech));
    });
    time("energy::evaluate_org (HY-PG, capsnet)", 100, || {
        std::hint::black_box(energy::evaluate_org(&org, &profile, &tech));
    });
    time("energy::per_op_energy (HY-PG, capsnet)", 100, || {
        std::hint::black_box(energy::per_op_energy(&org, &profile, &tech));
    });
    time("hy_shared_size (Algorithm 1 inner)", 200, || {
        std::hint::black_box(dse::hy_shared_size(&profile, 8 * KIB, 32 * KIB, 16 * KIB));
    });
}
