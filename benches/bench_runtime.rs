//! Hot-path benchmark: PJRT execution latency per artifact — the serving
//! request path (compile once, then per-batch execute).  Skips gracefully
//! when `make artifacts` has not run.

use std::path::PathBuf;

use descnet::coordinator::server::synthetic_image;
use descnet::runtime::Runtime;
use descnet::util::bench::time;
use descnet::util::prng::Prng;

fn main() {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("artifacts not built; skipping runtime bench");
        return;
    }
    let mut rt = Runtime::new(&dir).expect("runtime");
    let mut rng = Prng::new(1);

    // Startup cost: parse + compile each artifact once.
    for (net, stage, b) in [
        ("capsnet", "full", 1usize),
        ("capsnet", "full", 4),
        ("capsnet", "conv1", 4),
        ("capsnet", "primarycaps", 4),
        ("capsnet", "classcaps", 4),
    ] {
        let name = format!("compile {net}/{stage} b{b}");
        // (load is cached, so time only the first call per artifact)
        // lint: allow(wall_clock, "bench harness wall-time measurement")
        let t = std::time::Instant::now();
        rt.load_stage(net, stage, b).expect("load");
        println!(
            "{:44} {:>12}   (one-time)",
            name,
            descnet::util::units::fmt_time(t.elapsed().as_secs_f64())
        );
    }

    // Steady-state execution latency.
    for b in [1usize, 4] {
        let mut input = Vec::new();
        for _ in 0..b {
            input.extend(synthetic_image(&mut rng, 28));
        }
        let stage_names: Vec<String> = {
            let stage = rt.load_stage("capsnet", "full", b).unwrap();
            let _ = &stage.entry;
            vec![format!("execute capsnet/full b{b}")]
        };
        let stage = rt.load_stage("capsnet", "full", b).unwrap();
        let r = time(&stage_names[0], 10, || {
            std::hint::black_box(stage.execute(&input).expect("execute"));
        });
        println!(
            "    -> {:.1} images/s",
            b as f64 / r.mean_s
        );
    }

    // Per-stage split (the Fig 7 measured counterpart).
    let mut input = Vec::new();
    for _ in 0..4 {
        input.extend(synthetic_image(&mut rng, 28));
    }
    let h = {
        let conv1 = rt.load_stage("capsnet", "conv1", 4).unwrap();
        time("execute capsnet/conv1 b4", 10, || {
            std::hint::black_box(conv1.execute(&input).unwrap());
        });
        conv1.execute(&input).unwrap().remove(0)
    };
    let u = {
        let prim = rt.load_stage("capsnet", "primarycaps", 4).unwrap();
        time("execute capsnet/primarycaps b4", 10, || {
            std::hint::black_box(prim.execute(&h).unwrap());
        });
        prim.execute(&h).unwrap().remove(0)
    };
    let class = rt.load_stage("capsnet", "classcaps", 4).unwrap();
    time("execute capsnet/classcaps+routing b4", 10, || {
        std::hint::black_box(class.execute(&u).unwrap());
    });
}
