//! Hot-path benchmark: DSE enumeration + evaluation throughput through the
//! shared execution engine (the L3 optimization target of EXPERIMENTS.md
//! section Perf).  Reports configs/s, thread scaling vs the single-thread
//! baseline, the CACTI cost-cache hit rate, the timeline-simulator event
//! throughput and the full 3-D (area/energy/latency) sweep wall time, then
//! writes the machine-readable baseline to `BENCH_dse.json` (schema v7:
//! v6 + the ISSUE 8 `fleet.fault` block — event throughput of the same
//! 4-shard trace with crash/recover + timeout/retry + hedging injection
//! active, so fault-path overhead has a recorded trajectory) so future
//! PRs have a perf trajectory to compare against.

use descnet::cacti::cache;
use descnet::config::{Accelerator, Technology};
use descnet::ctx::EvalCtx;
use descnet::dataflow::{profile_network, NetworkProfile};
use descnet::dse;
use descnet::dse::evaluate::SubtreeEval;
use descnet::dse::heuristic::{anneal, AnnealOptions};
use descnet::dse::multi::{self, WorkloadSet};
use descnet::dse::stream;
use descnet::fleet::{self, FleetConfig, RoutingPolicy, ShardPlan};
use descnet::model::{capsnet_mnist, deepcaps_cifar10, random_networks};
use descnet::sim::Timeline;
use descnet::util::bench::{throughput, time};
use descnet::util::json::Json;

/// Measures serial per-point evaluator throughput two ways over the same
/// candidate sequence (whole subtrees in enumeration order, capped at
/// `limit` points): the O(ops)-per-point reference
/// (`evaluate::area_energy_latency`) vs the subtree-factored path
/// (`SubtreeEval::prepare` once per subtree — *included* in the timed
/// region — then O(components) per point).  Returns
/// (points, reference_points_per_s, factored_points_per_s).
fn evaluator_throughput(
    label: &str,
    profile: &NetworkProfile,
    tech: &Technology,
    accel: &Accelerator,
    limit: usize,
) -> (usize, f64, f64) {
    let tl = Timeline::build(profile, tech, accel);
    let sts = stream::subtrees(profile).expect("subtree derivation");
    let mut used: Vec<&stream::Subtree> = Vec::new();
    let mut points = 0usize;
    for st in &sts {
        if st.count() == 0 {
            continue;
        }
        if points >= limit {
            break;
        }
        points += st.count();
        used.push(st);
    }

    let mut orgs = Vec::new();
    for st in &used {
        st.materialize_into(&mut orgs);
    }
    let r_ref = time(&format!("{label} reference evaluator ({points} pts)"), 3, || {
        for org in &orgs {
            std::hint::black_box(dse::evaluate::area_energy_latency(org, profile, tech, &tl));
        }
    });

    let mut batch = Vec::new();
    let r_fac = time(&format!("{label} factored evaluator ({points} pts)"), 3, || {
        for st in &used {
            let prep = SubtreeEval::prepare(st.kind(), st.sizes(), st.pools(), profile, tech, &tl);
            batch.clear();
            st.materialize_into(&mut batch);
            for org in &batch {
                std::hint::black_box(prep.eval(org));
            }
        }
    });

    let ref_pps = points as f64 / r_ref.mean_s.max(1e-12);
    let fac_pps = points as f64 / r_fac.mean_s.max(1e-12);
    println!(
        "    -> evaluator: reference {:.0} pts/s, factored {:.0} pts/s ({:.1}x)",
        ref_pps,
        fac_pps,
        fac_pps / ref_pps.max(1e-12),
    );
    (points, ref_pps, fac_pps)
}

fn evaluator_json(ref_pps: f64, fac_pps: f64, points: usize) -> Json {
    Json::from_pairs(vec![
        ("points", points.into()),
        ("reference_points_per_s", ref_pps.into()),
        ("factored_points_per_s", fac_pps.into()),
        ("speedup", (fac_pps / ref_pps.max(1e-12)).into()),
    ])
}

fn main() {
    let accel = Accelerator::default();
    let tech = Technology::default();
    let ctx = |threads: usize| EvalCtx::new(tech.clone(), accel.clone()).threads(threads);
    let mut nets_json: Vec<Json> = Vec::new();

    for net in [capsnet_mnist(), deepcaps_cifar10()] {
        let profile = profile_network(&net, &accel);
        println!("== {} ==", net.name);

        let mut orgs = Vec::new();
        let r = time(&format!("{} enumerate", net.name), 3, || {
            orgs = dse::enumerate(&profile).expect("enumeration");
        });
        println!(
            "    -> {} configurations, {}",
            orgs.len(),
            throughput(&r, orgs.len())
        );

        // Org-independent timeline, built once per sweep like dse::run.
        let timeline = Timeline::build(&profile, &tech, &accel);

        // Timeline-simulator throughput: schedule events (fill/compute/
        // drain per op) per second over repeated builds.
        const SIM_BUILDS: usize = 2_000;
        let r = time(&format!("{} sim timeline x{}", net.name, SIM_BUILDS), 3, || {
            for _ in 0..SIM_BUILDS {
                std::hint::black_box(Timeline::build(&profile, &tech, &accel));
            }
        });
        let sim_events = timeline.op_events() * SIM_BUILDS;
        let sim_events_per_s = sim_events as f64 / r.mean_s.max(1e-12);
        println!("    -> {} (op-events/s)", throughput(&r, sim_events));

        // Serial baseline through the same engine code path (threads=1),
        // then the engine-parallel sweep at increasing worker counts.
        let serial_ctx = ctx(1);
        let serial = time(&format!("{} evaluate (serial baseline)", net.name), 2, || {
            std::hint::black_box(dse::evaluate_all(&serial_ctx, &orgs, &profile, &timeline));
        });
        println!("    -> {}", throughput(&serial, orgs.len()));
        let mut parallel_means: Vec<(usize, f64)> = Vec::new();
        for threads in [2usize, 4, 8] {
            let par_ctx = ctx(threads);
            let r = time(
                &format!("{} evaluate (engine, {} threads)", net.name, threads),
                2,
                || {
                    std::hint::black_box(dse::evaluate_all(&par_ctx, &orgs, &profile, &timeline));
                },
            );
            println!("    -> {}", throughput(&r, orgs.len()));
            parallel_means.push((threads, r.mean_s));
        }
        let speedup_4t: Option<f64> = parallel_means
            .iter()
            .find(|(t, _)| *t == 4)
            .map(|(_, mean)| serial.mean_s / mean);
        match speedup_4t {
            Some(s) => println!(
                "    -> 4-thread speedup vs serial baseline: {s:.2}x (ISSUE 1 target: >= 2x on >= 4 cores)"
            ),
            None => println!("    -> no 4-thread measurement in this run"),
        }

        let points = dse::evaluate_all(&ctx(8), &orgs, &profile, &timeline);
        time(&format!("{} pareto extraction (3-D)", net.name), 5, || {
            std::hint::black_box(dse::pareto_indices(&points));
        });

        // Full 3-D sweep wall time: streaming enumerate + bound + evaluate
        // + 3-D Pareto + selection, the `descnet dse` end-to-end path.
        let mut sweep_stats = descnet::dse::stream::SweepStats::default();
        let sweep_ctx = ctx(8);
        let sweep3d = time(&format!("{} full 3-D sweep (8 threads)", net.name), 2, || {
            let res = dse::run(&sweep_ctx, &profile).expect("3-D sweep");
            sweep_stats = res.stats;
            std::hint::black_box(res);
        });
        println!(
            "    -> branch-and-bound: {} enumerated, {} pruned ({:.1}%), {} evaluated",
            sweep_stats.enumerated,
            sweep_stats.pruned,
            100.0 * sweep_stats.pruned_fraction(),
            sweep_stats.evaluated,
        );
        time(&format!("{} per-option selection", net.name), 5, || {
            std::hint::black_box(dse::select_per_option(&points));
        });

        // ISSUE 7: per-point evaluator throughput, reference vs factored,
        // over the full space (target: >= 3x points/s on capsnet).
        let (eval_points, ref_pps, fac_pps) =
            evaluator_throughput(&net.name, &profile, &tech, &accel, usize::MAX);

        // Heuristic (section V-D): speed/quality vs the exhaustive sweep.
        let hy_opt = points
            .iter()
            .filter(|p| p.option().label().starts_with("HY"))
            .map(|p| p.energy_j)
            .fold(f64::INFINITY, f64::min);
        // Iterations scaled to the space (DeepCaps' HY space is ~11x larger).
        let opts = AnnealOptions {
            iterations: if net.name == "capsnet" { 2_000 } else { 30_000 },
            ..AnnealOptions::default()
        };
        let iters_label = opts.iterations / 1000;
        let mut result = None;
        let anneal_ctx = ctx(1);
        let r = time(
            &format!("{} simulated annealing ({}k iters)", net.name, iters_label),
            3,
            || {
                result = Some(anneal(&anneal_ctx, &profile, &opts));
            },
        );
        let res = result.unwrap();
        println!(
            "    -> best {:.4} mJ vs exhaustive HY optimum {:.4} mJ ({:+.1}%), {} evals in {}",
            res.best.energy_j * 1e3,
            hy_opt * 1e3,
            (res.best.energy_j / hy_opt - 1.0) * 100.0,
            res.evaluations,
            descnet::util::units::fmt_time(r.mean_s),
        );

        let parallel_json = Json::from_pairs(
            parallel_means
                .iter()
                .map(|(t, s)| (threads_key(*t), Json::from(*s)))
                .collect(),
        );
        nets_json.push(Json::from_pairs(vec![
            ("network", net.name.as_str().into()),
            ("configs", orgs.len().into()),
            ("serial_mean_s", serial.mean_s.into()),
            ("sim_events_per_s", sim_events_per_s.into()),
            ("sweep3d_mean_s", sweep3d.mean_s.into()),
            ("parallel_mean_s_by_threads", parallel_json),
            (
                "speedup_4t_vs_serial",
                speedup_4t.map(Json::from).unwrap_or(Json::Null),
            ),
            ("anneal_best_mj", (res.best.energy_j * 1e3).into()),
            ("anneal_evaluations", res.evaluations.into()),
            ("pruning", pruning_json(&sweep_stats)),
            ("evaluator", evaluator_json(ref_pps, fac_pps, eval_points)),
        ]));
    }

    // ISSUE 7 asymptotic demo: replicate the capsnet op list 32x (sizes
    // and subtree structure unchanged — maxima are replication-invariant)
    // so the reference pays 32x more per point while the factored path's
    // per-point cost stays O(components).  The speedup here should dwarf
    // the per-network numbers above.
    let scaling_json = {
        const REPLICAS: usize = 32;
        let base = profile_network(&capsnet_mnist(), &accel);
        let mut big = base.clone();
        big.network = format!("capsnet-x{REPLICAS}").into();
        for _ in 1..REPLICAS {
            big.ops.extend(base.ops.iter().cloned());
        }
        println!("== evaluator scaling ({} ops) ==", big.ops.len());
        let (points, ref_pps, fac_pps) =
            evaluator_throughput("capsnet-x32", &big, &tech, &accel, 4_096);
        Json::from_pairs(vec![
            ("base_ops", base.ops.len().into()),
            ("replicas", REPLICAS.into()),
            ("ops", big.ops.len().into()),
            ("points", points.into()),
            ("reference_points_per_s", ref_pps.into()),
            ("factored_points_per_s", fac_pps.into()),
            ("speedup", (fac_pps / ref_pps.max(1e-12)).into()),
        ])
    };

    // Multi-network co-design sweep: the paper pair + 3 random networks
    // through `dse::multi` — records scenario throughput (nets x points/s).
    let multi_nets = {
        let mut nets = vec![capsnet_mnist(), deepcaps_cifar10()];
        nets.extend(random_networks(3, 7));
        nets
    };
    let profiles: Vec<_> = multi_nets
        .iter()
        .map(|n| profile_network(n, &accel))
        .collect();
    let n_nets = profiles.len();
    let set = WorkloadSet::new(profiles).expect("workload set");
    let mut multi_points = 0usize;
    let mut multi_stats = descnet::dse::stream::SweepStats::default();
    let multi_ctx = ctx(8);
    let r = time(&format!("multi co-design sweep ({n_nets} nets)"), 2, || {
        let res = multi::run(&multi_ctx, &set).expect("multi DSE");
        multi_points = res.points.len();
        multi_stats = res.stats;
        std::hint::black_box(res);
    });
    let net_points = n_nets * multi_points;
    println!(
        "    -> {} orgs x {} nets = {} net-evaluations, {}",
        multi_points,
        n_nets,
        net_points,
        throughput(&r, net_points)
    );
    let multi_json = Json::from_pairs(vec![
        ("networks", n_nets.into()),
        ("configs", multi_points.into()),
        ("mean_s", r.mean_s.into()),
        (
            "net_points_per_s",
            (net_points as f64 / r.mean_s.max(1e-12)).into(),
        ),
        ("pruning", pruning_json(&multi_stats)),
    ]);

    // Fleet discrete-event simulator throughput (schema v4): a synthetic
    // 4-shard fleet (one slow-binned shard) under JSQ, events/s over a
    // 20k-request trace — the `fleet::simulate` hot path without the
    // design-time DSE in front of it.
    let fleet_plans: Vec<ShardPlan> = (0..4)
        .map(|i| {
            let speed = if i == 3 { 0.5 } else { 1.0 };
            ShardPlan::synthetic("bench", vec![1, 2, 4], 10e-3, 5e-3, speed, 2e-3)
                .expect("synthetic plan")
        })
        .collect();
    let fleet_cfg = FleetConfig {
        rps: 400.0,
        requests: 20_000,
        seed: 7,
        policy: RoutingPolicy::Jsq,
        slo_s: Some(50e-3),
        fault: None,
    };
    let mut fleet_events = 0u64;
    let r = time("fleet sim (4 shards, 20k requests)", 3, || {
        let stats = fleet::simulate(&fleet_plans, &fleet_cfg).expect("fleet sim");
        fleet_events = stats.events;
        std::hint::black_box(stats);
    });
    let fleet_events_per_s = fleet_events as f64 / r.mean_s.max(1e-12);
    println!("    -> {} (fleet events/s)", throughput(&r, fleet_events as usize));

    // ISSUE 8: the same trace with the fault machinery fully active —
    // crash/recover schedules on every shard, per-request timeout/retry
    // and hedged re-dispatch.  The extra Crash/Recover/Timeout/Hedge
    // events and the dead-entry purges are the overhead being tracked.
    let fault_cfg = FleetConfig {
        fault: Some(fleet::fault::FaultConfig {
            mtbf_s: 5.0,
            mttr_s: 0.5,
            timeout_s: Some(100e-3),
            retries: 2,
            hedge_s: Some(50e-3),
            fault_seed: 11,
            ..fleet::fault::FaultConfig::default()
        }),
        ..fleet_cfg.clone()
    };
    let mut fault_events = 0u64;
    let mut fault_stats_snapshot = None;
    let rf = time("fleet sim + faults (4 shards, 20k requests)", 3, || {
        let stats = fleet::simulate(&fleet_plans, &fault_cfg).expect("fleet fault sim");
        fault_events = stats.events;
        fault_stats_snapshot = Some((stats.crashes, stats.retries, stats.hedges, stats.dropped));
        std::hint::black_box(stats);
    });
    let fault_events_per_s = fault_events as f64 / rf.mean_s.max(1e-12);
    let (crashes, retries, hedges, dropped) = fault_stats_snapshot.unwrap_or((0, 0, 0, 0));
    println!(
        "    -> {} (fault events/s; {} crashes, {} retries, {} hedges, {} dropped)",
        throughput(&rf, fault_events as usize),
        crashes,
        retries,
        hedges,
        dropped,
    );

    let fleet_json = Json::from_pairs(vec![
        ("shards", fleet_plans.len().into()),
        ("requests", fleet_cfg.requests.into()),
        ("events", (fleet_events as usize).into()),
        ("mean_s", r.mean_s.into()),
        ("events_per_s", fleet_events_per_s.into()),
        (
            "fault",
            Json::from_pairs(vec![
                ("mtbf_s", 5.0.into()),
                ("mttr_s", 0.5.into()),
                ("timeout_ms", 100.0.into()),
                ("retries", 2usize.into()),
                ("hedge_ms", 50.0.into()),
                ("events", (fault_events as usize).into()),
                ("mean_s", rf.mean_s.into()),
                ("events_per_s", fault_events_per_s.into()),
                ("crashes", (crashes as usize).into()),
                ("injected_retries", (retries as usize).into()),
                ("hedges", (hedges as usize).into()),
                ("dropped", (dropped as usize).into()),
            ]),
        ),
    ]);

    let out = Json::from_pairs(vec![
        ("schema", "descnet-bench-dse-v7".into()),
        ("status", "recorded".into()),
        (
            "cacti_cache",
            Json::from_pairs(vec![
                ("geometries", cache::global().len().into()),
                ("hits", cache::global().hits().into()),
                ("misses", cache::global().misses().into()),
            ]),
        ),
        ("networks", Json::Arr(nets_json)),
        ("multi_network", multi_json),
        ("fleet", fleet_json),
        ("evaluator_scaling", scaling_json),
    ]);
    let path = std::path::Path::new("BENCH_dse.json");
    out.write_file(path).expect("writing BENCH_dse.json");
    println!("wrote {}", path.display());
}

fn pruning_json(st: &descnet::dse::stream::SweepStats) -> Json {
    Json::from_pairs(vec![
        ("enumerated", st.enumerated.into()),
        ("pruned", st.pruned.into()),
        ("evaluated", st.evaluated.into()),
        ("pruned_fraction", st.pruned_fraction().into()),
        ("subtrees", st.subtrees.into()),
        ("subtrees_pruned", st.subtrees_pruned.into()),
        ("archive_inserts", st.archive_inserts.into()),
        ("archive_len", st.archive_len.into()),
        ("mean_bound_gap", st.mean_bound_gap().into()),
        ("prep_s", st.prep_s.into()),
        ("eval_s", st.eval_s.into()),
    ])
}

fn threads_key(threads: usize) -> &'static str {
    match threads {
        2 => "2",
        4 => "4",
        8 => "8",
        _ => "other",
    }
}
