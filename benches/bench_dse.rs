//! Hot-path benchmark: DSE enumeration + evaluation throughput (the L3
//! optimization target of EXPERIMENTS.md section Perf).  Reports configs/s
//! and thread scaling for both networks.

use descnet::config::{Accelerator, Technology};
use descnet::dataflow::profile_network;
use descnet::dse;
use descnet::model::{capsnet_mnist, deepcaps_cifar10};
use descnet::dse::heuristic::{anneal, AnnealOptions};
use descnet::util::bench::{throughput, time};

fn main() {
    let accel = Accelerator::default();
    let tech = Technology::default();

    for net in [capsnet_mnist(), deepcaps_cifar10()] {
        let profile = profile_network(&net, &accel);
        println!("== {} ==", net.name);

        let mut orgs = Vec::new();
        let r = time(&format!("{} enumerate", net.name), 3, || {
            orgs = dse::enumerate(&profile);
        });
        println!("    -> {} configurations, {}", orgs.len(), throughput(&r, orgs.len()));

        for threads in [1usize, 2, 4, 8] {
            let r = time(
                &format!("{} evaluate ({} threads)", net.name, threads),
                2,
                || {
                    std::hint::black_box(dse::evaluate_all(&orgs, &profile, &tech, threads));
                },
            );
            println!("    -> {}", throughput(&r, orgs.len()));
        }

        let points = dse::evaluate_all(&orgs, &profile, &tech, 8);
        time(&format!("{} pareto extraction", net.name), 5, || {
            std::hint::black_box(dse::pareto_indices(&points));
        });
        time(&format!("{} per-option selection", net.name), 5, || {
            std::hint::black_box(dse::select_per_option(&points));
        });

        // Heuristic (section V-D): speed/quality vs the exhaustive sweep.
        let hy_opt = points
            .iter()
            .filter(|p| p.option().starts_with("HY"))
            .map(|p| p.energy_j)
            .fold(f64::INFINITY, f64::min);
        // Iterations scaled to the space (DeepCaps' HY space is ~11x larger).
        let mut opts = AnnealOptions::default();
        opts.iterations = if net.name == "capsnet" { 2_000 } else { 30_000 };
        let iters_label = opts.iterations / 1000;
        let mut result = None;
        let r = time(
            &format!("{} simulated annealing ({}k iters)", net.name, iters_label),
            3,
            || {
                result = Some(anneal(&profile, &tech, &opts));
            },
        );
        let res = result.unwrap();
        println!(
            "    -> best {:.4} mJ vs exhaustive HY optimum {:.4} mJ ({:+.1}%), {} evals in {}",
            res.best.energy_j * 1e3,
            hy_opt * 1e3,
            (res.best.energy_j / hy_opt - 1.0) * 100.0,
            res.evaluations,
            descnet::util::units::fmt_time(r.mean_s),
        );
    }
}
