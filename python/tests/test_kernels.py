"""Kernel-vs-oracle correctness: every Pallas kernel against ref.py.

This is the CORE correctness signal for L1 (see DESIGN.md section 2): the HLO
the rust runtime executes is lowered from exactly these kernels, so numerical
agreement here transfers to the served model.

hypothesis sweeps shapes/dtypes/tiles; fixed tests pin the exact paper shapes
(CapsNet ClassCaps 1152x10x8x16, DeepCaps ClassCaps 2048x10x8x32).
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels as K
from compile.kernels import ref

SETTINGS = dict(max_examples=25, deadline=None)


def _rng(seed):
    return np.random.default_rng(seed)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=1e-4, atol=1e-5)


def _allclose(a, b, dtype=jnp.float32):
    np.testing.assert_allclose(
        np.asarray(a, np.float32), np.asarray(b, np.float32), **_tol(dtype)
    )


# ---------------------------------------------------------------- squash

@settings(**SETTINGS)
@given(
    n=st.integers(1, 300),
    d=st.integers(1, 64),
    tile=st.sampled_from([8, 32, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_squash_matches_ref(n, d, tile, seed):
    x = jnp.asarray(_rng(seed).normal(size=(n, d)).astype(np.float32) * 3.0)
    _allclose(K.squash(x, tile=tile), ref.squash(x))


@settings(**SETTINGS)
@given(n=st.integers(1, 64), d=st.integers(1, 32), seed=st.integers(0, 2**31 - 1))
def test_squash_bf16(n, d, seed):
    x = jnp.asarray(_rng(seed).normal(size=(n, d)).astype(np.float32)).astype(jnp.bfloat16)
    out = K.squash(x, tile=32)
    assert out.dtype == jnp.bfloat16
    _allclose(out, ref.squash(x), dtype=jnp.bfloat16)


def test_squash_norm_bound():
    # |squash(s)| < 1 always, and monotone in |s|.
    x = jnp.asarray(_rng(1).normal(size=(256, 16)).astype(np.float32) * 10)
    v = np.asarray(K.squash(x))
    norms = np.linalg.norm(v, axis=1)
    assert (norms < 1.0 + 1e-5).all()


def test_squash_zero_vector_is_finite():
    x = jnp.zeros((4, 8), jnp.float32)
    v = np.asarray(K.squash(x))
    assert np.isfinite(v).all()
    assert np.abs(v).max() < 1e-3


def test_squash_nd_reshapes():
    x = jnp.asarray(_rng(2).normal(size=(6, 6, 32, 8)).astype(np.float32))
    _allclose(K.squash_nd(x), ref.squash(x))


# ---------------------------------------------------------------- votes

@settings(**SETTINGS)
@given(
    ni=st.integers(1, 160),
    no=st.integers(1, 12),
    di=st.sampled_from([4, 8, 16]),
    do=st.sampled_from([8, 16, 32]),
    tile=st.sampled_from([16, 64, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_votes_matches_ref(ni, no, di, do, tile, seed):
    r = _rng(seed)
    u = jnp.asarray(r.normal(size=(ni, di)).astype(np.float32))
    w = jnp.asarray(r.normal(size=(ni, no, di, do)).astype(np.float32) * 0.1)
    _allclose(K.votes(u, w, tile=tile), ref.votes(u, w))


def test_votes_capsnet_classcaps_shape():
    # Exact Google-CapsNet ClassCaps geometry: 1152 caps x 8D -> 10 caps x 16D.
    r = _rng(3)
    u = jnp.asarray(r.normal(size=(1152, 8)).astype(np.float32))
    w = jnp.asarray(r.normal(size=(1152, 10, 8, 16)).astype(np.float32) * 0.05)
    out = K.votes(u, w)
    assert out.shape == (1152, 10, 16)
    _allclose(out, ref.votes(u, w))


def test_votes_bf16_dtype_propagates():
    r = _rng(4)
    u = jnp.asarray(r.normal(size=(32, 8))).astype(jnp.bfloat16)
    w = jnp.asarray(r.normal(size=(32, 4, 8, 16)) * 0.1).astype(jnp.bfloat16)
    out = K.votes(u, w, tile=16)
    assert out.dtype == jnp.bfloat16
    _allclose(out, ref.votes(u, w), dtype=jnp.bfloat16)


# ---------------------------------------------------------------- routing

@settings(**SETTINGS)
@given(
    ni=st.integers(1, 200),
    no=st.integers(1, 12),
    do=st.sampled_from([4, 8, 16]),
    tile=st.sampled_from([16, 64, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_softmax_sum_matches_ref(ni, no, do, tile, seed):
    r = _rng(seed)
    b = jnp.asarray(r.normal(size=(ni, no)).astype(np.float32))
    uhat = jnp.asarray(r.normal(size=(ni, no, do)).astype(np.float32))
    c, s = K.softmax_sum(b, uhat, tile=tile)
    c_ref = ref.routing_softmax(b)
    _allclose(c, c_ref)
    _allclose(s, ref.routing_sum(c_ref, uhat))


@settings(**SETTINGS)
@given(
    ni=st.integers(1, 200),
    no=st.integers(1, 12),
    do=st.sampled_from([4, 8, 16]),
    tile=st.sampled_from([16, 64, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_update_matches_ref(ni, no, do, tile, seed):
    r = _rng(seed)
    b = jnp.asarray(r.normal(size=(ni, no)).astype(np.float32))
    uhat = jnp.asarray(r.normal(size=(ni, no, do)).astype(np.float32))
    v = jnp.asarray(r.normal(size=(no, do)).astype(np.float32))
    _allclose(K.update(b, uhat, v, tile=tile), ref.routing_update(b, uhat, v))


@settings(max_examples=10, deadline=None)
@given(
    ni=st.integers(2, 128),
    no=st.integers(2, 10),
    do=st.sampled_from([4, 8, 16]),
    iters=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_dynamic_routing_matches_ref(ni, no, do, iters, seed):
    uhat = jnp.asarray(_rng(seed).normal(size=(ni, no, do)).astype(np.float32))
    _allclose(
        K.dynamic_routing(uhat, num_iterations=iters, tile=32),
        ref.dynamic_routing(uhat, num_iterations=iters),
    )


def test_coupling_coefficients_are_distribution():
    # sum_j c_ij == 1 for every input capsule (softmax over output axis).
    r = _rng(7)
    b = jnp.asarray(r.normal(size=(96, 10)).astype(np.float32))
    uhat = jnp.asarray(r.normal(size=(96, 10, 16)).astype(np.float32))
    c, _ = K.softmax_sum(b, uhat, tile=32)
    np.testing.assert_allclose(np.asarray(c).sum(axis=1), np.ones(96), rtol=1e-5)


def test_routing_uniform_logits_equal_average():
    # With b == 0 the first Sum is the plain mean-like aggregation: s_j =
    # (1/NO-normalized) softmax weights, identical across i.
    r = _rng(8)
    uhat = jnp.asarray(r.normal(size=(64, 5, 8)).astype(np.float32))
    b = jnp.zeros((64, 5), jnp.float32)
    _, s = K.softmax_sum(b, uhat, tile=32)
    expected = np.asarray(uhat).sum(axis=0) / 5.0
    np.testing.assert_allclose(np.asarray(s), expected, rtol=1e-4, atol=1e-4)


def test_routing_agreement_increases_coupling():
    # An input capsule whose vote aligns with the output pose must gain
    # coupling relative to one voting orthogonally (paper section II-A).
    uhat = np.zeros((2, 2, 4), np.float32)
    uhat[0, 0] = [1, 0, 0, 0]     # capsule 0 votes strongly for output 0
    uhat[1, 0] = [-0.5, 0, 0, 0]  # capsule 1 votes (more weakly) against it
    uhat = jnp.asarray(uhat)
    b = jnp.zeros((2, 2), jnp.float32)
    b1, _ = K.routing_iteration(b, uhat, tile=2)
    b1 = np.asarray(b1)
    assert b1[0, 0] > b1[1, 0]


def test_margin_loss_reference_sanity():
    # Perfect prediction (long correct capsule, short others) -> near-zero loss.
    v = np.zeros((2, 10, 16), np.float32)
    v[0, 3, 0] = 0.95
    v[1, 7, 0] = 0.95
    loss = ref.margin_loss(jnp.asarray(v), jnp.asarray([3, 7]))
    assert float(loss) < 1e-3
    # Uniformly wrong -> large loss.
    v2 = np.full((2, 10, 16), 0.3, np.float32)
    loss2 = ref.margin_loss(jnp.asarray(v2), jnp.asarray([0, 0]))
    assert float(loss2) > float(loss)
