"""AOT bridge tests: DSCW weight serialization roundtrip, manifest
consistency, and HLO-text sanity (the rust loader's expectations)."""

import json
import os
import struct

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile import model as M

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def read_weights(path):
    """Independent DSCW v1 reader (deliberately not reusing aot.py code)."""
    out = {}
    with open(path, "rb") as f:
        assert f.read(4) == b"DSCW"
        version, count = struct.unpack("<II", f.read(8))
        assert version == 1
        for _ in range(count):
            (nlen,) = struct.unpack("<H", f.read(2))
            name = f.read(nlen).decode()
            code, ndim = struct.unpack("<BB", f.read(2))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim))
            (blen,) = struct.unpack("<Q", f.read(8))
            raw = f.read(blen)
            dtype = {0: "<f4", 1: "<i4"}[code]
            out[name] = np.frombuffer(raw, dtype=dtype).reshape(dims)
    return out


def test_weights_roundtrip(tmp_path):
    cfg = M.CapsNetConfig.small()
    params = M.init_capsnet(jax.random.PRNGKey(7), cfg)
    order = M.capsnet_param_order(cfg)
    path = tmp_path / "w.bin"
    aot.write_weights(str(path), params, order)
    back = read_weights(str(path))
    assert list(back) == order  # order-preserving
    for k in order:
        np.testing.assert_array_equal(back[k], np.asarray(params[k]))


def test_hlo_text_lowering_small():
    cfg = M.CapsNetConfig.small()
    params = M.init_capsnet(jax.random.PRNGKey(8), cfg)
    order = M.capsnet_param_order(cfg)
    fn = lambda p, x: M.capsnet_forward(p, x, cfg, use_pallas=False)
    lowered = aot.lower_stage(fn, order, params,
                              (1, cfg.image_hw, cfg.image_hw, 1))
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # Params + input = 6 HLO parameters, in the fixed order.
    assert text.count("parameter(") >= len(order) + 1


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.json")),
                    reason="artifacts not built (run `make artifacts`)")
class TestManifest:
    @pytest.fixture(autouse=True)
    def _load(self):
        with open(os.path.join(ART, "manifest.json")) as f:
            self.manifest = json.load(f)

    def test_format(self):
        assert self.manifest["format"] == "descnet-artifacts-v1"
        assert self.manifest["interchange"] == "hlo-text"

    def test_files_exist_and_are_hlo_text(self):
        for e in self.manifest["artifacts"]:
            path = os.path.join(ART, e["file"])
            assert os.path.exists(path), e["file"]
            with open(path) as f:
                head = f.read(64)
            assert head.startswith("HloModule"), e["file"]

    def test_weight_bundles_match_manifest_shapes(self):
        for wb in self.manifest["weights"]:
            weights = read_weights(os.path.join(ART, wb["file"]))
            assert list(weights) == wb["params"]
            for k, shape in wb["shapes"].items():
                assert list(weights[k].shape) == shape

    def test_capsnet_stage_shapes_chain(self):
        """conv1 output shape == primarycaps input shape etc. per batch."""
        by = {(e["stage"], e["batch"]): e for e in self.manifest["artifacts"]
              if e["net"] == "capsnet"}
        batches = sorted({b for (_, b) in by})
        for b in batches:
            conv1, prim = by[("conv1", b)], by[("primarycaps", b)]
            cls, full = by[("classcaps", b)], by[("full", b)]
            assert conv1["outputs"][0]["shape"] == prim["inputs"][0]["shape"]
            assert prim["outputs"][0]["shape"] == cls["inputs"][0]["shape"]
            assert full["inputs"][0]["shape"] == conv1["inputs"][0]["shape"]
            assert full["outputs"] == cls["outputs"]
            assert full["outputs"][0]["shape"] == [b, 10]

    def test_paper_geometry_in_manifest(self):
        full_b1 = next(e for e in self.manifest["artifacts"]
                       if e["name"] == "capsnet_full_b1")
        assert full_b1["inputs"][0]["shape"] == [1, 28, 28, 1]
        cls_b1 = next(e for e in self.manifest["artifacts"]
                      if e["name"] == "capsnet_classcaps_b1")
        assert cls_b1["inputs"][0]["shape"] == [1, 1152, 8]
