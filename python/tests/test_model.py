"""L2 model tests: shapes, stage composition, pallas-vs-oracle equivalence,
and a short end-to-end learning check on the synthetic-digits task."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import data
from compile import model as M
from compile.kernels import ref


@pytest.fixture(scope="module")
def small_cfg():
    return M.CapsNetConfig.small()


@pytest.fixture(scope="module")
def small_params(small_cfg):
    return M.init_capsnet(jax.random.PRNGKey(0), small_cfg)


def _digits(n, hw=28, seed=0):
    x, y = data.synthetic_digits(n, seed=seed, hw=hw)
    return jnp.asarray(x), jnp.asarray(y)


# ----------------------------------------------------------------- geometry

def test_google_config_matches_paper():
    cfg = M.CapsNetConfig.google()
    assert cfg.conv1_hw == 20           # 28 - 9 + 1
    assert cfg.primary_hw == 6          # (20 - 9) / 2 + 1
    assert cfg.num_primary_caps == 1152  # 6 * 6 * 32 capsule types
    assert cfg.class_caps_dim == 16


def test_deepcaps_full_config_matches_design():
    cfg = M.DeepCapsConfig.full()
    assert cfg.caps_channels == 256
    assert cfg.final_hw == 16
    assert cfg.num_final_caps == 8192
    # The 8 MiB vote buffer of DESIGN.md section 6:
    votes_bytes = cfg.final_hw**2 * cfg.caps_types * cfg.caps_types * cfg.caps_dim * 4
    assert votes_bytes == 8 * 1024 * 1024


def test_capsnet_param_shapes(small_cfg, small_params):
    assert small_params["conv1_w"].shape == (9, 9, 1, 32)
    assert small_params["class_w"].shape[0] == small_cfg.num_primary_caps
    order = M.capsnet_param_order(small_cfg)
    assert set(order) == set(small_params)


def test_deepcaps_param_order_covers_params():
    cfg = M.DeepCapsConfig.lite()
    params = M.init_deepcaps(jax.random.PRNGKey(1), cfg)
    order = M.deepcaps_param_order(cfg)
    assert set(order) == set(params)
    assert len(order) == len(set(order))


# ----------------------------------------------------------------- forward

def test_capsnet_forward_shapes(small_cfg, small_params):
    x, _ = _digits(3)
    lengths, v = M.capsnet_forward(small_params, x, small_cfg, use_pallas=False)
    assert lengths.shape == (3, 10)
    assert v.shape == (3, 10, small_cfg.class_caps_dim)
    assert np.isfinite(np.asarray(lengths)).all()
    # capsule lengths are squash outputs -> in (0, 1)
    assert (np.asarray(lengths) < 1.0).all() and (np.asarray(lengths) >= 0).all()


def test_capsnet_pallas_matches_oracle(small_cfg, small_params):
    x, _ = _digits(2)
    l_pal, v_pal = M.capsnet_forward(small_params, x, small_cfg, use_pallas=True)
    l_ref, v_ref = M.capsnet_forward(small_params, x, small_cfg, use_pallas=False)
    np.testing.assert_allclose(np.asarray(v_pal), np.asarray(v_ref),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(l_pal), np.asarray(l_ref),
                               rtol=1e-4, atol=1e-5)


def test_capsnet_stage_composition_equals_full(small_cfg, small_params):
    x, _ = _digits(2)
    h = M.capsnet_conv1(small_params, x, small_cfg)
    u = M.capsnet_primarycaps(small_params, h, small_cfg, use_pallas=False)
    l_st, v_st = M.capsnet_classcaps(small_params, u, small_cfg, use_pallas=False)
    l_full, v_full = M.capsnet_forward(small_params, x, small_cfg, use_pallas=False)
    np.testing.assert_allclose(np.asarray(v_st), np.asarray(v_full),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(l_st), np.asarray(l_full),
                               rtol=1e-5, atol=1e-6)


def test_primarycaps_squashed(small_cfg, small_params):
    x, _ = _digits(2)
    h = M.capsnet_conv1(small_params, x, small_cfg)
    u = M.capsnet_primarycaps(small_params, h, small_cfg, use_pallas=False)
    norms = np.linalg.norm(np.asarray(u), axis=-1)
    assert (norms < 1.0 + 1e-5).all()


def test_capsnet_batch_invariance(small_cfg, small_params):
    # Row i of a batch must equal the same image run at batch 1.
    x, _ = _digits(3)
    l_b, _ = M.capsnet_forward(small_params, x, small_cfg, use_pallas=False)
    l_1, _ = M.capsnet_forward(small_params, x[1:2], small_cfg, use_pallas=False)
    np.testing.assert_allclose(np.asarray(l_b[1:2]), np.asarray(l_1),
                               rtol=1e-5, atol=1e-6)


# ----------------------------------------------------------------- deepcaps

def test_deepcaps_lite_forward_shapes():
    cfg = M.DeepCapsConfig.lite()
    params = M.init_deepcaps(jax.random.PRNGKey(2), cfg)
    x = jnp.asarray(data.synthetic_cifar(2, hw=cfg.image_hw)[0])
    lengths, v = M.deepcaps_forward(params, x, cfg, use_pallas=False)
    assert lengths.shape == (2, 10)
    assert v.shape == (2, 10, cfg.class_caps_dim)
    assert np.isfinite(np.asarray(v)).all()


def test_deepcaps_pallas_matches_oracle():
    cfg = M.DeepCapsConfig.lite()
    params = M.init_deepcaps(jax.random.PRNGKey(3), cfg)
    x = jnp.asarray(data.synthetic_cifar(1, hw=cfg.image_hw)[0])
    l_pal, v_pal = M.deepcaps_forward(params, x, cfg, use_pallas=True)
    l_ref, v_ref = M.deepcaps_forward(params, x, cfg, use_pallas=False)
    np.testing.assert_allclose(np.asarray(v_pal), np.asarray(v_ref),
                               rtol=1e-3, atol=1e-4)


# ----------------------------------------------------------------- data

def test_synthetic_digits_separable():
    x, y = data.synthetic_digits(64, seed=0)
    assert x.shape == (64, 28, 28, 1) and x.dtype == np.float32
    assert x.min() >= 0 and x.max() <= 1
    assert len(np.unique(y)) > 3
    # Same class, same seed-stream -> images correlate more within class
    # than across (weak structural check).
    x2, y2 = data.synthetic_digits(64, seed=0)
    np.testing.assert_array_equal(y, y2)
    np.testing.assert_allclose(x, x2)


def test_synthetic_cifar_shapes():
    x, y = data.synthetic_cifar(8, hw=32)
    assert x.shape == (8, 32, 32, 3)
    assert (y >= 0).all() and (y < 10).all()


# ----------------------------------------------------------------- training

def test_margin_loss_decreases_quickly():
    from compile.train import train
    _, hist = train(steps=41, batch=8, cfg=M.CapsNetConfig.small(),
                    seed=0, verbose=False)
    assert hist[-1]["loss"] < hist[0]["loss"]
