"""L2 — JAX functional models: Google CapsNet (MNIST) and DeepCaps (CIFAR10).

Built from the L1 Pallas kernels (``compile.kernels``); the pure-jnp oracle
path (``use_pallas=False``) computes the identical function with ``ref.py``
ops and is used (a) as the correctness pin in tests and (b) for the fast
training demo, where interpret-mode Pallas in the backward pass would be
needlessly slow.

The *stage* functions (conv1 / primarycaps / classcaps) are the units the
rust coordinator schedules: ``aot.py`` lowers each stage (and the fused full
net) to one HLO-text artifact, and the rust performance model
(rust/src/dataflow) accounts cycles/memory for exactly the same stages.

Weights are passed as explicit arguments (not closed-over constants) so the
HLO stays small; ``aot.py`` serializes them to ``artifacts/*_weights.bin``
and the rust runtime feeds them as leading PJRT literals.
"""

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from . import kernels as K
from .kernels import ref

Params = Dict[str, jnp.ndarray]


# --------------------------------------------------------------------------
# Configurations
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CapsNetConfig:
    """Google's CapsNet [Sabour et al. 2017] geometry (MNIST)."""
    image_hw: int = 28
    image_c: int = 1
    conv1_channels: int = 256
    conv1_kernel: int = 9
    primary_channels: int = 256   # 32 capsule types x 8D
    primary_kernel: int = 9
    primary_stride: int = 2
    caps_dim: int = 8
    num_classes: int = 10
    class_caps_dim: int = 16
    routing_iterations: int = 3

    @property
    def conv1_hw(self) -> int:
        return self.image_hw - self.conv1_kernel + 1  # valid conv

    @property
    def primary_hw(self) -> int:
        return (self.conv1_hw - self.primary_kernel) // self.primary_stride + 1

    @property
    def num_primary_caps(self) -> int:
        return self.primary_hw * self.primary_hw * self.primary_channels // self.caps_dim

    @staticmethod
    def google() -> "CapsNetConfig":
        """The exact paper geometry: 20x20x256 conv1, 6x6x256 primary,
        1152 x 8D -> 10 x 16D ClassCaps."""
        return CapsNetConfig()

    @staticmethod
    def small() -> "CapsNetConfig":
        """Reduced geometry for fast CPU tests / the training demo."""
        return CapsNetConfig(conv1_channels=32, primary_channels=32)


@dataclasses.dataclass(frozen=True)
class DeepCapsConfig:
    """DeepCaps [Rajasegaran et al. 2019] geometry, adapted per DESIGN.md:
    4 cells x 4 ConvCaps2D (strides 2,2,1,1), 32 capsule types x 8D,
    3D ConvCaps with routing in the last cell, ClassCaps 10 x 32D."""
    image_hw: int = 64
    image_c: int = 3
    conv1_channels: int = 128
    caps_types: int = 32
    caps_dim: int = 8
    cell_strides: Tuple[int, ...] = (2, 2, 1, 1)
    convs_per_cell: int = 4        # 3 sequential + 1 parallel skip
    num_classes: int = 10
    class_caps_dim: int = 32
    routing_iterations: int = 3

    @property
    def caps_channels(self) -> int:
        return self.caps_types * self.caps_dim  # 256

    @property
    def final_hw(self) -> int:
        hw = self.image_hw
        for s in self.cell_strides:
            hw //= s
        return hw  # 16 for the full config

    @property
    def num_final_caps(self) -> int:
        return self.final_hw * self.final_hw * self.caps_types  # 8192

    @staticmethod
    def full() -> "DeepCapsConfig":
        return DeepCapsConfig()

    @staticmethod
    def lite() -> "DeepCapsConfig":
        """Runtime-servable reduction (CPU interpret-mode artifacts): 32x32
        input, 8 caps types x 8D, final 4x4 grid.  The analytical model in
        rust uses full(); see DESIGN.md section Substitutions."""
        return DeepCapsConfig(
            image_hw=32,
            conv1_channels=32,
            caps_types=8,
            cell_strides=(2, 2, 2, 1),
            class_caps_dim=16,
        )


# --------------------------------------------------------------------------
# Parameter initialization
# --------------------------------------------------------------------------

def _conv_init(key, kh, kw, cin, cout, scale=None):
    fan_in = kh * kw * cin
    scale = scale or (2.0 / fan_in) ** 0.5
    return jax.random.normal(key, (kh, kw, cin, cout), jnp.float32) * scale


def init_capsnet(key, cfg: CapsNetConfig = CapsNetConfig.google()) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "conv1_w": _conv_init(k1, cfg.conv1_kernel, cfg.conv1_kernel,
                              cfg.image_c, cfg.conv1_channels),
        "conv1_b": jnp.zeros((cfg.conv1_channels,), jnp.float32),
        "primary_w": _conv_init(k2, cfg.primary_kernel, cfg.primary_kernel,
                                cfg.conv1_channels, cfg.primary_channels),
        "primary_b": jnp.zeros((cfg.primary_channels,), jnp.float32),
        "class_w": jax.random.normal(
            k3, (cfg.num_primary_caps, cfg.num_classes,
                 cfg.caps_dim, cfg.class_caps_dim), jnp.float32) * 0.05,
    }


def capsnet_param_order(cfg: CapsNetConfig) -> List[str]:
    """Deterministic argument order used by aot.py and the rust runtime."""
    return ["conv1_w", "conv1_b", "primary_w", "primary_b", "class_w"]


def init_deepcaps(key, cfg: DeepCapsConfig = DeepCapsConfig.lite()) -> Params:
    keys = jax.random.split(key, 64)
    ki = iter(keys)
    params: Params = {
        "conv1_w": _conv_init(next(ki), 3, 3, cfg.image_c, cfg.conv1_channels),
        "conv1_b": jnp.zeros((cfg.conv1_channels,), jnp.float32),
    }
    cell_in = cfg.conv1_channels
    for cell in range(len(cfg.cell_strides)):
        for conv in range(cfg.convs_per_cell):
            # conv0 (sequential head) and the last conv (parallel skip) both
            # see the cell input; the middle sequential convs see caps_channels.
            cin = cell_in if conv in (0, cfg.convs_per_cell - 1) else cfg.caps_channels
            name = f"cell{cell}_conv{conv}"
            params[f"{name}_w"] = _conv_init(next(ki), 3, 3, cin, cfg.caps_channels)
            params[f"{name}_b"] = jnp.zeros((cfg.caps_channels,), jnp.float32)
        cell_in = cfg.caps_channels
    # 3D ConvCaps: per-(in-type, out-type) pose transforms, shared spatially.
    params["caps3d_w"] = jax.random.normal(
        next(ki), (cfg.caps_types, cfg.caps_types, cfg.caps_dim, cfg.caps_dim),
        jnp.float32) * 0.1
    params["class_w"] = jax.random.normal(
        next(ki), (cfg.num_final_caps, cfg.num_classes,
                   cfg.caps_dim, cfg.class_caps_dim), jnp.float32) * 0.03
    return params


def deepcaps_param_order(cfg: DeepCapsConfig) -> List[str]:
    order = ["conv1_w", "conv1_b"]
    for cell in range(len(cfg.cell_strides)):
        for conv in range(cfg.convs_per_cell):
            order += [f"cell{cell}_conv{conv}_w", f"cell{cell}_conv{conv}_b"]
    order += ["caps3d_w", "class_w"]
    return order


# --------------------------------------------------------------------------
# Shared pieces
# --------------------------------------------------------------------------

def _conv2d(x, w, b, stride=1, padding="VALID"):
    out = jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return out + b


def _squash_last(x, use_pallas: bool):
    if use_pallas:
        return K.squash_nd(x)
    return ref.squash(x)


def _classcaps(u, w, iterations, use_pallas: bool):
    """u: [B, NI, DI], w: [NI, NO, DI, DO] -> v: [B, NO, DO]."""
    if use_pallas:
        def one(ui):
            uhat = K.votes(ui, w)
            return K.dynamic_routing(uhat, num_iterations=iterations)
    else:
        def one(ui):
            return ref.classcaps(ui, w, num_iterations=iterations)
    return jax.vmap(one)(u)


def caps_lengths(v):
    """Output capsule lengths == class scores: [B, NO, DO] -> [B, NO]."""
    return jnp.sqrt(jnp.sum(jnp.square(v), axis=-1) + ref.EPS)


# --------------------------------------------------------------------------
# CapsNet stages (the units the rust coordinator schedules)
# --------------------------------------------------------------------------

def capsnet_conv1(params: Params, x, cfg: CapsNetConfig):
    """x: [B, 28, 28, 1] -> ReLU conv features [B, 20, 20, 256]."""
    return jax.nn.relu(_conv2d(x, params["conv1_w"], params["conv1_b"]))


def capsnet_primarycaps(params: Params, h, cfg: CapsNetConfig,
                        use_pallas: bool = True):
    """h: [B, 20, 20, 256] -> primary capsule poses [B, 1152, 8] (squashed)."""
    p = _conv2d(h, params["primary_w"], params["primary_b"],
                stride=cfg.primary_stride)
    b = p.shape[0]
    u = p.reshape(b, cfg.num_primary_caps, cfg.caps_dim)
    return _squash_last(u, use_pallas)


def capsnet_classcaps(params: Params, u, cfg: CapsNetConfig,
                      use_pallas: bool = True):
    """u: [B, 1152, 8] -> (lengths [B, 10], v [B, 10, 16])."""
    v = _classcaps(u, params["class_w"], cfg.routing_iterations, use_pallas)
    return caps_lengths(v), v


def capsnet_forward(params: Params, x, cfg: CapsNetConfig = CapsNetConfig.google(),
                    use_pallas: bool = True):
    """Full inference: x [B, 28, 28, 1] -> (lengths [B, 10], v [B, 10, 16])."""
    h = capsnet_conv1(params, x, cfg)
    u = capsnet_primarycaps(params, h, cfg, use_pallas)
    return capsnet_classcaps(params, u, cfg, use_pallas)


# --------------------------------------------------------------------------
# DeepCaps
# --------------------------------------------------------------------------

def _convcaps2d(x, w, b, stride, cfg: DeepCapsConfig, use_pallas: bool):
    """ConvCaps2D: conv over flattened capsule channels + squash per capsule."""
    p = _conv2d(x, w, b, stride=stride, padding="SAME")
    bsz, h, wd, _ = p.shape
    caps = p.reshape(bsz, h, wd, cfg.caps_types, cfg.caps_dim)
    caps = _squash_last(caps, use_pallas)
    return caps.reshape(bsz, h, wd, cfg.caps_channels)


def deepcaps_cell(params: Params, x, cell: int, cfg: DeepCapsConfig,
                  use_pallas: bool):
    """3 sequential ConvCaps2D + 1 parallel skip ConvCaps2D (summed), as in
    DeepCaps Fig 5: the skip branch sees the cell input."""
    stride = cfg.cell_strides[cell]
    seq = x
    for conv in range(cfg.convs_per_cell - 1):
        name = f"cell{cell}_conv{conv}"
        s = stride if conv == 0 else 1
        seq = _convcaps2d(seq, params[f"{name}_w"], params[f"{name}_b"],
                          s, cfg, use_pallas)
    name = f"cell{cell}_conv{cfg.convs_per_cell - 1}"
    skip = _convcaps2d(x, params[f"{name}_w"], params[f"{name}_b"],
                       stride, cfg, use_pallas)
    return seq + skip


def deepcaps_caps3d(params: Params, x, cfg: DeepCapsConfig, use_pallas: bool):
    """3D ConvCaps with dynamic routing: every spatial position's input
    capsule votes for each output capsule type via a spatially-shared
    transform; routing aggregates over (position x in-type).

    x: [B, S, S, 256] -> v3d: [B, caps_types, caps_dim].
    The vote buffer here is exactly the 8 MiB accumulator working set of the
    analytical model (DESIGN.md section 6)."""
    bsz, s, _, _ = x.shape
    ni = s * s * cfg.caps_types
    u = x.reshape(bsz, ni, cfg.caps_dim)
    # Spatially-shared transforms, tiled to per-input-capsule form.
    w = jnp.tile(params["caps3d_w"], (s * s, 1, 1, 1))  # [NI, CJ, D, D]
    if use_pallas:
        def one(ui):
            uhat = K.votes(ui, w)
            return K.dynamic_routing(uhat, num_iterations=cfg.routing_iterations)
    else:
        def one(ui):
            return ref.classcaps(ui, w, num_iterations=cfg.routing_iterations)
    return jax.vmap(one)(u)


def deepcaps_forward(params: Params, x,
                     cfg: DeepCapsConfig = DeepCapsConfig.lite(),
                     use_pallas: bool = True):
    """Full DeepCaps inference.

    x: [B, HW, HW, 3] -> (lengths [B, 10], v [B, 10, class_caps_dim]).
    The flattened final-cell capsules feed ClassCaps (FC caps with routing);
    the 3D-ConvCaps output poses modulate the class capsules additively on
    their leading dims (a faithful simplification of DeepCaps' concatenation,
    documented in DESIGN.md)."""
    h = jax.nn.relu(_conv2d(x, params["conv1_w"], params["conv1_b"],
                            padding="SAME"))
    for cell in range(len(cfg.cell_strides)):
        h = deepcaps_cell(params, h, cell, cfg, use_pallas)
    v3d = deepcaps_caps3d(params, h, cfg, use_pallas)          # [B, CT, D]

    bsz = h.shape[0]
    u = h.reshape(bsz, cfg.num_final_caps, cfg.caps_dim)
    v = _classcaps(u, params["class_w"], cfg.routing_iterations, use_pallas)
    # Inject the routed 3D-caps pose summary into the class capsules.
    pose = jnp.mean(v3d, axis=1)                               # [B, D]
    v = v + jnp.pad(pose, ((0, 0), (0, cfg.class_caps_dim - cfg.caps_dim))
                    )[:, None, :] * 0.1
    return caps_lengths(v), v


# --------------------------------------------------------------------------
# Stage table used by aot.py
# --------------------------------------------------------------------------

def capsnet_stage_fns(cfg: CapsNetConfig, use_pallas: bool = True):
    """Returns {stage_name: (fn(params, x) -> tuple, input_shape_fn(batch))}
    used by the AOT lowering and mirrored by rust/src/runtime/artifacts.rs."""
    hw, c1 = cfg.conv1_hw, cfg.conv1_channels

    return {
        "conv1": (
            lambda p, x: (capsnet_conv1(p, x, cfg),),
            lambda b: (b, cfg.image_hw, cfg.image_hw, cfg.image_c),
        ),
        "primarycaps": (
            lambda p, h: (capsnet_primarycaps(p, h, cfg, use_pallas),),
            lambda b: (b, hw, hw, c1),
        ),
        "classcaps": (
            lambda p, u: capsnet_classcaps(p, u, cfg, use_pallas),
            lambda b: (b, cfg.num_primary_caps, cfg.caps_dim),
        ),
        "full": (
            lambda p, x: capsnet_forward(p, x, cfg, use_pallas),
            lambda b: (b, cfg.image_hw, cfg.image_hw, cfg.image_c),
        ),
    }
