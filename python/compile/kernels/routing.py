"""Pallas kernels for one dynamic-routing iteration.

The iteration is decomposed into the same operations the CapsAcc schedule
executes (and that the rust performance model accounts for, see
DESIGN.md section 6):

  Softmax+Sum : c = softmax(b, axis=out); partial s_j accumulated per
                input-capsule tile                      (`_softmax_sum_kernel`)
  Squash      : v_j = squash(s_j)                       (kernels/squash.py)
  Update      : b   += <uhat_ij, v_j>                   (`_update_kernel`)

TPU mapping: the softmax reduction axis (output capsules, NO <= 32 for both
networks) is kept whole inside each block, so the grid only tiles the large
input-capsule axis (NI = 1152 for CapsNet, 2048 for DeepCaps ClassCaps).
Each grid step emits a *partial* vote sum; the (tiny, [G, NO, DO]) partials
are reduced by XLA outside the kernel.  This mirrors the accelerator, whose
16-PE accumulator row drains per-tile partial sums into the accumulator SPM.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref
from .squash import squash

# One grid step covers CapsNet's 1152-capsule axis: b (46 kB) + uhat
# (737 kB) + partials ~= 0.8 MB of VMEM << 16 MB (see votes.py note).
DEFAULT_TILE = 1152


def _softmax_sum_kernel(b_ref, uhat_ref, c_ref, s_ref):
    b = b_ref[...].astype(jnp.float32)            # [TI, NO]
    uhat = uhat_ref[...].astype(jnp.float32)      # [TI, NO, DO]
    m = jnp.max(b, axis=1, keepdims=True)
    e = jnp.exp(b - m)
    c = e / jnp.sum(e, axis=1, keepdims=True)     # [TI, NO]
    c_ref[...] = c.astype(c_ref.dtype)
    # Partial weighted vote sum for this input tile: s[n,d] = sum_i c*uhat.
    part = jnp.sum(c[:, :, None] * uhat, axis=0)  # [NO, DO]
    s_ref[...] = part[None].astype(s_ref.dtype)


def _update_kernel(b_ref, uhat_ref, v_ref, o_ref):
    b = b_ref[...].astype(jnp.float32)            # [TI, NO]
    uhat = uhat_ref[...].astype(jnp.float32)      # [TI, NO, DO]
    v = v_ref[...].astype(jnp.float32)            # [NO, DO]
    agreement = jnp.sum(uhat * v[None], axis=-1)  # [TI, NO]
    o_ref[...] = (b + agreement).astype(o_ref.dtype)


def _pad_rows(x, tile):
    pad = (-x.shape[0]) % tile
    if pad:
        x = jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1))
    return x


@functools.partial(jax.jit, static_argnames=("tile",))
def softmax_sum(b, uhat, tile=DEFAULT_TILE):
    """c = softmax(b, axis=1); s = sum_i c[i,:,None]*uhat[i].

    b: [NI, NO], uhat: [NI, NO, DO] -> (c: [NI, NO], s: [NO, DO]).
    """
    ni, no = b.shape
    do = uhat.shape[2]
    tile = min(tile, max(1, ni))
    bp, up = _pad_rows(b, tile), _pad_rows(uhat, tile)
    grid = (bp.shape[0] // tile,)
    c, s_parts = pl.pallas_call(
        _softmax_sum_kernel,
        out_shape=(
            jax.ShapeDtypeStruct(bp.shape, b.dtype),
            jax.ShapeDtypeStruct((grid[0], no, do), jnp.float32),
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, no), lambda i: (i, 0)),
            pl.BlockSpec((tile, no, do), lambda i: (i, 0, 0)),
        ],
        out_specs=(
            pl.BlockSpec((tile, no), lambda i: (i, 0)),
            pl.BlockSpec((1, no, do), lambda i: (i, 0, 0)),
        ),
        interpret=True,
    )(bp, up)
    # NOTE on padding correctness: padded b rows are all-zero -> softmax gives
    # uniform c, but the matching uhat rows are all-zero, so the partial sums
    # they contribute are exactly zero.
    s = jnp.sum(s_parts, axis=0).astype(uhat.dtype)
    return c[:ni], s


@functools.partial(jax.jit, static_argnames=("tile",))
def update(b, uhat, v, tile=DEFAULT_TILE):
    """b' = b + <uhat, v> ; b: [NI, NO], uhat: [NI, NO, DO], v: [NO, DO]."""
    ni, no = b.shape
    do = uhat.shape[2]
    tile = min(tile, max(1, ni))
    bp, up = _pad_rows(b, tile), _pad_rows(uhat, tile)
    grid = (bp.shape[0] // tile,)
    out = pl.pallas_call(
        _update_kernel,
        out_shape=jax.ShapeDtypeStruct(bp.shape, b.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, no), lambda i: (i, 0)),
            pl.BlockSpec((tile, no, do), lambda i: (i, 0, 0)),
            pl.BlockSpec((no, do), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile, no), lambda i: (i, 0)),
        interpret=True,
    )(bp, up, v)
    return out[:ni]


def routing_iteration(b, uhat, tile=DEFAULT_TILE):
    """One full iteration; returns (b_next, v).  Matches ref.routing_iteration."""
    _, s = softmax_sum(b, uhat, tile=tile)
    v = squash(s)
    b_next = update(b, uhat, v, tile=tile)
    return b_next, v


def dynamic_routing(uhat, num_iterations=3, tile=DEFAULT_TILE):
    """Unrolled dynamic routing (3 iterations in both paper networks).

    Unrolling (vs ``lax.fori_loop``) keeps the lowered HLO free of While ops,
    which compiles to a flatter module for the PJRT runtime; the L2 AOT step
    relies on this (see python/compile/aot.py and EXPERIMENTS.md section Perf/L2).
    """
    b = jnp.zeros(uhat.shape[:2], dtype=uhat.dtype)
    v = None
    for _ in range(num_iterations):
        b, v = routing_iteration(b, uhat, tile=tile)
    return v
