"""Pallas kernel for the capsule vote (prediction-vector) computation.

``uhat[i, j, :] = u[i, :] @ W[i, j, :, :]`` — the ClassCaps transformation
that feeds dynamic routing.  This is the MXU hot-spot of the ClassCaps layer:
each (input-tile, output-capsule) grid step performs a ``[TI, DI] x
[TI, DI, DO]`` batched contraction.

TPU mapping: the grid dimension ``i`` walks ``TI``-capsule tiles (HBM -> VMEM
streaming of u and W, double-buffered by the Pallas pipeline), ``j`` walks
output capsules, mirroring the output-capsule-stationary schedule of the
CapsAcc dataflow model (rust/src/dataflow/routing.rs).  VMEM footprint per
step = TI*DI + TI*DI*DO + TI*DO elements, far below the 16 MiB VMEM budget
for the CapsNet/DeepCaps shapes (see DESIGN.md section 10).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile sized to cover CapsNet's full input-capsule axis in one grid step
# (1152 caps): VMEM footprint per step = u (36.9 kB) + W (589 kB) + out
# (73.7 kB) ~= 0.7 MB << 16 MB, and interpret-mode grid-step overhead
# dominates CPU execution (EXPERIMENTS.md section Perf/L1: 3.6x on classcaps).
DEFAULT_TILE = 1152


def _votes_kernel(u_ref, w_ref, o_ref):
    u = u_ref[...].astype(jnp.float32)          # [TI, DI]
    w = w_ref[...].astype(jnp.float32)[:, 0]    # [TI, DI, DO]
    # Batched vector-matrix product over the capsule tile: one MXU pass per
    # input capsule row; contraction over DI.
    uhat = jax.lax.dot_general(
        u, w,
        dimension_numbers=(((1,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )                                            # [TI, DO]
    o_ref[...] = uhat[:, None, :].astype(o_ref.dtype)


def _pad_rows(x, tile):
    pad = (-x.shape[0]) % tile
    if pad:
        x = jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1))
    return x


@functools.partial(jax.jit, static_argnames=("tile",))
def votes(u, w, tile=DEFAULT_TILE):
    """u: [NI, DI], w: [NI, NO, DI, DO] -> uhat: [NI, NO, DO]."""
    ni, di = u.shape
    assert w.shape[0] == ni and w.shape[2] == di, (u.shape, w.shape)
    no, do = w.shape[1], w.shape[3]
    tile = min(tile, max(1, ni))
    up = _pad_rows(u, tile)
    wp = _pad_rows(w.astype(u.dtype), tile)
    grid = (up.shape[0] // tile, no)
    out = pl.pallas_call(
        _votes_kernel,
        out_shape=jax.ShapeDtypeStruct((up.shape[0], no, do), u.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, di), lambda i, j: (i, 0)),
            pl.BlockSpec((tile, 1, di, do), lambda i, j: (i, j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((tile, 1, do), lambda i, j: (i, j, 0)),
        interpret=True,
    )(up, wp)
    return out[:ni]
