"""L1 — Pallas kernels for the CapsNet compute hot-spots.

Modules:
  squash  : capsule squash non-linearity (VPU-style row kernel)
  votes   : capsule prediction vectors uhat = u @ W (MXU-style tiles)
  routing : fused Softmax+Sum and Update kernels for dynamic routing
  ref     : pure-jnp oracle, the correctness ground truth for all of the above

All kernels are lowered with ``interpret=True`` so the resulting HLO runs on
the CPU PJRT client (see /opt/xla-example/README.md for why real-TPU Mosaic
lowering cannot be executed here).
"""

from . import ref  # noqa: F401
from .squash import squash, squash_nd  # noqa: F401
from .votes import votes  # noqa: F401
from .routing import (  # noqa: F401
    softmax_sum,
    update,
    routing_iteration,
    dynamic_routing,
)
