"""Pure-jnp reference oracle for the DESCNet L1 kernels.

Every Pallas kernel in this package has a mathematically identical
implementation here, written with plain ``jax.numpy`` ops only.  The pytest
suite (``python/tests/test_kernels.py``) pins each kernel against its oracle
with ``assert_allclose`` over a hypothesis-driven sweep of shapes and dtypes.

The reference also *defines* the semantics used by the L2 models, so any
change to a kernel must keep this file in sync.
"""

import jax.numpy as jnp

EPS = 1e-7


def squash(s, axis=-1):
    """CapsNet squash non-linearity (Sabour et al., Eq. 1).

    v = (|s|^2 / (1 + |s|^2)) * s / |s|
    """
    norm2 = jnp.sum(jnp.square(s), axis=axis, keepdims=True)
    scale = norm2 / (1.0 + norm2) / jnp.sqrt(norm2 + EPS)
    return (s * scale).astype(s.dtype)


def votes(u, w):
    """Capsule prediction vectors (the ClassCaps transformation).

    u: [NI, DI]            input capsule poses
    w: [NI, NO, DI, DO]    per-(input, output)-pair transformation matrices
    returns uhat: [NI, NO, DO] with uhat[i, j] = u[i] @ w[i, j]
    """
    return jnp.einsum("id,indo->ino", u, w.astype(u.dtype)).astype(u.dtype)


def routing_softmax(b):
    """Coupling coefficients: softmax of the routing logits over the
    *output*-capsule axis (axis 1).  b: [NI, NO] -> c: [NI, NO]."""
    m = jnp.max(b, axis=1, keepdims=True)
    e = jnp.exp(b - m)
    return (e / jnp.sum(e, axis=1, keepdims=True)).astype(b.dtype)


def routing_sum(c, uhat):
    """Weighted vote aggregation: s[j] = sum_i c[i, j] * uhat[i, j].

    c: [NI, NO], uhat: [NI, NO, DO] -> s: [NO, DO]
    """
    return jnp.einsum("in,ind->nd", c, uhat).astype(uhat.dtype)


def routing_update(b, uhat, v):
    """Routing-logit update: b[i, j] += <uhat[i, j], v[j]>."""
    agreement = jnp.einsum("ind,nd->in", uhat, v.astype(uhat.dtype))
    return (b + agreement.astype(b.dtype)).astype(b.dtype)


def routing_iteration(b, uhat):
    """One full dynamic-routing iteration (Softmax -> Sum -> Squash -> Update).

    Returns (b_next, v).
    """
    c = routing_softmax(b)
    s = routing_sum(c, uhat)
    v = squash(s, axis=-1)
    b_next = routing_update(b, uhat, v)
    return b_next, v


def dynamic_routing(uhat, num_iterations=3):
    """Full dynamic-routing loop; returns the output capsule poses v: [NO, DO].

    The final iteration does not need the logit update to produce v, but the
    hardware schedule (and the paper's operation list) performs it anyway, so
    we keep the update for op-count parity with the performance model.
    """
    b = jnp.zeros(uhat.shape[:2], dtype=uhat.dtype)
    v = None
    for _ in range(num_iterations):
        b, v = routing_iteration(b, uhat)
    return v


def classcaps(u, w, num_iterations=3):
    """Fully-connected capsule layer with dynamic routing (votes + routing)."""
    return dynamic_routing(votes(u, w), num_iterations=num_iterations)


def margin_loss(v, labels, m_pos=0.9, m_neg=0.1, lam=0.5):
    """CapsNet margin loss over output capsule lengths.

    v: [B, NO, DO], labels: [B] int -> scalar loss.
    """
    lengths = jnp.sqrt(jnp.sum(jnp.square(v), axis=-1) + EPS)  # [B, NO]
    t = jnp.eye(lengths.shape[1], dtype=v.dtype)[labels]       # [B, NO]
    pos = t * jnp.square(jnp.maximum(0.0, m_pos - lengths))
    neg = (1.0 - t) * jnp.square(jnp.maximum(0.0, lengths - m_neg))
    return jnp.mean(jnp.sum(pos + lam * neg, axis=1))
