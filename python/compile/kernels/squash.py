"""Pallas kernel for the CapsNet squash non-linearity.

TPU mapping (see DESIGN.md Hardware-Adaptation): squash is a row-wise vector
op (norm + scale) — it runs on the VPU, with capsule poses tiled into VMEM in
``TN``-row blocks.  On CapsAcc this is the dedicated activation unit; the
BlockSpec row tile mirrors the 16-wide accumulator drain of the array.

Lowered with ``interpret=True`` so that the emitted HLO is executable on the
CPU PJRT client (real-TPU lowering emits a Mosaic custom-call).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Default row tile.  128 matches the VPU lane width on TPU; any positive
# value is functionally correct (the wrapper pads).
DEFAULT_TILE = 1024


def _squash_kernel(x_ref, o_ref):
    x = x_ref[...]
    f32 = x.astype(jnp.float32)
    norm2 = jnp.sum(f32 * f32, axis=-1, keepdims=True)
    scale = norm2 / (1.0 + norm2) / jnp.sqrt(norm2 + ref.EPS)
    o_ref[...] = (f32 * scale).astype(x.dtype)


def _pad_rows(x, tile):
    n = x.shape[0]
    pad = (-n) % tile
    if pad:
        x = jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1))
    return x, n


@functools.partial(jax.jit, static_argnames=("tile",))
def squash(s, tile=DEFAULT_TILE):
    """Squash over the last axis of ``s: [N, D]`` (2-D only; the L2 models
    flatten leading axes before calling)."""
    assert s.ndim == 2, f"squash kernel expects [N, D], got {s.shape}"
    x, n = _pad_rows(s, tile)
    grid = (x.shape[0] // tile,)
    out = pl.pallas_call(
        _squash_kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, s.dtype),
        grid=grid,
        in_specs=[pl.BlockSpec((tile, x.shape[1]), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((tile, x.shape[1]), lambda i: (i, 0)),
        interpret=True,
    )(x)
    return out[:n]


def squash_nd(s, tile=DEFAULT_TILE):
    """Squash over the last axis of an arbitrary-rank tensor by flattening
    the leading axes into the row dimension."""
    lead = s.shape[:-1]
    flat = s.reshape((-1, s.shape[-1]))
    return squash(flat, tile=tile).reshape(lead + (s.shape[-1],))
