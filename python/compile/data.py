"""Synthetic structured datasets (MNIST / CIFAR10 stand-ins).

The image has no network access and no dataset files, so — per DESIGN.md
section Substitutions — we generate *procedural* classification tasks whose
tensor shapes match the paper's benchmarks exactly:

  * ``synthetic_digits``  : 28x28x1, 10 classes of digit-like stroke
    patterns (each class = a fixed polyline skeleton, rendered with random
    translation/rotation/thickness/noise).  Learnable but non-trivial.
  * ``synthetic_cifar``   : HWxHWx3, 10 classes of oriented-texture patches.

Accuracy on these is NOT a paper claim (the paper inherits 99.67% / 92.74%
from [2]/[3]); they exist to give the training demo a real learning signal
and the serving path realistic inputs.
"""

import numpy as np

# Polyline skeletons (in a unit box) loosely tracing the 10 digits.
_DIGIT_STROKES = {
    0: [(0.3, 0.2), (0.7, 0.2), (0.8, 0.5), (0.7, 0.8), (0.3, 0.8), (0.2, 0.5), (0.3, 0.2)],
    1: [(0.5, 0.15), (0.5, 0.85)],
    2: [(0.25, 0.25), (0.6, 0.15), (0.75, 0.35), (0.3, 0.8), (0.75, 0.8)],
    3: [(0.3, 0.2), (0.7, 0.25), (0.45, 0.5), (0.7, 0.7), (0.3, 0.8)],
    4: [(0.65, 0.85), (0.65, 0.15), (0.25, 0.6), (0.8, 0.6)],
    5: [(0.7, 0.2), (0.3, 0.2), (0.3, 0.5), (0.65, 0.55), (0.6, 0.8), (0.3, 0.8)],
    6: [(0.6, 0.15), (0.35, 0.5), (0.3, 0.7), (0.5, 0.85), (0.7, 0.65), (0.4, 0.55)],
    7: [(0.25, 0.2), (0.75, 0.2), (0.45, 0.85)],
    8: [(0.5, 0.5), (0.3, 0.3), (0.5, 0.15), (0.7, 0.3), (0.5, 0.5), (0.3, 0.7), (0.5, 0.85), (0.7, 0.7), (0.5, 0.5)],
    9: [(0.65, 0.45), (0.45, 0.2), (0.3, 0.35), (0.55, 0.5), (0.65, 0.3), (0.55, 0.85)],
}


def _render_polyline(img, pts, thickness):
    h, w = img.shape
    for (x0, y0), (x1, y1) in zip(pts[:-1], pts[1:]):
        steps = max(2, int(3 * h))
        for t in np.linspace(0.0, 1.0, steps):
            cx, cy = (x0 + (x1 - x0) * t) * w, (y0 + (y1 - y0) * t) * h
            lo_y, hi_y = int(cy - thickness), int(cy + thickness) + 1
            lo_x, hi_x = int(cx - thickness), int(cx + thickness) + 1
            for yy in range(max(0, lo_y), min(h, hi_y)):
                for xx in range(max(0, lo_x), min(w, hi_x)):
                    d2 = (yy - cy) ** 2 + (xx - cx) ** 2
                    if d2 <= thickness ** 2:
                        img[yy, xx] = 1.0


def synthetic_digits(num, seed=0, hw=28):
    """Returns (images [N, hw, hw, 1] float32 in [0,1], labels [N] int32)."""
    rng = np.random.default_rng(seed)
    images = np.zeros((num, hw, hw, 1), np.float32)
    labels = rng.integers(0, 10, size=num).astype(np.int32)
    for n in range(num):
        pts = np.array(_DIGIT_STROKES[int(labels[n])], np.float64)
        # Random similarity transform: rotation, scale, translation.
        ang = rng.normal(0, 0.15)
        scale = rng.uniform(0.8, 1.1)
        ca, sa = np.cos(ang) * scale, np.sin(ang) * scale
        center = pts.mean(axis=0)
        pts = (pts - center) @ np.array([[ca, -sa], [sa, ca]]) + center
        pts += rng.normal(0, 0.03, size=2)
        img = np.zeros((hw, hw), np.float32)
        _render_polyline(img, pts, thickness=rng.uniform(0.9, 1.6))
        img += rng.normal(0, 0.05, size=img.shape).astype(np.float32)
        images[n, :, :, 0] = np.clip(img, 0.0, 1.0)
    return images, labels


def synthetic_cifar(num, seed=0, hw=32):
    """Oriented-texture patches, 10 classes: class k = sinusoidal grating at
    angle k*18deg with class-coloured channels + noise.
    Returns (images [N, hw, hw, 3] float32, labels [N] int32)."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 10, size=num).astype(np.int32)
    yy, xx = np.mgrid[0:hw, 0:hw].astype(np.float32) / hw
    images = np.zeros((num, hw, hw, 3), np.float32)
    for n in range(num):
        k = int(labels[n])
        ang = k * np.pi / 10.0 + rng.normal(0, 0.08)
        freq = rng.uniform(3.0, 5.0)
        phase = rng.uniform(0, 2 * np.pi)
        g = 0.5 + 0.5 * np.sin(2 * np.pi * freq * (xx * np.cos(ang) + yy * np.sin(ang)) + phase)
        tint = np.array([0.4 + 0.06 * k, 0.9 - 0.07 * k, 0.5 + 0.04 * ((k * 3) % 10)], np.float32)
        img = g[:, :, None] * tint[None, None, :]
        img += rng.normal(0, 0.05, size=img.shape)
        images[n] = np.clip(img, 0.0, 1.0)
    return images, labels
