"""Training demo (end-to-end validation, EXPERIMENTS.md E20).

Trains a CapsNet on the synthetic-digits task for a few hundred steps with
margin loss + Adam and logs the loss/accuracy curve to
``results/train_loss.csv``.  Build-time only — the served artifacts embed the
weights this script (or ``aot.py``'s fixed-seed init) produced; python never
runs at request time.

Uses the pure-jnp oracle path (``use_pallas=False``): interpret-mode Pallas
has no efficient VJP and tests pin it numerically equal to the oracle, so
training through the oracle is exact w.r.t. the served function.

Usage: cd python && python -m compile.train [--steps 300] [--small]
"""

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data
from .kernels import ref
from .model import CapsNetConfig, capsnet_forward, init_capsnet


def adam_init(params):
    z = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": z, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": 0}


def adam_update(params, grads, state, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mhat_scale = 1.0 / (1 - b1 ** t)
    vhat_scale = 1.0 / (1 - b2 ** t)
    params = jax.tree_util.tree_map(
        lambda p, mm, vv: p - lr * (mm * mhat_scale) / (jnp.sqrt(vv * vhat_scale) + eps),
        params, m, v)
    return params, {"m": m, "v": v, "t": t}


def make_step(cfg: CapsNetConfig):
    def loss_fn(params, x, y):
        _, v = capsnet_forward(params, x, cfg, use_pallas=False)
        return ref.margin_loss(v, y)

    @jax.jit
    def step(params, opt, x, y):
        loss, grads = jax.value_and_grad(loss_fn)(params, x, y)
        params, opt = adam_update(params, grads, opt)
        return params, opt, loss

    return step


@jax.jit
def _accuracy_scores(lengths, y):
    return jnp.mean((jnp.argmax(lengths, axis=1) == y).astype(jnp.float32))


def train(steps=300, batch=16, cfg=None, seed=0, log_path=None, verbose=True):
    """Returns (params, history) where history is a list of dicts."""
    cfg = cfg or CapsNetConfig.small()
    key = jax.random.PRNGKey(seed)
    params = init_capsnet(key, cfg)
    opt = adam_init(params)
    step_fn = make_step(cfg)

    # A fixed pool regenerated per epoch keeps memory flat and is equivalent
    # to streaming the procedural generator.
    pool_x, pool_y = data.synthetic_digits(1024, seed=seed, hw=cfg.image_hw)
    test_x, test_y = data.synthetic_digits(256, seed=seed + 1, hw=cfg.image_hw)
    test_x, test_y = jnp.asarray(test_x), jnp.asarray(test_y)

    rng = np.random.default_rng(seed)
    history = []
    t0 = time.time()
    for it in range(steps):
        idx = rng.integers(0, len(pool_x), size=batch)
        params, opt, loss = step_fn(params, opt,
                                    jnp.asarray(pool_x[idx]), jnp.asarray(pool_y[idx]))
        if it % 20 == 0 or it == steps - 1:
            lengths, _ = capsnet_forward(params, test_x, cfg, use_pallas=False)
            acc = float(_accuracy_scores(lengths, test_y))
            rec = {"step": it, "loss": float(loss), "test_acc": acc,
                   "elapsed_s": time.time() - t0}
            history.append(rec)
            if verbose:
                print(f"step {it:4d}  loss {rec['loss']:.4f}  "
                      f"test_acc {acc:.3f}  ({rec['elapsed_s']:.1f}s)")
    if log_path:
        os.makedirs(os.path.dirname(log_path), exist_ok=True)
        with open(log_path, "w") as f:
            f.write("step,loss,test_acc,elapsed_s\n")
            for rec in history:
                f.write(f"{rec['step']},{rec['loss']:.6f},"
                        f"{rec['test_acc']:.4f},{rec['elapsed_s']:.2f}\n")
    return params, history


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--google", action="store_true",
                    help="full Google geometry (slow on CPU); default: small")
    ap.add_argument("--out", default="../results/train_loss.csv")
    args = ap.parse_args()
    cfg = CapsNetConfig.google() if args.google else CapsNetConfig.small()
    _, history = train(steps=args.steps, batch=args.batch, cfg=cfg,
                       log_path=args.out)
    first, last = history[0], history[-1]
    print(f"loss {first['loss']:.4f} -> {last['loss']:.4f}; "
          f"test_acc {first['test_acc']:.3f} -> {last['test_acc']:.3f}")


if __name__ == "__main__":
    main()
