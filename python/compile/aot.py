"""AOT lowering: JAX models -> HLO-text artifacts + weights + manifest.

This is the single build-time bridge between python and rust:

  * every CapsNet *stage* (conv1 / primarycaps / classcaps) and the fused
    full net, at each serving batch size, becomes one ``artifacts/*.hlo.txt``
  * weights are serialized to ``artifacts/<net>_weights.bin`` (DSCW format,
    parsed by rust/src/runtime/weights.rs) and fed as leading PJRT literals —
    keeping weights out of the HLO keeps the text small and lets the same
    artifact serve retrained weights
  * ``artifacts/manifest.json`` indexes everything (shapes, dtypes, files)
    for rust/src/runtime/artifacts.rs

Interchange format is HLO **text**, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which the ``xla`` crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage: cd python && python -m compile.aot --out-dir ../artifacts
"""

import argparse
import json
import os
import struct

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M

WEIGHTS_MAGIC = b"DSCW"
WEIGHTS_VERSION = 1
_DTYPE_CODES = {"float32": 0, "int32": 1}


# --------------------------------------------------------------------------
# HLO text lowering (the gotcha-laden part — see module docstring)
# --------------------------------------------------------------------------

def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (return_tuple=True, so
    the rust side unwraps with ``to_tuple``)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_stage(fn, param_order, params, input_shape):
    """Lower ``fn(*flat_params, x)`` with params as explicit leading args in
    ``param_order`` — fixing the PJRT argument order the rust runtime uses."""
    def flat_fn(*args):
        p = dict(zip(param_order, args[:-1]))
        return fn(p, args[-1])

    specs = [jax.ShapeDtypeStruct(params[k].shape, params[k].dtype)
             for k in param_order]
    specs.append(jax.ShapeDtypeStruct(input_shape, jnp.float32))
    # keep_unused: each stage receives the full weight list so the PJRT
    # argument convention is uniform across stages (rust feeds all weights
    # plus the input to every stage).
    return jax.jit(flat_fn, keep_unused=True).lower(*specs)


# --------------------------------------------------------------------------
# Weights serialization (DSCW v1; mirrored by rust/src/runtime/weights.rs)
#
#   magic "DSCW" | u32 version | u32 count
#   per tensor:  u16 name_len | name utf8 | u8 dtype | u8 ndim
#                | u32 dims[ndim] | u64 byte_len | raw LE bytes
# --------------------------------------------------------------------------

def write_weights(path, params, order):
    with open(path, "wb") as f:
        f.write(WEIGHTS_MAGIC)
        f.write(struct.pack("<II", WEIGHTS_VERSION, len(order)))
        for name in order:
            arr = np.asarray(params[name])
            code = _DTYPE_CODES[str(arr.dtype)]
            nb = name.encode()
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", code, arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            raw = arr.astype("<" + str(arr.dtype)[0] + "4").tobytes()
            f.write(struct.pack("<Q", len(raw)))
            f.write(raw)


# --------------------------------------------------------------------------
# Artifact bundles
# --------------------------------------------------------------------------

def _shape_entry(shape):
    return {"shape": list(shape), "dtype": "f32"}


def build_capsnet(out_dir, batches, seed, use_pallas=True, stages=None):
    cfg = M.CapsNetConfig.google()
    params = M.init_capsnet(jax.random.PRNGKey(seed), cfg)
    order = M.capsnet_param_order(cfg)
    write_weights(os.path.join(out_dir, "capsnet_weights.bin"), params, order)

    stage_fns = M.capsnet_stage_fns(cfg, use_pallas=use_pallas)
    wanted = stages or list(stage_fns)
    entries = []
    for stage in wanted:
        fn, in_shape_fn = stage_fns[stage]
        for b in batches:
            in_shape = in_shape_fn(b)
            lowered = lower_stage(fn, order, params, in_shape)
            name = f"capsnet_{stage}_b{b}"
            fname = f"{name}.hlo.txt"
            text = to_hlo_text(lowered)
            with open(os.path.join(out_dir, fname), "w") as f:
                f.write(text)
            outs = jax.eval_shape(
                lambda *a: fn(dict(zip(order, a[:-1])), a[-1]),
                *[params[k] for k in order],
                jax.ShapeDtypeStruct(in_shape, jnp.float32))
            entries.append({
                "name": name, "file": fname, "net": "capsnet",
                "stage": stage, "batch": b,
                "params": order,
                "inputs": [_shape_entry(in_shape)],
                "outputs": [_shape_entry(o.shape) for o in outs],
            })
            print(f"  wrote {fname} ({len(text) / 1e6:.2f} MB)")
    return entries, {"net": "capsnet", "file": "capsnet_weights.bin",
                     "params": order,
                     "shapes": {k: list(params[k].shape) for k in order}}


def build_deepcaps_lite(out_dir, seed, use_pallas=True):
    cfg = M.DeepCapsConfig.lite()
    params = M.init_deepcaps(jax.random.PRNGKey(seed + 1), cfg)
    order = M.deepcaps_param_order(cfg)
    write_weights(os.path.join(out_dir, "deepcaps_lite_weights.bin"), params, order)

    def fn(p, x):
        return M.deepcaps_forward(p, x, cfg, use_pallas=use_pallas)

    in_shape = (1, cfg.image_hw, cfg.image_hw, cfg.image_c)
    lowered = lower_stage(fn, order, params, in_shape)
    fname = "deepcaps_lite_full_b1.hlo.txt"
    text = to_hlo_text(lowered)
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    outs = jax.eval_shape(
        lambda *a: fn(dict(zip(order, a[:-1])), a[-1]),
        *[params[k] for k in order],
        jax.ShapeDtypeStruct(in_shape, jnp.float32))
    print(f"  wrote {fname} ({len(text) / 1e6:.2f} MB)")
    entry = {
        "name": "deepcaps_lite_full_b1", "file": fname, "net": "deepcaps_lite",
        "stage": "full", "batch": 1, "params": order,
        "inputs": [_shape_entry(in_shape)],
        "outputs": [_shape_entry(o.shape) for o in outs],
    }
    return [entry], {"net": "deepcaps_lite", "file": "deepcaps_lite_weights.bin",
                     "params": order,
                     "shapes": {k: list(params[k].shape) for k in order}}


def write_golden(out_dir, seed, use_pallas=True):
    """Golden cross-check consumed by rust/tests/runtime_golden.rs: a fixed
    synthetic input and the expected full-net outputs, so the rust PJRT
    execution path is pinned numerically against this python session."""
    from . import data
    cfg = M.CapsNetConfig.google()
    params = M.init_capsnet(jax.random.PRNGKey(seed), cfg)
    x, _ = data.synthetic_digits(2, seed=1234, hw=cfg.image_hw)
    x = jnp.asarray(x[:1])
    lengths, v = M.capsnet_forward(params, x, cfg, use_pallas=use_pallas)
    golden = {
        "artifact": "capsnet_full_b1",
        "input": [float(f) for f in np.asarray(x).reshape(-1)],
        "lengths": [float(f) for f in np.asarray(lengths).reshape(-1)],
        "poses_l2": float(np.linalg.norm(np.asarray(v))),
        "tolerance": 2e-4,
    }
    with open(os.path.join(out_dir, "golden_capsnet.json"), "w") as f:
        json.dump(golden, f)
    print("  wrote golden_capsnet.json")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--batches", default="1,4")
    ap.add_argument("--seed", type=int, default=42)
    ap.add_argument("--no-deepcaps", action="store_true")
    ap.add_argument("--no-pallas", action="store_true",
                    help="lower the oracle path instead of the Pallas kernels")
    args = ap.parse_args()

    out_dir = os.path.abspath(args.out_dir)
    os.makedirs(out_dir, exist_ok=True)
    batches = [int(b) for b in args.batches.split(",")]
    use_pallas = not args.no_pallas

    print(f"AOT lowering -> {out_dir} (batches={batches}, pallas={use_pallas})")
    entries, caps_w = build_capsnet(out_dir, batches, args.seed, use_pallas)
    weights = [caps_w]
    if not args.no_deepcaps:
        dc_entries, dc_w = build_deepcaps_lite(out_dir, args.seed, use_pallas)
        entries += dc_entries
        weights.append(dc_w)

    write_golden(out_dir, args.seed, use_pallas)

    manifest = {
        "format": "descnet-artifacts-v1",
        "interchange": "hlo-text",
        "seed": args.seed,
        "artifacts": entries,
        "weights": weights,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"manifest: {len(entries)} artifacts, {len(weights)} weight bundles")


if __name__ == "__main__":
    main()
