//! Cycle-approximate performance timeline — latency as a first-class DSE
//! objective (DESIGN.md section 11).
//!
//! The analytical dataflow model (`crate::dataflow`) gives per-op *compute*
//! cycles; the prefetch analysis (`memory::prefetch`) checks the "no
//! performance loss" claim at infinite burst granularity.  This module sits
//! between them: an event timeline that schedules, per operation,
//!
//! * **off-chip DMA burst trains** — `off_rd + off_wr` bytes quantized to
//!   `Technology::dram_burst_bytes` bursts, delivered at the *effective*
//!   fill bandwidth `min(dram_bandwidth_bps, spm_banks x
//!   spm_bank_fill_bytes x clock)` (the SPM fill ports bound the on-chip
//!   side; the default 16 x 4 B @ 200 MHz exactly matches the 12.8 GB/s
//!   DRAM peak, so the paper configuration is never bank-limited).  The
//!   train pays the burst latency once — bursts are pipelined;
//! * **double-buffered SPM fills/drains** — each op (op 0 included)
//!   streams its own tiles double-buffered *during* its compute window
//!   (the CapsAcc schedule the dataflow module documents), so only the
//!   residue `max(0, dma - compute)` is exposed as a dma-stall.  A true
//!   cold start additionally pays op 0's input fill once, before the
//!   first frame can begin — reported as `cold_fill_cycles`, an
//!   upper-bound startup penalty on top of the per-frame figures;
//! * **compute occupancy** — the op's analytical cycles (CapsAcc-style PE
//!   utilization from the `OpProfile` MAC/stream/normalization model);
//! * **power-gating wake-ups** — when an organization's sector schedule
//!   turns additional sectors ON at an op boundary, the
//!   `cacti::powergate` wakeup latency must be masked by pre-activation
//!   during the *previous* op; any residue is a wakeup-stall
//!   ([`wakeup_exposure_s`]).  With the paper's 0.072 ns wakeup every
//!   boundary masks, which is exactly the "no performance loss" claim:
//!   gated and ungated organizations simulate to identical latency.
//!
//! The org-independent part lives in [`Timeline`] (built once per profile,
//! shared by every DSE evaluation); the org-dependent wakeup exposure is a
//! cheap second pass (`wakeup_exposure_s`, the single implementation used
//! by `dse::evaluate::area_energy_latency`, [`simulate`] and the
//! coordinator).  `rust/tests/sim_golden.rs` pins the goldens; the
//! cross-check against `pmu::evaluate`'s sector schedules lives in the
//! tests below.

use std::sync::Arc;

use anyhow::{ensure, Result};

use crate::config::{Accelerator, Technology};
use crate::dataflow::NetworkProfile;
use crate::memory::{cover_op, org_fits, Component, Organization};

/// What bounds one operation's duration on the timeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Bound {
    /// MAC array occupancy covers the DMA train: compute-bound.
    Compute,
    /// The DMA train outruns the compute window: dma-bound.
    Dma,
}

/// One operation's slot on the timeline (all quantities in cycles).
#[derive(Debug, Clone)]
pub struct OpLatency {
    /// Interned (shared with the source [`OpProfile`]): building or cloning
    /// a timeline bumps refcounts instead of copying strings.
    pub name: Arc<str>,
    /// Analytical busy cycles on the array (compute occupancy).
    pub compute_cycles: u64,
    /// Cycles the DMA train needs for this op's off-chip traffic.
    pub dma_cycles: u64,
    /// Exposed residue: `max(0, dma - compute)` (double-buffer overlap rule).
    pub dma_stall_cycles: u64,
    pub start_cycle: u64,
    pub end_cycle: u64,
}

impl OpLatency {
    pub fn duration_cycles(&self) -> u64 {
        self.compute_cycles + self.dma_stall_cycles
    }

    pub fn bound(&self) -> Bound {
        if self.dma_stall_cycles > 0 {
            Bound::Dma
        } else {
            Bound::Compute
        }
    }
}

/// Org-independent event timeline of one batch execution.
#[derive(Debug, Clone)]
pub struct Timeline {
    pub network: Arc<str>,
    pub ops: Vec<OpLatency>,
    pub clock_hz: f64,
    /// Inferences per batch execution (mirrors `NetworkProfile::batch`).
    pub batch: usize,
    /// One-time cold-start penalty [cycles]: on the very first frame after
    /// reset there is no previous frame to prefetch behind, so op 0's
    /// input fill is exposed before compute can begin.  Upper bound (the
    /// full op-0 fill; only the first tiles are strictly required).  The
    /// per-frame totals are unaffected — every frame, op 0 included,
    /// already charges its own in-window DMA streaming like any other op.
    pub cold_fill_cycles: u64,
    /// `min(dram_bandwidth_bps, banks x fill_bytes x clock)` [B/s].
    pub effective_fill_bps: f64,
}

impl Timeline {
    /// Builds the timeline for one profiled network.  Org-independent: the
    /// DSE builds this once and reuses it across every organization.
    pub fn build(profile: &NetworkProfile, tech: &Technology, accel: &Accelerator) -> Timeline {
        let clock = profile.clock_hz;
        let bank_bps =
            accel.spm_banks.max(1) as f64 * accel.spm_bank_fill_bytes.max(1) as f64 * clock;
        let eff_bps = tech.dram_bandwidth_bps.min(bank_bps);
        let burst = tech.dram_burst_bytes.max(1) as u64;
        let dma_cycles = |bytes: u64| -> u64 {
            if bytes == 0 {
                return 0;
            }
            let padded = bytes.div_ceil(burst) * burst;
            let transfer_s = tech.dram_latency_s + padded as f64 / eff_bps;
            (transfer_s * clock).ceil() as u64
        };

        let mut ops = Vec::with_capacity(profile.ops.len());
        let mut t = 0u64;
        let mut cold_fill = 0u64;
        for (i, op) in profile.ops.iter().enumerate() {
            let dma = dma_cycles(op.off_rd + op.off_wr);
            if i == 0 {
                cold_fill = dma_cycles(op.off_rd);
            }
            let stall = dma.saturating_sub(op.cycles);
            let start = t;
            let end = start + op.cycles + stall;
            t = end;
            ops.push(OpLatency {
                name: op.name.clone(),
                compute_cycles: op.cycles,
                dma_cycles: dma,
                dma_stall_cycles: stall,
                start_cycle: start,
                end_cycle: end,
            });
        }
        Timeline {
            network: profile.network.clone(),
            ops,
            clock_hz: clock,
            batch: profile.batch,
            cold_fill_cycles: cold_fill,
            effective_fill_bps: eff_bps,
        }
    }

    /// End-to-end steady-state cycles of one batch execution.
    pub fn total_cycles(&self) -> u64 {
        self.ops.last().map(|o| o.end_cycle).unwrap_or(0)
    }

    pub fn compute_cycles(&self) -> u64 {
        self.ops.iter().map(|o| o.compute_cycles).sum()
    }

    pub fn dma_stall_cycles(&self) -> u64 {
        self.ops.iter().map(|o| o.dma_stall_cycles).sum()
    }

    /// One batch execution [s] (steady state, no wakeup exposure).
    pub fn batch_latency_s(&self) -> f64 {
        self.total_cycles() as f64 / self.clock_hz
    }

    /// Per-inference latency [s], amortized over the batch.
    pub fn inference_latency_s(&self) -> f64 {
        self.batch_latency_s() / self.batch.max(1) as f64
    }

    /// Scheduled events (fill, compute, drain per op) — the bench unit.
    pub fn op_events(&self) -> usize {
        self.ops.len() * 3
    }

    pub fn op(&self, name: &str) -> Option<&OpLatency> {
        self.ops.iter().find(|o| o.name.as_ref() == name)
    }
}

/// Wakeup latency exposed by an organization's sector schedule over the
/// timeline [s].
///
/// For each op boundary where any power-gated component needs *more* ON
/// sectors than the previous op (an OFF->ON wake event, the same rule
/// `pmu::evaluate` schedules), the PMU pre-activates during the previous
/// op; the exposure is `max(0, wakeup_latency - prev_op_duration)` — zero
/// whenever the previous op outlasts one wakeup (the paper's masking
/// argument; components wake in parallel, so one residue per boundary).
/// Op 0's sectors wake during the previous frame and are never exposed.
///
/// Single implementation shared by `dse::evaluate::area_energy_latency`,
/// [`simulate`] and the coordinator — allocation-free, callers guarantee
/// the organization fits the profile (the DSE enumeration does by
/// construction; see [`simulate`] for the checked entry point).
pub fn wakeup_exposure_s(
    tl: &Timeline,
    profile: &NetworkProfile,
    org: &Organization,
    tech: &Technology,
) -> f64 {
    let wl = tech.wakeup_latency_s;
    if wl <= 0.0 {
        return 0.0;
    }
    // Always-on (O(1)): a mismatched timeline would silently pair wake
    // charges with the wrong ops (lint rule debug_guard, ISSUE 9).
    assert_eq!(tl.ops.len(), profile.ops.len(), "timeline/profile mismatch");

    // Per-component sector geometry (shared, data, weight, acc).
    let mut sector_bytes = [0usize; 4];
    let mut sectors = [1usize; 4];
    for (idx, c) in Component::ALL.iter().enumerate() {
        if let Some(spec) = org.spec(*c) {
            sectors[idx] = spec.sectors;
            sector_bytes[idx] = (spec.size / spec.sectors.max(1)).max(1);
        }
    }
    if sectors.iter().all(|&s| s <= 1) {
        return 0.0; // nothing is gated
    }

    let mut prev_on = [0usize; 4];
    let mut exposure = 0.0;
    for (i, op) in profile.ops.iter().enumerate() {
        // The same Algorithm-1 residual coverage the PMU schedules with —
        // sharing `cover_op` keeps this pass and `pmu::evaluate` from ever
        // desynchronizing.  Callers guarantee the fit; an op that somehow
        // does not fit schedules no sectors here.
        let Some(cov) = cover_op(org, op) else {
            continue;
        };
        let needs = [cov.shared_total(), cov.ded_d, cov.ded_w, cov.ded_a];
        let mut wakes = false;
        for c in 0..4 {
            if sectors[c] <= 1 {
                continue;
            }
            let on = needs[c].div_ceil(sector_bytes[c]);
            if on > prev_on[c] && i > 0 {
                wakes = true;
            }
            prev_on[c] = on;
        }
        if wakes {
            // Division (not a reciprocal multiply) keeps this bit-identical
            // to `PmuReport::wakeup_exposure_s` over externally computed
            // durations — pinned by `wakeup_events_agree_with_pmu_schedule`.
            let prev_dur = tl.ops[i - 1].duration_cycles() as f64 / tl.clock_hz;
            exposure += (wl - prev_dur).max(0.0);
        }
    }
    exposure
}

/// Full per-op latency report for one organization (the reporting-path
/// counterpart of the DSE fast path; `descnet analyze --sim` prints it).
#[derive(Debug, Clone)]
pub struct LatencyProfile {
    pub label: String,
    pub timeline: Timeline,
    /// Wakeup latency not masked by pre-activation [s] (0 at the paper's
    /// 0.072 ns wakeup — the "no performance loss" claim).
    pub wakeup_exposure_s: f64,
}

impl LatencyProfile {
    pub fn batch_latency_s(&self) -> f64 {
        self.timeline.batch_latency_s() + self.wakeup_exposure_s
    }

    pub fn inference_latency_s(&self) -> f64 {
        self.batch_latency_s() / self.timeline.batch.max(1) as f64
    }

    pub fn wakeup_stall_cycles(&self) -> u64 {
        (self.wakeup_exposure_s * self.timeline.clock_hz).ceil() as u64
    }

    /// Busy/stall split: (compute, dma-stall, wakeup-stall) cycles.
    pub fn breakdown_cycles(&self) -> (u64, u64, u64) {
        (
            self.timeline.compute_cycles(),
            self.timeline.dma_stall_cycles(),
            self.wakeup_stall_cycles(),
        )
    }
}

/// Simulates one organization over one profiled network; errors when the
/// organization cannot hold an operation's working set.
pub fn simulate(
    profile: &NetworkProfile,
    org: &Organization,
    tech: &Technology,
    accel: &Accelerator,
) -> Result<LatencyProfile> {
    ensure!(
        org_fits(org, profile),
        "organization {} does not fit '{}' (an operation's working set overflows)",
        org.label(),
        profile.network
    );
    let timeline = Timeline::build(profile, tech, accel);
    let exposure = wakeup_exposure_s(&timeline, profile, org, tech);
    Ok(LatencyProfile {
        label: org.label(),
        timeline,
        wakeup_exposure_s: exposure,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::{profile_network, profile_network_batched};
    use crate::memory::MemSpec;
    use crate::model::{capsnet_mnist, deepcaps_cifar10};
    use crate::pmu;
    use crate::util::units::KIB;

    fn capsnet_profile() -> NetworkProfile {
        profile_network(&capsnet_mnist(), &Accelerator::default())
    }

    fn sep_pg() -> Organization {
        Organization::sep(
            MemSpec::new(25 * KIB, 2),
            MemSpec::new(64 * KIB, 8),
            MemSpec::new(32 * KIB, 2),
        )
    }

    #[test]
    fn default_config_has_no_stalls_and_matches_analytic_cycles() {
        // The acceptance-shaping invariant: at the paper's configuration the
        // timeline adds nothing over the analytical cycle count — the
        // hierarchy hides all off-chip traffic (section VI-D).
        let tech = Technology::default();
        let accel = Accelerator::default();
        for net in [capsnet_mnist(), deepcaps_cifar10()] {
            let p = profile_network(&net, &accel);
            let tl = Timeline::build(&p, &tech, &accel);
            assert_eq!(tl.total_cycles(), p.total_cycles(), "{}", net.name);
            assert_eq!(tl.dma_stall_cycles(), 0, "{}", net.name);
            assert!(tl.cold_fill_cycles > 0, "{}", net.name);
            for op in &tl.ops {
                assert_eq!(op.bound(), Bound::Compute, "{}", op.name);
            }
        }
    }

    #[test]
    fn timeline_events_are_contiguous_and_ordered() {
        let tech = Technology::default();
        let accel = Accelerator::default();
        let tl = Timeline::build(&capsnet_profile(), &tech, &accel);
        let mut t = 0;
        for op in &tl.ops {
            assert_eq!(op.start_cycle, t, "{}", op.name);
            assert_eq!(op.end_cycle, op.start_cycle + op.duration_cycles());
            t = op.end_cycle;
        }
        assert_eq!(tl.total_cycles(), t);
        assert_eq!(tl.op_events(), tl.ops.len() * 3);
    }

    #[test]
    fn starved_bandwidth_stalls_and_classifies_dma_bound() {
        let mut tech = Technology::default();
        tech.dram_bandwidth_bps = 100e6; // 100 MB/s
        let accel = Accelerator::default();
        let p = capsnet_profile();
        let tl = Timeline::build(&p, &tech, &accel);
        assert!(tl.dma_stall_cycles() > 0);
        assert!(tl.total_cycles() > p.total_cycles());
        // The weight-heavy PrimaryCaps fetch must be dma-bound now.
        assert_eq!(tl.op("Prim").unwrap().bound(), Bound::Dma);
        // Mid-routing ops move no off-chip bytes: still compute-bound.
        assert_eq!(
            tl.op("Class-Sum+Squash2").unwrap().bound(),
            Bound::Compute
        );
    }

    #[test]
    fn prefetch_is_the_timeline_bit_exact() {
        // The "no performance loss" claim has one implementation: the
        // prefetch report is a view over this timeline, so per-op stalls
        // must agree bit-exactly in every bandwidth regime — including a
        // starved one where the stalls are non-zero.
        use crate::memory::prefetch;
        for (bw, burst) in [(12.8e9, 4096usize), (400e6, 64), (100e6, 4096)] {
            let mut tech = Technology::default();
            tech.dram_bandwidth_bps = bw;
            tech.dram_burst_bytes = burst;
            let accel = Accelerator::default();
            let p = capsnet_profile();
            let tl = Timeline::build(&p, &tech, &accel);
            let pf = prefetch::analyze(&p, &tech, &accel);
            assert_eq!(tl.dma_stall_cycles(), pf.total_stall_cycles, "bw {bw}");
            for (slot, stall) in tl.ops.iter().zip(&pf.ops) {
                assert_eq!(slot.dma_stall_cycles, stall.stall_cycles, "{}", slot.name);
                assert_eq!(slot.compute_cycles, stall.compute_cycles, "{}", slot.name);
            }
        }
        // And starved bandwidth really does stall (the regime is exercised).
        let mut tech = Technology::default();
        tech.dram_bandwidth_bps = 100e6;
        let pf = prefetch::analyze(&capsnet_profile(), &tech, &Accelerator::default());
        assert!(pf.total_stall_cycles > 0);
    }

    #[test]
    fn fewer_banks_bound_fill_bandwidth() {
        let tech = Technology::default();
        let mut accel = Accelerator::default();
        accel.spm_banks = 4; // 3.2 GB/s fill — below the DRAM peak
        let p = capsnet_profile();
        let tl = Timeline::build(&p, &tech, &accel);
        assert!((tl.effective_fill_bps - 3.2e9).abs() < 1.0);
        // The weight-stream-bound ClassCaps consumes its 1.47 MB transform
        // stream at exactly the 16 B/cycle port rate; a 4-bank fill side
        // cannot keep up, so it stalls.
        assert!(tl.op("Class").unwrap().dma_stall_cycles > 0);
    }

    #[test]
    fn wakeup_is_masked_at_paper_constants() {
        let tech = Technology::default();
        let accel = Accelerator::default();
        let p = capsnet_profile();
        let lp = simulate(&p, &sep_pg(), &tech, &accel).unwrap();
        assert_eq!(lp.wakeup_exposure_s, 0.0);
        assert_eq!(lp.wakeup_stall_cycles(), 0);
        // ... so the gated design's latency equals the ungated timeline.
        let tl = Timeline::build(&p, &tech, &accel);
        assert_eq!(lp.batch_latency_s().to_bits(), tl.batch_latency_s().to_bits());
    }

    #[test]
    fn slow_wakeup_exposes_stalls_on_gated_orgs_only() {
        let mut tech = Technology::default();
        tech.wakeup_latency_s = 1.0; // absurd 1 s wakeup: nothing masks
        let accel = Accelerator::default();
        let p = capsnet_profile();
        let ungated = Organization::sep(
            MemSpec::new(25 * KIB, 1),
            MemSpec::new(64 * KIB, 1),
            MemSpec::new(32 * KIB, 1),
        );
        let lp_un = simulate(&p, &ungated, &tech, &accel).unwrap();
        let lp_pg = simulate(&p, &sep_pg(), &tech, &accel).unwrap();
        assert_eq!(lp_un.wakeup_exposure_s, 0.0);
        assert!(lp_pg.wakeup_exposure_s > 0.0);
        assert!(lp_pg.batch_latency_s() > lp_un.batch_latency_s());
    }

    #[test]
    fn wakeup_events_agree_with_pmu_schedule() {
        // The fast exposure pass and the PMU's reporting schedule must see
        // the same wake boundaries: with an unmaskable wakeup latency the
        // exposure equals the PMU-derived sum bit-exactly.
        let mut tech = Technology::default();
        tech.wakeup_latency_s = 0.5;
        let accel = Accelerator::default();
        let p = capsnet_profile();
        let org = sep_pg();
        let tl = Timeline::build(&p, &tech, &accel);
        let fast = wakeup_exposure_s(&tl, &p, &org, &tech);

        let report = pmu::evaluate(&org, &p, &tech).unwrap();
        let durations: Vec<f64> = tl
            .ops
            .iter()
            .map(|o| o.duration_cycles() as f64 / tl.clock_hz)
            .collect();
        let slow = report.wakeup_exposure_s(&durations, tech.wakeup_latency_s);
        assert_eq!(fast.to_bits(), slow.to_bits(), "fast {fast} vs pmu {slow}");
        assert!(fast > 0.0);
    }

    #[test]
    fn batched_latency_amortizes_per_inference() {
        let tech = Technology::default();
        let accel = Accelerator::default();
        let net = capsnet_mnist();
        let t1 = Timeline::build(&profile_network_batched(&net, &accel, 1), &tech, &accel);
        let t8 = Timeline::build(&profile_network_batched(&net, &accel, 8), &tech, &accel);
        assert!(t8.batch_latency_s() >= t1.batch_latency_s());
        assert!(t8.inference_latency_s() < t1.inference_latency_s());
    }

    #[test]
    fn unfitting_org_errors() {
        let tech = Technology::default();
        let accel = Accelerator::default();
        let p = capsnet_profile();
        let tiny = Organization::sep(
            MemSpec::new(8 * KIB, 1),
            MemSpec::new(8 * KIB, 1),
            MemSpec::new(8 * KIB, 1),
        );
        let err = simulate(&p, &tiny, &tech, &accel).unwrap_err();
        assert!(format!("{err:#}").contains("does not fit"), "{err:#}");
    }
}
