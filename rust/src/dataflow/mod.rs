//! CapsAcc dataflow model: maps each network operation onto the 16x16 NP
//! array and derives, analytically, the quantities the paper measures —
//! per-operation SPM working sets (Figs 1, 10a, 11a), read/write access
//! counts (Figs 10b/c, 11b/c), off-chip traffic (Figs 27, 28), and clock
//! cycles (Fig 9).
//!
//! The tiling/schedule policies and their calibration are documented in
//! DESIGN.md section 6; `rust/tests/paper_claims.rs` and
//! `rust/tests/workload_invariants.rs` pin the emergent maxima against the
//! paper's Table I/II sizes and the throughput/share claims (116 fps,
//! routing > 50%; 9.7 fps, ConvCaps2D ~= 73%).
//!
//! Scheduling summary:
//!  * Convolutions: weight tiles of 16x16 channel pairs double-buffered in
//!    the weight SPM; input rows stream through kh-row windows (or stay
//!    fully resident when a DeepCaps skip branch re-reads them and they fit
//!    below the residency threshold); 16 output channels accumulate per
//!    pass.
//!  * ClassCaps votes: input capsules resident in the data SPM, transform
//!    tiles of `classcaps_w_tile_caps` input capsules streamed through the
//!    weight SPM.
//!  * Dynamic routing: output-capsule-stationary — per-j vote tiles resident
//!    in the data SPM, coupling state (b, c) in the weight SPM, per-i
//!    normalization handled by the activation tail (the calibrated
//!    `routing_j_overhead_cap` serialization).  Off-chip is touched only by
//!    the first (vote fetch) and last (pose write-back) routing operations —
//!    the paper's pointer (4).
//!  * DeepCaps 3-D ConvCaps: spatially-shared transforms pinned in PE-local
//!    registers; the full vote tensor lives in an accumulator ring buffer
//!    (8 MiB minus one drained position slot overlaid by routing state).

pub mod tpu;

use std::sync::Arc;

use crate::config::Accelerator;
use crate::model::{LayerGroup, Network, OpKind, Operation, RoutingHalf};

/// Bytes of the 3-D ConvCaps vote tensor NOT buffered in the accumulator
/// ring: three position slots stay in flight (drained while the next is
/// computed), and their space is overlaid by the input-pose staging and the
/// routing/normalization state.  Keeps the ring + staging within 8 MiB —
/// the Table II accumulator size.
pub const VOTE_RING_OVERLAY: usize = 96 * 1024;

/// Everything the paper measures about one operation, per **batch**
/// execution (batch 1 == per inference, the paper's setting).
///
/// Batch semantics: each op processes the whole batch before the next op
/// runs, with weights resident across the batch — so weight *parameter*
/// traffic (conv/vote transform streams through the weight SPM, and the
/// weight off-chip fetch) is paid once per batch while activation,
/// accumulator, squash and per-sample routing-state work (the b/c
/// coupling state is also billed to the weight SPM) scale with the batch
/// size.  Working sets are per-sample (activations stream through sample
/// by sample), so coverage and SPM sizing are batch-invariant.
#[derive(Debug, Clone, PartialEq)]
pub struct OpProfile {
    /// Interned: cloning a profile (or building a [`sim::Timeline`] from
    /// one) bumps a refcount instead of copying the string.
    pub name: Arc<str>,
    pub group: LayerGroup,
    /// Clock cycles on the CapsAcc array.
    pub cycles: u64,
    /// SPM working sets [bytes] (Figs 1/10a/11a).
    pub usage_d: usize,
    pub usage_w: usize,
    pub usage_a: usize,
    /// SPM accesses (port transactions; D/W at byte granularity, A at
    /// word-update granularity — see DESIGN.md section 7).
    pub rd_d: u64,
    pub wr_d: u64,
    pub rd_w: u64,
    pub wr_w: u64,
    pub rd_a: u64,
    pub wr_a: u64,
    /// Off-chip traffic [bytes] (Figs 27/28; appendix Eqs 3-4).
    pub off_rd: u64,
    pub off_wr: u64,
    /// Compute work (for accelerator energy).
    pub macs: u64,
    pub act_ops: u64,
}

impl OpProfile {
    pub fn usage_total(&self) -> usize {
        self.usage_d + self.usage_w + self.usage_a
    }

    pub fn spm_accesses(&self) -> u64 {
        self.rd_d + self.wr_d + self.rd_w + self.wr_w + self.rd_a + self.wr_a
    }
}

/// Profile of a full network on the accelerator.
#[derive(Debug, Clone, PartialEq)]
pub struct NetworkProfile {
    pub network: Arc<str>,
    pub ops: Vec<OpProfile>,
    pub clock_hz: f64,
    /// Inferences per batch execution (op quantities are per batch).
    pub batch: usize,
}

impl NetworkProfile {
    pub fn total_cycles(&self) -> u64 {
        self.ops.iter().map(|o| o.cycles).sum()
    }

    /// One batch execution [s].
    pub fn batch_s(&self) -> f64 {
        self.total_cycles() as f64 / self.clock_hz
    }

    /// Per-inference latency [s]: the batch time amortized over the batch
    /// (compute-bound; the prefetcher check in `memory::prefetch` verifies
    /// off-chip latency is hidden).
    pub fn inference_s(&self) -> f64 {
        self.batch_s() / self.batch.max(1) as f64
    }

    /// Per-inference throughput (amortized over the batch).
    pub fn fps(&self) -> f64 {
        1.0 / self.inference_s()
    }

    /// Component-wise maxima -> the SEP sizes of Eq. 2.
    pub fn max_d(&self) -> usize {
        self.ops.iter().map(|o| o.usage_d).max().unwrap_or(0)
    }

    pub fn max_w(&self) -> usize {
        self.ops.iter().map(|o| o.usage_w).max().unwrap_or(0)
    }

    pub fn max_a(&self) -> usize {
        self.ops.iter().map(|o| o.usage_a).max().unwrap_or(0)
    }

    /// Operation-wise maximum of D+W+A -> the SMP size of Eq. 1.
    pub fn max_total(&self) -> usize {
        self.ops.iter().map(|o| o.usage_total()).max().unwrap_or(0)
    }

    pub fn routing_cycle_share(&self) -> f64 {
        let routing: u64 = self
            .ops
            .iter()
            .filter(|o| o.group == LayerGroup::DynRouting)
            .map(|o| o.cycles)
            .sum();
        routing as f64 / self.total_cycles() as f64
    }

    pub fn group_cycle_share(&self, group: LayerGroup) -> f64 {
        let g: u64 = self
            .ops
            .iter()
            .filter(|o| o.group == group)
            .map(|o| o.cycles)
            .sum();
        g as f64 / self.total_cycles() as f64
    }

    pub fn total_off_chip(&self) -> u64 {
        self.ops.iter().map(|o| o.off_rd + o.off_wr).sum()
    }

    pub fn total_macs(&self) -> u64 {
        self.ops.iter().map(|o| o.macs).sum()
    }

    pub fn total_act_ops(&self) -> u64 {
        self.ops.iter().map(|o| o.act_ops).sum()
    }

    pub fn op(&self, name: &str) -> Option<&OpProfile> {
        self.ops.iter().find(|o| o.name.as_ref() == name)
    }
}

/// Profiles a whole network at batch 1 (the paper's setting).
pub fn profile_network(net: &Network, accel: &Accelerator) -> NetworkProfile {
    profile_network_batched(net, accel, 1)
}

/// Profiles a whole network for `batch` inferences per execution.  Batch 1
/// is bit-identical to [`profile_network`]; larger batches amortize weight
/// traffic (and, downstream, static/wakeup energy) per inference.
pub fn profile_network_batched(net: &Network, accel: &Accelerator, batch: usize) -> NetworkProfile {
    let batch = batch.max(1);
    NetworkProfile {
        network: net.name.as_str().into(),
        ops: net
            .ops
            .iter()
            .map(|op| profile_op_batched(op, accel, batch))
            .collect(),
        clock_hz: accel.clock_hz,
        batch,
    }
}

/// Profiles one operation at batch 1 (the core analytical model).
pub fn profile_op(op: &Operation, accel: &Accelerator) -> OpProfile {
    profile_op_batched(op, accel, 1)
}

/// Profiles one operation over a batch (see [`OpProfile`] for semantics).
pub fn profile_op_batched(op: &Operation, accel: &Accelerator, batch: usize) -> OpProfile {
    let b = batch.max(1) as u64;
    match &op.kind {
        OpKind::Conv2d {
            hin,
            win,
            cin,
            hout,
            wout,
            cout,
            kh,
            kw,
            squash_caps,
            skip_reuse,
            ..
        } => conv_profile(
            op,
            accel,
            b,
            (*hin, *win, *cin),
            (*hout, *wout, *cout),
            (*kh, *kw),
            *squash_caps,
            *skip_reuse,
        ),
        OpKind::Votes {
            ni,
            no,
            di,
            dout,
            weights_in_pe_regs,
            votes_in_acc,
        } => votes_profile(
            op,
            accel,
            b,
            *ni,
            *no,
            *di,
            *dout,
            *weights_in_pe_regs,
            *votes_in_acc,
        ),
        OpKind::Routing {
            ni,
            no,
            dout,
            iter,
            total_iters,
            half,
            votes_in_acc,
        } => routing_profile(
            op,
            accel,
            b,
            *ni,
            *no,
            *dout,
            *iter,
            *total_iters,
            *half,
            *votes_in_acc,
        ),
    }
}

#[allow(clippy::too_many_arguments)]
fn conv_profile(
    op: &Operation,
    accel: &Accelerator,
    b: u64,
    (hin, win, cin): (usize, usize, usize),
    (hout, wout, cout): (usize, usize, usize),
    (kh, kw): (usize, usize),
    squash_caps: usize,
    skip_reuse: bool,
) -> OpProfile {
    let db = accel.data_bytes;
    let pes = accel.pes() as u64;
    let macs = b * (hout * wout * cout * kh * kw * cin) as u64;
    let fmap_in = hin * win * cin * db; // per-sample (working-set) bytes
    let out_bytes = b * (hout * wout * cout * db) as u64;
    let params = op.param_bytes();

    // --- cycles: MAC-bound streaming + squash drain through the 16-lane
    // activation unit + pipeline fill/drain.  Weights are resident across
    // the batch, so the weight stream is paid once while MAC/squash work
    // scales with b.
    let squash_cycles =
        b * (squash_caps * accel.squash_cycles_per_elem / accel.array_cols.max(1)) as u64;
    // Weight-port bound: the weight SPM delivers one `array_cols`-byte row
    // per cycle, so layers whose weight volume outruns their MAC count (the
    // FC ClassCaps, notably) are weight-stream bound — as in CapsAcc.
    let w_stream = params / accel.array_cols as u64;
    let cycles = (macs / pes).max(w_stream) + squash_cycles + accel.op_overhead_cycles as u64;

    // --- working sets (DESIGN.md section 6 policies).
    let usage_d = if skip_reuse && fmap_in <= accel.fmap_resident_threshold {
        fmap_in // resident: the parallel skip branch re-reads it
    } else {
        kh * win * cin.min(accel.window_tci) * db * 2 // kh-row window, x2
    };
    let usage_w = kh * kw * cin.min(accel.array_rows) * cout.min(accel.array_cols) * db * 2;
    // Output-tile psums plus the array-edge drain/staging registers.
    let usage_a = hout * wout * cout.min(accel.array_cols) * accel.acc_bytes
        + accel.array_rows * accel.array_cols * accel.acc_bytes;

    // --- accesses (per batch: activation traffic x b, weight traffic x 1).
    let wr_d = b * fmap_in as u64; // filled from DRAM once per sample
    let rd_d = 2 * b * fmap_in as u64; // window-overlap re-reads (row-reuse regs)
    let rd_w = params;
    let wr_w = params;
    // One psum update per column per cycle -> macs/rows accumulator
    // read-modify-writes, plus the activation drain reads.
    let acc_updates = macs / accel.array_rows as u64;
    let rd_a = acc_updates + out_bytes;
    let wr_a = acc_updates;

    OpProfile {
        name: op.name.as_str().into(),
        group: op.group,
        cycles,
        usage_d,
        usage_w,
        usage_a,
        rd_d,
        wr_d,
        rd_w,
        wr_w,
        rd_a,
        wr_a,
        off_rd: wr_d + wr_w, // appendix Eq. 3
        off_wr: out_bytes,   // appendix Eq. 4
        macs,
        act_ops: b * (squash_caps + hout * wout * cout) as u64, // squash + relu
    }
}

#[allow(clippy::too_many_arguments)]
fn votes_profile(
    op: &Operation,
    accel: &Accelerator,
    b: u64,
    ni: usize,
    no: usize,
    di: usize,
    dout: usize,
    weights_in_pe_regs: bool,
    votes_in_acc: bool,
) -> OpProfile {
    let db = accel.data_bytes;
    let pes = accel.pes() as u64;
    let macs = b * (ni * no * di * dout) as u64;
    let params = op.param_bytes();
    let uhat_bytes = b * (ni * no * dout * db) as u64;

    // Weight-stream bound (see conv_profile): the 1.47 MB ClassCaps
    // transform stream at 16 B/cycle dominates its 5.8 k MAC cycles.
    let w_stream = if weights_in_pe_regs { 0 } else { params / accel.array_cols as u64 };
    let cycles = (macs / pes).max(w_stream) + accel.op_overhead_cycles as u64;

    let usage_d = ni * di * db; // input capsule poses resident (per sample)
    let usage_w = if weights_in_pe_regs {
        0 // spatially-shared transforms pinned in PE register files
    } else {
        accel.classcaps_w_tile_caps * no * di * dout * db // streamed tile
    };
    let usage_a = if votes_in_acc {
        // 3-D ConvCaps vote ring buffer: one sample's full vote tensor
        // minus one drained position slot (overlaid by routing state) —
        // stays <= 8 MiB.  Saturating: a generated network whose vote
        // tensor is smaller than the overlay simply has no residual ring.
        (ni * no * dout * accel.acc_bytes).saturating_sub(VOTE_RING_OVERLAY)
    } else {
        // psum staging for one output capsule across the 16 row-groups
        accel.array_rows * dout * accel.acc_bytes
    };

    let acc_updates = macs / accel.array_rows as u64;
    let (off_wr, wr_a_extra) = if votes_in_acc {
        (0, uhat_bytes / db as u64) // votes written into the acc SPM ring
    } else {
        (uhat_bytes, 0) // uhat drained to DRAM (re-fetched by routing op 1)
    };

    OpProfile {
        name: op.name.as_str().into(),
        group: op.group,
        cycles,
        usage_d,
        usage_w,
        usage_a,
        rd_d: b * (ni * di * no) as u64, // u re-read per output capsule
        wr_d: b * (ni * di) as u64,
        // PE-register-pinned transforms never touch the weight SPM (they
        // are loaded once from DRAM straight into the register files);
        // streamed transforms refill the SPM once per batch.
        rd_w: if weights_in_pe_regs { 0 } else { params },
        wr_w: if weights_in_pe_regs { 0 } else { params },
        rd_a: acc_updates,
        wr_a: acc_updates + wr_a_extra,
        off_rd: b * (ni * di) as u64 * db as u64 + params,
        off_wr,
        macs,
        act_ops: 0,
    }
}

#[allow(clippy::too_many_arguments)]
fn routing_profile(
    op: &Operation,
    accel: &Accelerator,
    b: u64,
    ni: usize,
    no: usize,
    dout: usize,
    iter: usize,
    total_iters: usize,
    half: RoutingHalf,
    votes_in_acc: bool,
) -> OpProfile {
    let db = accel.data_bytes;
    let pairs = b * (ni * no) as u64;
    let macs = pairs * dout as u64;
    let uhat_bytes = b * (ni * no * dout * db) as u64;
    let state_bytes = (ni * no * 2 * accel.routing_state_bytes) as u64;

    // --- cycles: one 16-long dot product per cycle on the PE row (so
    // pairs*dout/16), plus the per-output-capsule serialized normalization
    // tail, capped by the double-buffered normalization unit (DESIGN.md
    // section 6 calibration).  Routing state is per-sample, so the whole
    // body scales with b.
    let j_overhead = (ni * accel.routing_act_serial_cycles).min(accel.routing_j_overhead_cap);
    let cycles = pairs * dout as u64 / accel.array_rows as u64
        + b * (no * j_overhead) as u64
        + accel.op_overhead_cycles as u64;

    // --- working sets (per sample).
    let (usage_d, usage_w, usage_a);
    if votes_in_acc {
        // 3-D ConvCaps routing runs in place over the vote ring buffer;
        // state overlays the drained slot.
        usage_d = 0;
        usage_w = 0;
        usage_a = (ni * no * dout * accel.acc_bytes).saturating_sub(VOTE_RING_OVERLAY);
    } else {
        usage_d = ni * dout * db; // per-j vote tile
        usage_w = if state_bytes as usize <= 65_536 {
            state_bytes as usize // b and c fully resident
        } else {
            ni * 4 * accel.routing_state_bytes // streamed normalization state
        };
        usage_a = 2 * no * dout * accel.acc_bytes; // s_j / v_j staging
    }

    // --- accesses.
    let mut rd_d = 0;
    let mut wr_d = 0;
    let mut rd_w = 0;
    let mut wr_w = 0;
    let mut rd_a = 0;
    let mut wr_a = 0;
    let mut off_rd = 0;
    let mut off_wr = 0;
    let mut act_ops = 0u64;

    match half {
        RoutingHalf::SumSquash => {
            // s_j = sum_i c_ij uhat_ij ; v_j = squash(s_j)
            if votes_in_acc {
                rd_a += uhat_bytes / db as u64;
                rd_a += pairs; // c_ij (state overlaid in the acc ring)
            } else {
                rd_d += uhat_bytes;
                rd_w += pairs; // c_ij
            }
            wr_a += macs / accel.array_rows as u64; // psum updates
            rd_a += macs / accel.array_rows as u64;
            act_ops += b * (no * dout) as u64; // squash
            if iter == 1 && !votes_in_acc {
                // per-j vote tiles fetched from DRAM exactly once for the
                // whole routing phase — the paper's pointer (4).
                off_rd = uhat_bytes;
            }
        }
        RoutingHalf::UpdateSoftmax => {
            // b += <uhat, v> ; c = softmax(b)
            if votes_in_acc {
                rd_a += uhat_bytes / db as u64;
                rd_a += pairs; // b (state overlaid in the acc ring)
                wr_a += 2 * pairs;
            } else {
                rd_d += uhat_bytes;
                rd_w += pairs; // b
                wr_w += 2 * pairs; // b update + c write
            }
            rd_a += b * (no * dout) as u64; // v_j
            act_ops += pairs; // exp per coupling coefficient
            if iter == total_iters {
                // final poses written back (last routing op writes off-chip,
                // staged through whichever SPM holds the routing state)
                off_wr = b * (no * dout * accel.acc_bytes) as u64;
                if votes_in_acc {
                    wr_a += b * (no * dout) as u64;
                } else {
                    wr_d += b * (no * dout) as u64;
                }
            }
        }
    }

    OpProfile {
        name: op.name.as_str().into(),
        group: op.group,
        cycles,
        usage_d,
        usage_w,
        usage_a,
        rd_d,
        wr_d,
        rd_w,
        wr_w,
        rd_a,
        wr_a,
        off_rd,
        off_wr,
        macs,
        act_ops,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{capsnet_mnist, deepcaps_cifar10};
    use crate::util::units::KIB;

    fn capsnet_profile() -> NetworkProfile {
        profile_network(&capsnet_mnist(), &Accelerator::default())
    }

    fn deepcaps_profile() -> NetworkProfile {
        profile_network(&deepcaps_cifar10(), &Accelerator::default())
    }

    // ------------------------------------------------ Table I reproduction

    #[test]
    fn capsnet_component_maxima_match_table_i_pools() {
        let p = capsnet_profile();
        // Emergent maxima must land in the (prev_pool, pool] interval that
        // selects exactly the paper's Table I SEP sizes: 25/64/32 kiB.
        assert!(p.max_d() > 16 * KIB && p.max_d() <= 25 * KIB, "D={}", p.max_d());
        assert!(p.max_w() > 32 * KIB && p.max_w() <= 64 * KIB, "W={}", p.max_w());
        assert!(p.max_a() > 16 * KIB && p.max_a() <= 32 * KIB, "A={}", p.max_a());
        // And the SMP size: 108 kiB (between 64 kiB and 108 kiB).
        assert!(
            p.max_total() > 64 * KIB && p.max_total() <= 108 * KIB,
            "total={}",
            p.max_total()
        );
    }

    #[test]
    fn capsnet_exact_calibrated_working_sets() {
        let p = capsnet_profile();
        assert_eq!(p.op("Prim").unwrap().usage_d, 23_040);
        assert_eq!(p.op("Prim").unwrap().usage_w, 41_472);
        assert_eq!(p.op("Class").unwrap().usage_w, 53_760);
        assert_eq!(p.op("Conv1").unwrap().usage_a, 26_624);
        assert_eq!(p.op("Class-Sum+Squash1").unwrap().usage_d, 18_432);
    }

    #[test]
    fn primarycaps_is_largest_total_usage_op() {
        // Fig 1: "the overall size can be determined by the operation that
        // requires the largest amount of memory (the PrimaryCaps layer)".
        let p = capsnet_profile();
        let prim = p.op("Prim").unwrap().usage_total();
        for op in &p.ops {
            assert!(op.usage_total() <= prim, "{} exceeds Prim", op.name);
        }
    }

    #[test]
    fn weight_peak_is_at_classcaps() {
        // Fig 10 pointer (1): the weight-SPM peak is the FC ClassCaps.
        let p = capsnet_profile();
        let class_w = p.op("Class").unwrap().usage_w;
        assert_eq!(p.max_w(), class_w);
    }

    #[test]
    fn classcaps_data_usage_is_low() {
        // Fig 10 pointer (2).
        let p = capsnet_profile();
        assert!(p.op("Class").unwrap().usage_d < p.op("Prim").unwrap().usage_d / 2);
    }

    // ---------------------------------------------- performance (Fig 9a)

    #[test]
    fn capsnet_fps_close_to_paper_116() {
        let p = capsnet_profile();
        let fps = p.fps();
        assert!(
            (fps - 116.0).abs() / 116.0 < 0.05,
            "fps = {fps:.1}, paper reports 116"
        );
    }

    #[test]
    fn routing_exceeds_half_of_execution_time() {
        // "the dynamic routing operations contribute for more than half of
        // the execution time of the complete CapsNet inference"
        let p = capsnet_profile();
        let share = p.routing_cycle_share();
        assert!(share > 0.50 && share < 0.65, "share = {share:.3}");
    }

    // ------------------------------------------------ off-chip (Fig 27)

    #[test]
    fn routing_touches_offchip_only_at_boundaries() {
        // Pointer (4): reads only in the first routing op, writes only in
        // the last one.
        let p = capsnet_profile();
        let routing: Vec<_> = p
            .ops
            .iter()
            .filter(|o| o.group == LayerGroup::DynRouting)
            .collect();
        assert!(routing[0].off_rd > 0);
        assert_eq!(routing[0].off_wr, 0);
        for mid in &routing[1..routing.len() - 1] {
            assert_eq!(mid.off_rd + mid.off_wr, 0, "{} hits DRAM", mid.name);
        }
        let last = routing.last().unwrap();
        assert!(last.off_wr > 0);
        assert_eq!(last.off_rd, 0);
    }

    #[test]
    fn offchip_peak_at_primarycaps() {
        // Fig 27: "the peak of accesses are measured for the Prim layer"
        // (its 5.3M weights dominate).
        let p = capsnet_profile();
        let prim = p.op("Prim").unwrap();
        for op in &p.ops {
            assert!(op.off_rd <= prim.off_rd, "{}", op.name);
        }
    }

    #[test]
    fn accumulator_accesses_dominate() {
        // Section IV: "the accumulators have the major contributions in
        // memory usage and accesses".
        let p = capsnet_profile();
        let acc: u64 = p.ops.iter().map(|o| o.rd_a + o.wr_a).sum();
        let dw: u64 = p.ops.iter().map(|o| o.rd_d + o.wr_d + o.rd_w + o.wr_w).sum();
        assert!(acc > dw, "acc={acc} dw={dw}");
    }

    // ------------------------------------------------ Table II (DeepCaps)

    #[test]
    fn deepcaps_component_maxima_match_table_ii_pools() {
        let p = deepcaps_profile();
        const MIB: usize = 1024 * 1024;
        assert!(p.max_d() > 128 * KIB && p.max_d() <= 256 * KIB, "D={}", p.max_d());
        assert!(p.max_w() > 64 * KIB && p.max_w() <= 128 * KIB, "W={}", p.max_w());
        assert!(p.max_a() > 4 * MIB && p.max_a() <= 8 * MIB, "A={}", p.max_a());
        assert!(
            p.max_total() > 4 * MIB && p.max_total() <= 8 * MIB,
            "total={}",
            p.max_total()
        );
    }

    #[test]
    fn deepcaps_vote_ring_is_accumulator_peak() {
        let p = deepcaps_profile();
        let ring = p.op("Caps3D-Votes").unwrap().usage_a;
        assert_eq!(ring, 8 * 1024 * 1024 - VOTE_RING_OVERLAY);
        assert_eq!(p.max_a(), ring);
    }

    #[test]
    fn deepcaps_data_peak_is_resident_cell_input() {
        let p = deepcaps_profile();
        assert_eq!(p.max_d(), 256 * KIB); // cell-1 input 32x32x256 resident
        assert_eq!(p.op("Cell1-Conv0").unwrap().usage_d, 256 * KIB);
    }

    #[test]
    fn deepcaps_fps_close_to_paper() {
        let p = deepcaps_profile();
        let fps = p.fps();
        assert!((fps - 9.7).abs() / 9.7 < 0.12, "fps = {fps:.2}, paper 9.7");
    }

    #[test]
    fn convcaps2d_share_close_to_73_percent() {
        let p = deepcaps_profile();
        let share = p.group_cycle_share(LayerGroup::ConvCaps2D);
        assert!((0.66..=0.80).contains(&share), "share = {share:.3}");
    }

    #[test]
    fn deepcaps_weight_usage_low_in_convs_high_in_routing() {
        // Section IV-B: "usage and accesses for the weight memory are low in
        // the convolutional layers, but higher for the dynamic routing".
        let p = deepcaps_profile();
        let conv_w = p.op("Cell1-Conv1").unwrap().usage_w;
        let routing_w = p.op("Class-Update+Softmax1").unwrap().usage_w;
        assert!(routing_w > conv_w, "routing {routing_w} <= conv {conv_w}");
    }

    #[test]
    fn deepcaps_offchip_peak_at_classcaps_start() {
        // Fig 28 pointer (5): the off-chip peak is the ClassCaps weight
        // fetch.
        let p = deepcaps_profile();
        let class = p.op("Class").unwrap().off_rd;
        for op in &p.ops {
            assert!(op.off_rd <= class, "{}", op.name);
        }
    }

    #[test]
    fn caps3d_routing_never_touches_offchip() {
        // Votes live in the accumulator ring, so 3-D routing never reads
        // DRAM; only the final pose write-back (last op) leaves the chip.
        let p = deepcaps_profile();
        for op in &p.ops {
            if op.name.starts_with("Caps3D-Sum") || op.name.starts_with("Caps3D-Update") {
                assert_eq!(op.off_rd, 0, "{}", op.name);
                if op.name.as_ref() != "Caps3D-Update+Softmax3" {
                    assert_eq!(op.off_wr, 0, "{}", op.name);
                }
            }
        }
    }

    // ------------------------------------------------ cross-checks

    #[test]
    fn cycles_are_positive_and_finite_everywhere() {
        for p in [capsnet_profile(), deepcaps_profile()] {
            for op in &p.ops {
                assert!(op.cycles > 0, "{}", op.name);
                assert!(op.usage_total() > 0 || op.name.starts_with("Caps3D-"), "{}", op.name);
            }
        }
    }

    #[test]
    fn appendix_eq3_holds_for_feedforward_ops() {
        // RD_off_i = WR_D_i + WR_W_i for the conv stages.
        let p = capsnet_profile();
        for name in ["Conv1", "Prim"] {
            let op = p.op(name).unwrap();
            assert_eq!(op.off_rd, op.wr_d + op.wr_w, "{name}");
        }
    }

    #[test]
    fn faster_clock_same_cycles() {
        let mut accel = Accelerator::default();
        accel.clock_hz = 400e6;
        let p = profile_network(&capsnet_mnist(), &accel);
        let base = capsnet_profile();
        assert_eq!(p.total_cycles(), base.total_cycles());
        assert!((p.fps() - 2.0 * base.fps()).abs() < 0.5);
    }

    // ------------------------------------------------ batch parameterization

    #[test]
    fn batch_one_is_bit_identical_to_default_profile() {
        for net in [capsnet_mnist(), deepcaps_cifar10()] {
            let a = profile_network(&net, &Accelerator::default());
            let b = profile_network_batched(&net, &Accelerator::default(), 1);
            assert_eq!(a, b);
        }
    }

    #[test]
    fn batch_amortizes_weight_traffic_and_cycles() {
        let accel = Accelerator::default();
        let net = capsnet_mnist();
        let b1 = profile_network(&net, &accel);
        let b8 = profile_network_batched(&net, &accel, 8);
        assert_eq!(b8.batch, 8);

        // Weight *parameter* traffic (conv/vote transform streams) is paid
        // once per batch, not per inference; the routing ops' coupling
        // state is per-sample and scales with the batch instead.
        let w_param_traffic = |p: &NetworkProfile| -> u64 {
            p.ops
                .iter()
                .filter(|o| o.group != LayerGroup::DynRouting)
                .map(|o| o.rd_w + o.wr_w)
                .sum()
        };
        assert_eq!(w_param_traffic(&b1), w_param_traffic(&b8));
        let routing_state_traffic = |p: &NetworkProfile| -> u64 {
            p.ops
                .iter()
                .filter(|o| o.group == LayerGroup::DynRouting)
                .map(|o| o.rd_w + o.wr_w)
                .sum()
        };
        assert_eq!(8 * routing_state_traffic(&b1), routing_state_traffic(&b8));

        // The weight-stream-bound ClassCaps becomes MAC-bound: its batch-8
        // cycles are well below 8x its batch-1 cycles.
        let class1 = b1.op("Class").unwrap().cycles;
        let class8 = b8.op("Class").unwrap().cycles;
        assert!(class8 < 8 * class1, "{class8} vs 8x{class1}");

        // Per-inference throughput therefore improves with batching.
        assert!(b8.fps() > b1.fps(), "{} <= {}", b8.fps(), b1.fps());
        // ...and per-inference latency shrinks while batch latency grows.
        assert!(b8.inference_s() < b1.inference_s());
        assert!(b8.batch_s() > b1.batch_s());
    }

    #[test]
    fn batch_keeps_working_sets_and_scales_activation_traffic() {
        let accel = Accelerator::default();
        let net = deepcaps_cifar10();
        let b1 = profile_network(&net, &accel);
        let b4 = profile_network_batched(&net, &accel, 4);
        for (o1, o4) in b1.ops.iter().zip(&b4.ops) {
            // SPM sizing (coverage) is batch-invariant.
            assert_eq!(o1.usage_d, o4.usage_d, "{}", o1.name);
            assert_eq!(o1.usage_w, o4.usage_w, "{}", o1.name);
            assert_eq!(o1.usage_a, o4.usage_a, "{}", o1.name);
            // Activation-side traffic scales with the batch.
            assert_eq!(4 * o1.rd_d, o4.rd_d, "{}", o1.name);
            assert_eq!(4 * o1.wr_d, o4.wr_d, "{}", o1.name);
            // Compute scales exactly.
            assert_eq!(4 * o1.macs, o4.macs, "{}", o1.name);
        }
        // Eq. 3 (off_rd = wr_d + wr_w) survives batching for the convs.
        for name in ["Conv1", "Cell0-Conv0"] {
            let op = b4.op(name).unwrap();
            assert_eq!(op.off_rd, op.wr_d + op.wr_w, "{name}");
        }
    }

    #[test]
    fn tiny_vote_tensor_saturates_ring_overlay() {
        // A generated network whose 3-D vote tensor is below the ring
        // overlay must profile with an empty residual ring, not panic.
        let op = Operation {
            name: "TinyVotes".into(),
            group: LayerGroup::ConvCaps3D,
            kind: OpKind::Votes {
                ni: 64,
                no: 8,
                di: 4,
                dout: 4,
                weights_in_pe_regs: true,
                votes_in_acc: true,
            },
        };
        let p = profile_op(&op, &Accelerator::default());
        assert_eq!(p.usage_a, 0);
        assert!(p.cycles > 0);
    }
}
