//! TPU-like mapping used for the Fig 1 comparison: a weight-stationary
//! systolic array with a large *unified buffer* (activations) and a weight
//! FIFO fed from DRAM, as in Jouppi et al. (ISCA'17), scaled to an
//! edge-class deployment.
//!
//! The point Fig 1 makes is architectural, not absolute: a generic DNN
//! memory organization holds whole feature maps (and all uhat votes during
//! routing) in the unified buffer, so its per-op utilization profile is
//! much flatter and higher than CapsAcc's operation-tuned working sets,
//! leaving less room for sizing/power-gating specialization.

use crate::config::Accelerator;
use crate::model::{Network, OpKind};

/// Per-op on-chip usage [bytes] under the TPU-like mapping.
#[derive(Debug, Clone)]
pub struct TpuOpUsage {
    pub name: String,
    /// Unified buffer residency (input + output activations / votes).
    pub unified: usize,
    /// Weight FIFO residency (double-buffered layer weight stream).
    pub weight_fifo: usize,
    /// Accumulator residency (32-bit psums for the active output tile).
    pub accumulators: usize,
}

impl TpuOpUsage {
    pub fn total(&self) -> usize {
        self.unified + self.weight_fifo + self.accumulators
    }
}

/// Weight FIFO depth: 4 tiles of 256x256 8-bit weights (as in the TPU's
/// 4-tile FIFO, scaled from 64k MACs to this array).
const WEIGHT_FIFO_TILES: usize = 4;

pub fn profile_tpu(net: &Network, accel: &Accelerator) -> Vec<TpuOpUsage> {
    let db = accel.data_bytes;
    let fifo_tile = 256 * 256 * db;
    net.ops
        .iter()
        .map(|op| {
            let (unified, weights) = match &op.kind {
                OpKind::Conv2d {
                    hin,
                    win,
                    cin,
                    hout,
                    wout,
                    cout,
                    ..
                } => (
                    (hin * win * cin + hout * wout * cout) * db,
                    op.param_bytes() as usize,
                ),
                OpKind::Votes { ni, no, di, dout, .. } => (
                    // u and the full vote tensor live in the unified buffer.
                    (ni * di + ni * no * dout) * db,
                    op.param_bytes() as usize,
                ),
                OpKind::Routing { ni, no, dout, .. } => (
                    // Full votes + coupling state resident; routing executes
                    // as generic matmul/softmax kernels over the UB.
                    (ni * no * dout + 2 * ni * no) * db,
                    0,
                ),
            };
            let weight_fifo = weights.min(WEIGHT_FIFO_TILES * fifo_tile);
            let accumulators =
                match &op.kind {
                    OpKind::Conv2d { hout, wout, cout, .. } => {
                        hout * wout * (*cout).min(accel.array_cols) * 4
                    }
                    OpKind::Votes { no, dout, .. } | OpKind::Routing { no, dout, .. } => {
                        no * dout * 4 * accel.array_rows
                    }
                };
            TpuOpUsage {
                name: op.name.clone(),
                unified,
                weight_fifo,
                accumulators,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::profile_network;
    use crate::model::capsnet_mnist;

    #[test]
    fn tpu_usage_exceeds_capsacc_everywhere_it_matters() {
        // Fig 1's message: the generic mapping needs (much) more on-chip
        // memory per op than the CapsNet-tuned CapsAcc working sets.
        let net = capsnet_mnist();
        let accel = Accelerator::default();
        let tpu = profile_tpu(&net, &accel);
        let caps = profile_network(&net, &accel);
        let tpu_max = tpu.iter().map(|o| o.total()).max().unwrap();
        let caps_max = caps.max_total();
        assert!(
            tpu_max > 2 * caps_max,
            "tpu={tpu_max} capsacc={caps_max}"
        );
    }

    #[test]
    fn routing_holds_full_votes_in_unified_buffer() {
        let net = capsnet_mnist();
        let tpu = profile_tpu(&net, &Accelerator::default());
        let sum1 = tpu.iter().find(|o| o.name == "Class-Sum+Squash1").unwrap();
        // 1152*10*16 votes + 2*1152*10 state.
        assert_eq!(sum1.unified, 1152 * 10 * 16 + 2 * 1152 * 10);
    }

    #[test]
    fn weight_fifo_is_capped() {
        let net = capsnet_mnist();
        let tpu = profile_tpu(&net, &Accelerator::default());
        let prim = tpu.iter().find(|o| o.name == "Prim").unwrap();
        assert_eq!(prim.weight_fifo, 4 * 256 * 256); // capped at 4 FIFO tiles
    }

    #[test]
    fn profile_covers_every_op() {
        let net = capsnet_mnist();
        let tpu = profile_tpu(&net, &Accelerator::default());
        assert_eq!(tpu.len(), net.ops.len());
    }
}
