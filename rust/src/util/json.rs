//! Minimal JSON reader/writer (the image vendors no `serde`).
//!
//! Covers the full JSON grammar we produce/consume: the artifact manifest
//! written by `python/compile/aot.py`, the config files under `configs/`,
//! and the result dumps under `results/`.  Numbers are parsed as `f64`
//! (JSON's native model); integer accessors check exactness.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.  Object keys are kept sorted (BTreeMap) so that
/// serialization is deterministic — important for golden-file tests.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ------------------------------------------------------- constructors
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn from_pairs(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    // --------------------------------------------------------- accessors
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= 2f64.powi(53) => {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|v| v as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; `Json::Null` for missing keys / non-objects.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Array index lookup; `Json::Null` out of range.
    pub fn idx(&self, i: usize) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Arr(a) => a.get(i).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn set(&mut self, key: &str, value: Json) {
        if let Json::Obj(o) = self {
            o.insert(key.to_string(), value);
        }
    }

    pub fn usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    // ------------------------------------------------------------ parsing
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let bytes = text.as_bytes();
        let mut p = Parser { b: bytes, i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn parse_file(path: &std::path::Path) -> Result<Json, Box<dyn std::error::Error>> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("reading {}: {e}", path.display()))?;
        Ok(Json::parse(&text)?)
    }

    // ------------------------------------------------------- serialization
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(0));
        out
    }

    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None);
        out
    }

    pub fn write_file(&self, path: &std::path::Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_string_pretty())
    }

    fn write(&self, out: &mut String, indent: Option<usize>) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 2f64.powi(53) {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(d) = indent {
                        out.push('\n');
                        out.push_str(&"  ".repeat(d + 1));
                        v.write(out, Some(d + 1));
                    } else {
                        v.write(out, None);
                    }
                }
                if let Some(d) = indent {
                    out.push('\n');
                    out.push_str(&"  ".repeat(d));
                }
                out.push(']');
            }
            Json::Obj(o) => {
                if o.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(d) = indent {
                        out.push('\n');
                        out.push_str(&"  ".repeat(d + 1));
                        write_escaped(out, k);
                        out.push_str(": ");
                        v.write(out, Some(d + 1));
                    } else {
                        write_escaped(out, k);
                        out.push(':');
                        v.write(out, None);
                    }
                }
                if let Some(d) = indent {
                    out.push('\n');
                    out.push_str(&"  ".repeat(d));
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(|x| x.into()).collect())
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            offset: self.i,
        }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5]).unwrap();
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // BMP only (we never emit surrogate pairs).
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Copy a full UTF-8 sequence.
                    let s = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".to_string())
        );
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").idx(2).get("b").as_str(), Some("x"));
        assert_eq!(v.get("c"), &Json::Null);
        assert_eq!(v.get("missing"), &Json::Null);
    }

    #[test]
    fn roundtrip_pretty_and_compact() {
        let src = r#"{"arr":[1,2.5,"s"],"num":3,"obj":{"x":true}}"#;
        let v = Json::parse(src).unwrap();
        for text in [v.to_string_pretty(), v.to_string_compact()] {
            assert_eq!(Json::parse(&text).unwrap(), v);
        }
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn integer_accessors_are_exact() {
        assert_eq!(Json::parse("7").unwrap().as_u64(), Some(7));
        assert_eq!(Json::parse("7.5").unwrap().as_u64(), None);
        assert_eq!(Json::parse("-1").unwrap().as_u64(), None);
    }

    #[test]
    fn escapes_controls() {
        let v = Json::Str("a\"b\\c\nd\u{1}".to_string());
        let text = v.to_string_compact();
        assert_eq!(Json::parse(&text).unwrap(), v);
    }

    #[test]
    fn usize_vec_helper() {
        let v = Json::parse("[1,2,3]").unwrap();
        assert_eq!(v.usize_vec(), Some(vec![1, 2, 3]));
        assert_eq!(Json::parse("[1,\"x\"]").unwrap().usize_vec(), None);
    }

    #[test]
    fn parses_manifest_like_structure() {
        let text = r#"{
          "artifacts": [
            {"name": "capsnet_full_b1", "inputs": [{"shape": [1,28,28,1], "dtype": "f32"}]}
          ]
        }"#;
        let v = Json::parse(text).unwrap();
        let shape = v.get("artifacts").idx(0).get("inputs").idx(0).get("shape");
        assert_eq!(shape.usize_vec(), Some(vec![1, 28, 28, 1]));
    }
}
