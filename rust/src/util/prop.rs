//! Mini property-testing framework (the image vendors no `proptest`).
//!
//! `check(name, cases, |rng| ...)` runs a closure over `cases` seeds derived
//! deterministically from the property name, so failures are reproducible
//! without storing seeds.  On failure it reports the failing case index and
//! seed.  Used by the coordinator/DSE/memory invariant suites in
//! `rust/tests/`.

use super::prng::Prng;

/// Derives a stable 64-bit seed from the property name (FNV-1a).
pub fn name_seed(name: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Runs `body` over `cases` deterministic PRNG streams.  The body returns
/// `Err(msg)` to fail the property; panics propagate as usual.
pub fn check<F>(name: &str, cases: u32, mut body: F)
where
    F: FnMut(&mut Prng) -> Result<(), String>,
{
    let base = name_seed(name);
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Prng::new(seed);
        if let Err(msg) = body(&mut rng) {
            panic!("property '{name}' failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Assert-like helper returning `Result` for use inside `check` bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !($cond) {
            return Err(format!($($fmt)+));
        }
    };
    ($cond:expr) => {
        if !($cond) {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seeds_stable_and_distinct() {
        assert_eq!(name_seed("x"), name_seed("x"));
        assert_ne!(name_seed("x"), name_seed("y"));
    }

    #[test]
    fn passing_property_runs_all_cases() {
        let mut runs = 0;
        check("always-true", 10, |_rng| {
            runs += 1;
            Ok(())
        });
        assert_eq!(runs, 10);
    }

    #[test]
    #[should_panic(expected = "property 'sometimes-false' failed")]
    fn failing_property_panics_with_context() {
        check("sometimes-false", 50, |rng| {
            let v = rng.below(10);
            prop_assert!(v < 9, "drew {v}");
            Ok(())
        });
    }

    #[test]
    fn deterministic_across_invocations() {
        let mut first = Vec::new();
        check("det", 5, |rng| {
            first.push(rng.next_u64());
            Ok(())
        });
        let mut second = Vec::new();
        check("det", 5, |rng| {
            second.push(rng.next_u64());
            Ok(())
        });
        assert_eq!(first, second);
    }
}
