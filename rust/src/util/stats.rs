//! Streaming statistics & latency percentile tracking for the coordinator
//! and the bench harness.

/// Online mean/min/max/variance (Welford).
#[derive(Debug, Clone)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Default for Summary {
    fn default() -> Summary {
        Summary::new()
    }
}

impl Summary {
    pub fn new() -> Summary {
        Summary {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }
}

/// Reservoir-less percentile tracker: stores all samples (serving runs are
/// bounded) and computes exact percentiles on demand.
#[derive(Debug, Clone, Default)]
pub struct Percentiles {
    samples: Vec<f64>,
    sorted: bool,
}

impl Percentiles {
    pub fn new() -> Percentiles {
        Percentiles {
            samples: Vec::new(),
            sorted: true,
        }
    }

    pub fn add(&mut self, x: f64) {
        self.samples.push(x);
        self.sorted = false;
    }

    pub fn count(&self) -> usize {
        self.samples.len()
    }

    /// Exact p-quantile (0..=100) by nearest-rank with linear interpolation.
    pub fn percentile(&mut self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        if !self.sorted {
            // total_cmp: a NaN sample (it would be a bug upstream, but the
            // fleet objectives are NaN-guarded, not NaN-free by type) sorts
            // last instead of panicking inside the percentile query.
            self.samples.sort_by(f64::total_cmp);
            self.sorted = true;
        }
        let rank = (p / 100.0) * (self.samples.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        let frac = rank - lo as f64;
        self.samples[lo] * (1.0 - frac) + self.samples[hi] * frac
    }

    pub fn p50(&mut self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p95(&mut self) -> f64 {
        self.percentile(95.0)
    }

    pub fn p99(&mut self) -> f64 {
        self.percentile(99.0)
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            0.0
        } else {
            self.samples.iter().sum::<f64>() / self.samples.len() as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_moments() {
        let mut s = Summary::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.add(x);
        }
        assert_eq!(s.count(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.stddev() - 2.138089935).abs() < 1e-6);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn empty_summary_is_safe() {
        let s = Summary::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.min(), 0.0);
        assert_eq!(s.variance(), 0.0);
    }

    #[test]
    fn percentiles_exact() {
        let mut p = Percentiles::new();
        for i in 1..=100 {
            p.add(i as f64);
        }
        assert!((p.p50() - 50.5).abs() < 1e-9);
        assert!((p.percentile(0.0) - 1.0).abs() < 1e-9);
        assert!((p.percentile(100.0) - 100.0).abs() < 1e-9);
        assert!((p.p99() - 99.01).abs() < 1e-9);
    }

    #[test]
    fn percentiles_unsorted_insertion() {
        let mut p = Percentiles::new();
        for x in [5.0, 1.0, 3.0, 2.0, 4.0] {
            p.add(x);
        }
        assert_eq!(p.p50(), 3.0);
    }
}
