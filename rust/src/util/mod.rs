//! Dependency-free infrastructure: JSON, PRNG, CSV, tables, Pareto,
//! statistics, and a mini property-test framework.
//!
//! The build environment vendors only the `xla` crate's dependency closure
//! (no serde / rand / proptest / criterion), so these small, well-tested
//! replacements live here.

pub mod bench;
pub mod csv;
pub mod json;
pub mod pareto;
pub mod prng;
pub mod prop;
pub mod stats;
pub mod table;
pub mod units;
