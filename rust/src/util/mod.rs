//! Dependency-free infrastructure: the parallel execution engine, JSON,
//! PRNG, CSV, tables, Pareto, statistics, and a mini property-test
//! framework.
//!
//! The build environment vendors only `anyhow` (no serde / rand / rayon /
//! proptest / criterion), so these small, well-tested replacements live
//! here.

pub mod bench;
pub mod csv;
pub mod exec;
pub mod json;
pub mod pareto;
pub mod prng;
pub mod prop;
pub mod stats;
pub mod table;
pub mod units;
