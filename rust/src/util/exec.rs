//! Shared parallel execution engine — the one place in the codebase that
//! spawns worker threads (DESIGN.md section 5).
//!
//! Before this module existed, three layers each hand-rolled their own
//! parallelism: `dse::evaluate_all` split the organization list into static
//! chunks (pathological when per-item cost varies, as it does between SMP
//! and HY configurations), the coordinator spawned ad-hoc generator
//! threads, and `main.rs` duplicated the `available_parallelism` dance.
//!
//! The engine provides:
//!
//! * [`Engine::map`] / [`Engine::map_indexed`] — data-parallel map with
//!   **work stealing via an atomic work index**: workers claim small index
//!   strides with a single `fetch_add`, so a thread that lands on cheap
//!   items simply claims more strides instead of idling at a chunk barrier.
//! * **Ordered, deterministic collection**: every result is keyed by its
//!   input index and reassembled in input order, so the output is
//!   bit-identical for any thread count (pinned by `tests` here and by
//!   `rust/tests/engine_cache.rs` across the whole DSE pipeline).
//! * [`background`] — a named, joinable producer thread for the serving
//!   path's request generator (the coordinator's only non-map parallelism).
//!
//! No work queue survives between calls; scoped threads mean no `'static`
//! bounds and no channels on the hot path.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Inputs shorter than this are mapped serially: thread spawn/join overhead
/// dwarfs the work (the DSE fast path evaluates an organization in ~µs).
const SERIAL_CUTOFF: usize = 32;

/// Default worker count: one per available hardware thread.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
}

/// A reusable parallel-map executor with a fixed worker count.
#[derive(Debug, Clone)]
pub struct Engine {
    threads: usize,
}

impl Engine {
    /// An engine with `threads` workers (clamped to at least 1).
    pub fn new(threads: usize) -> Engine {
        Engine {
            threads: threads.max(1),
        }
    }

    /// An engine sized to the machine.
    pub fn auto() -> Engine {
        Engine::new(default_threads())
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Parallel map preserving input order.  Deterministic: the output is
    /// identical (bit-for-bit, for pure `f`) under any thread count.
    pub fn map<T, U, F>(&self, items: &[T], f: F) -> Vec<U>
    where
        T: Sync,
        U: Send,
        F: Fn(&T) -> U + Sync,
    {
        self.map_indexed(items, |_, item| f(item))
    }

    /// [`Engine::map`] with the input index passed to `f`.
    pub fn map_indexed<T, U, F>(&self, items: &[T], f: F) -> Vec<U>
    where
        T: Sync,
        U: Send,
        F: Fn(usize, &T) -> U + Sync,
    {
        self.map_impl(items, SERIAL_CUTOFF, f)
    }

    /// [`Engine::map`] for coarse-grained items (milliseconds-plus each,
    /// e.g. whole annealing chains): parallelizes for any input length
    /// instead of applying the serial cutoff, which is tuned for the DSE's
    /// microsecond-scale items.
    pub fn map_coarse<T, U, F>(&self, items: &[T], f: F) -> Vec<U>
    where
        T: Sync,
        U: Send,
        F: Fn(&T) -> U + Sync,
    {
        self.map_impl(items, 2, |_, item| f(item))
    }

    fn map_impl<T, U, F>(&self, items: &[T], serial_cutoff: usize, f: F) -> Vec<U>
    where
        T: Sync,
        U: Send,
        F: Fn(usize, &T) -> U + Sync,
    {
        let n = items.len();
        let threads = self.threads.min(n.max(1));
        if threads <= 1 || n < serial_cutoff {
            return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
        }

        // Work stealing: each worker claims `stride` consecutive indices
        // per fetch_add.  Strides are small enough (~1/8 of a fair share)
        // that uneven per-item cost rebalances, large enough that the
        // atomic is off the critical path.
        let stride = (n / (threads * 8)).max(1);
        let next = AtomicUsize::new(0);
        let mut shards: Vec<Vec<(usize, U)>> = Vec::with_capacity(threads);
        std::thread::scope(|scope| {
            let f = &f;
            let next = &next;
            let handles: Vec<_> = (0..threads)
                .map(|_| {
                    scope.spawn(move || {
                        let mut local: Vec<(usize, U)> = Vec::new();
                        loop {
                            let start = next.fetch_add(stride, Ordering::Relaxed);
                            if start >= n {
                                break;
                            }
                            let end = (start + stride).min(n);
                            for (i, item) in items.iter().enumerate().take(end).skip(start) {
                                local.push((i, f(i, item)));
                            }
                        }
                        local
                    })
                })
                .collect();
            for h in handles {
                shards.push(h.join().expect("engine worker panicked"));
            }
        });

        // Ordered collection: place every (index, result) pair into its
        // slot, independent of which worker produced it.
        let mut slots: Vec<Option<U>> = Vec::with_capacity(n);
        slots.resize_with(n, || None);
        for (i, value) in shards.into_iter().flatten() {
            debug_assert!(slots[i].is_none(), "index {i} produced twice");
            slots[i] = Some(value);
        }
        slots
            .into_iter()
            .map(|s| s.expect("every index produces exactly one result"))
            .collect()
    }
}

impl Default for Engine {
    fn default() -> Engine {
        Engine::auto()
    }
}

/// A joinable background task (named thread).  Used by the coordinator for
/// its request-generator thread; prefer [`Engine::map`] for data-parallel
/// work.
pub struct Background<T> {
    handle: std::thread::JoinHandle<T>,
}

impl<T> Background<T> {
    /// Waits for the task and returns its value.  Panics if the task
    /// panicked (the panic is not swallowed).
    pub fn join(self) -> T {
        self.handle.join().expect("background task panicked")
    }

    pub fn is_finished(&self) -> bool {
        self.handle.is_finished()
    }
}

/// Spawns `f` on a named (`descnet-<name>`) background thread.
pub fn background<T, F>(name: &str, f: F) -> Background<T>
where
    T: Send + 'static,
    F: FnOnce() -> T + Send + 'static,
{
    let handle = std::thread::Builder::new()
        .name(format!("descnet-{name}"))
        .spawn(f)
        .expect("spawning background task");
    Background { handle }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_matches_serial_for_any_thread_count() {
        let items: Vec<u64> = (0..1000).collect();
        let serial: Vec<u64> = items.iter().map(|&x| x * x + 1).collect();
        for threads in [1usize, 2, 3, 7, 16] {
            let got = Engine::new(threads).map(&items, |&x| x * x + 1);
            assert_eq!(got, serial, "threads={threads}");
        }
    }

    #[test]
    fn map_indexed_passes_input_indices() {
        let items: Vec<&str> = vec!["a"; 500];
        let got = Engine::new(4).map_indexed(&items, |i, _| i);
        let want: Vec<usize> = (0..items.len()).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn empty_and_small_inputs() {
        let empty: Vec<u32> = Vec::new();
        assert!(Engine::new(8).map(&empty, |&x| x).is_empty());
        // Below the serial cutoff with more threads than items.
        let small: Vec<u32> = (0..5).collect();
        assert_eq!(Engine::new(8).map(&small, |&x| x * 2), vec![0, 2, 4, 6, 8]);
    }

    #[test]
    fn zero_threads_clamps_to_one() {
        assert_eq!(Engine::new(0).threads(), 1);
        let items: Vec<u32> = (0..100).collect();
        assert_eq!(Engine::new(0).map(&items, |&x| x).len(), 100);
    }

    #[test]
    fn uneven_work_still_collects_in_order() {
        // Early items are ~100x more expensive than late ones; static
        // chunking would leave the first worker far behind, stealing keeps
        // everyone busy — either way the output order must be the input
        // order.
        let items: Vec<usize> = (0..600).collect();
        let f = |&i: &usize| -> usize {
            let spins = if i < 60 { 10_000 } else { 100 };
            let mut acc = i;
            for k in 0..spins {
                acc = acc.wrapping_mul(31).wrapping_add(k);
            }
            std::hint::black_box(acc);
            i
        };
        let got = Engine::new(4).map(&items, f);
        assert_eq!(got, items);
    }

    #[test]
    fn map_coarse_parallelizes_small_inputs_and_preserves_order() {
        // 4 items is far below SERIAL_CUTOFF, yet map_coarse must take the
        // parallel path (observable via distinct worker thread names) and
        // still return results in input order.
        let items: Vec<u32> = (0..4).collect();
        let names = Engine::new(4).map_coarse(&items, |&x| {
            let name = std::thread::current().name().map(String::from);
            (x, name)
        });
        let values: Vec<u32> = names.iter().map(|(x, _)| *x).collect();
        assert_eq!(values, items);
        // Workers run inside thread::scope spawns, not the test thread.
        let test_thread = std::thread::current().name().map(String::from);
        assert!(
            names.iter().any(|(_, n)| *n != test_thread),
            "map_coarse stayed on the calling thread: {names:?}"
        );
    }

    #[test]
    fn background_task_joins_with_value() {
        let task = background("unit-test", || 41 + 1);
        assert_eq!(task.join(), 42);
    }
}
