//! Minimal benchmark harness (no `criterion` vendored): warmup + timed
//! iterations with mean/min/max reporting, used by every `benches/*.rs`
//! target (`cargo bench` with `harness = false`).

use std::time::Instant;

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u32,
    pub mean_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:44} {:>12}/iter  (min {:>12}, max {:>12}, {} iters)",
            self.name,
            super::units::fmt_time(self.mean_s),
            super::units::fmt_time(self.min_s),
            super::units::fmt_time(self.max_s),
            self.iters
        )
    }
}

/// Times `f` over `iters` iterations (plus one untimed warmup) and prints
/// the result.  Returns it for optional throughput math by the caller.
pub fn time<F: FnMut()>(name: &str, iters: u32, mut f: F) -> BenchResult {
    assert!(iters > 0);
    f(); // warmup
    let mut min_s = f64::INFINITY;
    let mut max_s: f64 = 0.0;
    let mut total = 0.0;
    for _ in 0..iters {
        let t = Instant::now();
        f();
        let dt = t.elapsed().as_secs_f64();
        total += dt;
        min_s = min_s.min(dt);
        max_s = max_s.max(dt);
    }
    let result = BenchResult {
        name: name.to_string(),
        iters,
        mean_s: total / iters as f64,
        min_s,
        max_s,
    };
    println!("{}", result.report());
    result
}

/// Convenience: items/second formatting for throughput benches.
pub fn throughput(result: &BenchResult, items: usize) -> String {
    let per_s = items as f64 / result.mean_s;
    if per_s > 1e6 {
        format!("{:.2} M items/s", per_s / 1e6)
    } else if per_s > 1e3 {
        format!("{:.2} k items/s", per_s / 1e3)
    } else {
        format!("{per_s:.1} items/s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_is_positive_and_ordered() {
        let r = time("spin", 5, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(r.min_s <= r.mean_s && r.mean_s <= r.max_s);
        assert!(r.min_s >= 0.0);
    }

    #[test]
    fn throughput_formats() {
        let r = BenchResult {
            name: "x".into(),
            iters: 1,
            mean_s: 1.0,
            min_s: 1.0,
            max_s: 1.0,
        };
        assert_eq!(throughput(&r, 2_000_000), "2.00 M items/s");
        assert_eq!(throughput(&r, 5_000), "5.00 k items/s");
        assert_eq!(throughput(&r, 10), "10.0 items/s");
    }
}
