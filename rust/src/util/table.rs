//! ASCII table rendering for CLI reports (`descnet report ...`).
//!
//! Right-aligns numeric-looking cells, left-aligns text, and supports a
//! markdown mode used when regenerating the paper's tables into
//! `results/*.md`.

pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "table row width mismatch");
        self.rows.push(cells);
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.header.iter().map(|h| h.chars().count()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.chars().count());
            }
        }
        w
    }

    fn numeric(cell: &str) -> bool {
        let t = cell.trim();
        !t.is_empty()
            && t.chars().next().map_or(false, |c| {
                c.is_ascii_digit() || c == '-' || c == '+' || c == '.'
            })
            && t.chars()
                .all(|c| c.is_ascii_digit() || ".,-+e%x".contains(c.to_ascii_lowercase()))
    }

    fn pad(cell: &str, width: usize) -> String {
        let len = cell.chars().count();
        let pad = " ".repeat(width - len);
        if Self::numeric(cell) {
            format!("{pad}{cell}")
        } else {
            format!("{cell}{pad}")
        }
    }

    /// Render as a boxed ASCII table for terminal output.
    pub fn to_ascii(&self) -> String {
        let w = self.widths();
        let sep = format!(
            "+{}+",
            w.iter().map(|x| "-".repeat(x + 2)).collect::<Vec<_>>().join("+")
        );
        let mut out = String::new();
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&format!(
            "|{}|",
            self.header
                .iter()
                .zip(&w)
                .map(|(h, &x)| format!(" {} ", Self::pad(h, x)))
                .collect::<Vec<_>>()
                .join("|")
        ));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for r in &self.rows {
            out.push_str(&format!(
                "|{}|",
                r.iter()
                    .zip(&w)
                    .map(|(c, &x)| format!(" {} ", Self::pad(c, x)))
                    .collect::<Vec<_>>()
                    .join("|")
            ));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }

    /// Render as GitHub-flavoured markdown (for `results/*.md`).
    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("| {} |\n", self.header.join(" | ")));
        out.push_str(&format!(
            "|{}|\n",
            self.header.iter().map(|_| "---").collect::<Vec<_>>().join("|")
        ));
        for r in &self.rows {
            out.push_str(&format!("| {} |\n", r.join(" | ")));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_ascii() {
        let mut t = Table::new(&["op", "cycles"]);
        t.row(vec!["Conv1".into(), "32400".into()]);
        t.row(vec!["PrimaryCaps".into(), "746496".into()]);
        let s = t.to_ascii();
        assert!(s.contains("| Conv1       |"));
        assert!(s.contains("|  32400 |")); // right-aligned numeric
        assert!(s.starts_with('+'));
    }

    #[test]
    fn renders_markdown() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["1".into(), "x".into()]);
        assert_eq!(t.to_markdown(), "| a | b |\n|---|---|\n| 1 | x |\n");
    }

    #[test]
    fn numeric_detection() {
        assert!(Table::numeric("123"));
        assert!(Table::numeric("-4.5"));
        assert!(Table::numeric("1,024"));
        assert!(!Table::numeric("Conv1"));
        assert!(!Table::numeric(""));
    }

    #[test]
    #[should_panic(expected = "width mismatch")]
    fn row_width_checked() {
        let mut t = Table::new(&["a"]);
        t.row(vec!["1".into(), "2".into()]);
    }
}
