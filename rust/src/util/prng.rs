//! Deterministic PRNG (xoshiro256**) — the image vendors no `rand`.
//!
//! Used by the property-test mini-framework (`util::prop`), the synthetic
//! request generators in the coordinator benches, and workload jitter in the
//! examples.  Deterministic seeding keeps every test and bench reproducible.

/// One SplitMix64 step (the xoshiro seeding mixer, also used to derive
/// independent sub-streams in [`Prng::stream`]).
fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

#[derive(Debug, Clone)]
pub struct Prng {
    s: [u64; 4],
}

impl Prng {
    pub fn new(seed: u64) -> Prng {
        // SplitMix64 expansion of the seed (standard xoshiro seeding).
        let mut x = seed;
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            splitmix64(x)
        };
        Prng {
            s: [next(), next(), next(), next()],
        }
    }

    /// Independent sub-stream derived from `(seed, stream)`.
    ///
    /// Streams are split at seeding time, so draws from one stream never
    /// perturb another: the fleet fault injector draws its per-shard
    /// crash/recover schedule from `stream(fault_seed, shard)` while the
    /// arrival process keeps drawing from `new(seed)` — turning injection
    /// on or off leaves the arrival sequence bit-identical.  `stream(s, k)`
    /// differs from `new(s)` for every `k` (the stream id passes through
    /// SplitMix64 with a non-zero tweak before it touches the seed).
    pub fn stream(seed: u64, stream: u64) -> Prng {
        Prng::new(seed ^ splitmix64(stream.wrapping_add(0xA0761D6478BD642F)))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`.  Panics if `n == 0`.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        // Lemire's multiply-shift rejection method: unbiased.
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= n.wrapping_neg() % n {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform in `[lo, hi]` inclusive.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    pub fn usize_below(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f64 in [lo, hi).
    pub fn f64_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Standard-normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = (self.f64()).max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.usize_below(items.len())]
    }

    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.usize_below(i + 1);
            items.swap(i, j);
        }
    }

    /// Exponentially-distributed value with the given mean (used for
    /// Poisson-ish request interarrival times in serving benches).
    pub fn exp(&mut self, mean: f64) -> f64 {
        -mean * (1.0 - self.f64()).ln()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Prng::new(7);
        let mut b = Prng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Prng::new(8);
        assert_ne!(Prng::new(7).next_u64(), c.next_u64());
    }

    #[test]
    fn streams_are_independent_and_distinct() {
        // A stream never collides with the base generator or a sibling
        // stream, and is a pure function of (seed, stream id).
        let base: Vec<u64> = {
            let mut p = Prng::new(7);
            (0..8).map(|_| p.next_u64()).collect()
        };
        let s0: Vec<u64> = {
            let mut p = Prng::stream(7, 0);
            (0..8).map(|_| p.next_u64()).collect()
        };
        let s1: Vec<u64> = {
            let mut p = Prng::stream(7, 1);
            (0..8).map(|_| p.next_u64()).collect()
        };
        assert_ne!(base, s0);
        assert_ne!(base, s1);
        assert_ne!(s0, s1);
        let mut again = Prng::stream(7, 1);
        assert_eq!(s1[0], again.next_u64());
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut p = Prng::new(1);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = p.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval_mean() {
        let mut p = Prng::new(2);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| p.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut p = Prng::new(3);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| p.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut p = Prng::new(4);
        let mut v: Vec<u32> = (0..50).collect();
        p.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn exp_mean_matches() {
        let mut p = Prng::new(5);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| p.exp(3.0)).sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.1, "mean={mean}");
    }
}
