//! Pareto-frontier extraction for the DSE (area vs energy minimization).
//!
//! The paper selects "non-dominated solutions" from the exhaustive sweep
//! (Figs 18/20/22); a point dominates another if it is <= on both axes and
//! < on at least one.

/// A point in (x, y) objective space with an opaque payload index.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Point {
    pub x: f64,
    pub y: f64,
    pub id: usize,
}

impl Point {
    pub fn new(x: f64, y: f64, id: usize) -> Point {
        Point { x, y, id }
    }

    /// True if `self` dominates `other` (minimization on both axes).
    pub fn dominates(&self, other: &Point) -> bool {
        self.x <= other.x && self.y <= other.y && (self.x < other.x || self.y < other.y)
    }
}

/// Returns the indices (into `points`) of the Pareto frontier, sorted by
/// ascending x.  O(n log n): sort by (x, y), then a single min-y sweep.
pub fn frontier(points: &[Point]) -> Vec<usize> {
    let mut order: Vec<usize> = (0..points.len()).collect();
    order.sort_by(|&a, &b| {
        points[a]
            .x
            .partial_cmp(&points[b].x)
            .unwrap()
            .then(points[a].y.partial_cmp(&points[b].y).unwrap())
    });
    let mut out = Vec::new();
    let mut best_y = f64::INFINITY;
    for &i in &order {
        if points[i].y < best_y {
            // Equal-x ties: the sort put the lower-y first, which strictly
            // improves best_y, so the worse tie is skipped — correct.
            out.push(i);
            best_y = points[i].y;
        }
    }
    out
}

/// True if `p` is not dominated by any point in `points`.
pub fn is_non_dominated(p: &Point, points: &[Point]) -> bool {
    !points.iter().any(|q| q.dominates(p))
}

/// The frontier point with minimal y (e.g. lowest-energy Pareto solution,
/// the paper's per-design-option selection rule in section VI-A).
pub fn min_y(points: &[Point]) -> Option<usize> {
    points
        .iter()
        .enumerate()
        .min_by(|(_, a), (_, b)| a.y.partial_cmp(&b.y).unwrap().then(a.x.partial_cmp(&b.x).unwrap()))
        .map(|(i, _)| i)
}

/// The frontier point with minimal x (lowest-area solution).
pub fn min_x(points: &[Point]) -> Option<usize> {
    points
        .iter()
        .enumerate()
        .min_by(|(_, a), (_, b)| a.x.partial_cmp(&b.x).unwrap().then(a.y.partial_cmp(&b.y).unwrap()))
        .map(|(i, _)| i)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pts(v: &[(f64, f64)]) -> Vec<Point> {
        v.iter()
            .enumerate()
            .map(|(i, &(x, y))| Point::new(x, y, i))
            .collect()
    }

    #[test]
    fn simple_frontier() {
        let p = pts(&[(1.0, 5.0), (2.0, 3.0), (3.0, 4.0), (4.0, 1.0), (2.5, 2.5)]);
        let f = frontier(&p);
        // (3,4) dominated by (2.5,2.5); others form the staircase.
        assert_eq!(f, vec![0, 1, 4, 3]);
    }

    #[test]
    fn dominated_point_excluded() {
        let p = pts(&[(1.0, 1.0), (2.0, 2.0)]);
        assert_eq!(frontier(&p), vec![0]);
        assert!(p[0].dominates(&p[1]));
        assert!(!p[1].dominates(&p[0]));
    }

    #[test]
    fn equal_points_keep_one() {
        let p = pts(&[(1.0, 1.0), (1.0, 1.0)]);
        assert_eq!(frontier(&p).len(), 1);
    }

    #[test]
    fn empty_input_has_empty_frontier() {
        assert!(frontier(&[]).is_empty());
        assert_eq!(min_x(&[]), None);
        assert_eq!(min_y(&[]), None);
    }

    #[test]
    fn single_point_is_its_own_frontier() {
        let p = pts(&[(3.0, 7.0)]);
        assert_eq!(frontier(&p), vec![0]);
        assert_eq!(min_x(&p), Some(0));
        assert_eq!(min_y(&p), Some(0));
    }

    #[test]
    fn many_duplicates_keep_exactly_one() {
        // The stable sort keeps the first of the equal points; a dominated
        // straggler never joins.
        let p = pts(&[(2.0, 2.0), (2.0, 2.0), (2.0, 2.0), (3.0, 3.0)]);
        assert_eq!(frontier(&p), vec![0]);
    }

    #[test]
    fn collinear_ties_keep_only_the_dominating_end() {
        // Same y: only the smallest x is non-dominated.
        let same_y = pts(&[(3.0, 2.0), (1.0, 2.0), (2.0, 2.0)]);
        assert_eq!(frontier(&same_y), vec![1]);
        // Same x: only the smallest y is non-dominated.
        let same_x = pts(&[(1.0, 5.0), (1.0, 3.0), (1.0, 4.0)]);
        assert_eq!(frontier(&same_x), vec![1]);
        // An L-shape with a redundant corner point on each arm.
        let l_shape = pts(&[(1.0, 4.0), (1.0, 2.0), (2.0, 2.0), (2.0, 1.0), (3.0, 1.0)]);
        assert_eq!(frontier(&l_shape), vec![1, 3]);
    }

    #[test]
    fn frontier_members_are_mutually_non_dominating() {
        let p = pts(&[
            (5.0, 1.0),
            (1.0, 5.0),
            (3.0, 3.0),
            (2.0, 4.5),
            (4.0, 2.0),
            (3.0, 3.5),
        ]);
        let f = frontier(&p);
        for &a in &f {
            for &b in &f {
                if a != b {
                    assert!(!p[a].dominates(&p[b]), "{a} dominates {b}");
                }
            }
        }
        // And every non-member is dominated by some member.
        for i in 0..p.len() {
            if !f.contains(&i) {
                assert!(f.iter().any(|&m| p[m].dominates(&p[i])), "point {i}");
            }
        }
    }

    #[test]
    fn min_selectors() {
        let p = pts(&[(5.0, 1.0), (1.0, 5.0), (3.0, 3.0)]);
        assert_eq!(min_y(&p), Some(0));
        assert_eq!(min_x(&p), Some(1));
    }
}
