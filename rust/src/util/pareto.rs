//! Pareto-frontier extraction for the DSE (area vs energy minimization,
//! and the 3-objective area/energy/latency variant the timeline simulator
//! adds).
//!
//! The paper selects "non-dominated solutions" from the exhaustive sweep
//! (Figs 18/20/22); a point dominates another if it is <= on both axes and
//! < on at least one.  [`frontier3`] extends the rule to three objectives
//! with an O(n log n) staircase sweep; when every point shares the same
//! third coordinate it reduces exactly to [`frontier`]'s result set.

/// A point in (x, y) objective space with an opaque payload index.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Point {
    pub x: f64,
    pub y: f64,
    pub id: usize,
}

impl Point {
    pub fn new(x: f64, y: f64, id: usize) -> Point {
        Point { x, y, id }
    }

    /// True if `self` dominates `other` (minimization on both axes).
    pub fn dominates(&self, other: &Point) -> bool {
        self.x <= other.x && self.y <= other.y && (self.x < other.x || self.y < other.y)
    }
}

/// Returns the indices (into `points`) of the Pareto frontier, sorted by
/// ascending x.  O(n log n): sort by (x, y), then a single min-y sweep.
pub fn frontier(points: &[Point]) -> Vec<usize> {
    let mut order: Vec<usize> = (0..points.len()).collect();
    // total_cmp: NaN objectives (a degenerate config upstream) sort last
    // instead of panicking mid-sweep; they then never improve best_y, so
    // they cannot join the frontier.
    order.sort_by(|&a, &b| {
        points[a]
            .x
            .total_cmp(&points[b].x)
            .then(points[a].y.total_cmp(&points[b].y))
    });
    let mut out = Vec::new();
    let mut best_y = f64::INFINITY;
    for &i in &order {
        if points[i].x.is_nan() || points[i].y.is_nan() {
            continue; // degenerate objective: never a frontier member
        }
        if points[i].y < best_y {
            // Equal-x ties: the sort put the lower-y first, which strictly
            // improves best_y, so the worse tie is skipped — correct.
            out.push(i);
            best_y = points[i].y;
        }
    }
    out
}

/// A point in (x, y, z) objective space with an opaque payload index.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Point3 {
    pub x: f64,
    pub y: f64,
    pub z: f64,
    pub id: usize,
}

impl Point3 {
    pub fn new(x: f64, y: f64, z: f64, id: usize) -> Point3 {
        Point3 { x, y, z, id }
    }

    /// True if `self` dominates `other` (minimization on all three axes).
    pub fn dominates(&self, other: &Point3) -> bool {
        self.x <= other.x
            && self.y <= other.y
            && self.z <= other.z
            && (self.x < other.x || self.y < other.y || self.z < other.z)
    }
}

/// Indices (into `points`) of the 3-objective Pareto frontier, in the
/// (x, y, z)-lexicographic processing order.  Exact duplicates keep only
/// their first occurrence, matching [`frontier`]'s tie convention.
///
/// Sweep: process points in (x, y, z)-lexicographic order; every earlier
/// point has x <= the current one, so 3-D dominance reduces to a 2-D
/// query over (y, z) against a staircase (y ascending, z strictly
/// descending) of the processed points' own (y, z) frontier.
pub fn frontier3(points: &[Point3]) -> Vec<usize> {
    let mut order: Vec<usize> = (0..points.len()).collect();
    order.sort_by(|&a, &b| {
        let (p, q) = (&points[a], &points[b]);
        p.x.total_cmp(&q.x)
            .then(p.y.total_cmp(&q.y))
            .then(p.z.total_cmp(&q.z))
    });
    let mut out = Vec::new();
    let mut stair: Vec<(f64, f64)> = Vec::new(); // (y, z), y asc, z strictly desc
    for &i in &order {
        let p = &points[i];
        if p.x.is_nan() || p.y.is_nan() || p.z.is_nan() {
            continue; // degenerate objective: never a frontier member
        }
        // Rightmost staircase entry with y <= p.y holds the minimal z over
        // that range; the point is dominated iff that z <= p.z (an exact
        // (y, z) duplicate counts as dominated: earlier x-ties win, like
        // `frontier`'s stable-sort convention).
        let pos = stair.partition_point(|&(y, _)| y <= p.y);
        if pos > 0 && stair[pos - 1].1 <= p.z {
            continue;
        }
        // Accepted: insert (p.y, p.z), dropping entries it (y, z)-covers —
        // those at y >= p.y with z >= p.z.  They form a contiguous run
        // starting at the first entry with y >= p.y (entries tied on y all
        // have z > p.z here, else the dominance test would have fired) and
        // ending where z drops below p.z.
        let start = stair.partition_point(|&(y, _)| y < p.y);
        let end = stair[start..]
            .iter()
            .position(|&(_, z)| z < p.z)
            .map(|k| start + k)
            .unwrap_or(stair.len());
        stair.splice(start..end, [(p.y, p.z)]);
        out.push(i);
    }
    out
}

/// True if `p` is not dominated by any point in `points`.
pub fn is_non_dominated(p: &Point, points: &[Point]) -> bool {
    !points.iter().any(|q| q.dominates(p))
}

/// Incremental 3-objective Pareto archive (minimization): the online dual
/// of [`frontier3`].  Members are kept (x, y, z)-lexicographically sorted;
/// insertion binary-searches the slot and splices, queries scan only the
/// prefix with `x <= p.x` (the only members that can dominate `p`).
///
/// *Weak* dominance (`<=` on every axis, equality allowed) drives both the
/// rejection test and member eviction, which reproduces [`frontier3`]'s
/// exact tie conventions: a later exact duplicate is weakly dominated by
/// the earlier member and rejected (first occurrence wins), and a strictly
/// dominated point is rejected outright.  Invariant (induction over
/// inserts: a member is only evicted by a weak dominator, a point is only
/// rejected by a weakly dominating member, and weak dominance is
/// transitive): after any insert sequence the archive holds, for every
/// point ever offered, a member that weakly dominates it — so the final
/// member set equals `frontier3` of the whole sequence.  Pinned by
/// `archive_matches_frontier3_on_random_cloud` below.
///
/// The DSE's branch-and-bound sweep uses this as its dominance oracle:
/// a subtree whose componentwise *lower bound* is weakly dominated by an
/// archive member cannot contribute a frontier point (every completion is
/// weakly dominated by that member, which was enumerated earlier).
#[derive(Debug, Clone, Default)]
pub struct Archive3 {
    /// Mutually non-dominated members, (x, y, z)-lexicographically sorted.
    members: Vec<Point3>,
    /// Accepted inserts over the archive's lifetime (evicted members
    /// still count — the DSE surfaces this as `archive_inserts`).
    inserts: usize,
}

/// `a <= b` on every axis (equality allowed): the archive's rejection and
/// eviction relation.
fn weakly_dominates(a: &Point3, b: &Point3) -> bool {
    a.x <= b.x && a.y <= b.y && a.z <= b.z
}

impl Archive3 {
    pub fn new() -> Archive3 {
        Archive3::default()
    }

    pub fn len(&self) -> usize {
        self.members.len()
    }

    pub fn is_empty(&self) -> bool {
        self.members.is_empty()
    }

    /// Accepted inserts over the archive's lifetime (>= `len()`).
    pub fn inserts(&self) -> usize {
        self.inserts
    }

    /// Current members, (x, y, z)-lexicographically sorted.
    pub fn members(&self) -> &[Point3] {
        &self.members
    }

    /// True if some member weakly dominates `p` (`<=` on all three axes).
    /// Only the sorted prefix with `x <= p.x` can qualify, so the scan
    /// stops at the binary-searched partition point.
    pub fn dominated(&self, p: &Point3) -> bool {
        let end = self.members.partition_point(|m| m.x <= p.x);
        self.members[..end].iter().any(|m| weakly_dominates(m, p))
    }

    /// Offers `p` to the archive.  Rejected (returns `false`) if any
    /// member weakly dominates it — including exact duplicates, so the
    /// first occurrence wins, matching [`frontier3`].  On acceptance,
    /// members weakly dominated by `p` are evicted and `p` is spliced
    /// into its lexicographic slot.
    pub fn insert(&mut self, p: Point3) -> bool {
        if p.x.is_nan() || p.y.is_nan() || p.z.is_nan() {
            return false; // degenerate objective: never a frontier member
        }
        if self.dominated(&p) {
            return false;
        }
        // Evict members `p` weakly dominates: all have x >= p.x, so only
        // the suffix after the partition point needs scanning.
        let start = self.members.partition_point(|m| m.x < p.x);
        let mut kept = start;
        for i in start..self.members.len() {
            if !weakly_dominates(&p, &self.members[i]) {
                self.members.swap(kept, i);
                kept += 1;
            }
        }
        self.members.truncate(kept);
        // The retained suffix kept its relative order (stable compaction),
        // so a single binary-searched splice restores lexicographic order.
        let slot = self.members.partition_point(|m| {
            m.x.total_cmp(&p.x)
                .then(m.y.total_cmp(&p.y))
                .then(m.z.total_cmp(&p.z))
                .is_lt()
        });
        self.members.insert(slot, p);
        self.inserts += 1;
        true
    }

    /// Folds `other` into `self` by offering its members in lexicographic
    /// order.  Because the final member set of any insert sequence equals
    /// `frontier3` of the sequence (order-independent as a set), merging
    /// per-shard archives in shard order is deterministic for any shard
    /// partition — the property `util::exec::Engine`-parallel sweeps rely
    /// on.
    pub fn merge(&mut self, other: &Archive3) {
        for m in &other.members {
            self.insert(*m);
        }
    }
}

/// The frontier point with minimal y (e.g. lowest-energy Pareto solution,
/// the paper's per-design-option selection rule in section VI-A).  NaN
/// coordinates are skipped, matching [`frontier`]'s convention.
pub fn min_y(points: &[Point]) -> Option<usize> {
    points
        .iter()
        .enumerate()
        .filter(|(_, p)| !p.x.is_nan() && !p.y.is_nan())
        .min_by(|(_, a), (_, b)| a.y.total_cmp(&b.y).then(a.x.total_cmp(&b.x)))
        .map(|(i, _)| i)
}

/// The frontier point with minimal x (lowest-area solution).  NaN
/// coordinates are skipped, matching [`frontier`]'s convention.
pub fn min_x(points: &[Point]) -> Option<usize> {
    points
        .iter()
        .enumerate()
        .filter(|(_, p)| !p.x.is_nan() && !p.y.is_nan())
        .min_by(|(_, a), (_, b)| a.x.total_cmp(&b.x).then(a.y.total_cmp(&b.y)))
        .map(|(i, _)| i)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pts(v: &[(f64, f64)]) -> Vec<Point> {
        v.iter()
            .enumerate()
            .map(|(i, &(x, y))| Point::new(x, y, i))
            .collect()
    }

    #[test]
    fn simple_frontier() {
        let p = pts(&[(1.0, 5.0), (2.0, 3.0), (3.0, 4.0), (4.0, 1.0), (2.5, 2.5)]);
        let f = frontier(&p);
        // (3,4) dominated by (2.5,2.5); others form the staircase.
        assert_eq!(f, vec![0, 1, 4, 3]);
    }

    #[test]
    fn dominated_point_excluded() {
        let p = pts(&[(1.0, 1.0), (2.0, 2.0)]);
        assert_eq!(frontier(&p), vec![0]);
        assert!(p[0].dominates(&p[1]));
        assert!(!p[1].dominates(&p[0]));
    }

    #[test]
    fn equal_points_keep_one() {
        let p = pts(&[(1.0, 1.0), (1.0, 1.0)]);
        assert_eq!(frontier(&p).len(), 1);
    }

    #[test]
    fn empty_input_has_empty_frontier() {
        assert!(frontier(&[]).is_empty());
        assert_eq!(min_x(&[]), None);
        assert_eq!(min_y(&[]), None);
    }

    #[test]
    fn single_point_is_its_own_frontier() {
        let p = pts(&[(3.0, 7.0)]);
        assert_eq!(frontier(&p), vec![0]);
        assert_eq!(min_x(&p), Some(0));
        assert_eq!(min_y(&p), Some(0));
    }

    #[test]
    fn many_duplicates_keep_exactly_one() {
        // The stable sort keeps the first of the equal points; a dominated
        // straggler never joins.
        let p = pts(&[(2.0, 2.0), (2.0, 2.0), (2.0, 2.0), (3.0, 3.0)]);
        assert_eq!(frontier(&p), vec![0]);
    }

    #[test]
    fn collinear_ties_keep_only_the_dominating_end() {
        // Same y: only the smallest x is non-dominated.
        let same_y = pts(&[(3.0, 2.0), (1.0, 2.0), (2.0, 2.0)]);
        assert_eq!(frontier(&same_y), vec![1]);
        // Same x: only the smallest y is non-dominated.
        let same_x = pts(&[(1.0, 5.0), (1.0, 3.0), (1.0, 4.0)]);
        assert_eq!(frontier(&same_x), vec![1]);
        // An L-shape with a redundant corner point on each arm.
        let l_shape = pts(&[(1.0, 4.0), (1.0, 2.0), (2.0, 2.0), (2.0, 1.0), (3.0, 1.0)]);
        assert_eq!(frontier(&l_shape), vec![1, 3]);
    }

    #[test]
    fn frontier_members_are_mutually_non_dominating() {
        let p = pts(&[
            (5.0, 1.0),
            (1.0, 5.0),
            (3.0, 3.0),
            (2.0, 4.5),
            (4.0, 2.0),
            (3.0, 3.5),
        ]);
        let f = frontier(&p);
        for &a in &f {
            for &b in &f {
                if a != b {
                    assert!(!p[a].dominates(&p[b]), "{a} dominates {b}");
                }
            }
        }
        // And every non-member is dominated by some member.
        for i in 0..p.len() {
            if !f.contains(&i) {
                assert!(f.iter().any(|&m| p[m].dominates(&p[i])), "point {i}");
            }
        }
    }

    #[test]
    fn min_selectors() {
        let p = pts(&[(5.0, 1.0), (1.0, 5.0), (3.0, 3.0)]);
        assert_eq!(min_y(&p), Some(0));
        assert_eq!(min_x(&p), Some(1));
    }

    #[test]
    fn nan_points_never_panic_or_join_the_frontier() {
        // A NaN objective (degenerate config upstream) must neither abort
        // the sort (the old partial_cmp().unwrap() panic) nor survive into
        // the frontier or the min-selections.
        let p = pts(&[(2.0, 2.0), (f64::NAN, 0.5), (0.5, f64::NAN), (1.0, 3.0)]);
        assert_eq!(frontier(&p), vec![3, 0]);
        assert_eq!(min_y(&p), Some(0));
        assert_eq!(min_x(&p), Some(3));
        let p3 = pts3(&[
            (2.0, 2.0, 2.0),
            (f64::NAN, 0.5, 0.5),
            (0.5, 0.5, f64::NAN),
            (1.0, 3.0, 1.0),
        ]);
        let mut f3 = frontier3(&p3);
        f3.sort_unstable();
        assert_eq!(f3, vec![0, 3]);
        // All-NaN input degrades to an empty frontier, not a panic.
        assert!(frontier(&pts(&[(f64::NAN, f64::NAN)])).is_empty());
    }

    // ------------------------------------------------------ 3-objective

    fn pts3(v: &[(f64, f64, f64)]) -> Vec<Point3> {
        v.iter()
            .enumerate()
            .map(|(i, &(x, y, z))| Point3::new(x, y, z, i))
            .collect()
    }

    #[test]
    fn frontier3_basic_domination() {
        let p = pts3(&[
            (1.0, 1.0, 1.0),
            (2.0, 2.0, 2.0), // dominated by 0
            (0.5, 3.0, 3.0), // better x: survives
            (3.0, 0.5, 3.0), // better y: survives
            (3.0, 3.0, 0.5), // better z: survives
        ]);
        let mut f = frontier3(&p);
        f.sort_unstable();
        assert_eq!(f, vec![0, 2, 3, 4]);
    }

    #[test]
    fn frontier3_reduces_to_2d_when_z_is_constant() {
        let flat: Vec<(f64, f64)> = vec![
            (1.0, 5.0),
            (2.0, 3.0),
            (3.0, 4.0),
            (4.0, 1.0),
            (2.5, 2.5),
            (1.0, 5.0), // duplicate: only the first survives
        ];
        let p2 = pts(&flat);
        let p3: Vec<Point3> = flat
            .iter()
            .enumerate()
            .map(|(i, &(x, y))| Point3::new(x, y, 7.25, i))
            .collect();
        let mut f2 = frontier(&p2);
        let mut f3 = frontier3(&p3);
        f2.sort_unstable();
        f3.sort_unstable();
        assert_eq!(f2, f3);
    }

    #[test]
    fn frontier3_duplicates_keep_exactly_one() {
        let p = pts3(&[(1.0, 1.0, 1.0), (1.0, 1.0, 1.0), (1.0, 1.0, 1.0)]);
        assert_eq!(frontier3(&p), vec![0]);
    }

    #[test]
    fn frontier3_equal_xy_ties_resolve_by_z() {
        // Same (x, y): only the smallest z survives; same (x, z): smallest y.
        let p = pts3(&[(1.0, 2.0, 5.0), (1.0, 2.0, 3.0), (1.0, 1.0, 5.0)]);
        let mut f = frontier3(&p);
        f.sort_unstable();
        assert_eq!(f, vec![1, 2]);
    }

    #[test]
    fn frontier3_matches_quadratic_reference_on_random_cloud() {
        // Pseudo-random cloud (LCG, deterministic): the sweep must agree
        // with the O(n^2) definition, modulo the duplicate convention
        // (no duplicates occur with these draws).
        let mut state = 0x2545F4914F6CDD1Du64;
        let mut next = || {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            ((state >> 33) % 1000) as f64 / 10.0
        };
        let p: Vec<Point3> = (0..300)
            .map(|i| Point3::new(next(), next(), next(), i))
            .collect();
        let mut fast = frontier3(&p);
        fast.sort_unstable();
        let mut slow: Vec<usize> = (0..p.len())
            .filter(|&i| {
                !p.iter().enumerate().any(|(j, q)| {
                    q.dominates(&p[i])
                        || (j < i && q.x == p[i].x && q.y == p[i].y && q.z == p[i].z)
                })
            })
            .collect();
        slow.sort_unstable();
        assert_eq!(fast, slow);
    }

    #[test]
    fn frontier3_empty_and_single() {
        assert!(frontier3(&[]).is_empty());
        assert_eq!(frontier3(&pts3(&[(1.0, 2.0, 3.0)])), vec![0]);
    }

    // ------------------------------------------------- incremental archive

    /// LCG cloud shared by the archive tests (same draw as the frontier3
    /// reference test, different seed).
    fn lcg_cloud(seed: u64, n: usize) -> Vec<Point3> {
        let mut state = seed;
        let mut next = || {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            ((state >> 33) % 100) as f64 / 4.0
        };
        (0..n).map(|i| Point3::new(next(), next(), next(), i)).collect()
    }

    #[test]
    fn archive_matches_frontier3_on_random_cloud() {
        // Online insertion must converge to exactly the offline frontier —
        // same member set, and (lex-sorted) same order.  The coarse grid
        // (400 draws from 100 levels per axis) forces duplicate and
        // equal-coordinate collisions, exercising the weak-dominance ties.
        let p = lcg_cloud(0x9E3779B97F4A7C15, 400);
        let mut arch = Archive3::new();
        for &q in &p {
            arch.insert(q);
        }
        let mut want: Vec<Point3> = frontier3(&p).into_iter().map(|i| p[i]).collect();
        want.sort_by(|a, b| {
            a.x.total_cmp(&b.x).then(a.y.total_cmp(&b.y)).then(a.z.total_cmp(&b.z))
        });
        let got: Vec<Point3> = arch.members().to_vec();
        assert_eq!(got.len(), want.len());
        for (g, w) in got.iter().zip(&want) {
            assert_eq!((g.x, g.y, g.z, g.id), (w.x, w.y, w.z, w.id));
        }
        assert!(arch.inserts() >= arch.len());
    }

    #[test]
    fn archive_rejects_duplicates_and_dominated_keeps_first() {
        let mut arch = Archive3::new();
        assert!(arch.insert(Point3::new(1.0, 2.0, 3.0, 0)));
        // Exact duplicate: weakly dominated by the earlier member.
        assert!(!arch.insert(Point3::new(1.0, 2.0, 3.0, 1)));
        // Strictly dominated.
        assert!(!arch.insert(Point3::new(1.0, 2.0, 3.5, 2)));
        // Dominates the member: evicts it.
        assert!(arch.insert(Point3::new(1.0, 1.0, 3.0, 3)));
        assert_eq!(arch.len(), 1);
        assert_eq!(arch.members()[0].id, 3);
        assert_eq!(arch.inserts(), 2);
        // The evicted member's coordinates are dominated if re-offered.
        assert!(!arch.insert(Point3::new(1.0, 2.0, 3.0, 4)));
        assert!(arch.dominated(&Point3::new(2.0, 1.0, 3.0, 5)));
        assert!(!arch.dominated(&Point3::new(0.5, 9.0, 9.0, 6)));
    }

    #[test]
    fn archive_insert_keeps_lexicographic_order_and_evicts_runs() {
        let mut arch = Archive3::new();
        // An anti-chain along x/y with constant z.
        for (i, x) in [4.0, 1.0, 3.0, 2.0].iter().enumerate() {
            assert!(arch.insert(Point3::new(*x, 10.0 - x, 5.0, i)));
        }
        let xs: Vec<f64> = arch.members().iter().map(|m| m.x).collect();
        assert_eq!(xs, vec![1.0, 2.0, 3.0, 4.0]);
        // One dominator wipes the x >= 2 half in a single insert.
        assert!(arch.insert(Point3::new(2.0, 6.0, 5.0, 9)));
        let ids: Vec<usize> = arch.members().iter().map(|m| m.id).collect();
        assert_eq!(ids, vec![1, 9]);
    }

    #[test]
    fn archive_nan_rejected() {
        let mut arch = Archive3::new();
        assert!(!arch.insert(Point3::new(f64::NAN, 1.0, 1.0, 0)));
        assert!(!arch.insert(Point3::new(1.0, f64::NAN, 1.0, 1)));
        assert!(!arch.insert(Point3::new(1.0, 1.0, f64::NAN, 2)));
        assert!(arch.is_empty());
        assert_eq!(arch.inserts(), 0);
    }

    #[test]
    fn archive_merge_matches_single_archive_for_any_partition() {
        // Sharded insertion + merge must land on the same member set as
        // one sequential archive — the determinism the engine-parallel
        // sweep rests on.
        let p = lcg_cloud(0xD1B54A32D192ED03, 300);
        let mut whole = Archive3::new();
        for &q in &p {
            whole.insert(q);
        }
        for shards in [2usize, 3, 7] {
            let mut parts: Vec<Archive3> = vec![Archive3::new(); shards];
            for (i, &q) in p.iter().enumerate() {
                parts[i % shards].insert(q);
            }
            let mut merged = Archive3::new();
            for part in &parts {
                merged.merge(part);
            }
            let a: Vec<(u64, u64, u64)> = whole
                .members()
                .iter()
                .map(|m| (m.x.to_bits(), m.y.to_bits(), m.z.to_bits()))
                .collect();
            let b: Vec<(u64, u64, u64)> = merged
                .members()
                .iter()
                .map(|m| (m.x.to_bits(), m.y.to_bits(), m.z.to_bits()))
                .collect();
            assert_eq!(a, b, "shards={shards}");
        }
    }
}
