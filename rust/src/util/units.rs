//! Size/energy/time unit helpers and human-readable formatting.
//!
//! The paper mixes kiB/MiB (memory sizes), mJ/nJ (energies), mm² (areas) and
//! clock cycles; keeping conversions in one place avoids the classic
//! 1000-vs-1024 and mJ-vs-nJ slips in the DSE tables.

pub const KIB: usize = 1024;
pub const MIB: usize = 1024 * 1024;

/// Bytes -> "25 kiB" / "8 MiB" / "123 B", matching the paper's table style.
pub fn fmt_size(bytes: usize) -> String {
    if bytes >= MIB && bytes % MIB == 0 {
        format!("{} MiB", bytes / MIB)
    } else if bytes >= KIB && bytes % KIB == 0 {
        format!("{} kiB", bytes / KIB)
    } else if bytes >= MIB {
        format!("{:.2} MiB", bytes as f64 / MIB as f64)
    } else if bytes >= KIB {
        format!("{:.1} kiB", bytes as f64 / KIB as f64)
    } else {
        format!("{bytes} B")
    }
}

/// "25 kiB" / "8MiB" / "512" -> bytes (accepts the forms used in configs).
pub fn parse_size(text: &str) -> Option<usize> {
    let t = text.trim();
    let lower = t.to_ascii_lowercase();
    let (num, mult) = if let Some(stripped) = lower.strip_suffix("mib") {
        (stripped, MIB)
    } else if let Some(stripped) = lower.strip_suffix("kib") {
        (stripped, KIB)
    } else if let Some(stripped) = lower.strip_suffix('b') {
        (stripped, 1)
    } else {
        (lower.as_str(), 1)
    };
    let num = num.trim();
    if let Ok(v) = num.parse::<usize>() {
        return Some(v * mult);
    }
    num.parse::<f64>().ok().map(|v| (v * mult as f64).round() as usize)
}

/// Joules -> adaptive "1.234 mJ" / "56.7 µJ" / "8.9 nJ".
pub fn fmt_energy(joules: f64) -> String {
    let a = joules.abs();
    if a >= 1e-3 {
        format!("{:.3} mJ", joules * 1e3)
    } else if a >= 1e-6 {
        format!("{:.2} µJ", joules * 1e6)
    } else if a >= 1e-9 {
        format!("{:.2} nJ", joules * 1e9)
    } else {
        format!("{:.2} pJ", joules * 1e12)
    }
}

/// Watts -> "123 mW" / "4.5 µW".
pub fn fmt_power(watts: f64) -> String {
    let a = watts.abs();
    if a >= 1.0 {
        format!("{watts:.2} W")
    } else if a >= 1e-3 {
        format!("{:.1} mW", watts * 1e3)
    } else {
        format!("{:.2} µW", watts * 1e6)
    }
}

/// Seconds -> "8.62 ms" / "1.2 µs" / "3.4 s".
pub fn fmt_time(seconds: f64) -> String {
    let a = seconds.abs();
    if a >= 1.0 {
        format!("{seconds:.2} s")
    } else if a >= 1e-3 {
        format!("{:.2} ms", seconds * 1e3)
    } else if a >= 1e-6 {
        format!("{:.2} µs", seconds * 1e6)
    } else {
        format!("{:.1} ns", seconds * 1e9)
    }
}

/// Integer with thousands separators: 15233 -> "15,233".
pub fn fmt_count(n: u64) -> String {
    let digits = n.to_string();
    let mut out = String::new();
    for (i, c) in digits.chars().enumerate() {
        if i > 0 && (digits.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(c);
    }
    out
}

/// Next power of two >= n (sizes in Algorithm 1 pools).
pub fn next_pow2(n: usize) -> usize {
    n.next_power_of_two()
}

pub fn is_pow2(n: usize) -> bool {
    n != 0 && n & (n - 1) == 0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn size_formatting_matches_paper_style() {
        assert_eq!(fmt_size(25 * KIB), "25 kiB");
        assert_eq!(fmt_size(8 * MIB), "8 MiB");
        assert_eq!(fmt_size(108 * KIB), "108 kiB");
        assert_eq!(fmt_size(100), "100 B");
        assert_eq!(fmt_size(23040), "22.5 kiB");
    }

    #[test]
    fn size_parsing_roundtrip() {
        for &b in &[25 * KIB, 64 * KIB, 8 * MIB, 512] {
            assert_eq!(parse_size(&fmt_size(b)), Some(b));
        }
        assert_eq!(parse_size("2 MiB"), Some(2 * MIB));
        assert_eq!(parse_size("108kib"), Some(108 * KIB));
        assert_eq!(parse_size("1024"), Some(1024));
        assert_eq!(parse_size("x"), None);
    }

    #[test]
    fn energy_power_time_formatting() {
        assert_eq!(fmt_energy(1.859e-3), "1.859 mJ");
        assert_eq!(fmt_energy(0.501e-3), "501.00 µJ");
        assert_eq!(fmt_energy(1.6e-9), "1.60 nJ");
        assert_eq!(fmt_power(0.0581), "58.1 mW");
        assert_eq!(fmt_time(8.62e-3), "8.62 ms");
        assert_eq!(fmt_time(0.072e-9), "0.1 ns");
    }

    #[test]
    fn count_separators() {
        assert_eq!(fmt_count(15233), "15,233");
        assert_eq!(fmt_count(215693), "215,693");
        assert_eq!(fmt_count(7), "7");
    }

    #[test]
    fn pow2_helpers() {
        assert_eq!(next_pow2(23040), 32768);
        assert!(is_pow2(64 * KIB));
        assert!(!is_pow2(108 * KIB));
    }
}
