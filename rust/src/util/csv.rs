//! CSV writer for the `results/` dumps (one file per paper figure/table).
//!
//! Deliberately minimal: comma separator, RFC-4180-style quoting only when
//! needed, numeric formatting stable across runs so figures can be diffed.

use std::io::Write;
use std::path::Path;

pub struct Csv {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Csv {
    pub fn new(header: &[&str]) -> Csv {
        Csv {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width {} != header width {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells);
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn write_file(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_string().as_bytes())
    }
}

/// Rendering goes through `Display`, so `Csv::to_string()` comes from the
/// blanket `ToString` impl (satisfies `clippy::inherent_to_string`).
impl std::fmt::Display for Csv {
    fn fmt(&self, out: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(out, "{}", join(&self.header))?;
        for r in &self.rows {
            writeln!(out, "{}", join(r))?;
        }
        Ok(())
    }
}

fn join(cells: &[String]) -> String {
    cells
        .iter()
        .map(|c| quote(c))
        .collect::<Vec<_>>()
        .join(",")
}

fn quote(cell: &str) -> String {
    if cell.contains(',') || cell.contains('"') || cell.contains('\n') {
        format!("\"{}\"", cell.replace('"', "\"\""))
    } else {
        cell.to_string()
    }
}

/// Cell formatting helpers with stable precision.
pub fn f(v: f64) -> String {
    if v == 0.0 {
        "0".to_string()
    } else if v.abs() >= 1e6 || v.abs() < 1e-4 {
        format!("{v:.6e}")
    } else {
        format!("{v:.6}")
    }
}

pub fn u(v: usize) -> String {
    v.to_string()
}

pub fn s(v: &str) -> String {
    v.to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_layout() {
        let mut c = Csv::new(&["op", "cycles"]);
        c.row(vec![s("Conv1"), u(32400)]);
        c.row(vec![s("Prim"), u(746000)]);
        assert_eq!(c.to_string(), "op,cycles\nConv1,32400\nPrim,746000\n");
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn quoting() {
        let mut c = Csv::new(&["a"]);
        c.row(vec![s("x,y")]);
        c.row(vec![s("say \"hi\"")]);
        assert_eq!(c.to_string(), "a\n\"x,y\"\n\"say \"\"hi\"\"\"\n");
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn width_mismatch_panics() {
        let mut c = Csv::new(&["a", "b"]);
        c.row(vec![s("only-one")]);
    }

    #[test]
    fn float_formatting_stable() {
        assert_eq!(f(0.0), "0");
        assert_eq!(f(0.501), "0.501000");
        assert_eq!(f(1.5e-9), "1.500000e-9");
    }
}
