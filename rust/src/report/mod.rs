//! Figure/table regenerators: one function per paper artifact (the E01–E18
//! index in DESIGN.md section 9).  Each writes a CSV (and, for tables, a
//! markdown file) under `results/` and returns the CSV for inspection.
//!
//! `descnet report all` regenerates everything; the per-figure bench
//! targets in `benches/` call the same functions.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::ctx::EvalCtx;
use crate::dataflow::{profile_network, tpu, NetworkProfile};
use crate::dse;
use crate::dse::multi::WorkloadSet;
use crate::energy::{self, system_with_org};
use crate::fleet;
use crate::memory::{cover_op, prefetch, Component, MemSpec, Organization};
use crate::model::{capsnet_mnist, deepcaps_cifar10};
use crate::pmu;
use crate::util::csv::{f, s, u, Csv};
use crate::util::table::Table;
use crate::util::units::fmt_size;

/// Everything the generators need, computed once: the unified evaluation
/// context (engine, technology, accelerator, CACTI cache, budget — DESIGN.md
/// section 17) plus the pre-profiled paper networks and the output
/// directory.  Thread count and latency budget are read from `eval`, so no
/// generator takes them positionally.
pub struct ReportCtx {
    pub eval: EvalCtx,
    pub capsnet: NetworkProfile,
    pub deepcaps: NetworkProfile,
    pub out_dir: PathBuf,
}

impl ReportCtx {
    pub fn new(eval: EvalCtx, out_dir: &Path) -> ReportCtx {
        let capsnet = profile_network(&capsnet_mnist(), eval.accel());
        let deepcaps = profile_network(&deepcaps_cifar10(), eval.accel());
        ReportCtx {
            eval,
            capsnet,
            deepcaps,
            out_dir: out_dir.to_path_buf(),
        }
    }

    fn write(&self, name: &str, csv: &Csv) {
        let path = self.out_dir.join(name);
        csv.write_file(&path)
            .unwrap_or_else(|e| panic!("writing {}: {e}", path.display()));
    }

    fn write_md(&self, name: &str, table: &Table) {
        let path = self.out_dir.join(name);
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir).ok();
        }
        std::fs::write(&path, table.to_markdown())
            .unwrap_or_else(|e| panic!("writing {}: {e}", path.display()));
    }

    fn profile(&self, net: &str) -> &NetworkProfile {
        match net {
            "capsnet" => &self.capsnet,
            "deepcaps" => &self.deepcaps,
            other => panic!("unknown network {other}"),
        }
    }

    /// The paper's selected Pareto organizations (Table I, re-derived from
    /// our own DSE selection rule in `selected_orgs`).
    pub fn table1_sep(&self) -> Organization {
        let (d, w, a) = dse::sep_sizes(&self.capsnet);
        Organization::sep(MemSpec::new(d, 1), MemSpec::new(w, 1), MemSpec::new(a, 1))
    }
}

// ---------------------------------------------------------------- E01 Fig 1

/// Fig 1: per-op on-chip memory usage, CapsAcc vs TPU mapping.
pub fn fig1(ctx: &ReportCtx) -> Csv {
    let mut csv = Csv::new(&[
        "op",
        "capsacc_data_B",
        "capsacc_weight_B",
        "capsacc_acc_B",
        "capsacc_total_B",
        "tpu_total_B",
    ]);
    let net = capsnet_mnist();
    let tpu_usage = tpu::profile_tpu(&net, ctx.eval.accel());
    for (op, t) in ctx.capsnet.ops.iter().zip(&tpu_usage) {
        csv.row(vec![
            s(&op.name),
            u(op.usage_d),
            u(op.usage_w),
            u(op.usage_a),
            u(op.usage_total()),
            u(t.total()),
        ]);
    }
    ctx.write("fig01_memory_utilization.csv", &csv);
    csv
}

// ---------------------------------------------------------------- E02 Fig 7

/// Fig 7: parameters vs execution time per layer group (the dynamic-routing
/// disproportion).  Time here is the analytical CapsAcc time; the serving
/// example records wall-clock PJRT stage times alongside.
pub fn fig7(ctx: &ReportCtx) -> Csv {
    let mut csv = Csv::new(&["layer", "params", "macs", "time_ms", "time_share"]);
    let net = capsnet_mnist();
    let total = ctx.capsnet.total_cycles() as f64;
    // Group: Conv1, Prim, ClassCaps(+routing).
    let groups: [(&str, Box<dyn Fn(&str) -> bool>); 3] = [
        ("Conv1", Box::new(|n: &str| n == "Conv1")),
        ("PrimaryCaps", Box::new(|n: &str| n == "Prim")),
        ("ClassCaps+Routing", Box::new(|n: &str| n.starts_with("Class"))),
    ];
    for (label, pred) in groups {
        let params: u64 = net
            .ops
            .iter()
            .filter(|o| pred(&o.name))
            .map(|o| o.param_bytes())
            .sum();
        let macs: u64 = ctx
            .capsnet
            .ops
            .iter()
            .filter(|o| pred(&o.name))
            .map(|o| o.macs)
            .sum();
        let cycles: u64 = ctx
            .capsnet
            .ops
            .iter()
            .filter(|o| pred(&o.name))
            .map(|o| o.cycles)
            .sum();
        csv.row(vec![
            s(label),
            u(params as usize),
            u(macs as usize),
            f(cycles as f64 / ctx.capsnet.clock_hz * 1e3),
            f(cycles as f64 / total),
        ]);
    }
    ctx.write("fig07_params_vs_time.csv", &csv);
    csv
}

// ---------------------------------------------------------------- E03 Fig 9

/// Fig 9a/9b: clock cycles per operation.
pub fn fig9(ctx: &ReportCtx) -> Csv {
    let mut csv = Csv::new(&["network", "op", "group", "cycles", "share"]);
    for p in [&ctx.capsnet, &ctx.deepcaps] {
        let total = p.total_cycles() as f64;
        for op in &p.ops {
            csv.row(vec![
                s(&p.network),
                s(&op.name),
                s(op.group.label()),
                u(op.cycles as usize),
                f(op.cycles as f64 / total),
            ]);
        }
    }
    ctx.write("fig09_cycles.csv", &csv);
    csv
}

// -------------------------------------------------------- E04/E05 Fig 10/11

fn usage_accesses_csv(p: &NetworkProfile) -> Csv {
    let mut csv = Csv::new(&[
        "op", "usage_d", "usage_w", "usage_a", "rd_d", "wr_d", "rd_w", "wr_w", "rd_a", "wr_a",
    ]);
    for op in &p.ops {
        csv.row(vec![
            s(&op.name),
            u(op.usage_d),
            u(op.usage_w),
            u(op.usage_a),
            u(op.rd_d as usize),
            u(op.wr_d as usize),
            u(op.rd_w as usize),
            u(op.wr_w as usize),
            u(op.rd_a as usize),
            u(op.wr_a as usize),
        ]);
    }
    csv
}

pub fn fig10(ctx: &ReportCtx) -> Csv {
    let csv = usage_accesses_csv(&ctx.capsnet);
    ctx.write("fig10_capsnet_usage_accesses.csv", &csv);
    csv
}

pub fn fig11(ctx: &ReportCtx) -> Csv {
    let csv = usage_accesses_csv(&ctx.deepcaps);
    ctx.write("fig11_deepcaps_usage_accesses.csv", &csv);
    csv
}

// --------------------------------------------------------------- E06 Fig 12

/// Fig 12: energy breakdown of versions (a) and (b).
pub fn fig12(ctx: &ReportCtx) -> Result<Csv> {
    let mut csv = Csv::new(&["version", "component", "energy_mj", "share"]);
    let a = energy::version_a(&ctx.capsnet, ctx.eval.tech())?;
    let b = energy::version_b(&ctx.capsnet, ctx.eval.tech(), dse::smp_size(&ctx.capsnet))?;
    for sys in [&a, &b] {
        let total = sys.total_j();
        let mut rows: Vec<(&str, f64)> = vec![
            ("accelerator_dyn", sys.accel.dyn_j),
            ("accelerator_static", sys.accel.static_j),
            ("onchip_dyn", sys.onchip.dyn_j()),
            ("onchip_static", sys.onchip.static_j()),
        ];
        if let Some(d) = sys.dram {
            rows.push(("offchip_transfer", d.transfer_j));
            rows.push(("offchip_background", d.background_j));
        }
        for (name, e) in rows {
            csv.row(vec![s(&sys.label), s(name), f(e * 1e3), f(e / total)]);
        }
        csv.row(vec![s(&sys.label), s("TOTAL"), f(total * 1e3), f(1.0)]);
    }
    ctx.write("fig12_energy_versions.csv", &csv);
    Ok(csv)
}

// ------------------------------------------------- E07/E09 Fig 18/20 + tabs

/// Runs the full DSE for one network and dumps scatter + frontier +
/// selected configurations (Fig 18/20, Tables I/II) — 3-D since the
/// timeline simulator: every row carries its simulated per-inference
/// latency, and the context's latency budget (the CLI's `--latency-budget`)
/// excludes configurations that miss the budget before Pareto/selection.
/// The last two tuple elements are the number of budget-excluded
/// configurations (0 when unconstrained) and the branch-and-bound counters
/// of the sweep, so callers can report enumerated vs pruned vs evaluated
/// counts.  Also writes the counters as `dse_stats_<net>.csv` (E23).
pub fn dse_scatter(
    ctx: &ReportCtx,
    net: &str,
) -> Result<(Csv, Table, usize, dse::stream::SweepStats)> {
    let profile = ctx.profile(net);
    let result = dse::run(&ctx.eval, profile)?;
    let pareto: std::collections::BTreeSet<usize> = result.pareto.iter().copied().collect();
    let selected: std::collections::BTreeMap<usize, String> = result
        .selected
        .iter()
        .map(|(name, i)| (*i, name.clone()))
        .collect();

    let mut csv = Csv::new(&[
        "option",
        "label",
        "shared_B",
        "shared_SC",
        "data_B",
        "data_SC",
        "weight_B",
        "weight_SC",
        "acc_B",
        "acc_SC",
        "area_mm2",
        "energy_mj",
        "latency_ms",
        "pareto",
        "selected",
    ]);
    for (i, p) in result.points.iter().enumerate() {
        let spec = |c| {
            p.org
                .spec(c)
                .map(|m: MemSpec| (m.size, m.sectors))
                .unwrap_or((0, 0))
        };
        let (ss, scs) = spec(Component::Shared);
        let (sd, scd) = spec(Component::Data);
        let (sw, scw) = spec(Component::Weight);
        let (sa, sca) = spec(Component::Acc);
        csv.row(vec![
            s(p.option().label()),
            s(&p.org.label()),
            u(ss),
            u(scs),
            u(sd),
            u(scd),
            u(sw),
            u(scw),
            u(sa),
            u(sca),
            f(p.area_mm2),
            f(p.energy_j * 1e3),
            f(p.latency_s * 1e3),
            s(if pareto.contains(&i) { "1" } else { "0" }),
            s(selected.get(&i).map(String::as_str).unwrap_or("")),
        ]);
    }

    // Table I/II analogue: the selected configurations (with the simulated
    // per-inference latency — equal across options at the paper constants,
    // the "no performance loss" column).
    let mut table = Table::new(&[
        "Mem", "Shared SZ", "SC", "Data SZ", "SC", "Weight SZ", "SC", "Acc SZ", "SC",
        "Area [mm2]", "Energy [mJ]", "Latency [ms]",
    ]);
    for (name, i) in &result.selected {
        let p = &result.points[*i];
        let cell = |c| {
            p.org
                .spec(c)
                .map(|m: MemSpec| (fmt_size(m.size), m.sectors.to_string()))
                .unwrap_or(("-".into(), "-".into()))
        };
        let (ss, scs) = cell(Component::Shared);
        let (sd, scd) = cell(Component::Data);
        let (sw, scw) = cell(Component::Weight);
        let (sa, sca) = cell(Component::Acc);
        table.row(vec![
            name.clone(),
            ss,
            scs,
            sd,
            scd,
            sw,
            scw,
            sa,
            sca,
            format!("{:.3}", p.area_mm2),
            format!("{:.3}", p.energy_j * 1e3),
            format!("{:.4}", p.latency_s * 1e3),
        ]);
    }

    let (fig, tab) = match net {
        "capsnet" => ("fig18_dse_capsnet.csv", "table1_selected_capsnet.md"),
        _ => ("fig20_dse_deepcaps.csv", "table2_selected_deepcaps.md"),
    };
    ctx.write(fig, &csv);
    ctx.write_md(tab, &table);
    ctx.write(&format!("dse_stats_{net}.csv"), &stats_csv(net, &result.stats));
    Ok((csv, table, result.excluded_by_budget, result.stats))
}

/// E23 pruning-effectiveness artifact: one row of branch-and-bound
/// counters for a sweep.
fn stats_csv(net: &str, st: &dse::stream::SweepStats) -> Csv {
    let mut csv = Csv::new(&[
        "network",
        "enumerated",
        "pruned",
        "evaluated",
        "pruned_fraction",
        "subtrees",
        "subtrees_pruned",
        "archive_inserts",
        "archive_len",
        "mean_bound_gap",
        // Factored-evaluator wall-time split (ISSUE 7): nondeterministic
        // run to run, recorded for throughput accounting only — never
        // compared by goldens or determinism tests.
        "prep_s",
        "eval_s",
    ]);
    csv.row(vec![
        s(net),
        u(st.enumerated),
        u(st.pruned),
        u(st.evaluated),
        f(st.pruned_fraction()),
        u(st.subtrees),
        u(st.subtrees_pruned),
        u(st.archive_inserts),
        u(st.archive_len),
        f(st.mean_bound_gap()),
        f(st.prep_s),
        f(st.eval_s),
    ]);
    csv
}

// ----------------------------------------------- E08/E10 Fig 19/21 breakdown

/// Figs 19/21 (a)-(d): per-component area/energy breakdowns and per-op
/// energy for the per-option selected configurations.
pub fn breakdowns(ctx: &ReportCtx, net: &str) -> Result<Csv> {
    let profile = ctx.profile(net);
    let result = dse::run(&ctx.eval, profile)?;
    let mut csv = Csv::new(&[
        "option",
        "component",
        "size_B",
        "sectors",
        "area_mm2",
        "dyn_mj",
        "static_mj",
        "wakeup_nj",
    ]);
    let mut per_op = Csv::new(&["option", "op", "energy_mj"]);
    for (name, i) in &result.selected {
        let org = &result.points[*i].org;
        let e = energy::evaluate_org(org, profile, ctx.eval.tech())?;
        for m in &e.memories {
            csv.row(vec![
                s(name),
                s(m.component.label()),
                u(m.spec.size),
                u(m.spec.sectors),
                f(m.area_mm2),
                f(m.dyn_j * 1e3),
                f(m.static_j * 1e3),
                f(m.wakeup_j * 1e9),
            ]);
        }
        for (op, ej) in energy::per_op_energy(org, profile, ctx.eval.tech())? {
            per_op.row(vec![s(name), s(&op), f(ej * 1e3)]);
        }
    }
    let (a, b) = match net {
        "capsnet" => ("fig19_capsnet_breakdown.csv", "fig19d_capsnet_per_op.csv"),
        _ => ("fig21_deepcaps_breakdown.csv", "fig21d_deepcaps_per_op.csv"),
    };
    ctx.write(a, &csv);
    ctx.write(b, &per_op);
    Ok(csv)
}

// --------------------------------------------------------------- E11 Fig 22

/// Fig 22: HY-PG DSE with constrained shared-memory ports.
pub fn fig22(ctx: &ReportCtx) -> Result<Csv> {
    let profile = &ctx.deepcaps;
    let timeline = crate::sim::Timeline::build(profile, ctx.eval.tech(), ctx.eval.accel());
    let mut csv = Csv::new(&["ports", "label", "area_mm2", "energy_mj", "pareto"]);
    for ports in [1usize, 2, 3] {
        let orgs = dse::enumerate_hy_ports(profile, ports)?;
        let points = dse::evaluate_all(&ctx.eval, &orgs, profile, &timeline);
        let front: std::collections::BTreeSet<usize> =
            dse::pareto_indices(&points).into_iter().collect();
        for (i, p) in points.iter().enumerate() {
            csv.row(vec![
                u(ports),
                s(&p.org.label()),
                f(p.area_mm2),
                f(p.energy_j * 1e3),
                s(if front.contains(&i) { "1" } else { "0" }),
            ]);
        }
    }
    ctx.write("fig22_hy_pg_ports.csv", &csv);
    Ok(csv)
}

// ---------------------------------------------- E12/E13 Fig 23-26 + E18

/// Figs 23–26: whole-accelerator energy/area for the chosen organizations,
/// plus the headline savings vs version (a) (E18).
pub fn whole_accelerator(ctx: &ReportCtx, net: &str) -> Result<Csv> {
    let profile = ctx.profile(net);
    let result = dse::run(&ctx.eval, profile)?;
    let selected: std::collections::BTreeMap<String, usize> =
        result.selected.iter().cloned().collect();

    let a = energy::version_a(profile, ctx.eval.tech())?;
    let mut csv = Csv::new(&[
        "system",
        "total_energy_mj",
        "total_area_mm2",
        "accel_mj",
        "onchip_dyn_mj",
        "onchip_static_mj",
        "offchip_mj",
        "energy_saving_vs_a",
        "area_saving_vs_a",
        "no_perf_loss",
    ]);
    csv.row(vec![
        s(&a.label),
        f(a.total_j() * 1e3),
        f(a.area_mm2),
        f(a.accel.total_j() * 1e3),
        f(a.onchip.dyn_j() * 1e3),
        f(a.onchip.static_j() * 1e3),
        f(0.0),
        f(0.0),
        f(0.0),
        s("1"),
    ]);

    let report = prefetch::analyze(profile, ctx.eval.tech(), ctx.eval.accel());
    for option in ["SEP", "SEP-PG", "HY-PG"] {
        let Some(&i) = selected.get(option) else { continue };
        let sys = system_with_org(profile, ctx.eval.tech(), &result.points[i].org, "DESCNet")?;
        csv.row(vec![
            s(&sys.label),
            f(sys.total_j() * 1e3),
            f(sys.area_mm2),
            f(sys.accel.total_j() * 1e3),
            f(sys.onchip.dyn_j() * 1e3),
            f(sys.onchip.static_j() * 1e3),
            f(sys.dram.map_or(0.0, |d| d.total_j()) * 1e3),
            f(1.0 - sys.total_j() / a.total_j()),
            f(1.0 - sys.area_mm2 / a.area_mm2),
            s(if report.no_performance_loss() { "1" } else { "0" }),
        ]);
    }
    let name = match net {
        "capsnet" => "fig23_24_capsnet_whole_accelerator.csv",
        _ => "fig25_26_deepcaps_whole_accelerator.csv",
    };
    ctx.write(name, &csv);
    Ok(csv)
}

// ------------------------------------------------------------- E14 Table III

/// Table III: per-memory area/dynamic/static/wakeup for the selected
/// configurations of both networks.
pub fn table3(ctx: &ReportCtx) -> Result<Table> {
    let mut table = Table::new(&[
        "NN", "Mem", "Component", "Size", "SC", "Area [mm2]", "Dyn [mJ]", "Static [mJ]",
        "Wakeup [nJ]",
    ]);
    for net in ["capsnet", "deepcaps"] {
        let profile = ctx.profile(net);
        let result = dse::run(&ctx.eval, profile)?;
        for (name, i) in &result.selected {
            let org = &result.points[*i].org;
            let e = energy::evaluate_org(org, profile, ctx.eval.tech())?;
            for m in &e.memories {
                table.row(vec![
                    net.to_string(),
                    name.clone(),
                    m.component.label().to_string(),
                    fmt_size(m.spec.size),
                    m.spec.sectors.to_string(),
                    format!("{:.3}", m.area_mm2),
                    format!("{:.3}", m.dyn_j * 1e3),
                    format!("{:.3}", m.static_j * 1e3),
                    format!("{:.3}", m.wakeup_j * 1e9),
                ]);
            }
        }
    }
    ctx.write_md("table3_area_energy.md", &table);
    Ok(table)
}

// ----------------------------------------------------------- E15 Fig 27/28

pub fn fig27_28(ctx: &ReportCtx) -> Csv {
    let mut csv = Csv::new(&["network", "op", "off_rd_B", "off_wr_B"]);
    for p in [&ctx.capsnet, &ctx.deepcaps] {
        for op in &p.ops {
            csv.row(vec![
                s(&p.network),
                s(&op.name),
                u(op.off_rd as usize),
                u(op.off_wr as usize),
            ]);
        }
    }
    ctx.write("fig27_28_offchip_accesses.csv", &csv);
    csv
}

// -------------------------------------------------------- E16 Fig 29/31/32

/// Figs 29/31: operation-wise memory breakdown (which physical memory holds
/// which value class) for the selected design options.
pub fn memory_breakdown(ctx: &ReportCtx, net: &str) -> Result<Csv> {
    let profile = ctx.profile(net);
    let result = dse::run(&ctx.eval, profile)?;
    let mut csv = Csv::new(&[
        "option", "op", "ded_d", "ded_w", "ded_a", "sh_d", "sh_w", "sh_a", "shared_types",
    ]);
    for (name, i) in &result.selected {
        let org = &result.points[*i].org;
        for op in &profile.ops {
            let cov = cover_op(org, op)
                .ok_or_else(|| anyhow!("selected org no longer fits op '{}'", op.name))?;
            csv.row(vec![
                s(name),
                s(&op.name),
                u(cov.ded_d),
                u(cov.ded_w),
                u(cov.ded_a),
                u(cov.sh_d),
                u(cov.sh_w),
                u(cov.sh_a),
                u(cov.shared_types()),
            ]);
        }
    }
    let name = match net {
        "capsnet" => "fig29_capsnet_memory_breakdown.csv",
        _ => "fig31_deepcaps_memory_breakdown.csv",
    };
    ctx.write(name, &csv);
    Ok(csv)
}

// --------------------------------------------------------------- E17 Fig 30

/// Fig 30: the HY-PG sector ON/OFF schedule across operations.
pub fn fig30(ctx: &ReportCtx) -> Result<Csv> {
    let profile = &ctx.capsnet;
    let result = dse::run(&ctx.eval, profile)?;
    let selected: std::collections::BTreeMap<String, usize> =
        result.selected.iter().cloned().collect();
    let i = *selected
        .get("HY-PG")
        .ok_or_else(|| anyhow!("DSE selected no HY-PG configuration"))?;
    let org = &result.points[i].org;
    let report = pmu::evaluate(org, profile, ctx.eval.tech())?;
    let mut csv = Csv::new(&["component", "sectors", "op", "sectors_on"]);
    for sched in &report.schedules {
        for (i, op) in profile.ops.iter().enumerate() {
            csv.row(vec![
                s(sched.component.label()),
                u(sched.sectors),
                s(&op.name),
                u(sched.on[i]),
            ]);
        }
    }
    ctx.write("fig30_hy_pg_schedule.csv", &csv);
    Ok(csv)
}

// ------------------------------------------------------------- E18 headline

/// The headline claims, as one summary CSV (and returned for the CLI).
pub fn headline(ctx: &ReportCtx) -> Result<Csv> {
    let mut csv = Csv::new(&["metric", "paper", "ours"]);
    let p = &ctx.capsnet;
    let tech = ctx.eval.tech();
    let a = energy::version_a(p, tech)?;
    let b = energy::version_b(p, tech, dse::smp_size(p))?;
    let result = dse::run(&ctx.eval, p)?;
    let selected: std::collections::BTreeMap<String, usize> =
        result.selected.iter().cloned().collect();
    let pick = |name: &str| -> Result<usize> {
        selected
            .get(name)
            .copied()
            .ok_or_else(|| anyhow!("DSE selected no {name} configuration"))
    };
    let sep_sys = system_with_org(p, tech, &result.points[pick("SEP")?].org, "DESCNet")?;
    let hy_sys = system_with_org(p, tech, &result.points[pick("HY-PG")?].org, "DESCNet")?;
    let report = prefetch::analyze(p, tech, ctx.eval.accel());

    csv.row(vec![s("capsnet_fps"), s("116"), f(p.fps())]);
    csv.row(vec![s("deepcaps_fps"), s("9.7"), f(ctx.deepcaps.fps())]);
    csv.row(vec![
        s("routing_cycle_share"),
        s(">0.50"),
        f(p.routing_cycle_share()),
    ]);
    csv.row(vec![
        s("convcaps2d_cycle_share"),
        s("0.73"),
        f(ctx.deepcaps
            .group_cycle_share(crate::model::LayerGroup::ConvCaps2D)),
    ]);
    csv.row(vec![
        s("version_b_saving_vs_a"),
        s("0.73"),
        f(1.0 - b.total_j() / a.total_j()),
    ]);
    csv.row(vec![
        s("sep_total_energy_saving_vs_a"),
        s("0.78"),
        f(1.0 - sep_sys.total_j() / a.total_j()),
    ]);
    csv.row(vec![
        s("hy_pg_total_energy_saving_vs_a"),
        s("0.79"),
        f(1.0 - hy_sys.total_j() / a.total_j()),
    ]);
    csv.row(vec![
        s("sep_area_saving_vs_a"),
        s("0.47"),
        f(1.0 - sep_sys.area_mm2 / a.area_mm2),
    ]);
    csv.row(vec![
        s("hy_pg_area_saving_vs_a"),
        s("0.40"),
        f(1.0 - hy_sys.area_mm2 / a.area_mm2),
    ]);
    csv.row(vec![
        s("performance_loss_cycles"),
        s("0"),
        u(report.total_stall_cycles as usize),
    ]);
    // Timeline simulator (E21): the gated DESCNet selection must run at the
    // ungated baseline's latency — the "no performance loss" claim as a
    // ratio — and the absolute simulated latency must match 1/116 fps.
    let sep_ungated = ctx.table1_sep();
    let lp_ungated = crate::sim::simulate(p, &sep_ungated, tech, ctx.eval.accel())?;
    let lp_gated = crate::sim::simulate(
        p,
        &result.points[pick("HY-PG")?].org,
        tech,
        ctx.eval.accel(),
    )?;
    csv.row(vec![
        s("sim_capsnet_latency_ms"),
        s("8.6"),
        f(lp_gated.batch_latency_s() * 1e3),
    ]);
    csv.row(vec![
        s("gated_vs_ungated_latency_ratio"),
        s("1.0"),
        f(lp_gated.batch_latency_s() / lp_ungated.batch_latency_s()),
    ]);
    csv.row(vec![
        s("memory_share_of_total_energy"),
        s("0.96"),
        f(b.memory_share()),
    ]);
    ctx.write("headline.csv", &csv);
    Ok(csv)
}

// ------------------------------------------------------- E19 multi-network

/// The default serving-mix workload set for the co-design artifact: both
/// paper networks at batch 1 plus CapsNet at batch 4 (the coordinator's
/// largest batch) — three scenarios sharing one organization.
pub fn default_serving_mix(ctx: &ReportCtx) -> Result<(WorkloadSet, Vec<String>)> {
    let b4 = crate::dataflow::profile_network_batched(
        &capsnet_mnist(),
        ctx.eval.accel(),
        4,
    );
    let names = vec![
        "capsnet".to_string(),
        "deepcaps".to_string(),
        "capsnet@b4".to_string(),
    ];
    let set = WorkloadSet::new(vec![ctx.capsnet.clone(), ctx.deepcaps.clone(), b4])?;
    Ok((set, names))
}

/// Multi-network co-design DSE artifact: the weighted scatter
/// (`dse_multi.csv`) and the selected co-designed organizations with
/// per-network energy columns (`table_multi_selected.md`).  With a latency
/// budget in the context, organizations whose mix-weighted per-inference
/// latency misses the budget are dropped before Pareto/selection.
pub fn multi_dse(
    ctx: &ReportCtx,
    set: &WorkloadSet,
    names: &[String],
) -> Result<(Csv, Table, usize, dse::stream::SweepStats)> {
    // The budget is enforced *inside* the branch-and-bound sweep (the old
    // post-hoc retain here predated the budgeted sweep): excluded
    // configurations never reach the archive, and an all-excluded budget
    // errors with the fastest achievable mix latency.
    let result = dse::multi::run(&ctx.eval, set).context("multi-network co-design DSE")?;
    let excluded = result.excluded_by_budget;
    let pareto: std::collections::BTreeSet<usize> = result.pareto.iter().copied().collect();
    let selected: std::collections::BTreeMap<usize, String> = result
        .selected
        .iter()
        .map(|(name, i)| (*i, name.clone()))
        .collect();

    let mut headers: Vec<String> = vec![
        "option".into(),
        "label".into(),
        "total_B".into(),
        "area_mm2".into(),
        "energy_weighted_mj".into(),
        "latency_weighted_ms".into(),
    ];
    for name in names {
        headers.push(format!("energy_mj_{name}"));
    }
    headers.push("pareto".into());
    headers.push("selected".into());
    let header_refs: Vec<&str> = headers.iter().map(String::as_str).collect();
    let mut csv = Csv::new(&header_refs);
    for (i, p) in result.points.iter().enumerate() {
        let mut row = vec![
            s(p.option().label()),
            s(&p.org.label()),
            u(p.org.total_size()),
            f(p.area_mm2),
            f(p.energy_j * 1e3),
            f(p.latency_s * 1e3),
        ];
        for &e in &result.per_net_j[i] {
            row.push(f(e * 1e3));
        }
        row.push(s(if pareto.contains(&i) { "1" } else { "0" }));
        row.push(s(selected.get(&i).map(String::as_str).unwrap_or("")));
        csv.row(row);
    }

    let mut table_headers: Vec<String> = vec![
        "Mem".into(),
        "Shared SZ".into(),
        "Data SZ".into(),
        "Weight SZ".into(),
        "Acc SZ".into(),
        "Area [mm2]".into(),
        "E-mix [mJ]".into(),
        "Lat-mix [ms]".into(),
    ];
    for name in names {
        table_headers.push(format!("E {name} [mJ]"));
    }
    let table_refs: Vec<&str> = table_headers.iter().map(String::as_str).collect();
    let mut table = Table::new(&table_refs);
    for (name, i) in &result.selected {
        let p = &result.points[*i];
        let cell = |c| {
            p.org
                .spec(c)
                .map(|m: MemSpec| fmt_size(m.size))
                .unwrap_or_else(|| "-".into())
        };
        let mut row = vec![
            name.clone(),
            cell(Component::Shared),
            cell(Component::Data),
            cell(Component::Weight),
            cell(Component::Acc),
            format!("{:.3}", p.area_mm2),
            format!("{:.3}", p.energy_j * 1e3),
            format!("{:.4}", p.latency_s * 1e3),
        ];
        for &e in &result.per_net_j[*i] {
            row.push(format!("{:.3}", e * 1e3));
        }
        table.row(row);
    }

    ctx.write("dse_multi.csv", &csv);
    ctx.write_md("table_multi_selected.md", &table);
    ctx.write("dse_stats_multi.csv", &stats_csv("workload-set", &result.stats));
    Ok((csv, table, excluded, result.stats))
}

// --------------------------------------------------------------- E22 fleet

/// E22: sharded fleet serving artifact.  Simulates both the codesigned
/// fleet and the homogeneous union-SMP baseline fleet under the same
/// seeded arrival trace, and writes per-shard + fleet-level rollups
/// (`fleet.csv`) and the shard-selection table (`table_fleet.md`).  The
/// acceptance row: the codesigned fleet's energy-per-request must not
/// exceed the baseline's (same executable batch sets, same schedule).
pub fn fleet_report(
    ctx: &ReportCtx,
    design: &fleet::FleetDesign,
    cfg: &fleet::FleetConfig,
) -> Result<(Csv, Table, fleet::FleetStats, fleet::FleetStats)> {
    let mut stats = fleet::simulate(&design.plans, cfg)?;
    let mut base = fleet::simulate(&design.baseline, cfg)?;
    // Mean-across-shards utilization of each fleet (the table's Util cell).
    let mean_util = |st: &fleet::FleetStats| -> f64 {
        let h = st.sim_time_s.max(1e-12);
        let busy: f64 = st.per_shard.iter().map(|sh| sh.busy_s).sum();
        busy / (h * st.per_shard.len().max(1) as f64)
    };
    let (stats_util, base_util) = (mean_util(&stats), mean_util(&base));

    let mut csv = Csv::new(&[
        "scope",
        "workload",
        "org",
        "policy",
        "served",
        "batches",
        "padded_slots",
        "utilization",
        "p50_ms",
        "p95_ms",
        "p99_ms",
        "slo_attainment",
        "energy_per_req_mj",
        "availability",
        "crashes",
        "dropped",
        "retries",
        "hedges",
    ]);
    let horizon = stats.sim_time_s;
    let policy = stats.policy.label().to_string();
    let slo = stats.slo_s;
    for (i, sh) in stats.per_shard.iter_mut().enumerate() {
        csv.row(vec![
            s(&format!("shard{i}")),
            s(&sh.workload),
            s(&sh.org_label),
            s(&policy),
            u(sh.served as usize),
            u(sh.batches as usize),
            u(sh.padded_slots as usize),
            f(sh.utilization(horizon)),
            f(sh.latency.p50() * 1e3),
            f(sh.latency.p95() * 1e3),
            f(sh.latency.p99() * 1e3),
            f(sh.slo_attainment(slo)),
            f(sh.energy_per_request_j() * 1e3),
            f(sh.availability(horizon)),
            u(sh.crashes as usize),
            // Dropped/retried/hedged are fleet-scoped (a request may touch
            // several shards), so the per-shard rows report 0.
            u(0),
            u(0),
            u(0),
        ]);
    }
    for (scope, st) in [("fleet", &mut stats), ("fleet-baseline", &mut base)] {
        let label = if scope == "fleet" {
            "codesigned".to_string()
        } else {
            design.baseline_label.clone()
        };
        let policy = st.policy.label().to_string();
        let (requests, batches, padded) = (st.requests, st.batches, st.padded_slots);
        let util = if scope == "fleet" { stats_util } else { base_util };
        let (att, e_req) = (st.slo_attainment(), st.energy_per_request_j());
        let (avail, crashes, dropped, retries, hedges) =
            (st.availability, st.crashes, st.dropped, st.retries, st.hedges);
        csv.row(vec![
            s(scope),
            s("mix"),
            s(&label),
            s(&policy),
            u(requests as usize),
            u(batches as usize),
            u(padded as usize),
            f(util),
            f(st.latency.p50() * 1e3),
            f(st.latency.p95() * 1e3),
            f(st.latency.p99() * 1e3),
            f(att),
            f(e_req * 1e3),
            f(avail),
            u(crashes as usize),
            u(dropped as usize),
            u(retries as usize),
            u(hedges as usize),
        ]);
    }

    let mut table = Table::new(&[
        "Shard", "Workload", "Org", "Batches", "E/req [mJ]", "p99 [ms]", "Util", "Avail",
    ]);
    for (i, (plan, sh)) in design.plans.iter().zip(&mut stats.per_shard).enumerate() {
        table.row(vec![
            format!("{i}"),
            plan.workload.clone(),
            plan.org.label(),
            format!("{:?}", plan.batcher.sizes()),
            format!("{:.3}", sh.energy_per_request_j() * 1e3),
            format!("{:.3}", sh.latency.p99() * 1e3),
            format!("{:.1}%", 100.0 * sh.utilization(horizon)),
            format!("{:.2}%", 100.0 * sh.availability(horizon)),
        ]);
    }
    table.row(vec![
        "fleet".into(),
        "mix".into(),
        "codesigned".into(),
        "-".into(),
        format!("{:.3}", stats.energy_per_request_j() * 1e3),
        format!("{:.3}", stats.latency.p99() * 1e3),
        format!("{:.1}%", 100.0 * stats_util),
        format!("{:.2}%", 100.0 * stats.availability),
    ]);
    table.row(vec![
        "baseline".into(),
        "mix".into(),
        design.baseline_label.clone(),
        "-".into(),
        format!("{:.3}", base.energy_per_request_j() * 1e3),
        format!("{:.3}", base.latency.p99() * 1e3),
        format!("{:.1}%", 100.0 * base_util),
        format!("{:.2}%", 100.0 * base.availability),
    ]);

    ctx.write("fleet.csv", &csv);
    ctx.write_md("table_fleet.md", &table);
    Ok((csv, table, stats, base))
}

/// The canonical E22 configuration (`descnet report fleet` / `report all`):
/// 2 CapsNet shards, JSQ, 100 req/s, 400 requests, 20 ms SLO.
pub fn fleet_default(
    ctx: &ReportCtx,
) -> Result<(Csv, Table, fleet::FleetStats, fleet::FleetStats)> {
    let opts = fleet::DesignOptions {
        shards: 2,
        slo_s: Some(20e-3),
        ..fleet::DesignOptions::default()
    };
    let design = fleet::design_fleet(&ctx.eval, &[capsnet_mnist()], &opts)?;
    let cfg = fleet::FleetConfig {
        slo_s: Some(20e-3),
        ..fleet::FleetConfig::default()
    };
    fleet_report(ctx, &design, &cfg)
}

/// Regenerate everything (the `descnet report all` entry point).
pub fn all(ctx: &ReportCtx) -> Result<Vec<String>> {
    let mut done = Vec::new();
    let mut mark = |name: &str| done.push(name.to_string());
    fig1(ctx);
    mark("fig1");
    fig7(ctx);
    mark("fig7");
    fig9(ctx);
    mark("fig9");
    fig10(ctx);
    mark("fig10");
    fig11(ctx);
    mark("fig11");
    fig12(ctx)?;
    mark("fig12");
    dse_scatter(ctx, "capsnet")?;
    mark("fig18+table1");
    breakdowns(ctx, "capsnet")?;
    mark("fig19");
    dse_scatter(ctx, "deepcaps")?;
    mark("fig20+table2");
    breakdowns(ctx, "deepcaps")?;
    mark("fig21");
    fig22(ctx)?;
    mark("fig22");
    whole_accelerator(ctx, "capsnet")?;
    mark("fig23-24");
    whole_accelerator(ctx, "deepcaps")?;
    mark("fig25-26");
    table3(ctx)?;
    mark("table3");
    fig27_28(ctx);
    mark("fig27-28");
    memory_breakdown(ctx, "capsnet")?;
    mark("fig29");
    memory_breakdown(ctx, "deepcaps")?;
    mark("fig31");
    fig30(ctx)?;
    mark("fig30");
    headline(ctx)?;
    mark("headline");
    let mix = default_serving_mix(ctx)?;
    multi_dse(ctx, &mix.0, &mix.1)?;
    mark("dse-multi");
    fleet_default(ctx)?;
    mark("fleet");
    Ok(done)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;

    fn ctx() -> ReportCtx {
        ctx_with(None)
    }

    /// A 4-thread context with an optional latency budget (must be valid).
    fn ctx_with(budget: Option<f64>) -> ReportCtx {
        let dir = std::env::temp_dir().join("descnet_report_tests");
        let eval = EvalCtx::for_config(&SystemConfig::default())
            .threads(4)
            .latency_budget_s(budget)
            .expect("valid latency budget");
        ReportCtx::new(eval, &dir)
    }

    #[test]
    fn fig1_has_nine_rows_and_tpu_dominates() {
        let c = ctx();
        let csv = fig1(&c);
        assert_eq!(csv.len(), 9);
        let text = csv.to_string();
        assert!(text.contains("Conv1") && text.contains("Class-Update+Softmax3"));
    }

    #[test]
    fn fig9_covers_both_networks() {
        let c = ctx();
        let csv = fig9(&c);
        assert_eq!(csv.len(), 9 + 31);
    }

    #[test]
    fn fig12_contains_both_versions_with_totals() {
        let c = ctx();
        let text = fig12(&c).unwrap().to_string();
        assert!(text.contains("version (a)"));
        assert!(text.contains("version (b)"));
        assert!(text.contains("offchip_transfer"));
        assert_eq!(text.matches("TOTAL").count(), 2);
    }

    #[test]
    fn headline_metrics_present() {
        let c = ctx();
        let text = headline(&c).unwrap().to_string();
        for metric in [
            "capsnet_fps",
            "hy_pg_total_energy_saving_vs_a",
            "performance_loss_cycles",
        ] {
            assert!(text.contains(metric), "{metric}");
        }
    }

    #[test]
    fn fig27_28_off_chip_rows() {
        let c = ctx();
        let csv = fig27_28(&c);
        assert_eq!(csv.len(), 40);
    }

    #[test]
    fn fig30_schedule_rows_cover_components_times_ops() {
        let c = ctx();
        let csv = fig30(&c).unwrap();
        // HY-PG has 4 memories x 9 ops.
        assert_eq!(csv.len() % 9, 0);
        assert!(csv.len() >= 18);
    }

    #[test]
    fn multi_dse_reports_per_network_energy() {
        let c = ctx();
        let (set, names) = default_serving_mix(&c).unwrap();
        assert_eq!(names.len(), 3);
        let (csv, table, excluded, stats) = multi_dse(&c, &set, &names).unwrap();
        assert_eq!(excluded, 0);
        assert!(!csv.is_empty());
        assert_eq!(stats.evaluated + stats.pruned, stats.enumerated);
        assert_eq!(stats.evaluated, csv.len());
        let text = csv.to_string();
        assert!(text.contains("energy_mj_capsnet@b4"), "missing per-net column");
        assert!(text.contains("latency_weighted_ms"), "missing latency column");
        let md = table.to_markdown();
        assert!(md.contains("E deepcaps [mJ]"), "{md}");
        assert!(md.contains("Lat-mix [ms]"), "{md}");
        // One co-designed selection per design option, each with a row.
        assert!(md.lines().count() >= 4);
    }

    #[test]
    fn dse_scatter_reports_latency_and_honors_budget() {
        let c = ctx();
        let (csv, table, excluded, stats) = dse_scatter(&c, "capsnet").unwrap();
        assert_eq!(excluded, 0);
        assert!(csv.to_string().contains("latency_ms"));
        assert!(table.to_markdown().contains("Latency [ms]"));
        // The branch-and-bound sweep culls a nonzero fraction on capsnet
        // and the counters reconcile with the emitted rows.
        assert!(stats.pruned > 0, "{stats:?}");
        assert_eq!(stats.evaluated + stats.pruned, stats.enumerated);
        assert_eq!(stats.evaluated, csv.len());
        // A generous budget keeps every survivor...
        let (loose, _, loose_excluded, _) =
            dse_scatter(&ctx_with(Some(1.0)), "capsnet").unwrap();
        assert_eq!(loose.len(), csv.len());
        assert_eq!(loose_excluded, 0);
        // ...an impossible one errors with the fastest achievable latency.
        let err = dse_scatter(&ctx_with(Some(1e-9)), "capsnet").unwrap_err();
        assert!(format!("{err:#}").contains("excludes all"));
    }

    #[test]
    fn headline_includes_no_performance_loss_ratio() {
        let c = ctx();
        let text = headline(&c).unwrap().to_string();
        assert!(text.contains("sim_capsnet_latency_ms"), "{text}");
        assert!(text.contains("gated_vs_ungated_latency_ratio"), "{text}");
        // The ratio row must report exactly 1 (no performance loss).
        let row = text
            .lines()
            .find(|l| l.starts_with("gated_vs_ungated_latency_ratio"))
            .unwrap()
            .to_string();
        let ours: f64 = row.rsplit(',').next().unwrap().parse().unwrap();
        assert_eq!(ours, 1.0, "{row}");
    }
}
