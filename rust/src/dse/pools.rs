//! Size and sector pools for the DSE (paper section V-C).
//!
//! Acceptable memory sizes are powers of two plus the paper's four
//! "randomly selected" fine-grained sizes (25, 108, 450, 460 kiB); sector
//! counts follow CACTI-P's constraint sigma(s) = powers of two in
//! [2, s/128], capped at 16 sectors (the largest the paper's selected
//! configurations use) to keep the exhaustive product tractable.

use crate::util::units::KIB;

/// The paper's four extra sizes (section V-C).
pub const RANDOM_SIZES: [usize; 4] = [25 * KIB, 108 * KIB, 450 * KIB, 460 * KIB];

/// Smallest memory size considered (one 16-bank array of 512 B banks).
pub const MIN_SIZE: usize = 8 * KIB;

/// Largest sector count considered in the HY sweep.
pub const MAX_SECTORS: usize = 16;

/// Smallest acceptable size >= `bytes` (power of two or a random size) —
/// footnote 12's rounding rule.  `bytes == 0` maps to 0 (memory absent).
pub fn roundup(bytes: usize) -> usize {
    if bytes == 0 {
        return 0;
    }
    let pow2 = bytes.next_power_of_two().max(MIN_SIZE);
    RANDOM_SIZES
        .iter()
        .copied()
        .filter(|&r| r >= bytes)
        .fold(pow2, usize::min)
}

/// Ascending pool of candidate sizes for one HY component: {0} followed by
/// every acceptable size up to (and including) the component's standalone
/// requirement `max_needed` rounded up.
pub fn size_pool(max_needed: usize) -> Vec<usize> {
    let cap = roundup(max_needed);
    let mut pool = vec![0];
    let mut p = MIN_SIZE;
    while p <= cap {
        pool.push(p);
        p *= 2;
    }
    pool.extend(RANDOM_SIZES.iter().copied().filter(|&r| r <= cap));
    pool.sort_unstable();
    pool.dedup();
    pool
}

/// sigma(s): valid power-gating sector counts for a memory of `size` bytes
/// — powers of two in [2, size/128], capped at [`MAX_SECTORS`].  Empty for
/// absent (size 0) memories.
pub fn sector_pool(size: usize) -> Vec<usize> {
    if size == 0 {
        return Vec::new();
    }
    let mut out = Vec::new();
    let mut sc = 2;
    while sc <= (size / 128).min(MAX_SECTORS) {
        out.push(sc);
        sc *= 2;
    }
    out
}

/// sigma(s) including the no-gating option (SC = 1).
pub fn sector_pool_with_off(size: usize) -> Vec<usize> {
    if size == 0 {
        return Vec::new();
    }
    let mut v = vec![1];
    v.extend(sector_pool(size));
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::units::MIB;

    #[test]
    fn roundup_reproduces_table_i_sizes() {
        // The calibrated CapsNet maxima -> the paper's Table I selections.
        assert_eq!(roundup(23_040), 25 * KIB); // data
        assert_eq!(roundup(53_760), 64 * KIB); // weight
        assert_eq!(roundup(26_624), 32 * KIB); // acc
        assert_eq!(roundup(66_816), 108 * KIB); // SMP
    }

    #[test]
    fn roundup_reproduces_table_ii_sizes() {
        assert_eq!(roundup(262_144), 256 * KIB); // DeepCaps data
        assert_eq!(roundup(107_520), 108 * KIB); // DeepCaps weight: the
        // 108 kiB random size undercuts 128 kiB — both acceptable; the DSE
        // keeps whichever, the paper's table prints the pow2 rounding.
        assert_eq!(roundup(8 * MIB - 96 * KIB), 8 * MIB); // DeepCaps acc
    }

    #[test]
    fn roundup_prefers_exact_and_random_sizes() {
        assert_eq!(roundup(64 * KIB), 64 * KIB);
        assert_eq!(roundup(65 * KIB), 108 * KIB); // random beats 128 kiB
        assert_eq!(roundup(200 * KIB), 256 * KIB);
        assert_eq!(roundup(300 * KIB), 450 * KIB);
        assert_eq!(roundup(0), 0);
        assert_eq!(roundup(1), MIN_SIZE);
    }

    #[test]
    fn size_pool_is_sorted_unique_and_capped() {
        let pool = size_pool(53_760); // -> cap 64 kiB
        assert_eq!(pool, vec![0, 8 * KIB, 16 * KIB, 25 * KIB, 32 * KIB, 64 * KIB]);
        let pool_a = size_pool(26_624); // -> cap 32 kiB
        assert_eq!(pool_a, vec![0, 8 * KIB, 16 * KIB, 25 * KIB, 32 * KIB]);
    }

    #[test]
    fn sector_pool_respects_cacti_constraint() {
        // size/128 lower-bounds the sector size.
        assert_eq!(sector_pool(64 * KIB), vec![2, 4, 8, 16]); // capped at 16
        assert_eq!(sector_pool(512), vec![2, 4]);
        assert_eq!(sector_pool(256), vec![2]);
        assert_eq!(sector_pool(128), Vec::<usize>::new());
        assert_eq!(sector_pool(0), Vec::<usize>::new());
    }

    #[test]
    fn sector_pool_with_off_prepends_one() {
        assert_eq!(sector_pool_with_off(64 * KIB), vec![1, 2, 4, 8, 16]);
        assert!(sector_pool_with_off(0).is_empty());
    }

    #[test]
    fn every_sector_choice_keeps_sectors_at_least_128_bytes() {
        for size in [8 * KIB, 25 * KIB, 64 * KIB, 8 * MIB] {
            for sc in sector_pool(size) {
                assert!(size / sc >= 128, "size {size} sc {sc}");
            }
        }
    }
}
