//! Heuristic DSE — the extension the paper sketches in section V-D:
//! "if the search space increases ... a heuristic search algorithm can
//! easily be integrated into our methodology, in order to find a solution
//! more quickly. Such a solution may be away from the optimal solution as
//! found by the exhaustive search."
//!
//! Implementation: simulated annealing over the HY configuration space
//! (dedicated sizes from the Algorithm-1 pools, sector counts from
//! sigma(s), shared size derived per Algorithm 1).  The energy objective
//! uses the same fast evaluator as the exhaustive sweep, so solutions are
//! directly comparable; `tests` pin the annealer to within a few percent
//! of the exhaustive optimum at a small fraction of the evaluations, and
//! the `bench_dse` target reports the speed/quality trade-off.

use super::{evaluate, hy_shared_size, pools, DsePoint};
use crate::ctx::EvalCtx;
use crate::dataflow::NetworkProfile;
use crate::memory::{MemSpec, Organization};
use crate::sim;
use crate::util::prng::Prng;

/// Annealing options.
#[derive(Debug, Clone)]
pub struct AnnealOptions {
    pub iterations: usize,
    /// Initial acceptance temperature as a fraction of the starting energy.
    pub t0_frac: f64,
    /// Geometric cooling rate per iteration.
    pub cooling: f64,
    /// Weight of area in the scalarized objective (J per mm²); 0 = pure
    /// energy (the Table I/II selection rule).
    pub area_weight: f64,
    pub seed: u64,
}

impl Default for AnnealOptions {
    fn default() -> AnnealOptions {
        AnnealOptions {
            iterations: 2_000,
            t0_frac: 0.3,
            cooling: 0.997,
            area_weight: 0.0,
            seed: 1,
        }
    }
}

/// Search state: indices into the size pools + sector choices.
#[derive(Clone)]
struct State {
    d: usize,
    w: usize,
    a: usize,
    scs: usize,
    scd: usize,
    scw: usize,
    sca: usize,
}

/// The annealer's view of the space.
struct Space {
    d_pool: Vec<usize>,
    w_pool: Vec<usize>,
    a_pool: Vec<usize>,
}

impl Space {
    fn materialize(&self, st: &State, profile: &NetworkProfile) -> Option<Organization> {
        let (d, w, a) = (self.d_pool[st.d], self.w_pool[st.w], self.a_pool[st.a]);
        // An erroring shared-size derivation (malformed workload) simply
        // yields no candidate; the annealer moves on.
        let s = hy_shared_size(profile, d, w, a).ok()?;
        if s == 0 {
            return None; // degenerate SEP; annealer stays in HY space
        }
        let pick = |sc_idx: usize, size: usize| -> usize {
            let pool = pools::sector_pool_with_off(size);
            if pool.is_empty() {
                1
            } else {
                pool[sc_idx % pool.len()]
            }
        };
        Some(Organization::hy(
            MemSpec::new(s, pick(st.scs, s)),
            MemSpec::new(d, pick(st.scd, d)),
            MemSpec::new(w, pick(st.scw, w)),
            MemSpec::new(a, pick(st.sca, a)),
            3,
        ))
    }
}

/// Result of one annealing run.
pub struct AnnealResult {
    pub best: DsePoint,
    pub evaluations: usize,
    /// Objective trace (every 50 iterations), for convergence plots.
    pub trace: Vec<f64>,
}

/// Runs simulated annealing; returns the best HY(-PG) configuration found.
/// The scalarized objective is energy + `area_weight` x area (the Table
/// I/II selection rule at weight 0); the timeline latency is carried along
/// in every candidate point so callers can inspect it.
pub fn anneal(ctx: &EvalCtx, profile: &NetworkProfile, opts: &AnnealOptions) -> AnnealResult {
    let space = Space {
        d_pool: pools::size_pool(profile.max_d()),
        w_pool: pools::size_pool(profile.max_w()),
        a_pool: pools::size_pool(profile.max_a()),
    };
    let timeline = sim::Timeline::build(profile, ctx.tech(), ctx.accel());
    let mut rng = Prng::new(opts.seed);
    let objective = |org: &Organization| -> (f64, f64, f64, f64) {
        let (area, energy, latency) =
            evaluate::area_energy_latency(org, profile, ctx.tech(), &timeline);
        (energy + opts.area_weight * area, area, energy, latency)
    };

    // Start from a mid-pool state.
    let mut st = State {
        d: space.d_pool.len() / 2,
        w: space.w_pool.len() / 2,
        a: space.a_pool.len() / 2,
        scs: 1,
        scd: 1,
        scw: 1,
        sca: 1,
    };
    let mut evaluations = 0;
    let mut current = loop {
        if let Some(org) = space.materialize(&st, profile) {
            evaluations += 1;
            let (obj, area, energy, latency) = objective(&org);
            break (
                obj,
                DsePoint {
                    org,
                    area_mm2: area,
                    energy_j: energy,
                    latency_s: latency,
                },
            );
        }
        st.d = rng.usize_below(space.d_pool.len());
    };
    let mut best = current.clone();
    let mut temp = current.0 * opts.t0_frac;
    let mut trace = Vec::new();

    for it in 0..opts.iterations {
        // Neighbor: perturb one coordinate by +-1 (sizes) or re-roll a
        // sector index.  One move in four is a long-range jump to a random
        // pool index — the DeepCaps landscape is deceptive (energy climbs
        // with accumulator size until the vote ring stops spilling into the
        // shared memory), so local moves alone get trapped on the plateau.
        let mut next = st.clone();
        let step = |rng: &mut Prng, idx: usize, len: usize| -> usize {
            if len <= 1 {
                return idx;
            }
            if rng.below(4) == 0 {
                return rng.usize_below(len); // long-range jump
            }
            if rng.bool() {
                (idx + 1).min(len - 1)
            } else {
                idx.saturating_sub(1)
            }
        };
        match rng.below(7) {
            0 => next.d = step(&mut rng, next.d, space.d_pool.len()),
            1 => next.w = step(&mut rng, next.w, space.w_pool.len()),
            2 => next.a = step(&mut rng, next.a, space.a_pool.len()),
            3 => next.scs = rng.usize_below(8),
            4 => next.scd = rng.usize_below(8),
            5 => next.scw = rng.usize_below(8),
            _ => next.sca = rng.usize_below(8),
        }
        let Some(org) = space.materialize(&next, profile) else {
            continue;
        };
        evaluations += 1;
        let (obj, area, energy, latency) = objective(&org);
        let accept = obj < current.0 || {
            let delta = obj - current.0;
            rng.f64() < (-delta / temp.max(1e-30)).exp()
        };
        if accept {
            st = next;
            current = (
                obj,
                DsePoint {
                    org,
                    area_mm2: area,
                    energy_j: energy,
                    latency_s: latency,
                },
            );
            if current.0 < best.0 {
                best = current.clone();
            }
        }
        temp *= opts.cooling;
        if it % 50 == 0 {
            trace.push(best.0);
        }
    }

    AnnealResult {
        best: best.1,
        evaluations,
        trace,
    }
}

/// Engine-parallel multi-start annealing: `restarts` independent chains
/// (seeds `opts.seed`, `opts.seed + 1`, ...) run concurrently on the
/// context's execution engine; the chain with the best scalarized
/// objective wins.  Ties resolve to the lowest seed, so the result is
/// deterministic for any thread count.  `evaluations` reports the total
/// across all chains.
pub fn anneal_restarts(
    ctx: &EvalCtx,
    profile: &NetworkProfile,
    opts: &AnnealOptions,
    restarts: usize,
) -> AnnealResult {
    let seeds: Vec<u64> = (0..restarts.max(1) as u64)
        .map(|i| opts.seed.wrapping_add(i))
        .collect();
    // map_coarse: a chain is seconds of work, so parallelize even a
    // handful of restarts (Engine::map's serial cutoff is tuned for
    // microsecond DSE items and would serialize any restarts < 32).
    let runs = ctx.engine().map_coarse(&seeds, |&seed| {
        let mut chain_opts = opts.clone();
        chain_opts.seed = seed;
        anneal(ctx, profile, &chain_opts)
    });
    let evaluations: usize = runs.iter().map(|r| r.evaluations).sum();
    let objective =
        |r: &AnnealResult| -> f64 { r.best.energy_j + opts.area_weight * r.best.area_mm2 };
    let mut best: Option<AnnealResult> = None;
    for run in runs {
        let better = match &best {
            None => true,
            Some(b) => objective(&run) < objective(b),
        };
        if better {
            best = Some(run);
        }
    }
    // lint: allow(hot_unwrap, "seeds are built from restarts.max(1) so the run list is never empty and the fold always selects a best")
    let mut out = best.expect("at least one restart");
    out.evaluations = evaluations;
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Accelerator, Technology};
    use crate::dataflow::profile_network;
    use crate::dse;
    use crate::model::capsnet_mnist;

    fn ctx(threads: usize) -> EvalCtx {
        EvalCtx::new(Technology::default(), Accelerator::default()).threads(threads)
    }

    fn exhaustive_hy_optimum(ctx: &EvalCtx, profile: &NetworkProfile) -> f64 {
        let orgs = dse::enumerate(profile).unwrap();
        let tl = sim::Timeline::build(profile, ctx.tech(), ctx.accel());
        let points = dse::evaluate_all(ctx, &orgs, profile, &tl);
        points
            .iter()
            .filter(|p| matches!(p.option(), dse::DesignOption::Hy | dse::DesignOption::HyPg))
            .map(|p| p.energy_j)
            .fold(f64::INFINITY, f64::min)
    }

    #[test]
    fn annealer_approaches_exhaustive_optimum() {
        // Section V-D's premise quantified: the heuristic reaches within 5%
        // of the exhaustive HY optimum using ~50x fewer evaluations.
        let accel = Accelerator::default();
        let c = ctx(4);
        let profile = profile_network(&capsnet_mnist(), &accel);
        let optimum = exhaustive_hy_optimum(&c, &profile);
        let result = anneal(&c, &profile, &AnnealOptions::default());
        let gap = result.best.energy_j / optimum - 1.0;
        assert!(gap < 0.05, "gap {gap:.3} (best {} vs {optimum})", result.best.energy_j);
        assert!(
            result.evaluations < 43_180 / 10,
            "{} evaluations",
            result.evaluations
        );
    }

    #[test]
    fn trace_is_monotone_nonincreasing() {
        let accel = Accelerator::default();
        let profile = profile_network(&capsnet_mnist(), &accel);
        let result = anneal(&ctx(1), &profile, &AnnealOptions::default());
        for w in result.trace.windows(2) {
            assert!(w[1] <= w[0] + 1e-18);
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let accel = Accelerator::default();
        let cx = ctx(1);
        let profile = profile_network(&capsnet_mnist(), &accel);
        let a = anneal(&cx, &profile, &AnnealOptions::default());
        let b = anneal(&cx, &profile, &AnnealOptions::default());
        assert_eq!(a.best.energy_j, b.best.energy_j);
        let mut opts = AnnealOptions::default();
        opts.seed = 99;
        let c = anneal(&cx, &profile, &opts);
        // Different seed may land elsewhere but must still be valid HY.
        assert!(c.best.org.shared.is_some());
    }

    #[test]
    fn multi_start_never_worse_than_single_and_is_deterministic() {
        let accel = Accelerator::default();
        let profile = profile_network(&capsnet_mnist(), &accel);
        let opts = AnnealOptions::default();
        let single = anneal(&ctx(1), &profile, &opts);
        // The restart fan includes the single run's seed, so the winner can
        // only match or beat it, whatever the worker count.
        let multi_a = anneal_restarts(&ctx(1), &profile, &opts, 3);
        let multi_b = anneal_restarts(&ctx(4), &profile, &opts, 3);
        assert!(multi_a.best.energy_j <= single.best.energy_j + 1e-18);
        assert_eq!(multi_a.best.energy_j, multi_b.best.energy_j);
        assert_eq!(multi_a.best.area_mm2, multi_b.best.area_mm2);
        assert_eq!(multi_a.evaluations, multi_b.evaluations);
        assert!(multi_a.evaluations > single.evaluations);
    }

    #[test]
    fn area_weight_trades_energy_for_area() {
        let accel = Accelerator::default();
        let cx = ctx(1);
        let profile = profile_network(&capsnet_mnist(), &accel);
        let pure = anneal(&cx, &profile, &AnnealOptions::default());
        let mut opts = AnnealOptions::default();
        opts.area_weight = 5e-3; // 5 mJ per mm²: area matters a lot
        let weighted = anneal(&cx, &profile, &opts);
        assert!(weighted.best.area_mm2 <= pure.best.area_mm2 * 1.001);
    }
}
