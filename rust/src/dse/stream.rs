//! Streaming branch-and-bound sweep over the scratchpad design space
//! (DESIGN.md sections 13–14).
//!
//! The exhaustive pipeline materialized every organization
//! (`dse::enumerate`), evaluated all of them, and only then filtered to
//! the Pareto frontier — paying full evaluation cost for the ~99% of
//! candidates that provably cannot reach the frontier.  This module
//! restructures the sweep around the *subtree* granularity Algorithm 2
//! naturally has: within one [`Subtree`] every component SIZE is fixed
//! and only the SECTOR counts vary over the pools, so
//!
//! * coverage (which bytes land in which memory) is subtree-constant,
//!   and with it the whole dynamic energy: [`evaluate::SubtreeEval`]
//!   prepares per-sector-option cost tables once on subtree entry,
//!   turning each surviving point evaluation into O(components) table
//!   lookups instead of an O(ops) pass (ISSUE 7);
//! * the same prepared tables yield an admissible lower bound on
//!   (area, energy, latency) — per component the minimum over the pool of
//!   the full per-option sum — so a subtree whose bound is already weakly
//!   dominated by an evaluated point (tracked incrementally in a
//!   [`Archive3`] staircase) is culled wholesale before any candidate is
//!   materialized.
//!
//! Exactness is non-negotiable and holds *bit-wise*, not approximately:
//!
//! * the factored evaluator replays the reference accumulation order of
//!   `evaluate::area_energy` exactly (see its accumulation-order
//!   contract), so surviving points carry identical bits to the
//!   exhaustive pipeline;
//! * the bound never exceeds any completion of its subtree (IEEE-754
//!   monotonicity of the mirrored combine — see [`evaluate::SubtreeEval`]),
//!   so a culled subtree only loses points that are weakly dominated by
//!   an earlier surviving point;
//! * weakly dominated points can never enter the 3-D frontier
//!   (`frontier3` keeps the first occurrence of a duplicate, and the
//!   archive member *is* earlier in enumeration order), and by the same
//!   first-wins rule they can never change the per-option lowest-energy
//!   selection — pruning additionally requires an earlier selected-or-
//!   better point per design option realized in the subtree;
//! * a point may act as a *dominator* only if it is unconditionally at
//!   least as good on every downstream objective too: with a nonzero
//!   wakeup latency a power-gated dominator could expose latency on
//!   *other* timelines (`fleet::design_fleet` re-checks SLOs against
//!   per-network timelines), so [`SweepEval::dominator_ok`] restricts the
//!   archive to non-gated organizations unless `wakeup_latency_s <= 0`.
//!   At the paper's constants (wakeups mask, exposure 0) every point
//!   qualifies and the archive has full pruning power.
//!
//! Determinism: subtrees are visited strictly in enumeration order;
//! within a subtree the engine evaluates candidates with ordered
//! collection.  Every pruning decision therefore sees the identical
//! archive state for any thread count — `rust/tests/prune_exact.rs` pins
//! threads=1 vs N bit-equality, and pruned-vs-exhaustive bit-identity of
//! frontier and selection across both seed networks and seeded generator
//! networks.  The [`SweepStats`] wall-time split (`prep_s`/`eval_s`) is
//! the only nondeterministic output and is excluded from all fingerprints.

use std::time::Instant;

use anyhow::{ensure, Context, Result};

use crate::config::Technology;
use crate::ctx::EvalCtx;
use crate::dataflow::NetworkProfile;
use crate::memory::{MemSpec, OrgKind, Organization};
use crate::sim;
use crate::util::pareto::{Archive3, Point3};

use super::multi::WorkloadSet;
use super::{evaluate, hy_shared_size, pools, sep_sizes, smp_size, DesignOption, DsePoint};

/// One branch of the enumeration tree: component sizes fixed, sector
/// counts free over `pools`.  Indexing is `Component::ALL` order
/// [shared, data, weight, acc]; a pool of `[1]` stands in for an absent
/// component (single no-op slot), an empty pool for a component whose
/// size admits no sector choice at all (the subtree then has no
/// candidates).
#[derive(Debug, Clone)]
pub struct Subtree {
    kind: OrgKind,
    sizes: [usize; 4],
    pools: [Vec<usize>; 4],
}

impl Subtree {
    pub fn kind(&self) -> OrgKind {
        self.kind
    }

    /// Component sizes, `Component::ALL` order (0 for absent components).
    pub fn sizes(&self) -> [usize; 4] {
        self.sizes
    }

    /// Candidate sector pools, `Component::ALL` order.
    pub fn pools(&self) -> &[Vec<usize>; 4] {
        &self.pools
    }

    /// Number of candidate organizations in this subtree.
    pub fn count(&self) -> usize {
        self.pools.iter().map(|p| p.len()).product()
    }

    /// Hard feasibility check: every op's residual (the bytes not covered
    /// by the dedicated memories) must fit the shared memory.  The
    /// evaluators assume this — `evaluate::area_energy` only carries a
    /// `debug_assert!`, which vanishes in release builds and would let an
    /// unfitting subtree produce silently wrong energies — so
    /// [`subtrees`] rejects misfits with a hard error instead (ISSUE 7
    /// bugfix; the Algorithm 1/2 size derivations guarantee the fit for
    /// well-formed profiles, making this a guard against inconsistent or
    /// hand-built inputs).
    pub(crate) fn ensure_fits(&self, profile: &NetworkProfile) -> Result<()> {
        let present = self.kind.presence();
        let cap = |i: usize| if present[i] { self.sizes[i] } else { 0 };
        for op in &profile.ops {
            let ded_d = op.usage_d.min(cap(1));
            let ded_w = op.usage_w.min(cap(2));
            let ded_a = op.usage_a.min(cap(3));
            let sh = (op.usage_d - ded_d) + (op.usage_w - ded_w) + (op.usage_a - ded_a);
            ensure!(
                sh <= cap(0),
                "{} subtree (sizes {:?}) cannot hold op `{}` of `{}`: \
                 {sh} residual bytes exceed the {}-byte shared memory",
                self.kind.label(),
                self.sizes,
                op.name,
                profile.network,
                cap(0),
            );
        }
        Ok(())
    }

    fn org(&self, sc: [usize; 4]) -> Organization {
        match self.kind {
            OrgKind::Smp => Organization::smp(MemSpec::new(self.sizes[0], sc[0])),
            OrgKind::Sep => Organization::sep(
                MemSpec::new(self.sizes[1], sc[1]),
                MemSpec::new(self.sizes[2], sc[2]),
                MemSpec::new(self.sizes[3], sc[3]),
            ),
            OrgKind::Hy => Organization::hy(
                MemSpec::new(self.sizes[0], sc[0]),
                MemSpec::new(self.sizes[1], sc[1]),
                MemSpec::new(self.sizes[2], sc[2]),
                MemSpec::new(self.sizes[3], sc[3]),
                3,
            ),
        }
    }

    /// Appends every candidate of this subtree in enumeration order —
    /// the shared-memory sector count is the outermost loop, matching the
    /// historical `dse::enumerate` nesting exactly (the exhaustive oracle
    /// of the property tests walks the same sequence).
    pub fn materialize_into(&self, out: &mut Vec<Organization>) {
        for &s0 in &self.pools[0] {
            for &s1 in &self.pools[1] {
                for &s2 in &self.pools[2] {
                    for &s3 in &self.pools[3] {
                        out.push(self.org([s0, s1, s2, s3]));
                    }
                }
            }
        }
    }

    /// Design options realized inside this subtree: the base option is
    /// always realized (every non-empty sector pool starts at SC = 1),
    /// the power-gated option iff some pool has a sectored entry.
    fn options(&self) -> (DesignOption, Option<DesignOption>) {
        let base = DesignOption::of(self.kind, false);
        let gated = self.pools.iter().any(|p| p.iter().any(|&sc| sc > 1));
        (base, gated.then(|| DesignOption::of(self.kind, true)))
    }
}

/// The full design space of a profile as a sequence of subtrees, in the
/// exact order `dse::enumerate` has always emitted candidates: the SEP
/// subtree, the SMP subtree, then one HY subtree per (d, w, a) size
/// triple of Algorithm 1 × Algorithm 2.  Every emitted subtree is
/// checked to fit the profile (see [`Subtree::ensure_fits`]).
pub fn subtrees(profile: &NetworkProfile) -> Result<Vec<Subtree>> {
    let mut out = Vec::new();
    let (sd, sw, sa) = sep_sizes(profile);

    // --- SEP (Eq. 2): sizes fixed, all sector combinations.
    out.push(Subtree {
        kind: OrgKind::Sep,
        sizes: [0, sd, sw, sa],
        pools: [
            vec![1],
            pools::sector_pool_with_off(sd),
            pools::sector_pool_with_off(sw),
            pools::sector_pool_with_off(sa),
        ],
    });

    // --- SMP (Eq. 1).
    let smp = smp_size(profile);
    out.push(Subtree {
        kind: OrgKind::Smp,
        sizes: [smp, 0, 0, 0],
        pools: [
            pools::sector_pool_with_off(smp),
            vec![1],
            vec![1],
            vec![1],
        ],
    });

    // --- HY (Algorithm 1 x Algorithm 2).
    for &d in &pools::size_pool(profile.max_d()) {
        for &w in &pools::size_pool(profile.max_w()) {
            for &a in &pools::size_pool(profile.max_a()) {
                let s = hy_shared_size(profile, d, w, a)
                    .context("Algorithm 1 shared-size derivation")?;
                if s == 0 {
                    continue; // degenerates to SEP (own subtree above)
                }
                if d == 0 && w == 0 && a == 0 {
                    continue; // degenerates to SMP (own subtree above)
                }
                out.push(Subtree {
                    kind: OrgKind::Hy,
                    sizes: [s, d, w, a],
                    pools: [
                        pools::sector_pool_with_off(s),
                        or_one(pools::sector_pool_with_off(d)),
                        or_one(pools::sector_pool_with_off(w)),
                        or_one(pools::sector_pool_with_off(a)),
                    ],
                });
            }
        }
    }
    for st in &out {
        st.ensure_fits(profile)?;
    }
    Ok(out)
}

fn or_one(pool: Vec<usize>) -> Vec<usize> {
    if pool.is_empty() {
        vec![1] // absent memory: single no-op sector slot
    } else {
        pool
    }
}

/// Branch-and-bound counters (BENCH schema v6 `pruning` section, the CLI's
/// `dse --stats`, and the E23/E24 effectiveness tables).
#[derive(Debug, Clone, Copy, Default)]
pub struct SweepStats {
    /// Candidates the full cross-product contains.
    pub enumerated: usize,
    /// Candidates fully evaluated (the bound could not cull them).
    pub evaluated: usize,
    /// Candidates culled by an admissible bound, never evaluated.
    pub pruned: usize,
    /// Subtrees visited (with at least one candidate) / culled wholesale.
    pub subtrees: usize,
    pub subtrees_pruned: usize,
    /// Accepted archive insertions over the sweep and the final archive
    /// size (== the frontier size of the admitted points).
    pub archive_inserts: usize,
    pub archive_len: usize,
    /// Bound tightness: Σ and count of per-evaluated-subtree relative
    /// energy gaps, (min evaluated energy − bound energy) / min energy.
    pub bound_gap_sum: f64,
    pub bound_gap_count: usize,
    /// Wall-time split of the sweep (ISSUE 7): subtree preparation +
    /// bounding vs point evaluation of the surviving candidates.  The
    /// only nondeterministic fields — excluded from every fingerprint and
    /// thread-determinism comparison.
    pub prep_s: f64,
    pub eval_s: f64,
}

impl SweepStats {
    /// Fraction of the space culled before evaluation.
    pub fn pruned_fraction(&self) -> f64 {
        if self.enumerated == 0 {
            0.0
        } else {
            self.pruned as f64 / self.enumerated as f64
        }
    }

    /// Mean relative energy-bound gap over evaluated subtrees (0 = the
    /// bound is tight; large = the bound rarely bites).
    pub fn mean_bound_gap(&self) -> f64 {
        if self.bound_gap_count == 0 {
            0.0
        } else {
            self.bound_gap_sum / self.bound_gap_count as f64
        }
    }
}

/// The sweep's per-objective-space adapter: single-network and
/// multi-network (co-design) sweeps share the driver below and differ
/// only in how a candidate is scored and bounded.
///
/// ISSUE 7 shape: the driver calls [`SweepEval::prepare`] once per
/// subtree, and both the bound and every point evaluation run off the
/// prepared state — the per-point cost is O(components), not O(ops).
pub(crate) trait SweepEval: Sync {
    /// Side data carried along with each surviving point (per-network
    /// energy/latency vectors for the co-design sweep).
    type Extra: Send;

    /// Per-subtree prepared state (factored cost tables), shared by the
    /// bound and all candidate evaluations of the subtree.
    type Prep: Sync;

    /// Builds the factored evaluator state for one subtree — the only
    /// O(ops) work; paid once per subtree.
    fn prepare(&self, st: &Subtree) -> Self::Prep;

    /// Full evaluation of one candidate off the prepared state.
    fn eval(&self, prep: &Self::Prep, org: &Organization) -> (DsePoint, Self::Extra);

    /// Admissible lower bound on (area, energy, latency) over *every*
    /// candidate of the subtree, bit-wise (never exceeds any completion).
    fn bound(&self, prep: &Self::Prep) -> (f64, f64, f64);

    /// Whether an evaluated point may act as a dominator in the archive
    /// (must be at least as good as any point it prunes on every
    /// downstream objective, including latency on foreign timelines).
    fn dominator_ok(&self, org: &Organization) -> bool;
}

/// Single-network sweep: the objective space of `dse::run`.
pub(crate) struct SingleNet<'a> {
    pub profile: &'a NetworkProfile,
    pub tech: &'a Technology,
    pub timeline: &'a sim::Timeline,
}

impl SweepEval for SingleNet<'_> {
    type Extra = ();
    type Prep = evaluate::SubtreeEval;

    fn prepare(&self, st: &Subtree) -> Self::Prep {
        evaluate::SubtreeEval::prepare(
            st.kind,
            st.sizes,
            &st.pools,
            self.profile,
            self.tech,
            self.timeline,
        )
    }

    fn eval(&self, prep: &Self::Prep, org: &Organization) -> (DsePoint, ()) {
        // Bit-identical to `dse::eval_one` (pinned by
        // rust/tests/factored_eval.rs + prune_exact.rs), at O(components)
        // instead of O(ops).
        let (area_mm2, energy_j, latency_s) = prep.eval(org);
        (
            DsePoint {
                org: org.clone(),
                area_mm2,
                energy_j,
                latency_s,
            },
            (),
        )
    }

    fn bound(&self, prep: &Self::Prep) -> (f64, f64, f64) {
        prep.bound()
    }

    fn dominator_ok(&self, org: &Organization) -> bool {
        self.tech.wakeup_latency_s <= 0.0 || !org.power_gated()
    }
}

/// Multi-network co-design sweep: the mix-weighted objective space of
/// `dse::multi::run` (subtrees come from the merged pseudo-profile,
/// scoring from the member profiles — one prepared evaluator each).
pub(crate) struct MultiSet<'a> {
    pub set: &'a WorkloadSet,
    pub tech: &'a Technology,
    pub tls: &'a [sim::Timeline],
}

impl SweepEval for MultiSet<'_> {
    type Extra = (Vec<f64>, Vec<f64>);
    type Prep = Vec<evaluate::SubtreeEval>;

    fn prepare(&self, st: &Subtree) -> Self::Prep {
        self.set
            .profiles()
            .iter()
            .zip(self.tls)
            .map(|(p, tl)| {
                evaluate::SubtreeEval::prepare(st.kind, st.sizes, &st.pools, p, self.tech, tl)
            })
            .collect()
    }

    fn eval(&self, prep: &Self::Prep, org: &Organization) -> (DsePoint, Self::Extra) {
        // Mirrors `multi::eval_one`'s accumulation exactly (same order,
        // `area = a` overwrite, weighted sums), with each member scored
        // through its prepared tables — the per-member triples are
        // bit-identical to `area_energy_latency`, so the fold is
        // bit-identical to the exhaustive co-design pipeline.
        let mut per_net = Vec::with_capacity(prep.len());
        let mut per_net_lat = Vec::with_capacity(prep.len());
        let mut area = 0.0;
        let mut energy = 0.0;
        let mut latency = 0.0;
        for (se, wgt) in prep.iter().zip(self.set.weights()) {
            let (a, e, l) = se.eval(org);
            area = a; // identical for every network: one physical org
            energy += wgt * e;
            latency += wgt * l;
            per_net.push(e);
            per_net_lat.push(l);
        }
        (
            DsePoint {
                org: org.clone(),
                area_mm2: area,
                energy_j: energy,
                latency_s: latency,
            },
            (per_net, per_net_lat),
        )
    }

    fn bound(&self, prep: &Self::Prep) -> (f64, f64, f64) {
        // Mirrors the eval fold above with each member's bound
        // substituted — monotone step by step, so the weighted bound is
        // admissible bit-wise, and for a 1-element set it degenerates
        // (0.0 + 1.0·x ≡ x) to the single-network bound.
        let mut area = 0.0;
        let mut energy = 0.0;
        let mut latency = 0.0;
        for (se, wgt) in prep.iter().zip(self.set.weights()) {
            let (a, e, l) = se.bound();
            area = a; // identical for every network: one physical org
            energy += wgt * e;
            latency += wgt * l;
        }
        (area, energy, latency)
    }

    fn dominator_ok(&self, org: &Organization) -> bool {
        self.tech.wakeup_latency_s <= 0.0 || !org.power_gated()
    }
}

/// Everything a budgeted sweep produces: the surviving points (in
/// enumeration order), their side data, and the counters.
pub(crate) struct SweepOutcome<X> {
    pub points: Vec<DsePoint>,
    pub extras: Vec<X>,
    /// Evaluated candidates dropped by the latency budget.
    pub excluded: usize,
    /// Minimum latency over every *evaluated* candidate, pre-budget
    /// (INFINITY when nothing was evaluated).  When the budget excludes
    /// everything no point ever enters the archive, so nothing is pruned
    /// and this is the true global minimum — the "fastest achievable" of
    /// the error message.
    pub fastest: f64,
    pub stats: SweepStats,
}

/// The branch-and-bound driver.  Subtrees are processed strictly in
/// order; each is prepared once ([`SweepEval::prepare`], the only O(ops)
/// work), bounded off the prepared tables, and — if it survives — its
/// candidates are evaluated engine-parallel with ordered collection, then
/// folded sequentially.  The engine and the optional latency budget come
/// from the evaluation context.  Every archive and selection decision is
/// deterministic for any thread count; only the `prep_s`/`eval_s` wall
/// times vary run to run.
pub(crate) fn sweep<E: SweepEval>(
    ctx: &EvalCtx,
    subtrees: &[Subtree],
    ev: &E,
) -> SweepOutcome<E::Extra> {
    let latency_budget_s = ctx.budget().latency_budget_s;
    let mut stats = SweepStats::default();
    let mut archive = Archive3::new();
    // Lowest admitted energy per design option (select_per_option's keep
    // rule: first point wins energy ties).
    let mut best_e: [Option<f64>; 6] = [None; 6];
    let mut points: Vec<DsePoint> = Vec::new();
    let mut extras: Vec<E::Extra> = Vec::new();
    let mut excluded = 0usize;
    let mut fastest = f64::INFINITY;
    let mut batch: Vec<Organization> = Vec::new();

    for st in subtrees {
        let count = st.count();
        if count == 0 {
            continue;
        }
        stats.enumerated += count;
        stats.subtrees += 1;

        // lint: allow(wall_clock, "feeds SweepStats::prep_s only — diagnostic timing, excluded from every fingerprint and result")
        let t_prep = Instant::now();
        let prep = ev.prepare(st);
        let (lb_area, lb_e, lb_lat) = ev.bound(&prep);
        stats.prep_s += t_prep.elapsed().as_secs_f64();
        // Prune only when BOTH hold: (a) an archive member weakly
        // dominates the bound — then it weakly dominates every completion,
        // which therefore cannot enter the frontier (first-wins on exact
        // duplicates, transitivity for chains); and (b) every design
        // option realized in the subtree already has an admitted point at
        // energy ≤ the bound — then no completion can displace a
        // per-option selection either.
        let (base_opt, pg_opt) = st.options();
        let covered = |o: DesignOption| matches!(best_e[o.index()], Some(e) if e <= lb_e);
        if covered(base_opt)
            && pg_opt.map_or(true, covered)
            && archive.dominated(&Point3::new(lb_area, lb_e, lb_lat, 0))
        {
            stats.pruned += count;
            stats.subtrees_pruned += 1;
            continue;
        }

        batch.clear();
        st.materialize_into(&mut batch);
        // lint: allow(wall_clock, "feeds SweepStats::eval_s only — diagnostic timing, excluded from every fingerprint and result")
        let t_eval = Instant::now();
        let evaluated = ctx.engine().map(&batch, |o| ev.eval(&prep, o));
        stats.eval_s += t_eval.elapsed().as_secs_f64();
        stats.evaluated += evaluated.len();

        let mut min_e = f64::INFINITY;
        for (p, extra) in evaluated {
            min_e = min_e.min(p.energy_j);
            fastest = fastest.min(p.latency_s);
            if let Some(budget) = latency_budget_s {
                if !(p.latency_s <= budget) {
                    excluded += 1;
                    continue;
                }
            }
            if ev.dominator_ok(&p.org) {
                archive.insert(Point3::new(
                    p.area_mm2,
                    p.energy_j,
                    p.latency_s,
                    points.len(),
                ));
            }
            let slot = &mut best_e[p.option().index()];
            match *slot {
                Some(e) if e <= p.energy_j => {}
                _ => *slot = Some(p.energy_j),
            }
            points.push(p);
            extras.push(extra);
        }
        if min_e.is_finite() && min_e > 0.0 {
            stats.bound_gap_sum += ((min_e - lb_e) / min_e).max(0.0);
            stats.bound_gap_count += 1;
        }
    }
    stats.archive_inserts = archive.inserts();
    stats.archive_len = archive.len();
    SweepOutcome {
        points,
        extras,
        excluded,
        fastest,
        stats,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Accelerator;
    use crate::dataflow::profile_network;
    use crate::dse;
    use crate::model::capsnet_mnist;

    fn profile() -> NetworkProfile {
        profile_network(&capsnet_mnist(), &Accelerator::default())
    }

    #[test]
    fn subtree_flattening_reproduces_enumerate() {
        let p = profile();
        let sts = subtrees(&p).unwrap();
        // SEP first, then SMP, then HY — the historical emission order.
        assert_eq!(sts[0].kind(), OrgKind::Sep);
        assert_eq!(sts[1].kind(), OrgKind::Smp);
        assert!(sts[2..].iter().all(|st| st.kind() == OrgKind::Hy));

        let mut flat = Vec::new();
        for st in &sts {
            let before = flat.len();
            st.materialize_into(&mut flat);
            assert_eq!(flat.len() - before, st.count(), "count() must match");
        }
        let legacy = dse::enumerate(&p).unwrap();
        assert_eq!(flat.len(), legacy.len());
        for (a, b) in flat.iter().zip(&legacy) {
            assert_eq!(a, b);
        }
        let total: usize = sts.iter().map(|st| st.count()).sum();
        assert_eq!(total, legacy.len());
    }

    #[test]
    fn subtree_options_detection() {
        let p = profile();
        let sts = subtrees(&p).unwrap();
        let (base, pg) = sts[0].options();
        assert_eq!(base, DesignOption::Sep);
        assert_eq!(pg, Some(DesignOption::SepPg)); // 25–64 kiB sector pools
        let (base, pg) = sts[1].options();
        assert_eq!(base, DesignOption::Smp);
        assert_eq!(pg, Some(DesignOption::SmpPg));
    }

    #[test]
    fn unfitting_subtree_is_rejected() {
        // The ISSUE 7 bugfix: the release-mode evaluators silently assume
        // every op fits (their fit check is a debug_assert!), so subtree
        // construction must reject a profile that does not fit with a
        // hard error instead of producing wrong energies.
        let p = profile();
        let too_small = Subtree {
            kind: OrgKind::Sep,
            sizes: [0, 1024, 1024, 1024], // capsnet needs far more
            pools: [vec![1], vec![1], vec![1], vec![1]],
        };
        let err = too_small.ensure_fits(&p).unwrap_err();
        assert!(
            err.to_string().contains("cannot hold op"),
            "unexpected error: {err}"
        );
        // And every subtree the real derivation emits passes the check
        // (subtrees() already enforces this internally — double-check the
        // property directly).
        for st in subtrees(&p).unwrap() {
            st.ensure_fits(&p).unwrap();
        }
    }

    #[test]
    fn bound_is_admissible_bitwise() {
        // The acid test of the whole scheme: for every subtree, the bound
        // must be ≤ every fully evaluated candidate on all three axes —
        // with plain f64 comparison, no epsilon.
        let p = profile();
        let tech = crate::config::Technology::default();
        let accel = Accelerator::default();
        let tl = sim::Timeline::build(&p, &tech, &accel);
        let ev = SingleNet {
            profile: &p,
            tech: &tech,
            timeline: &tl,
        };
        let mut batch = Vec::new();
        for st in subtrees(&p).unwrap() {
            if st.count() == 0 {
                continue;
            }
            let prep = ev.prepare(&st);
            let (lb_area, lb_e, lb_lat) = ev.bound(&prep);
            batch.clear();
            st.materialize_into(&mut batch);
            for org in &batch {
                let (point, ()) = ev.eval(&prep, org);
                assert!(
                    lb_area <= point.area_mm2,
                    "{}: area bound {lb_area} > {}",
                    org.label(),
                    point.area_mm2
                );
                assert!(
                    lb_e <= point.energy_j,
                    "{}: energy bound {lb_e} > {}",
                    org.label(),
                    point.energy_j
                );
                assert!(
                    lb_lat <= point.latency_s,
                    "{}: latency bound {lb_lat} > {}",
                    org.label(),
                    point.latency_s
                );
            }
        }
    }

    #[test]
    fn sweep_prunes_capsnet_without_changing_outcomes() {
        // Fast smoke of the exactness property (the full property sweep
        // over generator networks lives in rust/tests/prune_exact.rs).
        let p = profile();
        let ctx = EvalCtx::new(crate::config::Technology::default(), Accelerator::default())
            .threads(4);

        let pruned = dse::run(&ctx, &p).unwrap();
        assert!(
            pruned.stats.pruned > 0,
            "no candidates culled on capsnet: {:?}",
            pruned.stats
        );
        assert_eq!(
            pruned.stats.evaluated + pruned.stats.pruned,
            pruned.stats.enumerated
        );
        assert_eq!(pruned.stats.evaluated, pruned.points.len());
        // The wall-time split is populated (non-negative, and some prep
        // happened for a non-empty space) but carries no determinism
        // guarantee.
        assert!(pruned.stats.prep_s >= 0.0 && pruned.stats.eval_s >= 0.0);

        // Exhaustive oracle over the same enumeration order.
        let orgs = dse::enumerate(&p).unwrap();
        let tl = sim::Timeline::build(&p, ctx.tech(), ctx.accel());
        let all = dse::evaluate_all(&ctx, &orgs, &p, &tl);
        let front = dse::pareto_indices(&all);
        let sel = dse::select_per_option(&all);

        // Bit-identical frontier (as point values and organizations).
        assert_eq!(pruned.pareto.len(), front.len());
        for (&i, &j) in pruned.pareto.iter().zip(&front) {
            let a = &pruned.points[i];
            let b = &all[j];
            assert_eq!(a.org, b.org);
            assert_eq!(a.area_mm2.to_bits(), b.area_mm2.to_bits());
            assert_eq!(a.energy_j.to_bits(), b.energy_j.to_bits());
            assert_eq!(a.latency_s.to_bits(), b.latency_s.to_bits());
        }
        // Bit-identical per-option selection.
        assert_eq!(pruned.selected.len(), sel.len());
        for ((name_a, i), (name_b, j)) in pruned.selected.iter().zip(&sel) {
            assert_eq!(name_a, name_b);
            let a = &pruned.points[*i];
            let b = &all[*j];
            assert_eq!(a.org, b.org);
            assert_eq!(a.energy_j.to_bits(), b.energy_j.to_bits());
        }
    }

    #[test]
    fn sweep_is_deterministic_across_thread_counts() {
        let p = profile();
        let mk = |threads| {
            EvalCtx::new(crate::config::Technology::default(), Accelerator::default())
                .threads(threads)
        };
        let one = dse::run(&mk(1), &p).unwrap();
        let many = dse::run(&mk(8), &p).unwrap();
        assert_eq!(one.points.len(), many.points.len());
        assert_eq!(one.pareto, many.pareto);
        assert_eq!(one.selected, many.selected);
        assert_eq!(one.stats.pruned, many.stats.pruned);
        assert_eq!(one.stats.evaluated, many.stats.evaluated);
        for (a, b) in one.points.iter().zip(&many.points) {
            assert_eq!(a.org, b.org);
            assert_eq!(a.energy_j.to_bits(), b.energy_j.to_bits());
        }
    }
}
