//! Multi-network co-design DSE: one memory organization sized and selected
//! across a *set* of workloads (CapStore, arXiv:1902.01151, motivates
//! sizing one on-chip memory for multiple workloads; NASCaps,
//! arXiv:2008.08476, supplies the families).
//!
//! Method:
//! * **sizing** — the workload set is merged into one pseudo-profile (ops
//!   concatenated, names prefixed `net/`), so Algorithm 1/2 enumeration
//!   over it uses the component-wise *union* of working sets: every
//!   emitted organization fits every operation of every network;
//! * **objective** — each organization is scored by the mix-weighted sum
//!   of its per-network, per-inference energies (the serving mix: weight
//!   w_i = fraction of inferences served for network i), evaluated through
//!   the same fast path (`dse::evaluate`) and memoized CACTI cost cache as
//!   the single-network sweep;
//! * **selection** — the existing Pareto / per-design-option machinery
//!   runs unchanged over the weighted points, so Tables I/II-style
//!   selections fall out per design option, now co-designed.

use anyhow::{bail, ensure, Context, Result};

use super::{evaluate, pareto_indices, select_per_option, stream, DsePoint};
use crate::config::Technology;
use crate::ctx::EvalCtx;
use crate::dataflow::NetworkProfile;
use crate::memory::Organization;
use crate::sim;

/// A set of network profiles plus the serving-mix weights (normalized to
/// sum 1) used for the weighted-energy objective.
#[derive(Debug, Clone)]
pub struct WorkloadSet {
    profiles: Vec<NetworkProfile>,
    weights: Vec<f64>,
}

impl WorkloadSet {
    /// Equal-mix workload set.
    pub fn new(profiles: Vec<NetworkProfile>) -> Result<WorkloadSet> {
        let n = profiles.len();
        ensure!(n > 0, "empty workload set");
        WorkloadSet::with_weights(profiles, vec![1.0; n])
    }

    /// Workload set with explicit mix weights (normalized internally).
    pub fn with_weights(profiles: Vec<NetworkProfile>, weights: Vec<f64>) -> Result<WorkloadSet> {
        ensure!(!profiles.is_empty(), "empty workload set");
        ensure!(
            profiles.len() == weights.len(),
            "{} weights for {} profiles",
            weights.len(),
            profiles.len()
        );
        for (p, &w) in profiles.iter().zip(&weights) {
            ensure!(
                w.is_finite() && w > 0.0,
                "non-positive mix weight {w} for network '{}'",
                p.network
            );
        }
        let total: f64 = weights.iter().sum();
        Ok(WorkloadSet {
            profiles,
            weights: weights.into_iter().map(|w| w / total).collect(),
        })
    }

    /// Traffic-weighted mix: weights proportional to each network's
    /// per-inference off-chip traffic, so the networks that move the most
    /// data dominate the co-designed organization's energy objective.
    pub fn traffic_weighted(profiles: Vec<NetworkProfile>) -> Result<WorkloadSet> {
        let weights: Vec<f64> = profiles
            .iter()
            .map(|p| (p.total_off_chip() as f64 / p.batch.max(1) as f64).max(1.0))
            .collect();
        WorkloadSet::with_weights(profiles, weights)
    }

    pub fn profiles(&self) -> &[NetworkProfile] {
        &self.profiles
    }

    /// Normalized mix weights (sum 1), same order as [`Self::profiles`].
    pub fn weights(&self) -> &[f64] {
        &self.weights
    }

    pub fn len(&self) -> usize {
        self.profiles.len()
    }

    pub fn is_empty(&self) -> bool {
        self.profiles.is_empty()
    }

    /// The sizing pseudo-profile: all ops of all networks concatenated
    /// (names prefixed `net/`), so `dse::enumerate` derives component-wise
    /// working-set *unions* and Algorithm 1 residuals over the whole set.
    pub fn merged_profile(&self) -> NetworkProfile {
        let ops = self
            .profiles
            .iter()
            .flat_map(|p| {
                p.ops.iter().map(move |op| {
                    let mut op = op.clone();
                    op.name = format!("{}/{}", p.network, op.name).into();
                    op
                })
            })
            .collect();
        NetworkProfile {
            network: "workload-set".into(),
            ops,
            clock_hz: self.profiles[0].clock_hz,
            batch: 1,
        }
    }
}

/// Result of a co-design sweep: `points[i].energy_j` is the mix-weighted
/// per-inference energy and `points[i].latency_s` the mix-weighted
/// per-inference latency; `per_net_j[i][k]` / `per_net_latency_s[i][k]`
/// are the unweighted per-inference values of network `k` on
/// organization `i`.
pub struct MultiDseResult {
    pub points: Vec<DsePoint>,
    pub per_net_j: Vec<Vec<f64>>,
    pub per_net_latency_s: Vec<Vec<f64>>,
    pub pareto: Vec<usize>,
    pub selected: Vec<(String, usize)>,
    /// Evaluated configurations dropped by the latency budget (0 when
    /// unconstrained).
    pub excluded_by_budget: usize,
    /// Branch-and-bound counters of the co-design sweep.
    pub stats: stream::SweepStats,
}

impl MultiDseResult {
    /// Index of the lowest-weighted-energy selected organization — the
    /// co-designed organization a serving deployment would instantiate.
    pub fn codesigned(&self) -> Option<usize> {
        self.selected
            .iter()
            .map(|&(_, i)| i)
            .min_by(|&a, &b| {
                // total_cmp, not partial_cmp-or-Equal: a NaN energy must
                // sort *last* (never be picked), not tie with everything.
                self.points[a]
                    .energy_j
                    .total_cmp(&self.points[b].energy_j)
            })
    }
}

/// Enumerates co-design candidates: every organization valid for every
/// network of the set (union sizing).
pub fn enumerate(set: &WorkloadSet) -> Result<Vec<Organization>> {
    super::enumerate(&set.merged_profile()).context("enumerating over the merged workload set")
}

/// Builds the org-independent timeline of every member profile (same
/// index order as [`WorkloadSet::profiles`]) under the context's
/// technology and accelerator.
pub fn timelines(ctx: &EvalCtx, set: &WorkloadSet) -> Vec<sim::Timeline> {
    set.profiles
        .iter()
        .map(|p| sim::Timeline::build(p, ctx.tech(), ctx.accel()))
        .collect()
}

/// Engine-parallel weighted evaluation; deterministic in input order for
/// any worker count (same engine contract as the single-network sweep).
/// `tls` are the member timelines from [`timelines`].
pub fn evaluate_all(
    ctx: &EvalCtx,
    orgs: &[Organization],
    set: &WorkloadSet,
    tls: &[sim::Timeline],
) -> (Vec<DsePoint>, Vec<Vec<f64>>, Vec<Vec<f64>>) {
    // Always-on: a timeline/profile mismatch would charge one network's
    // latency to another (lint rule debug_guard, ISSUE 9).
    assert_eq!(tls.len(), set.profiles.len(), "one timeline per member profile");
    let evals: Vec<(DsePoint, Vec<f64>, Vec<f64>)> = ctx
        .engine()
        .map(orgs, |org| eval_one(org, set, ctx.tech(), tls));
    let mut points = Vec::with_capacity(evals.len());
    let mut per_net_j = Vec::with_capacity(evals.len());
    let mut per_net_latency_s = Vec::with_capacity(evals.len());
    for (pt, e, l) in evals {
        points.push(pt);
        per_net_j.push(e);
        per_net_latency_s.push(l);
    }
    (points, per_net_j, per_net_latency_s)
}

/// One weighted co-design evaluation — the single scoring implementation
/// shared by [`evaluate_all`] and the branch-and-bound sweep
/// (`stream::MultiSet`).  The returned point holds the mix-weighted
/// objectives; the vectors hold the unweighted per-network energies and
/// latencies.
pub(crate) fn eval_one(
    org: &Organization,
    set: &WorkloadSet,
    tech: &Technology,
    tls: &[sim::Timeline],
) -> (DsePoint, Vec<f64>, Vec<f64>) {
    let mut per_net = Vec::with_capacity(set.profiles.len());
    let mut per_net_lat = Vec::with_capacity(set.profiles.len());
    let mut area = 0.0;
    let mut energy = 0.0;
    let mut latency = 0.0;
    for ((p, wgt), tl) in set.profiles.iter().zip(&set.weights).zip(tls) {
        let (a, e, l) = evaluate::area_energy_latency(org, p, tech, tl);
        area = a; // identical for every network: one physical org
        energy += wgt * e;
        latency += wgt * l;
        per_net.push(e);
        per_net_lat.push(l);
    }
    (
        DsePoint {
            org: org.clone(),
            area_mm2: area,
            energy_j: energy,
            latency_s: latency,
        },
        per_net,
        per_net_lat,
    )
}

/// The full co-design pipeline under the context's optional hard budget
/// on the mix-weighted per-inference latency
/// ([`crate::ctx::Budget::latency_budget_s`]): organizations that miss
/// the budget are excluded before Pareto extraction and per-option
/// selection.  Errors when the budget excludes every configuration
/// (reporting the fastest achievable mix latency) or is not a positive
/// finite number (the builder already rejects such budgets; this guards
/// direct [`crate::ctx::Budget`] construction).
pub fn run(ctx: &EvalCtx, set: &WorkloadSet) -> Result<MultiDseResult> {
    let latency_budget_s = ctx.budget().latency_budget_s;
    if let Some(budget) = latency_budget_s {
        ensure!(
            budget.is_finite() && budget > 0.0,
            "latency budget must be a positive duration, got {budget} s"
        );
    }
    let merged = set.merged_profile();
    let subtrees =
        stream::subtrees(&merged).context("enumerating over the merged workload set")?;
    let tls = timelines(ctx, set);
    let ev = stream::MultiSet {
        set,
        tech: ctx.tech(),
        tls: &tls,
    };
    let out = stream::sweep(ctx, &subtrees, &ev);
    if let Some(budget) = latency_budget_s {
        if out.points.is_empty() {
            bail!(
                "latency budget {:.4} ms excludes all {} co-design configurations \
                 (fastest achievable mix latency: {:.4} ms)",
                budget * 1e3,
                out.stats.enumerated,
                out.fastest * 1e3
            );
        }
    }
    let mut per_net_j = Vec::with_capacity(out.extras.len());
    let mut per_net_latency_s = Vec::with_capacity(out.extras.len());
    for (e, l) in out.extras {
        per_net_j.push(e);
        per_net_latency_s.push(l);
    }
    let pareto = pareto_indices(&out.points);
    let selected = select_per_option(&out.points);
    Ok(MultiDseResult {
        points: out.points,
        per_net_j,
        per_net_latency_s,
        pareto,
        selected,
        excluded_by_budget: out.excluded,
        stats: out.stats,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Accelerator;
    use crate::dataflow::{profile_network, profile_network_batched};
    use crate::dse;
    use crate::memory::org_fits;
    use crate::model::{capsnet_mnist, deepcaps_cifar10, random_network};

    fn set2() -> WorkloadSet {
        let accel = Accelerator::default();
        WorkloadSet::new(vec![
            profile_network(&capsnet_mnist(), &accel),
            profile_network(&deepcaps_cifar10(), &accel),
        ])
        .unwrap()
    }

    fn ctx(threads: usize) -> EvalCtx {
        EvalCtx::new(Technology::default(), Accelerator::default()).threads(threads)
    }

    #[test]
    fn merged_profile_takes_component_unions() {
        let set = set2();
        let merged = set.merged_profile();
        let caps = &set.profiles()[0];
        let deep = &set.profiles()[1];
        assert_eq!(merged.ops.len(), caps.ops.len() + deep.ops.len());
        assert_eq!(merged.max_d(), caps.max_d().max(deep.max_d()));
        assert_eq!(merged.max_w(), caps.max_w().max(deep.max_w()));
        assert_eq!(merged.max_a(), caps.max_a().max(deep.max_a()));
        assert_eq!(merged.max_total(), caps.max_total().max(deep.max_total()));
        assert!(merged.op("capsnet/Prim").is_some());
        assert!(merged.op("deepcaps/Caps3D-Votes").is_some());
    }

    #[test]
    fn every_codesign_candidate_fits_every_network() {
        let set = set2();
        let orgs = enumerate(&set).unwrap();
        assert!(!orgs.is_empty());
        for org in orgs.iter().step_by(97) {
            for p in set.profiles() {
                assert!(org_fits(org, p), "{} unfit for {}", org.label(), p.network);
            }
        }
    }

    #[test]
    fn weighted_energy_is_the_mix_of_per_net_energies() {
        let accel = Accelerator::default();
        let profiles = vec![
            profile_network(&capsnet_mnist(), &accel),
            profile_network(&deepcaps_cifar10(), &accel),
        ];
        let set = WorkloadSet::with_weights(profiles, vec![3.0, 1.0]).unwrap();
        assert!((set.weights()[0] - 0.75).abs() < 1e-12);
        let orgs: Vec<_> = enumerate(&set).unwrap().into_iter().take(50).collect();
        let c = ctx(2);
        let tls = timelines(&c, &set);
        let (points, per_net, per_lat) = evaluate_all(&c, &orgs, &set, &tls);
        for ((pt, nets), lats) in points.iter().zip(&per_net).zip(&per_lat) {
            let expect = 0.75 * nets[0] + 0.25 * nets[1];
            assert!(
                (pt.energy_j - expect).abs() <= expect * 1e-12,
                "{} vs {expect}",
                pt.energy_j
            );
            let expect_lat = 0.75 * lats[0] + 0.25 * lats[1];
            assert!(
                (pt.latency_s - expect_lat).abs() <= expect_lat * 1e-12,
                "{} vs {expect_lat}",
                pt.latency_s
            );
        }
    }

    #[test]
    fn single_network_set_reproduces_single_network_dse() {
        // Equal machinery: a 1-element set must select exactly what the
        // single-network sweep selects (modulo the name prefix).
        let accel = Accelerator::default();
        let c = ctx(2);
        let p = profile_network(&capsnet_mnist(), &accel);
        let single = dse::run(&c, &p).unwrap();
        let set = WorkloadSet::new(vec![p]).unwrap();
        let multi = run(&c, &set).unwrap();
        assert_eq!(single.points.len(), multi.points.len());
        assert_eq!(single.selected, multi.selected);
        for (a, b) in single.points.iter().zip(&multi.points) {
            assert_eq!(a.org, b.org);
            assert_eq!(a.energy_j.to_bits(), b.energy_j.to_bits());
            assert_eq!(a.latency_s.to_bits(), b.latency_s.to_bits());
        }
    }

    #[test]
    fn codesign_over_three_networks_selects_one_org() {
        let accel = Accelerator::default();
        let set = WorkloadSet::new(vec![
            profile_network(&capsnet_mnist(), &accel),
            profile_network_batched(&capsnet_mnist(), &accel, 4),
            profile_network(&random_network(3), &accel),
        ])
        .unwrap();
        let res = run(&ctx(4), &set).unwrap();
        assert!(!res.points.is_empty());
        assert!(!res.selected.is_empty());
        let best = res.codesigned().unwrap();
        // The co-designed org fits every member and has 3 per-net energies
        // (and latencies).
        assert_eq!(res.per_net_j[best].len(), 3);
        assert_eq!(res.per_net_latency_s[best].len(), 3);
        // Batched capsnet's per-inference latency amortizes below batch-1.
        assert!(res.per_net_latency_s[best][1] < res.per_net_latency_s[best][0]);
        for (p, &e) in set.profiles().iter().zip(&res.per_net_j[best]) {
            assert!(org_fits(&res.points[best].org, p));
            assert!(e > 0.0 && e.is_finite());
        }
        // Batched capsnet must be cheaper per inference than batch-1.
        assert!(res.per_net_j[best][1] < res.per_net_j[best][0]);
    }

    #[test]
    fn deterministic_across_thread_counts() {
        let set = set2();
        let tls = timelines(&ctx(1), &set);
        let orgs: Vec<_> = enumerate(&set).unwrap().into_iter().take(400).collect();
        let (p1, n1, l1) = evaluate_all(&ctx(1), &orgs, &set, &tls);
        let (p4, n4, l4) = evaluate_all(&ctx(4), &orgs, &set, &tls);
        for ((a, b), (na, nb)) in p1.iter().zip(&p4).zip(n1.iter().zip(&n4)) {
            assert_eq!(a.energy_j.to_bits(), b.energy_j.to_bits());
            assert_eq!(a.area_mm2.to_bits(), b.area_mm2.to_bits());
            assert_eq!(a.latency_s.to_bits(), b.latency_s.to_bits());
            assert_eq!(na.len(), nb.len());
            for (x, y) in na.iter().zip(nb) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
        for (x, y) in l1.iter().zip(&l4) {
            assert_eq!(x.len(), y.len());
        }
    }

    #[test]
    fn invalid_sets_report_errors() {
        let accel = Accelerator::default();
        assert!(WorkloadSet::new(vec![]).is_err());
        let p = profile_network(&capsnet_mnist(), &accel);
        assert!(WorkloadSet::with_weights(vec![p.clone()], vec![1.0, 2.0]).is_err());
        assert!(WorkloadSet::with_weights(vec![p.clone()], vec![0.0]).is_err());
        assert!(WorkloadSet::with_weights(vec![p], vec![f64::NAN]).is_err());
    }
}
