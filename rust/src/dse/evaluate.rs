//! Allocation-free fast path for the DSE inner loop.
//!
//! `energy::evaluate_org` + `pmu::evaluate` are the readable, reporting
//! implementations — but they build `OrgEnergy`/`PmuReport`/`String`s per
//! configuration, and the exhaustive sweep evaluates ~half a million
//! configurations.  This module computes the identical (area, energy)
//! objective with one pass over the operations and zero heap allocation
//! per configuration; `tests::fast_matches_reference` pins it bit-close to
//! the reference implementation (see EXPERIMENTS.md section Perf/L3 for the
//! before/after).

use crate::cacti::{cache, SramConfig};
use crate::config::Technology;
use crate::dataflow::NetworkProfile;
use crate::memory::{Component, Organization, OrgKind};
use crate::sim;

// NOTE (EXPERIMENTS.md section Perf/L3): a function-local HashMap memo was
// once tried here and reverted — single-core, the hash lookup cost as much
// as the powf calls it saved (-6%).  The shared `cacti::cache` supersedes
// that experiment with a different design point: a process-global,
// read-mostly store keyed by (Technology, SramConfig).  The enumerated
// organizations reuse a few hundred geometries, so after warmup every
// lookup is a shared-read hit with no lock contention across engine
// workers, and — unlike the local memo — the same entries also feed the
// energy/pmu reporting layers and the serving co-simulation.

/// Per-component constants hoisted out of the op loop.
#[derive(Clone, Copy, Default)]
struct CompCosts {
    present: bool,
    size: usize,
    sectors: usize,
    sector_bytes: usize,
    access_e: f64,
    leak_on: f64,
    leak_sector_on: f64,
    leak_sector_off: f64,
    wakeup_e: f64,
    area: f64,
}

/// Fast (area_mm2, energy_j) evaluation of one organization; the energy is
/// per inference (the profile's per-batch totals amortized over
/// `NetworkProfile::batch`, matching `energy::evaluate_org`).
pub fn area_energy(org: &Organization, profile: &NetworkProfile, tech: &Technology) -> (f64, f64) {
    // One technology fingerprint for all four component lookups.
    let costs_of = cache::for_tech(tech);
    let mut comps = [CompCosts::default(); 4]; // shared, data, weight, acc
    for (idx, c) in Component::ALL.iter().enumerate() {
        if let Some(cfg) = org.sram_config(*c) {
            let costs = costs_of.costs(&cfg);
            comps[idx] = CompCosts {
                present: true,
                size: cfg.size_bytes,
                sectors: cfg.sectors,
                sector_bytes: cfg.sector_bytes().max(1),
                access_e: costs.access_energy_j,
                leak_on: costs.leak_on_w,
                leak_sector_on: costs.leak_sector_on_w,
                leak_sector_off: costs.leak_sector_off_w,
                wakeup_e: costs.wakeup_energy_j,
                area: costs.area_mm2,
            };
        }
    }
    let [shared, data, weight, acc] = &comps;

    let cap = |c: &CompCosts| if c.present { c.size } else { 0 };
    let inv_clock = 1.0 / profile.clock_hz;

    let mut energy = 0.0;
    // Previous ON-sector counts for wakeup accounting (all start OFF).
    let mut prev_on = [0usize; 4];

    for op in &profile.ops {
        let dur = op.cycles as f64 * inv_clock;

        // Coverage (inline cover_op, no struct).
        let ded_d = op.usage_d.min(cap(data));
        let ded_w = op.usage_w.min(cap(weight));
        let ded_a = op.usage_a.min(cap(acc));
        let sh = (op.usage_d - ded_d) + (op.usage_w - ded_w) + (op.usage_a - ded_a);
        debug_assert!(sh <= cap(shared), "org must fit profile");

        // Dynamic energy: accesses split proportionally to covered bytes.
        let d_acc = (op.rd_d + op.wr_d) as f64;
        let w_acc = (op.rd_w + op.wr_w) as f64;
        let a_acc = (op.rd_a + op.wr_a) as f64;
        // Split fractions; zero-usage classes carry no SPM traffic (their
        // accesses, if any, are accounted elsewhere by the dataflow model).
        let split = |acc_count: f64, ded: usize, total: usize| -> (f64, f64) {
            if total == 0 {
                (0.0, 0.0)
            } else {
                let f = ded as f64 / total as f64;
                (acc_count * f, acc_count * (1.0 - f))
            }
        };
        let (dd, ds) = split(d_acc, ded_d, op.usage_d);
        let (wd, ws) = split(w_acc, ded_w, op.usage_w);
        let (ad, as_) = split(a_acc, ded_a, op.usage_a);
        energy += dd * data.access_e
            + wd * weight.access_e
            + ad * acc.access_e
            + (ds + ws + as_) * shared.access_e;

        // Static + wakeup per component.
        let needs = [sh, ded_d, ded_w, ded_a];
        for (i, c) in comps.iter().enumerate() {
            if !c.present {
                continue;
            }
            if c.sectors <= 1 {
                energy += c.leak_on * dur;
            } else {
                let on = needs[i].div_ceil(c.sector_bytes);
                let off = c.sectors - on;
                energy += dur * (on as f64 * c.leak_sector_on + off as f64 * c.leak_sector_off);
                energy += on.saturating_sub(prev_on[i]) as f64 * c.wakeup_e;
                prev_on[i] = on;
            }
        }
    }

    let area = comps.iter().filter(|c| c.present).map(|c| c.area).sum();
    (area, energy / profile.batch.max(1) as f64)
}

/// Admissible subtree lower bound on (area_mm2, energy_j) for the
/// branch-and-bound sweep (`dse::stream`).
///
/// Within a subtree all component SIZES are fixed and only the SECTOR
/// counts vary over `pools`, so coverage — and with it every
/// usage-dependent quantity in [`area_energy`] — is subtree-constant.
/// The bound replays `area_energy`'s accumulation with the *same
/// expression shapes in the same order*, but substitutes at every step the
/// per-component minimum over the subtree's sector variants, and drops the
/// (non-negative) wakeup additions.  IEEE-754 addition is monotone in both
/// operands and multiplication by a non-negative factor is monotone, so
/// the bound's accumulator never exceeds the real accumulator of *any*
/// completion — the bound is admissible bit-wise, with no epsilon slack
/// (pinned by `stream::tests::bound_is_admissible_bitwise` and
/// `rust/tests/prune_exact.rs`).
///
/// `sizes`/`pools` are indexed [shared, data, weight, acc]
/// (`Component::ALL` order).  Presence follows the constructor semantics
/// of `kind`: SMP instantiates only the shared memory, SEP only the three
/// dedicated ones, and HY all four — even at size 0, matching
/// [`Organization::hy`].
pub(crate) fn area_energy_lower_bound(
    kind: OrgKind,
    sizes: [usize; 4],
    pools: &[Vec<usize>; 4],
    profile: &NetworkProfile,
    tech: &Technology,
) -> (f64, f64) {
    let costs_of = cache::for_tech(tech);
    let present = match kind {
        OrgKind::Smp => [true, false, false, false],
        OrgKind::Sep => [false, true, true, true],
        OrgKind::Hy => [true, true, true, true],
    };

    // Per-variant static-leak constants: (sectors, sector_bytes, leak_on,
    // leak_sector_on, leak_sector_off).  At most |sector pool| ≈ 5 entries
    // per component, all served from the shared CACTI cache.
    #[derive(Default)]
    struct BoundComp {
        present: bool,
        size: usize,
        min_access_e: f64,
        min_area: f64,
        variants: Vec<(usize, usize, f64, f64, f64)>,
    }
    let mut comps: [BoundComp; 4] = Default::default();
    for idx in 0..4 {
        if !present[idx] {
            continue;
        }
        let ports = if idx == 0 { 3 } else { 1 };
        let c = &mut comps[idx];
        c.present = true;
        c.size = sizes[idx];
        c.min_access_e = f64::INFINITY;
        c.min_area = f64::INFINITY;
        for &sc in &pools[idx] {
            let cfg = SramConfig::new(sizes[idx], ports, sc);
            let costs = costs_of.costs(&cfg);
            c.min_access_e = c.min_access_e.min(costs.access_energy_j);
            c.min_area = c.min_area.min(costs.area_mm2);
            c.variants.push((
                cfg.sectors,
                cfg.sector_bytes().max(1),
                costs.leak_on_w,
                costs.leak_sector_on_w,
                costs.leak_sector_off_w,
            ));
        }
        if c.variants.is_empty() {
            // Empty sector pool ⟹ the subtree has zero candidates; the
            // sweep never asks for its bound.  Keep the terms neutral.
            c.min_access_e = 0.0;
            c.min_area = 0.0;
        }
    }
    let [shared, data, weight, acc] = &comps;
    let cap = |c: &BoundComp| if c.present { c.size } else { 0 };
    let inv_clock = 1.0 / profile.clock_hz;

    let mut energy = 0.0;
    for op in &profile.ops {
        let dur = op.cycles as f64 * inv_clock;

        // Coverage: size-only, identical for every completion.
        let ded_d = op.usage_d.min(cap(data));
        let ded_w = op.usage_w.min(cap(weight));
        let ded_a = op.usage_a.min(cap(acc));
        let sh = (op.usage_d - ded_d) + (op.usage_w - ded_w) + (op.usage_a - ded_a);
        debug_assert!(sh <= cap(shared), "subtree must fit profile");

        // Dynamic energy with per-component minimum access energies —
        // same expression tree as `area_energy`.
        let d_acc = (op.rd_d + op.wr_d) as f64;
        let w_acc = (op.rd_w + op.wr_w) as f64;
        let a_acc = (op.rd_a + op.wr_a) as f64;
        let split = |acc_count: f64, ded: usize, total: usize| -> (f64, f64) {
            if total == 0 {
                (0.0, 0.0)
            } else {
                let f = ded as f64 / total as f64;
                (acc_count * f, acc_count * (1.0 - f))
            }
        };
        let (dd, ds) = split(d_acc, ded_d, op.usage_d);
        let (wd, ws) = split(w_acc, ded_w, op.usage_w);
        let (ad, as_) = split(a_acc, ded_a, op.usage_a);
        energy += dd * data.min_access_e
            + wd * weight.min_access_e
            + ad * acc.min_access_e
            + (ds + ws + as_) * shared.min_access_e;

        // Static energy: per component, the minimum over sector variants
        // of that variant's exact static term (wakeup terms dropped —
        // they only ever add energy).
        let needs = [sh, ded_d, ded_w, ded_a];
        for (i, c) in comps.iter().enumerate() {
            if !c.present || c.variants.is_empty() {
                continue;
            }
            let mut static_min = f64::INFINITY;
            for &(sectors, sector_bytes, leak_on, ls_on, ls_off) in &c.variants {
                let term = if sectors <= 1 {
                    leak_on * dur
                } else {
                    let on = needs[i].div_ceil(sector_bytes);
                    let off = sectors - on;
                    dur * (on as f64 * ls_on + off as f64 * ls_off)
                };
                static_min = static_min.min(term);
            }
            energy += static_min;
        }
    }

    let mut area = 0.0;
    for c in comps.iter().filter(|c| c.present) {
        area += c.min_area;
    }
    (area, energy / profile.batch.max(1) as f64)
}

/// Fast 3-objective evaluation: (area_mm2, energy_j, latency_s), all per
/// inference.  The latency is the org-independent timeline (built once per
/// sweep by the caller) plus this organization's wakeup exposure — the
/// single implementation in `sim::wakeup_exposure_s`, so the DSE objective,
/// `sim::simulate` reporting and the coordinator's SLO accounting can never
/// drift apart.
pub fn area_energy_latency(
    org: &Organization,
    profile: &NetworkProfile,
    tech: &Technology,
    timeline: &sim::Timeline,
) -> (f64, f64, f64) {
    let (area, energy) = area_energy(org, profile, tech);
    let batch_s =
        timeline.batch_latency_s() + sim::wakeup_exposure_s(timeline, profile, org, tech);
    (area, energy, batch_s / profile.batch.max(1) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Accelerator;
    use crate::dataflow::profile_network;
    use crate::dse;
    use crate::energy::evaluate_org;
    use crate::model::{capsnet_mnist, deepcaps_cifar10};

    #[test]
    fn fast_matches_reference() {
        // The fast path must agree with the readable evaluator on every
        // enumerated configuration class (sampled) for both networks.
        let accel = Accelerator::default();
        let tech = Technology::default();
        for net in [capsnet_mnist(), deepcaps_cifar10()] {
            let p = profile_network(&net, &accel);
            let orgs = dse::enumerate(&p).unwrap();
            for (k, org) in orgs.iter().enumerate() {
                if k % 97 != 0 {
                    continue; // sample ~1%
                }
                let (fast_area, fast_e) = area_energy(org, &p, &tech);
                let slow = evaluate_org(org, &p, &tech).unwrap();
                let slow_e = slow.energy_j();
                assert!(
                    (fast_area - slow.area_mm2()).abs() < 1e-12,
                    "{}: area {fast_area} vs {}",
                    org.label(),
                    slow.area_mm2()
                );
                assert!(
                    (fast_e - slow_e).abs() <= slow_e * 1e-12 + 1e-18,
                    "{}: energy {fast_e} vs {slow_e}",
                    org.label()
                );
            }
        }
    }

    #[test]
    fn fast_matches_reference_at_batch_8() {
        // The per-inference amortization must agree between the fast path
        // and the readable evaluator for batched profiles too.
        use crate::dataflow::profile_network_batched;
        let accel = Accelerator::default();
        let tech = Technology::default();
        let p = profile_network_batched(&capsnet_mnist(), &accel, 8);
        for (k, org) in dse::enumerate(&p).unwrap().iter().enumerate() {
            if k % 211 != 0 {
                continue;
            }
            let (_, fast_e) = area_energy(org, &p, &tech);
            let slow_e = evaluate_org(org, &p, &tech).unwrap().energy_j();
            assert!(
                (fast_e - slow_e).abs() <= slow_e * 1e-12 + 1e-18,
                "{}: energy {fast_e} vs {slow_e}",
                org.label()
            );
        }
    }
}
