//! Allocation-free fast path for the DSE inner loop, plus the
//! subtree-factored prepared evaluator the branch-and-bound sweep runs on.
//!
//! `energy::evaluate_org` + `pmu::evaluate` are the readable, reporting
//! implementations — but they build `OrgEnergy`/`PmuReport`/`String`s per
//! configuration, and the exhaustive sweep evaluates ~half a million
//! configurations.  [`area_energy`] computes the identical (area, energy)
//! objective with one pass over the operations and zero heap allocation
//! per configuration; `tests::fast_matches_reference` pins it bit-close to
//! the reference implementation (see EXPERIMENTS.md section Perf/L3 for the
//! before/after).
//!
//! [`SubtreeEval`] (DESIGN.md section 14) goes one step further for the
//! sweep: within a `dse::stream::Subtree` every component SIZE is fixed and
//! only SECTOR counts vary, so coverage, access splits, op durations and
//! therefore the entire dynamic energy are subtree-invariant, and the
//! sector-dependent static/wakeup terms take one of |pool| values per
//! component.  Preparing those tables once per subtree turns each point
//! evaluation from O(ops) into O(components) — four table lookups plus an
//! area sum.  The factored path is **bit-identical** to [`area_energy`] /
//! [`area_energy_latency`] by construction: `area_energy`'s accumulation is
//! deliberately structured as one dynamic accumulator plus four
//! per-component static accumulators combined at the end, and the prepared
//! tables replay exactly those per-accumulator addition sequences (f64
//! addition is deterministic, so equal sequences give equal bits).  Pinned
//! by `rust/tests/factored_eval.rs` and `rust/tests/prune_exact.rs`.

use crate::cacti::{cache, SramConfig};
use crate::config::Technology;
use crate::dataflow::NetworkProfile;
use crate::memory::{Component, Organization, OrgKind};
use crate::sim;

// NOTE (EXPERIMENTS.md section Perf/L3): a function-local HashMap memo was
// once tried here and reverted — single-core, the hash lookup cost as much
// as the powf calls it saved (-6%).  The shared `cacti::cache` supersedes
// that experiment with a different design point: a process-global,
// read-mostly store keyed by (Technology, SramConfig).  The enumerated
// organizations reuse a few hundred geometries, so after warmup every
// lookup is a shared-read hit with no lock contention across engine
// workers, and — unlike the local memo — the same entries also feed the
// energy/pmu reporting layers and the serving co-simulation.

/// Per-component constants hoisted out of the op loop.
#[derive(Clone, Copy, Default)]
struct CompCosts {
    present: bool,
    size: usize,
    sectors: usize,
    sector_bytes: usize,
    access_e: f64,
    leak_on: f64,
    leak_sector_on: f64,
    leak_sector_off: f64,
    wakeup_e: f64,
    area: f64,
}

/// Fast (area_mm2, energy_j) evaluation of one organization; the energy is
/// per inference (the profile's per-batch totals amortized over
/// `NetworkProfile::batch`, matching `energy::evaluate_org`).
///
/// ACCUMULATION-ORDER CONTRACT (DESIGN.md section 14): the energy is summed
/// as one *dynamic* accumulator plus four *per-component static*
/// accumulators, combined only at the end as
/// `dyn + stat[shared] + stat[data] + stat[weight] + stat[acc]` (present
/// components in `Component::ALL` order) and then divided by the batch.
/// [`SubtreeEval`] replays exactly these per-accumulator sequences from its
/// prepared tables, which is what makes the factored sweep path
/// bit-identical to this reference — do not reorder the additions here
/// without updating the factored path and DESIGN.md section 14 together.
pub fn area_energy(org: &Organization, profile: &NetworkProfile, tech: &Technology) -> (f64, f64) {
    // One technology fingerprint for all four component lookups.
    let costs_of = cache::for_tech(tech);
    let mut comps = [CompCosts::default(); 4]; // shared, data, weight, acc
    for (idx, c) in Component::ALL.iter().enumerate() {
        if let Some(cfg) = org.sram_config(*c) {
            let costs = costs_of.costs(&cfg);
            comps[idx] = CompCosts {
                present: true,
                size: cfg.size_bytes,
                sectors: cfg.sectors,
                sector_bytes: cfg.sector_bytes().max(1),
                access_e: costs.access_energy_j,
                leak_on: costs.leak_on_w,
                leak_sector_on: costs.leak_sector_on_w,
                leak_sector_off: costs.leak_sector_off_w,
                wakeup_e: costs.wakeup_energy_j,
                area: costs.area_mm2,
            };
        }
    }
    let [shared, data, weight, acc] = &comps;

    let cap = |c: &CompCosts| if c.present { c.size } else { 0 };
    let inv_clock = 1.0 / profile.clock_hz;

    let mut dyn_e = 0.0;
    let mut stat = [0.0f64; 4];
    // Previous ON-sector counts for wakeup accounting (all start OFF).
    let mut prev_on = [0usize; 4];

    for op in &profile.ops {
        let dur = op.cycles as f64 * inv_clock;

        // Coverage (inline cover_op, no struct).
        let ded_d = op.usage_d.min(cap(data));
        let ded_w = op.usage_w.min(cap(weight));
        let ded_a = op.usage_a.min(cap(acc));
        let sh = (op.usage_d - ded_d) + (op.usage_w - ded_w) + (op.usage_a - ded_a);
        // Always-on: a non-fitting org here would silently mis-attribute
        // energy in release builds (lint rule debug_guard, ISSUE 9).
        assert!(sh <= cap(shared), "org must fit profile");

        // Dynamic energy: accesses split proportionally to covered bytes.
        let d_acc = (op.rd_d + op.wr_d) as f64;
        let w_acc = (op.rd_w + op.wr_w) as f64;
        let a_acc = (op.rd_a + op.wr_a) as f64;
        // Split fractions; zero-usage classes carry no SPM traffic (their
        // accesses, if any, are accounted elsewhere by the dataflow model).
        let split = |acc_count: f64, ded: usize, total: usize| -> (f64, f64) {
            if total == 0 {
                (0.0, 0.0)
            } else {
                let f = ded as f64 / total as f64;
                (acc_count * f, acc_count * (1.0 - f))
            }
        };
        let (dd, ds) = split(d_acc, ded_d, op.usage_d);
        let (wd, ws) = split(w_acc, ded_w, op.usage_w);
        let (ad, as_) = split(a_acc, ded_a, op.usage_a);
        dyn_e += dd * data.access_e
            + wd * weight.access_e
            + ad * acc.access_e
            + (ds + ws + as_) * shared.access_e;

        // Static + wakeup per component, each into its own accumulator.
        let needs = [sh, ded_d, ded_w, ded_a];
        for (i, c) in comps.iter().enumerate() {
            if !c.present {
                continue;
            }
            if c.sectors <= 1 {
                stat[i] += c.leak_on * dur;
            } else {
                let on = needs[i].div_ceil(c.sector_bytes);
                let off = c.sectors - on;
                stat[i] += dur * (on as f64 * c.leak_sector_on + off as f64 * c.leak_sector_off);
                stat[i] += on.saturating_sub(prev_on[i]) as f64 * c.wakeup_e;
                prev_on[i] = on;
            }
        }
    }

    let mut energy = dyn_e;
    for (i, c) in comps.iter().enumerate() {
        if c.present {
            energy += stat[i];
        }
    }
    let area = comps.iter().filter(|c| c.present).map(|c| c.area).sum();
    (area, energy / profile.batch.max(1) as f64)
}

/// Fast 3-objective evaluation: (area_mm2, energy_j, latency_s), all per
/// inference.  The latency is the org-independent timeline (built once per
/// sweep by the caller) plus this organization's wakeup exposure — the
/// single implementation in `sim::wakeup_exposure_s`, so the DSE objective,
/// `sim::simulate` reporting and the coordinator's SLO accounting can never
/// drift apart.
pub fn area_energy_latency(
    org: &Organization,
    profile: &NetworkProfile,
    tech: &Technology,
    timeline: &sim::Timeline,
) -> (f64, f64, f64) {
    let (area, energy) = area_energy(org, profile, tech);
    let batch_s =
        timeline.batch_latency_s() + sim::wakeup_exposure_s(timeline, profile, org, tech);
    (area, energy, batch_s / profile.batch.max(1) as f64)
}

/// One candidate sector option of one component within a subtree: the full
/// op-summed static contribution, the area, and the wakeup-boundary set.
struct SectorOption {
    /// The option's sector count (the lookup key within the pool).
    sectors: usize,
    /// Σ over ops of this component's static leak + wakeup energy [J]
    /// (batch-undivided), accumulated in op order with the exact
    /// leak-then-wakeup addition sequence of [`area_energy`].
    static_e: f64,
    area_mm2: f64,
    /// Bit `k` set ⟺ this option's ON-sector count rises at op `k` (k > 0)
    /// — the wake boundaries feeding the latency-exposure union.  Only
    /// populated for gated options when some boundary charge is nonzero.
    rise: Vec<u64>,
    /// sectors > 1: participates in wakeup exposure.
    gated: bool,
}

/// One component's prepared table: candidate sector counts (pool order)
/// and their precomputed costs.
#[derive(Default)]
struct CompTable {
    present: bool,
    options: Vec<SectorOption>,
    /// min over options of `static_e` / `area_mm2` (0.0 when the pool is
    /// empty — the subtree then has no candidates and is never evaluated).
    min_static_e: f64,
    min_area: f64,
}

/// Per-subtree prepared evaluator (DESIGN.md section 14): everything
/// size-dependent — coverage, access splits, op durations, the whole
/// dynamic energy, and the per-sector-option static/wakeup sums — is
/// computed once on subtree entry, so evaluating one point is O(components)
/// table lookups instead of an O(ops) pass.
///
/// Bit-exactness contract: [`SubtreeEval::eval`] returns exactly the bits
/// of [`area_energy_latency`] for every organization drawn from the
/// prepared subtree (pinned by `rust/tests/factored_eval.rs`), because the
/// tables replay the reference's per-accumulator addition sequences — see
/// the accumulation-order contract on [`area_energy`].
///
/// The prepared tables also yield the sweep's admissible lower bound
/// ([`SubtreeEval::bound`]): per component the minimum over the pool of the
/// *full* per-option static sum (wakeup included — each minimum is realized
/// by an actual option, unlike the per-op minima of the pre-factored bound,
/// so this bound is at least as tight), combined in the evaluator's exact
/// accumulation shape.  IEEE-754 addition and division by a positive
/// constant are monotone, so substituting each table minimum can only lower
/// the result — the bound never exceeds any completion, bit-wise.
pub struct SubtreeEval {
    comps: [CompTable; 4],
    /// Dynamic energy Σ over ops [J], batch-undivided — subtree-invariant
    /// because CACTI access energies depend on (size, ports) only.
    dyn_e: f64,
    /// `profile.batch.max(1)` — the per-inference divisor.
    batch: f64,
    /// Org-independent `timeline.batch_latency_s()`.
    base_latency_s: f64,
    /// Per-op wakeup-boundary charge `(wakeup_latency - prev_dur).max(0)`
    /// [s]; empty when the wakeup latency is ≤ 0.  Index 0 is never
    /// charged (op 0's sectors wake during the previous frame).
    charge: Vec<f64>,
    /// Some charge is > 0 (at the paper's 0.072 ns wakeup every boundary
    /// masks and every exposure is exactly +0.0, so the whole union walk
    /// can be skipped without changing a bit).
    has_charge: bool,
}

impl SubtreeEval {
    /// Prepares the factored evaluator for one subtree: `sizes`/`pools`
    /// are indexed [shared, data, weight, acc] (`Component::ALL` order),
    /// presence follows `kind` via [`OrgKind::presence`].  One pass over
    /// the ops per (component, sector option) — O(ops × Σ|pool|) once,
    /// against O(ops) per point saved for every candidate in the subtree.
    pub fn prepare(
        kind: OrgKind,
        sizes: [usize; 4],
        pools: &[Vec<usize>; 4],
        profile: &NetworkProfile,
        tech: &Technology,
        timeline: &sim::Timeline,
    ) -> SubtreeEval {
        let costs_of = cache::for_tech(tech);
        let present = kind.presence();
        let n = profile.ops.len();
        let inv_clock = 1.0 / profile.clock_hz;
        let cap = |i: usize| if present[i] { sizes[i] } else { 0 };

        // Subtree-constant per-op precomputation: coverage and durations.
        let mut needs: Vec<[usize; 4]> = Vec::with_capacity(n);
        let mut durs: Vec<f64> = Vec::with_capacity(n);
        for op in &profile.ops {
            let ded_d = op.usage_d.min(cap(1));
            let ded_w = op.usage_w.min(cap(2));
            let ded_a = op.usage_a.min(cap(3));
            let sh = (op.usage_d - ded_d) + (op.usage_w - ded_w) + (op.usage_a - ded_a);
            // Always-on (per subtree, not per point — negligible): the
            // factored tables would replay a misfit into every candidate.
            assert!(
                sh <= cap(0),
                "subtree must fit profile (stream::subtrees rejects misfits)"
            );
            needs.push([sh, ded_d, ded_w, ded_a]);
            durs.push(op.cycles as f64 * inv_clock);
        }

        // Access energies are sector-independent (CACTI: a function of
        // size and ports only), so any pool entry yields the same value
        // and the dynamic term collapses to ONE number for the subtree —
        // accumulated in the exact per-op expression order of
        // `area_energy`.
        let mut access_e = [0.0f64; 4];
        for i in 0..4 {
            if present[i] {
                let sc = pools[i].first().copied().unwrap_or(1);
                let ports = if i == 0 { 3 } else { 1 };
                access_e[i] = costs_of
                    .costs(&SramConfig::new(sizes[i], ports, sc))
                    .access_energy_j;
            }
        }
        let mut dyn_e = 0.0;
        for (k, op) in profile.ops.iter().enumerate() {
            let [_, ded_d, ded_w, ded_a] = needs[k];
            let d_acc = (op.rd_d + op.wr_d) as f64;
            let w_acc = (op.rd_w + op.wr_w) as f64;
            let a_acc = (op.rd_a + op.wr_a) as f64;
            let split = |acc_count: f64, ded: usize, total: usize| -> (f64, f64) {
                if total == 0 {
                    (0.0, 0.0)
                } else {
                    let f = ded as f64 / total as f64;
                    (acc_count * f, acc_count * (1.0 - f))
                }
            };
            let (dd, ds) = split(d_acc, ded_d, op.usage_d);
            let (wd, ws) = split(w_acc, ded_w, op.usage_w);
            let (ad, as_) = split(a_acc, ded_a, op.usage_a);
            dyn_e += dd * access_e[1]
                + wd * access_e[2]
                + ad * access_e[3]
                + (ds + ws + as_) * access_e[0];
        }

        // Org-independent wakeup-boundary charges (`sim::wakeup_exposure_s`
        // computes the identical expression per boundary, division and
        // all).  At wl <= 0 the reference returns 0.0 before summing.
        let wl = tech.wakeup_latency_s;
        let mut charge: Vec<f64> = Vec::new();
        let mut has_charge = false;
        if wl > 0.0 {
            charge = vec![0.0f64; n];
            for k in 1..n {
                let prev_dur = timeline.ops[k - 1].duration_cycles() as f64 / timeline.clock_hz;
                let c = (wl - prev_dur).max(0.0);
                charge[k] = c;
                has_charge |= c > 0.0;
            }
        }

        // Per-(component, sector option) static/wakeup sums and wake
        // boundaries — the accumulation sequence mirrors `area_energy`'s
        // per-component accumulator and `sim::wakeup_exposure_s`'s
        // rise detection exactly.
        let words = n.div_ceil(64);
        let mut comps: [CompTable; 4] = Default::default();
        for i in 0..4 {
            let t = &mut comps[i];
            t.present = present[i];
            if !present[i] {
                continue;
            }
            let ports = if i == 0 { 3 } else { 1 };
            t.min_static_e = f64::INFINITY;
            t.min_area = f64::INFINITY;
            for &sc in &pools[i] {
                let cfg = SramConfig::new(sizes[i], ports, sc);
                let costs = costs_of.costs(&cfg);
                let sector_bytes = cfg.sector_bytes().max(1);
                let gated = cfg.sectors > 1;
                let mut static_e = 0.0;
                let mut rise: Vec<u64> = if gated && has_charge {
                    vec![0u64; words]
                } else {
                    Vec::new()
                };
                if !gated {
                    for &dur in &durs {
                        static_e += costs.leak_on_w * dur;
                    }
                } else {
                    let mut prev_on = 0usize;
                    for k in 0..n {
                        let on = needs[k][i].div_ceil(sector_bytes);
                        let off = cfg.sectors - on;
                        static_e += durs[k]
                            * (on as f64 * costs.leak_sector_on_w
                                + off as f64 * costs.leak_sector_off_w);
                        static_e += on.saturating_sub(prev_on) as f64 * costs.wakeup_energy_j;
                        if !rise.is_empty() && k > 0 && on > prev_on {
                            rise[k / 64] |= 1u64 << (k % 64);
                        }
                        prev_on = on;
                    }
                }
                t.min_static_e = t.min_static_e.min(static_e);
                t.min_area = t.min_area.min(costs.area_mm2);
                t.options.push(SectorOption {
                    sectors: cfg.sectors,
                    static_e,
                    area_mm2: costs.area_mm2,
                    rise,
                    gated,
                });
            }
            if t.options.is_empty() {
                // Empty sector pool ⟹ zero candidates; the sweep skips
                // the subtree, keep the bound terms neutral.
                t.min_static_e = 0.0;
                t.min_area = 0.0;
            }
        }

        SubtreeEval {
            comps,
            dyn_e,
            batch: profile.batch.max(1) as f64,
            base_latency_s: timeline.batch_latency_s(),
            charge,
            has_charge,
        }
    }

    /// Evaluates one organization drawn from the prepared subtree:
    /// (area_mm2, energy_j, latency_s) per inference, bit-identical to
    /// [`area_energy_latency`].  O(components) — four pool lookups plus,
    /// only in exposed-wakeup regimes, a bitset walk over wake boundaries.
    pub fn eval(&self, org: &Organization) -> (f64, f64, f64) {
        let mut energy = self.dyn_e;
        let mut area = 0.0;
        let mut rises: [Option<&[u64]>; 4] = [None; 4];
        for (i, c) in Component::ALL.iter().enumerate() {
            let t = &self.comps[i];
            if !t.present {
                continue;
            }
            let sectors = org.spec(*c).map(|s| s.sectors).unwrap_or(1);
            let opt = t
                .options
                .iter()
                .find(|o| o.sectors == sectors)
                // lint: allow(hot_unwrap, "caller contract: eval() only sees orgs materialized from this subtree, whose pools built these option tables; Result here would cost the factored fast path its point")
                .expect("organization not drawn from the prepared subtree");
            energy += opt.static_e;
            area += opt.area_mm2;
            if opt.gated && !opt.rise.is_empty() {
                rises[i] = Some(opt.rise.as_slice());
            }
        }

        // Wakeup exposure: one charge per op where ANY gated component
        // wakes — the union of the options' rise bitsets, summed in
        // ascending op order (the reference's exact addition sequence).
        let mut exposure = 0.0;
        if self.has_charge && rises.iter().any(|r| r.is_some()) {
            let words = self.charge.len().div_ceil(64);
            for w in 0..words {
                let mut m = 0u64;
                for r in rises.iter().flatten() {
                    m |= r[w];
                }
                while m != 0 {
                    let k = w * 64 + m.trailing_zeros() as usize;
                    exposure += self.charge[k];
                    m &= m - 1;
                }
            }
        }

        let batch_s = self.base_latency_s + exposure;
        (area, energy / self.batch, batch_s / self.batch)
    }

    /// Admissible lower bound on (area_mm2, energy_j, latency_s) over
    /// every candidate of the prepared subtree, bit-wise (never exceeds
    /// any completion) — see the type-level docs for the argument.
    pub fn bound(&self) -> (f64, f64, f64) {
        let mut energy = self.dyn_e;
        let mut area = 0.0;
        for t in &self.comps {
            if t.present {
                energy += t.min_static_e;
                area += t.min_area;
            }
        }
        // Exposure is ≥ +0.0 for every candidate, so the org-independent
        // base timeline is a bit-tight latency bound.
        (area, energy / self.batch, self.base_latency_s / self.batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Accelerator;
    use crate::dataflow::profile_network;
    use crate::dse;
    use crate::energy::evaluate_org;
    use crate::model::{capsnet_mnist, deepcaps_cifar10};

    #[test]
    fn fast_matches_reference() {
        // The fast path must agree with the readable evaluator on every
        // enumerated configuration class (sampled) for both networks.
        let accel = Accelerator::default();
        let tech = Technology::default();
        for net in [capsnet_mnist(), deepcaps_cifar10()] {
            let p = profile_network(&net, &accel);
            let orgs = dse::enumerate(&p).unwrap();
            for (k, org) in orgs.iter().enumerate() {
                if k % 97 != 0 {
                    continue; // sample ~1%
                }
                let (fast_area, fast_e) = area_energy(org, &p, &tech);
                let slow = evaluate_org(org, &p, &tech).unwrap();
                let slow_e = slow.energy_j();
                assert!(
                    (fast_area - slow.area_mm2()).abs() < 1e-12,
                    "{}: area {fast_area} vs {}",
                    org.label(),
                    slow.area_mm2()
                );
                assert!(
                    (fast_e - slow_e).abs() <= slow_e * 1e-12 + 1e-18,
                    "{}: energy {fast_e} vs {slow_e}",
                    org.label()
                );
            }
        }
    }

    #[test]
    fn fast_matches_reference_at_batch_8() {
        // The per-inference amortization must agree between the fast path
        // and the readable evaluator for batched profiles too.
        use crate::dataflow::profile_network_batched;
        let accel = Accelerator::default();
        let tech = Technology::default();
        let p = profile_network_batched(&capsnet_mnist(), &accel, 8);
        for (k, org) in dse::enumerate(&p).unwrap().iter().enumerate() {
            if k % 211 != 0 {
                continue;
            }
            let (_, fast_e) = area_energy(org, &p, &tech);
            let slow_e = evaluate_org(org, &p, &tech).unwrap().energy_j();
            assert!(
                (fast_e - slow_e).abs() <= slow_e * 1e-12 + 1e-18,
                "{}: energy {fast_e} vs {slow_e}",
                org.label()
            );
        }
    }

    #[test]
    fn factored_eval_is_bit_identical_to_reference_on_capsnet() {
        // Smoke of the central ISSUE 7 property (the full sweep across
        // networks, batches and wakeup regimes lives in
        // rust/tests/factored_eval.rs): every candidate of every subtree
        // evaluates to the same bits through the prepared tables as
        // through the per-point reference.
        let accel = Accelerator::default();
        let tech = Technology::default();
        let p = profile_network(&capsnet_mnist(), &accel);
        let tl = sim::Timeline::build(&p, &tech, &accel);
        let mut batch = Vec::new();
        for st in dse::stream::subtrees(&p).unwrap() {
            if st.count() == 0 {
                continue;
            }
            let prep = SubtreeEval::prepare(st.kind(), st.sizes(), st.pools(), &p, &tech, &tl);
            batch.clear();
            st.materialize_into(&mut batch);
            for (k, org) in batch.iter().enumerate() {
                if k % 7 != 0 {
                    continue;
                }
                let fast = prep.eval(org);
                let slow = area_energy_latency(org, &p, &tech, &tl);
                assert_eq!(fast.0.to_bits(), slow.0.to_bits(), "{}: area", org.label());
                assert_eq!(fast.1.to_bits(), slow.1.to_bits(), "{}: energy", org.label());
                assert_eq!(fast.2.to_bits(), slow.2.to_bits(), "{}: latency", org.label());
            }
        }
    }
}
