//! Design-space exploration (paper section V-C/V-D, Algorithms 1–2):
//! exhaustive enumeration of SMP/SEP/HY organizations (sizes x sectors x
//! shared-port constraints), parallel evaluation through the CACTI/PMU
//! energy models, Pareto-frontier extraction, and the per-design-option
//! lowest-energy selection that produces Tables I and II.
//!
//! The sweep runs as a composable pipeline on the shared execution engine
//! (`util::exec::Engine`): enumerate → evaluate (engine-parallel, costs
//! memoized by `cacti::cache`) → Pareto/select.  The evaluation stage is
//! deterministic under any thread count — `rust/tests/engine_cache.rs`
//! pins bit-identical `DsePoint` sets for threads=1 vs threads=N.
//!
//! Since the timeline simulator (`crate::sim`, DESIGN.md section 11) the
//! objective space is three-dimensional — area, energy *and* per-inference
//! latency (compute + dma-stall + wakeup exposure).  The org-independent
//! [`sim::Timeline`] is built once per sweep; each evaluation adds only
//! the organization's wakeup exposure.  [`run`] additionally enforces the
//! context's latency budget as a hard constraint (the CLI's
//! `--latency-budget`).
//!
//! Every entry point takes the unified evaluation context
//! ([`crate::ctx::EvalCtx`], DESIGN.md section 17): engine, technology,
//! accelerator and budget travel as one bundle instead of positionally.

pub mod evaluate;
pub mod heuristic;
pub mod multi;
pub mod pools;
pub mod stream;

use anyhow::{anyhow, bail, ensure, Result};

use crate::config::Technology;
use crate::ctx::EvalCtx;
use crate::dataflow::NetworkProfile;
use crate::sim;

use crate::memory::{cover_op, org_fits, required_shared_ports, MemSpec, OrgKind, Organization};
use crate::util::pareto::{frontier3, Point3};

/// One evaluated configuration: the DSE objective space of Figs 18/20/22,
/// plus the timeline latency.
#[derive(Debug, Clone)]
pub struct DsePoint {
    pub org: Organization,
    pub area_mm2: f64,
    /// Total on-chip SPM energy per inference (dynamic+static+wakeup) [J].
    pub energy_j: f64,
    /// Per-inference latency [s]: the simulated timeline plus this
    /// organization's wakeup exposure, amortized over the batch.  Identical
    /// across organizations at the paper's constants (wakeups mask) — the
    /// "no performance loss" claim.
    pub latency_s: f64,
}

impl DsePoint {
    /// Design-option bucket: SMP, SMP-PG, SEP, SEP-PG, HY, HY-PG.
    pub fn option(&self) -> DesignOption {
        DesignOption::of(self.org.kind, self.org.power_gated())
    }
}

/// Design-option bucket of a configuration: the organization kind crossed
/// with power gating.  `Copy` — the sweep buckets hundreds of thousands of
/// points, and the old `String`-returning `option()` allocated on every
/// call.  The variant order matches the lexicographic order of the labels,
/// so iterating [`DesignOption::ALL`] reproduces the ordering the old
/// `BTreeMap<String, _>` selection produced.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum DesignOption {
    Hy,
    HyPg,
    Sep,
    SepPg,
    Smp,
    SmpPg,
}

impl DesignOption {
    /// All options, in label-lexicographic order.
    pub const ALL: [DesignOption; 6] = [
        DesignOption::Hy,
        DesignOption::HyPg,
        DesignOption::Sep,
        DesignOption::SepPg,
        DesignOption::Smp,
        DesignOption::SmpPg,
    ];

    pub fn of(kind: OrgKind, power_gated: bool) -> DesignOption {
        match (kind, power_gated) {
            (OrgKind::Hy, false) => DesignOption::Hy,
            (OrgKind::Hy, true) => DesignOption::HyPg,
            (OrgKind::Sep, false) => DesignOption::Sep,
            (OrgKind::Sep, true) => DesignOption::SepPg,
            (OrgKind::Smp, false) => DesignOption::Smp,
            (OrgKind::Smp, true) => DesignOption::SmpPg,
        }
    }

    /// The paper's table label ("HY-PG", "SEP", ...).
    pub fn label(self) -> &'static str {
        match self {
            DesignOption::Hy => "HY",
            DesignOption::HyPg => "HY-PG",
            DesignOption::Sep => "SEP",
            DesignOption::SepPg => "SEP-PG",
            DesignOption::Smp => "SMP",
            DesignOption::SmpPg => "SMP-PG",
        }
    }

    /// Dense index into [`DesignOption::ALL`] (per-option accumulator
    /// arrays in the sweep).
    pub fn index(self) -> usize {
        self as usize
    }
}

impl std::fmt::Display for DesignOption {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.label())
    }
}

/// The SEP sizes of Eq. 2 (component-wise maxima, pool-rounded).
pub fn sep_sizes(profile: &NetworkProfile) -> (usize, usize, usize) {
    (
        pools::roundup(profile.max_d()),
        pools::roundup(profile.max_w()),
        pools::roundup(profile.max_a()),
    )
}

/// The SMP size of Eq. 1.
pub fn smp_size(profile: &NetworkProfile) -> usize {
    pools::roundup(profile.max_total())
}

/// The shared-memory size Algorithm 1 computes for a dedicated-size triple:
/// the operation-wise worst-case residual, pool-rounded.  Errors (instead
/// of panicking) on a workload whose residuals overflow even the unbounded
/// probe — the failure mode of a malformed workload spec.
pub fn hy_shared_size(profile: &NetworkProfile, d: usize, w: usize, a: usize) -> Result<usize> {
    let probe = Organization::hy(
        MemSpec::new(usize::MAX / 4, 1),
        MemSpec::new(d, 1),
        MemSpec::new(w, 1),
        MemSpec::new(a, 1),
        3,
    );
    let mut max_residual = 0;
    for op in &profile.ops {
        let cov = cover_op(&probe, op).ok_or_else(|| {
            anyhow!(
                "operation '{}' of '{}' overflows the unbounded shared-memory probe",
                op.name,
                profile.network
            )
        })?;
        max_residual = max_residual.max(cov.shared_total());
    }
    Ok(pools::roundup(max_residual))
}

/// Full enumeration: SMP + SEP + HY, each with every valid sector
/// combination (Algorithm 2).  SEP and SMP boundary cases of HY are
/// emitted once, as their own design options.
///
/// The enumeration order is defined by [`stream::subtrees`] — the pruned
/// sweep and this materialized list walk the exact same sequence, which is
/// what makes the exhaustive path a drop-in oracle for the property tests.
pub fn enumerate(profile: &NetworkProfile) -> Result<Vec<Organization>> {
    let mut out = Vec::new();
    for st in stream::subtrees(profile)? {
        st.materialize_into(&mut out);
    }
    // Real guard, not debug-only: this is the oracle the pruned sweep is
    // checked against, so a non-fitting org must never survive in release
    // builds either (lint rule debug_guard, ISSUE 9).
    ensure!(
        out.iter().all(|o| org_fits(o, profile)),
        "enumeration produced an organization that does not fit '{}'",
        profile.network
    );
    Ok(out)
}

/// The Fig 22 study: HY organizations with the shared memory constrained to
/// `ports` ports (only configurations whose spill pattern actually needs no
/// more than that many value types simultaneously are valid).
pub fn enumerate_hy_ports(profile: &NetworkProfile, ports: usize) -> Result<Vec<Organization>> {
    let mut out = Vec::new();
    for org in enumerate(profile)? {
        if org.kind != OrgKind::Hy {
            continue;
        }
        let mut constrained = org.clone();
        constrained.shared_ports = ports;
        if required_shared_ports(&constrained, profile) <= ports {
            out.push(constrained);
        }
    }
    Ok(out)
}

/// Evaluates organizations on the context's execution engine.  Results
/// come back in input order, bit-identical for any worker count.
/// `timeline` is the org-independent simulated timeline of the same
/// profile (build it once with [`sim::Timeline::build`]).
pub fn evaluate_all(
    ctx: &EvalCtx,
    orgs: &[Organization],
    profile: &NetworkProfile,
    timeline: &sim::Timeline,
) -> Vec<DsePoint> {
    ctx.engine()
        .map(orgs, |o| eval_one(o, profile, ctx.tech(), timeline))
}

fn eval_one(
    org: &Organization,
    profile: &NetworkProfile,
    tech: &Technology,
    timeline: &sim::Timeline,
) -> DsePoint {
    // Fast path (see dse::evaluate): identical numbers to
    // energy::evaluate_org, ~10x cheaper — pinned by
    // evaluate::tests::fast_matches_reference.
    let (area_mm2, energy_j, latency_s) =
        evaluate::area_energy_latency(org, profile, tech, timeline);
    DsePoint {
        org: org.clone(),
        area_mm2,
        energy_j,
        latency_s,
    }
}

/// Indices of the Pareto-optimal points (area, energy and latency
/// minimization — 3-D since the timeline simulator; identical latencies
/// reduce it to the paper's 2-D area/energy frontier).
pub fn pareto_indices(points: &[DsePoint]) -> Vec<usize> {
    let ps: Vec<Point3> = points
        .iter()
        .enumerate()
        .map(|(i, p)| Point3::new(p.area_mm2, p.energy_j, p.latency_s, i))
        .collect();
    frontier3(&ps)
}

/// Per-design-option lowest-energy selection (the Table I/II rule:
/// "for each design option ... the Pareto-optimal solutions with
/// lowest-energy are selected").
pub fn select_per_option(points: &[DsePoint]) -> Vec<(String, usize)> {
    let mut best: [Option<usize>; 6] = [None; 6];
    for (i, p) in points.iter().enumerate() {
        let slot = &mut best[p.option().index()];
        match *slot {
            Some(j) if points[j].energy_j <= p.energy_j => {}
            _ => *slot = Some(i),
        }
    }
    DesignOption::ALL
        .iter()
        .zip(best)
        .filter_map(|(o, b)| b.map(|i| (o.label().to_string(), i)))
        .collect()
}

/// Convenience: the full DSE for one network profile.
///
/// Since the branch-and-bound sweep, `points` holds only the *surviving*
/// candidates — configurations whose subtree the lower bound could not
/// cull.  The frontier (`pareto`) and per-option selection (`selected`)
/// over the survivors are bit-identical to the exhaustive sweep's (pinned
/// by `rust/tests/prune_exact.rs`); `stats` says how much of the space was
/// culled without evaluation.
pub struct DseResult {
    pub points: Vec<DsePoint>,
    pub pareto: Vec<usize>,
    pub selected: Vec<(String, usize)>,
    /// Configurations dropped by the latency budget (0 when unconstrained).
    pub excluded_by_budget: usize,
    /// Branch-and-bound counters (enumerated / pruned / evaluated / ...).
    pub stats: stream::SweepStats,
}

/// The full pipeline: enumerate → evaluate (engine-parallel) → Pareto →
/// per-option selection, under the context's optional hard per-inference
/// latency budget ([`crate::ctx::Budget::latency_budget_s`]):
/// configurations whose simulated latency exceeds the budget are excluded
/// before Pareto extraction and per-option selection.  Errors when the
/// budget excludes every configuration (reporting the fastest achievable
/// latency) or is not a positive finite number (the builder already
/// rejects such budgets; this guards direct [`crate::ctx::Budget`]
/// construction).
pub fn run(ctx: &EvalCtx, profile: &NetworkProfile) -> Result<DseResult> {
    let latency_budget_s = ctx.budget().latency_budget_s;
    if let Some(budget) = latency_budget_s {
        ensure!(
            budget.is_finite() && budget > 0.0,
            "latency budget must be a positive duration, got {budget} s"
        );
    }
    let timeline = sim::Timeline::build(profile, ctx.tech(), ctx.accel());
    let subtrees = stream::subtrees(profile)?;
    let ev = stream::SingleNet {
        profile,
        tech: ctx.tech(),
        timeline: &timeline,
    };
    let out = stream::sweep(ctx, &subtrees, &ev);
    if let Some(budget) = latency_budget_s {
        if out.points.is_empty() {
            // All-excluded ⟹ nothing ever entered the archive ⟹ zero
            // pruning, so `enumerated` and `fastest` cover the full space —
            // the message is identical to the exhaustive sweep's.
            bail!(
                "latency budget {:.4} ms excludes all {} configurations of '{}' \
                 (fastest achievable: {:.4} ms)",
                budget * 1e3,
                out.stats.enumerated,
                profile.network,
                out.fastest * 1e3
            );
        }
    }
    let pareto = pareto_indices(&out.points);
    let selected = select_per_option(&out.points);
    Ok(DseResult {
        points: out.points,
        pareto,
        selected,
        excluded_by_budget: out.excluded,
        stats: out.stats,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Accelerator;
    use crate::dataflow::profile_network;
    use crate::model::capsnet_mnist;
    use crate::util::units::KIB;

    fn profile() -> NetworkProfile {
        profile_network(&capsnet_mnist(), &Accelerator::default())
    }

    fn timeline(p: &NetworkProfile) -> sim::Timeline {
        sim::Timeline::build(p, &Technology::default(), &Accelerator::default())
    }

    fn ctx(threads: usize) -> EvalCtx {
        EvalCtx::new(Technology::default(), Accelerator::default()).threads(threads)
    }

    #[test]
    fn eq1_eq2_reproduce_table_i() {
        let p = profile();
        assert_eq!(sep_sizes(&p), (25 * KIB, 64 * KIB, 32 * KIB));
        assert_eq!(smp_size(&p), 108 * KIB);
    }

    #[test]
    fn hy_shared_size_boundaries() {
        let p = profile();
        // Dedicated memories at SEP sizes -> nothing spills -> shared = 0.
        let (d, w, a) = sep_sizes(&p);
        assert_eq!(hy_shared_size(&p, d, w, a).unwrap(), 0);
        // No dedicated memories -> shared covers the SMP worst case.
        assert_eq!(hy_shared_size(&p, 0, 0, 0).unwrap(), 108 * KIB);
        // Partial coverage -> something in between.
        let s = hy_shared_size(&p, 8 * KIB, 32 * KIB, 16 * KIB).unwrap();
        assert!(s > 0 && s < 108 * KIB, "{s}");
    }

    #[test]
    fn enumeration_covers_all_design_options() {
        let p = profile();
        let orgs = enumerate(&p).unwrap();
        let opts: std::collections::BTreeSet<String> = orgs
            .iter()
            .map(|o| {
                format!(
                    "{}{}",
                    o.kind.label(),
                    if o.power_gated() { "-PG" } else { "" }
                )
            })
            .collect();
        for want in ["SMP", "SMP-PG", "SEP", "SEP-PG", "HY", "HY-PG"] {
            assert!(opts.contains(want), "missing {want}");
        }
        // Same order of magnitude as the paper's 15,233 CapsNet configs.
        assert!(
            orgs.len() > 3_000 && orgs.len() < 150_000,
            "{} configs",
            orgs.len()
        );
    }

    #[test]
    fn every_enumerated_org_fits_the_profile() {
        let p = profile();
        for org in enumerate(&p).unwrap() {
            assert!(crate::memory::org_fits(&org, &p), "{:?}", org.label());
        }
    }

    #[test]
    fn evaluation_is_deterministic_and_parallel_consistent() {
        let p = profile();
        let tl = timeline(&p);
        let orgs: Vec<_> = enumerate(&p).unwrap().into_iter().take(300).collect();
        let seq = evaluate_all(&ctx(1), &orgs, &p, &tl);
        let par = evaluate_all(&ctx(4), &orgs, &p, &tl);
        assert_eq!(seq.len(), par.len());
        for (a, b) in seq.iter().zip(&par) {
            assert_eq!(a.org, b.org);
            assert!((a.energy_j - b.energy_j).abs() < 1e-15);
            assert!((a.area_mm2 - b.area_mm2).abs() < 1e-12);
            assert_eq!(a.latency_s.to_bits(), b.latency_s.to_bits());
        }
    }

    #[test]
    fn selected_sep_matches_table_i_and_frontier_shape() {
        let p = profile();
        let res = run(&ctx(4), &p).unwrap();
        let sel: std::collections::BTreeMap<_, _> = res.selected.iter().cloned().collect();

        // SEP selection == Table I sizes by construction.
        let sep = &res.points[sel["SEP"]];
        assert_eq!(sep.org.data.unwrap().size, 25 * KIB);
        assert_eq!(sep.org.weight.unwrap().size, 64 * KIB);
        assert_eq!(sep.org.acc.unwrap().size, 32 * KIB);

        // Paper Fig 18: HY-PG is the lowest-energy option overall...
        let hy_pg = &res.points[sel["HY-PG"]];
        for (name, &i) in &sel {
            assert!(
                hy_pg.energy_j <= res.points[i].energy_j + 1e-15,
                "HY-PG not lowest energy vs {name}"
            );
        }
        // ... SMP designs are dominated (not on the frontier) ...
        let pareto_opts: std::collections::BTreeSet<String> = res
            .pareto
            .iter()
            .map(|&i| res.points[i].option().to_string())
            .collect();
        assert!(!pareto_opts.contains("SMP"), "SMP on frontier");
        // ... and some SEP/SEP-PG/HY-PG configuration is on the frontier.
        assert!(
            pareto_opts.contains("SEP")
                || pareto_opts.contains("SEP-PG")
                || pareto_opts.contains("HY-PG"),
            "frontier options: {pareto_opts:?}"
        );
    }

    #[test]
    fn select_per_option_breaks_energy_ties_toward_first_index() {
        let org = Organization::smp(MemSpec::new(108 * KIB, 1));
        let mk = |area: f64, energy: f64| DsePoint {
            org: org.clone(),
            area_mm2: area,
            energy_j: energy,
            latency_s: 8.6e-3,
        };
        // Equal energies: the earliest index must win, deterministically.
        let tied = vec![mk(2.0, 1.0), mk(1.0, 1.0)];
        assert_eq!(select_per_option(&tied), vec![("SMP".to_string(), 0)]);
        // A strictly lower energy later in the list still wins.
        let better_late = vec![mk(2.0, 1.0), mk(1.0, 1.0), mk(3.0, 0.5)];
        assert_eq!(
            select_per_option(&better_late),
            vec![("SMP".to_string(), 2)]
        );
    }

    #[test]
    fn empty_point_sets_are_handled() {
        assert!(select_per_option(&[]).is_empty());
        assert!(pareto_indices(&[]).is_empty());
        let p = profile();
        let tl = timeline(&p);
        assert!(evaluate_all(&ctx(4), &[], &p, &tl).is_empty());
    }

    #[test]
    fn engine_and_serial_selection_agree() {
        // The engine-parallel pipeline must reproduce the serial pipeline
        // exactly — points, frontier and selection (satellite of ISSUE 1;
        // the full-enumeration bit-equality pin lives in
        // rust/tests/engine_cache.rs).
        let p = profile();
        let tl = timeline(&p);
        let orgs: Vec<_> = enumerate(&p).unwrap().into_iter().take(800).collect();
        let serial = evaluate_all(&ctx(1), &orgs, &p, &tl);
        let parallel = evaluate_all(&ctx(4), &orgs, &p, &tl);
        for (a, b) in serial.iter().zip(&parallel) {
            assert_eq!(a.org, b.org);
            assert_eq!(a.area_mm2.to_bits(), b.area_mm2.to_bits());
            assert_eq!(a.energy_j.to_bits(), b.energy_j.to_bits());
            assert_eq!(a.latency_s.to_bits(), b.latency_s.to_bits());
        }
        assert_eq!(select_per_option(&serial), select_per_option(&parallel));
        assert_eq!(pareto_indices(&serial), pareto_indices(&parallel));
    }

    #[test]
    fn pg_variant_always_saves_energy_at_same_sizes() {
        let p = profile();
        let tech = Technology::default();
        let tl = timeline(&p);
        let (d, w, a) = sep_sizes(&p);
        let base = eval_one(
            &Organization::sep(
                MemSpec::new(d, 1),
                MemSpec::new(w, 1),
                MemSpec::new(a, 1),
            ),
            &p,
            &tech,
            &tl,
        );
        let pg = eval_one(
            &Organization::sep(
                MemSpec::new(d, 2),
                MemSpec::new(w, 8),
                MemSpec::new(a, 2),
            ),
            &p,
            &tech,
            &tl,
        );
        assert!(pg.energy_j < base.energy_j);
        assert!(pg.area_mm2 > base.area_mm2); // PG costs area
        // ... at identical latency: the paper's "no performance loss".
        assert_eq!(pg.latency_s.to_bits(), base.latency_s.to_bits());
    }

    #[test]
    fn latency_is_uniform_across_orgs_at_paper_constants() {
        // Wakeups mask at 0.072 ns, so every organization's latency equals
        // the org-independent timeline — the 3-D frontier degenerates to
        // the paper's 2-D one.
        let p = profile();
        let tl = timeline(&p);
        let orgs: Vec<_> = enumerate(&p).unwrap().into_iter().take(500).collect();
        let points = evaluate_all(&ctx(4), &orgs, &p, &tl);
        let expect = tl.inference_latency_s();
        for pt in &points {
            assert_eq!(pt.latency_s.to_bits(), expect.to_bits(), "{}", pt.org.label());
        }
    }

    #[test]
    fn budget_below_fastest_errors_and_above_keeps_everything() {
        let p = profile();
        let tight = ctx(2).latency_budget_s(Some(1e-9)).unwrap();
        let err = run(&tight, &p).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("excludes all"), "{msg}");
        assert!(msg.contains("fastest achievable"), "{msg}");

        let loose = run(&ctx(2).latency_budget_s(Some(1.0)).unwrap(), &p).unwrap();
        let unconstrained = run(&ctx(2), &p).unwrap();
        assert_eq!(loose.points.len(), unconstrained.points.len());
        assert_eq!(loose.excluded_by_budget, 0);
        assert_eq!(loose.selected, unconstrained.selected);

        // Malformed budgets never reach the sweep: the context builder
        // rejects them at construction (rust/tests/ctx.rs pins messages).
        assert!(ctx(2).latency_budget_s(Some(f64::NAN)).is_err());
        assert!(ctx(2).latency_budget_s(Some(-1.0)).is_err());
    }

    #[test]
    fn port_constrained_enumeration_is_nonempty_and_valid() {
        let p = profile();
        let one_port = enumerate_hy_ports(&p, 1).unwrap();
        assert!(!one_port.is_empty());
        for org in &one_port {
            assert_eq!(org.shared_ports, 1);
            assert!(required_shared_ports(org, &p) <= 1);
        }
        // More ports admit at least as many configurations.
        let two_port = enumerate_hy_ports(&p, 2).unwrap();
        assert!(two_port.len() >= one_port.len());
    }

    #[test]
    fn pareto_members_not_dominated() {
        let p = profile();
        let orgs: Vec<_> = enumerate(&p).unwrap().into_iter().take(2_000).collect();
        let points = evaluate_all(&ctx(4), &orgs, &p, &timeline(&p));
        let front = pareto_indices(&points);
        assert!(!front.is_empty());
        for &i in &front {
            for (j, q) in points.iter().enumerate() {
                if i != j {
                    let dominated = q.area_mm2 <= points[i].area_mm2
                        && q.energy_j <= points[i].energy_j
                        && (q.area_mm2 < points[i].area_mm2
                            || q.energy_j < points[i].energy_j);
                    assert!(!dominated, "{i} dominated by {j}");
                }
            }
        }
    }
}
