//! DESCNet: scratchpad-memory design-space exploration for Capsule-Network
//! accelerators — reproduction of Marchisio et al., IEEE TCAD 2020.
//!
//! See `rust/DESIGN.md` for the system inventory (section 5 covers the
//! shared execution engine `util::exec` and the memoized CACTI cost cache
//! `cacti::cache` every evaluation layer goes through, section 17 the
//! unified evaluation context `ctx` every entry point takes) and
//! `rust/EXPERIMENTS.md` for the paper-vs-measured record.

// The public `ctx` API is fully documented; legacy modules predate the
// missing_docs gate and are allow-listed item-by-item below until their
// public surfaces are documented too (ISSUE 10 satellite).
#![warn(missing_docs)]

#[allow(missing_docs)]
pub mod accel;
#[allow(missing_docs)]
pub mod analysis;
#[allow(missing_docs)]
pub mod cacti;
#[allow(missing_docs)]
pub mod config;
#[allow(missing_docs)]
pub mod coordinator;
pub mod ctx;
#[allow(missing_docs)]
pub mod dataflow;
#[allow(missing_docs)]
pub mod dse;
#[allow(missing_docs)]
pub mod energy;
#[allow(missing_docs)]
pub mod fleet;
#[allow(missing_docs)]
pub mod memory;
#[allow(missing_docs)]
pub mod model;
#[allow(missing_docs)]
pub mod pmu;
#[allow(missing_docs)]
pub mod report;
#[allow(missing_docs)]
pub mod runtime;
#[allow(missing_docs)]
pub mod sim;
#[allow(missing_docs)]
pub mod util;
