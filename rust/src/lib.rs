//! DESCNet: scratchpad-memory design-space exploration for Capsule-Network
//! accelerators — reproduction of Marchisio et al., IEEE TCAD 2020.
//!
//! See `rust/DESIGN.md` for the system inventory (section 5 covers the
//! shared execution engine `util::exec` and the memoized CACTI cost cache
//! `cacti::cache` every evaluation layer goes through) and
//! `rust/EXPERIMENTS.md` for the paper-vs-measured record.

pub mod accel;
pub mod analysis;
pub mod cacti;
pub mod config;
pub mod coordinator;
pub mod dataflow;
pub mod dse;
pub mod energy;
pub mod fleet;
pub mod memory;
pub mod model;
pub mod pmu;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod util;
