//! Unified evaluation context (DESIGN.md section 17).
//!
//! Every evaluation layer — `dse`, `dse::multi`, `dse::stream`,
//! `dse::heuristic`, `fleet`, `report`, the coordinator's co-design path —
//! used to thread the same tuple of shared state positionally: an
//! execution [`Engine`], a [`Technology`], an [`Accelerator`], a thread
//! count, a batch size, an optional latency budget.  Each widening of that
//! tuple rippled through dozens of call sites as an arity break.
//!
//! [`EvalCtx`] is the one bundle every entry point takes instead
//! (`dse::run(&ctx, &profile)`, `fleet::design_fleet(&ctx, ...)`,
//! `report::*` through [`crate::report::ReportCtx`]).  It carries:
//!
//! * the shared parallel [`Engine`] (`util::exec`, DESIGN.md section 5) —
//!   one engine per command, so thread-count determinism is a property of
//!   the context, not of each call site;
//! * the [`SystemConfig`] (technology constants + accelerator geometry);
//! * the process-global CACTI cost-cache handle ([`CostCache`]) the deep
//!   evaluation layers memoize through;
//! * a [`Budget`] of per-run options: batch size, optional hard latency
//!   budget, stats toggle.
//!
//! Construction is a chained builder whose defaults are exactly the CLI's
//! historical defaults, so `EvalCtx::new(tech, accel)` behaves like
//! `descnet <cmd>` with no flags:
//!
//! ```
//! use descnet::config::{Accelerator, Technology};
//! use descnet::ctx::EvalCtx;
//!
//! let ctx = EvalCtx::new(Technology::default(), Accelerator::default())
//!     .threads(2)
//!     .batch(1)
//!     .latency_budget_s(Some(15e-3))
//!     .expect("a positive finite budget");
//! assert_eq!(ctx.engine().threads(), 2);
//! ```
//!
//! Adding a future evaluation knob means adding a [`Budget`] field plus a
//! builder method — no entry-point signature changes, no arity ripple.

use anyhow::{ensure, Result};

use crate::cacti::cache::{self, CostCache};
use crate::config::{Accelerator, SystemConfig, Technology};
use crate::util::exec::Engine;

/// Per-run evaluation options, bundled so new knobs never widen an entry
/// point's signature.  Defaults match the CLI's no-flag behavior.
#[derive(Debug, Clone, PartialEq)]
pub struct Budget {
    /// Inference batch size profiles are built at (CLI `--batch`; 1 =
    /// single-inference, the paper's configuration).
    pub batch: usize,
    /// Optional hard per-inference latency budget [s] (CLI
    /// `--latency-budget`, which takes milliseconds): configurations whose
    /// simulated latency exceeds it are excluded before Pareto extraction
    /// and per-option selection.  `None` = unconstrained.
    pub latency_budget_s: Option<f64>,
    /// Whether to report sweep diagnostics (CLI `--stats`): branch-and-bound
    /// counters, evaluator wall-time split, cost-cache hit rates.
    pub stats: bool,
}

impl Default for Budget {
    fn default() -> Budget {
        Budget {
            batch: 1,
            latency_budget_s: None,
            stats: false,
        }
    }
}

/// The shared evaluation context: engine + system configuration + CACTI
/// cost-cache handle + per-run [`Budget`].  Built once per command (or
/// test) and passed by reference to every evaluation entry point.
#[derive(Clone)]
pub struct EvalCtx {
    engine: Engine,
    cfg: SystemConfig,
    cache: &'static CostCache,
    budget: Budget,
}

impl EvalCtx {
    /// A context over the given technology and accelerator with the CLI's
    /// defaults: an [`Engine::auto`] sized to the machine, batch 1, no
    /// latency budget, stats off, and the process-global cost cache.
    pub fn new(tech: Technology, accel: Accelerator) -> EvalCtx {
        EvalCtx::for_config(&SystemConfig { tech, accel })
    }

    /// [`EvalCtx::new`] over a bundled [`SystemConfig`] (the shape the CLI
    /// loads from `--config` files).
    pub fn for_config(cfg: &SystemConfig) -> EvalCtx {
        EvalCtx {
            engine: Engine::auto(),
            cfg: cfg.clone(),
            cache: cache::global(),
            budget: Budget::default(),
        }
    }

    /// Replaces the engine with one of `n` workers (clamped to at least 1,
    /// like the CLI's `--threads`).
    pub fn threads(mut self, n: usize) -> EvalCtx {
        self.engine = Engine::new(n);
        self
    }

    /// Sets the inference batch size (CLI `--batch`).
    pub fn batch(mut self, batch: usize) -> EvalCtx {
        self.budget.batch = batch;
        self
    }

    /// Sets (or clears, with `None`) the hard latency budget [s].
    ///
    /// Validation happens here, at construction — not deep inside a sweep —
    /// so every downstream consumer may assume a well-formed budget.
    /// Errors on a NaN, infinite, zero or negative duration.
    pub fn latency_budget_s(mut self, budget: Option<f64>) -> Result<EvalCtx> {
        if let Some(b) = budget {
            ensure!(
                b.is_finite() && b > 0.0,
                "latency budget must be a positive duration, got {b} s"
            );
        }
        self.budget.latency_budget_s = budget;
        Ok(self)
    }

    /// Toggles sweep diagnostics (CLI `--stats`).
    pub fn stats(mut self, on: bool) -> EvalCtx {
        self.budget.stats = on;
        self
    }

    /// The shared parallel execution engine.
    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// The full system configuration (technology + accelerator).
    pub fn config(&self) -> &SystemConfig {
        &self.cfg
    }

    /// The technology constants (CACTI anchors, DRAM, MAC energies).
    pub fn tech(&self) -> &Technology {
        &self.cfg.tech
    }

    /// The accelerator geometry (array, clock, SPM banking, tiling).
    pub fn accel(&self) -> &Accelerator {
        &self.cfg.accel
    }

    /// The memoized CACTI cost cache this context's evaluations go
    /// through.  Today this is always the process-global cache
    /// (`cacti::cache::global`) — the handle exists so diagnostics
    /// (`--stats` hit rates) and any future per-context cache read the
    /// same object the deep layers write.
    pub fn cache(&self) -> &'static CostCache {
        self.cache
    }

    /// The per-run options bundle.
    pub fn budget(&self) -> &Budget {
        &self.budget
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::exec;

    #[test]
    fn defaults_match_cli_defaults() {
        // The no-flag CLI: threads = available parallelism, batch 1, no
        // budget, stats off (rust/tests/ctx.rs pins this contract too).
        let ctx = EvalCtx::new(Technology::default(), Accelerator::default());
        assert_eq!(ctx.engine().threads(), exec::default_threads());
        assert_eq!(ctx.budget().batch, 1);
        assert_eq!(ctx.budget().latency_budget_s, None);
        assert!(!ctx.budget().stats);
        assert_eq!(ctx.config(), &SystemConfig::default());
    }

    #[test]
    fn builder_sets_every_knob() {
        let ctx = EvalCtx::for_config(&SystemConfig::default())
            .threads(3)
            .batch(8)
            .stats(true)
            .latency_budget_s(Some(20e-3))
            .unwrap();
        assert_eq!(ctx.engine().threads(), 3);
        assert_eq!(ctx.budget().batch, 8);
        assert!(ctx.budget().stats);
        assert_eq!(ctx.budget().latency_budget_s, Some(20e-3));
    }

    #[test]
    fn invalid_budgets_rejected_at_construction() {
        let mk = || EvalCtx::new(Technology::default(), Accelerator::default());
        for bad in [f64::NAN, f64::INFINITY, 0.0, -1.0] {
            let err = mk().latency_budget_s(Some(bad)).err();
            assert!(err.is_some(), "budget {bad} accepted");
        }
        assert!(mk().latency_budget_s(None).is_ok());
        assert!(mk().latency_budget_s(Some(1e-3)).is_ok());
    }

    #[test]
    fn cache_handle_is_the_global_cache() {
        let ctx = EvalCtx::new(Technology::default(), Accelerator::default());
        assert!(std::ptr::eq(ctx.cache(), cache::global()));
    }
}
