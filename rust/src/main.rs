//! `descnet` CLI — leader entrypoint.
//!
//! Subcommands:
//!   analyze   print the per-operation workload profile (Figs 1/9/10/11)
//!   dse       run the design-space exploration (Figs 18/20/22, Tables I/II);
//!             with a multi-network workload set (--workload / --random /
//!             comma-separated --net) it runs the co-design stage (dse::multi)
//!   report    regenerate paper figures/tables into results/ (see DESIGN.md E-index)
//!   serve     serve CapsNet inference via the PJRT runtime + coordinator
//!   headline  print the paper-vs-ours headline metrics
//!   lint      run the in-repo invariant analyzer over the repo's sources

use std::path::PathBuf;

use descnet::accel;
use descnet::config::SystemConfig;
use descnet::coordinator::server::{ServeOptions, Server};
use descnet::ctx::EvalCtx;
use descnet::dataflow::{profile_network_batched, NetworkProfile};
use descnet::dse::multi::WorkloadSet;
use descnet::fleet;
use descnet::model::{self, Network};
use descnet::report::{self, ReportCtx};
use descnet::sim;
use descnet::util::exec;
use descnet::util::table::Table;
use descnet::util::units::{fmt_count, fmt_size, fmt_time};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let rest = if args.is_empty() { &args[..] } else { &args[1..] };
    let code = match cmd {
        "analyze" => cmd_analyze(rest),
        "dse" => cmd_dse(rest),
        "fleet" => cmd_fleet(rest),
        "report" => cmd_report(rest),
        "serve" => cmd_serve(rest),
        "headline" => cmd_headline(rest),
        "lint" => cmd_lint(rest),
        "config" => cmd_config(rest),
        "help" | "--help" | "-h" => {
            print_help();
            0
        }
        other => {
            eprintln!("unknown command '{other}'\n");
            print_help();
            2
        }
    };
    std::process::exit(code);
}

fn print_help() {
    println!(
        "descnet — DESCNet scratchpad-memory DSE for CapsNet accelerators\n\n\
         USAGE: descnet <command> [options]\n\n\
         COMMANDS:\n\
           analyze  [--net capsnet|deepcaps] [--workload FILE] [--batch B] [--sim]\n\
                    per-op workload profile; --sim adds the event-level phase\n\
                    breakdown and the DMA/compute timeline (busy vs stall)\n\
           dse      [--net NAME[,NAME...]] [--workload FILE] [--random N] [--seed S]\n\
                    [--batch B] [--mix W1,W2,...] [--traffic-weighted] [--ports]\n\
                    [--latency-budget MS] [--stats] [--threads N] [--out DIR]\n\
                    single-network DSE, or (with a multi-network workload set)\n\
                    the dse::multi co-design stage: one organization across\n\
                    every network, per-network energy reported.  The objective\n\
                    space is 3-D (area, energy, simulated latency);\n\
                    --latency-budget MS drops configurations over budget\n\
           fleet    [--shards N] [--rps R] [--requests N] [--policy rr|jsq|energy]\n\
                    [--slo-ms MS] [--seed S] [--batch-max B] [--homogeneous]\n\
                    [--net NAME[,NAME...]] [--threads N] [--out DIR]\n\
                    [--mtbf-s S|inf] [--mttr-s S] [--timeout-ms MS] [--retries K]\n\
                    [--hedge-ms MS] [--fault-seed S] [--crash-policy requeue|drop]\n\
                    [--fault-budget F [--attainment FRAC]]\n\
                    sharded fleet serving simulation: SLO-constrained per-shard\n\
                    SPM co-design (vs the homogeneous union-SMP baseline) +\n\
                    seeded discrete-event simulation with p50/p95/p99, SLO\n\
                    attainment, energy/request and shard utilization rollups.\n\
                    Fault injection: seeded per-shard crash/recover schedules\n\
                    (--mtbf-s/--mttr-s), per-request timeout + bounded retry\n\
                    with exponential backoff (--timeout-ms/--retries), hedged\n\
                    re-dispatch (--hedge-ms); --fault-budget F provisions the\n\
                    fleet N+F so degraded attainment stays over --attainment\n\
           report   [all|fig1|fig7|fig9|fig10|fig11|fig12|fig18|fig19|fig20|fig21|\n\
                     fig22|fig23|fig25|fig27|fig29|fig30|fig31|multi|fleet|table3|headline]\n\
                    [--out DIR] [--threads N] [--config FILE]\n\
           serve    [--artifacts DIR] [--requests N] [--batch-max B] [--stage-pipeline]\n\
                    [--slo-ms MS]  (batch sizes whose simulated batch latency\n\
                    exceeds the SLO are never scheduled)\n\
           headline [--threads N]                           paper-vs-ours summary\n\
           lint     [--root DIR] [--format table|json]\n\
                    in-repo static analyzer enforcing the determinism, NaN-safety\n\
                    and panic-freedom invariants (DESIGN.md section 16); exits\n\
                    non-zero on any finding — suppression is inline-only\n\
                    (lint: allow(rule, reason)), there is no baseline file\n\
           config   [--save FILE] [--config FILE]           print/snapshot the technology config\n\n\
         WORKLOAD FILES (configs/workloads/*.json): a single network spec\n\
         ({{name, input, layers}}) or a set ({{networks: [...], weights: [...]}});\n\
         layer types: conv, primary_caps, conv_caps2d, caps_cell, conv_caps3d,\n\
         pool_caps, class_caps, routing.  --random N appends N seeded random\n\
         NASCaps-style networks; --batch B profiles every network at batch B."
    );
}

/// Tiny flag parser: `--key value` pairs plus positional words.
struct Flags {
    positional: Vec<String>,
    kv: std::collections::BTreeMap<String, String>,
}

fn parse_flags(args: &[String]) -> Flags {
    let mut positional = Vec::new();
    let mut kv = std::collections::BTreeMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                kv.insert(key.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                kv.insert(key.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            positional.push(args[i].clone());
            i += 1;
        }
    }
    Flags { positional, kv }
}

impl Flags {
    fn get(&self, key: &str, default: &str) -> String {
        self.kv
            .get(key)
            .cloned()
            .unwrap_or_else(|| default.to_string())
    }

    /// Strict integer flag: absent -> default, present-but-malformed ->
    /// error (a typo must not silently fall back to the default).
    fn usize(&self, key: &str, default: usize) -> anyhow::Result<usize> {
        match self.kv.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects a non-negative integer, got '{v}'")),
        }
    }

    /// Strict float flag with a default (e.g. `--rps R`).
    fn f64(&self, key: &str, default: f64) -> anyhow::Result<f64> {
        Ok(self.f64_opt(key)?.unwrap_or(default))
    }

    /// Strict optional float flag (e.g. `--latency-budget MS`).
    fn f64_opt(&self, key: &str) -> anyhow::Result<Option<f64>> {
        match self.kv.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse::<f64>()
                .map(Some)
                .map_err(|_| anyhow::anyhow!("--{key} expects a number, got '{v}'")),
        }
    }

    fn has(&self, key: &str) -> bool {
        self.kv.contains_key(key)
    }

    /// Rejects unrecognized `--flags`, listing the command's known set — a
    /// typo like `--lateny-budget` must not silently run an unbudgeted
    /// sweep with the flag ignored.
    fn check_known(&self, known: &[&str]) -> anyhow::Result<()> {
        for key in self.kv.keys() {
            if !known.contains(&key.as_str()) {
                anyhow::bail!(
                    "unknown flag --{key}; known flags: {}",
                    known
                        .iter()
                        .map(|k| format!("--{k}"))
                        .collect::<Vec<_>>()
                        .join(", ")
                );
            }
        }
        Ok(())
    }
}

/// Unwraps a strict flag parse or exits with usage code 2.
macro_rules! try_flag {
    ($expr:expr) => {
        match $expr {
            Ok(v) => v,
            Err(e) => {
                eprintln!("{e:#}");
                return 2;
            }
        }
    };
}

fn load_config(flags: &Flags) -> SystemConfig {
    match flags.kv.get("config") {
        Some(path) => SystemConfig::load(std::path::Path::new(path)).unwrap_or_else(|e| {
            eprintln!("failed to load config {path}: {e:#}");
            std::process::exit(2);
        }),
        None => SystemConfig::default(),
    }
}

/// Collects the workload set a command names: `--net a,b,...` builtins,
/// `--workload FILE` specs, `--random N` generated networks.  Also returns
/// the spec file's mix weights, if any.
fn collect_networks(flags: &Flags) -> anyhow::Result<(Vec<Network>, Option<Vec<f64>>)> {
    let mut nets = Vec::new();
    let mut weights: Option<Vec<f64>> = None;
    if let Some(list) = flags.kv.get("net") {
        for name in list.split(',').filter(|s| !s.is_empty()) {
            nets.push(model::spec::builtin(name)?);
        }
    }
    if let Some(path) = flags.kv.get("workload") {
        let spec = model::spec::load(std::path::Path::new(path))?;
        if nets.is_empty() {
            weights = spec.weights;
        } else if spec.weights.is_some() {
            anyhow::bail!("--workload weights cannot be combined with --net networks");
        }
        nets.extend(spec.networks);
    }
    if let Some(n) = flags.kv.get("random") {
        let n: usize = n
            .parse()
            .map_err(|_| anyhow::anyhow!("--random expects a count, got '{n}'"))?;
        let seed = flags.usize("seed", 1)? as u64;
        if weights.is_some() {
            anyhow::bail!("--random cannot be combined with explicit workload weights");
        }
        nets.extend(model::random_networks(n, seed));
    }
    if nets.is_empty() {
        nets.push(model::capsnet_mnist());
    }
    Ok((nets, weights))
}

fn cmd_analyze(args: &[String]) -> i32 {
    let flags = parse_flags(args);
    try_flag!(flags.check_known(&[
        "batch", "config", "net", "random", "seed", "sim", "workload",
    ]));
    let cfg = load_config(&flags);
    let batch = try_flag!(flags.usize("batch", 1));
    let (nets, _) = match collect_networks(&flags) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("analyze failed: {e:#}");
            return 2;
        }
    };
    for network in &nets {
        let p = profile_network_batched(network, &cfg.accel, batch);
        let mut table = Table::new(&[
            "op", "group", "cycles", "D usage", "W usage", "A usage", "off rd", "off wr",
        ]);
        for op in &p.ops {
            table.row(vec![
                op.name.to_string(),
                op.group.label().to_string(),
                fmt_count(op.cycles),
                fmt_size(op.usage_d),
                fmt_size(op.usage_w),
                fmt_size(op.usage_a),
                fmt_size(op.off_rd as usize),
                fmt_size(op.off_wr as usize),
            ]);
        }
        println!("== {} (batch {batch}) ==", network.name);
        println!("{}", table.to_ascii());
        println!(
            "total: {} cycles/batch  ->  {:.1} fps @ {:.0} MHz (paper: {} fps at batch 1)",
            fmt_count(p.total_cycles()),
            p.fps(),
            cfg.accel.clock_hz / 1e6,
            network.paper_fps,
        );
        println!(
            "maxima: D {}  W {}  A {}  SMP {}",
            fmt_size(p.max_d()),
            fmt_size(p.max_w()),
            fmt_size(p.max_a()),
            fmt_size(p.max_total()),
        );
        if flags.has("sim") {
            // Event-level simulation: phase breakdown + closed-form validation.
            let mut t = Table::new(&["op", "compute", "w-stream", "drain", "normalize", "util"]);
            for sim in accel::sim_network(network, &cfg.accel) {
                t.row(vec![
                    sim.name.clone(),
                    fmt_count(sim.compute),
                    fmt_count(sim.weight_stream),
                    fmt_count(sim.drain),
                    fmt_count(sim.normalization),
                    format!("{:.1}%", 100.0 * sim.utilization()),
                ]);
            }
            println!("{}", t.to_ascii());
            println!(
                "event-sim vs closed form: max disagreement {:.2}%",
                100.0 * accel::validate_network(network, &cfg.accel)
            );

            // DMA/compute timeline (DESIGN.md section 11): busy vs stall.
            let tl = sim::Timeline::build(&p, &cfg.tech, &cfg.accel);
            let mut tt = Table::new(&["op", "start", "compute", "dma", "dma-stall", "bound"]);
            for op in &tl.ops {
                tt.row(vec![
                    op.name.to_string(),
                    fmt_count(op.start_cycle),
                    fmt_count(op.compute_cycles),
                    fmt_count(op.dma_cycles),
                    fmt_count(op.dma_stall_cycles),
                    match op.bound() {
                        sim::Bound::Compute => "compute".to_string(),
                        sim::Bound::Dma => "dma".to_string(),
                    },
                ]);
            }
            println!("{}", tt.to_ascii());
            println!(
                "timeline: {} cycles/batch ({} compute + {} dma-stall)  ->  \
                 {:.3} ms/inference at {:.1} GB/s effective fill bandwidth \
                 (+ one-time cold-start fill: {} cycles before the first frame)",
                fmt_count(tl.total_cycles()),
                fmt_count(tl.compute_cycles()),
                fmt_count(tl.dma_stall_cycles()),
                tl.inference_latency_s() * 1e3,
                tl.effective_fill_bps / 1e9,
                fmt_count(tl.cold_fill_cycles),
            );
        }
    }
    0
}

fn cmd_dse(args: &[String]) -> i32 {
    let flags = parse_flags(args);
    try_flag!(flags.check_known(&[
        "batch",
        "config",
        "latency-budget",
        "mix",
        "net",
        "out",
        "ports",
        "random",
        "seed",
        "stats",
        "threads",
        "traffic-weighted",
        "workload",
    ]));
    let cfg = load_config(&flags);
    let out = PathBuf::from(flags.get("out", "results"));
    let threads = try_flag!(flags.usize("threads", exec::default_threads()));
    let batch = try_flag!(flags.usize("batch", 1));
    let latency_budget_s = try_flag!(flags.f64_opt("latency-budget")).map(|ms| ms * 1e-3);
    // Budget validation lives in the EvalCtx builder; keep the CLI's exact
    // diagnostic for a malformed value.
    let eval = match EvalCtx::for_config(&cfg)
        .threads(threads)
        .batch(batch)
        .stats(flags.has("stats"))
        .latency_budget_s(latency_budget_s)
    {
        Ok(eval) => eval,
        Err(_) => {
            let b = latency_budget_s.unwrap_or(f64::NAN);
            eprintln!("--latency-budget expects a positive duration in ms, got {}", b * 1e3);
            return 2;
        }
    };
    let ctx = ReportCtx::new(eval, &out);

    if flags.has("ports") {
        // The Fig 22 artifact is defined for builtin DeepCaps at batch 1;
        // refuse workload-set flags instead of silently ignoring them.
        let incompatible = flags.has("workload")
            || flags.has("random")
            || flags.has("mix")
            || flags.has("traffic-weighted")
            || flags.has("latency-budget")
            || batch != 1
            || flags.get("net", "deepcaps") != "deepcaps";
        if incompatible {
            eprintln!(
                "dse --ports is the Fig 22 builtin-DeepCaps study; it cannot be \
                 combined with --workload/--random/--mix/--traffic-weighted/--batch/\
                 --latency-budget or a --net other than deepcaps"
            );
            return 2;
        }
        return match report::fig22(&ctx) {
            Ok(csv) => {
                println!(
                    "port-constrained HY-PG DSE: {} configurations (paper: 113,337)",
                    fmt_count(csv.len() as u64)
                );
                0
            }
            Err(e) => {
                eprintln!("dse --ports failed: {e:#}");
                1
            }
        };
    }

    let (nets, weights) = match collect_networks(&flags) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("dse failed: {e:#}");
            return 2;
        }
    };

    // Single builtin named via --net at batch 1: the classic Fig 18/20
    // artifact path.  Workload-file/random networks always take the
    // co-design path, even when their `name` field says "capsnet" — a
    // spec's geometry must never be silently swapped for the builtin's.
    let builtin_only = !flags.has("workload") && !flags.has("random");
    if builtin_only
        && nets.len() == 1
        && batch == 1
        && matches!(nets[0].name.as_str(), "capsnet" | "deepcaps")
    {
        let net = nets[0].name.clone();
        return match report::dse_scatter(&ctx, &net) {
            Ok((csv, table, excluded, stats)) => {
                println!(
                    "{net} DSE: {} configurations enumerated (paper: {}), \
                     {} pruned by bound, {} evaluated",
                    fmt_count(stats.enumerated as u64),
                    if net == "capsnet" { "15,233" } else { "215,693" },
                    fmt_count(stats.pruned as u64),
                    fmt_count(stats.evaluated as u64),
                );
                if let Some(b) = latency_budget_s {
                    println!(
                        "latency budget {:.4} ms: {} of {} configurations within \
                         budget, {} excluded (3-D Pareto: energy/area/latency)",
                        b * 1e3,
                        fmt_count(csv.len() as u64),
                        fmt_count((csv.len() + excluded) as u64),
                        fmt_count(excluded as u64),
                    );
                }
                if ctx.eval.budget().stats {
                    print_sweep_stats(&stats);
                }
                println!("{}", table.to_ascii());
                0
            }
            Err(e) => {
                eprintln!("dse failed: {e:#}");
                1
            }
        };
    }

    // Workload-set path: co-design one organization across every network.
    match run_multi_dse(&ctx, &nets, weights, &flags) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("dse failed: {e:#}");
            1
        }
    }
}

fn run_multi_dse(
    ctx: &ReportCtx,
    nets: &[Network],
    weights: Option<Vec<f64>>,
    flags: &Flags,
) -> anyhow::Result<()> {
    let batch = ctx.eval.budget().batch;
    let profiles: Vec<NetworkProfile> = nets
        .iter()
        .map(|n| profile_network_batched(n, ctx.eval.accel(), batch))
        .collect();
    let names: Vec<String> = nets
        .iter()
        .map(|n| {
            if batch > 1 {
                format!("{}@b{batch}", n.name)
            } else {
                n.name.clone()
            }
        })
        .collect();
    let mix = if let Some(list) = flags.kv.get("mix") {
        let ws: Vec<f64> = list
            .split(',')
            .map(|w| {
                w.parse::<f64>()
                    .map_err(|_| anyhow::anyhow!("--mix expects numbers, got '{w}'"))
            })
            .collect::<anyhow::Result<_>>()?;
        WorkloadSet::with_weights(profiles, ws)?
    } else if let Some(ws) = weights {
        WorkloadSet::with_weights(profiles, ws)?
    } else if flags.has("traffic-weighted") {
        WorkloadSet::traffic_weighted(profiles)?
    } else {
        WorkloadSet::new(profiles)?
    };

    let (csv, table, excluded, stats) = report::multi_dse(ctx, &mix, &names)?;
    println!(
        "co-design DSE over {} networks ({}): {} configurations enumerated, \
         {} pruned by bound, {} evaluated",
        names.len(),
        names.join(", "),
        fmt_count(stats.enumerated as u64),
        fmt_count(stats.pruned as u64),
        fmt_count(stats.evaluated as u64),
    );
    if excluded > 0 {
        println!(
            "latency budget: {} configurations within budget, {} excluded",
            fmt_count(csv.len() as u64),
            fmt_count(excluded as u64),
        );
    }
    if ctx.eval.budget().stats {
        print_sweep_stats(&stats);
    }
    println!("{}", table.to_ascii());
    println!(
        "mix weights: {}",
        mix.weights()
            .iter()
            .zip(&names)
            .map(|(w, n)| format!("{n}={w:.3}"))
            .collect::<Vec<_>>()
            .join("  ")
    );
    Ok(())
}

/// `--stats` detail lines: branch-and-bound effectiveness counters and
/// the factored-evaluator wall-time split from the streaming sweep
/// (DESIGN.md sections 13–14).
fn print_sweep_stats(stats: &descnet::dse::stream::SweepStats) {
    println!(
        "pruning stats: {:.1}% culled before evaluation ({} of {}); \
         {} of {} subtrees pruned whole; archive {} inserts / {} final; \
         mean energy bound gap {:.1}%",
        100.0 * stats.pruned_fraction(),
        fmt_count(stats.pruned as u64),
        fmt_count(stats.enumerated as u64),
        fmt_count(stats.subtrees_pruned as u64),
        fmt_count(stats.subtrees as u64),
        fmt_count(stats.archive_inserts as u64),
        fmt_count(stats.archive_len as u64),
        100.0 * stats.mean_bound_gap(),
    );
    println!(
        "evaluator timing: subtree prep {} + point eval {} \
         ({} points evaluated through the factored tables)",
        fmt_time(stats.prep_s),
        fmt_time(stats.eval_s),
        fmt_count(stats.evaluated as u64),
    );
}

/// `descnet fleet`: SLO-constrained fleet co-design + the seeded
/// discrete-event serving simulation, for both the codesigned fleet and
/// the homogeneous union-SMP baseline (same arrival trace), with the
/// artifacts of `report fleet` written alongside.
fn cmd_fleet(args: &[String]) -> i32 {
    let flags = parse_flags(args);
    try_flag!(flags.check_known(&[
        "attainment",
        "batch-max",
        "config",
        "crash-policy",
        "fault-budget",
        "fault-seed",
        "hedge-ms",
        "homogeneous",
        "mtbf-s",
        "mttr-s",
        "net",
        "out",
        "policy",
        "random",
        "requests",
        "retries",
        "rps",
        "seed",
        "shards",
        "slo-ms",
        "threads",
        "timeout-ms",
        "workload",
    ]));
    let cfg = load_config(&flags);
    let out = PathBuf::from(flags.get("out", "results"));
    let threads = try_flag!(flags.usize("threads", exec::default_threads()));
    let eval = EvalCtx::for_config(&cfg).threads(threads);
    let shards = try_flag!(flags.usize("shards", 2));
    let requests = try_flag!(flags.usize("requests", 400));
    let seed = try_flag!(flags.usize("seed", 7)) as u64;
    let batch_max = try_flag!(flags.usize("batch-max", 4));
    let rps = try_flag!(flags.f64("rps", 100.0));
    let slo_s = try_flag!(flags.f64_opt("slo-ms")).map(|ms| ms * 1e-3);
    let policy = match fleet::RoutingPolicy::parse(&flags.get("policy", "jsq")) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e:#}");
            return 2;
        }
    };

    // Fault-injection block (ISSUE 8).  `--mtbf-s inf` (the default) keeps
    // injection off; parse accepts "inf" via f64::from_str.  An explicit
    // fault flag builds a FaultConfig even when it stays inert, so that the
    // inert-config bit-identity contract is exercised from the CLI too.
    let fault_flag_given = ["mtbf-s", "mttr-s", "timeout-ms", "retries", "hedge-ms",
        "fault-seed", "crash-policy"]
        .iter()
        .any(|k| flags.has(k));
    let mtbf_s = try_flag!(flags.f64("mtbf-s", f64::INFINITY));
    let mttr_s = try_flag!(flags.f64("mttr-s", 1.0));
    let timeout_s = try_flag!(flags.f64_opt("timeout-ms")).map(|ms| ms * 1e-3);
    let retries = try_flag!(flags.usize("retries", 2)) as u32;
    let hedge_s = try_flag!(flags.f64_opt("hedge-ms")).map(|ms| ms * 1e-3);
    let fault_seed = try_flag!(flags.usize("fault-seed", 0)) as u64;
    let crash_policy = match fleet::fault::CrashPolicy::parse(&flags.get("crash-policy", "requeue"))
    {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e:#}");
            return 2;
        }
    };
    let fault = fault_flag_given.then(|| fleet::fault::FaultConfig {
        mtbf_s,
        mttr_s,
        timeout_s,
        retries,
        hedge_s,
        fault_seed,
        crash_policy,
        pinned_down: Vec::new(),
    });
    let fault_budget = try_flag!(flags.usize("fault-budget", 0));
    let attainment = try_flag!(flags.f64("attainment", 0.99));

    let (nets, _) = match collect_networks(&flags) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("fleet failed: {e:#}");
            return 2;
        }
    };

    let res: anyhow::Result<()> = (|| {
        // Executable batch sizes: powers of two up to --batch-max (the
        // SLO further prunes them per shard).
        let mut batch_sizes = Vec::new();
        let mut b = 1usize;
        while b <= batch_max.max(1) {
            batch_sizes.push(b);
            match b.checked_mul(2) {
                Some(next) => b = next,
                None => break,
            }
        }
        let opts = fleet::DesignOptions {
            shards,
            batch_sizes,
            slo_s,
            flush_deadline_s: 2e-3,
            homogeneous: flags.has("homogeneous"),
        };
        let fcfg = fleet::FleetConfig {
            rps,
            requests,
            seed,
            policy,
            slo_s,
            fault,
        };
        let design = if fault_budget > 0 {
            // N+F provisioning: escalate shard count until the fleet still
            // meets the attainment target with its F highest-capacity
            // shards pinned down (adversarial worst case).
            let np = fleet::NPlusOptions {
                fault_budget,
                attainment_target: attainment,
                max_extra: 4,
            };
            let nd = fleet::design_fleet_n_plus(&eval, &nets, &opts, &fcfg, &np)?;
            println!(
                "N+{fault_budget} provisioning: {} shards (base {}), degraded \
                 attainment {:.1}% with shards {:?} down (target {:.1}%)",
                nd.shards,
                shards,
                100.0 * nd.degraded.slo_attainment(),
                nd.pinned,
                100.0 * attainment,
            );
            nd.design
        } else {
            fleet::design_fleet(&eval, &nets, &opts)?
        };
        let ctx = ReportCtx::new(eval, &out);
        let (_, _, mut stats, base) = report::fleet_report(&ctx, &design, &fcfg)?;
        print!("{}", stats.summary());
        println!(
            "baseline [{}]: {:.3} mJ/request -> codesigned saves {:.1}%",
            design.baseline_label,
            base.energy_per_request_j() * 1e3,
            100.0 * (1.0 - stats.energy_per_request_j() / base.energy_per_request_j()),
        );
        println!("results under {}", out.display());
        Ok(())
    })();
    match res {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("fleet failed: {e:#}");
            1
        }
    }
}

fn cmd_report(args: &[String]) -> i32 {
    let flags = parse_flags(args);
    try_flag!(flags.check_known(&["config", "out", "threads"]));
    let cfg = load_config(&flags);
    let out = PathBuf::from(flags.get("out", "results"));
    let threads = try_flag!(flags.usize("threads", exec::default_threads()));
    let what = flags
        .positional
        .first()
        .cloned()
        .unwrap_or_else(|| "all".to_string());
    let ctx = ReportCtx::new(EvalCtx::for_config(&cfg).threads(threads), &out);
    let res: anyhow::Result<()> = (|| {
        match what.as_str() {
            "all" => {
                let done = report::all(&ctx)?;
                println!("regenerated: {}", done.join(", "));
            }
            "fig1" => drop(report::fig1(&ctx)),
            "fig7" => drop(report::fig7(&ctx)),
            "fig9" => drop(report::fig9(&ctx)),
            "fig10" => drop(report::fig10(&ctx)),
            "fig11" => drop(report::fig11(&ctx)),
            "fig12" => drop(report::fig12(&ctx)?),
            "fig18" => drop(report::dse_scatter(&ctx, "capsnet")?),
            "fig19" => drop(report::breakdowns(&ctx, "capsnet")?),
            "fig20" => drop(report::dse_scatter(&ctx, "deepcaps")?),
            "fig21" => drop(report::breakdowns(&ctx, "deepcaps")?),
            "fig22" => drop(report::fig22(&ctx)?),
            "fig23" | "fig24" => drop(report::whole_accelerator(&ctx, "capsnet")?),
            "fig25" | "fig26" => drop(report::whole_accelerator(&ctx, "deepcaps")?),
            "fig27" | "fig28" => drop(report::fig27_28(&ctx)),
            "fig29" => drop(report::memory_breakdown(&ctx, "capsnet")?),
            "fig30" => drop(report::fig30(&ctx)?),
            "fig31" | "fig32" => drop(report::memory_breakdown(&ctx, "deepcaps")?),
            "multi" => {
                let (set, names) = report::default_serving_mix(&ctx)?;
                let (_, table, _, _) = report::multi_dse(&ctx, &set, &names)?;
                println!("{}", table.to_ascii());
            }
            "fleet" => {
                let (_, table, _, _) = report::fleet_default(&ctx)?;
                println!("{}", table.to_ascii());
            }
            "table3" => println!("{}", report::table3(&ctx)?.to_ascii()),
            "headline" => println!("{}", report::headline(&ctx)?),
            other => anyhow::bail!("unknown report target '{other}'"),
        }
        Ok(())
    })();
    match res {
        Ok(()) => {
            println!("results under {}", out.display());
            0
        }
        Err(e) => {
            eprintln!("report failed: {e:#}");
            1
        }
    }
}

fn cmd_headline(args: &[String]) -> i32 {
    let flags = parse_flags(args);
    try_flag!(flags.check_known(&["config", "threads"]));
    let cfg = load_config(&flags);
    let threads = try_flag!(flags.usize("threads", exec::default_threads()));
    let dir = std::env::temp_dir().join("descnet_headline");
    let ctx = ReportCtx::new(EvalCtx::for_config(&cfg).threads(threads), &dir);
    match report::headline(&ctx) {
        Ok(csv) => {
            println!("{csv}");
            0
        }
        Err(e) => {
            eprintln!("headline failed: {e:#}");
            1
        }
    }
}

/// `descnet lint`: the ISSUE 9 invariant analyzer over the repo's own
/// sources.  Exit codes: 0 clean, 1 findings, 2 usage/IO error — so CI can
/// gate on the exit status alone while also grepping the summary line
/// (embedded in the JSON output too).
fn cmd_lint(args: &[String]) -> i32 {
    let flags = parse_flags(args);
    try_flag!(flags.check_known(&["format", "root"]));
    let root = PathBuf::from(flags.get("root", "."));
    let format = flags.get("format", "table");
    if format != "table" && format != "json" {
        eprintln!("--format expects 'table' or 'json', got '{format}'");
        return 2;
    }
    match descnet::analysis::lint_tree(&root) {
        Ok(report) => {
            if format == "json" {
                println!("{}", report.to_json().to_string_pretty());
            } else {
                print!("{}", report.to_text());
            }
            if report.is_clean() {
                0
            } else {
                1
            }
        }
        Err(e) => {
            eprintln!("lint failed: {e:#}");
            2
        }
    }
}

/// `descnet config --save configs/default.json`: snapshot the calibrated
/// defaults so experiments can pin/modify them (DESIGN.md section 7).
fn cmd_config(args: &[String]) -> i32 {
    let flags = parse_flags(args);
    try_flag!(flags.check_known(&["config", "save"]));
    let cfg = load_config(&flags);
    match flags.kv.get("save") {
        Some(path) => {
            let p = std::path::Path::new(path);
            if let Err(e) = cfg.save(p) {
                eprintln!("saving {path}: {e}");
                return 1;
            }
            println!("wrote {path}");
        }
        None => println!("{}", cfg.to_json().to_string_pretty()),
    }
    0
}

fn cmd_serve(args: &[String]) -> i32 {
    let flags = parse_flags(args);
    try_flag!(flags.check_known(&[
        "artifacts", "batch-max", "requests", "seed", "slo-ms", "stage-pipeline",
    ]));
    let slo_s = try_flag!(flags.f64_opt("slo-ms")).map(|ms| ms * 1e-3);
    let opts = ServeOptions {
        artifacts_dir: PathBuf::from(flags.get("artifacts", "artifacts")),
        requests: try_flag!(flags.usize("requests", 64)),
        batch_max: try_flag!(flags.usize("batch-max", 4)),
        stage_pipeline: flags.has("stage-pipeline"),
        seed: try_flag!(flags.usize("seed", 7)) as u64,
        slo_s,
    };
    match Server::run_synthetic(&opts) {
        Ok(mut stats) => {
            println!("{}", stats.summary());
            0
        }
        Err(e) => {
            eprintln!("serve failed: {e}");
            1
        }
    }
}
