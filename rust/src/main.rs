//! `descnet` CLI — leader entrypoint.
//!
//! Subcommands:
//!   analyze   print the per-operation workload profile (Figs 1/9/10/11)
//!   dse       run the design-space exploration (Figs 18/20/22, Tables I/II)
//!   report    regenerate paper figures/tables into results/ (see DESIGN.md E-index)
//!   serve     serve CapsNet inference via the PJRT runtime + coordinator
//!   headline  print the paper-vs-ours headline metrics

use std::path::PathBuf;

use descnet::accel;
use descnet::config::SystemConfig;
use descnet::coordinator::server::{ServeOptions, Server};
use descnet::dataflow::profile_network;
use descnet::model::{capsnet_mnist, deepcaps_cifar10};
use descnet::report::{self, ReportCtx};
use descnet::util::exec;
use descnet::util::table::Table;
use descnet::util::units::{fmt_count, fmt_size};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let rest = if args.is_empty() { &args[..] } else { &args[1..] };
    let code = match cmd {
        "analyze" => cmd_analyze(rest),
        "dse" => cmd_dse(rest),
        "report" => cmd_report(rest),
        "serve" => cmd_serve(rest),
        "headline" => cmd_headline(rest),
        "config" => cmd_config(rest),
        "help" | "--help" | "-h" => {
            print_help();
            0
        }
        other => {
            eprintln!("unknown command '{other}'\n");
            print_help();
            2
        }
    };
    std::process::exit(code);
}

fn print_help() {
    println!(
        "descnet — DESCNet scratchpad-memory DSE for CapsNet accelerators\n\n\
         USAGE: descnet <command> [options]\n\n\
         COMMANDS:\n\
           analyze  [--net capsnet|deepcaps] [--sim]        per-op workload profile\n\
           dse      [--net capsnet|deepcaps] [--ports]      design-space exploration\n\
                    [--threads N] [--out DIR]\n\
           report   [all|fig1|fig7|fig9|fig10|fig11|fig12|fig18|fig19|fig20|fig21|\n\
                     fig22|fig23|fig25|fig27|fig29|fig30|fig31|table3|headline]\n\
                    [--out DIR] [--threads N] [--config FILE]\n\
           serve    [--artifacts DIR] [--requests N] [--batch-max B] [--stage-pipeline]\n\
           headline [--threads N]                           paper-vs-ours summary\n\
           config   [--save FILE] [--config FILE]           print/snapshot the technology config"
    );
}

/// Tiny flag parser: `--key value` pairs plus positional words.
struct Flags {
    positional: Vec<String>,
    kv: std::collections::BTreeMap<String, String>,
}

fn parse_flags(args: &[String]) -> Flags {
    let mut positional = Vec::new();
    let mut kv = std::collections::BTreeMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                kv.insert(key.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                kv.insert(key.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            positional.push(args[i].clone());
            i += 1;
        }
    }
    Flags { positional, kv }
}

impl Flags {
    fn get(&self, key: &str, default: &str) -> String {
        self.kv
            .get(key)
            .cloned()
            .unwrap_or_else(|| default.to_string())
    }

    fn usize(&self, key: &str, default: usize) -> usize {
        self.kv
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    fn has(&self, key: &str) -> bool {
        self.kv.contains_key(key)
    }
}

fn load_config(flags: &Flags) -> SystemConfig {
    match flags.kv.get("config") {
        Some(path) => SystemConfig::load(std::path::Path::new(path)).unwrap_or_else(|e| {
            eprintln!("failed to load config {path}: {e}");
            std::process::exit(2);
        }),
        None => SystemConfig::default(),
    }
}

fn cmd_analyze(args: &[String]) -> i32 {
    let flags = parse_flags(args);
    let cfg = load_config(&flags);
    let net = flags.get("net", "capsnet");
    let network = match net.as_str() {
        "capsnet" => capsnet_mnist(),
        "deepcaps" => deepcaps_cifar10(),
        other => {
            eprintln!("unknown network {other}");
            return 2;
        }
    };
    let p = profile_network(&network, &cfg.accel);
    let mut table = Table::new(&[
        "op", "group", "cycles", "D usage", "W usage", "A usage", "off rd", "off wr",
    ]);
    for op in &p.ops {
        table.row(vec![
            op.name.clone(),
            op.group.label().to_string(),
            fmt_count(op.cycles),
            fmt_size(op.usage_d),
            fmt_size(op.usage_w),
            fmt_size(op.usage_a),
            fmt_size(op.off_rd as usize),
            fmt_size(op.off_wr as usize),
        ]);
    }
    println!("{}", table.to_ascii());
    println!(
        "total: {} cycles  ->  {:.1} fps @ {:.0} MHz (paper: {} fps)",
        fmt_count(p.total_cycles()),
        p.fps(),
        cfg.accel.clock_hz / 1e6,
        network.paper_fps,
    );
    println!(
        "maxima: D {}  W {}  A {}  SMP {}",
        fmt_size(p.max_d()),
        fmt_size(p.max_w()),
        fmt_size(p.max_a()),
        fmt_size(p.max_total()),
    );
    if flags.has("sim") {
        // Event-level simulation: phase breakdown + closed-form validation.
        let mut t = Table::new(&["op", "compute", "w-stream", "drain", "normalize", "util"]);
        for sim in accel::sim_network(&network, &cfg.accel) {
            t.row(vec![
                sim.name.clone(),
                fmt_count(sim.compute),
                fmt_count(sim.weight_stream),
                fmt_count(sim.drain),
                fmt_count(sim.normalization),
                format!("{:.1}%", 100.0 * sim.utilization()),
            ]);
        }
        println!("{}", t.to_ascii());
        println!(
            "event-sim vs closed form: max disagreement {:.2}%",
            100.0 * accel::validate_network(&network, &cfg.accel)
        );
    }
    0
}

fn cmd_dse(args: &[String]) -> i32 {
    let flags = parse_flags(args);
    let cfg = load_config(&flags);
    let out = PathBuf::from(flags.get("out", "results"));
    let threads = flags.usize("threads", exec::default_threads());
    let net = flags.get("net", "capsnet");
    let ctx = ReportCtx::new(cfg, &out);

    if flags.has("ports") {
        let csv = report::fig22(&ctx, threads);
        println!(
            "port-constrained HY-PG DSE: {} configurations (paper: 113,337)",
            fmt_count(csv.len() as u64)
        );
        return 0;
    }
    let (csv, table) = report::dse_scatter(&ctx, &net, threads);
    println!(
        "{net} DSE: {} configurations evaluated (paper: {})",
        fmt_count(csv.len() as u64),
        if net == "capsnet" { "15,233" } else { "215,693" },
    );
    println!("{}", table.to_ascii());
    0
}

fn cmd_report(args: &[String]) -> i32 {
    let flags = parse_flags(args);
    let cfg = load_config(&flags);
    let out = PathBuf::from(flags.get("out", "results"));
    let threads = flags.usize("threads", exec::default_threads());
    let what = flags
        .positional
        .first()
        .cloned()
        .unwrap_or_else(|| "all".to_string());
    let ctx = ReportCtx::new(cfg, &out);
    match what.as_str() {
        "all" => {
            let done = report::all(&ctx, threads);
            println!("regenerated: {}", done.join(", "));
        }
        "fig1" => drop(report::fig1(&ctx)),
        "fig7" => drop(report::fig7(&ctx)),
        "fig9" => drop(report::fig9(&ctx)),
        "fig10" => drop(report::fig10(&ctx)),
        "fig11" => drop(report::fig11(&ctx)),
        "fig12" => drop(report::fig12(&ctx)),
        "fig18" => drop(report::dse_scatter(&ctx, "capsnet", threads)),
        "fig19" => drop(report::breakdowns(&ctx, "capsnet", threads)),
        "fig20" => drop(report::dse_scatter(&ctx, "deepcaps", threads)),
        "fig21" => drop(report::breakdowns(&ctx, "deepcaps", threads)),
        "fig22" => drop(report::fig22(&ctx, threads)),
        "fig23" | "fig24" => drop(report::whole_accelerator(&ctx, "capsnet", threads)),
        "fig25" | "fig26" => drop(report::whole_accelerator(&ctx, "deepcaps", threads)),
        "fig27" | "fig28" => drop(report::fig27_28(&ctx)),
        "fig29" => drop(report::memory_breakdown(&ctx, "capsnet", threads)),
        "fig30" => drop(report::fig30(&ctx, threads)),
        "fig31" | "fig32" => drop(report::memory_breakdown(&ctx, "deepcaps", threads)),
        "table3" => println!("{}", report::table3(&ctx, threads).to_ascii()),
        "headline" => println!("{}", report::headline(&ctx, threads).to_string()),
        other => {
            eprintln!("unknown report target '{other}'");
            return 2;
        }
    }
    println!("results under {}", out.display());
    0
}

fn cmd_headline(args: &[String]) -> i32 {
    let flags = parse_flags(args);
    let cfg = load_config(&flags);
    let threads = flags.usize("threads", exec::default_threads());
    let dir = std::env::temp_dir().join("descnet_headline");
    let ctx = ReportCtx::new(cfg, &dir);
    println!("{}", report::headline(&ctx, threads).to_string());
    0
}

/// `descnet config --save configs/default.json`: snapshot the calibrated
/// defaults so experiments can pin/modify them (DESIGN.md section 7).
fn cmd_config(args: &[String]) -> i32 {
    let flags = parse_flags(args);
    let cfg = load_config(&flags);
    match flags.kv.get("save") {
        Some(path) => {
            let p = std::path::Path::new(path);
            if let Err(e) = cfg.save(p) {
                eprintln!("saving {path}: {e}");
                return 1;
            }
            println!("wrote {path}");
        }
        None => println!("{}", cfg.to_json().to_string_pretty()),
    }
    0
}

fn cmd_serve(args: &[String]) -> i32 {
    let flags = parse_flags(args);
    let opts = ServeOptions {
        artifacts_dir: PathBuf::from(flags.get("artifacts", "artifacts")),
        requests: flags.usize("requests", 64),
        batch_max: flags.usize("batch-max", 4),
        stage_pipeline: flags.has("stage-pipeline"),
        seed: flags.usize("seed", 7) as u64,
    };
    match Server::run_synthetic(&opts) {
        Ok(mut stats) => {
            println!("{}", stats.summary());
            0
        }
        Err(e) => {
            eprintln!("serve failed: {e}");
            1
        }
    }
}
