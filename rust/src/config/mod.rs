//! Configuration system: technology constants, accelerator geometry, and
//! DSE pool parameters — all JSON-round-trippable so experiments are
//! reproducible from `configs/*.json` snapshots.
//!
//! Defaults implement the calibration in DESIGN.md sections 6–7 (32nm CMOS,
//! CapsAcc 16x16 @ 200 MHz, CACTI-P-anchored SRAM constants).

use anyhow::Context;

use crate::util::json::Json;

/// SRAM / DRAM / accelerator energy+area constants (DESIGN.md section 7).
///
/// These replace CACTI-P + Synopsys synthesis: analytical scaling laws whose
/// free constants are fitted to the paper's Table III anchor cells.  The
/// fit is validated by `cacti::tests` against those anchors.
#[derive(Debug, Clone, PartialEq)]
pub struct Technology {
    /// SRAM leakage, W per byte for a 1-port array (32nm HP ~0.87 µW/B).
    pub sram_leak_w_per_byte: f64,
    /// Leakage multiplier per extra port: (1 + k*(ports-1)).
    pub sram_leak_port_factor: f64,
    /// SRAM dynamic energy: E = e0 * size_kib^s_exp * ports^p_exp  [J].
    pub sram_dyn_e0_j: f64,
    pub sram_dyn_size_exp: f64,
    pub sram_dyn_port_exp: f64,
    /// SRAM area anchor: mm² of a 64 KiB 1-port array (Table III anchor).
    pub sram_area_64k_mm2: f64,
    /// Piecewise size exponents around the 128 KiB knee (CACTI-P shape:
    /// periphery-dominated below, density-gaining above).
    pub sram_area_exp_small: f64,
    pub sram_area_exp_large: f64,
    /// Area multiplier per extra port: (1 + k*(ports-1)).
    pub sram_area_port_factor: f64,
    /// Sectoring (banking) area overhead: (1 + k*(SC-1)^0.9).
    pub sram_area_sector_factor: f64,
    /// Sleep-transistor area overhead fraction when power-gating is present
    /// (paper: "on average 2.75%").
    pub powergate_area_overhead: f64,
    /// OFF-sector leakage as a fraction of ON leakage (non-retentive sleep).
    pub powergate_off_leak_frac: f64,
    /// Wakeup energy per KiB of sector capacity [J].
    pub wakeup_j_per_kib: f64,
    /// Wakeup latency [s] (paper: 0.072 ns, masked by pre-activation).
    pub wakeup_latency_s: f64,
    /// DRAM energy per byte transferred [J] (LPDDR-class, incl. interface).
    pub dram_j_per_byte: f64,
    /// DRAM static/background power [W] attributed to this accelerator.
    pub dram_background_w: f64,
    /// DRAM burst latency [s] and peak bandwidth [B/s] (for prefetch checks
    /// and the `sim` timeline).
    pub dram_latency_s: f64,
    pub dram_bandwidth_bps: f64,
    /// DMA burst granularity [bytes]: off-chip transfers are quantized to
    /// whole bursts by the timeline simulator (`sim`); the train pays the
    /// burst latency once (bursts are pipelined back to back).
    pub dram_burst_bytes: usize,
    /// NP-array MAC energy [J] (8-bit MAC incl. local pipeline regs).
    pub mac_energy_j: f64,
    /// Activation-unit op energy [J] (exp/sqrt/div LUT pipeline).
    pub act_energy_j: f64,
    /// Accelerator (array + control) leakage [W] and area [mm²].  The area
    /// is calibrated to the paper's Fig 23b/24b whole-accelerator splits:
    /// their synthesized CapsAcc (PE array + activation LUT banks + control)
    /// is comparable in footprint to the version-(a) 8 MiB SPM, which is
    /// what makes the headline "47% area reduction" arithmetic work.
    pub accel_leak_w: f64,
    pub accel_area_mm2: f64,
}

impl Default for Technology {
    fn default() -> Technology {
        Technology {
            sram_leak_w_per_byte: 0.87e-6,
            sram_leak_port_factor: 0.45,
            sram_dyn_e0_j: 1.9e-12,
            sram_dyn_size_exp: 0.407,
            sram_dyn_port_exp: 1.45,
            sram_area_64k_mm2: 0.314,
            sram_area_exp_small: 1.2,
            sram_area_exp_large: 0.92,
            sram_area_port_factor: 1.64,
            sram_area_sector_factor: 0.065,
            powergate_area_overhead: 0.0275,
            powergate_off_leak_frac: 0.10,
            wakeup_j_per_kib: 25.0e-12,
            wakeup_latency_s: 0.072e-9,
            dram_j_per_byte: 1.2e-9,
            dram_background_w: 80.0e-3,
            dram_latency_s: 100e-9,
            dram_bandwidth_bps: 12.8e9,
            dram_burst_bytes: 4096,
            mac_energy_j: 0.9e-12,
            act_energy_j: 6.0e-12,
            accel_leak_w: 18.0e-3,
            accel_area_mm2: 36.0,
        }
    }
}

impl Technology {
    /// Stable fingerprint of every constant, used to key the global CACTI
    /// cost cache (`cacti::cache`): configurations with identical constants
    /// share cached costs, while any perturbation (e.g. the `dse_sweep`
    /// ablations) gets its own namespace.  The exhaustive destructuring
    /// (no `..`) makes a newly added field a compile error here, so the
    /// fingerprint can never silently alias distinct technologies.
    pub fn cache_key(&self) -> u64 {
        use std::hash::{Hash, Hasher};
        let &Technology {
            sram_leak_w_per_byte,
            sram_leak_port_factor,
            sram_dyn_e0_j,
            sram_dyn_size_exp,
            sram_dyn_port_exp,
            sram_area_64k_mm2,
            sram_area_exp_small,
            sram_area_exp_large,
            sram_area_port_factor,
            sram_area_sector_factor,
            powergate_area_overhead,
            powergate_off_leak_frac,
            wakeup_j_per_kib,
            wakeup_latency_s,
            dram_j_per_byte,
            dram_background_w,
            dram_latency_s,
            dram_bandwidth_bps,
            dram_burst_bytes,
            mac_energy_j,
            act_energy_j,
            accel_leak_w,
            accel_area_mm2,
        } = self;
        let mut h = std::collections::hash_map::DefaultHasher::new();
        (*dram_burst_bytes as u64).hash(&mut h);
        for v in [
            sram_leak_w_per_byte,
            sram_leak_port_factor,
            sram_dyn_e0_j,
            sram_dyn_size_exp,
            sram_dyn_port_exp,
            sram_area_64k_mm2,
            sram_area_exp_small,
            sram_area_exp_large,
            sram_area_port_factor,
            sram_area_sector_factor,
            powergate_area_overhead,
            powergate_off_leak_frac,
            wakeup_j_per_kib,
            wakeup_latency_s,
            dram_j_per_byte,
            dram_background_w,
            dram_latency_s,
            dram_bandwidth_bps,
            mac_energy_j,
            act_energy_j,
            accel_leak_w,
            accel_area_mm2,
        ] {
            v.to_bits().hash(&mut h);
        }
        h.finish()
    }

    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("sram_leak_w_per_byte", self.sram_leak_w_per_byte.into()),
            ("sram_leak_port_factor", self.sram_leak_port_factor.into()),
            ("sram_dyn_e0_j", self.sram_dyn_e0_j.into()),
            ("sram_dyn_size_exp", self.sram_dyn_size_exp.into()),
            ("sram_dyn_port_exp", self.sram_dyn_port_exp.into()),
            ("sram_area_64k_mm2", self.sram_area_64k_mm2.into()),
            ("sram_area_exp_small", self.sram_area_exp_small.into()),
            ("sram_area_exp_large", self.sram_area_exp_large.into()),
            ("sram_area_port_factor", self.sram_area_port_factor.into()),
            ("sram_area_sector_factor", self.sram_area_sector_factor.into()),
            ("powergate_area_overhead", self.powergate_area_overhead.into()),
            ("powergate_off_leak_frac", self.powergate_off_leak_frac.into()),
            ("wakeup_j_per_kib", self.wakeup_j_per_kib.into()),
            ("wakeup_latency_s", self.wakeup_latency_s.into()),
            ("dram_j_per_byte", self.dram_j_per_byte.into()),
            ("dram_background_w", self.dram_background_w.into()),
            ("dram_latency_s", self.dram_latency_s.into()),
            ("dram_bandwidth_bps", self.dram_bandwidth_bps.into()),
            ("dram_burst_bytes", self.dram_burst_bytes.into()),
            ("mac_energy_j", self.mac_energy_j.into()),
            ("act_energy_j", self.act_energy_j.into()),
            ("accel_leak_w", self.accel_leak_w.into()),
            ("accel_area_mm2", self.accel_area_mm2.into()),
        ])
    }

    pub fn from_json(j: &Json) -> Technology {
        let d = Technology::default();
        let f = |key: &str, dv: f64| j.get(key).as_f64().unwrap_or(dv);
        Technology {
            sram_leak_w_per_byte: f("sram_leak_w_per_byte", d.sram_leak_w_per_byte),
            sram_leak_port_factor: f("sram_leak_port_factor", d.sram_leak_port_factor),
            sram_dyn_e0_j: f("sram_dyn_e0_j", d.sram_dyn_e0_j),
            sram_dyn_size_exp: f("sram_dyn_size_exp", d.sram_dyn_size_exp),
            sram_dyn_port_exp: f("sram_dyn_port_exp", d.sram_dyn_port_exp),
            sram_area_64k_mm2: f("sram_area_64k_mm2", d.sram_area_64k_mm2),
            sram_area_exp_small: f("sram_area_exp_small", d.sram_area_exp_small),
            sram_area_exp_large: f("sram_area_exp_large", d.sram_area_exp_large),
            sram_area_port_factor: f("sram_area_port_factor", d.sram_area_port_factor),
            sram_area_sector_factor: f("sram_area_sector_factor", d.sram_area_sector_factor),
            powergate_area_overhead: f("powergate_area_overhead", d.powergate_area_overhead),
            powergate_off_leak_frac: f("powergate_off_leak_frac", d.powergate_off_leak_frac),
            wakeup_j_per_kib: f("wakeup_j_per_kib", d.wakeup_j_per_kib),
            wakeup_latency_s: f("wakeup_latency_s", d.wakeup_latency_s),
            dram_j_per_byte: f("dram_j_per_byte", d.dram_j_per_byte),
            dram_background_w: f("dram_background_w", d.dram_background_w),
            dram_latency_s: f("dram_latency_s", d.dram_latency_s),
            dram_bandwidth_bps: f("dram_bandwidth_bps", d.dram_bandwidth_bps),
            dram_burst_bytes: j
                .get("dram_burst_bytes")
                .as_usize()
                .unwrap_or(d.dram_burst_bytes),
            mac_energy_j: f("mac_energy_j", d.mac_energy_j),
            act_energy_j: f("act_energy_j", d.act_energy_j),
            accel_leak_w: f("accel_leak_w", d.accel_leak_w),
            accel_area_mm2: f("accel_area_mm2", d.accel_area_mm2),
        }
    }
}

/// CapsAcc array geometry + dataflow/tiling constants (DESIGN.md section 6).
#[derive(Debug, Clone, PartialEq)]
pub struct Accelerator {
    /// PE array rows/columns (CapsAcc: 16x16).
    pub array_rows: usize,
    pub array_cols: usize,
    /// Clock frequency [Hz].
    pub clock_hz: f64,
    /// Datatype widths in bytes: activations/weights, accumulators, routing
    /// state (b/c coefficients).
    pub data_bytes: usize,
    pub acc_bytes: usize,
    pub routing_state_bytes: usize,
    /// Number of SPM banks (fixed to the array edge: B=16 in the paper).
    pub spm_banks: usize,
    /// Fill-port width of one SPM bank [bytes/cycle]: bounds the on-chip
    /// side of DMA fills in the `sim` timeline — effective fill bandwidth
    /// is min(DRAM bandwidth, banks x width x clock).  The default
    /// (16 banks x 4 B @ 200 MHz = 12.8 GB/s) matches the DRAM peak, so
    /// the paper configuration is never bank-limited.
    pub spm_bank_fill_bytes: usize,
    /// Squash drain cost, cycles per capsule through the 16-lane
    /// activation unit.
    pub squash_cycles_per_elem: usize,
    /// Dynamic-routing serialization (DESIGN.md section 6): per output
    /// capsule j, the normalization/activation tail is serialized over the
    /// NI inputs at `routing_act_serial_cycles` each, capped by
    /// `routing_j_overhead_cap` once the double-buffered normalization unit
    /// overlaps with the next capsule's accumulation.  Calibrated so that
    /// routing is >50% of CapsNet cycles (116 fps) while ConvCaps2D stays
    /// ~73% of DeepCaps cycles (9.7 fps).
    pub routing_act_serial_cycles: usize,
    pub routing_j_overhead_cap: usize,
    /// Streaming data-window channel tile (kh-row double-buffered windows).
    pub window_tci: usize,
    /// Data-SPM full-fmap residency threshold [bytes]: inputs larger than
    /// this are streamed as 3-row double-buffered windows (DeepCaps policy).
    pub fmap_resident_threshold: usize,
    /// ClassCaps weight-tile: input capsules per tile (single-buffered
    /// streaming; 42 reproduces the paper's 64 kiB weight-SPM peak while
    /// keeping PrimaryCaps the largest-total-usage op, Fig 1).
    pub classcaps_w_tile_caps: usize,
    /// Pipeline fill/drain overhead per operation [cycles].
    pub op_overhead_cycles: usize,
}

impl Default for Accelerator {
    fn default() -> Accelerator {
        Accelerator {
            array_rows: 16,
            array_cols: 16,
            clock_hz: 200e6,
            data_bytes: 1,
            acc_bytes: 4,
            routing_state_bytes: 1,
            spm_banks: 16,
            spm_bank_fill_bytes: 4,
            squash_cycles_per_elem: 16,
            routing_act_serial_cycles: 12,
            routing_j_overhead_cap: 13_848,
            window_tci: 64,
            fmap_resident_threshold: 256 * 1024,
            classcaps_w_tile_caps: 42,
            op_overhead_cycles: 64,
        }
    }
}

impl Accelerator {
    pub fn pes(&self) -> usize {
        self.array_rows * self.array_cols
    }

    pub fn cycle_s(&self) -> f64 {
        1.0 / self.clock_hz
    }

    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("array_rows", self.array_rows.into()),
            ("array_cols", self.array_cols.into()),
            ("clock_hz", self.clock_hz.into()),
            ("data_bytes", self.data_bytes.into()),
            ("acc_bytes", self.acc_bytes.into()),
            ("routing_state_bytes", self.routing_state_bytes.into()),
            ("spm_banks", self.spm_banks.into()),
            ("spm_bank_fill_bytes", self.spm_bank_fill_bytes.into()),
            ("squash_cycles_per_elem", self.squash_cycles_per_elem.into()),
            ("routing_act_serial_cycles", self.routing_act_serial_cycles.into()),
            ("routing_j_overhead_cap", self.routing_j_overhead_cap.into()),
            ("window_tci", self.window_tci.into()),
            ("fmap_resident_threshold", self.fmap_resident_threshold.into()),
            ("classcaps_w_tile_caps", self.classcaps_w_tile_caps.into()),
            ("op_overhead_cycles", self.op_overhead_cycles.into()),
        ])
    }

    pub fn from_json(j: &Json) -> Accelerator {
        let d = Accelerator::default();
        let u = |key: &str, dv: usize| j.get(key).as_usize().unwrap_or(dv);
        Accelerator {
            array_rows: u("array_rows", d.array_rows),
            array_cols: u("array_cols", d.array_cols),
            clock_hz: j.get("clock_hz").as_f64().unwrap_or(d.clock_hz),
            data_bytes: u("data_bytes", d.data_bytes),
            acc_bytes: u("acc_bytes", d.acc_bytes),
            routing_state_bytes: u("routing_state_bytes", d.routing_state_bytes),
            spm_banks: u("spm_banks", d.spm_banks),
            spm_bank_fill_bytes: u("spm_bank_fill_bytes", d.spm_bank_fill_bytes),
            squash_cycles_per_elem: u("squash_cycles_per_elem", d.squash_cycles_per_elem),
            routing_act_serial_cycles: u("routing_act_serial_cycles", d.routing_act_serial_cycles),
            routing_j_overhead_cap: u("routing_j_overhead_cap", d.routing_j_overhead_cap),
            window_tci: u("window_tci", d.window_tci),
            fmap_resident_threshold: u("fmap_resident_threshold", d.fmap_resident_threshold),
            classcaps_w_tile_caps: u("classcaps_w_tile_caps", d.classcaps_w_tile_caps),
            op_overhead_cycles: u("op_overhead_cycles", d.op_overhead_cycles),
        }
    }
}

/// Top-level bundle: what every evaluation entry point takes.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SystemConfig {
    pub tech: Technology,
    pub accel: Accelerator,
}

impl SystemConfig {
    /// Rejects degenerate timing parameters before they reach the timeline
    /// simulator: a zero/NaN clock, DRAM bandwidth or bank fill width turns
    /// every simulated latency into NaN/inf, which would then flow silently
    /// into the Pareto frontier and the fleet SLO accounting.  [`Self::load`]
    /// validates every config file; defaults are valid by construction.
    pub fn validate(&self) -> anyhow::Result<()> {
        let positive = |name: &str, v: f64| -> anyhow::Result<()> {
            anyhow::ensure!(
                v.is_finite() && v > 0.0,
                "config: {name} must be a positive finite number, got {v}"
            );
            Ok(())
        };
        positive("accelerator.clock_hz", self.accel.clock_hz)?;
        positive("technology.dram_bandwidth_bps", self.tech.dram_bandwidth_bps)?;
        // Zero burst latency is a legitimate idealization; negative/NaN
        // would silently zero every DMA train in the timeline.
        anyhow::ensure!(
            self.tech.dram_latency_s.is_finite() && self.tech.dram_latency_s >= 0.0,
            "config: technology.dram_latency_s must be a non-negative finite duration, got {}",
            self.tech.dram_latency_s
        );
        anyhow::ensure!(
            self.accel.spm_bank_fill_bytes > 0,
            "config: accelerator.spm_bank_fill_bytes must be non-zero \
             (a zero-width fill port starves the DMA timeline)"
        );
        anyhow::ensure!(
            self.accel.spm_banks > 0,
            "config: accelerator.spm_banks must be non-zero"
        );
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("technology", self.tech.to_json()),
            ("accelerator", self.accel.to_json()),
        ])
    }

    pub fn from_json(j: &Json) -> SystemConfig {
        SystemConfig {
            tech: Technology::from_json(j.get("technology")),
            accel: Accelerator::from_json(j.get("accelerator")),
        }
    }

    pub fn load(path: &std::path::Path) -> anyhow::Result<SystemConfig> {
        let cfg = SystemConfig::from_json(
            &Json::parse_file(path).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?,
        );
        cfg.validate()
            .with_context(|| format!("validating {}", path.display()))?;
        Ok(cfg)
    }

    pub fn save(&self, path: &std::path::Path) -> std::io::Result<()> {
        self.to_json().write_file(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_design_doc() {
        let a = Accelerator::default();
        assert_eq!(a.pes(), 256);
        assert_eq!(a.spm_banks, 16);
        assert!((a.clock_hz - 200e6).abs() < 1.0);
        let t = Technology::default();
        assert!((t.sram_leak_w_per_byte - 0.87e-6).abs() < 1e-12);
        assert!((t.powergate_area_overhead - 0.0275).abs() < 1e-9);
    }

    #[test]
    fn json_roundtrip_exact() {
        let cfg = SystemConfig::default();
        let text = cfg.to_json().to_string_pretty();
        let back = SystemConfig::from_json(&Json::parse(&text).unwrap());
        assert_eq!(cfg, back);
    }

    #[test]
    fn partial_json_uses_defaults() {
        let j = Json::parse(r#"{"accelerator": {"clock_hz": 250e6}}"#).unwrap();
        let cfg = SystemConfig::from_json(&j);
        assert!((cfg.accel.clock_hz - 250e6).abs() < 1.0);
        assert_eq!(cfg.accel.array_rows, 16); // default preserved
        assert_eq!(cfg.tech, Technology::default());
    }

    #[test]
    fn cache_key_distinguishes_technologies() {
        let base = Technology::default();
        assert_eq!(base.cache_key(), Technology::default().cache_key());
        let mut leaky = Technology::default();
        leaky.sram_leak_w_per_byte *= 2.0;
        assert_ne!(base.cache_key(), leaky.cache_key());
        let mut ported = Technology::default();
        ported.sram_dyn_port_exp = 2.0;
        assert_ne!(base.cache_key(), ported.cache_key());
        assert_ne!(leaky.cache_key(), ported.cache_key());
    }

    #[test]
    fn validate_rejects_degenerate_timing_parameters() {
        assert!(SystemConfig::default().validate().is_ok());
        let mut cfg = SystemConfig::default();
        cfg.accel.clock_hz = 0.0;
        assert!(cfg.validate().is_err());
        cfg.accel.clock_hz = f64::NAN;
        assert!(cfg.validate().is_err());
        let mut cfg = SystemConfig::default();
        cfg.tech.dram_bandwidth_bps = f64::INFINITY;
        assert!(cfg.validate().is_err());
        let mut cfg = SystemConfig::default();
        cfg.tech.dram_latency_s = -1.0;
        assert!(cfg.validate().is_err());
        let mut cfg = SystemConfig::default();
        cfg.tech.dram_latency_s = 0.0; // ideal DRAM: allowed
        assert!(cfg.validate().is_ok());
        let mut cfg = SystemConfig::default();
        cfg.accel.spm_bank_fill_bytes = 0;
        assert!(cfg.validate().is_err());
        let mut cfg = SystemConfig::default();
        cfg.accel.spm_banks = 0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn load_rejects_invalid_config_files() {
        let dir = std::env::temp_dir().join("descnet_cfg_invalid_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("zero_clock.json");
        std::fs::write(&path, r#"{"accelerator": {"clock_hz": 0}}"#).unwrap();
        let err = SystemConfig::load(&path).unwrap_err();
        assert!(format!("{err:#}").contains("clock_hz"), "{err:#}");
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join("descnet_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("sys.json");
        let cfg = SystemConfig::default();
        cfg.save(&path).unwrap();
        let back = SystemConfig::load(&path).unwrap();
        assert_eq!(cfg, back);
    }
}
