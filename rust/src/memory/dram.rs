//! Off-chip DRAM model (LPDDR-class edge memory).
//!
//! Energy is charged per byte moved (interface + array, amortized over
//! bursts); a background power term covers refresh/standby attributable to
//! this accelerator.  Latency/bandwidth feed the prefetch latency-hiding
//! check (`memory::prefetch`).

use crate::config::Technology;

pub struct Dram<'t> {
    pub tech: &'t Technology,
}

impl<'t> Dram<'t> {
    pub fn new(tech: &'t Technology) -> Dram<'t> {
        Dram { tech }
    }

    /// Transfer energy for `bytes` moved in either direction [J].
    pub fn transfer_energy_j(&self, bytes: u64) -> f64 {
        bytes as f64 * self.tech.dram_j_per_byte
    }

    /// Background (standby/refresh) energy over an interval [J].
    pub fn background_energy_j(&self, duration_s: f64) -> f64 {
        self.tech.dram_background_w * duration_s
    }

    /// Time to move `bytes` as one streamed burst train [s].
    pub fn transfer_time_s(&self, bytes: u64) -> f64 {
        self.tech.dram_latency_s + bytes as f64 / self.tech.dram_bandwidth_bps
    }

    pub fn bandwidth_bps(&self) -> f64 {
        self.tech.dram_bandwidth_bps
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn energy_linear_in_bytes() {
        let tech = Technology::default();
        let d = Dram::new(&tech);
        let e1 = d.transfer_energy_j(1_000_000);
        let e2 = d.transfer_energy_j(2_000_000);
        assert!((e2 - 2.0 * e1).abs() < 1e-15);
        // ~1.2 nJ/B default -> 1 MB costs ~1.2 mJ.
        assert!((e1 - 1.2e-3).abs() / 1.2e-3 < 1e-9);
    }

    #[test]
    fn transfer_time_includes_latency() {
        let tech = Technology::default();
        let d = Dram::new(&tech);
        let t0 = d.transfer_time_s(0);
        assert!((t0 - 100e-9).abs() < 1e-12);
        let t = d.transfer_time_s(12_800);
        assert!(t > t0);
        assert!((t - (100e-9 + 1e-6)).abs() < 1e-9); // 12.8 kB @ 12.8 GB/s
    }

    #[test]
    fn background_power_over_capsnet_inference() {
        let tech = Technology::default();
        let d = Dram::new(&tech);
        let e = d.background_energy_j(8.6e-3);
        assert!(e > 0.0 && e < 1e-3); // sub-mJ share
    }
}
