//! Prefetch / latency-hiding analysis — verifies the paper's "no
//! performance loss" claim (section III Q2, section VI-D).
//!
//! The DESCNet hierarchy hides off-chip latency by (a) streaming each
//! operation's own weight/data tiles double-buffered *during* the
//! operation, and (b) pre-loading the next operation's first tiles while
//! the current one computes.  Both hold as long as each op's off-chip
//! traffic fits in its own compute window at the effective fill bandwidth;
//! the residue is a stall.
//!
//! The stall physics has exactly **one implementation**: [`analyze`]
//! delegates to the event timeline (`crate::sim::Timeline`), so this
//! report and the DSE latency objective can never disagree about whether
//! the claim holds (it used to be an independent latency+bandwidth model
//! that ignored burst quantization and the SPM fill-port bound; the two
//! drifted by design).  `sim::tests::prefetch_is_the_timeline_bit_exact`
//! pins the delegation.
//!
//! With the calibrated workload model, every CapsNet/DeepCaps op satisfies
//! the bound (the weight-stream-limited ClassCaps included), so the stall
//! count is zero — the claim reproduces.  The analysis still computes
//! stalls for arbitrary configurations (used by the ablation bench that
//! sweeps DRAM bandwidth).

use crate::config::{Accelerator, Technology};
use crate::dataflow::NetworkProfile;
use crate::sim::Timeline;

/// Per-op stall report.
#[derive(Debug, Clone)]
pub struct OpStall {
    pub name: String,
    pub compute_cycles: u64,
    pub required_bytes: u64,
    pub stall_cycles: u64,
}

/// Full latency-hiding analysis of a profile.
#[derive(Debug, Clone)]
pub struct PrefetchReport {
    pub ops: Vec<OpStall>,
    pub total_stall_cycles: u64,
    pub baseline_cycles: u64,
}

impl PrefetchReport {
    /// The paper's claim: the hierarchy adds no cycles over the all-on-chip
    /// baseline.
    pub fn no_performance_loss(&self) -> bool {
        self.total_stall_cycles == 0
    }

    /// Slowdown factor vs the all-on-chip baseline.
    pub fn slowdown(&self) -> f64 {
        (self.baseline_cycles + self.total_stall_cycles) as f64 / self.baseline_cycles as f64
    }
}

/// Analyzes latency hiding: each op must receive its own off-chip reads and
/// emit its writes within its compute window (double-buffered tile
/// streaming overlaps transfer and compute).  Thin view over the event
/// timeline: the per-op stalls *are* `sim::Timeline`'s `dma_stall_cycles`.
pub fn analyze(profile: &NetworkProfile, tech: &Technology, accel: &Accelerator) -> PrefetchReport {
    let tl = Timeline::build(profile, tech, accel);
    let ops = profile
        .ops
        .iter()
        .zip(&tl.ops)
        .map(|(op, slot)| OpStall {
            name: op.name.to_string(),
            compute_cycles: op.cycles,
            required_bytes: op.off_rd + op.off_wr,
            stall_cycles: slot.dma_stall_cycles,
        })
        .collect();
    PrefetchReport {
        ops,
        total_stall_cycles: tl.dma_stall_cycles(),
        baseline_cycles: profile.total_cycles(),
    }
}

/// Minimum DRAM bandwidth [B/s] at which the profile still runs stall-free
/// (for the bandwidth-sensitivity ablation).  Mirrors the timeline's DMA
/// rule: off-chip bytes are padded to whole `dram_burst_bytes` bursts and
/// must drain within the compute window left after one burst latency.  The
/// returned bandwidth is only achievable while it stays below the SPM
/// fill-port bound `spm_banks x spm_bank_fill_bytes x clock` — past that,
/// no DRAM bandwidth removes the stalls (the fill side is the bottleneck).
pub fn min_bandwidth_for_no_loss(
    profile: &NetworkProfile,
    tech: &Technology,
    accel: &Accelerator,
) -> f64 {
    let cycle_s = accel.cycle_s();
    let burst = tech.dram_burst_bytes.max(1) as u64;
    profile
        .ops
        .iter()
        .map(|op| {
            let bytes = op.off_rd + op.off_wr;
            if bytes == 0 {
                return 0.0;
            }
            let padded = bytes.div_ceil(burst) * burst;
            let window = (op.cycles as f64 * cycle_s - tech.dram_latency_s).max(1e-12);
            padded as f64 / window
        })
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::profile_network;
    use crate::model::{capsnet_mnist, deepcaps_cifar10};

    #[test]
    fn capsnet_has_no_performance_loss() {
        // Section VI-D: "there is no performance loss, compared to the
        // CapsNet executed on the baseline CapsAcc".
        let tech = Technology::default();
        let accel = Accelerator::default();
        let p = profile_network(&capsnet_mnist(), &accel);
        let report = analyze(&p, &tech, &accel);
        assert!(
            report.no_performance_loss(),
            "stalls: {:?}",
            report
                .ops
                .iter()
                .filter(|o| o.stall_cycles > 0)
                .collect::<Vec<_>>()
        );
        assert!((report.slowdown() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn deepcaps_has_no_performance_loss() {
        let tech = Technology::default();
        let accel = Accelerator::default();
        let p = profile_network(&deepcaps_cifar10(), &accel);
        assert!(analyze(&p, &tech, &accel).no_performance_loss());
    }

    #[test]
    fn starved_bandwidth_stalls() {
        let mut tech = Technology::default();
        tech.dram_bandwidth_bps = 100e6; // 100 MB/s: far too slow
        let accel = Accelerator::default();
        let p = profile_network(&capsnet_mnist(), &accel);
        let report = analyze(&p, &tech, &accel);
        assert!(!report.no_performance_loss());
        assert!(report.slowdown() > 1.05);
    }

    #[test]
    fn min_bandwidth_is_the_stall_threshold() {
        let tech = Technology::default();
        let accel = Accelerator::default();
        let p = profile_network(&capsnet_mnist(), &accel);
        let min_bw = min_bandwidth_for_no_loss(&p, &tech, &accel);
        assert!(min_bw > 0.0 && min_bw < tech.dram_bandwidth_bps);

        // Just above the threshold: fine; well below: stalls.
        let mut t_ok = Technology::default();
        t_ok.dram_bandwidth_bps = min_bw * 1.01;
        assert!(analyze(&p, &t_ok, &accel).no_performance_loss());
        let mut t_bad = Technology::default();
        t_bad.dram_bandwidth_bps = min_bw * 0.5;
        assert!(!analyze(&p, &t_bad, &accel).no_performance_loss());
    }

    #[test]
    fn report_covers_all_ops() {
        let tech = Technology::default();
        let accel = Accelerator::default();
        let p = profile_network(&capsnet_mnist(), &accel);
        let report = analyze(&p, &tech, &accel);
        assert_eq!(report.ops.len(), p.ops.len());
        assert_eq!(report.baseline_cycles, p.total_cycles());
    }
}
