//! DESCNet SPM organizations (paper Fig 14): Shared Multi-Port (SMP),
//! Separated (SEP), and Hybrid (HY), with per-operation usage *coverage* —
//! which physical memory holds which logical data (the Fig 29/31 memory
//! breakdowns), and validity checks (every operation's working set must fit,
//! Algorithm 1's constraint).

pub mod dram;
pub mod prefetch;

use crate::cacti::SramConfig;
use crate::dataflow::{NetworkProfile, OpProfile};

/// The four physical memories an organization can instantiate.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Component {
    Shared,
    Data,
    Weight,
    Acc,
}

impl Component {
    pub const ALL: [Component; 4] = [
        Component::Shared,
        Component::Data,
        Component::Weight,
        Component::Acc,
    ];

    pub fn label(&self) -> &'static str {
        match self {
            Component::Shared => "shared",
            Component::Data => "data",
            Component::Weight => "weight",
            Component::Acc => "acc",
        }
    }
}

/// Size + sector count of one physical memory.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct MemSpec {
    pub size: usize,
    pub sectors: usize,
}

impl MemSpec {
    pub fn new(size: usize, sectors: usize) -> MemSpec {
        MemSpec { size, sectors }
    }
}

/// Organization kind (design option in the paper's terminology).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OrgKind {
    Smp,
    Sep,
    Hy,
}

impl OrgKind {
    pub fn label(&self) -> &'static str {
        match self {
            OrgKind::Smp => "SMP",
            OrgKind::Sep => "SEP",
            OrgKind::Hy => "HY",
        }
    }

    /// Component presence in `Component::ALL` order [shared, data, weight,
    /// acc], matching the constructor semantics of [`Organization::smp`] /
    /// [`Organization::sep`] / [`Organization::hy`]: SMP instantiates only
    /// the shared memory, SEP only the three dedicated ones, and HY all
    /// four — even at size 0.
    pub fn presence(self) -> [bool; 4] {
        match self {
            OrgKind::Smp => [true, false, false, false],
            OrgKind::Sep => [false, true, true, true],
            OrgKind::Hy => [true, true, true, true],
        }
    }
}

/// A concrete DESCNet organization: which memories exist, their sizes,
/// sector counts and the shared memory's port count.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Organization {
    pub kind: OrgKind,
    pub shared: Option<MemSpec>,
    pub data: Option<MemSpec>,
    pub weight: Option<MemSpec>,
    pub acc: Option<MemSpec>,
    /// Ports of the shared memory (3 in the base design; the Fig 22 study
    /// constrains it to 1 or 2).
    pub shared_ports: usize,
}

impl Organization {
    pub fn smp(shared: MemSpec) -> Organization {
        Organization {
            kind: OrgKind::Smp,
            shared: Some(shared),
            data: None,
            weight: None,
            acc: None,
            shared_ports: 3,
        }
    }

    pub fn sep(data: MemSpec, weight: MemSpec, acc: MemSpec) -> Organization {
        Organization {
            kind: OrgKind::Sep,
            shared: None,
            data: Some(data),
            weight: Some(weight),
            acc: Some(acc),
            shared_ports: 3,
        }
    }

    pub fn hy(
        shared: MemSpec,
        data: MemSpec,
        weight: MemSpec,
        acc: MemSpec,
        shared_ports: usize,
    ) -> Organization {
        Organization {
            kind: OrgKind::Hy,
            shared: Some(shared),
            data: Some(data),
            weight: Some(weight),
            acc: Some(acc),
            shared_ports,
        }
    }

    /// "SEP", "SEP-PG", "HY-PG (P_S=1)", ... as used in the paper's tables.
    pub fn label(&self) -> String {
        let pg = if self.power_gated() { "-PG" } else { "" };
        let ports = if self.kind == OrgKind::Hy && self.shared_ports != 3 {
            format!(" (P_S={})", self.shared_ports)
        } else {
            String::new()
        };
        format!("{}{}{}", self.kind.label(), pg, ports)
    }

    pub fn power_gated(&self) -> bool {
        self.components()
            .iter()
            .any(|(_, spec)| spec.sectors > 1)
    }

    /// The instantiated (component, spec) pairs.
    pub fn components(&self) -> Vec<(Component, MemSpec)> {
        let mut v = Vec::new();
        if let Some(s) = self.shared {
            v.push((Component::Shared, s));
        }
        if let Some(s) = self.data {
            v.push((Component::Data, s));
        }
        if let Some(s) = self.weight {
            v.push((Component::Weight, s));
        }
        if let Some(s) = self.acc {
            v.push((Component::Acc, s));
        }
        v
    }

    pub fn spec(&self, c: Component) -> Option<MemSpec> {
        match c {
            Component::Shared => self.shared,
            Component::Data => self.data,
            Component::Weight => self.weight,
            Component::Acc => self.acc,
        }
    }

    /// SRAM geometry of a component for the CACTI model.
    pub fn sram_config(&self, c: Component) -> Option<SramConfig> {
        let ports = match c {
            Component::Shared => self.shared_ports,
            _ => 1,
        };
        self.spec(c)
            .map(|s| SramConfig::new(s.size, ports, s.sectors))
    }

    pub fn total_size(&self) -> usize {
        self.components().iter().map(|(_, s)| s.size).sum()
    }
}

/// How one operation's working set maps onto the physical memories: bytes
/// of {data, weight, acc} usage held by each component (the paper's Fig
/// 29/31 "memory breakdown").
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Coverage {
    /// Bytes of each logical class in its dedicated memory.
    pub ded_d: usize,
    pub ded_w: usize,
    pub ded_a: usize,
    /// Bytes of each logical class spilled to the shared memory.
    pub sh_d: usize,
    pub sh_w: usize,
    pub sh_a: usize,
}

impl Coverage {
    pub fn shared_total(&self) -> usize {
        self.sh_d + self.sh_w + self.sh_a
    }

    /// Number of distinct value types in the shared memory — the port
    /// requirement of this op for the Fig 22 / Appendix B.2 analysis.
    pub fn shared_types(&self) -> usize {
        [self.sh_d, self.sh_w, self.sh_a]
            .iter()
            .filter(|&&b| b > 0)
            .count()
    }
}

/// Maps an op's usage onto an organization: dedicated memories absorb up to
/// their size; the remainder spills to the shared memory (Algorithm 1's
/// residual rule).  Returns None if the op does not fit.
pub fn cover_op(org: &Organization, op: &OpProfile) -> Option<Coverage> {
    let cap = |c: Component| org.spec(c).map(|s| s.size).unwrap_or(0);
    let ded_d = op.usage_d.min(cap(Component::Data));
    let ded_w = op.usage_w.min(cap(Component::Weight));
    let ded_a = op.usage_a.min(cap(Component::Acc));
    let cov = Coverage {
        ded_d,
        ded_w,
        ded_a,
        sh_d: op.usage_d - ded_d,
        sh_w: op.usage_w - ded_w,
        sh_a: op.usage_a - ded_a,
    };
    if cov.shared_total() <= cap(Component::Shared) {
        Some(cov)
    } else {
        None
    }
}

/// Whether every operation of the profile fits this organization
/// (Algorithm 1's "still guarantees the minimum memory usage required by
/// each operation").
pub fn org_fits(org: &Organization, profile: &NetworkProfile) -> bool {
    profile.ops.iter().all(|op| cover_op(org, op).is_some())
}

/// Max over ops of the number of value types simultaneously in the shared
/// memory — the minimum port count the shared memory actually needs
/// (Appendix B.2's observation enabling the P_S-constrained study).
pub fn required_shared_ports(org: &Organization, profile: &NetworkProfile) -> usize {
    profile
        .ops
        .iter()
        .filter_map(|op| cover_op(org, op).map(|c| c.shared_types()))
        .max()
        .unwrap_or(0)
}

/// Per-op accesses routed to one component under a coverage (for energy):
/// accesses split proportionally to the covered fraction of each class.
pub fn component_accesses(op: &OpProfile, cov: &Coverage, c: Component) -> f64 {
    let frac = |ded: usize, total: usize| {
        if total == 0 {
            0.0
        } else {
            ded as f64 / total as f64
        }
    };
    let d_acc = (op.rd_d + op.wr_d) as f64;
    let w_acc = (op.rd_w + op.wr_w) as f64;
    let a_acc = (op.rd_a + op.wr_a) as f64;
    match c {
        Component::Data => d_acc * frac(cov.ded_d, op.usage_d.max(1)),
        Component::Weight => w_acc * frac(cov.ded_w, op.usage_w.max(1)),
        Component::Acc => a_acc * frac(cov.ded_a, op.usage_a.max(1)),
        Component::Shared => {
            d_acc * frac(cov.sh_d, op.usage_d.max(1))
                + w_acc * frac(cov.sh_w, op.usage_w.max(1))
                + a_acc * frac(cov.sh_a, op.usage_a.max(1))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Accelerator;
    use crate::dataflow::profile_network;
    use crate::model::capsnet_mnist;
    use crate::util::units::KIB;

    fn profile() -> NetworkProfile {
        profile_network(&capsnet_mnist(), &Accelerator::default())
    }

    fn table1_sep() -> Organization {
        Organization::sep(
            MemSpec::new(25 * KIB, 1),
            MemSpec::new(64 * KIB, 1),
            MemSpec::new(32 * KIB, 1),
        )
    }

    #[test]
    fn table1_sep_fits_capsnet() {
        assert!(org_fits(&table1_sep(), &profile()));
    }

    #[test]
    fn table1_smp_fits_capsnet() {
        let org = Organization::smp(MemSpec::new(108 * KIB, 1));
        assert!(org_fits(&org, &profile()));
        // ...but a 64 kiB SMP does not (max total usage is 66.8 kiB).
        let small = Organization::smp(MemSpec::new(64 * KIB, 1));
        assert!(!org_fits(&small, &profile()));
    }

    #[test]
    fn table1_hy_pg_fits_capsnet() {
        // Paper Table I HY-PG row: shared 32k/2, data 25k/2, w 25k/4, acc 32k/2.
        let org = Organization::hy(
            MemSpec::new(32 * KIB, 2),
            MemSpec::new(25 * KIB, 2),
            MemSpec::new(25 * KIB, 4),
            MemSpec::new(32 * KIB, 2),
            3,
        );
        assert!(org_fits(&org, &profile()));
        assert!(org.power_gated());
        assert_eq!(org.label(), "HY-PG");
    }

    #[test]
    fn sep_without_shared_rejects_oversized_ops() {
        let tiny = Organization::sep(
            MemSpec::new(8 * KIB, 1),
            MemSpec::new(64 * KIB, 1),
            MemSpec::new(32 * KIB, 1),
        );
        // Prim's 22.5 kiB data window exceeds 8 kiB and there is no shared
        // memory to spill into.
        assert!(!org_fits(&tiny, &profile()));
    }

    #[test]
    fn hy_spills_to_shared() {
        let p = profile();
        let org = Organization::hy(
            MemSpec::new(32 * KIB, 1),
            MemSpec::new(8 * KIB, 1),
            MemSpec::new(32 * KIB, 1),
            MemSpec::new(16 * KIB, 1),
            3,
        );
        let prim = p.op("Prim").unwrap();
        let cov = cover_op(&org, prim).expect("fits");
        assert_eq!(cov.ded_d, 8 * KIB);
        assert_eq!(cov.sh_d, prim.usage_d - 8 * KIB);
        assert_eq!(cov.ded_w, 32 * KIB);
        assert_eq!(cov.sh_w, prim.usage_w - 32 * KIB);
        assert!(cov.shared_total() <= 32 * KIB);
    }

    #[test]
    fn coverage_conserves_usage() {
        let p = profile();
        let org = Organization::hy(
            MemSpec::new(32 * KIB, 2),
            MemSpec::new(25 * KIB, 2),
            MemSpec::new(25 * KIB, 4),
            MemSpec::new(32 * KIB, 2),
            3,
        );
        for op in &p.ops {
            let cov = cover_op(&org, op).unwrap();
            assert_eq!(cov.ded_d + cov.sh_d, op.usage_d, "{}", op.name);
            assert_eq!(cov.ded_w + cov.sh_w, op.usage_w, "{}", op.name);
            assert_eq!(cov.ded_a + cov.sh_a, op.usage_a, "{}", op.name);
        }
    }

    #[test]
    fn component_accesses_partition_totals() {
        let p = profile();
        let org = Organization::hy(
            MemSpec::new(32 * KIB, 1),
            MemSpec::new(8 * KIB, 1),
            MemSpec::new(32 * KIB, 1),
            MemSpec::new(16 * KIB, 1),
            3,
        );
        for op in &p.ops {
            let cov = cover_op(&org, op).unwrap();
            let total: f64 = Component::ALL
                .iter()
                .map(|&c| component_accesses(op, &cov, c))
                .sum();
            let expected = op.spm_accesses() as f64;
            assert!(
                (total - expected).abs() / expected.max(1.0) < 1e-9,
                "{}: {total} vs {expected}",
                op.name
            );
        }
    }

    #[test]
    fn required_ports_reflect_spill_diversity() {
        let p = profile();
        // Huge dedicated memories: nothing spills -> 0 ports needed.
        let all_ded = Organization::hy(
            MemSpec::new(128 * KIB, 1),
            MemSpec::new(64 * KIB, 1),
            MemSpec::new(64 * KIB, 1),
            MemSpec::new(64 * KIB, 1),
            3,
        );
        assert_eq!(required_shared_ports(&all_ded, &p), 0);
        // No dedicated memories at all: everything spills -> 3 types.
        let all_shared = Organization::hy(
            MemSpec::new(108 * KIB, 1),
            MemSpec::new(0, 1),
            MemSpec::new(0, 1),
            MemSpec::new(0, 1),
            3,
        );
        assert_eq!(required_shared_ports(&all_shared, &p), 3);
    }

    #[test]
    fn labels_match_paper_terms() {
        assert_eq!(table1_sep().label(), "SEP");
        assert_eq!(
            Organization::smp(MemSpec::new(108 * KIB, 2)).label(),
            "SMP-PG"
        );
        let mut hy1 = Organization::hy(
            MemSpec::new(4096 * KIB, 8),
            MemSpec::new(256 * KIB, 8),
            MemSpec::new(128 * KIB, 16),
            MemSpec::new(2048 * KIB, 4),
            1,
        );
        assert_eq!(hy1.label(), "HY-PG (P_S=1)");
        hy1.shared_ports = 3;
        assert_eq!(hy1.label(), "HY-PG");
    }

    #[test]
    fn total_size_sums_components() {
        assert_eq!(table1_sep().total_size(), (25 + 64 + 32) * KIB);
    }
}
