//! Deterministic fault injection for the fleet simulator (DESIGN.md
//! section 15).
//!
//! A production fleet is defined by how it behaves when shards die.  This
//! module adds the fault model around `fleet::simulate` without touching
//! its no-fault behavior:
//!
//! * **Crash/recover schedule** — each shard draws alternating up-times
//!   (mean [`FaultConfig::mtbf_s`]) and down-times (mean
//!   [`FaultConfig::mttr_s`]) from its own PRNG stream,
//!   `Prng::stream(fault_seed, shard)`.  Streams are split at seeding
//!   time, so the schedule is a pure function of `(fault_seed, mtbf,
//!   mttr, wake penalty)` — independent of arrivals, routing and thread
//!   counts — and the arrival stream (`Prng::new(seed)`) is bit-identical
//!   with injection on or off.
//! * **Degraded-mode semantics** — a crash fails the in-flight batch; its
//!   requests are re-enqueued on an up shard or dropped per
//!   [`CrashPolicy`].  Recovery pays the power-gating cold-wake charge
//!   (`ShardPlan::wake_penalty_s`, the `sim::wakeup_exposure_s` rule with
//!   no previous op to mask it), extending the outage.
//! * **Timeout + bounded retry + hedging** — a queued request that waits
//!   out [`FaultConfig::timeout_s`] is pulled back and re-dispatched up
//!   to [`FaultConfig::retries`] times with exponential backoff
//!   ([`backoff_s`]); past the budget it is dropped.  With
//!   [`FaultConfig::hedge_s`], a request still waiting after that delay
//!   is duplicated onto the least-loaded *other* up shard; the first copy
//!   to start service wins and the loser is cancelled.
//!
//! The conservation invariant the whole model is tested against
//! (`rust/tests/fleet_faults.rs`): every arrival is eventually counted
//! exactly once as completed or dropped, and timeout retries never exceed
//! `retries` per request.

use anyhow::{bail, ensure, Result};

use crate::util::prng::Prng;

/// What happens to the in-flight batch of a crashing shard.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CrashPolicy {
    /// Failed requests are re-enqueued (routed among up shards) at the
    /// crash instant.  Crash re-enqueues do not consume the timeout-retry
    /// budget — they are the router's doing, not the client's.
    Requeue,
    /// Failed requests are dropped (counted in `FleetStats::dropped`).
    Drop,
}

impl CrashPolicy {
    pub fn parse(s: &str) -> Result<CrashPolicy> {
        match s {
            "requeue" => Ok(CrashPolicy::Requeue),
            "drop" => Ok(CrashPolicy::Drop),
            other => bail!("unknown crash policy '{other}' (expected requeue or drop)"),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            CrashPolicy::Requeue => "requeue",
            CrashPolicy::Drop => "drop",
        }
    }
}

/// Fault-injection knobs of one simulation run.  The default is fully
/// inert: `mtbf_s = inf`, no timeout, no hedging, nothing pinned down —
/// a run with the default config is bit-identical to a run with no fault
/// config at all (pinned by `rust/tests/fleet_faults.rs`).
#[derive(Debug, Clone)]
pub struct FaultConfig {
    /// Mean time between failures per shard [s]; `f64::INFINITY` disables
    /// crash injection.
    pub mtbf_s: f64,
    /// Mean time to recover per shard [s] (the cold-wake charge is added
    /// on top of each drawn down-time).
    pub mttr_s: f64,
    /// Per-copy queue-wait timeout [s]; `None` disables timeouts.
    pub timeout_s: Option<f64>,
    /// Max timeout-driven re-dispatches per request; past this the
    /// request is dropped.
    pub retries: u32,
    /// Hedged re-dispatch delay [s]; `None` disables hedging.
    pub hedge_s: Option<f64>,
    /// Seed of the crash/recover schedule (dedicated stream, split from
    /// the arrival stream).
    pub fault_seed: u64,
    pub crash_policy: CrashPolicy,
    /// Shards held down for the entire run (degraded-capacity what-ifs
    /// and the N+1 provisioning check).  Must leave at least one shard up.
    pub pinned_down: Vec<usize>,
}

impl Default for FaultConfig {
    fn default() -> FaultConfig {
        FaultConfig {
            mtbf_s: f64::INFINITY,
            mttr_s: 1.0,
            timeout_s: None,
            retries: 2,
            hedge_s: None,
            fault_seed: 0,
            crash_policy: CrashPolicy::Requeue,
            pinned_down: Vec::new(),
        }
    }
}

impl FaultConfig {
    /// True when any fault mechanism can fire.  The simulator uses this to
    /// gate every fault-path branch, so an inert config cannot perturb the
    /// no-fault event sequence (the injection-off bit-identity invariant).
    pub fn is_active(&self) -> bool {
        self.mtbf_s.is_finite()
            || self.timeout_s.is_some()
            || self.hedge_s.is_some()
            || !self.pinned_down.is_empty()
    }

    /// Validates against a fleet of `shards` shards.
    pub fn validate(&self, shards: usize) -> Result<()> {
        ensure!(
            self.mtbf_s > 0.0 && !self.mtbf_s.is_nan(),
            "MTBF must be a positive duration (or inf to disable), got {} s",
            self.mtbf_s
        );
        if self.mtbf_s.is_finite() {
            ensure!(
                self.mttr_s.is_finite() && self.mttr_s > 0.0,
                "MTTR must be a positive finite duration, got {} s",
                self.mttr_s
            );
        }
        if let Some(t) = self.timeout_s {
            ensure!(
                t.is_finite() && t > 0.0,
                "request timeout must be a positive duration, got {t} s"
            );
        }
        if let Some(h) = self.hedge_s {
            ensure!(
                h.is_finite() && h > 0.0,
                "hedge delay must be a positive duration, got {h} s"
            );
        }
        for &s in &self.pinned_down {
            ensure!(
                s < shards,
                "pinned-down shard {s} out of range (fleet has {shards})"
            );
        }
        let mut down = vec![false; shards];
        for &s in &self.pinned_down {
            down[s] = true;
        }
        ensure!(
            down.iter().any(|d| !d),
            "every shard is pinned down — the fleet could never serve"
        );
        Ok(())
    }
}

/// Exponential backoff before timeout-retry `attempt` (1-based):
/// `timeout * 2^(attempt-1)`, capped at 2^20 to keep the product finite
/// for absurd retry budgets.
pub fn backoff_s(timeout_s: f64, attempt: u32) -> f64 {
    timeout_s * (1u64 << (attempt.saturating_sub(1)).min(20)) as f64
}

/// One shard's lazily-drawn crash/recover schedule.  Draws alternate
/// up-time, down-time, up-time, ... from a dedicated per-shard stream, so
/// the k-th draw of shard `s` is the same number no matter what the rest
/// of the simulation does.
#[derive(Debug, Clone)]
pub struct ShardFaults {
    rng: Prng,
    mtbf_s: f64,
    mttr_s: f64,
}

impl ShardFaults {
    pub fn new(fault_seed: u64, shard: usize, mtbf_s: f64, mttr_s: f64) -> ShardFaults {
        ShardFaults {
            rng: Prng::stream(fault_seed, shard as u64),
            mtbf_s,
            mttr_s,
        }
    }

    /// Next up-time duration [s] (time until the next crash).
    pub fn uptime_s(&mut self) -> f64 {
        self.rng.exp(self.mtbf_s)
    }

    /// Next down-time duration [s] (recovery delay, before the cold-wake
    /// charge is added).
    pub fn downtime_s(&mut self) -> f64 {
        self.rng.exp(self.mttr_s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_is_inert_and_valid() {
        let c = FaultConfig::default();
        assert!(!c.is_active());
        c.validate(2).unwrap();
    }

    #[test]
    fn activity_is_any_mechanism() {
        let mut c = FaultConfig::default();
        c.mtbf_s = 10.0;
        assert!(c.is_active());
        let mut c = FaultConfig::default();
        c.timeout_s = Some(0.1);
        assert!(c.is_active());
        let mut c = FaultConfig::default();
        c.hedge_s = Some(0.05);
        assert!(c.is_active());
        let mut c = FaultConfig::default();
        c.pinned_down = vec![0];
        assert!(c.is_active());
    }

    #[test]
    fn validation_rejects_degenerate_knobs() {
        let mut c = FaultConfig::default();
        c.mtbf_s = 0.0;
        assert!(c.validate(2).is_err());
        let mut c = FaultConfig::default();
        c.mtbf_s = 5.0;
        c.mttr_s = f64::INFINITY;
        assert!(c.validate(2).is_err());
        let mut c = FaultConfig::default();
        c.timeout_s = Some(-1.0);
        assert!(c.validate(2).is_err());
        let mut c = FaultConfig::default();
        c.hedge_s = Some(f64::NAN);
        assert!(c.validate(2).is_err());
        let mut c = FaultConfig::default();
        c.pinned_down = vec![2];
        assert!(c.validate(2).is_err());
        let mut c = FaultConfig::default();
        c.pinned_down = vec![0, 1];
        assert!(c.validate(2).is_err());
        let mut c = FaultConfig::default();
        c.pinned_down = vec![1];
        c.validate(2).unwrap();
    }

    #[test]
    fn crash_policy_roundtrip() {
        for (s, p) in [("requeue", CrashPolicy::Requeue), ("drop", CrashPolicy::Drop)] {
            assert_eq!(CrashPolicy::parse(s).unwrap(), p);
            assert_eq!(p.label(), s);
        }
        assert!(CrashPolicy::parse("retry").is_err());
    }

    #[test]
    fn backoff_doubles_and_saturates() {
        assert_eq!(backoff_s(0.1, 1), 0.1);
        assert_eq!(backoff_s(0.1, 2), 0.2);
        assert_eq!(backoff_s(0.1, 3), 0.4);
        assert!(backoff_s(0.1, 1_000).is_finite());
    }

    #[test]
    fn schedules_are_per_shard_deterministic() {
        let draw = |shard: usize| {
            let mut f = ShardFaults::new(9, shard, 5.0, 0.5);
            (0..6).map(|_| f.uptime_s()).collect::<Vec<_>>()
        };
        assert_eq!(draw(0), draw(0));
        assert_ne!(draw(0), draw(1));
        // Independent of the arrival seed by construction: the stream is
        // keyed on (fault_seed, shard) only.
        let mut a = ShardFaults::new(9, 0, 5.0, 0.5);
        let up = a.uptime_s();
        let down = a.downtime_s();
        assert!(up > 0.0 && down > 0.0);
    }
}
