//! Sharded multi-accelerator serving simulator (DESIGN.md section 12).
//!
//! DESCNet's headline result is per-instance: one CapsAcc accelerator, one
//! SPM organization, 79% energy reduction with no performance loss.  The
//! ROADMAP's north star is a serving *fleet* of such instances.  This
//! module closes the gap with two layers:
//!
//! * **[`simulate`]** — a seeded, deterministic discrete-event simulator of
//!   N accelerator shards: open-loop Poisson request arrivals
//!   (`util::prng`), per-shard FIFO queues batched by the same
//!   `coordinator::batcher::BatchPolicy` the single-instance server uses,
//!   pluggable routing policies ([`RoutingPolicy`]: round-robin,
//!   join-shortest-queue, energy-aware), per-batch service times charged
//!   from the timeline simulator (`sim::simulate`), and fleet-level rollups
//!   ([`FleetStats`]: p50/p95/p99 latency, SLO attainment,
//!   energy-per-request, per-shard utilization).  The event loop is serial
//!   and fully ordered (event time ties broken by insertion sequence), so
//!   a (seed, plans, config) triple reproduces bit-identically regardless
//!   of how many threads the surrounding design pass used.
//!
//! * **[`design_fleet`]** — an SLO-constrained fleet co-design pass that
//!   extends `dse::multi`: each shard's SPM organization is selected per
//!   workload (or one organization co-designed across every shard with
//!   `homogeneous`), under a fleet-wide energy objective with the SLO as a
//!   hard constraint on the smallest executable batch's simulated latency.
//!   The result carries a homogeneous union-SMP baseline fleet evaluated
//!   under the *same* executable batch sets, so the energy comparison is
//!   schedule-for-schedule (`rust/tests/fleet.rs` pins codesigned <=
//!   baseline).
//!
//! Surfaced as `descnet fleet --shards N --rps R --policy P --slo-ms MS`,
//! `descnet report fleet` (fleet.csv + table_fleet.md) and
//! `examples/fleet_serving.rs`; EXPERIMENTS.md E22 records the numbers.

use std::collections::{BTreeMap, BinaryHeap, VecDeque};

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::config::SystemConfig;
use crate::coordinator::batcher::BatchPolicy;
use crate::dataflow::{profile_network_batched, NetworkProfile};
use crate::dse::multi::WorkloadSet;
use crate::dse::{self, DsePoint};
use crate::energy::system_with_org;
use crate::memory::{MemSpec, Organization};
use crate::model::Network;
use crate::sim;
use crate::util::exec::Engine;
use crate::util::prng::Prng;
use crate::util::stats::Percentiles;

// ------------------------------------------------------------------ routing

/// How arrivals are routed to shards.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutingPolicy {
    /// Cyclic assignment, blind to queue state.
    RoundRobin,
    /// Fewest outstanding requests (queued + in service); ties to the
    /// lowest shard index.
    Jsq,
    /// Among the shards within one request of the shortest queue, the one
    /// with the lowest per-inference energy at its largest batch — spends
    /// queue slack on the cheapest silicon without sacrificing latency.
    EnergyAware,
}

impl RoutingPolicy {
    pub fn parse(s: &str) -> Result<RoutingPolicy> {
        match s {
            "rr" | "round-robin" => Ok(RoutingPolicy::RoundRobin),
            "jsq" | "join-shortest-queue" => Ok(RoutingPolicy::Jsq),
            "energy" | "energy-aware" => Ok(RoutingPolicy::EnergyAware),
            other => bail!("unknown routing policy '{other}' (expected rr, jsq or energy)"),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            RoutingPolicy::RoundRobin => "rr",
            RoutingPolicy::Jsq => "jsq",
            RoutingPolicy::EnergyAware => "energy",
        }
    }
}

// -------------------------------------------------------------- shard plans

/// Everything one shard needs to serve: its workload label, organization,
/// executable batch sizes and the pre-simulated per-batch energy/latency.
/// Plans come from [`design_fleet`] (DSE-backed) or [`ShardPlan::synthetic`]
/// (closed-form, for property tests and benches).
#[derive(Debug, Clone)]
pub struct ShardPlan {
    pub workload: String,
    pub org: Organization,
    pub batcher: BatchPolicy,
    /// Per-inference system energy [J] by executable batch size.
    pub energy_per_inf: BTreeMap<usize, f64>,
    /// Simulated end-to-end *batch* latency [s] by executable batch size.
    pub batch_latency_s: BTreeMap<usize, f64>,
    /// Clock-binning speed factor: service time divides by this (1.0 =
    /// nominal silicon; used to model asymmetric fleets).
    pub speed: f64,
}

impl ShardPlan {
    pub fn new(
        workload: &str,
        org: Organization,
        batcher: BatchPolicy,
        energy_per_inf: BTreeMap<usize, f64>,
        batch_latency_s: BTreeMap<usize, f64>,
        speed: f64,
    ) -> Result<ShardPlan> {
        ensure!(
            speed.is_finite() && speed > 0.0,
            "shard speed must be positive, got {speed}"
        );
        for &b in &batcher.sizes {
            let e = energy_per_inf
                .get(&b)
                .ok_or_else(|| anyhow!("no energy for executable batch {b}"))?;
            let l = batch_latency_s
                .get(&b)
                .ok_or_else(|| anyhow!("no latency for executable batch {b}"))?;
            ensure!(
                e.is_finite() && *e >= 0.0 && l.is_finite() && *l > 0.0,
                "degenerate per-batch cost for batch {b}: {e} J, {l} s"
            );
        }
        Ok(ShardPlan {
            workload: workload.to_string(),
            org,
            batcher,
            energy_per_inf,
            batch_latency_s,
            speed,
        })
    }

    /// Synthetic closed-form plan (no DSE): batch latency grows linearly
    /// with the batch while per-inference energy amortizes — the shape the
    /// real timeline produces, without its cost.  For tests and benches.
    pub fn synthetic(
        workload: &str,
        batch_sizes: Vec<usize>,
        base_latency_s: f64,
        energy_per_inf_j: f64,
        speed: f64,
        flush_deadline_s: f64,
    ) -> Result<ShardPlan> {
        let batcher = BatchPolicy::new(batch_sizes, flush_deadline_s)?;
        let mut energy = BTreeMap::new();
        let mut latency = BTreeMap::new();
        for &b in &batcher.sizes {
            latency.insert(b, base_latency_s * (0.5 + 0.5 * b as f64));
            energy.insert(b, energy_per_inf_j * (0.5 + 0.5 / b as f64));
        }
        ShardPlan::new(
            workload,
            Organization::smp(MemSpec::new(64 * 1024, 1)),
            batcher,
            energy,
            latency,
            speed,
        )
    }

    /// Service time of one executed batch of size `b` on this shard [s].
    pub fn service_time_s(&self, b: usize) -> f64 {
        self.batch_latency_s[&b] / self.speed
    }

    /// Per-inference energy at the largest executable batch — the routing
    /// figure of merit for [`RoutingPolicy::EnergyAware`].
    pub fn best_energy_per_inf(&self) -> f64 {
        self.energy_per_inf[&self.batcher.max_batch()]
    }
}

// ------------------------------------------------------------ fleet config

/// Arrival process + routing knobs of one simulation run.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// Open-loop mean arrival rate [requests/s].
    pub rps: f64,
    /// Total requests injected.
    pub requests: usize,
    pub seed: u64,
    pub policy: RoutingPolicy,
    /// End-to-end latency SLO [s] for the attainment rollup (and the hard
    /// design constraint when passed to [`design_fleet`]).
    pub slo_s: Option<f64>,
}

impl Default for FleetConfig {
    fn default() -> FleetConfig {
        FleetConfig {
            rps: 100.0,
            requests: 400,
            seed: 7,
            policy: RoutingPolicy::Jsq,
            slo_s: None,
        }
    }
}

impl FleetConfig {
    fn validate(&self) -> Result<()> {
        ensure!(
            self.rps.is_finite() && self.rps > 0.0,
            "fleet rps must be positive, got {}",
            self.rps
        );
        ensure!(self.requests > 0, "fleet needs at least one request");
        if let Some(slo) = self.slo_s {
            ensure!(
                slo.is_finite() && slo > 0.0,
                "SLO must be a positive duration, got {slo} s"
            );
        }
        Ok(())
    }
}

// ------------------------------------------------------------------- stats

/// Per-shard rollup of one simulation run.
#[derive(Debug, Clone)]
pub struct ShardStats {
    pub workload: String,
    pub org_label: String,
    pub served: u64,
    pub batches: u64,
    pub padded_slots: u64,
    pub busy_s: f64,
    pub queue_peak: usize,
    pub energy_j: f64,
    pub slo_met: u64,
    pub latency: Percentiles,
}

impl ShardStats {
    /// Fraction of the simulated horizon this shard spent executing.
    pub fn utilization(&self, horizon_s: f64) -> f64 {
        if horizon_s > 0.0 {
            self.busy_s / horizon_s
        } else {
            0.0
        }
    }

    /// Fraction of this shard's requests served within the SLO (1.0 when
    /// no SLO was configured).
    pub fn slo_attainment(&self, slo_s: Option<f64>) -> f64 {
        if slo_s.is_some() && self.served > 0 {
            self.slo_met as f64 / self.served as f64
        } else {
            1.0
        }
    }

    /// This shard's energy per served request [J].
    pub fn energy_per_request_j(&self) -> f64 {
        self.energy_j / self.served.max(1) as f64
    }
}

/// Fleet-level rollup of one simulation run.
#[derive(Debug, Clone)]
pub struct FleetStats {
    pub policy: RoutingPolicy,
    pub requests: u64,
    pub batches: u64,
    pub padded_slots: u64,
    /// Simulated time of the last completion [s].
    pub sim_time_s: f64,
    /// Discrete events processed (arrivals + completions + flushes) — the
    /// bench throughput unit.
    pub events: u64,
    pub energy_j: f64,
    pub slo_s: Option<f64>,
    pub slo_met: u64,
    /// End-to-end (enqueue -> completion) request latency.
    pub latency: Percentiles,
    pub per_shard: Vec<ShardStats>,
}

impl FleetStats {
    pub fn throughput_rps(&self) -> f64 {
        if self.sim_time_s > 0.0 {
            self.requests as f64 / self.sim_time_s
        } else {
            0.0
        }
    }

    pub fn energy_per_request_j(&self) -> f64 {
        self.energy_j / self.requests.max(1) as f64
    }

    pub fn slo_attainment(&self) -> f64 {
        if self.slo_s.is_some() && self.requests > 0 {
            self.slo_met as f64 / self.requests as f64
        } else {
            1.0
        }
    }

    /// Bit-exact digest of every rollup (floats as hex bit patterns): the
    /// determinism tests compare this across thread counts, and the golden
    /// test pins it per (seed, config).
    pub fn fingerprint(&mut self) -> String {
        let h = |v: f64| format!("{:016x}", v.to_bits());
        let mut out = format!(
            "policy={} requests={} batches={} padded={} events={} sim_time={} energy={} \
             p50={} p95={} p99={} slo_met={}",
            self.policy.label(),
            self.requests,
            self.batches,
            self.padded_slots,
            self.events,
            h(self.sim_time_s),
            h(self.energy_j),
            h(self.latency.p50()),
            h(self.latency.p95()),
            h(self.latency.p99()),
            self.slo_met,
        );
        for (i, s) in self.per_shard.iter().enumerate() {
            out.push_str(&format!(
                " | s{i}[{}] served={} batches={} padded={} busy={} peak={} energy={} slo_met={}",
                s.workload,
                s.served,
                s.batches,
                s.padded_slots,
                h(s.busy_s),
                s.queue_peak,
                h(s.energy_j),
                s.slo_met,
            ));
        }
        out
    }

    /// Human-readable report (the `descnet fleet` stdout).
    pub fn summary(&mut self) -> String {
        use crate::util::units::{fmt_energy, fmt_time};
        let mut out = String::new();
        out.push_str(&format!(
            "fleet: {} shards, policy {}, {} requests in {} simulated ({:.1} req/s)\n",
            self.per_shard.len(),
            self.policy.label(),
            self.requests,
            fmt_time(self.sim_time_s),
            self.throughput_rps(),
        ));
        out.push_str(&format!(
            "latency: p50 {}  p95 {}  p99 {}\n",
            fmt_time(self.latency.p50()),
            fmt_time(self.latency.p95()),
            fmt_time(self.latency.p99()),
        ));
        if let Some(slo) = self.slo_s {
            out.push_str(&format!(
                "SLO {}: {:.1}% attainment ({}/{} within)\n",
                fmt_time(slo),
                100.0 * self.slo_attainment(),
                self.slo_met,
                self.requests,
            ));
        }
        out.push_str(&format!(
            "energy: {} per request ({} total, {} batches, {} padded slots)\n",
            fmt_energy(self.energy_per_request_j()),
            fmt_energy(self.energy_j),
            self.batches,
            self.padded_slots,
        ));
        let horizon = self.sim_time_s;
        for (i, s) in self.per_shard.iter().enumerate() {
            out.push_str(&format!(
                "shard {i} [{} | {}]: served {}, {} batches, util {:.1}%, peak queue {}\n",
                s.workload,
                s.org_label,
                s.served,
                s.batches,
                100.0 * s.utilization(horizon),
                s.queue_peak,
            ));
        }
        out
    }
}

// ------------------------------------------------------------- event engine

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum EvKind {
    Arrival,
    ShardDone(usize),
    Flush(usize),
}

/// Heap entry; ordered min-first by (time, insertion sequence), so
/// simultaneous events resolve deterministically in insertion order.
#[derive(Debug, Clone, Copy)]
struct Ev {
    t: f64,
    seq: u64,
    kind: EvKind,
}

impl PartialEq for Ev {
    fn eq(&self, other: &Ev) -> bool {
        self.cmp(other) == std::cmp::Ordering::Equal
    }
}
impl Eq for Ev {}
impl PartialOrd for Ev {
    fn partial_cmp(&self, other: &Ev) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Ev {
    fn cmp(&self, other: &Ev) -> std::cmp::Ordering {
        // Reversed: BinaryHeap is a max-heap, we want the earliest event.
        other
            .t
            .total_cmp(&self.t)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

#[derive(Debug, Clone, Copy)]
struct QueuedReq {
    arrival: f64,
    /// `arrival + flush_deadline`, precomputed so the flush comparison uses
    /// the exact float the flush event was scheduled with.
    deadline_t: f64,
}

/// Runs the discrete-event fleet simulation.  Serial and deterministic:
/// the only randomness is the seeded arrival process.
pub fn simulate(plans: &[ShardPlan], cfg: &FleetConfig) -> Result<FleetStats> {
    ensure!(!plans.is_empty(), "fleet needs at least one shard");
    cfg.validate()?;
    let n = plans.len();

    let mut rng = Prng::new(cfg.seed);
    let mean_gap = 1.0 / cfg.rps;
    let mut heap: BinaryHeap<Ev> = BinaryHeap::new();
    let mut seq = 0u64;

    let mut queues: Vec<VecDeque<QueuedReq>> = vec![VecDeque::new(); n];
    let mut busy = vec![false; n];
    // Arrival times of the requests currently executing on each shard.
    let mut exec: Vec<Vec<f64>> = vec![Vec::new(); n];
    // One outstanding flush event per shard at most — re-dispatching while
    // one is pending must not enqueue another (it would inflate the event
    // count and do redundant work when it fires).
    let mut flush_pending = vec![false; n];
    let mut rr_next = 0usize;
    let mut arrivals_left = cfg.requests;

    let mut stats = FleetStats {
        policy: cfg.policy,
        requests: 0,
        batches: 0,
        padded_slots: 0,
        sim_time_s: 0.0,
        events: 0,
        energy_j: 0.0,
        slo_s: cfg.slo_s,
        slo_met: 0,
        latency: Percentiles::new(),
        per_shard: plans
            .iter()
            .map(|p| ShardStats {
                workload: p.workload.clone(),
                org_label: p.org.label(),
                served: 0,
                batches: 0,
                padded_slots: 0,
                busy_s: 0.0,
                queue_peak: 0,
                energy_j: 0.0,
                slo_met: 0,
                latency: Percentiles::new(),
            })
            .collect(),
    };

    heap.push(Ev {
        t: rng.exp(mean_gap),
        seq,
        kind: EvKind::Arrival,
    });
    seq += 1;

    while let Some(ev) = heap.pop() {
        stats.events += 1;
        match ev.kind {
            EvKind::Arrival => {
                arrivals_left -= 1;
                if arrivals_left > 0 {
                    heap.push(Ev {
                        t: ev.t + rng.exp(mean_gap),
                        seq,
                        kind: EvKind::Arrival,
                    });
                    seq += 1;
                }
                let s = route(cfg.policy, plans, &queues, &exec, &mut rr_next);
                queues[s].push_back(QueuedReq {
                    arrival: ev.t,
                    deadline_t: ev.t + plans[s].batcher.flush_deadline_s,
                });
                stats.per_shard[s].queue_peak = stats.per_shard[s].queue_peak.max(queues[s].len());
                dispatch(
                    s,
                    ev.t,
                    plans,
                    &mut queues,
                    &mut busy,
                    &mut exec,
                    &mut flush_pending,
                    arrivals_left,
                    &mut stats,
                    &mut heap,
                    &mut seq,
                );
            }
            EvKind::ShardDone(s) => {
                busy[s] = false;
                // The horizon is the last *completion*: a stale flush event
                // (scheduled while waiting, overtaken by a full batch) may
                // pop later, but it must not stretch the utilization base.
                stats.sim_time_s = ev.t;
                for arrival in std::mem::take(&mut exec[s]) {
                    let lat = ev.t - arrival;
                    stats.latency.add(lat);
                    stats.per_shard[s].latency.add(lat);
                    stats.per_shard[s].served += 1;
                    stats.requests += 1;
                    if let Some(slo) = cfg.slo_s {
                        if lat <= slo {
                            stats.slo_met += 1;
                            stats.per_shard[s].slo_met += 1;
                        }
                    }
                }
                dispatch(
                    s,
                    ev.t,
                    plans,
                    &mut queues,
                    &mut busy,
                    &mut exec,
                    &mut flush_pending,
                    arrivals_left,
                    &mut stats,
                    &mut heap,
                    &mut seq,
                );
            }
            EvKind::Flush(s) => {
                flush_pending[s] = false;
                dispatch(
                    s,
                    ev.t,
                    plans,
                    &mut queues,
                    &mut busy,
                    &mut exec,
                    &mut flush_pending,
                    arrivals_left,
                    &mut stats,
                    &mut heap,
                    &mut seq,
                );
            }
        }
    }
    debug_assert_eq!(stats.requests as usize, cfg.requests, "requests lost");
    Ok(stats)
}

fn route(
    policy: RoutingPolicy,
    plans: &[ShardPlan],
    queues: &[VecDeque<QueuedReq>],
    exec: &[Vec<f64>],
    rr_next: &mut usize,
) -> usize {
    let n = plans.len();
    let outstanding = |s: usize| queues[s].len() + exec[s].len();
    match policy {
        RoutingPolicy::RoundRobin => {
            let s = *rr_next % n;
            *rr_next += 1;
            s
        }
        RoutingPolicy::Jsq => (0..n)
            .min_by_key(|&s| (outstanding(s), s))
            .expect("non-empty fleet"),
        RoutingPolicy::EnergyAware => {
            let min_out = (0..n).map(outstanding).min().expect("non-empty fleet");
            (0..n)
                .filter(|&s| outstanding(s) <= min_out + 1)
                .min_by(|&a, &b| {
                    plans[a]
                        .best_energy_per_inf()
                        .total_cmp(&plans[b].best_energy_per_inf())
                        .then_with(|| outstanding(a).cmp(&outstanding(b)))
                        .then_with(|| a.cmp(&b))
                })
                .expect("non-empty fleet")
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn dispatch(
    s: usize,
    now: f64,
    plans: &[ShardPlan],
    queues: &mut [VecDeque<QueuedReq>],
    busy: &mut [bool],
    exec: &mut [Vec<f64>],
    flush_pending: &mut [bool],
    arrivals_left: usize,
    stats: &mut FleetStats,
    heap: &mut BinaryHeap<Ev>,
    seq: &mut u64,
) {
    if busy[s] || queues[s].is_empty() {
        return;
    }
    let plan = &plans[s];
    // Force a padded flush once the oldest request has waited out the
    // deadline, or when no more arrivals can complete a full batch.
    let force = arrivals_left == 0 || now >= queues[s][0].deadline_t;
    match plan.batcher.plan(queues[s].len(), force).first() {
        Some(&b) => {
            let take = b.min(queues[s].len());
            exec[s] = queues[s].drain(..take).map(|r| r.arrival).collect();
            let pad = (b - take) as u64;
            let service = plan.service_time_s(b);
            busy[s] = true;
            heap.push(Ev {
                t: now + service,
                seq: *seq,
                kind: EvKind::ShardDone(s),
            });
            *seq += 1;
            stats.batches += 1;
            stats.padded_slots += pad;
            stats.energy_j += b as f64 * plan.energy_per_inf[&b];
            let sh = &mut stats.per_shard[s];
            sh.batches += 1;
            sh.padded_slots += pad;
            sh.busy_s += service;
            sh.energy_j += b as f64 * plan.energy_per_inf[&b];
        }
        None => {
            // Sub-batch remainder: wait for peers until the oldest
            // request's flush deadline (the flush event re-dispatches with
            // force=true — `deadline_t` is the exact float compared above,
            // so the flush can never reschedule itself forever).  At most
            // one flush is in flight per shard.
            if !flush_pending[s] {
                heap.push(Ev {
                    t: queues[s][0].deadline_t.max(now),
                    seq: *seq,
                    kind: EvKind::Flush(s),
                });
                *seq += 1;
                flush_pending[s] = true;
            }
        }
    }
}

// --------------------------------------------------------- fleet co-design

/// Options of the SLO-constrained fleet co-design pass.
#[derive(Debug, Clone)]
pub struct DesignOptions {
    pub shards: usize,
    /// Candidate executable batch sizes (the SLO prunes them per shard).
    pub batch_sizes: Vec<usize>,
    /// Hard constraint: every shard's smallest executable batch must
    /// simulate within this latency, and organizations that miss it are
    /// excluded from selection.
    pub slo_s: Option<f64>,
    pub flush_deadline_s: f64,
    /// One organization co-designed across every shard workload instead of
    /// one per workload.
    pub homogeneous: bool,
    pub threads: usize,
}

impl Default for DesignOptions {
    fn default() -> DesignOptions {
        DesignOptions {
            shards: 2,
            batch_sizes: vec![1, 2, 4],
            slo_s: None,
            flush_deadline_s: 2e-3,
            homogeneous: false,
            threads: 1,
        }
    }
}

/// The designed fleet: per-shard plans plus the homogeneous union-SMP
/// baseline fleet (same shards, same executable batch sets, the Eq.-1
/// monolithic organization sized to the union of every shard workload) —
/// the reference the energy comparison in E22 is made against.
#[derive(Debug, Clone)]
pub struct FleetDesign {
    pub plans: Vec<ShardPlan>,
    pub baseline: Vec<ShardPlan>,
    /// Label of the baseline organization (for reports).
    pub baseline_label: String,
}

/// Selects per-shard SPM organizations for `opts.shards` shards serving the
/// `nets` workloads (assigned round-robin: shard k serves
/// `nets[k % nets.len()]`), under a fleet-wide energy objective with the
/// SLO as a hard constraint.
pub fn design_fleet(
    cfg: &SystemConfig,
    nets: &[Network],
    opts: &DesignOptions,
) -> Result<FleetDesign> {
    ensure!(opts.shards > 0, "fleet needs at least one shard");
    ensure!(!nets.is_empty(), "fleet needs at least one workload");
    cfg.validate()?;
    let batcher_probe = BatchPolicy::new(opts.batch_sizes.clone(), opts.flush_deadline_s)
        .context("fleet executable batch sizes")?;
    let batch_sizes = batcher_probe.sizes;
    let engine = Engine::new(opts.threads);

    // Batched profiles per workload (indexes parallel to `nets`).
    let per_net_profiles: Vec<Vec<NetworkProfile>> = nets
        .iter()
        .map(|net| {
            batch_sizes
                .iter()
                .map(|&b| profile_network_batched(net, &cfg.accel, b))
                .collect()
        })
        .collect();

    // Organization per workload: SLO-feasible minimum-energy point of the
    // co-design sweep over that workload's batch profiles (or of the whole
    // fleet's profiles when homogeneous).  The hard constraint is checked
    // on the smallest executable batch of every workload in the sweep.
    let select = |profiles: Vec<NetworkProfile>,
                  slo_checks: &[NetworkProfile],
                  label: &str|
     -> Result<Organization> {
        let check_tls: Vec<sim::Timeline> = slo_checks
            .iter()
            .map(|p| sim::Timeline::build(p, &cfg.tech, &cfg.accel))
            .collect();
        // The org-independent timeline lower-bounds every organization's
        // latency (wakeup exposure only adds): an SLO below it is
        // unmeetable before the sweep even starts, so fail fast.
        if let Some(slo) = opts.slo_s {
            let fastest = check_tls
                .iter()
                .map(|tl| tl.batch_latency_s())
                .fold(0.0, f64::max);
            ensure!(
                fastest <= slo,
                "SLO {:.3} ms is unmeetable for {label}: the smallest executable batch \
                 simulates to at least {:.3} ms",
                slo * 1e3,
                fastest * 1e3
            );
        }
        let set = WorkloadSet::new(profiles)?;
        let result = dse::multi::run_on(&engine, &set, &cfg.tech, &cfg.accel)
            .with_context(|| format!("co-designing the organization of {label}"))?;
        let feasible = |p: &DsePoint| match opts.slo_s {
            None => true,
            Some(slo) => slo_checks.iter().zip(&check_tls).all(|(b1, tl)| {
                tl.batch_latency_s() + sim::wakeup_exposure_s(tl, b1, &p.org, &cfg.tech) <= slo
            }),
        };
        let best = result
            .points
            .iter()
            .enumerate()
            .filter(|(_, p)| feasible(p))
            .min_by(|(_, a), (_, b)| a.energy_j.total_cmp(&b.energy_j))
            .map(|(i, _)| i);
        match (best, opts.slo_s) {
            (Some(i), _) => Ok(result.points[i].org.clone()),
            // This branch is only reachable past the fast-path check above,
            // i.e. the org-independent timeline meets the SLO but every
            // candidate's wakeup exposure pushes it over.
            (None, Some(slo)) => bail!(
                "SLO {:.3} ms excludes all {} candidate organizations for {label}: \
                 the ungated timeline meets it, but every candidate's wakeup \
                 exposure pushes the smallest executable batch past the SLO",
                slo * 1e3,
                result.points.len(),
            ),
            (None, None) => bail!(
                "the co-design sweep produced no candidate organizations for {label}"
            ),
        }
    };

    // `batch_sizes` is ascending, so profiles[0] is each workload's
    // smallest executable batch — the SLO check point.
    let b1_checks: Vec<NetworkProfile> =
        per_net_profiles.iter().map(|ps| ps[0].clone()).collect();
    let per_net_orgs: Vec<Organization> = if opts.homogeneous {
        let all: Vec<NetworkProfile> = per_net_profiles.iter().flatten().cloned().collect();
        let org = select(all, &b1_checks, "the homogeneous fleet")?;
        vec![org; nets.len()]
    } else {
        nets.iter()
            .zip(&per_net_profiles)
            .map(|(net, profiles)| {
                select(
                    profiles.clone(),
                    &profiles[..1],
                    &format!("workload '{}'", net.name),
                )
            })
            .collect::<Result<_>>()?
    };

    // Homogeneous union-SMP baseline: Eq. 1 over the merged pseudo-profile
    // of every workload at every executable batch size.
    let all_profiles: Vec<NetworkProfile> = per_net_profiles.iter().flatten().cloned().collect();
    let merged = WorkloadSet::new(all_profiles)?.merged_profile();
    let smp = Organization::smp(MemSpec::new(dse::smp_size(&merged), 1));
    let baseline_label = smp.label();

    // Shard plans: shard k serves workload k % nets.len().  The baseline
    // fleet reuses each shard's admitted batch set so the comparison is
    // schedule-for-schedule.
    let mut plans = Vec::with_capacity(opts.shards);
    let mut baseline = Vec::with_capacity(opts.shards);
    for k in 0..opts.shards {
        let w = k % nets.len();
        let name = &nets[w].name;
        let plan = shard_plan(cfg, name, &per_net_profiles[w], per_net_orgs[w].clone(), opts, None)?;
        let admitted = plan.batcher.sizes.clone();
        let base = shard_plan(
            cfg,
            name,
            &per_net_profiles[w],
            smp.clone(),
            opts,
            Some(&admitted),
        )?;
        // Guarantee of E22: the shard never loses to the baseline on *any*
        // admitted batch size — pointwise dominance means every realizable
        // schedule spends <= baseline energy, not just the mix the DSE
        // optimized.  The mix-optimal organization dominates in practice;
        // should a degenerate workload break that, the shard falls back to
        // the baseline organization (equality, never a regression).
        let dominated = plan
            .batcher
            .sizes
            .iter()
            .all(|b| plan.energy_per_inf[b] <= base.energy_per_inf[b]);
        plans.push(if dominated { plan } else { base.clone() });
        baseline.push(base);
    }
    Ok(FleetDesign {
        plans,
        baseline,
        baseline_label,
    })
}

/// Builds one shard's plan: simulate every candidate batch size on the
/// chosen organization and record per-inference energy + batch latency.
/// With `restrict: None` the SLO prunes oversized batches; with
/// `restrict: Some(sizes)` exactly those sizes are admitted (the baseline
/// fleet mirrors the codesigned fleet's executable batch set so the energy
/// comparison is schedule-for-schedule).
fn shard_plan(
    cfg: &SystemConfig,
    workload: &str,
    profiles: &[NetworkProfile],
    org: Organization,
    opts: &DesignOptions,
    restrict: Option<&[usize]>,
) -> Result<ShardPlan> {
    let mut admitted = Vec::new();
    let mut energy = BTreeMap::new();
    let mut latency = BTreeMap::new();
    for p in profiles {
        let b = p.batch;
        if let Some(sizes) = restrict {
            if !sizes.contains(&b) {
                continue;
            }
        }
        let lp = sim::simulate(p, &org, &cfg.tech, &cfg.accel)
            .with_context(|| format!("simulating batch {b} of '{workload}'"))?;
        let batch_lat = lp.batch_latency_s();
        if restrict.is_none() {
            if let Some(slo) = opts.slo_s {
                if batch_lat > slo {
                    continue; // batch too large for the SLO: never scheduled
                }
            }
        }
        let sys = system_with_org(p, &cfg.tech, &org, "fleet")?;
        admitted.push(b);
        energy.insert(b, sys.total_j());
        latency.insert(b, batch_lat);
    }
    ensure!(
        !admitted.is_empty(),
        "SLO {:.3} ms admits no executable batch for '{workload}'",
        opts.slo_s.unwrap_or(f64::NAN) * 1e3
    );
    ShardPlan::new(
        workload,
        org,
        BatchPolicy::new(admitted, opts.flush_deadline_s)?,
        energy,
        latency,
        1.0,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plan(speed: f64) -> ShardPlan {
        ShardPlan::synthetic("wl", vec![1, 2, 4], 10e-3, 5e-3, speed, 2e-3).unwrap()
    }

    fn cfg(policy: RoutingPolicy) -> FleetConfig {
        FleetConfig {
            rps: 150.0,
            requests: 300,
            seed: 11,
            policy,
            slo_s: Some(60e-3),
        }
    }

    #[test]
    fn policy_parse_roundtrip() {
        for (s, p) in [
            ("rr", RoutingPolicy::RoundRobin),
            ("jsq", RoutingPolicy::Jsq),
            ("energy", RoutingPolicy::EnergyAware),
        ] {
            assert_eq!(RoutingPolicy::parse(s).unwrap(), p);
            assert_eq!(p.label(), s);
        }
        assert!(RoutingPolicy::parse("p2c").is_err());
    }

    #[test]
    fn synthetic_plan_amortizes() {
        let p = plan(1.0);
        assert!(p.service_time_s(4) > p.service_time_s(1));
        assert!(p.energy_per_inf[&4] < p.energy_per_inf[&1]);
        assert!(p.service_time_s(4) / 4.0 < p.service_time_s(1));
    }

    #[test]
    fn simulate_serves_every_request_exactly_once() {
        let plans = vec![plan(1.0), plan(1.0)];
        let stats = simulate(&plans, &cfg(RoutingPolicy::Jsq)).unwrap();
        assert_eq!(stats.requests, 300);
        assert_eq!(
            stats.per_shard.iter().map(|s| s.served).sum::<u64>(),
            300
        );
        assert!(stats.latency.count() == 300);
        assert!(stats.sim_time_s > 0.0);
        assert!(stats.energy_j > 0.0);
        assert!(stats.batches > 0);
        // Every executed slot is either a request or padding.
        let slots: u64 = stats.requests + stats.padded_slots;
        assert!(slots >= stats.batches); // batches are non-empty
    }

    #[test]
    fn same_seed_is_bit_identical_and_seeds_differ() {
        let plans = vec![plan(1.0), plan(0.7)];
        let c = cfg(RoutingPolicy::EnergyAware);
        let a = simulate(&plans, &c).unwrap().fingerprint();
        let b = simulate(&plans, &c).unwrap().fingerprint();
        assert_eq!(a, b);
        let mut c2 = c.clone();
        c2.seed = 12;
        assert_ne!(a, simulate(&plans, &c2).unwrap().fingerprint());
    }

    #[test]
    fn utilization_and_latency_are_sane() {
        let plans = vec![plan(1.0), plan(1.0)];
        let mut stats = simulate(&plans, &cfg(RoutingPolicy::RoundRobin)).unwrap();
        let horizon = stats.sim_time_s;
        for s in &stats.per_shard {
            let u = s.utilization(horizon);
            assert!((0.0..=1.0 + 1e-9).contains(&u), "{u}");
        }
        // Latency at least one service time (batch 1 at nominal speed).
        assert!(stats.latency.percentile(0.0) >= plans[0].service_time_s(1) - 1e-12);
        assert!(stats.latency.p50() <= stats.latency.p99());
    }

    #[test]
    fn slo_attainment_counts_within_budget() {
        let plans = vec![plan(1.0), plan(1.0)];
        let mut c = cfg(RoutingPolicy::Jsq);
        c.slo_s = Some(1e9); // everything within
        let stats = simulate(&plans, &c).unwrap();
        assert_eq!(stats.slo_met, stats.requests);
        assert_eq!(stats.slo_attainment(), 1.0);
        c.slo_s = Some(1e-9); // nothing within
        let stats = simulate(&plans, &c).unwrap();
        assert_eq!(stats.slo_met, 0);
    }

    #[test]
    fn jsq_prefers_short_queues_and_energy_prefers_cheap_shards() {
        // One shard at quarter speed: JSQ must route most work to the fast
        // shard; energy-aware with equal queues must prefer the cheaper
        // shard (here: the one with lower per-inference energy).
        let plans = vec![plan(0.25), plan(1.0)];
        let stats = simulate(&plans, &cfg(RoutingPolicy::Jsq)).unwrap();
        assert!(
            stats.per_shard[1].served > stats.per_shard[0].served,
            "fast shard served {} vs slow {}",
            stats.per_shard[1].served,
            stats.per_shard[0].served
        );

        let cheap = ShardPlan::synthetic("wl", vec![1, 2, 4], 10e-3, 1e-3, 1.0, 2e-3).unwrap();
        let dear = ShardPlan::synthetic("wl", vec![1, 2, 4], 10e-3, 9e-3, 1.0, 2e-3).unwrap();
        let plans = vec![dear, cheap];
        let mut c = cfg(RoutingPolicy::EnergyAware);
        c.rps = 20.0; // light load: queues stay short and symmetric
        let stats = simulate(&plans, &c).unwrap();
        assert!(
            stats.per_shard[1].served > stats.per_shard[0].served,
            "cheap shard served {} vs dear {}",
            stats.per_shard[1].served,
            stats.per_shard[0].served
        );
    }

    #[test]
    fn remainders_flush_at_the_deadline_not_immediately() {
        // Batch sizes {4}: a lone request must wait ~flush_deadline before
        // a padded flush, not execute instantly.
        let p = ShardPlan::synthetic("wl", vec![4], 5e-3, 1e-3, 1.0, 2e-3).unwrap();
        let c = FleetConfig {
            rps: 10.0, // sparse arrivals: batches rarely fill
            requests: 20,
            seed: 3,
            policy: RoutingPolicy::RoundRobin,
            slo_s: None,
        };
        let mut stats = simulate(&[p.clone()], &c).unwrap();
        assert_eq!(stats.requests, 20);
        assert!(stats.padded_slots > 0, "padding expected on sparse load");
        // Every latency >= service time; padded-flush latencies also carry
        // the deadline wait.
        let min_lat = stats.latency.percentile(0.0);
        assert!(min_lat >= p.service_time_s(4) - 1e-12, "{min_lat}");
    }

    #[test]
    fn invalid_inputs_error() {
        assert!(simulate(&[], &FleetConfig::default()).is_err());
        let p = plan(1.0);
        let c = FleetConfig {
            rps: 0.0,
            ..FleetConfig::default()
        };
        assert!(simulate(&[p.clone()], &c).is_err());
        let c = FleetConfig {
            requests: 0,
            ..FleetConfig::default()
        };
        assert!(simulate(&[p.clone()], &c).is_err());
        let c = FleetConfig {
            slo_s: Some(f64::NAN),
            ..FleetConfig::default()
        };
        assert!(simulate(&[p], &c).is_err());
        assert!(ShardPlan::synthetic("wl", vec![1], 5e-3, 1e-3, 0.0, 1e-3).is_err());
        assert!(ShardPlan::synthetic("wl", vec![], 5e-3, 1e-3, 1.0, 1e-3).is_err());
    }
}
