//! Sharded multi-accelerator serving simulator (DESIGN.md section 12),
//! with deterministic fault injection (DESIGN.md section 15).
//!
//! DESCNet's headline result is per-instance: one CapsAcc accelerator, one
//! SPM organization, 79% energy reduction with no performance loss.  The
//! ROADMAP's north star is a serving *fleet* of such instances.  This
//! module closes the gap with three layers:
//!
//! * **[`simulate`]** — a seeded, deterministic discrete-event simulator of
//!   N accelerator shards: open-loop Poisson request arrivals
//!   (`util::prng`), per-shard FIFO queues batched by the same
//!   `coordinator::batcher::BatchPolicy` the single-instance server uses,
//!   pluggable routing policies ([`RoutingPolicy`]: round-robin,
//!   join-shortest-queue, energy-aware), per-batch service times charged
//!   from the timeline simulator (`sim::simulate`), and fleet-level rollups
//!   ([`FleetStats`]: p50/p95/p99 latency, SLO attainment,
//!   energy-per-request, per-shard utilization).  The event loop is serial
//!   and fully ordered (event time ties broken by insertion sequence), so
//!   a (seed, plans, config) triple reproduces bit-identically regardless
//!   of how many threads the surrounding design pass used.
//!
//! * **[`fault`]** — deterministic fault injection around the same event
//!   loop: a seeded per-shard crash/recover schedule (MTBF/MTTR from a
//!   dedicated `Prng::stream`, so arrivals are bit-identical with
//!   injection on or off), per-request timeout + bounded retry with
//!   exponential backoff, optional hedged re-dispatch, routing that skips
//!   down shards, and degraded-mode semantics: a crash fails the in-flight
//!   batch (re-enqueued or dropped per [`fault::CrashPolicy`]) and
//!   recovery pays the power-gating cold-wake charge
//!   ([`ShardPlan::wake_penalty_s`], the `sim::wakeup_exposure_s` rule
//!   with no previous op to mask it).  Every fault branch is gated on
//!   [`fault::FaultConfig::is_active`], so an inert config cannot perturb
//!   a single bit of the no-fault run (`rust/tests/fleet_faults.rs`).
//!
//! * **[`design_fleet`]** — an SLO-constrained fleet co-design pass that
//!   extends `dse::multi`: each shard's SPM organization is selected per
//!   workload (or one organization co-designed across every shard with
//!   `homogeneous`), under a fleet-wide energy objective with the SLO as a
//!   hard constraint on the smallest executable batch's simulated latency.
//!   The result carries a homogeneous union-SMP baseline fleet evaluated
//!   under the *same* executable batch sets, so the energy comparison is
//!   schedule-for-schedule (`rust/tests/fleet.rs` pins codesigned <=
//!   baseline).  [`design_fleet_n_plus`] wraps it in an N+1 provisioning
//!   loop: escalate the shard count until the min-energy design keeps its
//!   SLO attainment with the declared fault budget's worth of shards down.
//!
//! Surfaced as `descnet fleet --shards N --rps R --policy P --slo-ms MS`
//! (fault knobs: `--mtbf-s/--mttr-s/--timeout-ms/--retries/--hedge-ms/
//! --fault-seed/--fault-budget`), `descnet report fleet` (fleet.csv +
//! table_fleet.md) and `examples/fleet_serving.rs` /
//! `examples/fleet_faults.rs`; EXPERIMENTS.md E22/E25 record the numbers.

pub mod fault;

use std::collections::{BTreeMap, BinaryHeap, VecDeque};

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::config::{SystemConfig, Technology};
use crate::coordinator::batcher::BatchPolicy;
use crate::ctx::EvalCtx;
use crate::dataflow::{profile_network_batched, NetworkProfile};
use crate::dse::multi::WorkloadSet;
use crate::dse::{self, DsePoint};
use crate::energy::system_with_org;
use crate::memory::{MemSpec, Organization};
use crate::model::Network;
use crate::sim;
use crate::util::prng::Prng;
use crate::util::stats::Percentiles;

use fault::{CrashPolicy, FaultConfig, ShardFaults};

// ------------------------------------------------------------------ routing

/// How arrivals are routed to shards.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutingPolicy {
    /// Cyclic assignment, blind to queue state.
    RoundRobin,
    /// Fewest outstanding requests (queued + in service); ties to the
    /// lowest shard index.
    Jsq,
    /// Among the shards within one request of the shortest queue, the one
    /// with the lowest per-inference energy at its largest batch — spends
    /// queue slack on the cheapest silicon without sacrificing latency.
    EnergyAware,
}

impl RoutingPolicy {
    pub fn parse(s: &str) -> Result<RoutingPolicy> {
        match s {
            "rr" | "round-robin" => Ok(RoutingPolicy::RoundRobin),
            "jsq" | "join-shortest-queue" => Ok(RoutingPolicy::Jsq),
            "energy" | "energy-aware" => Ok(RoutingPolicy::EnergyAware),
            other => bail!("unknown routing policy '{other}' (expected rr, jsq or energy)"),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            RoutingPolicy::RoundRobin => "rr",
            RoutingPolicy::Jsq => "jsq",
            RoutingPolicy::EnergyAware => "energy",
        }
    }
}

// -------------------------------------------------------------- shard plans

/// Everything one shard needs to serve: its workload label, organization,
/// executable batch sizes and the pre-simulated per-batch energy/latency.
/// Plans come from [`design_fleet`] (DSE-backed) or [`ShardPlan::synthetic`]
/// (closed-form, for property tests and benches).
#[derive(Debug, Clone)]
pub struct ShardPlan {
    pub workload: String,
    pub org: Organization,
    pub batcher: BatchPolicy,
    /// Per-inference system energy [J] by executable batch size.
    pub energy_per_inf: BTreeMap<usize, f64>,
    /// Simulated end-to-end *batch* latency [s] by executable batch size.
    pub batch_latency_s: BTreeMap<usize, f64>,
    /// Clock-binning speed factor: service time divides by this (1.0 =
    /// nominal silicon; used to model asymmetric fleets).
    pub speed: f64,
    /// Cold-wake charge a recovery pays before serving again [s]: the
    /// `sim::wakeup_exposure_s` physics with no previous operation to mask
    /// the wake ([`cold_wake_s`]); 0 for ungated organizations.
    pub wake_penalty_s: f64,
}

impl ShardPlan {
    pub fn new(
        workload: &str,
        org: Organization,
        batcher: BatchPolicy,
        energy_per_inf: BTreeMap<usize, f64>,
        batch_latency_s: BTreeMap<usize, f64>,
        speed: f64,
    ) -> Result<ShardPlan> {
        ensure!(
            speed.is_finite() && speed > 0.0,
            "shard speed must be positive, got {speed}"
        );
        for &b in batcher.sizes() {
            let e = energy_per_inf
                .get(&b)
                .ok_or_else(|| anyhow!("no energy for executable batch {b}"))?;
            let l = batch_latency_s
                .get(&b)
                .ok_or_else(|| anyhow!("no latency for executable batch {b}"))?;
            ensure!(
                e.is_finite() && *e >= 0.0 && l.is_finite() && *l > 0.0,
                "degenerate per-batch cost for batch {b}: {e} J, {l} s"
            );
        }
        Ok(ShardPlan {
            workload: workload.to_string(),
            org,
            batcher,
            energy_per_inf,
            batch_latency_s,
            speed,
            wake_penalty_s: 0.0,
        })
    }

    /// Sets the recovery cold-wake charge (builder-style, used by the
    /// design pass and the fault tests).
    pub fn with_wake_penalty(mut self, wake_penalty_s: f64) -> Result<ShardPlan> {
        ensure!(
            wake_penalty_s.is_finite() && wake_penalty_s >= 0.0,
            "wake penalty must be a non-negative duration, got {wake_penalty_s} s"
        );
        self.wake_penalty_s = wake_penalty_s;
        Ok(self)
    }

    /// Synthetic closed-form plan (no DSE): batch latency grows linearly
    /// with the batch while per-inference energy amortizes — the shape the
    /// real timeline produces, without its cost.  For tests and benches.
    pub fn synthetic(
        workload: &str,
        batch_sizes: Vec<usize>,
        base_latency_s: f64,
        energy_per_inf_j: f64,
        speed: f64,
        flush_deadline_s: f64,
    ) -> Result<ShardPlan> {
        let batcher = BatchPolicy::new(batch_sizes, flush_deadline_s)?;
        let mut energy = BTreeMap::new();
        let mut latency = BTreeMap::new();
        for &b in batcher.sizes() {
            latency.insert(b, base_latency_s * (0.5 + 0.5 * b as f64));
            energy.insert(b, energy_per_inf_j * (0.5 + 0.5 / b as f64));
        }
        ShardPlan::new(
            workload,
            Organization::smp(MemSpec::new(64 * 1024, 1)),
            batcher,
            energy,
            latency,
            speed,
        )
    }

    /// Service time of one executed batch of size `b` on this shard [s].
    pub fn service_time_s(&self, b: usize) -> f64 {
        self.batch_latency_s[&b] / self.speed
    }

    /// Per-inference energy at the largest executable batch — the routing
    /// figure of merit for [`RoutingPolicy::EnergyAware`].
    pub fn best_energy_per_inf(&self) -> f64 {
        self.energy_per_inf[&self.batcher.max_batch()]
    }
}

/// Cold-wake charge of a recovering shard [s]: a power-gated organization
/// (any component with >1 sector) wakes from fully gated with no previous
/// operation to mask the wake, so it pays the full `wakeup_latency_s` once
/// — the `sim::wakeup_exposure_s` residue rule with `prev_dur = 0`.
/// Ungated organizations pay nothing.
pub fn cold_wake_s(org: &Organization, tech: &Technology) -> f64 {
    if org.power_gated() {
        tech.wakeup_latency_s
    } else {
        0.0
    }
}

// ------------------------------------------------------------ fleet config

/// Arrival process + routing knobs of one simulation run.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// Open-loop mean arrival rate [requests/s].
    pub rps: f64,
    /// Total requests injected.
    pub requests: usize,
    pub seed: u64,
    pub policy: RoutingPolicy,
    /// End-to-end latency SLO [s] for the attainment rollup (and the hard
    /// design constraint when passed to [`design_fleet`]).
    pub slo_s: Option<f64>,
    /// Fault injection (None and `Some(FaultConfig::default())` are both
    /// inert and bit-identical to the pre-fault simulator).
    pub fault: Option<FaultConfig>,
}

impl Default for FleetConfig {
    fn default() -> FleetConfig {
        FleetConfig {
            rps: 100.0,
            requests: 400,
            seed: 7,
            policy: RoutingPolicy::Jsq,
            slo_s: None,
            fault: None,
        }
    }
}

impl FleetConfig {
    fn validate(&self) -> Result<()> {
        ensure!(
            self.rps.is_finite() && self.rps > 0.0,
            "fleet rps must be positive, got {}",
            self.rps
        );
        ensure!(self.requests > 0, "fleet needs at least one request");
        if let Some(slo) = self.slo_s {
            ensure!(
                slo.is_finite() && slo > 0.0,
                "SLO must be a positive duration, got {slo} s"
            );
        }
        Ok(())
    }
}

// ------------------------------------------------------------------- stats

/// Per-shard rollup of one simulation run.
#[derive(Debug, Clone)]
pub struct ShardStats {
    pub workload: String,
    pub org_label: String,
    pub served: u64,
    pub batches: u64,
    pub padded_slots: u64,
    pub busy_s: f64,
    pub queue_peak: usize,
    pub energy_j: f64,
    pub slo_met: u64,
    pub latency: Percentiles,
    /// Crashes this shard suffered (0 when injection is off).
    pub crashes: u64,
    /// Total time this shard spent down [s] (repair + cold wake, clipped
    /// to the simulated horizon in the availability rollup).
    pub downtime_s: f64,
}

impl ShardStats {
    /// Fraction of the simulated horizon this shard spent executing.
    pub fn utilization(&self, horizon_s: f64) -> f64 {
        if horizon_s > 0.0 {
            self.busy_s / horizon_s
        } else {
            0.0
        }
    }

    /// Fraction of the simulated horizon this shard was up (1.0 when
    /// injection is off).
    pub fn availability(&self, horizon_s: f64) -> f64 {
        if horizon_s > 0.0 {
            (1.0 - self.downtime_s / horizon_s).clamp(0.0, 1.0)
        } else {
            1.0
        }
    }

    /// Fraction of this shard's requests served within the SLO (1.0 when
    /// no SLO was configured).
    pub fn slo_attainment(&self, slo_s: Option<f64>) -> f64 {
        if slo_s.is_some() && self.served > 0 {
            self.slo_met as f64 / self.served as f64
        } else {
            1.0
        }
    }

    /// This shard's energy per served request [J].
    pub fn energy_per_request_j(&self) -> f64 {
        self.energy_j / self.served.max(1) as f64
    }
}

/// Fleet-level rollup of one simulation run.
#[derive(Debug, Clone)]
pub struct FleetStats {
    pub policy: RoutingPolicy,
    /// Requests *completed* (under faults, dropped requests are counted in
    /// [`FleetStats::dropped`] instead; completed + dropped == arrivals).
    pub requests: u64,
    pub batches: u64,
    pub padded_slots: u64,
    /// Simulated time of the last completion [s].
    pub sim_time_s: f64,
    /// Discrete events processed (arrivals + completions + flushes +
    /// fault events) — the bench throughput unit.
    pub events: u64,
    pub energy_j: f64,
    pub slo_s: Option<f64>,
    pub slo_met: u64,
    /// End-to-end (enqueue -> completion) request latency.
    pub latency: Percentiles,
    pub per_shard: Vec<ShardStats>,
    /// Whether any fault mechanism was armed for this run; when false the
    /// run (and its fingerprint) is bit-identical to the pre-fault
    /// simulator.
    pub faults_active: bool,
    /// Requests dropped (timeout budget exhausted, crash policy `drop`, or
    /// stranded at simulation end).
    pub dropped: u64,
    /// Timeout-driven re-dispatches (bounded by `retries` per request).
    pub retries: u64,
    /// Hedged duplicate dispatches (at most one per request).
    pub hedges: u64,
    /// In-flight requests re-enqueued by crashes (crash policy `requeue`;
    /// does not consume the timeout-retry budget).
    pub crash_requeues: u64,
    /// Shard crashes across the fleet.
    pub crashes: u64,
    /// Total cold-wake charge paid by recoveries [s].
    pub wake_penalty_s: f64,
    /// Mean fraction of shard-time up over the simulated horizon (1.0 when
    /// injection is off).
    pub availability: f64,
}

impl FleetStats {
    pub fn throughput_rps(&self) -> f64 {
        if self.sim_time_s > 0.0 {
            self.requests as f64 / self.sim_time_s
        } else {
            0.0
        }
    }

    pub fn energy_per_request_j(&self) -> f64 {
        self.energy_j / self.requests.max(1) as f64
    }

    pub fn slo_attainment(&self) -> f64 {
        if self.slo_s.is_some() && self.requests > 0 {
            self.slo_met as f64 / self.requests as f64
        } else {
            1.0
        }
    }

    /// Bit-exact digest of every rollup (floats as hex bit patterns): the
    /// determinism tests compare this across thread counts, and the golden
    /// test pins it per (seed, config).  The fault block is appended only
    /// when injection was active, so an inert fault config reproduces the
    /// pre-fault fingerprint byte-for-byte.
    pub fn fingerprint(&mut self) -> String {
        let h = |v: f64| format!("{:016x}", v.to_bits());
        let mut out = format!(
            "policy={} requests={} batches={} padded={} events={} sim_time={} energy={} \
             p50={} p95={} p99={} slo_met={}",
            self.policy.label(),
            self.requests,
            self.batches,
            self.padded_slots,
            self.events,
            h(self.sim_time_s),
            h(self.energy_j),
            h(self.latency.p50()),
            h(self.latency.p95()),
            h(self.latency.p99()),
            self.slo_met,
        );
        for (i, s) in self.per_shard.iter().enumerate() {
            out.push_str(&format!(
                " | s{i}[{}] served={} batches={} padded={} busy={} peak={} energy={} slo_met={}",
                s.workload,
                s.served,
                s.batches,
                s.padded_slots,
                h(s.busy_s),
                s.queue_peak,
                h(s.energy_j),
                s.slo_met,
            ));
        }
        if self.faults_active {
            out.push_str(&format!(
                " | faults crashes={} requeues={} retries={} hedges={} dropped={} wake={} avail={}",
                self.crashes,
                self.crash_requeues,
                self.retries,
                self.hedges,
                self.dropped,
                h(self.wake_penalty_s),
                h(self.availability),
            ));
            for (i, s) in self.per_shard.iter().enumerate() {
                out.push_str(&format!(" d{i}={}", h(s.downtime_s)));
            }
        }
        out
    }

    /// Human-readable report (the `descnet fleet` stdout).
    pub fn summary(&mut self) -> String {
        use crate::util::units::{fmt_energy, fmt_time};
        let mut out = String::new();
        out.push_str(&format!(
            "fleet: {} shards, policy {}, {} requests in {} simulated ({:.1} req/s)\n",
            self.per_shard.len(),
            self.policy.label(),
            self.requests,
            fmt_time(self.sim_time_s),
            self.throughput_rps(),
        ));
        out.push_str(&format!(
            "latency: p50 {}  p95 {}  p99 {}\n",
            fmt_time(self.latency.p50()),
            fmt_time(self.latency.p95()),
            fmt_time(self.latency.p99()),
        ));
        if let Some(slo) = self.slo_s {
            out.push_str(&format!(
                "SLO {}: {:.1}% attainment ({}/{} within)\n",
                fmt_time(slo),
                100.0 * self.slo_attainment(),
                self.slo_met,
                self.requests,
            ));
        }
        out.push_str(&format!(
            "energy: {} per request ({} total, {} batches, {} padded slots)\n",
            fmt_energy(self.energy_per_request_j()),
            fmt_energy(self.energy_j),
            self.batches,
            self.padded_slots,
        ));
        if self.faults_active {
            out.push_str(&format!(
                "availability: {:.2}% ({} crashes, {} requeues, {} retries, {} hedges, \
                 {} dropped, wake charge {})\n",
                100.0 * self.availability,
                self.crashes,
                self.crash_requeues,
                self.retries,
                self.hedges,
                self.dropped,
                fmt_time(self.wake_penalty_s),
            ));
        }
        let horizon = self.sim_time_s;
        for (i, s) in self.per_shard.iter().enumerate() {
            out.push_str(&format!(
                "shard {i} [{} | {}]: served {}, {} batches, util {:.1}%, peak queue {}\n",
                s.workload,
                s.org_label,
                s.served,
                s.batches,
                100.0 * s.utilization(horizon),
                s.queue_peak,
            ));
        }
        out
    }
}

// ------------------------------------------------------------- event engine

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum EvKind {
    Arrival,
    /// Batch completion; `epoch` invalidates completions of batches that
    /// were failed by a crash in between.
    ShardDone { s: usize, epoch: u32 },
    Flush(usize),
    Crash(usize),
    Recover(usize),
    /// Queue-wait timeout of one enqueued copy (`tag`) of request `id`.
    Timeout { id: u32, tag: u32 },
    /// Backoff expired: re-dispatch request `id`.
    Retry { id: u32 },
    /// Hedge delay expired: duplicate request `id` onto another shard.
    Hedge { id: u32 },
}

/// Heap entry; ordered min-first by (time, insertion sequence), so
/// simultaneous events resolve deterministically in insertion order.
#[derive(Debug, Clone, Copy)]
struct Ev {
    t: f64,
    seq: u64,
    kind: EvKind,
}

impl PartialEq for Ev {
    fn eq(&self, other: &Ev) -> bool {
        self.cmp(other) == std::cmp::Ordering::Equal
    }
}
impl Eq for Ev {}
impl PartialOrd for Ev {
    // lint: allow(nan_cmp, "delegates to the total Ord impl below (total_cmp on event time); PartialOrd is only here because BinaryHeap requires the trait bound")
    fn partial_cmp(&self, other: &Ev) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Ev {
    fn cmp(&self, other: &Ev) -> std::cmp::Ordering {
        // Reversed: BinaryHeap is a max-heap, we want the earliest event.
        other
            .t
            .total_cmp(&self.t)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

#[derive(Debug, Clone, Copy)]
struct QueuedReq {
    id: u32,
    /// Copy tag: each enqueue of a request (initial, retry, crash-requeue,
    /// hedge) gets a fresh tag, so a timeout event can tell whether *its*
    /// copy is still live.
    tag: u32,
    /// `enqueue + flush_deadline`, precomputed so the flush comparison uses
    /// the exact float the flush event was scheduled with.
    deadline_t: f64,
}

/// Per-request bookkeeping.  Maintained on the no-fault path too (same
/// code, no branches), but only read by the fault mechanisms.
#[derive(Debug, Clone)]
struct ReqState {
    arrival: f64,
    next_tag: u32,
    timeout_retries: u32,
    /// Live queued copies as (tag, shard).  Emptied when a copy enters
    /// service (cancelling the others) or when the request resolves.
    live: Vec<(u32, usize)>,
    in_service: Option<usize>,
    retry_pending: bool,
    done: bool,
    dropped: bool,
    hedged: bool,
}

impl ReqState {
    fn new(arrival: f64) -> ReqState {
        ReqState {
            arrival,
            next_tag: 0,
            timeout_retries: 0,
            live: Vec::new(),
            in_service: None,
            retry_pending: false,
            done: false,
            dropped: false,
            hedged: false,
        }
    }

    fn resolved(&self) -> bool {
        self.done || self.dropped
    }
}

struct Sim<'a> {
    plans: &'a [ShardPlan],
    cfg: &'a FleetConfig,
    fault: FaultConfig,
    /// `fault.is_active()`, hoisted: gates every fault-path branch so the
    /// inactive run is bit-identical to the pre-fault simulator.
    active: bool,
    rng: Prng,
    mean_gap: f64,
    heap: BinaryHeap<Ev>,
    seq: u64,
    queues: Vec<VecDeque<QueuedReq>>,
    busy: Vec<bool>,
    /// Request ids of the batch currently executing on each shard.
    exec: Vec<Vec<u32>>,
    /// Scheduled completion time of the in-flight batch (for the busy-time
    /// refund when a crash fails it).
    service_end: Vec<f64>,
    // One outstanding flush event per shard at most — re-dispatching while
    // one is pending must not enqueue another (it would inflate the event
    // count and do redundant work when it fires).
    flush_pending: Vec<bool>,
    rr_next: usize,
    arrivals_left: usize,
    reqs: Vec<ReqState>,
    up: Vec<bool>,
    /// Bumped on every crash; stale `ShardDone` events carry the old epoch
    /// and are discarded.
    epoch: Vec<u32>,
    down_since: Vec<Option<f64>>,
    faults: Vec<Option<ShardFaults>>,
    stats: FleetStats,
}

/// Runs the discrete-event fleet simulation.  Serial and deterministic:
/// the only randomness is the seeded arrival process and — when fault
/// injection is armed — the per-shard crash/recover streams, which are
/// split from the arrival stream at seeding time
/// (`Prng::stream(fault_seed, shard)`), so the arrival sequence is
/// bit-identical with injection on or off.
pub fn simulate(plans: &[ShardPlan], cfg: &FleetConfig) -> Result<FleetStats> {
    ensure!(!plans.is_empty(), "fleet needs at least one shard");
    cfg.validate()?;
    let n = plans.len();
    let fault = cfg.fault.clone().unwrap_or_default();
    fault.validate(n)?;
    let active = fault.is_active();

    let stats = FleetStats {
        policy: cfg.policy,
        requests: 0,
        batches: 0,
        padded_slots: 0,
        sim_time_s: 0.0,
        events: 0,
        energy_j: 0.0,
        slo_s: cfg.slo_s,
        slo_met: 0,
        latency: Percentiles::new(),
        per_shard: plans
            .iter()
            .map(|p| ShardStats {
                workload: p.workload.clone(),
                org_label: p.org.label(),
                served: 0,
                batches: 0,
                padded_slots: 0,
                busy_s: 0.0,
                queue_peak: 0,
                energy_j: 0.0,
                slo_met: 0,
                latency: Percentiles::new(),
                crashes: 0,
                downtime_s: 0.0,
            })
            .collect(),
        faults_active: active,
        dropped: 0,
        retries: 0,
        hedges: 0,
        crash_requeues: 0,
        crashes: 0,
        wake_penalty_s: 0.0,
        availability: 1.0,
    };

    let sim = Sim {
        plans,
        cfg,
        fault,
        active,
        rng: Prng::new(cfg.seed),
        mean_gap: 1.0 / cfg.rps,
        heap: BinaryHeap::new(),
        seq: 0,
        queues: vec![VecDeque::new(); n],
        busy: vec![false; n],
        exec: vec![Vec::new(); n],
        service_end: vec![0.0; n],
        flush_pending: vec![false; n],
        rr_next: 0,
        arrivals_left: cfg.requests,
        reqs: Vec::with_capacity(cfg.requests),
        up: vec![true; n],
        epoch: vec![0; n],
        down_since: vec![None; n],
        faults: vec![None; n],
        stats,
    };
    sim.run()
}

impl<'a> Sim<'a> {
    fn push(&mut self, t: f64, kind: EvKind) {
        self.heap.push(Ev {
            t,
            seq: self.seq,
            kind,
        });
        self.seq += 1;
    }

    fn run(mut self) -> Result<FleetStats> {
        let n = self.plans.len();
        if self.active {
            // Arm the schedules before the first arrival.  Crash times come
            // from per-shard streams, so the values (and the arrival stream)
            // are independent of this ordering.
            for s in 0..n {
                if self.fault.pinned_down.contains(&s) {
                    self.up[s] = false;
                    self.down_since[s] = Some(0.0);
                } else if self.fault.mtbf_s.is_finite() {
                    let mut f = ShardFaults::new(
                        self.fault.fault_seed,
                        s,
                        self.fault.mtbf_s,
                        self.fault.mttr_s,
                    );
                    let up = f.uptime_s();
                    self.faults[s] = Some(f);
                    self.push(up, EvKind::Crash(s));
                }
            }
        }
        let t0 = self.rng.exp(self.mean_gap);
        self.push(t0, EvKind::Arrival);

        // Backstop against fault storms (MTBF/MTTR that leave no serving
        // capacity): the crash/recover chain regenerates forever, so the
        // settle check below is the normal exit and this cap is the bail.
        let cap = 10_000_000u64.max(self.cfg.requests as u64 * 1000);
        while let Some(ev) = self.heap.pop() {
            self.stats.events += 1;
            if self.active && self.stats.events > cap {
                bail!(
                    "fault storm: simulation exceeded {cap} events before settling \
                     ({} served, {} dropped of {} requests) — the MTBF/MTTR likely \
                     leave no capacity to drain the queue",
                    self.stats.requests,
                    self.stats.dropped,
                    self.cfg.requests
                );
            }
            match ev.kind {
                EvKind::Arrival => self.on_arrival(ev.t),
                EvKind::ShardDone { s, epoch } => self.on_done(s, epoch, ev.t),
                EvKind::Flush(s) => {
                    self.flush_pending[s] = false;
                    self.dispatch(s, ev.t);
                }
                EvKind::Crash(s) => self.on_crash(s, ev.t),
                EvKind::Recover(s) => self.on_recover(s, ev.t),
                EvKind::Timeout { id, tag } => self.on_timeout(id, tag, ev.t),
                EvKind::Retry { id } => self.on_retry(id, ev.t),
                EvKind::Hedge { id } => self.on_hedge(id, ev.t),
            }
            // With injection on, the crash/recover chain never drains the
            // heap, so stop once every request is accounted for.  With
            // injection off the heap drains exactly as before (stale flush
            // events included), keeping the event count bit-identical.
            if self.active
                && self.arrivals_left == 0
                && self.stats.requests + self.stats.dropped >= self.cfg.requests as u64
            {
                break;
            }
        }
        self.finish()
    }

    fn on_arrival(&mut self, t: f64) {
        self.arrivals_left -= 1;
        if self.arrivals_left > 0 {
            let gap = self.rng.exp(self.mean_gap);
            self.push(t + gap, EvKind::Arrival);
        }
        let id = self.reqs.len() as u32;
        self.reqs.push(ReqState::new(t));
        let s = self.route();
        self.enqueue_copy(id, s, t);
        if let Some(h) = self.fault.hedge_s {
            self.push(t + h, EvKind::Hedge { id });
        }
        self.dispatch(s, t);
    }

    fn on_done(&mut self, s: usize, epoch: u32, t: f64) {
        if epoch != self.epoch[s] {
            return; // the shard crashed mid-batch: this completion is void
        }
        self.busy[s] = false;
        // The horizon is the last *completion*: a stale flush event
        // (scheduled while waiting, overtaken by a full batch) may pop
        // later, but it must not stretch the utilization base.
        self.stats.sim_time_s = t;
        for id in std::mem::take(&mut self.exec[s]) {
            let arrival = {
                let r = &mut self.reqs[id as usize];
                r.done = true;
                r.in_service = None;
                r.arrival
            };
            let lat = t - arrival;
            self.stats.latency.add(lat);
            self.stats.per_shard[s].latency.add(lat);
            self.stats.per_shard[s].served += 1;
            self.stats.requests += 1;
            if let Some(slo) = self.cfg.slo_s {
                if lat <= slo {
                    self.stats.slo_met += 1;
                    self.stats.per_shard[s].slo_met += 1;
                }
            }
        }
        self.dispatch(s, t);
    }

    fn on_crash(&mut self, s: usize, t: f64) {
        if !self.up[s] {
            return; // defensive: the schedule keeps one pending crash per up shard
        }
        self.up[s] = false;
        self.epoch[s] = self.epoch[s].wrapping_add(1);
        self.down_since[s] = Some(t);
        self.stats.crashes += 1;
        self.stats.per_shard[s].crashes += 1;
        if self.busy[s] {
            // Fail the in-flight batch.  The energy was committed at
            // dispatch and stays spent (the silicon did the work up to the
            // crash); the unexecuted tail of busy time is refunded so
            // utilization stays an execution measure.
            self.busy[s] = false;
            let refund = (self.service_end[s] - t).max(0.0);
            self.stats.per_shard[s].busy_s -= refund;
            for id in std::mem::take(&mut self.exec[s]) {
                self.reqs[id as usize].in_service = None;
                match self.fault.crash_policy {
                    CrashPolicy::Requeue => {
                        self.stats.crash_requeues += 1;
                        let target = self.route();
                        self.enqueue_copy(id, target, t);
                        self.dispatch(target, t);
                    }
                    CrashPolicy::Drop => {
                        let r = &mut self.reqs[id as usize];
                        if !r.resolved() {
                            r.dropped = true;
                            self.stats.dropped += 1;
                        }
                    }
                }
            }
        }
        // Recovery pays the drawn repair time plus the cold-wake charge.
        let down = self
            .faults[s]
            .as_mut()
            // lint: allow(hot_unwrap, "Crash events are only ever pushed by arm_faults/on_recover, both gated on the schedule existing for this shard")
            .expect("crash event without a fault schedule")
            .downtime_s();
        let wake = self.plans[s].wake_penalty_s;
        self.stats.wake_penalty_s += wake;
        self.push(t + down + wake, EvKind::Recover(s));
    }

    fn on_recover(&mut self, s: usize, t: f64) {
        self.up[s] = true;
        if let Some(since) = self.down_since[s].take() {
            self.stats.per_shard[s].downtime_s += t - since;
        }
        let up = self
            .faults[s]
            .as_mut()
            // lint: allow(hot_unwrap, "Recover events are only pushed by on_crash, which already drew from this shard's schedule")
            .expect("recover event without a fault schedule")
            .uptime_s();
        self.push(t + up, EvKind::Crash(s));
        self.dispatch(s, t);
    }

    fn on_timeout(&mut self, id: u32, tag: u32, t: f64) {
        let i = id as usize;
        if self.reqs[i].resolved() || self.reqs[i].in_service.is_some() {
            return;
        }
        let Some(pos) = self.reqs[i].live.iter().position(|&(tg, _)| tg == tag) else {
            return; // this copy was already cancelled or drained
        };
        self.reqs[i].live.swap_remove(pos);
        let timeout = self
            .fault
            .timeout_s
            // lint: allow(hot_unwrap, "Timeout events are only pushed by enqueue_copy when timeout_s is Some; the config is immutable for the run")
            .expect("timeout event without a timeout config");
        if self.reqs[i].timeout_retries < self.fault.retries {
            self.reqs[i].timeout_retries += 1;
            self.reqs[i].retry_pending = true;
            self.stats.retries += 1;
            let delay = fault::backoff_s(timeout, self.reqs[i].timeout_retries);
            self.push(t + delay, EvKind::Retry { id });
        } else if self.reqs[i].live.is_empty() && !self.reqs[i].retry_pending {
            self.reqs[i].dropped = true;
            self.stats.dropped += 1;
        }
    }

    fn on_retry(&mut self, id: u32, t: f64) {
        let i = id as usize;
        self.reqs[i].retry_pending = false;
        if self.reqs[i].resolved() || self.reqs[i].in_service.is_some() {
            return;
        }
        let s = self.route();
        self.enqueue_copy(id, s, t);
        self.dispatch(s, t);
    }

    fn on_hedge(&mut self, id: u32, t: f64) {
        let i = id as usize;
        {
            let r = &self.reqs[i];
            if r.resolved() || r.in_service.is_some() || r.hedged || r.live.is_empty() {
                return;
            }
        }
        // Least-loaded *up* shard not already holding a copy.
        let n = self.plans.len();
        let target = (0..n)
            .filter(|&s| self.up[s] && !self.reqs[i].live.iter().any(|&(_, sh)| sh == s))
            .min_by_key(|&s| (self.live_len(s) + self.exec[s].len(), s));
        let Some(target) = target else {
            return; // nowhere to hedge to
        };
        self.reqs[i].hedged = true;
        self.stats.hedges += 1;
        self.enqueue_copy(id, target, t);
        self.dispatch(target, t);
    }

    /// Live (non-cancelled) queue length of shard `s`.  On the no-fault
    /// path every entry is live, so this is `len()` — bit-identical to the
    /// pre-fault routing inputs.
    fn live_len(&self, s: usize) -> usize {
        if !self.active {
            return self.queues[s].len();
        }
        self.queues[s]
            .iter()
            .filter(|q| self.entry_live(q, s))
            .count()
    }

    fn entry_live(&self, q: &QueuedReq, s: usize) -> bool {
        let r = &self.reqs[q.id as usize];
        !r.resolved()
            && r.in_service.is_none()
            && r.live.iter().any(|&(tg, sh)| tg == q.tag && sh == s)
    }

    /// Routes one request: the configured policy over *up* shards (falling
    /// back to all shards in the transient where the whole fleet is down —
    /// the request queues and is served on recovery or dropped at the end).
    fn route(&mut self) -> usize {
        let n = self.plans.len();
        let any_up = self.up.iter().any(|&u| u);
        match self.cfg.policy {
            RoutingPolicy::RoundRobin => loop {
                let s = self.rr_next % n;
                self.rr_next += 1;
                if !any_up || self.up[s] {
                    return s;
                }
            },
            RoutingPolicy::Jsq => (0..n)
                .filter(|&s| !any_up || self.up[s])
                .min_by_key(|&s| (self.live_len(s) + self.exec[s].len(), s))
                // lint: allow(hot_unwrap, "n >= 1 (simulate ensures non-empty plans) and the filter passes every shard when any_up is false")
                .expect("non-empty fleet"),
            RoutingPolicy::EnergyAware => {
                let out = |s: usize| self.live_len(s) + self.exec[s].len();
                let min_out = (0..n)
                    .filter(|&s| !any_up || self.up[s])
                    .map(out)
                    .min()
                    // lint: allow(hot_unwrap, "n >= 1 (simulate ensures non-empty plans) and the filter passes every shard when any_up is false")
                    .expect("non-empty fleet");
                (0..n)
                    .filter(|&s| !any_up || self.up[s])
                    .filter(|&s| out(s) <= min_out + 1)
                    .min_by(|&a, &b| {
                        self.plans[a]
                            .best_energy_per_inf()
                            .total_cmp(&self.plans[b].best_energy_per_inf())
                            .then_with(|| out(a).cmp(&out(b)))
                            .then_with(|| a.cmp(&b))
                    })
                    // lint: allow(hot_unwrap, "the min_out shard itself always survives the <= min_out + 1 refinement")
                    .expect("non-empty fleet")
            }
        }
    }

    fn enqueue_copy(&mut self, id: u32, s: usize, now: f64) {
        let tag = {
            let r = &mut self.reqs[id as usize];
            let tag = r.next_tag;
            r.next_tag += 1;
            r.live.push((tag, s));
            tag
        };
        self.queues[s].push_back(QueuedReq {
            id,
            tag,
            deadline_t: now + self.plans[s].batcher.flush_deadline_s,
        });
        let len = self.queues[s].len();
        let sh = &mut self.stats.per_shard[s];
        sh.queue_peak = sh.queue_peak.max(len);
        if let Some(timeout) = self.fault.timeout_s {
            self.push(now + timeout, EvKind::Timeout { id, tag });
        }
    }

    fn dispatch(&mut self, s: usize, now: f64) {
        if self.busy[s] || !self.up[s] {
            return;
        }
        if self.active {
            // Purge cancelled copies (drained elsewhere, timed out, or
            // resolved) so the batcher plans over live requests only.  A
            // no-op on the no-fault path (every entry is live).
            let reqs = &self.reqs;
            self.queues[s].retain(|q| {
                let r = &reqs[q.id as usize];
                !r.resolved()
                    && r.in_service.is_none()
                    && r.live.iter().any(|&(tg, sh)| tg == q.tag && sh == s)
            });
        }
        if self.queues[s].is_empty() {
            return;
        }
        let plan = &self.plans[s];
        // Force a padded flush once the oldest request has waited out the
        // deadline, or when no more arrivals can complete a full batch.
        let force = self.arrivals_left == 0 || now >= self.queues[s][0].deadline_t;
        match plan.batcher.plan(self.queues[s].len(), force).first() {
            Some(&b) => {
                let take = b.min(self.queues[s].len());
                let ids: Vec<u32> = self.queues[s].drain(..take).map(|r| r.id).collect();
                for &id in &ids {
                    let r = &mut self.reqs[id as usize];
                    r.in_service = Some(s);
                    // First copy to enter service wins: cancel the others
                    // (they become dead queue entries, purged lazily).
                    r.live.clear();
                }
                self.exec[s] = ids;
                let pad = (b - take) as u64;
                let service = plan.service_time_s(b);
                self.busy[s] = true;
                self.service_end[s] = now + service;
                let epoch = self.epoch[s];
                self.push(now + service, EvKind::ShardDone { s, epoch });
                self.stats.batches += 1;
                self.stats.padded_slots += pad;
                self.stats.energy_j += b as f64 * plan.energy_per_inf[&b];
                let sh = &mut self.stats.per_shard[s];
                sh.batches += 1;
                sh.padded_slots += pad;
                sh.busy_s += service;
                sh.energy_j += b as f64 * plan.energy_per_inf[&b];
            }
            None => {
                // Sub-batch remainder: wait for peers until the oldest
                // request's flush deadline (the flush event re-dispatches
                // with force=true — `deadline_t` is the exact float compared
                // above, so the flush can never reschedule itself forever).
                // At most one flush is in flight per shard.
                if !self.flush_pending[s] {
                    let t = self.queues[s][0].deadline_t.max(now);
                    self.push(t, EvKind::Flush(s));
                    self.flush_pending[s] = true;
                }
            }
        }
    }

    fn finish(mut self) -> Result<FleetStats> {
        if self.active {
            // Requests still unresolved when the heap/settle check ended the
            // run (e.g. queued on a shard that never recovered in time with
            // no timeout armed) are stranded: count them dropped so the
            // conservation invariant holds.
            for r in &mut self.reqs {
                if !r.resolved() {
                    r.dropped = true;
                    self.stats.dropped += 1;
                }
            }
            let horizon = self.stats.sim_time_s;
            let mut down_total = 0.0;
            for (s, sh) in self.stats.per_shard.iter_mut().enumerate() {
                if let Some(since) = self.down_since[s].take() {
                    sh.downtime_s += (horizon - since).max(0.0);
                }
                down_total += sh.downtime_s.min(horizon.max(0.0));
            }
            let n = self.plans.len() as f64;
            self.stats.availability = if horizon > 0.0 {
                (1.0 - down_total / (horizon * n)).clamp(0.0, 1.0)
            } else {
                1.0
            };
            // Real errors, not debug-only asserts: conservation is the
            // invariant every availability/attainment rollup rests on, and
            // release builds are exactly where the fleet numbers are
            // produced (lint rule debug_guard, ISSUE 9).
            ensure!(
                self.stats.requests + self.stats.dropped == self.cfg.requests as u64,
                "request conservation violated: {} completed + {} dropped != {} arrivals",
                self.stats.requests,
                self.stats.dropped,
                self.cfg.requests
            );
        } else {
            ensure!(
                self.stats.requests as usize == self.cfg.requests,
                "requests lost: {} completed of {} arrivals with no fault injection",
                self.stats.requests,
                self.cfg.requests
            );
        }
        Ok(self.stats)
    }
}

// --------------------------------------------------------- fleet co-design

/// Options of the SLO-constrained fleet co-design pass.
#[derive(Debug, Clone)]
pub struct DesignOptions {
    pub shards: usize,
    /// Candidate executable batch sizes (the SLO prunes them per shard).
    pub batch_sizes: Vec<usize>,
    /// Hard constraint: every shard's smallest executable batch must
    /// simulate within this latency, and organizations that miss it are
    /// excluded from selection.
    pub slo_s: Option<f64>,
    pub flush_deadline_s: f64,
    /// One organization co-designed across every shard workload instead of
    /// one per workload.
    pub homogeneous: bool,
}

impl Default for DesignOptions {
    fn default() -> DesignOptions {
        DesignOptions {
            shards: 2,
            batch_sizes: vec![1, 2, 4],
            slo_s: None,
            flush_deadline_s: 2e-3,
            homogeneous: false,
        }
    }
}

/// The designed fleet: per-shard plans plus the homogeneous union-SMP
/// baseline fleet (same shards, same executable batch sets, the Eq.-1
/// monolithic organization sized to the union of every shard workload) —
/// the reference the energy comparison in E22 is made against.
#[derive(Debug, Clone)]
pub struct FleetDesign {
    pub plans: Vec<ShardPlan>,
    pub baseline: Vec<ShardPlan>,
    /// Label of the baseline organization (for reports).
    pub baseline_label: String,
}

/// Selects per-shard SPM organizations for `opts.shards` shards serving the
/// `nets` workloads (assigned round-robin: shard k serves
/// `nets[k % nets.len()]`), under a fleet-wide energy objective with the
/// SLO as a hard constraint.
pub fn design_fleet(
    ctx: &EvalCtx,
    nets: &[Network],
    opts: &DesignOptions,
) -> Result<FleetDesign> {
    ensure!(opts.shards > 0, "fleet needs at least one shard");
    ensure!(!nets.is_empty(), "fleet needs at least one workload");
    let cfg = ctx.config();
    cfg.validate()?;
    let batcher_probe = BatchPolicy::new(opts.batch_sizes.clone(), opts.flush_deadline_s)
        .context("fleet executable batch sizes")?;
    let batch_sizes = batcher_probe.sizes().to_vec();

    // Batched profiles per workload (indexes parallel to `nets`).
    let per_net_profiles: Vec<Vec<NetworkProfile>> = nets
        .iter()
        .map(|net| {
            batch_sizes
                .iter()
                .map(|&b| profile_network_batched(net, &cfg.accel, b))
                .collect()
        })
        .collect();

    // Organization per workload: SLO-feasible minimum-energy point of the
    // co-design sweep over that workload's batch profiles (or of the whole
    // fleet's profiles when homogeneous).  The hard constraint is checked
    // on the smallest executable batch of every workload in the sweep.
    let select = |profiles: Vec<NetworkProfile>,
                  slo_checks: &[NetworkProfile],
                  label: &str|
     -> Result<Organization> {
        let check_tls: Vec<sim::Timeline> = slo_checks
            .iter()
            .map(|p| sim::Timeline::build(p, &cfg.tech, &cfg.accel))
            .collect();
        // The org-independent timeline lower-bounds every organization's
        // latency (wakeup exposure only adds): an SLO below it is
        // unmeetable before the sweep even starts, so fail fast.
        if let Some(slo) = opts.slo_s {
            let fastest = check_tls
                .iter()
                .map(|tl| tl.batch_latency_s())
                .fold(0.0, f64::max);
            ensure!(
                fastest <= slo,
                "SLO {:.3} ms is unmeetable for {label}: the smallest executable batch \
                 simulates to at least {:.3} ms",
                slo * 1e3,
                fastest * 1e3
            );
        }
        let set = WorkloadSet::new(profiles)?;
        let result = dse::multi::run(ctx, &set)
            .with_context(|| format!("co-designing the organization of {label}"))?;
        let feasible = |p: &DsePoint| match opts.slo_s {
            None => true,
            Some(slo) => slo_checks.iter().zip(&check_tls).all(|(b1, tl)| {
                tl.batch_latency_s() + sim::wakeup_exposure_s(tl, b1, &p.org, &cfg.tech) <= slo
            }),
        };
        let best = result
            .points
            .iter()
            .enumerate()
            .filter(|(_, p)| feasible(p))
            .min_by(|(_, a), (_, b)| a.energy_j.total_cmp(&b.energy_j))
            .map(|(i, _)| i);
        match (best, opts.slo_s) {
            (Some(i), _) => Ok(result.points[i].org.clone()),
            // This branch is only reachable past the fast-path check above,
            // i.e. the org-independent timeline meets the SLO but every
            // candidate's wakeup exposure pushes it over.
            (None, Some(slo)) => bail!(
                "SLO {:.3} ms excludes all {} candidate organizations for {label}: \
                 the ungated timeline meets it, but every candidate's wakeup \
                 exposure pushes the smallest executable batch past the SLO",
                slo * 1e3,
                result.points.len(),
            ),
            (None, None) => bail!(
                "the co-design sweep produced no candidate organizations for {label}"
            ),
        }
    };

    // `batch_sizes` is ascending, so profiles[0] is each workload's
    // smallest executable batch — the SLO check point.
    let b1_checks: Vec<NetworkProfile> =
        per_net_profiles.iter().map(|ps| ps[0].clone()).collect();
    let per_net_orgs: Vec<Organization> = if opts.homogeneous {
        let all: Vec<NetworkProfile> = per_net_profiles.iter().flatten().cloned().collect();
        let org = select(all, &b1_checks, "the homogeneous fleet")?;
        vec![org; nets.len()]
    } else {
        nets.iter()
            .zip(&per_net_profiles)
            .map(|(net, profiles)| {
                select(
                    profiles.clone(),
                    &profiles[..1],
                    &format!("workload '{}'", net.name),
                )
            })
            .collect::<Result<_>>()?
    };

    // Homogeneous union-SMP baseline: Eq. 1 over the merged pseudo-profile
    // of every workload at every executable batch size.
    let all_profiles: Vec<NetworkProfile> = per_net_profiles.iter().flatten().cloned().collect();
    let merged = WorkloadSet::new(all_profiles)?.merged_profile();
    let smp = Organization::smp(MemSpec::new(dse::smp_size(&merged), 1));
    let baseline_label = smp.label();

    // Shard plans: shard k serves workload k % nets.len().  The baseline
    // fleet reuses each shard's admitted batch set so the comparison is
    // schedule-for-schedule.
    let mut plans = Vec::with_capacity(opts.shards);
    let mut baseline = Vec::with_capacity(opts.shards);
    for k in 0..opts.shards {
        let w = k % nets.len();
        let name = &nets[w].name;
        let plan = shard_plan(cfg, name, &per_net_profiles[w], per_net_orgs[w].clone(), opts, None)?;
        let admitted = plan.batcher.sizes().to_vec();
        let base = shard_plan(
            cfg,
            name,
            &per_net_profiles[w],
            smp.clone(),
            opts,
            Some(&admitted),
        )?;
        // Guarantee of E22: the shard never loses to the baseline on *any*
        // admitted batch size — pointwise dominance means every realizable
        // schedule spends <= baseline energy, not just the mix the DSE
        // optimized.  The mix-optimal organization dominates in practice;
        // should a degenerate workload break that, the shard falls back to
        // the baseline organization (equality, never a regression).
        let dominated = plan
            .batcher
            .sizes()
            .iter()
            .all(|b| plan.energy_per_inf[b] <= base.energy_per_inf[b]);
        plans.push(if dominated { plan } else { base.clone() });
        baseline.push(base);
    }
    Ok(FleetDesign {
        plans,
        baseline,
        baseline_label,
    })
}

/// Builds one shard's plan: simulate every candidate batch size on the
/// chosen organization and record per-inference energy + batch latency.
/// With `restrict: None` the SLO prunes oversized batches; with
/// `restrict: Some(sizes)` exactly those sizes are admitted (the baseline
/// fleet mirrors the codesigned fleet's executable batch set so the energy
/// comparison is schedule-for-schedule).
fn shard_plan(
    cfg: &SystemConfig,
    workload: &str,
    profiles: &[NetworkProfile],
    org: Organization,
    opts: &DesignOptions,
    restrict: Option<&[usize]>,
) -> Result<ShardPlan> {
    let mut admitted = Vec::new();
    let mut energy = BTreeMap::new();
    let mut latency = BTreeMap::new();
    for p in profiles {
        let b = p.batch;
        if let Some(sizes) = restrict {
            if !sizes.contains(&b) {
                continue;
            }
        }
        let lp = sim::simulate(p, &org, &cfg.tech, &cfg.accel)
            .with_context(|| format!("simulating batch {b} of '{workload}'"))?;
        let batch_lat = lp.batch_latency_s();
        if restrict.is_none() {
            if let Some(slo) = opts.slo_s {
                if batch_lat > slo {
                    continue; // batch too large for the SLO: never scheduled
                }
            }
        }
        let sys = system_with_org(p, &cfg.tech, &org, "fleet")?;
        admitted.push(b);
        energy.insert(b, sys.total_j());
        latency.insert(b, batch_lat);
    }
    ensure!(
        !admitted.is_empty(),
        "SLO {:.3} ms admits no executable batch for '{workload}'",
        opts.slo_s.unwrap_or(f64::NAN) * 1e3
    );
    let wake = cold_wake_s(&org, &cfg.tech);
    ShardPlan::new(
        workload,
        org,
        BatchPolicy::new(admitted, opts.flush_deadline_s)?,
        energy,
        latency,
        1.0,
    )?
    .with_wake_penalty(wake)
}

// ------------------------------------------------------- N+1 provisioning

/// Options of the N+1 provisioning loop ([`design_fleet_n_plus`]).
#[derive(Debug, Clone)]
pub struct NPlusOptions {
    /// Simultaneous shard failures the fleet must absorb.
    pub fault_budget: usize,
    /// Minimum SLO attainment the degraded fleet must keep.
    pub attainment_target: f64,
    /// Extra shards (beyond `shards + fault_budget`) the escalation may
    /// add before giving up.
    pub max_extra: usize,
}

impl Default for NPlusOptions {
    fn default() -> NPlusOptions {
        NPlusOptions {
            fault_budget: 1,
            attainment_target: 0.99,
            max_extra: 4,
        }
    }
}

/// Result of the N+1 provisioning loop.
#[derive(Debug, Clone)]
pub struct NPlusDesign {
    pub design: FleetDesign,
    /// Provisioned shard count (>= requested shards + fault budget).
    pub shards: usize,
    /// Shards the worst-case degraded check pinned down.
    pub pinned: Vec<usize>,
    /// Stats of the degraded-mode simulation that met the target.
    pub degraded: FleetStats,
}

/// N+1 fleet provisioning: escalates the shard count from
/// `opts.shards + np.fault_budget` upward until the min-energy
/// [`design_fleet`] selection keeps `np.attainment_target` SLO attainment
/// with the fault budget's worth of shards down.  The degraded check is
/// adversarial and deterministic: the `fault_budget` *highest-capacity*
/// shards (capacity = max batch / its service time) are pinned down and
/// the probe traffic is replayed over the survivors — if the fleet
/// survives losing its biggest shards, it survives any budget-sized
/// failure set of this design.
pub fn design_fleet_n_plus(
    ctx: &EvalCtx,
    nets: &[Network],
    opts: &DesignOptions,
    probe: &FleetConfig,
    np: &NPlusOptions,
) -> Result<NPlusDesign> {
    ensure!(
        np.fault_budget > 0,
        "N+1 provisioning needs a fault budget of at least one shard"
    );
    ensure!(
        (0.0..=1.0).contains(&np.attainment_target),
        "attainment target must be in [0, 1], got {}",
        np.attainment_target
    );
    ensure!(
        probe.slo_s.is_some(),
        "N+1 provisioning needs an SLO: the attainment target is measured against it"
    );
    let mut last_att = 0.0;
    for extra in 0..=np.max_extra {
        let total = opts.shards + np.fault_budget + extra;
        let mut o = opts.clone();
        o.shards = total;
        let design = design_fleet(ctx, nets, &o)?;
        let cap = |s: usize| {
            let p = &design.plans[s];
            let b = p.batcher.max_batch();
            b as f64 / p.service_time_s(b)
        };
        let mut by_cap: Vec<usize> = (0..total).collect();
        by_cap.sort_by(|&a, &b| cap(b).total_cmp(&cap(a)).then_with(|| a.cmp(&b)));
        let pinned: Vec<usize> = by_cap[..np.fault_budget].to_vec();
        let mut degraded_cfg = probe.clone();
        let mut f = probe.fault.clone().unwrap_or_default();
        f.pinned_down = pinned.clone();
        degraded_cfg.fault = Some(f);
        let degraded = simulate(&design.plans, &degraded_cfg)
            .with_context(|| format!("degraded-mode check of the {total}-shard fleet"))?;
        last_att = degraded.slo_attainment();
        if last_att >= np.attainment_target {
            return Ok(NPlusDesign {
                design,
                shards: total,
                pinned,
                degraded,
            });
        }
    }
    bail!(
        "N+1 provisioning failed: even {} shards (requested {} + fault budget {} + {} extra) \
         keep only {:.1}% attainment with the {} largest shards down (target {:.1}%) — \
         raise --shards, relax the SLO, or lower the fault budget",
        opts.shards + np.fault_budget + np.max_extra,
        opts.shards,
        np.fault_budget,
        np.max_extra,
        100.0 * last_att,
        np.fault_budget,
        100.0 * np.attainment_target,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plan(speed: f64) -> ShardPlan {
        ShardPlan::synthetic("wl", vec![1, 2, 4], 10e-3, 5e-3, speed, 2e-3).unwrap()
    }

    fn cfg(policy: RoutingPolicy) -> FleetConfig {
        FleetConfig {
            rps: 150.0,
            requests: 300,
            seed: 11,
            policy,
            slo_s: Some(60e-3),
            fault: None,
        }
    }

    #[test]
    fn policy_parse_roundtrip() {
        for (s, p) in [
            ("rr", RoutingPolicy::RoundRobin),
            ("jsq", RoutingPolicy::Jsq),
            ("energy", RoutingPolicy::EnergyAware),
        ] {
            assert_eq!(RoutingPolicy::parse(s).unwrap(), p);
            assert_eq!(p.label(), s);
        }
        assert!(RoutingPolicy::parse("p2c").is_err());
    }

    #[test]
    fn synthetic_plan_amortizes() {
        let p = plan(1.0);
        assert!(p.service_time_s(4) > p.service_time_s(1));
        assert!(p.energy_per_inf[&4] < p.energy_per_inf[&1]);
        assert!(p.service_time_s(4) / 4.0 < p.service_time_s(1));
    }

    #[test]
    fn simulate_serves_every_request_exactly_once() {
        let plans = vec![plan(1.0), plan(1.0)];
        let stats = simulate(&plans, &cfg(RoutingPolicy::Jsq)).unwrap();
        assert_eq!(stats.requests, 300);
        assert_eq!(
            stats.per_shard.iter().map(|s| s.served).sum::<u64>(),
            300
        );
        assert!(stats.latency.count() == 300);
        assert!(stats.sim_time_s > 0.0);
        assert!(stats.energy_j > 0.0);
        assert!(stats.batches > 0);
        // Every executed slot is either a request or padding.
        let slots: u64 = stats.requests + stats.padded_slots;
        assert!(slots >= stats.batches); // batches are non-empty
    }

    #[test]
    fn same_seed_is_bit_identical_and_seeds_differ() {
        let plans = vec![plan(1.0), plan(0.7)];
        let c = cfg(RoutingPolicy::EnergyAware);
        let a = simulate(&plans, &c).unwrap().fingerprint();
        let b = simulate(&plans, &c).unwrap().fingerprint();
        assert_eq!(a, b);
        let mut c2 = c.clone();
        c2.seed = 12;
        assert_ne!(a, simulate(&plans, &c2).unwrap().fingerprint());
    }

    #[test]
    fn inert_fault_config_is_bit_identical() {
        // None and Some(default) must produce byte-identical fingerprints:
        // the injection-off bit-identity invariant, also pinned end-to-end
        // by rust/tests/fleet_faults.rs.
        let plans = vec![plan(1.0), plan(0.7)];
        let c = cfg(RoutingPolicy::Jsq);
        let a = simulate(&plans, &c).unwrap().fingerprint();
        let mut c2 = c.clone();
        c2.fault = Some(FaultConfig::default());
        let b = simulate(&plans, &c2).unwrap().fingerprint();
        assert_eq!(a, b);
        // An explicit infinite MTBF is the CLI's `--mtbf-s inf` spelling.
        let mut c3 = c.clone();
        c3.fault = Some(FaultConfig {
            mtbf_s: f64::INFINITY,
            ..FaultConfig::default()
        });
        assert_eq!(a, simulate(&plans, &c3).unwrap().fingerprint());
    }

    #[test]
    fn crashes_conserve_requests_and_cost_availability() {
        let plans = vec![plan(1.0), plan(1.0)];
        let mut c = cfg(RoutingPolicy::Jsq);
        c.fault = Some(FaultConfig {
            mtbf_s: 0.2,
            mttr_s: 0.05,
            fault_seed: 3,
            ..FaultConfig::default()
        });
        let stats = simulate(&plans, &c).unwrap();
        assert!(stats.faults_active);
        assert_eq!(stats.requests + stats.dropped, 300, "conservation");
        assert!(stats.crashes > 0, "0.2 s MTBF over a ~2 s horizon must crash");
        assert!(stats.availability < 1.0);
        assert!(stats.availability > 0.0);
        let down: f64 = stats.per_shard.iter().map(|s| s.downtime_s).sum();
        assert!(down > 0.0);
        // Requeue policy: nothing dropped by crashes alone (no timeouts).
        assert_eq!(stats.dropped, 0);
        assert!(stats.crash_requeues > 0 || stats.crashes > 0);
    }

    #[test]
    fn crash_drop_policy_drops_in_flight() {
        let plans = vec![plan(1.0), plan(1.0)];
        let mut c = cfg(RoutingPolicy::Jsq);
        c.fault = Some(FaultConfig {
            mtbf_s: 0.1,
            mttr_s: 0.05,
            fault_seed: 3,
            crash_policy: CrashPolicy::Drop,
            ..FaultConfig::default()
        });
        let stats = simulate(&plans, &c).unwrap();
        assert_eq!(stats.requests + stats.dropped, 300);
        assert!(stats.dropped > 0, "0.1 s MTBF with drop policy must drop");
        assert_eq!(stats.crash_requeues, 0);
    }

    #[test]
    fn pinned_down_shard_serves_nothing() {
        let plans = vec![plan(1.0), plan(1.0)];
        let mut c = cfg(RoutingPolicy::Jsq);
        c.fault = Some(FaultConfig {
            pinned_down: vec![0],
            ..FaultConfig::default()
        });
        let stats = simulate(&plans, &c).unwrap();
        assert_eq!(stats.per_shard[0].served, 0);
        assert_eq!(stats.per_shard[1].served, 300);
        assert_eq!(stats.requests, 300);
        let horizon = stats.sim_time_s;
        assert!(stats.per_shard[0].availability(horizon) < 1e-9);
        assert!((stats.per_shard[1].availability(horizon) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn timeouts_drop_after_retry_budget() {
        // One shard, batch {4} only, 10 s flush deadline: sparse arrivals
        // wait forever for peers, so every copy times out; with retries=1
        // each request re-dispatches once and is then dropped (unless 4
        // happen to pool up).
        let p = ShardPlan::synthetic("wl", vec![4], 5e-3, 1e-3, 1.0, 10.0).unwrap();
        let c = FleetConfig {
            rps: 10.0,
            requests: 30,
            seed: 3,
            policy: RoutingPolicy::RoundRobin,
            slo_s: None,
            fault: Some(FaultConfig {
                timeout_s: Some(20e-3),
                retries: 1,
                ..FaultConfig::default()
            }),
        };
        let stats = simulate(&[p], &c).unwrap();
        assert_eq!(stats.requests + stats.dropped, 30, "conservation");
        assert!(stats.dropped > 0, "starved batches must drop");
        assert!(stats.retries > 0);
        assert!(stats.retries <= 30, "retry budget is 1 per request");
    }

    #[test]
    fn hedging_duplicates_at_most_once_and_conserves() {
        // One slow, one fast shard under RR: half the requests queue on the
        // slow shard and hedge onto the fast one after 5 ms.
        let plans = vec![plan(0.1), plan(1.0)];
        let mut c = cfg(RoutingPolicy::RoundRobin);
        c.fault = Some(FaultConfig {
            hedge_s: Some(5e-3),
            ..FaultConfig::default()
        });
        let stats = simulate(&plans, &c).unwrap();
        assert_eq!(stats.requests + stats.dropped, 300, "conservation");
        assert!(stats.hedges > 0, "slow-shard queues must trigger hedges");
        assert!(stats.hedges <= 300, "at most one hedge per request");
        assert_eq!(stats.dropped, 0, "hedging never drops");
    }

    #[test]
    fn utilization_and_latency_are_sane() {
        let plans = vec![plan(1.0), plan(1.0)];
        let mut stats = simulate(&plans, &cfg(RoutingPolicy::RoundRobin)).unwrap();
        let horizon = stats.sim_time_s;
        for s in &stats.per_shard {
            let u = s.utilization(horizon);
            assert!((0.0..=1.0 + 1e-9).contains(&u), "{u}");
        }
        // Latency at least one service time (batch 1 at nominal speed).
        assert!(stats.latency.percentile(0.0) >= plans[0].service_time_s(1) - 1e-12);
        assert!(stats.latency.p50() <= stats.latency.p99());
    }

    #[test]
    fn slo_attainment_counts_within_budget() {
        let plans = vec![plan(1.0), plan(1.0)];
        let mut c = cfg(RoutingPolicy::Jsq);
        c.slo_s = Some(1e9); // everything within
        let stats = simulate(&plans, &c).unwrap();
        assert_eq!(stats.slo_met, stats.requests);
        assert_eq!(stats.slo_attainment(), 1.0);
        c.slo_s = Some(1e-9); // nothing within
        let stats = simulate(&plans, &c).unwrap();
        assert_eq!(stats.slo_met, 0);
    }

    #[test]
    fn jsq_prefers_short_queues_and_energy_prefers_cheap_shards() {
        // One shard at quarter speed: JSQ must route most work to the fast
        // shard; energy-aware with equal queues must prefer the cheaper
        // shard (here: the one with lower per-inference energy).
        let plans = vec![plan(0.25), plan(1.0)];
        let stats = simulate(&plans, &cfg(RoutingPolicy::Jsq)).unwrap();
        assert!(
            stats.per_shard[1].served > stats.per_shard[0].served,
            "fast shard served {} vs slow {}",
            stats.per_shard[1].served,
            stats.per_shard[0].served
        );

        let cheap = ShardPlan::synthetic("wl", vec![1, 2, 4], 10e-3, 1e-3, 1.0, 2e-3).unwrap();
        let dear = ShardPlan::synthetic("wl", vec![1, 2, 4], 10e-3, 9e-3, 1.0, 2e-3).unwrap();
        let plans = vec![dear, cheap];
        let mut c = cfg(RoutingPolicy::EnergyAware);
        c.rps = 20.0; // light load: queues stay short and symmetric
        let stats = simulate(&plans, &c).unwrap();
        assert!(
            stats.per_shard[1].served > stats.per_shard[0].served,
            "cheap shard served {} vs dear {}",
            stats.per_shard[1].served,
            stats.per_shard[0].served
        );
    }

    #[test]
    fn remainders_flush_at_the_deadline_not_immediately() {
        // Batch sizes {4}: a lone request must wait ~flush_deadline before
        // a padded flush, not execute instantly.
        let p = ShardPlan::synthetic("wl", vec![4], 5e-3, 1e-3, 1.0, 2e-3).unwrap();
        let c = FleetConfig {
            rps: 10.0, // sparse arrivals: batches rarely fill
            requests: 20,
            seed: 3,
            policy: RoutingPolicy::RoundRobin,
            slo_s: None,
            fault: None,
        };
        let mut stats = simulate(&[p.clone()], &c).unwrap();
        assert_eq!(stats.requests, 20);
        assert!(stats.padded_slots > 0, "padding expected on sparse load");
        // Every latency >= service time; padded-flush latencies also carry
        // the deadline wait.
        let min_lat = stats.latency.percentile(0.0);
        assert!(min_lat >= p.service_time_s(4) - 1e-12, "{min_lat}");
    }

    #[test]
    fn cold_wake_follows_power_gating() {
        use crate::config::Technology;
        let tech = Technology::default();
        let ungated = Organization::smp(MemSpec::new(64 * 1024, 1));
        assert_eq!(cold_wake_s(&ungated, &tech), 0.0);
        let gated = Organization::smp(MemSpec::new(64 * 1024, 4));
        assert_eq!(cold_wake_s(&gated, &tech), tech.wakeup_latency_s);
    }

    #[test]
    fn invalid_inputs_error() {
        assert!(simulate(&[], &FleetConfig::default()).is_err());
        let p = plan(1.0);
        let c = FleetConfig {
            rps: 0.0,
            ..FleetConfig::default()
        };
        assert!(simulate(&[p.clone()], &c).is_err());
        let c = FleetConfig {
            requests: 0,
            ..FleetConfig::default()
        };
        assert!(simulate(&[p.clone()], &c).is_err());
        let c = FleetConfig {
            slo_s: Some(f64::NAN),
            ..FleetConfig::default()
        };
        assert!(simulate(&[p.clone()], &c).is_err());
        // Fault configs are validated against the fleet size.
        let c = FleetConfig {
            fault: Some(FaultConfig {
                pinned_down: vec![0],
                ..FaultConfig::default()
            }),
            ..FleetConfig::default()
        };
        assert!(simulate(&[p], &c).is_err(), "all shards pinned down");
        assert!(ShardPlan::synthetic("wl", vec![1], 5e-3, 1e-3, 0.0, 1e-3).is_err());
        assert!(ShardPlan::synthetic("wl", vec![], 5e-3, 1e-3, 1.0, 1e-3).is_err());
        assert!(plan(1.0).with_wake_penalty(-1.0).is_err());
    }
}
