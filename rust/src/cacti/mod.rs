//! Analytical SRAM area/energy model — the CACTI-P [17] substitute.
//!
//! CACTI-P itself is not available in this environment, so we use scaling
//! laws of the standard CACTI form (periphery-dominated small arrays,
//! density-gaining large arrays, superlinear multi-port cost, sleep-
//! transistor-based sector power gating) whose free constants are **fitted
//! to the paper's own Table III anchor cells** — see DESIGN.md section 7 and
//! the `anchors` test module below, which pins the fit to <= 25% on every
//! anchor the paper prints.
//!
//! All DSE energy/area numbers flow through [`Sram::evaluate`], so the
//! fit tolerance bounds the absolute error of every reproduced figure; the
//! *orderings* (what the DSE actually decides on) are far less sensitive.
//!
//! Callers on hot paths should go through [`cache`] (the concurrent
//! memoized front-end) rather than instantiating [`Sram`] per evaluation:
//! the enumerated organizations reuse a small pool of array geometries, so
//! nearly every lookup is a shared-read cache hit.

pub mod cache;
pub mod powergate;

use crate::config::Technology;
use crate::util::units::KIB;

/// Geometry of one scratchpad memory (or one component of an organization).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SramConfig {
    pub size_bytes: usize,
    /// Read/write ports (1 for SEP components; 2-3 for shared memories).
    pub ports: usize,
    /// Banks (fixed at 16 in the paper's DSE; kept for generality).
    pub banks: usize,
    /// Power-gating sectors (1 = no power gating possible).
    pub sectors: usize,
}

impl SramConfig {
    pub fn new(size_bytes: usize, ports: usize, sectors: usize) -> SramConfig {
        SramConfig {
            size_bytes,
            ports,
            banks: 16,
            sectors,
        }
    }

    pub fn sector_bytes(&self) -> usize {
        self.size_bytes / self.sectors.max(1)
    }

    pub fn power_gated(&self) -> bool {
        self.sectors > 1
    }
}

/// Evaluated costs of one SRAM array.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SramCosts {
    pub area_mm2: f64,
    /// Energy per port transaction [J] (read ~= write at this abstraction).
    pub access_energy_j: f64,
    /// Leakage power with all sectors ON [W].
    pub leak_on_w: f64,
    /// Leakage power of one OFF sector [W].
    pub leak_sector_off_w: f64,
    /// Leakage power of one ON sector [W].
    pub leak_sector_on_w: f64,
    /// Energy of one sector wakeup (OFF -> ON transition) [J].
    pub wakeup_energy_j: f64,
    /// Wakeup latency [s].
    pub wakeup_latency_s: f64,
}

/// The model itself; stateless, parameterized by [`Technology`].
pub struct Sram<'t> {
    pub tech: &'t Technology,
}

/// Size knee between the periphery-dominated and density-gaining regimes.
const AREA_KNEE_BYTES: f64 = 128.0 * KIB as f64;
/// Anchor point of the area fit (64 KiB, Table III SEP weight memory).
const AREA_ANCHOR_BYTES: f64 = 64.0 * KIB as f64;
/// Sector/power-gating area overhead fit: 1 + BASE - SLOPE * log2(SC)
/// (CACTI-P's sectored arrays shrink slightly with more, smaller sectors;
/// fitted to the Table III -PG rows).
const PG_AREA_BASE: f64 = 0.63;
const PG_AREA_LOG_SLOPE: f64 = 0.07;

impl<'t> Sram<'t> {
    pub fn new(tech: &'t Technology) -> Sram<'t> {
        Sram { tech }
    }

    /// Area [mm²]: piecewise power law around the 128 KiB knee, times port
    /// and sector factors.
    pub fn area_mm2(&self, cfg: &SramConfig) -> f64 {
        let t = self.tech;
        let s = cfg.size_bytes as f64;
        let base = if s <= AREA_KNEE_BYTES {
            t.sram_area_64k_mm2 * (s / AREA_ANCHOR_BYTES).powf(t.sram_area_exp_small)
        } else {
            let knee = t.sram_area_64k_mm2
                * (AREA_KNEE_BYTES / AREA_ANCHOR_BYTES).powf(t.sram_area_exp_small);
            knee * (s / AREA_KNEE_BYTES).powf(t.sram_area_exp_large)
        };
        base * self.port_area_factor(cfg.ports) * self.sector_area_factor(cfg.sectors)
    }

    fn port_area_factor(&self, ports: usize) -> f64 {
        1.0 + self.tech.sram_area_port_factor * (ports.saturating_sub(1)) as f64
    }

    fn sector_area_factor(&self, sectors: usize) -> f64 {
        if sectors <= 1 {
            1.0
        } else {
            1.0 + PG_AREA_BASE - PG_AREA_LOG_SLOPE * (sectors as f64).log2()
        }
    }

    /// Dynamic energy per port transaction [J].
    pub fn access_energy_j(&self, cfg: &SramConfig) -> f64 {
        let t = self.tech;
        t.sram_dyn_e0_j
            * (cfg.size_bytes as f64 / KIB as f64).powf(t.sram_dyn_size_exp)
            * (cfg.ports as f64).powf(t.sram_dyn_port_exp)
    }

    /// Leakage power with all sectors ON [W].
    pub fn leak_on_w(&self, cfg: &SramConfig) -> f64 {
        let t = self.tech;
        t.sram_leak_w_per_byte
            * cfg.size_bytes as f64
            * (1.0 + t.sram_leak_port_factor * (cfg.ports.saturating_sub(1)) as f64)
    }

    pub fn evaluate(&self, cfg: &SramConfig) -> SramCosts {
        let leak_on = self.leak_on_w(cfg);
        let per_sector = leak_on / cfg.sectors.max(1) as f64;
        SramCosts {
            area_mm2: self.area_mm2(cfg),
            access_energy_j: self.access_energy_j(cfg),
            leak_on_w: leak_on,
            leak_sector_on_w: per_sector,
            leak_sector_off_w: per_sector * self.tech.powergate_off_leak_frac,
            wakeup_energy_j: self.tech.wakeup_j_per_kib
                * (cfg.sector_bytes() as f64 / KIB as f64),
            wakeup_latency_s: self.tech.wakeup_latency_s,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::units::{KIB, MIB};

    fn sram(tech: &Technology) -> Sram<'_> {
        Sram::new(tech)
    }

    fn rel_err(got: f64, want: f64) -> f64 {
        (got - want).abs() / want
    }

    /// Table III anchor cells (CapsNet rows): the fit must stay within the
    /// tolerances DESIGN.md section 7 commits to.
    mod anchors {
        use super::*;

        #[test]
        fn area_64k_1port_is_sep_weight_cell() {
            let tech = Technology::default();
            let a = sram(&tech).area_mm2(&SramConfig::new(64 * KIB, 1, 1));
            assert!(rel_err(a, 0.314) < 0.01, "{a}");
        }

        #[test]
        fn area_25k_1port_is_sep_data_cell() {
            let tech = Technology::default();
            let a = sram(&tech).area_mm2(&SramConfig::new(25 * KIB, 1, 1));
            assert!(rel_err(a, 0.104) < 0.20, "{a}");
        }

        #[test]
        fn area_32k_1port_is_sep_acc_cell() {
            let tech = Technology::default();
            let a = sram(&tech).area_mm2(&SramConfig::new(32 * KIB, 1, 1));
            assert!(rel_err(a, 0.125) < 0.20, "{a}");
        }

        #[test]
        fn area_108k_3port_is_smp_cell() {
            let tech = Technology::default();
            let a = sram(&tech).area_mm2(&SramConfig::new(108 * KIB, 3, 1));
            assert!(rel_err(a, 2.521) < 0.15, "{a}");
        }

        #[test]
        fn area_8mib_1port_is_deepcaps_acc_cell() {
            let tech = Technology::default();
            let a = sram(&tech).area_mm2(&SramConfig::new(8 * MIB, 1, 1));
            assert!(rel_err(a, 31.392) < 0.25, "{a}");
        }

        #[test]
        fn leak_64k_matches_sep_weight_static() {
            // Table III: 0.501 mJ static over the ~8.6 ms inference -> 58 mW.
            let tech = Technology::default();
            let l = sram(&tech).leak_on_w(&SramConfig::new(64 * KIB, 1, 1));
            assert!(rel_err(l, 58.1e-3) < 0.15, "{l}");
        }

        #[test]
        fn leak_108k_3port_matches_smp_static() {
            // 1.529 mJ / 8.62 ms = 177 mW.
            let tech = Technology::default();
            let l = sram(&tech).leak_on_w(&SramConfig::new(108 * KIB, 3, 1));
            assert!(rel_err(l, 177.0e-3) < 0.15, "{l}");
        }

        #[test]
        fn dyn_32k_matches_capsnet_acc_energy() {
            // 0.196 mJ over ~25M accumulator transactions -> ~7.8 pJ.
            let tech = Technology::default();
            let e = sram(&tech).access_energy_j(&SramConfig::new(32 * KIB, 1, 1));
            assert!(rel_err(e, 7.8e-12) < 0.25, "{e}");
        }

        #[test]
        fn dyn_8mib_matches_deepcaps_acc_energy() {
            // 34.268 mJ over ~459M transactions -> ~74.7 pJ.
            let tech = Technology::default();
            let e = sram(&tech).access_energy_j(&SramConfig::new(8 * MIB, 1, 1));
            assert!(rel_err(e, 74.7e-12) < 0.25, "{e}");
        }

        #[test]
        fn dyn_108k_3port_matches_smp_energy() {
            // 1.859 mJ over ~32M transactions -> ~58 pJ.
            let tech = Technology::default();
            let e = sram(&tech).access_energy_j(&SramConfig::new(108 * KIB, 3, 1));
            assert!(rel_err(e, 58.0e-12) < 0.25, "{e}");
        }

        #[test]
        fn pg_area_overhead_matches_sep_pg_rows() {
            // W 64 kiB SC=8: 0.469/0.314 = 1.49; D 25 kiB SC=2: 1.66.
            let tech = Technology::default();
            let m = sram(&tech);
            let w = m.area_mm2(&SramConfig::new(64 * KIB, 1, 8))
                / m.area_mm2(&SramConfig::new(64 * KIB, 1, 1));
            assert!((1.30..=1.60).contains(&w), "{w}");
            let d = m.area_mm2(&SramConfig::new(25 * KIB, 1, 2))
                / m.area_mm2(&SramConfig::new(25 * KIB, 1, 1));
            assert!((1.40..=1.70).contains(&d), "{d}");
        }
    }

    // ------------------------------------------------- structural sanity

    #[test]
    fn monotone_in_size() {
        let tech = Technology::default();
        let m = sram(&tech);
        let mut prev_area = 0.0;
        let mut prev_e = 0.0;
        let mut prev_leak = 0.0;
        for kib in [8, 16, 25, 32, 64, 108, 128, 256, 512, 1024, 4096, 8192] {
            let cfg = SramConfig::new(kib * KIB, 1, 1);
            let c = m.evaluate(&cfg);
            assert!(c.area_mm2 > prev_area, "{kib} kiB area");
            assert!(c.access_energy_j > prev_e, "{kib} kiB energy");
            assert!(c.leak_on_w > prev_leak, "{kib} kiB leak");
            prev_area = c.area_mm2;
            prev_e = c.access_energy_j;
            prev_leak = c.leak_on_w;
        }
    }

    #[test]
    fn more_ports_cost_more() {
        let tech = Technology::default();
        let m = sram(&tech);
        for p in 2..=3 {
            let lo = m.evaluate(&SramConfig::new(64 * KIB, p - 1, 1));
            let hi = m.evaluate(&SramConfig::new(64 * KIB, p, 1));
            assert!(hi.area_mm2 > lo.area_mm2);
            assert!(hi.access_energy_j > lo.access_energy_j);
            assert!(hi.leak_on_w > lo.leak_on_w);
        }
    }

    #[test]
    fn separated_memories_beat_shared_multiport_in_area() {
        // The paper's key observation (section VI-A): SEP's three 1-port
        // arrays (25+64+32 kiB) occupy less area than the 108 kiB 3-port SMP.
        let tech = Technology::default();
        let m = sram(&tech);
        let sep: f64 = [25, 64, 32]
            .iter()
            .map(|&k| m.area_mm2(&SramConfig::new(k * KIB, 1, 1)))
            .sum();
        let smp = m.area_mm2(&SramConfig::new(108 * KIB, 3, 1));
        assert!(sep < smp / 3.0, "sep={sep} smp={smp}");
    }

    #[test]
    fn sector_leakage_splits_evenly() {
        let tech = Technology::default();
        let c = sram(&tech).evaluate(&SramConfig::new(64 * KIB, 1, 8));
        assert!((c.leak_sector_on_w * 8.0 - c.leak_on_w).abs() < 1e-12);
        assert!(
            (c.leak_sector_off_w - 0.1 * c.leak_sector_on_w).abs() < 1e-12,
            "off-sector leak is 10% of on"
        );
    }

    #[test]
    fn wakeup_scales_with_sector_size() {
        let tech = Technology::default();
        let m = sram(&tech);
        let big = m.evaluate(&SramConfig::new(64 * KIB, 1, 2));
        let small = m.evaluate(&SramConfig::new(64 * KIB, 1, 16));
        assert!(big.wakeup_energy_j > small.wakeup_energy_j);
        // Paper reports ~1.6 nJ average wakeup energy; our sector sizes land
        // in the same decade.
        assert!(big.wakeup_energy_j > 0.1e-9 && big.wakeup_energy_j < 10e-9);
        assert!((big.wakeup_latency_s - 0.072e-9).abs() < 1e-12);
    }

    #[test]
    fn sector_bytes_helper() {
        assert_eq!(SramConfig::new(64 * KIB, 1, 8).sector_bytes(), 8 * KIB);
        assert!(!SramConfig::new(64 * KIB, 1, 1).power_gated());
        assert!(SramConfig::new(64 * KIB, 1, 2).power_gated());
    }
}
