//! Concurrent memoized CACTI cost cache (DESIGN.md section 5).
//!
//! The exhaustive DSE evaluates hundreds of thousands of organizations, but
//! they are assembled from a *small* set of SRAM array shapes: the
//! Algorithm-1/2 pools admit only a few dozen sizes, sector counts and port
//! counts, so the same `(Technology, SramConfig)` geometry is costed
//! millions of times.  This cache computes each geometry once through
//! [`Sram::evaluate`] and serves every later request — from the DSE fast
//! path, the `energy`/`pmu` reporting rollups, and the serving layer's
//! per-inference co-simulation — out of a read-mostly store.
//!
//! Design:
//! * keyed by [`Technology::cache_key`] (bit-exact fingerprint of every
//!   constant) + [`SramConfig`], so perturbed-technology sweeps
//!   (`examples/dse_sweep.rs`) never alias the calibrated baseline;
//! * sharded `RwLock<HashMap>`: after warmup every access is a shared read
//!   lock, so worker threads of `util::exec::Engine` don't serialize;
//! * misses compute **outside** any lock — the model is pure, so a racing
//!   duplicate computation is benign (both writers insert the same value);
//! * hit/miss counters (relaxed atomics) so tests and benches can assert
//!   the cache is actually exercised across layers.

// lint: allow(hash_collect, "per-key memo: lookups only, iteration order is never observed by any output path")
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{OnceLock, RwLock};

use super::{Sram, SramConfig, SramCosts};
use crate::config::Technology;

const SHARDS: usize = 16;

#[derive(Clone, Copy, PartialEq, Eq, Hash)]
struct Key {
    tech: u64,
    cfg: SramConfig,
}

fn shard_of(key: &Key) -> usize {
    let mut h = std::collections::hash_map::DefaultHasher::new();
    key.hash(&mut h);
    (h.finish() as usize) % SHARDS
}

/// A sharded, counted memo of [`Sram::evaluate`] results.
pub struct CostCache {
    // lint: allow(hash_collect, "memo shards are read by point lookup only; nothing iterates them")
    shards: [RwLock<HashMap<Key, SramCosts>>; SHARDS],
    hits: AtomicU64,
    misses: AtomicU64,
}

impl CostCache {
    pub fn new() -> CostCache {
        CostCache {
            // lint: allow(hash_collect, "memo construction; see struct field note")
            shards: std::array::from_fn(|_| RwLock::new(HashMap::new())),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// Memoized [`Sram::evaluate`].  For repeated lookups under one
    /// technology (the DSE fast path costs 4 geometries per organization),
    /// prefer [`CostCache::tech`], which fingerprints the 22 technology
    /// constants once instead of per call.
    pub fn costs(&self, tech: &Technology, cfg: &SramConfig) -> SramCosts {
        self.costs_keyed(tech.cache_key(), tech, cfg)
    }

    /// A per-technology view with the fingerprint precomputed.
    pub fn tech<'a>(&'a self, tech: &'a Technology) -> TechCosts<'a> {
        TechCosts {
            cache: self,
            tech,
            key: tech.cache_key(),
        }
    }

    fn costs_keyed(&self, tech_key: u64, tech: &Technology, cfg: &SramConfig) -> SramCosts {
        let key = Key {
            tech: tech_key,
            cfg: *cfg,
        };
        let shard = &self.shards[shard_of(&key)];
        if let Some(costs) = shard.read().expect("cache lock poisoned").get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return *costs;
        }
        let costs = Sram::new(tech).evaluate(cfg);
        self.misses.fetch_add(1, Ordering::Relaxed);
        shard
            .write()
            .expect("cache lock poisoned")
            .insert(key, costs);
        costs
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Distinct geometries cached so far.
    pub fn len(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.read().expect("cache lock poisoned").len())
            .sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drops every entry (counters are kept: they are lifetime totals).
    pub fn clear(&self) {
        for s in &self.shards {
            s.write().expect("cache lock poisoned").clear();
        }
    }
}

impl Default for CostCache {
    fn default() -> CostCache {
        CostCache::new()
    }
}

/// A borrowed view of a [`CostCache`] for one technology: the 22-constant
/// fingerprint is hashed once at construction, so hot loops pay only the
/// small per-geometry key hash per lookup (the function-local-memo
/// experiment recorded in EXPERIMENTS.md Perf/L3 showed per-lookup hashing
/// overhead is what makes or breaks memoization here).
pub struct TechCosts<'a> {
    cache: &'a CostCache,
    tech: &'a Technology,
    key: u64,
}

impl TechCosts<'_> {
    pub fn costs(&self, cfg: &SramConfig) -> SramCosts {
        self.cache.costs_keyed(self.key, self.tech, cfg)
    }
}

/// The process-global cache every evaluation layer shares.
pub fn global() -> &'static CostCache {
    static GLOBAL: OnceLock<CostCache> = OnceLock::new();
    GLOBAL.get_or_init(CostCache::new)
}

/// Convenience: memoized costs through the global cache.
pub fn costs(tech: &Technology, cfg: &SramConfig) -> SramCosts {
    global().costs(tech, cfg)
}

/// Convenience: a per-technology view of the global cache for hot loops.
pub fn for_tech(tech: &Technology) -> TechCosts<'_> {
    global().tech(tech)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::exec::Engine;
    use crate::util::units::KIB;

    #[test]
    fn cached_costs_equal_direct_evaluation() {
        let tech = Technology::default();
        let cache = CostCache::new();
        for (size, ports, sectors) in [(25 * KIB, 1, 1), (64 * KIB, 1, 8), (108 * KIB, 3, 2)] {
            let cfg = SramConfig::new(size, ports, sectors);
            let direct = Sram::new(&tech).evaluate(&cfg);
            let first = cache.costs(&tech, &cfg);
            let second = cache.costs(&tech, &cfg);
            assert_eq!(first, direct);
            assert_eq!(second, direct);
        }
        assert_eq!(cache.misses(), 3);
        assert_eq!(cache.hits(), 3);
        assert_eq!(cache.len(), 3);
    }

    #[test]
    fn distinct_technologies_do_not_alias() {
        let base = Technology::default();
        let mut leaky = Technology::default();
        leaky.sram_leak_w_per_byte *= 4.0;
        let cfg = SramConfig::new(64 * KIB, 1, 1);
        let cache = CostCache::new();
        let a = cache.costs(&base, &cfg);
        let b = cache.costs(&leaky, &cfg);
        assert!(b.leak_on_w > a.leak_on_w * 3.0, "{} vs {}", b.leak_on_w, a.leak_on_w);
        assert_eq!(cache.len(), 2);
        // Both served again -> pure hits.
        let before = cache.hits();
        cache.costs(&base, &cfg);
        cache.costs(&leaky, &cfg);
        assert_eq!(cache.hits(), before + 2);
    }

    #[test]
    fn concurrent_lookups_are_consistent() {
        let tech = Technology::default();
        let cache = CostCache::new();
        let sizes: Vec<usize> = (0..256).map(|i| (8 + (i % 16) * 8) * KIB).collect();
        let direct: Vec<SramCosts> = sizes
            .iter()
            .map(|&s| Sram::new(&tech).evaluate(&SramConfig::new(s, 1, 1)))
            .collect();
        // Hammer the same 16 geometries from 8 workers; results must be
        // identical to the uncached model and the store must stay small.
        let got = Engine::new(8).map(&sizes, |&s| cache.costs(&tech, &SramConfig::new(s, 1, 1)));
        for (g, d) in got.iter().zip(&direct) {
            assert_eq!(g, d);
        }
        assert_eq!(cache.len(), 16);
        assert_eq!(cache.hits() + cache.misses(), 256);
        assert!(cache.hits() >= 256 - 16 * 8, "hits {}", cache.hits());
    }

    #[test]
    fn tech_handle_matches_plain_lookups_and_counts_hits() {
        let tech = Technology::default();
        let cache = CostCache::new();
        let handle = cache.tech(&tech);
        let cfg = SramConfig::new(64 * KIB, 1, 8);
        let via_handle = handle.costs(&cfg);
        let via_plain = cache.costs(&tech, &cfg);
        assert_eq!(via_handle, via_plain);
        assert_eq!(cache.misses(), 1);
        assert_eq!(cache.hits(), 1);
        // Same key namespace: the handle hits entries warmed without it.
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn clear_empties_but_keeps_counters() {
        let tech = Technology::default();
        let cache = CostCache::new();
        cache.costs(&tech, &SramConfig::new(32 * KIB, 1, 1));
        assert!(!cache.is_empty());
        cache.clear();
        assert!(cache.is_empty());
        assert_eq!(cache.misses(), 1);
    }
}
