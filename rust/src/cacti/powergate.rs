//! Sleep-transistor power-gating circuit model (paper Figs 15–16).
//!
//! Captures the 2-way-handshake sleep cycle (ON -> OFF -> wakeup -> ON) and
//! the break-even analysis that decides whether gating a sector for a given
//! interval actually saves energy: the saved leakage over the sleep
//! duration must exceed the wakeup energy.  The PMU (`crate::pmu`) uses
//! [`sleep_saves_energy`] when building sector schedules.

use super::SramCosts;

/// Net energy effect of putting one sector to sleep for `duration_s`
/// (positive = saving).
pub fn sleep_net_saving_j(costs: &SramCosts, duration_s: f64) -> f64 {
    let saved = (costs.leak_sector_on_w - costs.leak_sector_off_w) * duration_s;
    saved - costs.wakeup_energy_j
}

/// Whether gating a sector for `duration_s` is worth the wakeup cost.
pub fn sleep_saves_energy(costs: &SramCosts, duration_s: f64) -> bool {
    sleep_net_saving_j(costs, duration_s) > 0.0
}

/// Break-even sleep duration [s]: shortest OFF interval that amortizes the
/// wakeup energy.
pub fn break_even_s(costs: &SramCosts) -> f64 {
    let delta = costs.leak_sector_on_w - costs.leak_sector_off_w;
    if delta <= 0.0 {
        f64::INFINITY
    } else {
        costs.wakeup_energy_j / delta
    }
}

/// One complete sleep cycle of a sector (Fig 16 timing diagram).
#[derive(Debug, Clone, Copy)]
pub struct SleepCycle {
    /// Time the sector spends OFF [s].
    pub off_s: f64,
    /// Wakeup transition latency [s] (masked by PMU pre-activation).
    pub wakeup_latency_s: f64,
    /// Energy of the OFF->ON transition [J].
    pub wakeup_energy_j: f64,
    /// Leakage energy actually spent while OFF [J].
    pub off_leak_j: f64,
    /// Leakage that would have been spent had the sector stayed ON [J].
    pub counterfactual_on_leak_j: f64,
}

impl SleepCycle {
    pub fn new(costs: &SramCosts, off_s: f64) -> SleepCycle {
        SleepCycle {
            off_s,
            wakeup_latency_s: costs.wakeup_latency_s,
            wakeup_energy_j: costs.wakeup_energy_j,
            off_leak_j: costs.leak_sector_off_w * off_s,
            counterfactual_on_leak_j: costs.leak_sector_on_w * off_s,
        }
    }

    pub fn net_saving_j(&self) -> f64 {
        self.counterfactual_on_leak_j - self.off_leak_j - self.wakeup_energy_j
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cacti::{Sram, SramConfig};
    use crate::config::Technology;
    use crate::util::units::KIB;

    fn costs() -> SramCosts {
        let tech = Technology::default();
        Sram::new(&tech).evaluate(&SramConfig::new(64 * KIB, 1, 8))
    }

    #[test]
    fn long_sleep_saves_short_sleep_does_not() {
        let c = costs();
        assert!(sleep_saves_energy(&c, 1e-3)); // 1 ms op: clear win
        assert!(!sleep_saves_energy(&c, 1e-9)); // 1 ns: wakeup dominates
    }

    #[test]
    fn break_even_is_well_below_op_durations() {
        // Paper section VI-A: wakeup overheads are negligible because ops
        // run for ~hundreds of microseconds; break-even must sit orders of
        // magnitude below the 614 µs average op duration.
        let be = break_even_s(&costs());
        assert!(be > 0.0 && be < 614e-6 / 100.0, "break-even {be}");
    }

    #[test]
    fn sleep_cycle_accounting_is_consistent() {
        let c = costs();
        let cyc = SleepCycle::new(&c, 500e-6);
        let direct = sleep_net_saving_j(&c, 500e-6);
        assert!((cyc.net_saving_j() - direct).abs() < 1e-18);
        assert!(cyc.net_saving_j() > 0.0);
        assert!((cyc.wakeup_latency_s - 0.072e-9).abs() < 1e-15);
    }

    #[test]
    fn break_even_monotone_in_sector_size() {
        // Bigger sectors save more per second but cost more to wake; the
        // wakeup energy and leakage both scale with size, so break-even is
        // size-independent in this model — a documented simplification.
        let tech = Technology::default();
        let m = Sram::new(&tech);
        let b2 = break_even_s(&m.evaluate(&SramConfig::new(64 * KIB, 1, 2)));
        let b16 = break_even_s(&m.evaluate(&SramConfig::new(64 * KIB, 1, 16)));
        assert!((b2 - b16).abs() / b2 < 1e-9);
    }
}
