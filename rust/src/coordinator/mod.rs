//! L3 coordinator: request routing, dynamic batching, worker pool and the
//! serving loop around the PJRT runtime — with the DESCNet memory-subsystem
//! co-simulation attached to every executed batch (each served inference is
//! also accounted through the analytical energy model, so the server
//! reports joules next to latency).
//!
//! No async runtime is vendored in this environment; the coordinator uses
//! std::thread + mpsc channels, which is deterministic and plenty for a
//! single-host serving loop.

pub mod batcher;
pub mod request;
pub mod server;
pub mod stats;

pub use batcher::BatchPolicy;
pub use request::{Request, Response};
