//! Request/response types for the serving path.

use std::time::Instant;

/// One inference request (a single image).
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    /// Target network ("capsnet" or "deepcaps_lite").
    pub net: String,
    /// Flattened input tensor (row-major, matching the manifest shape
    /// without the batch dimension).
    pub image: Vec<f32>,
    pub enqueued: Instant,
}

impl Request {
    pub fn new(id: u64, net: &str, image: Vec<f32>) -> Request {
        Request {
            id,
            net: net.to_string(),
            image,
            // lint: allow(wall_clock, "serving-path enqueue timestamp for latency reporting; never feeds a simulation or fingerprint")
            enqueued: Instant::now(),
        }
    }
}

/// One classified response.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub class: usize,
    pub lengths: Vec<f32>,
    /// End-to-end latency (enqueue -> response) [s].
    pub latency_s: f64,
    /// Batch size this request was served in.
    pub batch: usize,
    /// Co-simulated accelerator+memory energy attributed to this request [J].
    pub energy_j: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_records_enqueue_time() {
        let r = Request::new(1, "capsnet", vec![0.0; 784]);
        assert!(r.enqueued.elapsed().as_secs_f64() < 1.0);
        assert_eq!(r.image.len(), 784);
    }
}
