//! Dynamic batching policy: the coordinator compiles one executable per
//! batch size (PJRT artifacts are shape-static), so the batcher decomposes
//! the pending queue into a sequence of available batch sizes — largest
//! first, padding only when a request would otherwise wait beyond the
//! flush deadline.

use anyhow::{ensure, Result};

/// Pure batching policy (threading-free, property-tested).
///
/// Non-empty by construction: the only constructor ([`BatchPolicy::new`])
/// rejects an empty size list, and the fields are private, so
/// [`BatchPolicy::max_batch`] / [`BatchPolicy::min_batch`] are infallible
/// — no `unwrap` on a `last()` that user input could have emptied.
#[derive(Debug, Clone)]
pub struct BatchPolicy {
    /// Available executable batch sizes, ascending (e.g. [1, 4]).
    sizes: Vec<usize>,
    /// Cached `sizes.last()` / `sizes[0]` (sizes is non-empty, sorted).
    largest: usize,
    smallest: usize,
    /// Max time a request may wait for peers before we pad-and-flush [s].
    pub flush_deadline_s: f64,
}

impl BatchPolicy {
    /// Errors (instead of asserting) on an empty size list or a zero batch
    /// size — both reachable from user input (an SLO that filters out every
    /// executable batch, a malformed manifest), so the serving path must be
    /// able to report them rather than abort.
    pub fn new(mut sizes: Vec<usize>, flush_deadline_s: f64) -> Result<BatchPolicy> {
        ensure!(!sizes.is_empty(), "need at least one batch size");
        ensure!(
            sizes.iter().all(|&s| s > 0),
            "batch sizes must be non-zero, got {sizes:?}"
        );
        ensure!(
            flush_deadline_s.is_finite() && flush_deadline_s >= 0.0,
            "flush deadline must be a non-negative duration, got {flush_deadline_s} s"
        );
        sizes.sort_unstable();
        sizes.dedup();
        let largest = sizes[sizes.len() - 1];
        let smallest = sizes[0];
        Ok(BatchPolicy {
            sizes,
            largest,
            smallest,
            flush_deadline_s,
        })
    }

    /// Executable batch sizes, ascending and deduplicated (never empty).
    pub fn sizes(&self) -> &[usize] {
        &self.sizes
    }

    pub fn max_batch(&self) -> usize {
        self.largest
    }

    pub fn min_batch(&self) -> usize {
        self.smallest
    }

    /// Greedy decomposition of `pending` requests into executable batch
    /// sizes (largest-first).  The remainder below the smallest size stays
    /// queued unless `force_flush` (deadline hit), in which case it is
    /// emitted as the smallest size that covers it (callers pad the tail).
    pub fn plan(&self, pending: usize, force_flush: bool) -> Vec<usize> {
        let mut out = Vec::new();
        let mut left = pending;
        for &size in self.sizes.iter().rev() {
            while left >= size {
                out.push(size);
                left -= size;
            }
        }
        if left > 0 && force_flush {
            let cover = self
                .sizes
                .iter()
                .copied()
                .find(|&s| s >= left)
                .unwrap_or(self.largest);
            out.push(cover);
        }
        out
    }

    /// Requests consumed by a plan (padding excluded).
    pub fn planned_requests(&self, pending: usize, force_flush: bool) -> usize {
        let planned: usize = self.plan(pending, force_flush).iter().sum();
        planned.min(pending)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::check;

    #[test]
    fn greedy_largest_first() {
        let p = BatchPolicy::new(vec![1, 4], 5e-3).unwrap();
        assert_eq!(p.plan(9, false), vec![4, 4, 1]);
        assert_eq!(p.plan(3, false), vec![1, 1, 1]);
        assert_eq!(p.plan(0, false), Vec::<usize>::new());
    }

    #[test]
    fn remainder_waits_unless_flushed() {
        let p = BatchPolicy::new(vec![4, 8], 5e-3).unwrap();
        assert_eq!(p.plan(3, false), Vec::<usize>::new()); // waits for peers
        assert_eq!(p.plan(3, true), vec![4]); // padded flush
        assert_eq!(p.plan(11, true), vec![8, 4]);
    }

    #[test]
    fn sizes_are_sorted_and_deduped() {
        let p = BatchPolicy::new(vec![4, 1, 4], 5e-3).unwrap();
        assert_eq!(p.sizes(), &[1, 4]);
        assert_eq!(p.max_batch(), 4);
        assert_eq!(p.min_batch(), 1);
    }

    #[test]
    fn invalid_policies_error_instead_of_asserting() {
        assert!(BatchPolicy::new(vec![], 1e-3).is_err());
        assert!(BatchPolicy::new(vec![0, 4], 1e-3).is_err());
        assert!(BatchPolicy::new(vec![1], f64::NAN).is_err());
        assert!(BatchPolicy::new(vec![1], -1.0).is_err());
    }

    #[test]
    fn prop_plan_covers_exactly_without_flush() {
        // Without flush, the plan serves as many requests as possible using
        // exact sizes; the remainder is strictly smaller than the smallest
        // batch size.
        check("batcher-exact-cover", 200, |rng| {
            let sizes: Vec<usize> = match rng.below(3) {
                0 => vec![1, 4],
                1 => vec![2, 8],
                _ => vec![1, 2, 4, 8],
            };
            let p = BatchPolicy::new(sizes.clone(), 1e-3).unwrap();
            let pending = rng.below(100) as usize;
            let plan = p.plan(pending, false);
            let served: usize = plan.iter().sum();
            prop_assert!(served <= pending, "over-served {served} > {pending}");
            prop_assert!(
                pending - served < sizes[0],
                "remainder {} >= smallest size {}",
                pending - served,
                sizes[0]
            );
            for b in &plan {
                prop_assert!(sizes.contains(b), "plan used unknown size {b}");
            }
            Ok(())
        });
    }

    #[test]
    fn prop_flush_always_serves_everything() {
        check("batcher-flush-covers", 200, |rng| {
            let p = BatchPolicy::new(vec![1 + rng.below(4) as usize * 3], 1e-3).unwrap();
            let pending = rng.below(50) as usize;
            let plan = p.plan(pending, true);
            let capacity: usize = plan.iter().sum();
            prop_assert!(capacity >= pending, "{capacity} < {pending}");
            // Padding never exceeds one batch's worth.
            prop_assert!(capacity - pending < p.max_batch());
            Ok(())
        });
    }
}
