//! The serving loop: synthetic-client generator -> dynamic batcher ->
//! PJRT execution (full-net or 3-stage pipeline) -> stats + DESCNet energy
//! co-simulation.
//!
//! This is the end-to-end driver of EXPERIMENTS.md E19: it proves the three
//! layers compose — Pallas kernels (L1) lowered into the stage HLO (L2)
//! executed under the rust coordinator (L3) — while the analytical DESCNet
//! model accounts energy for every served inference.

use std::path::PathBuf;
use std::sync::mpsc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::batcher::BatchPolicy;
use super::request::{Request, Response};
use super::stats::ServeStats;
use crate::config::SystemConfig;
use crate::ctx::EvalCtx;
use crate::dataflow::{profile_network_batched, NetworkProfile};
use crate::dse::multi::{self, WorkloadSet};
use crate::energy::system_with_org;
use crate::memory::Organization;
use crate::model::capsnet_mnist;
use crate::runtime::{argmax_per_row, Runtime};
use crate::util::exec;
use crate::util::prng::Prng;

#[derive(Debug, Clone)]
pub struct ServeOptions {
    pub artifacts_dir: PathBuf,
    pub requests: usize,
    pub batch_max: usize,
    pub stage_pipeline: bool,
    pub seed: u64,
    /// Per-batch latency SLO [s]: batch sizes whose *simulated* batch
    /// latency (DESCNet timeline, `sim`) exceeds this are never scheduled,
    /// so batching can only grow until the accelerator-side latency budget
    /// is spent.  None = energy-only batch selection (the pre-sim policy).
    pub slo_s: Option<f64>,
}

impl Default for ServeOptions {
    fn default() -> ServeOptions {
        ServeOptions {
            artifacts_dir: PathBuf::from("artifacts"),
            requests: 64,
            batch_max: 4,
            stage_pipeline: false,
            seed: 7,
            slo_s: None,
        }
    }
}

pub struct Server;

/// Synthetic MNIST-like image: a couple of random strokes plus noise —
/// shape-compatible stand-in for the python generator (DESIGN.md
/// Substitutions; classification content is irrelevant to serving metrics).
pub fn synthetic_image(rng: &mut Prng, hw: usize) -> Vec<f32> {
    let mut img = vec![0.0f32; hw * hw];
    for _ in 0..2 {
        let (x0, y0) = (rng.f64() * hw as f64, rng.f64() * hw as f64);
        let (x1, y1) = (rng.f64() * hw as f64, rng.f64() * hw as f64);
        for t in 0..(3 * hw) {
            let f = t as f64 / (3 * hw - 1) as f64;
            let cx = x0 + (x1 - x0) * f;
            let cy = y0 + (y1 - y0) * f;
            let (xi, yi) = (cx as usize, cy as usize);
            if xi < hw && yi < hw {
                img[yi * hw + xi] = 1.0;
            }
        }
    }
    for v in img.iter_mut() {
        *v = (*v + rng.f64() as f32 * 0.1).min(1.0);
    }
    img
}

/// Batch-aware co-simulation plan: one organization co-designed (via
/// `dse::multi`) across the CapsNet profiles of every batch size the
/// batcher may execute, with per-batch energy *and* simulated latency.
pub(crate) struct ServingCodesign {
    pub org: Organization,
    /// Per-inference system energy [J] of each batch size.
    pub energy_per_inf: std::collections::BTreeMap<usize, f64>,
    /// Simulated end-to-end *batch* latency [s] of each batch size
    /// (timeline + wakeup exposure) — what an SLO is charged against.
    pub batch_latency_s: std::collections::BTreeMap<usize, f64>,
}

/// Co-designs the serving organization and evaluates each batch size —
/// each served inference is accounted with the energy of the batch it
/// actually rode in (weight traffic and static energy amortize as batches
/// fill), and batch-size selection can charge the simulated per-batch
/// latency against an SLO instead of energy alone.
pub(crate) fn codesign_serving(ctx: &EvalCtx, batches: &[usize]) -> Result<ServingCodesign> {
    anyhow::ensure!(!batches.is_empty(), "no batch sizes to co-design for");
    let cfg = ctx.config();
    let net = capsnet_mnist();
    let profiles: Vec<NetworkProfile> = batches
        .iter()
        .map(|&b| profile_network_batched(&net, &cfg.accel, b))
        .collect();
    let set = WorkloadSet::new(profiles)?;
    let result = multi::run(ctx, &set)
        .context("co-designing the serving organization")?;
    let best = result
        .codesigned()
        .ok_or_else(|| anyhow::anyhow!("co-design DSE selected no organization"))?;
    let org = result.points[best].org.clone();
    let mut energy_per_inf = std::collections::BTreeMap::new();
    let mut batch_latency_s = std::collections::BTreeMap::new();
    for (b, p) in batches.iter().zip(set.profiles()) {
        let sys = system_with_org(p, &cfg.tech, &org, "serving")?;
        energy_per_inf.insert(*b, sys.total_j());
        let lp = crate::sim::simulate(p, &org, &cfg.tech, &cfg.accel)?;
        batch_latency_s.insert(*b, lp.batch_latency_s());
    }
    Ok(ServingCodesign {
        org,
        energy_per_inf,
        batch_latency_s,
    })
}

impl Server {
    /// Serves `opts.requests` synthetic requests and returns the stats.
    pub fn run_synthetic(opts: &ServeOptions) -> Result<ServeStats> {
        let cfg = SystemConfig::default();
        let mut runtime = Runtime::new(&opts.artifacts_dir)
            .context("loading artifacts (run `make artifacts` first)")?;
        let platform = runtime.platform();

        // Discover batch sizes and pre-compile executables (outside the
        // serving loop — compilation is a startup cost).
        let batches: Vec<usize> = runtime
            .manifest
            .batches("capsnet", "full")
            .into_iter()
            .filter(|&b| b <= opts.batch_max)
            .collect();
        anyhow::ensure!(!batches.is_empty(), "no capsnet batch <= {}", opts.batch_max);

        // Co-design one SPM organization across every batch size the
        // batcher may execute; each served inference is then accounted
        // with the per-inference energy of its actual batch, and the
        // simulated per-batch latency gates batch sizes against the SLO.
        let plan = codesign_serving(&EvalCtx::for_config(&cfg), &batches)?;
        let batches = match opts.slo_s {
            Some(slo) => {
                let ok: Vec<usize> = batches
                    .iter()
                    .copied()
                    .filter(|b| plan.batch_latency_s[b] <= slo)
                    .collect();
                anyhow::ensure!(
                    !ok.is_empty(),
                    "SLO {:.3} ms is unmeetable: the smallest batch ({}) simulates to {:.3} ms",
                    slo * 1e3,
                    batches[0],
                    plan.batch_latency_s[&batches[0]] * 1e3
                );
                ok
            }
            None => batches,
        };
        let energy_by_batch = &plan.energy_per_inf;
        let stages: &[&str] = if opts.stage_pipeline {
            &["conv1", "primarycaps", "classcaps"]
        } else {
            &["full"]
        };
        for stage in stages {
            for &b in &batches {
                runtime.load_stage("capsnet", stage, b)?;
            }
        }
        let policy = BatchPolicy::new(batches, 2e-3)
            .context("building the batching policy from the admitted batch sizes")?;

        // Generator task: Poisson-ish arrivals on the shared engine's
        // background facility (one named producer thread).
        let (tx, rx) = mpsc::channel::<Request>();
        let n = opts.requests;
        let seed = opts.seed;
        let hw = 28;
        let gen = exec::background("request-gen", move || {
            let mut rng = Prng::new(seed);
            for id in 0..n as u64 {
                let img = synthetic_image(&mut rng, hw);
                if tx.send(Request::new(id, "capsnet", img)).is_err() {
                    return;
                }
                std::thread::sleep(Duration::from_micros(rng.exp(300.0) as u64));
            }
        });

        let mut stats = ServeStats::default();
        stats.platform = platform;
        stats.slo_s = opts.slo_s;
        stats.sim_batch_latency = policy
            .sizes()
            .iter()
            .map(|b| (*b, plan.batch_latency_s[b]))
            .collect();
        let t0 = Instant::now();
        let mut pending: Vec<Request> = Vec::new();
        let mut served = 0usize;
        let mut closed = false;

        while served < opts.requests {
            // Fill the pending queue up to the largest batch or deadline.
            let deadline = Instant::now() + Duration::from_secs_f64(policy.flush_deadline_s);
            while pending.len() < policy.max_batch() && !closed {
                let now = Instant::now();
                if now >= deadline && !pending.is_empty() {
                    break;
                }
                let timeout = if pending.is_empty() {
                    Duration::from_millis(200)
                } else {
                    deadline - now
                };
                match rx.recv_timeout(timeout) {
                    Ok(req) => pending.push(req),
                    Err(mpsc::RecvTimeoutError::Timeout) => {
                        if !pending.is_empty() {
                            break;
                        }
                    }
                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                        closed = true;
                    }
                }
            }
            if pending.is_empty() {
                if closed {
                    break;
                }
                continue;
            }

            let force = closed || pending.len() < policy.min_batch();
            let _ = force;
            let plan = policy.plan(pending.len(), true);
            for batch in plan {
                if pending.is_empty() {
                    break;
                }
                let take = batch.min(pending.len());
                let reqs: Vec<Request> = pending.drain(..take).collect();
                let pad = batch - take;
                let energy_per_inf = energy_by_batch
                    .get(&batch)
                    .copied()
                    .ok_or_else(|| anyhow::anyhow!("no co-designed energy for batch {batch}"))?;
                let t_exec = Instant::now();
                let responses = if opts.stage_pipeline {
                    Self::execute_staged(&mut runtime, batch, &reqs, pad, energy_per_inf)?
                } else {
                    Self::execute_full(&mut runtime, batch, &reqs, pad, energy_per_inf)?
                };
                stats.batch_exec.add(t_exec.elapsed().as_secs_f64());
                for resp in responses {
                    stats.latency.add(resp.latency_s);
                    stats.energy_j += resp.energy_j;
                    if resp.class < 10 {
                        stats.class_histogram[resp.class] += 1;
                    }
                    served += 1;
                }
                stats.batches += 1;
                stats.padded_slots += pad as u64;
            }
        }
        stats.requests = served as u64;
        stats.wall_s = t0.elapsed().as_secs_f64();
        gen.join();
        Ok(stats)
    }

    fn pack_input(batch: usize, reqs: &[Request], pad: usize) -> Vec<f32> {
        let per = reqs.first().map(|r| r.image.len()).unwrap_or(0);
        let mut input = Vec::with_capacity(batch * per);
        for r in reqs {
            input.extend_from_slice(&r.image);
        }
        for _ in 0..pad {
            input.extend(std::iter::repeat(0.0f32).take(per));
        }
        input
    }

    fn to_responses(
        reqs: &[Request],
        lengths: &[f32],
        batch: usize,
        energy_per_inf: f64,
    ) -> Vec<Response> {
        let classes = argmax_per_row(lengths, 10);
        reqs.iter()
            .enumerate()
            .map(|(i, r)| Response {
                id: r.id,
                class: classes[i],
                lengths: lengths[i * 10..(i + 1) * 10].to_vec(),
                latency_s: r.enqueued.elapsed().as_secs_f64(),
                batch,
                energy_j: energy_per_inf,
            })
            .collect()
    }

    fn execute_full(
        runtime: &mut Runtime,
        batch: usize,
        reqs: &[Request],
        pad: usize,
        energy_per_inf: f64,
    ) -> Result<Vec<Response>> {
        let input = Self::pack_input(batch, reqs, pad);
        let (lengths, _poses) = runtime.infer_full("capsnet", batch, &input)?;
        Ok(Self::to_responses(reqs, &lengths, batch, energy_per_inf))
    }

    /// Stage-wise execution through the three per-stage artifacts — the
    /// operation granularity the DESCNet memory model schedules.
    fn execute_staged(
        runtime: &mut Runtime,
        batch: usize,
        reqs: &[Request],
        pad: usize,
        energy_per_inf: f64,
    ) -> Result<Vec<Response>> {
        let input = Self::pack_input(batch, reqs, pad);
        let h = runtime
            .load_stage("capsnet", "conv1", batch)?
            .execute(&input)?
            .remove(0);
        let u = runtime
            .load_stage("capsnet", "primarycaps", batch)?
            .execute(&h)?
            .remove(0);
        let outs = runtime
            .load_stage("capsnet", "classcaps", batch)?
            .execute(&u)?;
        let lengths = &outs[0];
        Ok(Self::to_responses(reqs, lengths, batch, energy_per_inf))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_image_in_range() {
        let mut rng = Prng::new(3);
        let img = synthetic_image(&mut rng, 28);
        assert_eq!(img.len(), 784);
        assert!(img.iter().all(|&v| (0.0..=1.0).contains(&v)));
        assert!(img.iter().any(|&v| v > 0.5), "strokes present");
    }

    #[test]
    fn codesigned_energy_is_millijoule_scale_and_amortizes() {
        let ctx = EvalCtx::for_config(&SystemConfig::default());
        let plan = codesign_serving(&ctx, &[1, 2, 4]).unwrap();
        assert!(plan.org.total_size() > 0);
        for (&b, &e) in &plan.energy_per_inf {
            assert!(e > 1e-4 && e < 0.1, "batch {b}: {e}");
        }
        // Bigger batches amortize weight traffic + static energy.
        assert!(plan.energy_per_inf[&4] < plan.energy_per_inf[&1]);
        assert!(plan.energy_per_inf[&2] < plan.energy_per_inf[&1]);
    }

    #[test]
    fn codesigned_energy_rejects_empty_batch_list() {
        let ctx = EvalCtx::for_config(&SystemConfig::default());
        assert!(codesign_serving(&ctx, &[]).is_err());
    }

    #[test]
    fn codesigned_batch_latency_grows_with_batch_but_amortizes() {
        // Charging an SLO needs the *batch* latency: it must grow with the
        // batch while the per-inference latency shrinks — the exact
        // batching trade-off the coordinator navigates.
        let ctx = EvalCtx::for_config(&SystemConfig::default());
        let plan = codesign_serving(&ctx, &[1, 2, 4]).unwrap();
        let l1 = plan.batch_latency_s[&1];
        let l2 = plan.batch_latency_s[&2];
        let l4 = plan.batch_latency_s[&4];
        assert!(l1 > 1e-3 && l1 < 0.1, "{l1}");
        assert!(l2 > l1 && l4 > l2, "{l1} {l2} {l4}");
        assert!(l4 / 4.0 < l1, "per-inference latency must amortize");
        // An SLO between batch-2 and batch-4 latency would admit {1, 2}:
        // exactly the filter run_synthetic applies.
        let slo = (l2 + l4) / 2.0;
        let admitted: Vec<usize> = [1usize, 2, 4]
            .iter()
            .copied()
            .filter(|b| plan.batch_latency_s[b] <= slo)
            .collect();
        assert_eq!(admitted, vec![1, 2]);
    }

    #[test]
    fn pack_input_pads_with_zeros() {
        let reqs = vec![Request::new(0, "capsnet", vec![1.0; 4])];
        let input = Server::pack_input(3, &reqs, 2);
        assert_eq!(input.len(), 12);
        assert!(input[4..].iter().all(|&v| v == 0.0));
    }
}
