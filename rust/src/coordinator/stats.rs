//! Serving statistics: latency percentiles, throughput, co-simulated
//! energy — the numbers EXPERIMENTS.md E19 records.

use crate::util::stats::{Percentiles, Summary};
use crate::util::units::{fmt_energy, fmt_time};

#[derive(Default)]
pub struct ServeStats {
    pub requests: u64,
    pub batches: u64,
    pub padded_slots: u64,
    pub latency: Percentiles,
    pub batch_exec: Summary,
    pub wall_s: f64,
    pub energy_j: f64,
    pub platform: String,
    pub class_histogram: [u64; 10],
    /// Per-batch latency SLO the batcher was gated with (None = energy-only).
    pub slo_s: Option<f64>,
    /// Simulated DESCNet batch latency of each admitted batch size
    /// (`sim::Timeline` + wakeup exposure), the values charged to the SLO.
    pub sim_batch_latency: Vec<(usize, f64)>,
}

impl ServeStats {
    pub fn throughput_rps(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.requests as f64 / self.wall_s
        } else {
            0.0
        }
    }

    pub fn mean_batch(&self) -> f64 {
        if self.batches > 0 {
            (self.requests + self.padded_slots) as f64 / self.batches as f64
        } else {
            0.0
        }
    }

    pub fn summary(&mut self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "served {} requests in {} on {} ({:.1} req/s)\n",
            self.requests,
            fmt_time(self.wall_s),
            self.platform,
            self.throughput_rps()
        ));
        out.push_str(&format!(
            "batches: {} (mean size {:.2}, {} padded slots)\n",
            self.batches,
            self.mean_batch(),
            self.padded_slots
        ));
        out.push_str(&format!(
            "latency: p50 {}  p95 {}  p99 {}  max {}\n",
            fmt_time(self.latency.p50()),
            fmt_time(self.latency.p95()),
            fmt_time(self.latency.p99()),
            fmt_time(self.latency.percentile(100.0)),
        ));
        out.push_str(&format!(
            "batch exec: mean {}  min {}  max {}\n",
            fmt_time(self.batch_exec.mean()),
            fmt_time(self.batch_exec.min()),
            fmt_time(self.batch_exec.max()),
        ));
        out.push_str(&format!(
            "co-simulated DESCNet energy: {} total, {} per inference\n",
            fmt_energy(self.energy_j),
            fmt_energy(self.energy_j / self.requests.max(1) as f64),
        ));
        if !self.sim_batch_latency.is_empty() {
            let per_batch = self
                .sim_batch_latency
                .iter()
                .map(|(b, l)| format!("b{b}={}", fmt_time(*l)))
                .collect::<Vec<_>>()
                .join("  ");
            match self.slo_s {
                Some(slo) => out.push_str(&format!(
                    "sim batch latency (SLO {}): {per_batch}\n",
                    fmt_time(slo)
                )),
                None => out.push_str(&format!("sim batch latency: {per_batch}\n")),
            }
        }
        out.push_str(&format!("class histogram: {:?}", self.class_histogram));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_and_mean_batch() {
        let mut s = ServeStats::default();
        s.requests = 100;
        s.batches = 30;
        s.padded_slots = 20;
        s.wall_s = 2.0;
        assert!((s.throughput_rps() - 50.0).abs() < 1e-9);
        assert!((s.mean_batch() - 4.0).abs() < 1e-9);
    }

    #[test]
    fn summary_contains_key_lines() {
        let mut s = ServeStats::default();
        s.requests = 4;
        s.batches = 1;
        s.wall_s = 0.1;
        s.platform = "cpu".into();
        for l in [0.01, 0.02, 0.03, 0.04] {
            s.latency.add(l);
        }
        s.batch_exec.add(0.02);
        s.energy_j = 4.0 * 12e-3;
        let text = s.summary();
        assert!(text.contains("served 4 requests"));
        assert!(text.contains("p95"));
        assert!(text.contains("per inference"));
        // No sim latencies recorded: the SLO line is omitted entirely.
        assert!(!text.contains("sim batch latency"));
    }

    #[test]
    fn summary_reports_slo_and_sim_latencies() {
        let mut s = ServeStats::default();
        s.requests = 1;
        s.slo_s = Some(20e-3);
        s.sim_batch_latency = vec![(1, 8.6e-3), (2, 12.0e-3)];
        let text = s.summary();
        assert!(text.contains("sim batch latency (SLO "), "{text}");
        assert!(text.contains("b1="), "{text}");
        assert!(text.contains("b2="), "{text}");
    }
}
