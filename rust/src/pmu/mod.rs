//! Application-driven memory power-management unit (paper section V-B).
//!
//! From the operation-wise utilization profile (Figs 10a/11a) the PMU
//! derives, per physical memory, how many sectors must be ON during each
//! operation; sectors needed by the *next* operation are pre-activated
//! while the current one computes, so the 0.072 ns wakeup latency is
//! transparently masked (checked in [`PmuReport::wakeup_masked`]).
//!
//! The report carries the -PG static-energy accounting (ON + residual OFF
//! leakage + wakeup transitions) and the Fig 30-style ON/OFF schedule.

use anyhow::{anyhow, Result};

use crate::cacti::{cache, SramCosts};
use crate::config::Technology;
use crate::dataflow::NetworkProfile;
use crate::memory::{cover_op, Component, Organization};

/// Per-component, per-op sector schedule (Fig 30).
#[derive(Debug, Clone)]
pub struct SectorSchedule {
    pub component: Component,
    pub sectors: usize,
    /// ON-sector count per operation (same index order as the profile).
    pub on: Vec<usize>,
    /// OFF->ON transitions over the inference (wakeup count).
    pub wakeups: u64,
}

impl SectorSchedule {
    /// Fraction of sector-time spent ON, weighted by op durations.
    pub fn on_fraction(&self, durations: &[f64]) -> f64 {
        let total: f64 = durations.iter().sum();
        if total == 0.0 || self.sectors == 0 {
            return 0.0;
        }
        let weighted: f64 = self
            .on
            .iter()
            .zip(durations)
            .map(|(&n, &d)| n as f64 / self.sectors as f64 * d)
            .sum();
        weighted / total
    }
}

/// Energy accounting for one component.
#[derive(Debug, Clone)]
pub struct ComponentStatic {
    pub component: Component,
    pub static_energy_j: f64,
    pub wakeup_energy_j: f64,
    pub wakeups: u64,
    /// Counterfactual static energy with power gating disabled.
    pub static_no_pg_j: f64,
}

/// Full PMU evaluation of an organization over a network profile.
#[derive(Debug, Clone)]
pub struct PmuReport {
    pub schedules: Vec<SectorSchedule>,
    pub components: Vec<ComponentStatic>,
    /// Longest wakeup latency vs shortest op duration (for masking check).
    pub max_wakeup_latency_s: f64,
    pub min_op_duration_s: f64,
}

impl PmuReport {
    pub fn static_energy_j(&self) -> f64 {
        self.components.iter().map(|c| c.static_energy_j).sum()
    }

    pub fn wakeup_energy_j(&self) -> f64 {
        self.components.iter().map(|c| c.wakeup_energy_j).sum()
    }

    pub fn static_no_pg_j(&self) -> f64 {
        self.components.iter().map(|c| c.static_no_pg_j).sum()
    }

    /// Pre-activation masks the wakeup latency as long as every op runs
    /// longer than a wakeup (paper: 0.072 ns vs ~614 µs average).
    pub fn wakeup_masked(&self) -> bool {
        self.max_wakeup_latency_s < self.min_op_duration_s
    }

    pub fn schedule(&self, c: Component) -> Option<&SectorSchedule> {
        self.schedules.iter().find(|s| s.component == c)
    }

    /// Wakeup latency left exposed by pre-activation over the given per-op
    /// durations [s]: for every op boundary where any component's schedule
    /// turns additional sectors ON, the residue
    /// `max(0, wakeup_latency - previous_op_duration)` is charged once
    /// (components wake in parallel).  Op 0's sectors wake during the
    /// previous frame and are never exposed.  The timeline simulator's fast
    /// pass (`sim::wakeup_exposure_s`) computes the identical sum without
    /// building a report — `sim::tests` pins the two bit-equal.
    pub fn wakeup_exposure_s(&self, durations_s: &[f64], wakeup_latency_s: f64) -> f64 {
        if wakeup_latency_s <= 0.0 {
            return 0.0;
        }
        let n = durations_s.len();
        let mut exposure = 0.0;
        for i in 1..n {
            let wakes = self
                .schedules
                .iter()
                .any(|s| s.sectors > 1 && s.on.len() == n && s.on[i] > s.on[i - 1]);
            if wakes {
                exposure += (wakeup_latency_s - durations_s[i - 1]).max(0.0);
            }
        }
        exposure
    }
}

/// Bytes of each component needed by each op under this organization.
fn component_needs(
    org: &Organization,
    profile: &NetworkProfile,
    c: Component,
) -> Result<Vec<usize>> {
    profile
        .ops
        .iter()
        .map(|op| {
            let cov = cover_op(org, op).ok_or_else(|| {
                anyhow!(
                    "operation '{}' of '{}' does not fit organization {}",
                    op.name,
                    profile.network,
                    org.label()
                )
            })?;
            Ok(match c {
                Component::Data => cov.ded_d,
                Component::Weight => cov.ded_w,
                Component::Acc => cov.ded_a,
                Component::Shared => cov.shared_total(),
            })
        })
        .collect()
}

/// Evaluates the PMU over one batch execution of `profile` on `org`.
/// (Schedules and energies are per batch; the `energy` layer amortizes per
/// inference.)  Errors instead of panicking when the organization cannot
/// hold an operation's working set.
pub fn evaluate(
    org: &Organization,
    profile: &NetworkProfile,
    tech: &Technology,
) -> Result<PmuReport> {
    let durations: Vec<f64> = profile
        .ops
        .iter()
        .map(|op| op.cycles as f64 / profile.clock_hz)
        .collect();
    let total_time: f64 = durations.iter().sum();

    let mut schedules = Vec::new();
    let mut components = Vec::new();
    let mut max_wakeup = 0.0f64;

    let costs_of = cache::for_tech(tech);
    for (component, spec) in org.components() {
        let cfg = org
            .sram_config(component)
            .ok_or_else(|| anyhow!("instantiated component {} has no spec", component.label()))?;
        let costs: SramCosts = costs_of.costs(&cfg);
        let needs = component_needs(org, profile, component)?;
        let sector_bytes = cfg.sector_bytes().max(1);

        // ON-sector count per op: contiguous allocation from sector 0.
        let on: Vec<usize> = needs
            .iter()
            .map(|&b| {
                if spec.sectors <= 1 {
                    // No power gating: the array is monolithic and always on.
                    1
                } else {
                    b.div_ceil(sector_bytes)
                }
            })
            .collect();

        let (static_j, wakeups) = if spec.sectors <= 1 {
            (costs.leak_on_w * total_time, 0)
        } else {
            let mut e = 0.0;
            let mut wakeups = 0u64;
            let mut prev_on = 0usize; // all sectors start OFF (pre-activated
                                      // for op 0 during the previous frame)
            for (i, &n) in on.iter().enumerate() {
                let off = spec.sectors - n;
                e += durations[i]
                    * (n as f64 * costs.leak_sector_on_w
                        + off as f64 * costs.leak_sector_off_w);
                wakeups += (n.saturating_sub(prev_on)) as u64;
                prev_on = n;
            }
            (e, wakeups)
        };

        max_wakeup = max_wakeup.max(costs.wakeup_latency_s);
        schedules.push(SectorSchedule {
            component,
            sectors: spec.sectors,
            on,
            wakeups,
        });
        components.push(ComponentStatic {
            component,
            static_energy_j: static_j,
            wakeup_energy_j: wakeups as f64 * costs.wakeup_energy_j,
            wakeups,
            static_no_pg_j: costs.leak_on_w * total_time,
        });
    }

    Ok(PmuReport {
        schedules,
        components,
        max_wakeup_latency_s: max_wakeup,
        min_op_duration_s: durations.iter().cloned().fold(f64::INFINITY, f64::min),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Accelerator;
    use crate::dataflow::profile_network;
    use crate::memory::MemSpec;
    use crate::model::capsnet_mnist;
    use crate::util::units::KIB;

    fn profile() -> NetworkProfile {
        profile_network(&capsnet_mnist(), &Accelerator::default())
    }

    fn sep_pg() -> Organization {
        // Paper Table I SEP-PG: data 25k/2, weight 64k/8, acc 32k/2.
        Organization::sep(
            MemSpec::new(25 * KIB, 2),
            MemSpec::new(64 * KIB, 8),
            MemSpec::new(32 * KIB, 2),
        )
    }

    #[test]
    fn power_gating_reduces_static_energy() {
        let tech = Technology::default();
        let p = profile();
        let report = evaluate(&sep_pg(), &p, &tech).unwrap();
        let saved = 1.0 - report.static_energy_j() / report.static_no_pg_j();
        // Paper Table I/III: SEP-PG cuts SEP's static energy by ~60-73%.
        assert!(
            (0.25..0.80).contains(&saved),
            "PG saving fraction = {saved:.3}"
        );
    }

    #[test]
    fn non_pg_static_equals_counterfactual() {
        let tech = Technology::default();
        let p = profile();
        let sep = Organization::sep(
            MemSpec::new(25 * KIB, 1),
            MemSpec::new(64 * KIB, 1),
            MemSpec::new(32 * KIB, 1),
        );
        let report = evaluate(&sep, &p, &tech).unwrap();
        assert!((report.static_energy_j() - report.static_no_pg_j()).abs() < 1e-15);
        assert_eq!(report.wakeup_energy_j(), 0.0);
    }

    #[test]
    fn weight_memory_schedule_follows_utilization() {
        // Fig 30's pattern: few sectors on during Conv1 (2.6k of 64k), most
        // during Class (53.8k), a middle amount during routing (22.5k).
        let tech = Technology::default();
        let p = profile();
        let report = evaluate(&sep_pg(), &p, &tech).unwrap();
        let w = report.schedule(Component::Weight).unwrap();
        assert_eq!(w.sectors, 8);
        let idx = |name: &str| p.ops.iter().position(|o| o.name.as_ref() == name).unwrap();
        assert_eq!(w.on[idx("Conv1")], 1); // 2,592 B -> 1 of 8 sectors
        assert_eq!(w.on[idx("Prim")], 6); // 41,472 B -> 6 sectors
        assert_eq!(w.on[idx("Class")], 7); // 53,760 B -> 7 sectors
        assert_eq!(w.on[idx("Class-Sum+Squash1")], 3); // 23,040 B -> 3
    }

    #[test]
    fn wakeup_latency_is_masked() {
        let tech = Technology::default();
        let p = profile();
        let report = evaluate(&sep_pg(), &p, &tech).unwrap();
        assert!(report.wakeup_masked());
        // Shortest op is still > 1000x the wakeup latency.
        assert!(report.min_op_duration_s / report.max_wakeup_latency_s > 1e3);
    }

    #[test]
    fn wakeup_energy_is_negligible_vs_static() {
        // Paper: average wakeup energy ~1.6 nJ vs mJ-scale static energy.
        let tech = Technology::default();
        let p = profile();
        let report = evaluate(&sep_pg(), &p, &tech).unwrap();
        assert!(report.wakeup_energy_j() > 0.0);
        assert!(report.wakeup_energy_j() < 1e-3 * report.static_energy_j());
    }

    #[test]
    fn more_sectors_save_more_static_energy() {
        let tech = Technology::default();
        let p = profile();
        let mut prev = f64::INFINITY;
        for sc in [2, 4, 8, 16] {
            let org = Organization::sep(
                MemSpec::new(25 * KIB, 2),
                MemSpec::new(64 * KIB, sc),
                MemSpec::new(32 * KIB, 2),
            );
            let e = evaluate(&org, &p, &tech).unwrap().static_energy_j();
            assert!(e <= prev + 1e-15, "SC={sc}: {e} > {prev}");
            prev = e;
        }
    }

    #[test]
    fn on_fraction_weighted_by_duration() {
        let tech = Technology::default();
        let p = profile();
        let report = evaluate(&sep_pg(), &p, &tech).unwrap();
        let durations: Vec<f64> = p
            .ops
            .iter()
            .map(|op| op.cycles as f64 / p.clock_hz)
            .collect();
        let f = report
            .schedule(Component::Weight)
            .unwrap()
            .on_fraction(&durations);
        assert!(f > 0.0 && f < 1.0, "{f}");
    }

    #[test]
    fn shared_memory_schedule_covers_spills() {
        let tech = Technology::default();
        let p = profile();
        // HY with tiny dedicated memories: shared carries the spill.
        let org = Organization::hy(
            MemSpec::new(64 * KIB, 4),
            MemSpec::new(8 * KIB, 1),
            MemSpec::new(32 * KIB, 1),
            MemSpec::new(16 * KIB, 1),
            3,
        );
        let report = evaluate(&org, &p, &tech).unwrap();
        let sh = report.schedule(Component::Shared).unwrap();
        assert!(sh.on.iter().any(|&n| n > 0));
        assert!(sh.on.iter().any(|&n| n < sh.sectors), "sometimes gated");
    }
}
