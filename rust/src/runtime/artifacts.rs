//! Artifact manifest loader — the rust half of the AOT contract with
//! `python/compile/aot.py` (`artifacts/manifest.json`).

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// One HLO-text artifact (a lowered model stage at a fixed batch size).
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: String,
    pub net: String,
    pub stage: String,
    pub batch: usize,
    /// Weight-argument names, in PJRT argument order (before the input).
    pub params: Vec<String>,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

#[derive(Debug, Clone)]
pub struct WeightRef {
    pub net: String,
    pub file: String,
    pub params: Vec<String>,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactEntry>,
    pub weights: Vec<WeightRef>,
}

fn tensor_specs(j: &Json) -> Result<Vec<TensorSpec>> {
    j.as_arr()
        .context("expected array of tensor specs")?
        .iter()
        .map(|t| {
            Ok(TensorSpec {
                shape: t
                    .get("shape")
                    .usize_vec()
                    .context("tensor spec missing shape")?,
            })
        })
        .collect()
}

fn strings(j: &Json) -> Result<Vec<String>> {
    j.as_arr()
        .context("expected array of strings")?
        .iter()
        .map(|v| Ok(v.as_str().context("expected string")?.to_string()))
        .collect()
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let j = Json::parse_file(&path)
            .map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))?;
        if j.get("format").as_str() != Some("descnet-artifacts-v1") {
            bail!("unexpected manifest format {:?}", j.get("format"));
        }
        if j.get("interchange").as_str() != Some("hlo-text") {
            bail!("manifest interchange must be hlo-text");
        }
        let artifacts = j
            .get("artifacts")
            .as_arr()
            .context("manifest missing artifacts")?
            .iter()
            .map(|e| {
                Ok(ArtifactEntry {
                    name: e.get("name").as_str().context("name")?.to_string(),
                    file: e.get("file").as_str().context("file")?.to_string(),
                    net: e.get("net").as_str().context("net")?.to_string(),
                    stage: e.get("stage").as_str().context("stage")?.to_string(),
                    batch: e.get("batch").as_usize().context("batch")?,
                    params: strings(e.get("params"))?,
                    inputs: tensor_specs(e.get("inputs"))?,
                    outputs: tensor_specs(e.get("outputs"))?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let weights = j
            .get("weights")
            .as_arr()
            .context("manifest missing weights")?
            .iter()
            .map(|w| {
                Ok(WeightRef {
                    net: w.get("net").as_str().context("net")?.to_string(),
                    file: w.get("file").as_str().context("file")?.to_string(),
                    params: strings(w.get("params"))?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Manifest {
            dir: dir.to_path_buf(),
            artifacts,
            weights,
        })
    }

    pub fn entry(&self, name: &str) -> Option<&ArtifactEntry> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// Stage artifact for a network at a batch size.
    pub fn stage(&self, net: &str, stage: &str, batch: usize) -> Option<&ArtifactEntry> {
        self.artifacts
            .iter()
            .find(|a| a.net == net && a.stage == stage && a.batch == batch)
    }

    /// Available batch sizes for a (net, stage), ascending.
    pub fn batches(&self, net: &str, stage: &str) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .artifacts
            .iter()
            .filter(|a| a.net == net && a.stage == stage)
            .map(|a| a.batch)
            .collect();
        v.sort_unstable();
        v
    }

    pub fn weights_for(&self, net: &str) -> Option<&WeightRef> {
        self.weights.iter().find(|w| w.net == net)
    }

    pub fn hlo_path(&self, entry: &ArtifactEntry) -> PathBuf {
        self.dir.join(&entry.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        artifacts_dir().join("manifest.json").exists()
    }

    #[test]
    fn loads_real_manifest() {
        if !have_artifacts() {
            return;
        }
        let m = Manifest::load(&artifacts_dir()).unwrap();
        assert!(!m.artifacts.is_empty());
        let full = m.stage("capsnet", "full", 1).expect("capsnet_full_b1");
        assert_eq!(full.inputs[0].shape, vec![1, 28, 28, 1]);
        assert_eq!(full.outputs[0].shape, vec![1, 10]);
        assert_eq!(full.params.len(), 5);
        assert!(m.hlo_path(full).exists());
        assert!(m.weights_for("capsnet").is_some());
    }

    #[test]
    fn stage_chain_shapes_are_consistent() {
        if !have_artifacts() {
            return;
        }
        let m = Manifest::load(&artifacts_dir()).unwrap();
        for &b in &m.batches("capsnet", "full") {
            let conv1 = m.stage("capsnet", "conv1", b).unwrap();
            let prim = m.stage("capsnet", "primarycaps", b).unwrap();
            let class = m.stage("capsnet", "classcaps", b).unwrap();
            assert_eq!(conv1.outputs[0].shape, prim.inputs[0].shape);
            assert_eq!(prim.outputs[0].shape, class.inputs[0].shape);
        }
    }

    #[test]
    fn rejects_wrong_format() {
        let dir = std::env::temp_dir().join("descnet_bad_manifest");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"format": "other", "interchange": "hlo-text", "artifacts": [], "weights": []}"#,
        )
        .unwrap();
        assert!(Manifest::load(&dir).is_err());
    }

    #[test]
    fn tensor_spec_elements() {
        let t = TensorSpec {
            shape: vec![4, 28, 28, 1],
        };
        assert_eq!(t.elements(), 4 * 28 * 28);
    }
}
