//! PJRT runtime: loads the HLO-text artifacts produced by
//! `python/compile/aot.py`, compiles them on the CPU PJRT client, and
//! executes them with weight literals fed in manifest order.
//!
//! Pattern follows /opt/xla-example/load_hlo: HLO *text* interchange
//! (`HloModuleProto::from_text_file` reassigns the 64-bit instruction ids
//! jax >= 0.5 emits, which xla_extension 0.5.1 would otherwise reject) and
//! `return_tuple=True` lowering (outputs unwrapped with `to_tuple`).
//!
//! Python never runs here: after `make artifacts`, the binary is
//! self-contained.
//!
//! The `xla` crate itself is only linked when the `pjrt` cargo feature is
//! enabled (it must be vendored by the build environment); the default
//! build substitutes `xla_stub`, which keeps this module compiling and
//! returns a clear "PJRT backend unavailable" error from `Runtime::new`.

pub mod artifacts;
pub mod weights;

#[cfg(not(feature = "pjrt"))]
#[path = "xla_stub.rs"]
mod xla;

// The feature is a reserved switch, not yet wired: flipping it must point
// at the vendoring instructions instead of failing with E0433 on every
// `xla::` path below.  To wire it, vendor the `xla` crate, add it as an
// optional path dependency (`pjrt = ["dep:xla"]`), and delete this guard.
#[cfg(feature = "pjrt")]
compile_error!(
    "the `pjrt` feature requires vendoring the real `xla` crate as a path \
     dependency (see rust/DESIGN.md section 3 and Cargo.toml); the default \
     build uses the compiled-in stub"
);

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use artifacts::{ArtifactEntry, Manifest};
use weights::WeightBundle;

/// A compiled stage: executable + pre-built weight literals.
pub struct CompiledStage {
    pub entry: ArtifactEntry,
    exe: xla::PjRtLoadedExecutable,
    weight_literals: Vec<xla::Literal>,
}

impl CompiledStage {
    /// Executes with a single f32 input tensor (shape per the manifest);
    /// returns the flattened f32 outputs in manifest order.
    pub fn execute(&self, input: &[f32]) -> Result<Vec<Vec<f32>>> {
        let spec = &self.entry.inputs[0];
        if input.len() != spec.elements() {
            bail!(
                "{}: input has {} elements, expected {:?} = {}",
                self.entry.name,
                input.len(),
                spec.shape,
                spec.elements()
            );
        }
        let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
        let lit = xla::Literal::vec1(input).reshape(&dims)?;
        let mut args: Vec<&xla::Literal> = self.weight_literals.iter().collect();
        args.push(&lit);
        let result = self.exe.execute::<&xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let outs = result.to_tuple()?;
        if outs.len() != self.entry.outputs.len() {
            bail!(
                "{}: got {} outputs, manifest says {}",
                self.entry.name,
                outs.len(),
                self.entry.outputs.len()
            );
        }
        outs.into_iter()
            .map(|o| o.to_vec::<f32>().map_err(Into::into))
            .collect()
    }
}

/// The runtime: one PJRT CPU client + compiled-stage cache + weight bundles.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    // BTreeMap, not HashMap: anything iterating the caches (diagnostics,
    // artifact listings) sees one stable order (lint rule hash_collect).
    bundles: BTreeMap<String, WeightBundle>,
    compiled: BTreeMap<String, CompiledStage>,
}

impl Runtime {
    pub fn new(artifacts_dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            client,
            manifest,
            bundles: BTreeMap::new(),
            compiled: BTreeMap::new(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn bundle(&mut self, net: &str) -> Result<&WeightBundle> {
        if !self.bundles.contains_key(net) {
            let wref = self
                .manifest
                .weights_for(net)
                .with_context(|| format!("no weight bundle for net {net}"))?;
            let bundle = weights::load(&self.manifest.dir.join(&wref.file))?;
            self.bundles.insert(net.to_string(), bundle);
        }
        Ok(&self.bundles[net])
    }

    fn weight_literals(&mut self, entry: &ArtifactEntry) -> Result<Vec<xla::Literal>> {
        let params = entry.params.clone();
        let net = entry.net.clone();
        let bundle = self.bundle(&net)?;
        params
            .iter()
            .map(|name| {
                let t = bundle
                    .get(name)
                    .with_context(|| format!("weight {name} missing from bundle {net}"))?;
                let values = t.as_f32()?;
                let dims: Vec<i64> = t.dims.iter().map(|&d| d as i64).collect();
                if dims.is_empty() {
                    return Ok(xla::Literal::vec1(&values));
                }
                xla::Literal::vec1(&values)
                    .reshape(&dims)
                    .map_err(Into::into)
            })
            .collect()
    }

    /// Loads + compiles a stage (cached by artifact name).
    pub fn load(&mut self, name: &str) -> Result<&CompiledStage> {
        if !self.compiled.contains_key(name) {
            let entry = self
                .manifest
                .entry(name)
                .with_context(|| format!("artifact {name} not in manifest"))?
                .clone();
            let path = self.manifest.hlo_path(&entry);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("artifact path not utf-8")?,
            )
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {name}"))?;
            let weight_literals = self.weight_literals(&entry)?;
            self.compiled.insert(
                name.to_string(),
                CompiledStage {
                    entry,
                    exe,
                    weight_literals,
                },
            );
        }
        Ok(&self.compiled[name])
    }

    /// Loads a (net, stage, batch) triple.
    pub fn load_stage(&mut self, net: &str, stage: &str, batch: usize) -> Result<&CompiledStage> {
        let name = self
            .manifest
            .stage(net, stage, batch)
            .with_context(|| format!("no artifact for {net}/{stage} batch {batch}"))?
            .name
            .clone();
        self.load(&name)
    }

    /// One-shot convenience: full-net inference, returns (lengths, poses).
    pub fn infer_full(
        &mut self,
        net: &str,
        batch: usize,
        input: &[f32],
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let stage = self.load_stage(net, "full", batch)?;
        let mut outs = stage.execute(input)?;
        if outs.len() < 2 {
            bail!("full-net artifact must emit (lengths, poses)");
        }
        let poses = outs.pop().unwrap();
        let lengths = outs.pop().unwrap();
        Ok((lengths, poses))
    }
}

/// argmax helper for classification outputs.
pub fn argmax_per_row(lengths: &[f32], classes: usize) -> Vec<usize> {
    lengths
        .chunks(classes)
        .map(|row| {
            row.iter()
                .enumerate()
                // A NaN length (a degenerate executable output) must
                // neither abort the serving loop mid-batch nor win the
                // argmax (total_cmp alone would rank +NaN above every
                // finite score); all-NaN rows fall back to class 0.
                .filter(|(_, v)| !v.is_nan())
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map(|(i, _)| i)
                .unwrap_or(0)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_rows() {
        let lengths = [0.1, 0.9, 0.2, 0.8, 0.05, 0.1];
        assert_eq!(argmax_per_row(&lengths, 3), vec![1, 0]);
    }
}
