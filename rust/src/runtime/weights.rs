//! DSCW v1 weight-bundle reader — mirror of `python/compile/aot.py`'s
//! `write_weights` (see the format comment there):
//!
//!   magic "DSCW" | u32 version | u32 count
//!   per tensor:  u16 name_len | name utf8 | u8 dtype | u8 ndim
//!                | u32 dims[ndim] | u64 byte_len | raw LE bytes

use anyhow::{bail, Context, Result};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn from_code(code: u8) -> Result<DType> {
        match code {
            0 => Ok(DType::F32),
            1 => Ok(DType::I32),
            other => bail!("unknown dtype code {other}"),
        }
    }

    pub fn bytes(&self) -> usize {
        4
    }
}

/// One tensor from a weight bundle (raw little-endian bytes).
#[derive(Debug, Clone)]
pub struct WeightTensor {
    pub name: String,
    pub dtype: DType,
    pub dims: Vec<usize>,
    pub data: Vec<u8>,
}

impl WeightTensor {
    pub fn element_count(&self) -> usize {
        self.dims.iter().product::<usize>().max(1)
    }

    pub fn as_f32(&self) -> Result<Vec<f32>> {
        if self.dtype != DType::F32 {
            bail!("{} is not f32", self.name);
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

/// An ordered weight bundle (order == PJRT argument order).
#[derive(Debug, Clone, Default)]
pub struct WeightBundle {
    pub tensors: Vec<WeightTensor>,
}

impl WeightBundle {
    pub fn get(&self, name: &str) -> Option<&WeightTensor> {
        self.tensors.iter().find(|t| t.name == name)
    }

    pub fn names(&self) -> Vec<&str> {
        self.tensors.iter().map(|t| t.name.as_str()).collect()
    }
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!("truncated DSCW file at offset {}", self.pos);
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    fn u16(&mut self) -> Result<u16> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes(b.try_into().unwrap()))
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
}

pub fn parse(bytes: &[u8]) -> Result<WeightBundle> {
    let mut r = Reader { buf: bytes, pos: 0 };
    if r.take(4)? != b"DSCW" {
        bail!("bad magic (not a DSCW weight bundle)");
    }
    let version = r.u32()?;
    if version != 1 {
        bail!("unsupported DSCW version {version}");
    }
    let count = r.u32()? as usize;
    let mut tensors = Vec::with_capacity(count);
    for _ in 0..count {
        let name_len = r.u16()? as usize;
        let name = String::from_utf8(r.take(name_len)?.to_vec()).context("tensor name utf8")?;
        let dtype = DType::from_code(r.u8()?)?;
        let ndim = r.u8()? as usize;
        let mut dims = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            dims.push(r.u32()? as usize);
        }
        let byte_len = r.u64()? as usize;
        let expected = dims.iter().product::<usize>().max(1) * dtype.bytes();
        if byte_len != expected {
            bail!("{name}: byte length {byte_len} != dims product {expected}");
        }
        let data = r.take(byte_len)?.to_vec();
        tensors.push(WeightTensor {
            name,
            dtype,
            dims,
            data,
        });
    }
    if r.pos != bytes.len() {
        bail!("{} trailing bytes in DSCW file", bytes.len() - r.pos);
    }
    Ok(WeightBundle { tensors })
}

pub fn load(path: &std::path::Path) -> Result<WeightBundle> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    parse(&bytes)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Builds a DSCW byte stream in-test (independent writer).
    fn encode(tensors: &[(&str, Vec<usize>, Vec<f32>)]) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(b"DSCW");
        out.extend_from_slice(&1u32.to_le_bytes());
        out.extend_from_slice(&(tensors.len() as u32).to_le_bytes());
        for (name, dims, data) in tensors {
            out.extend_from_slice(&(name.len() as u16).to_le_bytes());
            out.extend_from_slice(name.as_bytes());
            out.push(0); // f32
            out.push(dims.len() as u8);
            for &d in dims {
                out.extend_from_slice(&(d as u32).to_le_bytes());
            }
            out.extend_from_slice(&((data.len() * 4) as u64).to_le_bytes());
            for v in data {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        out
    }

    #[test]
    fn roundtrip() {
        let bytes = encode(&[
            ("conv1_w", vec![2, 2, 1, 3], (0..12).map(|i| i as f32).collect()),
            ("conv1_b", vec![3], vec![0.5, -1.0, 2.0]),
        ]);
        let bundle = parse(&bytes).unwrap();
        assert_eq!(bundle.names(), vec!["conv1_w", "conv1_b"]);
        let w = bundle.get("conv1_w").unwrap();
        assert_eq!(w.dims, vec![2, 2, 1, 3]);
        assert_eq!(w.element_count(), 12);
        assert_eq!(w.as_f32().unwrap()[3], 3.0);
    }

    #[test]
    fn rejects_bad_magic_and_truncation() {
        assert!(parse(b"NOPE").is_err());
        let bytes = encode(&[("x", vec![2], vec![1.0, 2.0])]);
        assert!(parse(&bytes[..bytes.len() - 1]).is_err());
        let mut extra = bytes.clone();
        extra.push(0);
        assert!(parse(&extra).is_err());
    }

    #[test]
    fn rejects_length_mismatch() {
        let mut bytes = encode(&[("x", vec![3], vec![1.0, 2.0, 3.0])]);
        // Corrupt the dims: claim 4 elements while 12 bytes follow.
        // dims u32 sits after magic(4)+ver(4)+count(4)+nlen(2)+name(1)+dtype(1)+ndim(1) = 17.
        bytes[17] = 4;
        assert!(parse(&bytes).is_err());
    }

    #[test]
    fn loads_real_artifact_bundle_if_present() {
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts/capsnet_weights.bin");
        if !path.exists() {
            return; // artifacts not built in this checkout
        }
        let bundle = load(&path).unwrap();
        assert_eq!(
            bundle.names(),
            vec!["conv1_w", "conv1_b", "primary_w", "primary_b", "class_w"]
        );
        let class_w = bundle.get("class_w").unwrap();
        assert_eq!(class_w.dims, vec![1152, 10, 8, 16]);
    }
}
