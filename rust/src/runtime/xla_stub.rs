//! Compile-time stub of the `xla` PJRT surface used by `runtime/mod.rs`.
//!
//! The default build has no vendored `xla` crate (see the `pjrt` cargo
//! feature in Cargo.toml), so this module mirrors exactly the types and
//! methods the runtime calls and returns a clear "PJRT backend unavailable"
//! error from every entry point that would touch a real device.  The
//! serving/runtime layers keep compiling, and their integration tests skip
//! (they already gate on `artifacts/manifest.json` existing).
//!
//! Keep the surface in lock-step with `runtime/mod.rs`: any new `xla::`
//! call site needs a stub twin here.

use std::fmt;

/// Stub error; converts into `anyhow::Error` at the call sites.
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable() -> Error {
    Error(
        "PJRT backend unavailable: built without the `pjrt` feature \
         (no vendored `xla` crate in this environment)"
            .to_string(),
    )
}

/// Stand-in for `xla::Literal`.
#[derive(Debug)]
pub struct Literal;

impl Literal {
    pub fn vec1(_values: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(unavailable())
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable())
    }
}

/// Stand-in for `xla::PjRtBuffer`.
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

/// Stand-in for `xla::PjRtLoadedExecutable`.
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

/// Stand-in for `xla::PjRtClient`.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable())
    }

    pub fn platform_name(&self) -> String {
        "pjrt-unavailable".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }
}

/// Stand-in for `xla::HloModuleProto`.
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable())
    }
}

/// Stand-in for `xla::XlaComputation`.
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_device_entry_point_reports_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
        let lit = Literal::vec1(&[1.0, 2.0]);
        assert!(lit.reshape(&[2]).is_err());
        assert!(lit.to_tuple().is_err());
        assert!(lit.to_vec::<f32>().is_err());
        let err = PjRtLoadedExecutable
            .execute::<&Literal>(&[])
            .err()
            .expect("stub must error");
        assert!(err.to_string().contains("PJRT backend unavailable"));
    }
}
