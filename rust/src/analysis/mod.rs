//! `descnet lint` — an in-repo static analyzer enforcing the determinism,
//! NaN-safety and panic-freedom invariants (DESIGN.md section 16, ISSUE 9).
//!
//! The headline numbers this repo reproduces (79% energy reduction, "no
//! performance loss" under power-gating) rest on bit-exact, thread-count-
//! independent evaluation.  The properties that guarantee it are global —
//! one NaN-unsafe sort, one release-vanishing fit guard, or one hash-order
//! iteration anywhere in `dse`/`energy`/`sim`/`fleet` silently corrupts
//! frontiers, fingerprints and property suites.  This module turns that
//! recurring manual audit (PRs 4–7 each hand-fixed instances) into a
//! machine-checked gate:
//!
//! * [`lexer`] strips comments and string/char literals and marks
//!   `#[cfg(test)]` items, so rules match real code only;
//! * [`rules`] holds the catalogue (R1–R5) with module-path scoping and the
//!   inline `lint: allow(rule, reason)` suppression mechanism — the *only*
//!   suppression mechanism: there is no baseline file, the tree is clean by
//!   construction;
//! * this module walks the repo's own sources (`rust/src`, `rust/tests`,
//!   `benches`, `examples`), maps file paths to module paths, and renders
//!   the findings as a human table or `--format json`.
//!
//! Surfaced three ways: the `descnet lint` CLI subcommand, the tier-1
//! zero-findings test (`rust/tests/lint.rs`), and a CI step.  Zero new
//! dependencies — the lexer is ~200 lines of state machine, the rules are
//! token/statement matchers.

pub mod lexer;
pub mod rules;

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{ensure, Context, Result};

pub use rules::{Finding, RuleInfo, RULES};

use crate::util::json::Json;
use crate::util::table::Table;

/// The source roots scanned, relative to the repo root.
const SCAN_ROOTS: &[&str] = &["rust/src", "rust/tests", "benches", "examples"];

/// Aggregate result of a tree lint.
#[derive(Debug)]
pub struct LintReport {
    /// All findings, in (file, line) order.
    pub findings: Vec<Finding>,
    /// Files scanned.
    pub files: usize,
    /// Source lines lexed.
    pub lines: usize,
    /// Findings suppressed by honored `lint: allow` annotations.
    pub suppressed: usize,
}

impl LintReport {
    pub fn is_clean(&self) -> bool {
        self.findings.is_empty()
    }

    /// Per-rule finding counts over the full catalogue (zeros included).
    pub fn per_rule(&self) -> BTreeMap<&'static str, usize> {
        let mut out: BTreeMap<&'static str, usize> =
            RULES.iter().map(|r| (r.id, 0usize)).collect();
        for f in &self.findings {
            *out.entry(f.rule.id).or_default() += 1;
        }
        out
    }

    /// The one-line summary CI greps for.
    pub fn summary(&self) -> String {
        format!(
            "lint: {} findings across {} files, {} lines ({} suppressions honored)",
            self.findings.len(),
            self.files,
            self.lines,
            self.suppressed,
        )
    }

    /// Human-readable report: findings table (when any), rule hints, summary.
    pub fn to_text(&self) -> String {
        let mut out = String::new();
        if !self.findings.is_empty() {
            let mut t = Table::new(&["rule", "location", "detail"]);
            for f in &self.findings {
                t.row(vec![
                    format!("{} [{}]", f.rule.id, f.rule.group),
                    format!("{}:{}", f.file, f.line),
                    f.detail.clone(),
                ]);
            }
            out.push_str(&t.to_ascii());
            out.push('\n');
            let mut seen: Vec<&'static str> = Vec::new();
            for f in &self.findings {
                if !seen.contains(&f.rule.id) {
                    seen.push(f.rule.id);
                    out.push_str(&format!("{}: {} — {}\n", f.rule.id, f.rule.what, f.rule.hint));
                }
            }
        }
        out.push_str(&self.summary());
        out.push('\n');
        out
    }

    /// Machine-readable report (`--format json`).
    pub fn to_json(&self) -> Json {
        let findings: Vec<Json> = self
            .findings
            .iter()
            .map(|f| {
                Json::from_pairs(vec![
                    ("file", Json::Str(f.file.clone())),
                    ("line", Json::Num(f.line as f64)),
                    ("rule", Json::Str(f.rule.id.to_string())),
                    ("group", Json::Str(f.rule.group.to_string())),
                    ("detail", Json::Str(f.detail.clone())),
                    ("hint", Json::Str(f.rule.hint.to_string())),
                ])
            })
            .collect();
        let per_rule = Json::Obj(
            self.per_rule()
                .into_iter()
                .map(|(k, v)| (k.to_string(), Json::Num(v as f64)))
                .collect(),
        );
        Json::from_pairs(vec![
            ("summary", Json::Str(self.summary())),
            ("total", Json::Num(self.findings.len() as f64)),
            ("files", Json::Num(self.files as f64)),
            ("lines", Json::Num(self.lines as f64)),
            ("suppressed", Json::Num(self.suppressed as f64)),
            ("per_rule", per_rule),
            ("findings", Json::Arr(findings)),
        ])
    }
}

/// Maps a repo-relative source path to its module path for rule scoping:
/// `rust/src/dse/evaluate.rs` -> `dse::evaluate`, `rust/src/dse/mod.rs` ->
/// `dse`, `rust/tests/fleet.rs` -> `tests::fleet`, `benches/bench_dse.rs`
/// -> `benches::bench_dse`.  Returns `None` for non-Rust files.
pub fn module_path_of(rel: &str) -> Option<String> {
    let rel = rel.strip_suffix(".rs")?;
    if let Some(inner) = rel.strip_prefix("rust/src/") {
        let inner = inner.strip_suffix("/mod").unwrap_or(inner);
        if inner == "lib" {
            return Some(String::new());
        }
        return Some(inner.replace('/', "::"));
    }
    if let Some(inner) = rel.strip_prefix("rust/tests/") {
        return Some(format!("tests::{}", inner.replace('/', "::")));
    }
    if let Some(inner) = rel.strip_prefix("benches/") {
        return Some(format!("benches::{}", inner.replace('/', "::")));
    }
    if let Some(inner) = rel.strip_prefix("examples/") {
        return Some(format!("examples::{}", inner.replace('/', "::")));
    }
    None
}

/// Lints one source text under an explicit module path.  The fixture entry
/// point for the rule self-tests; [`lint_tree`] goes through it too.
/// Returns (findings, lines lexed, suppressions honored).
pub fn lint_source(module: &str, file: &str, text: &str) -> (Vec<Finding>, usize, usize) {
    let lines = lexer::strip(text);
    let n = lines.len();
    let (findings, suppressed) = rules::check(module, file, &lines);
    (findings, n, suppressed)
}

/// Walks `root` (the repo root) and lints every Rust source under the scan
/// roots.  Deterministic: files are visited in sorted path order.
pub fn lint_tree(root: &Path) -> Result<LintReport> {
    ensure!(
        root.join("rust/src").is_dir(),
        "{} does not look like the repo root (no rust/src); run from the \
         checkout or pass --root",
        root.display()
    );
    let mut files: Vec<(String, PathBuf)> = Vec::new();
    for scan in SCAN_ROOTS {
        let dir = root.join(scan);
        if dir.is_dir() {
            collect_rs(&dir, &mut |p| {
                if let Ok(rel) = p.strip_prefix(root) {
                    let rel = rel.to_string_lossy().replace('\\', "/");
                    files.push((rel, p.to_path_buf()));
                }
            })?;
        }
    }
    files.sort();

    let mut report = LintReport {
        findings: Vec::new(),
        files: 0,
        lines: 0,
        suppressed: 0,
    };
    for (rel, path) in &files {
        let Some(module) = module_path_of(rel) else {
            continue;
        };
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let (mut findings, lines, suppressed) = lint_source(&module, rel, &text);
        report.files += 1;
        report.lines += lines;
        report.suppressed += suppressed;
        report.findings.append(&mut findings);
    }
    report
        .findings
        .sort_by(|a, b| a.file.cmp(&b.file).then(a.line.cmp(&b.line)));
    Ok(report)
}

/// Recursive `.rs` collector (no walkdir dependency); directory entries are
/// visited in sorted order for determinism.
fn collect_rs(dir: &Path, visit: &mut dyn FnMut(&Path)) -> Result<()> {
    let mut entries: Vec<PathBuf> = std::fs::read_dir(dir)
        .with_context(|| format!("listing {}", dir.display()))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .collect();
    entries.sort();
    for path in entries {
        if path.is_dir() {
            collect_rs(&path, visit)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            visit(&path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn module_mapping() {
        assert_eq!(module_path_of("rust/src/dse/evaluate.rs").as_deref(), Some("dse::evaluate"));
        assert_eq!(module_path_of("rust/src/dse/mod.rs").as_deref(), Some("dse"));
        assert_eq!(module_path_of("rust/src/lib.rs").as_deref(), Some(""));
        assert_eq!(module_path_of("rust/src/main.rs").as_deref(), Some("main"));
        assert_eq!(module_path_of("rust/tests/fleet.rs").as_deref(), Some("tests::fleet"));
        assert_eq!(
            module_path_of("benches/bench_dse.rs").as_deref(),
            Some("benches::bench_dse")
        );
        assert_eq!(module_path_of("rust/tests/goldens/fleet_seed7.txt"), None);
    }

    #[test]
    fn json_report_carries_summary_and_counts() {
        let report = LintReport {
            findings: Vec::new(),
            files: 3,
            lines: 120,
            suppressed: 2,
        };
        let js = report.to_json().to_string_pretty();
        assert!(js.contains("lint: 0 findings across 3 files"));
        assert!(js.contains("\"suppressed\": 2"));
        // The full catalogue appears in per_rule, zeros included.
        for r in RULES {
            assert!(js.contains(r.id), "{} missing from per_rule", r.id);
        }
    }
}
