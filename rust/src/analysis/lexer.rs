//! Line lexer for `descnet lint` (DESIGN.md section 16).
//!
//! Reduces a Rust source file to per-line records the rule pass can match
//! against without tripping over comments and literals:
//!
//! * `code` — the line with comments, string/char literals (including raw
//!   and byte strings) removed, so a rule token inside a doc comment or an
//!   error message never fires;
//! * `comment` — the concatenated comment text of the line, kept verbatim
//!   so the suppression pass can parse `lint: allow(rule, reason)`
//!   annotations;
//! * `in_test` — whether the line belongs to a `#[cfg(test)]` item
//!   (typically `mod tests { ... }`): test code is exempt from every rule,
//!   since panicking and wall-clock reads are fine in tests.
//!
//! The lexer is a character state machine over the whole file, so multi-line
//! block comments (nested, as Rust allows), multi-line strings, and `{`/`}`
//! inside literals are all handled; brace depth is then computed over the
//! stripped code, which is what makes the `#[cfg(test)]` item-skipping
//! sound at line granularity.

/// One lexed source line.
#[derive(Debug, Clone)]
pub struct Line {
    /// 1-based line number.
    pub n: usize,
    /// Comment- and literal-stripped code.
    pub code: String,
    /// Comment text (both `//` and `/* */` parts) on this line.
    pub comment: String,
    /// Inside a `#[cfg(test)]` item.
    pub in_test: bool,
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum State {
    Code,
    LineComment,
    /// Nesting depth of `/* */` (Rust block comments nest).
    BlockComment(u32),
    Str,
    /// Raw string, with the number of `#` marks in its delimiter.
    RawStr(u32),
    Char,
}

/// Splits `text` into lexed lines: literals stripped from `code`, comments
/// collected into `comment`, `in_test` marked for `#[cfg(test)]` items.
pub fn strip(text: &str) -> Vec<Line> {
    let mut lines = raw_strip(text);
    mark_tests(&mut lines);
    lines
}

fn raw_strip(text: &str) -> Vec<Line> {
    let mut out = Vec::new();
    let mut code = String::new();
    let mut comment = String::new();
    let mut n = 1usize;
    let mut state = State::Code;

    let chars: Vec<char> = text.chars().collect();
    let mut i = 0usize;
    while i < chars.len() {
        let c = chars[i];
        if c == '\n' {
            out.push(Line {
                n,
                code: std::mem::take(&mut code),
                comment: std::mem::take(&mut comment),
                in_test: false,
            });
            n += 1;
            // A line comment ends at the newline; everything else persists.
            if state == State::LineComment {
                state = State::Code;
            }
            i += 1;
            continue;
        }
        match state {
            State::Code => {
                let next = chars.get(i + 1).copied();
                if c == '/' && next == Some('/') {
                    state = State::LineComment;
                    i += 2;
                } else if c == '/' && next == Some('*') {
                    state = State::BlockComment(1);
                    i += 2;
                } else if c == '"' {
                    code.push('"');
                    state = State::Str;
                    i += 1;
                } else if (c == 'r' || c == 'b') && !(i > 0 && is_ident(chars[i - 1])) {
                    // Raw / byte strings: r"..", r#"..."#, br"..", b"..".
                    // `r#ident` (raw identifiers) must fall through to code.
                    if let Some((skip, hashes)) = raw_str_open(&chars, i) {
                        code.push('"');
                        state = State::RawStr(hashes);
                        i += skip;
                    } else if c == 'b' && next == Some('\'') {
                        code.push('b');
                        state = State::Char;
                        i += 2;
                    } else if c == 'b' && next == Some('"') {
                        code.push('"');
                        state = State::Str;
                        i += 2;
                    } else {
                        code.push(c);
                        i += 1;
                    }
                } else if c == '\'' {
                    // Char literal vs lifetime: 'x' / '\n' are literals,
                    // 'a in `&'a T` (no closing quote nearby) is a lifetime.
                    if next == Some('\\')
                        || (chars.get(i + 2) == Some(&'\'') && next != Some('\''))
                    {
                        state = State::Char;
                        i += 1;
                    } else {
                        code.push('\'');
                        i += 1;
                    }
                } else {
                    code.push(c);
                    i += 1;
                }
            }
            State::LineComment => {
                comment.push(c);
                i += 1;
            }
            State::BlockComment(d) => {
                let next = chars.get(i + 1).copied();
                if c == '/' && next == Some('*') {
                    state = State::BlockComment(d + 1);
                    i += 2;
                } else if c == '*' && next == Some('/') {
                    state = if d == 1 {
                        State::Code
                    } else {
                        State::BlockComment(d - 1)
                    };
                    i += 2;
                } else {
                    comment.push(c);
                    i += 1;
                }
            }
            State::Str => {
                if c == '\\' {
                    // Skip the escaped char (handles \" and \\) — but never
                    // swallow a newline: a line-continuation escape must
                    // still produce its Line record.
                    i += if chars.get(i + 1) == Some(&'\n') { 1 } else { 2 };
                } else if c == '"' {
                    code.push('"');
                    state = State::Code;
                    i += 1;
                } else {
                    i += 1;
                }
            }
            State::RawStr(hashes) => {
                if c == '"' && closes_raw(&chars, i, hashes) {
                    code.push('"');
                    state = State::Code;
                    i += 1 + hashes as usize;
                } else {
                    i += 1;
                }
            }
            State::Char => {
                if c == '\\' {
                    i += 2;
                } else if c == '\'' {
                    state = State::Code;
                    i += 1;
                } else {
                    i += 1;
                }
            }
        }
    }
    if !code.is_empty() || !comment.is_empty() {
        out.push(Line {
            n,
            code,
            comment,
            in_test: false,
        });
    }
    out
}

/// At `chars[i] == 'r'` or `'b'`: does a raw-string delimiter start here?
/// Returns (chars to skip past the opening quote, hash count).
fn raw_str_open(chars: &[char], i: usize) -> Option<(usize, u32)> {
    let mut j = i + 1;
    if chars.get(i) == Some(&'b') {
        if chars.get(j) != Some(&'r') {
            return None;
        }
        j += 1;
    }
    let mut hashes = 0u32;
    while chars.get(j) == Some(&'#') {
        hashes += 1;
        j += 1;
    }
    if chars.get(j) == Some(&'"') {
        Some((j + 1 - i, hashes))
    } else {
        None
    }
}

/// At `chars[i] == '"'` inside a raw string: is it followed by `hashes` `#`s?
fn closes_raw(chars: &[char], i: usize, hashes: u32) -> bool {
    (1..=hashes as usize).all(|k| chars.get(i + k) == Some(&'#'))
}

fn is_ident(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Marks lines belonging to `#[cfg(test)]` items.  Brace depth is tracked
/// over the stripped code; the item following the attribute (plus any
/// intervening attributes) is skipped until depth returns to the entry
/// level on a line that closes a block or ends a declaration.
fn mark_tests(lines: &mut [Line]) {
    let mut depth: i64 = 0;
    let mut pending = false;
    let mut skip_entry: Option<i64> = None;

    for line in lines.iter_mut() {
        let trimmed = line.code.trim().to_string();
        if skip_entry.is_none() {
            if trimmed.contains("cfg(test)") || trimmed.contains("cfg(all(test") {
                pending = true;
                line.in_test = true;
            } else if pending {
                line.in_test = true;
                if !trimmed.is_empty() && !trimmed.starts_with("#[") {
                    // First line of the gated item.
                    skip_entry = Some(depth);
                    pending = false;
                }
            }
        } else {
            line.in_test = true;
        }

        let opens = trimmed.matches('{').count() as i64;
        let closes = trimmed.matches('}').count() as i64;
        depth += opens - closes;

        if let Some(entry) = skip_entry {
            let terminated = trimmed.contains(';') || trimmed.contains('}');
            if depth <= entry && terminated && !trimmed.is_empty() {
                skip_entry = None;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn codes(text: &str) -> Vec<String> {
        strip(text).into_iter().map(|l| l.code).collect()
    }

    #[test]
    fn strips_line_comments_and_keeps_text() {
        let lines = strip("let x = 1; // trailing words\n");
        assert_eq!(lines[0].code.trim_end(), "let x = 1;");
        assert_eq!(lines[0].comment, " trailing words");
    }

    #[test]
    fn strips_string_contents() {
        let c = codes("let s = \"tok_inside_string()\";\n");
        assert!(!c[0].contains("tok_inside_string"));
        assert!(c[0].contains("let s = \"\";"));
    }

    #[test]
    fn strips_raw_and_byte_strings() {
        let c = codes("let s = r#\"raw \"quoted\" body\"#; let b = b\"bytes\";\n");
        assert!(!c[0].contains("raw"));
        assert!(!c[0].contains("bytes"));
        // Raw identifiers are NOT raw strings.
        let c = codes("let r#fn = 1;\n");
        assert!(c[0].contains("r#fn"));
    }

    #[test]
    fn nested_block_comments() {
        let c = codes("a /* outer /* inner */ still comment */ b\n");
        assert_eq!(c[0].split_whitespace().collect::<Vec<_>>(), ["a", "b"]);
    }

    #[test]
    fn multiline_block_comment_collects_text() {
        let lines = strip("x /* one\ntwo */ y\n");
        assert_eq!(lines[0].comment, " one");
        assert!(lines[1].comment.contains("two"));
        assert!(lines[1].code.contains('y'));
    }

    #[test]
    fn char_literal_vs_lifetime() {
        let c = codes("let c = '{'; fn f<'a>(x: &'a str) {}\n");
        // The brace inside the char literal is stripped...
        assert_eq!(c[0].matches('{').count(), 1);
        // ...while the lifetime survives as code.
        assert!(c[0].contains("'a"));
    }

    #[test]
    fn escaped_quote_in_string() {
        let c = codes("let s = \"a\\\"b{\"; let t = 1;\n");
        assert_eq!(c[0].matches('{').count(), 0);
        assert!(c[0].contains("let t = 1;"));
    }

    #[test]
    fn cfg_test_mod_is_marked() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n    fn helper() {}\n}\nfn after() {}\n";
        let lines = strip(src);
        let flags: Vec<bool> = lines.iter().map(|l| l.in_test).collect();
        assert_eq!(flags, vec![false, true, true, true, true, false]);
    }

    #[test]
    fn cfg_test_single_item_is_marked() {
        let src = "#[cfg(test)]\nuse foo::bar;\nfn live() {}\n";
        let lines = strip(src);
        assert!(lines[1].in_test);
        assert!(!lines[2].in_test);
    }

    #[test]
    fn cfg_not_test_is_not_marked() {
        let src = "#[cfg(not(test))]\nfn live() {}\n";
        let lines = strip(src);
        assert!(!lines[1].in_test);
    }

    #[test]
    fn brace_in_format_string_does_not_break_depth() {
        let src = "#[cfg(test)]\nmod tests {\n    fn f() { let s = \"}\"; }\n}\nfn live() {}\n";
        let lines = strip(src);
        assert!(lines[2].in_test);
        assert!(!lines[4].in_test, "stray literal brace must not end the test mod early");
    }
}
