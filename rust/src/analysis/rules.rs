//! The invariant catalogue of `descnet lint` (DESIGN.md section 16).
//!
//! Every rule guards one of the properties the repo's headline numbers rest
//! on — bit-exact, thread-count-independent, panic-free evaluation:
//!
//! * `nan_cmp` (R1): NaN-unsafe float comparison; `total_cmp` is required.
//! * `debug_guard` (R2): `debug_assert!` guarding fit/conservation
//!   conditions in evaluation modules vanishes in release builds.
//! * `hash_collect` / `wall_clock` / `ambient_rand` (R3): determinism —
//!   no hash-order iteration, no wall clock, no ambient RNG outside the
//!   allowlisted sites.
//! * `hot_unwrap` (R4): no `.unwrap()` / `.expect()` panics in library
//!   hot paths; `anyhow::Result` instead.
//! * `unordered_fold` (R5): float accumulation over unordered iterators in
//!   the accumulation-order-contracted modules.
//! * `ctx_bypass` (R6): raw `Engine::new(` in the evaluation stack — every
//!   entry point takes an `EvalCtx` (DESIGN.md section 17), so a privately
//!   constructed engine bypasses the context's thread-count contract.
//!
//! Scoping is by module path (derived from the file path); the only
//! suppression mechanism is an inline annotation on the finding line or the
//! comment-only line directly above it, with a mandatory reason:
//!
//! ```text
//! // lint: allow(hot_unwrap, "non-empty by construction: N >= 1 checked above")
//! ```
//!
//! There is deliberately no baseline file — the tree must be clean.

use std::collections::BTreeMap;

use super::lexer::Line;

/// One rule of the catalogue.
#[derive(Debug)]
pub struct RuleInfo {
    /// Stable id, the name suppression annotations reference.
    pub id: &'static str,
    /// Paper-facing group (R1..R5; R0 is the lint's own hygiene).
    pub group: &'static str,
    /// What the rule guards.
    pub what: &'static str,
    /// Fix hint attached to every finding.
    pub hint: &'static str,
}

/// The catalogue, in reporting order.
pub static RULES: &[RuleInfo] = &[
    RuleInfo {
        id: "nan_cmp",
        group: "R1",
        what: "NaN-unsafe float comparison",
        hint: "use f64::total_cmp (total order; NaN sorts last instead of panicking or tying)",
    },
    RuleInfo {
        id: "debug_guard",
        group: "R2",
        what: "release-vanishing guard in an evaluation module",
        hint: "promote to assert!/ensure! (always-on) or annotate why debug-only is sound",
    },
    RuleInfo {
        id: "hash_collect",
        group: "R3",
        what: "hash-ordered collection (iteration order is nondeterministic)",
        hint: "use BTreeMap/BTreeSet, or sort at every output edge and annotate",
    },
    RuleInfo {
        id: "wall_clock",
        group: "R3",
        what: "wall-clock read outside the allowlisted timing sites",
        hint: "thread simulated time through instead; wall time may only feed \
               diagnostics excluded from fingerprints",
    },
    RuleInfo {
        id: "ambient_rand",
        group: "R3",
        what: "ambient RNG (unseeded, irreproducible)",
        hint: "use util::prng::Prng with an explicit seed",
    },
    RuleInfo {
        id: "hot_unwrap",
        group: "R4",
        what: "panic path in a library hot-path module",
        hint: "return anyhow::Result, or annotate with the structural invariant that \
               makes the panic unreachable",
    },
    RuleInfo {
        id: "unordered_fold",
        group: "R5",
        what: "float accumulation over an unordered iterator",
        hint: "collect and sort keys first — f64 addition is order-dependent and these \
               modules declare an accumulation-order contract",
    },
    RuleInfo {
        id: "ctx_bypass",
        group: "R6",
        what: "raw Engine construction in a context-threaded evaluation module",
        hint: "take &EvalCtx and use ctx.engine() — private engines bypass the unified \
               evaluation context (DESIGN.md section 17)",
    },
    RuleInfo {
        id: "allow_syntax",
        group: "R0",
        what: "malformed suppression annotation",
        hint: "the form is: allow(<rule>, <non-empty reason>) — a reason is mandatory",
    },
];

/// Looks a rule up by id.
pub fn rule(id: &str) -> Option<&'static RuleInfo> {
    RULES.iter().find(|r| r.id == id)
}

/// One finding: file:line, the violated rule, and what matched.
#[derive(Debug, Clone)]
pub struct Finding {
    /// Repo-relative path.
    pub file: String,
    /// 1-based line.
    pub line: usize,
    pub rule: &'static RuleInfo,
    /// The matched token or pattern, for the report.
    pub detail: String,
}

/// Token-set rule: fires when any token occurs in stripped code, subject to
/// module scoping.  `include: None` means every module; `exclude` wins.
struct TokenRule {
    id: &'static str,
    tokens: &'static [&'static str],
    include: Option<&'static [&'static str]>,
    exclude: &'static [&'static str],
}

/// R2/R4 module scopes: the evaluation/serving stack whose invariants the
/// headline claims rest on (ISSUE 9).
const GUARDED_DEBUG: &[&str] = &["dse", "sim", "fleet", "energy"];
const GUARDED_PANIC: &[&str] = &["dse", "energy", "sim", "fleet", "pmu"];
/// R3 built-in allowlists (the only module-level exemptions; everything
/// else needs an inline annotation).
const WALL_CLOCK_OK: &[&str] = &["util::bench", "coordinator::server"];
const RAND_OK: &[&str] = &["util::prng"];
/// R5 scope: the modules with a declared accumulation-order contract
/// (DESIGN.md section 14).
const ORDER_CONTRACT: &[&str] = &["energy", "dse::evaluate"];
/// R6 scope: the evaluation stack whose entry points take `&EvalCtx`
/// (DESIGN.md section 17).  `ctx` itself and `util::exec` construct engines
/// by design and are simply out of scope.
const CTX_THREADED: &[&str] = &["dse", "sim", "fleet", "report"];

const TOKEN_RULES: &[TokenRule] = &[
    TokenRule {
        id: "nan_cmp",
        tokens: &["partial_cmp"],
        include: None,
        exclude: &[],
    },
    TokenRule {
        id: "debug_guard",
        tokens: &["debug_assert!", "debug_assert_eq!", "debug_assert_ne!"],
        include: Some(GUARDED_DEBUG),
        exclude: &[],
    },
    TokenRule {
        id: "hash_collect",
        tokens: &["HashMap", "HashSet"],
        include: None,
        exclude: &[],
    },
    TokenRule {
        id: "wall_clock",
        tokens: &["Instant::now", "SystemTime"],
        include: None,
        exclude: WALL_CLOCK_OK,
    },
    TokenRule {
        id: "ambient_rand",
        tokens: &["thread_rng", "rand::", "StdRng", "SmallRng", "getrandom"],
        include: None,
        exclude: RAND_OK,
    },
    TokenRule {
        id: "hot_unwrap",
        tokens: &[".unwrap()", ".expect(", ".unwrap_unchecked()"],
        include: Some(GUARDED_PANIC),
        exclude: &[],
    },
    TokenRule {
        id: "ctx_bypass",
        tokens: &["Engine::new(", "Engine::auto("],
        include: Some(CTX_THREADED),
        exclude: &[],
    },
];

/// `module` is `prefix` itself or a submodule of it.
fn in_scope(module: &str, prefix: &str) -> bool {
    match module.strip_prefix(prefix) {
        Some(rest) => rest.is_empty() || rest.starts_with("::"),
        None => false,
    }
}

fn applies(module: &str, r: &TokenRule) -> bool {
    if r.exclude.iter().any(|p| in_scope(module, p)) {
        return false;
    }
    match r.include {
        None => true,
        Some(list) => list.iter().any(|p| in_scope(module, p)),
    }
}

fn ident_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Finds `tok` in `code` respecting identifier boundaries: a token starting
/// (resp. ending) with an identifier char must not be preceded (resp.
/// followed) by one — `operand::` never matches `rand::`.
fn has_token(code: &str, tok: &str) -> bool {
    let mut from = 0;
    while let Some(pos) = code[from..].find(tok) {
        let at = from + pos;
        let left_ok = !tok.starts_with(ident_char)
            || !code[..at].chars().next_back().is_some_and(ident_char);
        let end = at + tok.len();
        let right_ok =
            !tok.ends_with(ident_char) || !code[end..].chars().next().is_some_and(ident_char);
        if left_ok && right_ok {
            return true;
        }
        from = at + tok.len().max(1);
    }
    false
}

/// A parsed `lint: allow(rule, reason)` annotation.  `reason: None` marks a
/// malformed annotation (the reason is mandatory).
#[derive(Debug, Clone)]
struct ParsedAllow {
    rule_id: String,
    reason: Option<String>,
}

/// Parses every suppression annotation in one comment.
fn parse_allows(comment: &str) -> Vec<ParsedAllow> {
    const NEEDLE: &str = "lint: allow(";
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(pos) = comment[from..].find(NEEDLE) {
        let body = &comment[from + pos + NEEDLE.len()..];
        let rule_end = body.find([',', ')']).unwrap_or(body.len());
        let rule_id = body[..rule_end].trim().to_string();
        let rest = &body[rule_end..];
        let reason = rest.strip_prefix(',').and_then(|tail| {
            // Reason runs to the last ')' of the annotation tail, so
            // reasons may themselves contain parentheses.
            let reason_end = tail.rfind(')').unwrap_or(tail.len());
            let r = tail[..reason_end].trim().trim_matches('"').trim();
            (!r.is_empty()).then(|| r.to_string())
        });
        out.push(ParsedAllow { rule_id, reason });
        from += pos + NEEDLE.len();
    }
    out
}

/// Runs the catalogue over one lexed file.  Returns the findings plus the
/// number of findings suppressed by honored annotations.
pub fn check(module: &str, file: &str, lines: &[Line]) -> (Vec<Finding>, usize) {
    let mut findings = Vec::new();
    let mut suppressed = 0usize;

    // Per-line allow sets.  A well-formed allow on line N applies to line N
    // and — when line N has no code of its own — to line N+1.
    let mut allowed: BTreeMap<usize, Vec<String>> = BTreeMap::new();
    for (idx, line) in lines.iter().enumerate() {
        for allow in parse_allows(&line.comment) {
            if allow.reason.is_some() {
                allowed.entry(line.n).or_default().push(allow.rule_id.clone());
                if line.code.trim().is_empty() {
                    if let Some(next) = lines.get(idx + 1) {
                        allowed.entry(next.n).or_default().push(allow.rule_id);
                    }
                }
            } else if let Some(r) = rule("allow_syntax") {
                findings.push(Finding {
                    file: file.to_string(),
                    line: line.n,
                    rule: r,
                    detail: format!("allow({}, ...) without a reason", allow.rule_id),
                });
            }
        }
    }
    let is_allowed = |n: usize, id: &str| {
        allowed
            .get(&n)
            .is_some_and(|ids| ids.iter().any(|a| a == id))
    };

    // Statement buffer for the multi-line R5 pattern: cleared at statement
    // or block boundaries, so a chain split across lines still matches.
    let mut stmt = String::new();

    for line in lines {
        if line.in_test {
            stmt.clear();
            continue;
        }
        let code = line.code.as_str();

        for tr in TOKEN_RULES {
            if !applies(module, tr) {
                continue;
            }
            for tok in tr.tokens {
                if has_token(code, tok) {
                    if is_allowed(line.n, tr.id) {
                        suppressed += 1;
                    } else if let Some(r) = rule(tr.id) {
                        findings.push(Finding {
                            file: file.to_string(),
                            line: line.n,
                            rule: r,
                            detail: format!("`{tok}`"),
                        });
                    }
                    break; // one finding per (rule, line)
                }
            }
        }

        // R5: unordered float reduction, matched at statement granularity.
        if ORDER_CONTRACT.iter().any(|p| in_scope(module, p)) {
            stmt.push_str(code);
            stmt.push(' ');
            let unordered = stmt.contains(".values()") || stmt.contains(".keys()");
            let reduces =
                stmt.contains(".sum()") || stmt.contains(".sum::<") || stmt.contains(".fold(");
            if unordered && reduces {
                if is_allowed(line.n, "unordered_fold") {
                    suppressed += 1;
                } else if let Some(r) = rule("unordered_fold") {
                    findings.push(Finding {
                        file: file.to_string(),
                        line: line.n,
                        rule: r,
                        detail: "float reduction over .values()/.keys()".to_string(),
                    });
                }
                stmt.clear();
            } else if code.contains(';') || code.contains('}') {
                stmt.clear();
            }
        }
    }
    (findings, suppressed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::lexer;

    fn run(module: &str, src: &str) -> (Vec<Finding>, usize) {
        check(module, "fixture.rs", &lexer::strip(src))
    }

    fn ids(f: &[Finding]) -> Vec<&'static str> {
        f.iter().map(|x| x.rule.id).collect()
    }

    #[test]
    fn token_boundaries() {
        assert!(has_token("a.partial_cmp(b)", "partial_cmp"));
        assert!(!has_token("my_partial_cmp_helper(b)", "partial_cmp"));
        assert!(!has_token("operand::width()", "rand::"));
        assert!(has_token("use rand::Rng;", "rand::"));
        assert!(!has_token("MyHashMapLike::new()", "HashMap"));
        assert!(has_token("HashMap::new()", "HashMap"));
    }

    #[test]
    fn scoping_prefix_is_module_aware() {
        assert!(in_scope("dse", "dse"));
        assert!(in_scope("dse::evaluate", "dse"));
        assert!(!in_scope("dsel::evaluate", "dse"));
        assert!(!in_scope("report", "dse"));
    }

    #[test]
    fn allow_reason_parses_with_parens_and_quotes() {
        let allows = parse_allows(" lint: allow(nan_cmp, \"total Ord (see below)\")");
        assert_eq!(allows.len(), 1);
        assert_eq!(allows[0].rule_id, "nan_cmp");
        let reason = allows[0].reason.as_deref().unwrap_or_default();
        assert!(reason.contains("(see below)"));
    }

    #[test]
    fn allow_without_reason_is_malformed() {
        let (f, s) = run("report", "let x = 1; // lint: allow(nan_cmp)\n");
        assert_eq!(ids(&f), vec!["allow_syntax"]);
        assert_eq!(s, 0);
    }

    #[test]
    fn ctx_bypass_scoped_to_evaluation_stack() {
        let (f, _) = run("dse::stream", "let e = Engine::new(4);\n");
        assert_eq!(ids(&f), vec!["ctx_bypass"]);
        let (f, _) = run("fleet", "let e = Engine::auto();\n");
        assert_eq!(ids(&f), vec!["ctx_bypass"]);
        // `ctx` and `util::exec` construct engines by design: out of scope.
        let (f, _) = run("ctx", "let e = Engine::new(4);\n");
        assert!(f.is_empty());
        let (f, _) = run("util::exec", "let e = Engine::auto();\n");
        assert!(f.is_empty());
    }

    #[test]
    fn ctx_bypass_suppression_is_honored() {
        let (f, s) = run(
            "report",
            "// lint: allow(ctx_bypass, \"one-off probe engine, never fingerprinted\")\n\
             let e = Engine::new(1);\n",
        );
        assert!(f.is_empty(), "{f:?}");
        assert_eq!(s, 1);
    }
}
