//! Whole-system energy/area rollups: per-memory Table III cells, the
//! version (a)/(b) comparison of Fig 12, the complete-accelerator
//! breakdowns of Figs 23–26, and the per-operation energy split of
//! Figs 19d/21d.
//!
//! Composition: `dataflow` supplies per-op accesses/cycles, `cacti::cache`
//! the (memoized) per-array costs, `pmu` the power-gated static energy,
//! `memory::dram` the off-chip side, and this module rolls them up.  All
//! SRAM costs come through the shared cost cache, so reporting reuses the
//! entries the DSE sweep warmed.
//!
//! Conventions:
//! * every energy this module reports is **per inference**: the profile's
//!   per-batch quantities are amortized over `NetworkProfile::batch`
//!   (batch 1, the paper's setting, divides by 1 and is bit-identical to
//!   the pre-batching rollups);
//! * evaluators return `anyhow::Result` instead of panicking — an
//!   organization that does not fit the profile (e.g. from a malformed
//!   workload spec) reports an error instead of aborting the sweep.

use anyhow::{anyhow, Context, Result};

use crate::cacti::cache;
use crate::config::Technology;
use crate::dataflow::NetworkProfile;
use crate::memory::{component_accesses, cover_op, dram::Dram, Component, MemSpec, Organization};
use crate::pmu;
use crate::util::units::MIB;

/// One Table III cell group: per-memory area + energy split.
#[derive(Debug, Clone)]
pub struct MemEnergy {
    pub component: Component,
    pub spec: MemSpec,
    pub area_mm2: f64,
    pub dyn_j: f64,
    pub static_j: f64,
    pub wakeup_j: f64,
}

impl MemEnergy {
    pub fn total_j(&self) -> f64 {
        self.dyn_j + self.static_j + self.wakeup_j
    }
}

/// On-chip SPM evaluation of one organization (the DSE objective space).
#[derive(Debug, Clone)]
pub struct OrgEnergy {
    pub label: String,
    pub memories: Vec<MemEnergy>,
}

impl OrgEnergy {
    pub fn area_mm2(&self) -> f64 {
        self.memories.iter().map(|m| m.area_mm2).sum()
    }

    pub fn dyn_j(&self) -> f64 {
        self.memories.iter().map(|m| m.dyn_j).sum()
    }

    pub fn static_j(&self) -> f64 {
        self.memories.iter().map(|m| m.static_j).sum()
    }

    pub fn wakeup_j(&self) -> f64 {
        self.memories.iter().map(|m| m.wakeup_j).sum()
    }

    pub fn energy_j(&self) -> f64 {
        self.dyn_j() + self.static_j() + self.wakeup_j()
    }

    pub fn memory(&self, c: Component) -> Option<&MemEnergy> {
        self.memories.iter().find(|m| m.component == c)
    }
}

/// Evaluates one organization's on-chip memories, per inference.
pub fn evaluate_org(
    org: &Organization,
    profile: &NetworkProfile,
    tech: &Technology,
) -> Result<OrgEnergy> {
    let per_inf = 1.0 / profile.batch.max(1) as f64;
    let pmu_report = pmu::evaluate(org, profile, tech)?;
    let costs_of = cache::for_tech(tech);
    let mut memories = Vec::new();
    for (component, spec) in org.components() {
        let cfg = org
            .sram_config(component)
            .ok_or_else(|| anyhow!("instantiated component {} has no spec", component.label()))?;
        let costs = costs_of.costs(&cfg);
        let mut dyn_j = 0.0;
        for op in &profile.ops {
            let cov = cover_op(org, op).ok_or_else(|| {
                anyhow!(
                    "operation '{}' of '{}' does not fit organization {}",
                    op.name,
                    profile.network,
                    org.label()
                )
            })?;
            dyn_j += component_accesses(op, &cov, component) * costs.access_energy_j;
        }
        let stat = pmu_report
            .components
            .iter()
            .find(|c| c.component == component)
            .ok_or_else(|| anyhow!("PMU report misses component {}", component.label()))?;
        memories.push(MemEnergy {
            component,
            spec,
            area_mm2: costs.area_mm2,
            dyn_j: dyn_j * per_inf,
            static_j: stat.static_energy_j * per_inf,
            wakeup_j: stat.wakeup_energy_j * per_inf,
        });
    }
    Ok(OrgEnergy {
        label: org.label(),
        memories,
    })
}

/// Per-operation on-chip memory energy (Figs 19d / 21d): dynamic accesses
/// of that op plus the (PG-aware) leakage spent during it, per inference.
pub fn per_op_energy(
    org: &Organization,
    profile: &NetworkProfile,
    tech: &Technology,
) -> Result<Vec<(String, f64)>> {
    let per_inf = 1.0 / profile.batch.max(1) as f64;
    let pmu_report = pmu::evaluate(org, profile, tech)?;
    let costs_of = cache::for_tech(tech);
    let mut comps = Vec::new();
    for (c, spec) in org.components() {
        let cfg = org
            .sram_config(c)
            .ok_or_else(|| anyhow!("instantiated component {} has no spec", c.label()))?;
        comps.push((c, spec, costs_of.costs(&cfg)));
    }

    profile
        .ops
        .iter()
        .enumerate()
        .map(|(i, op)| {
            let dur = op.cycles as f64 / profile.clock_hz;
            let cov = cover_op(org, op).ok_or_else(|| {
                anyhow!("operation '{}' does not fit organization {}", op.name, org.label())
            })?;
            let mut e = 0.0;
            for (c, spec, costs) in &comps {
                e += component_accesses(op, &cov, *c) * costs.access_energy_j;
                if spec.sectors <= 1 {
                    e += costs.leak_on_w * dur;
                } else {
                    let on = pmu_report
                        .schedule(*c)
                        .ok_or_else(|| anyhow!("no PMU schedule for {}", c.label()))?
                        .on[i];
                    let off = spec.sectors - on;
                    e += dur
                        * (on as f64 * costs.leak_sector_on_w
                            + off as f64 * costs.leak_sector_off_w);
                }
            }
            Ok((op.name.to_string(), e * per_inf))
        })
        .collect()
}

/// Accelerator (NP array + activation + control) energy over one inference.
#[derive(Debug, Clone, Copy)]
pub struct AccelEnergy {
    pub dyn_j: f64,
    pub static_j: f64,
}

impl AccelEnergy {
    pub fn total_j(&self) -> f64 {
        self.dyn_j + self.static_j
    }
}

pub fn accel_energy(profile: &NetworkProfile, tech: &Technology) -> AccelEnergy {
    let per_inf = 1.0 / profile.batch.max(1) as f64;
    AccelEnergy {
        dyn_j: (profile.total_macs() as f64 * tech.mac_energy_j
            + profile.total_act_ops() as f64 * tech.act_energy_j)
            * per_inf,
        static_j: tech.accel_leak_w * profile.inference_s(),
    }
}

/// Off-chip DRAM energy over one inference.
#[derive(Debug, Clone, Copy)]
pub struct DramEnergy {
    pub transfer_j: f64,
    pub background_j: f64,
}

impl DramEnergy {
    pub fn total_j(&self) -> f64 {
        self.transfer_j + self.background_j
    }
}

pub fn dram_energy(profile: &NetworkProfile, tech: &Technology) -> DramEnergy {
    let per_inf = 1.0 / profile.batch.max(1) as f64;
    let dram = Dram::new(tech);
    DramEnergy {
        transfer_j: dram.transfer_energy_j(profile.total_off_chip()) * per_inf,
        background_j: dram.background_energy_j(profile.inference_s()),
    }
}

/// Complete-system evaluation (Figs 12, 23–26 and the headline numbers).
#[derive(Debug, Clone)]
pub struct SystemEnergy {
    pub label: String,
    pub accel: AccelEnergy,
    pub onchip: OrgEnergy,
    /// None for the all-on-chip version (a).
    pub dram: Option<DramEnergy>,
    pub area_mm2: f64,
}

impl SystemEnergy {
    pub fn total_j(&self) -> f64 {
        self.accel.total_j() + self.onchip.energy_j() + self.dram.map_or(0.0, |d| d.total_j())
    }

    pub fn onchip_share(&self) -> f64 {
        self.onchip.energy_j() / self.total_j()
    }

    pub fn offchip_share(&self) -> f64 {
        self.dram.map_or(0.0, |d| d.total_j()) / self.total_j()
    }

    pub fn memory_share(&self) -> f64 {
        self.onchip_share() + self.offchip_share()
    }
}

/// Version (a): the state-of-the-art baseline of [1] — everything in one
/// 8 MiB on-chip SPM, no DRAM traffic during inference.
pub fn version_a(profile: &NetworkProfile, tech: &Technology) -> Result<SystemEnergy> {
    let per_inf = 1.0 / profile.batch.max(1) as f64;
    let org = Organization::smp(MemSpec::new(8 * MIB, 1));
    // All accesses (including what the hierarchy would fetch off-chip) hit
    // the big SPM; its single port is modelled 1-port since [1] reports a
    // monolithic buffer + small staging FIFOs.
    let mut big = Organization::smp(MemSpec::new(8 * MIB, 1));
    big.shared_ports = 1;
    let cfg = big
        .sram_config(Component::Shared)
        .ok_or_else(|| anyhow!("SMP organization lost its shared memory"))?;
    let costs = cache::costs(tech, &cfg);
    let accesses: f64 = profile
        .ops
        .iter()
        .map(|op| op.spm_accesses() as f64 + (op.off_rd + op.off_wr) as f64)
        .sum();
    let dyn_j = accesses * costs.access_energy_j * per_inf;
    let static_j = costs.leak_on_w * profile.inference_s();
    let onchip = OrgEnergy {
        label: "all-on-chip 8 MiB".into(),
        memories: vec![MemEnergy {
            component: Component::Shared,
            spec: org
                .shared
                .ok_or_else(|| anyhow!("SMP organization lost its shared memory"))?,
            area_mm2: costs.area_mm2,
            dyn_j,
            static_j,
            wakeup_j: 0.0,
        }],
    };
    let accel = accel_energy(profile, tech);
    let area = costs.area_mm2 + tech.accel_area_mm2;
    Ok(SystemEnergy {
        label: "version (a): all on-chip [1]".into(),
        accel,
        onchip,
        dram: None,
        area_mm2: area,
    })
}

/// Version (b): the modified architecture of Fig 8b before DESCNet
/// optimization — an SMP-sized hierarchy plus off-chip DRAM.
pub fn version_b(
    profile: &NetworkProfile,
    tech: &Technology,
    smp_size: usize,
) -> Result<SystemEnergy> {
    let org = Organization::smp(MemSpec::new(smp_size, 1));
    system_with_org(profile, tech, &org, "version (b): on-chip + off-chip")
}

/// Complete system around an arbitrary DESCNet organization.
pub fn system_with_org(
    profile: &NetworkProfile,
    tech: &Technology,
    org: &Organization,
    label: &str,
) -> Result<SystemEnergy> {
    let onchip = evaluate_org(org, profile, tech)
        .with_context(|| format!("evaluating {label} [{}]", org.label()))?;
    Ok(SystemEnergy {
        label: format!("{label} [{}]", org.label()),
        accel: accel_energy(profile, tech),
        dram: Some(dram_energy(profile, tech)),
        area_mm2: onchip.area_mm2() + tech.accel_area_mm2,
        onchip,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Accelerator;
    use crate::dataflow::profile_network;
    use crate::model::capsnet_mnist;
    use crate::util::units::KIB;

    fn profile() -> NetworkProfile {
        profile_network(&capsnet_mnist(), &Accelerator::default())
    }

    fn sep() -> Organization {
        Organization::sep(
            MemSpec::new(25 * KIB, 1),
            MemSpec::new(64 * KIB, 1),
            MemSpec::new(32 * KIB, 1),
        )
    }

    fn sep_pg() -> Organization {
        Organization::sep(
            MemSpec::new(25 * KIB, 2),
            MemSpec::new(64 * KIB, 8),
            MemSpec::new(32 * KIB, 2),
        )
    }

    // -------------------------------------------- Table III (CapsNet SEP)

    #[test]
    fn sep_static_energies_match_table_iii() {
        // Paper: W 0.501 mJ, D 0.188 mJ, A 0.238 mJ static.
        let tech = Technology::default();
        let e = evaluate_org(&sep(), &profile(), &tech).unwrap();
        let w = e.memory(Component::Weight).unwrap().static_j;
        let d = e.memory(Component::Data).unwrap().static_j;
        let a = e.memory(Component::Acc).unwrap().static_j;
        assert!((w - 0.501e-3).abs() / 0.501e-3 < 0.15, "W static {w}");
        assert!((d - 0.188e-3).abs() / 0.188e-3 < 0.15, "D static {d}");
        assert!((a - 0.238e-3).abs() / 0.238e-3 < 0.15, "A static {a}");
    }

    #[test]
    fn sep_accumulator_dynamic_matches_table_iii() {
        // Paper: accumulator dynamic 0.196 mJ (the largest dynamic term).
        let tech = Technology::default();
        let e = evaluate_org(&sep(), &profile(), &tech).unwrap();
        let a = e.memory(Component::Acc).unwrap().dyn_j;
        assert!((a - 0.196e-3).abs() / 0.196e-3 < 0.35, "A dyn {a}");
        // And it dominates the data-memory dynamic energy.
        assert!(a > e.memory(Component::Data).unwrap().dyn_j);
    }

    #[test]
    fn sep_weight_dynamic_order_matches_table_iii() {
        // Paper: 0.051 mJ.
        let tech = Technology::default();
        let e = evaluate_org(&sep(), &profile(), &tech).unwrap();
        let w = e.memory(Component::Weight).unwrap().dyn_j;
        assert!((0.02e-3..0.15e-3).contains(&w), "W dyn {w}");
    }

    #[test]
    fn pg_reduces_static_keeps_dynamic() {
        // Fig 19c observation (3): dynamic unchanged between non-PG and PG.
        let tech = Technology::default();
        let base = evaluate_org(&sep(), &profile(), &tech).unwrap();
        let pg = evaluate_org(&sep_pg(), &profile(), &tech).unwrap();
        assert!((pg.dyn_j() - base.dyn_j()).abs() / base.dyn_j() < 1e-9);
        assert!(pg.static_j() < 0.75 * base.static_j());
        assert!(pg.wakeup_j() > 0.0 && pg.wakeup_j() < 1e-6);
    }

    // --------------------------------------------------- Fig 12 versions

    #[test]
    fn version_b_saves_about_73_percent_over_version_a() {
        // "by designing a different memory hierarchy we can already save
        // 73% of the total energy" — we accept 65-90% for the analytical
        // substitute.
        let tech = Technology::default();
        let p = profile();
        let a = version_a(&p, &tech).unwrap();
        let b = version_b(&p, &tech, 108 * KIB).unwrap();
        let saving = 1.0 - b.total_j() / a.total_j();
        assert!((0.60..0.92).contains(&saving), "saving {saving:.3}");
    }

    #[test]
    fn memories_dominate_total_energy() {
        // Section I: "on-chip and off-chip memories contribute to 96% of
        // the total energy".
        let tech = Technology::default();
        let p = profile();
        let b = version_b(&p, &tech, 108 * KIB).unwrap();
        assert!(b.memory_share() > 0.85, "share {:.3}", b.memory_share());
        let a = version_a(&p, &tech).unwrap();
        assert!(a.onchip_share() > 0.9);
    }

    #[test]
    fn version_b_onchip_share_is_minor_but_significant() {
        // Paper: on-chip ~31% of version (b) total; we accept 15-45%.
        let tech = Technology::default();
        let b = version_b(&profile(), &tech, 108 * KIB).unwrap();
        let share = b.onchip_share();
        assert!((0.15..0.45).contains(&share), "{share:.3}");
    }

    // ----------------------------------------------------- headline E18

    #[test]
    fn headline_sep_and_hypg_savings_vs_version_a() {
        // "no performance loss and an energy reduction of 79% for the
        // complete accelerator" (HY-PG); SEP: 78%.
        let tech = Technology::default();
        let p = profile();
        let a = version_a(&p, &tech).unwrap();
        let sep_sys = system_with_org(&p, &tech, &sep(), "DESCNet").unwrap();
        let hy_pg = Organization::hy(
            MemSpec::new(32 * KIB, 2),
            MemSpec::new(25 * KIB, 2),
            MemSpec::new(25 * KIB, 4),
            MemSpec::new(32 * KIB, 2),
            3,
        );
        let hy_sys = system_with_org(&p, &tech, &hy_pg, "DESCNet").unwrap();
        let sep_saving = 1.0 - sep_sys.total_j() / a.total_j();
        let hy_saving = 1.0 - hy_sys.total_j() / a.total_j();
        assert!((0.65..0.95).contains(&sep_saving), "SEP {sep_saving:.3}");
        assert!((0.65..0.95).contains(&hy_saving), "HY-PG {hy_saving:.3}");
        assert!(hy_sys.onchip.energy_j() < sep_sys.onchip.energy_j());
        // Area reduction (paper: 40-47%).
        assert!(sep_sys.area_mm2 < a.area_mm2);
        assert!(hy_sys.area_mm2 < a.area_mm2);
    }

    // --------------------------------------------------- per-op breakdown

    #[test]
    fn per_op_energy_sums_to_org_energy() {
        let tech = Technology::default();
        let p = profile();
        let org = sep_pg();
        let per_op: f64 = per_op_energy(&org, &p, &tech).unwrap().iter().map(|(_, e)| e).sum();
        let total = {
            let e = evaluate_org(&org, &p, &tech).unwrap();
            e.dyn_j() + e.static_j() // wakeups are transition events, not per-op
        };
        assert!((per_op - total).abs() / total < 1e-6, "{per_op} vs {total}");
    }

    #[test]
    fn primarycaps_consumes_most_memory_energy() {
        // Fig 19d: "the highest portion of energy comes from the Prim
        // layer" (high utilization + frequent access + long duration).
        let tech = Technology::default();
        let per_op = per_op_energy(&sep(), &profile(), &tech).unwrap();
        let prim = per_op.iter().find(|(n, _)| n == "Prim").unwrap().1;
        let max = per_op.iter().map(|(_, e)| *e).fold(0.0, f64::max);
        assert!((prim - max).abs() < 1e-12, "Prim {prim} max {max}");
    }

    #[test]
    fn pg_cuts_routing_op_energy_hardest() {
        // Fig 19d pointer (6): routing-op energy drops most under -PG.
        let tech = Technology::default();
        let p = profile();
        let base = per_op_energy(&sep(), &p, &tech).unwrap();
        let pg = per_op_energy(&sep_pg(), &p, &tech).unwrap();
        let ratio = |name: &str| {
            let b = base.iter().find(|(n, _)| n == name).unwrap().1;
            let g = pg.iter().find(|(n, _)| n == name).unwrap().1;
            g / b
        };
        // Routing ops keep most sectors off -> bigger relative cut than Prim.
        assert!(ratio("Class-Sum+Squash2") < ratio("Prim"));
    }

    #[test]
    fn accel_energy_is_small_share() {
        // Fig 12: the computational array is a few percent of the total.
        let tech = Technology::default();
        let p = profile();
        let b = version_b(&p, &tech, 108 * KIB).unwrap();
        let share = b.accel.total_j() / b.total_j();
        assert!(share < 0.12, "accel share {share:.3}");
    }

    // ------------------------------------------------- batch amortization

    #[test]
    fn batching_amortizes_per_inference_energy() {
        // Weight traffic and static/wakeup energy amortize as batch grows:
        // the per-inference on-chip + system energy must fall monotonically
        // over 1 -> 4 -> 16.
        use crate::dataflow::profile_network_batched;
        let tech = Technology::default();
        let net = crate::model::capsnet_mnist();
        let accel = Accelerator::default();
        let mut prev_onchip = f64::INFINITY;
        let mut prev_total = f64::INFINITY;
        for batch in [1usize, 4, 16] {
            let p = profile_network_batched(&net, &accel, batch);
            let onchip = evaluate_org(&sep_pg(), &p, &tech).unwrap().energy_j();
            let total = system_with_org(&p, &tech, &sep_pg(), "b").unwrap().total_j();
            assert!(onchip < prev_onchip, "batch {batch}: {onchip} >= {prev_onchip}");
            assert!(total < prev_total, "batch {batch}: {total} >= {prev_total}");
            prev_onchip = onchip;
            prev_total = total;
        }
    }

    #[test]
    fn batch_one_energy_matches_unbatched_exactly() {
        use crate::dataflow::profile_network_batched;
        let tech = Technology::default();
        let net = crate::model::capsnet_mnist();
        let accel = Accelerator::default();
        let a = evaluate_org(&sep_pg(), &profile(), &tech).unwrap();
        let b = evaluate_org(
            &sep_pg(),
            &profile_network_batched(&net, &accel, 1),
            &tech,
        )
        .unwrap();
        assert_eq!(a.energy_j().to_bits(), b.energy_j().to_bits());
        assert_eq!(a.area_mm2().to_bits(), b.area_mm2().to_bits());
    }

    // ------------------------------------------------------ error reporting

    #[test]
    fn unfitting_org_reports_error_instead_of_panicking() {
        let tech = Technology::default();
        let p = profile();
        // 8 kiB everything: Prim's working set cannot fit anywhere.
        let tiny = Organization::sep(
            MemSpec::new(8 * KIB, 1),
            MemSpec::new(8 * KIB, 1),
            MemSpec::new(8 * KIB, 1),
        );
        let err = evaluate_org(&tiny, &p, &tech).unwrap_err();
        assert!(format!("{err:#}").contains("does not fit"), "{err:#}");
        assert!(per_op_energy(&tiny, &p, &tech).is_err());
        assert!(system_with_org(&p, &tech, &tiny, "x").is_err());
    }
}
