//! Seeded NASCaps-style random capsule-network generator
//! (arXiv:2008.08476 motivates sweeping *families* of CapsNets through the
//! hardware model; this module supplies the family).
//!
//! Every generated network is built through the declarative IR, so the
//! geometry invariants the builder enforces (extent chaining, capsule
//! counts, routing pairs) hold by construction;
//! `rust/tests/builder_golden.rs` additionally property-checks the derived
//! profiles (working sets fit the SMP bound, off-chip traffic consistent
//! with op geometry) for a fan of seeds.  The choice pools keep the
//! networks inside an edge-accelerator envelope: the biggest random net
//! stays within the DeepCaps working-set class, so DSE sweeps over random
//! families terminate in the same time class as the paper pair.

use super::builder::{NetBuilder, Padding};
use super::Network;
use crate::util::prng::Prng;

/// Minimum bytes of a 3-D ConvCaps vote tensor for the generator to emit
/// one: below this the accumulator-ring schedule (which overlays
/// `dataflow::VOTE_RING_OVERLAY`) is not worth modelling.
const MIN_3D_VOTE_BYTES: usize = 512 * 1024;

/// Deterministically generates one random capsule network for `seed`.
pub fn random_network(seed: u64) -> Network {
    let mut rng = Prng::new(seed ^ 0xD5C0_CA95);
    let (mut hw, cin) = *rng.choose(&[(28usize, 1usize), (32, 3), (64, 3)]);
    let types = *rng.choose(&[8usize, 16, 32]);
    let dim = *rng.choose(&[4usize, 8]);

    let mut b = NetBuilder::new(format!("rand-{seed}"), "synthetic")
        .input(hw, hw, cin)
        .conv(
            "Conv1",
            *rng.choose(&[64usize, 128, 256]),
            *rng.choose(&[3usize, 5]),
            1,
            Padding::Same,
        );

    // PrimaryCaps; large inputs stride down so the capsule grid stays in
    // the paper networks' range.
    let prim_stride = if hw >= 32 { 2 } else { *rng.choose(&[1usize, 2]) };
    b = b.primary_caps(
        "Prim",
        types,
        dim,
        *rng.choose(&[3usize, 5, 9]),
        prim_stride,
        Padding::Same,
    );
    hw = hw.div_ceil(prim_stride);

    // 0..=2 DeepCaps-style cells while the grid can afford them.
    let cells = rng.below(3) as usize;
    for cell in 0..cells {
        if hw < 8 {
            break;
        }
        let stride = if hw >= 16 { *rng.choose(&[1usize, 2]) } else { 1 };
        b = b.caps_cell(format!("Cell{cell}"), types, dim, stride);
        hw = hw.div_ceil(stride);
    }

    // Optional 3-D ConvCaps with in-ring routing when the vote tensor is
    // big enough to exercise the accumulator-ring schedule.
    let vote_bytes = hw * hw * types * types * dim * 4;
    if vote_bytes >= MIN_3D_VOTE_BYTES && rng.bool() {
        b = b.conv_caps3d("Caps3D", types, 3);
    }

    // Optional capsule pooling ahead of ClassCaps.
    if hw >= 8 && rng.bool() {
        b = b.pool_caps(2);
    }

    b.class_caps(
        "Class",
        *rng.choose(&[10usize, 20]),
        *rng.choose(&[8usize, 16, 32]),
        1 + rng.below(3) as usize,
    )
    .paper_fps(0.0)
    .build()
    .unwrap_or_else(|e| panic!("generator invariant violated for seed {seed}: {e:#}"))
}

/// `n` networks from consecutive sub-seeds of `seed`.
pub fn random_networks(n: usize, seed: u64) -> Vec<Network> {
    (0..n as u64)
        .map(|i| random_network(seed.wrapping_add(i)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::LayerGroup;

    #[test]
    fn deterministic_per_seed() {
        let a = random_network(7);
        let b = random_network(7);
        assert_eq!(a.ops, b.ops);
        let c = random_network(8);
        assert!(a.ops != c.ops || a.name != c.name);
    }

    #[test]
    fn every_seed_builds_a_classifier() {
        for seed in 0..64 {
            let net = random_network(seed);
            assert!(net.ops.len() >= 4, "seed {seed}: {} ops", net.ops.len());
            assert!(
                net.ops.iter().any(|o| o.group == LayerGroup::ClassCaps),
                "seed {seed} lacks ClassCaps"
            );
            assert!(
                net.ops.iter().any(|o| o.is_routing()),
                "seed {seed} lacks routing"
            );
            assert!(net.total_macs() > 0);
            assert!(net.total_param_bytes() > 0);
        }
    }

    #[test]
    fn random_networks_are_distinct_sub_seeds() {
        let nets = random_networks(3, 100);
        assert_eq!(nets.len(), 3);
        assert_eq!(nets[0].name, "rand-100");
        assert_eq!(nets[2].name, "rand-102");
    }
}
