//! Declarative capsule-network builder IR.
//!
//! [`NetBuilder`] replaces the hand-inlined operation lists of the seed
//! definitions with chained layer constructors — conv / primary-caps /
//! convcaps-2d / caps-cell / convcaps-3d / pool / class-caps / dynamic
//! routing — that *derive* geometry instead of restating it per op: output
//! extents chain from input extents through the padding rule, capsule
//! counts fall out of the spatial grid times the type count, and routing
//! pairs come from the preceding vote op.
//!
//! Bit-compatibility contract: `capsnet_mnist()` and `deepcaps_cifar10()`
//! are expressed on this builder and must produce `Operation` sequences
//! identical (`PartialEq`) to the frozen `model::seed` lists —
//! `rust/tests/builder_golden.rs` pins both the ops and the resulting
//! `OpProfile`s.
//!
//! Error handling: constructors are infallible so chains stay ergonomic; a
//! geometry violation (kernel larger than the input under valid padding,
//! a capsule layer before any capsules exist, ...) is recorded and
//! surfaced by [`NetBuilder::build`] as an `anyhow::Error` — the workload
//! spec loader (`model::spec`) and the random generator
//! (`model::generator`) both build through this path, so a malformed spec
//! reports an error instead of aborting the sweep.

use anyhow::{anyhow, bail, ensure, Result};

use super::{routing_ops, LayerGroup, Network, OpKind, Operation};

/// Convolution padding rule used to derive output extents.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Padding {
    /// No padding: `out = (in - k) / stride + 1`.
    Valid,
    /// Zero padding preserving extent at stride 1: `out = ceil(in / stride)`.
    Same,
}

impl Padding {
    pub fn parse(s: &str) -> Result<Padding> {
        match s {
            "valid" => Ok(Padding::Valid),
            "same" => Ok(Padding::Same),
            other => bail!("unknown padding '{other}' (expected 'valid' or 'same')"),
        }
    }

    fn out(self, input: usize, k: usize, stride: usize) -> Result<usize> {
        ensure!(stride >= 1, "stride must be >= 1");
        ensure!(k >= 1, "kernel must be >= 1");
        match self {
            Padding::Valid => {
                ensure!(
                    input >= k,
                    "valid-padded kernel {k} exceeds input extent {input}"
                );
                Ok((input - k) / stride + 1)
            }
            Padding::Same => {
                ensure!(input >= 1, "empty input extent");
                Ok(input.div_ceil(stride))
            }
        }
    }
}

/// Current activation grid.
#[derive(Debug, Clone, Copy)]
struct Shape {
    h: usize,
    w: usize,
    c: usize,
}

/// Capsule-grid state once a capsule layer has run: the grid holds
/// `h * w * types` capsules of `dim` dimensions each.
#[derive(Debug, Clone, Copy)]
struct CapsState {
    types: usize,
    dim: usize,
}

/// Geometry of the most recent vote op, for explicit `.routing()` tails.
#[derive(Debug, Clone, Copy)]
struct VotesGeom {
    ni: usize,
    no: usize,
    dout: usize,
    votes_in_acc: bool,
}

/// Chainable builder; see the module docs.
#[derive(Debug)]
pub struct NetBuilder {
    name: String,
    dataset: String,
    paper_fps: f64,
    ops: Vec<Operation>,
    shape: Option<Shape>,
    caps: Option<CapsState>,
    last_votes: Option<VotesGeom>,
    err: Option<anyhow::Error>,
}

impl NetBuilder {
    pub fn new(name: impl Into<String>, dataset: impl Into<String>) -> NetBuilder {
        NetBuilder {
            name: name.into(),
            dataset: dataset.into(),
            paper_fps: 0.0,
            ops: Vec::new(),
            shape: None,
            caps: None,
            last_votes: None,
            err: None,
        }
    }

    /// Declares the input feature map; must precede every layer.
    pub fn input(self, h: usize, w: usize, c: usize) -> NetBuilder {
        self.step(|b| {
            ensure!(h > 0 && w > 0 && c > 0, "degenerate input {h}x{w}x{c}");
            ensure!(b.shape.is_none(), "input() declared twice");
            b.shape = Some(Shape { h, w, c });
            Ok(())
        })
    }

    /// Plain (ReLU) convolution.
    pub fn conv(
        self,
        name: impl Into<String>,
        cout: usize,
        k: usize,
        stride: usize,
        pad: Padding,
    ) -> NetBuilder {
        let name = name.into();
        self.step(|b| {
            b.push_conv(name, LayerGroup::Conv, cout, k, stride, pad, 0, false)?;
            b.caps = None;
            Ok(())
        })
    }

    /// PrimaryCaps: a convolution producing `types` capsule types of `dim`
    /// dimensions per position (`cout = types * dim`), squashing every
    /// output capsule.
    pub fn primary_caps(
        self,
        name: impl Into<String>,
        types: usize,
        dim: usize,
        k: usize,
        stride: usize,
        pad: Padding,
    ) -> NetBuilder {
        let name = name.into();
        self.step(|b| {
            ensure!(types > 0 && dim > 0, "degenerate capsule geometry");
            let (hout, wout) = b.conv_out(k, stride, pad)?;
            let squash = hout * wout * types;
            b.push_conv(
                name,
                LayerGroup::PrimaryCaps,
                types * dim,
                k,
                stride,
                pad,
                squash,
                false,
            )?;
            b.caps = Some(CapsState { types, dim });
            Ok(())
        })
    }

    /// A 2-D ConvCaps layer (capsule-typed convolution + squash).
    #[allow(clippy::too_many_arguments)]
    pub fn conv_caps2d(
        self,
        name: impl Into<String>,
        types: usize,
        dim: usize,
        k: usize,
        stride: usize,
        pad: Padding,
        skip_reuse: bool,
    ) -> NetBuilder {
        let name = name.into();
        self.step(|b| {
            ensure!(types > 0 && dim > 0, "degenerate capsule geometry");
            let (hout, wout) = b.conv_out(k, stride, pad)?;
            let squash = hout * wout * types;
            b.push_conv(
                name,
                LayerGroup::ConvCaps2D,
                types * dim,
                k,
                stride,
                pad,
                squash,
                skip_reuse,
            )?;
            b.caps = Some(CapsState { types, dim });
            Ok(())
        })
    }

    /// A DeepCaps cell: three sequential 3x3 ConvCaps (the first applies
    /// the cell stride) plus a parallel skip ConvCaps over the cell input.
    /// The cell input is re-read by the skip branch, so both the first conv
    /// and the skip conv mark `skip_reuse`.
    pub fn caps_cell(
        self,
        prefix: impl Into<String>,
        types: usize,
        dim: usize,
        stride: usize,
    ) -> NetBuilder {
        let prefix = prefix.into();
        self.step(|b| {
            ensure!(types > 0 && dim > 0, "degenerate capsule geometry");
            let cell_in = b.shape.ok_or_else(|| anyhow!("caps_cell before input()"))?;
            // Three sequential ConvCaps; the first strides and re-reads the
            // cell input (the parallel skip branch streams it again).
            b.push_conv(
                format!("{prefix}-Conv0"),
                LayerGroup::ConvCaps2D,
                types * dim,
                3,
                stride,
                Padding::Same,
                0, // squash derived below
                true,
            )?;
            b.fix_last_squash(types);
            for conv in 1..3 {
                b.push_conv(
                    format!("{prefix}-Conv{conv}"),
                    LayerGroup::ConvCaps2D,
                    types * dim,
                    3,
                    1,
                    Padding::Same,
                    0,
                    false,
                )?;
                b.fix_last_squash(types);
            }
            // Parallel skip ConvCaps over the saved cell input.
            let after = b.shape;
            b.shape = Some(cell_in);
            b.push_conv(
                format!("{prefix}-Skip"),
                LayerGroup::ConvCaps2D,
                types * dim,
                3,
                stride,
                Padding::Same,
                0,
                true,
            )?;
            b.fix_last_squash(types);
            b.shape = after;
            b.caps = Some(CapsState { types, dim });
            Ok(())
        })
    }

    /// 3-D ConvCaps: spatially-shared transforms pinned in PE registers
    /// vote every grid capsule into `out_types` output types of the same
    /// dimensionality; the vote tensor stays resident in the accumulator
    /// ring and `iters` routing iterations run over it in place.
    pub fn conv_caps3d(
        self,
        name: impl Into<String>,
        out_types: usize,
        iters: usize,
    ) -> NetBuilder {
        let name = name.into();
        self.step(|b| {
            ensure!(out_types > 0, "degenerate capsule geometry");
            let shape = b.shape.ok_or_else(|| anyhow!("conv_caps3d before input()"))?;
            let caps = b
                .caps
                .ok_or_else(|| anyhow!("conv_caps3d requires a preceding capsule layer"))?;
            let ni = shape.h * shape.w * caps.types;
            b.ops.push(Operation {
                name: format!("{name}-Votes"),
                group: LayerGroup::ConvCaps3D,
                kind: OpKind::Votes {
                    ni,
                    no: out_types,
                    di: caps.dim,
                    dout: caps.dim,
                    weights_in_pe_regs: true,
                    votes_in_acc: true,
                },
            });
            b.last_votes = Some(VotesGeom {
                ni,
                no: out_types,
                dout: caps.dim,
                votes_in_acc: true,
            });
            if iters > 0 {
                b.ops
                    .extend(routing_ops(&name, ni, out_types, caps.dim, iters, true));
            }
            b.shape = Some(Shape {
                h: shape.h,
                w: shape.w,
                c: out_types * caps.dim,
            });
            b.caps = Some(CapsState {
                types: out_types,
                dim: caps.dim,
            });
            Ok(())
        })
    }

    /// Spatial `factor:1` pooling of the capsule grid.
    pub fn pool_caps(self, factor: usize) -> NetBuilder {
        self.step(|b| {
            ensure!(factor >= 1, "pool factor must be >= 1");
            let shape = b.shape.ok_or_else(|| anyhow!("pool_caps before input()"))?;
            ensure!(
                b.caps.is_some(),
                "pool_caps requires a preceding capsule layer"
            );
            ensure!(
                shape.h >= factor && shape.w >= factor,
                "pool factor {factor} exceeds grid {}x{}",
                shape.h,
                shape.w
            );
            b.shape = Some(Shape {
                h: shape.h / factor,
                w: shape.w / factor,
                c: shape.c,
            });
            Ok(())
        })
    }

    /// ClassCaps: every grid capsule votes into `classes` output capsules
    /// of `dout` dimensions, followed by `iters` dynamic-routing
    /// iterations (`iters == 0` emits the vote op only; attach routing
    /// later with [`NetBuilder::routing`]).
    pub fn class_caps(
        self,
        name: impl Into<String>,
        classes: usize,
        dout: usize,
        iters: usize,
    ) -> NetBuilder {
        let name = name.into();
        self.step(|b| {
            ensure!(classes > 0 && dout > 0, "degenerate capsule geometry");
            let shape = b.shape.ok_or_else(|| anyhow!("class_caps before input()"))?;
            let caps = b
                .caps
                .ok_or_else(|| anyhow!("class_caps requires a preceding capsule layer"))?;
            let ni = shape.h * shape.w * caps.types;
            b.ops.push(Operation {
                name: name.clone(),
                group: LayerGroup::ClassCaps,
                kind: OpKind::Votes {
                    ni,
                    no: classes,
                    di: caps.dim,
                    dout,
                    weights_in_pe_regs: false,
                    votes_in_acc: false,
                },
            });
            b.last_votes = Some(VotesGeom {
                ni,
                no: classes,
                dout,
                votes_in_acc: false,
            });
            if iters > 0 {
                b.ops
                    .extend(routing_ops(&name, ni, classes, dout, iters, false));
            }
            b.shape = Some(Shape {
                h: 1,
                w: 1,
                c: classes * dout,
            });
            b.caps = Some(CapsState {
                types: classes,
                dim: dout,
            });
            Ok(())
        })
    }

    /// Explicit dynamic-routing tail over the most recent vote op (for
    /// workload specs that separate votes from routing).
    pub fn routing(self, prefix: impl Into<String>, iters: usize) -> NetBuilder {
        let prefix = prefix.into();
        self.step(|b| {
            ensure!(iters > 0, "routing with zero iterations");
            let v = b
                .last_votes
                .ok_or_else(|| anyhow!("routing() requires a preceding vote op"))?;
            b.ops
                .extend(routing_ops(&prefix, v.ni, v.no, v.dout, iters, v.votes_in_acc));
            Ok(())
        })
    }

    /// Paper-reported throughput on CapsAcc, for validation.
    pub fn paper_fps(mut self, fps: f64) -> NetBuilder {
        self.paper_fps = fps;
        self
    }

    /// Finalizes the network; returns the first recorded chain error.
    pub fn build(self) -> Result<Network> {
        if let Some(e) = self.err {
            return Err(e.context(format!("building network '{}'", self.name)));
        }
        ensure!(
            !self.ops.is_empty(),
            "network '{}' has no operations",
            self.name
        );
        Ok(Network {
            name: self.name,
            dataset: self.dataset,
            ops: self.ops,
            paper_fps: self.paper_fps,
        })
    }

    // ------------------------------------------------------------ internals

    fn step(mut self, f: impl FnOnce(&mut NetBuilder) -> Result<()>) -> NetBuilder {
        if self.err.is_none() {
            if let Err(e) = f(&mut self) {
                self.err = Some(e);
            }
        }
        self
    }

    fn conv_out(&self, k: usize, stride: usize, pad: Padding) -> Result<(usize, usize)> {
        let shape = self.shape.ok_or_else(|| anyhow!("layer before input()"))?;
        Ok((pad.out(shape.h, k, stride)?, pad.out(shape.w, k, stride)?))
    }

    #[allow(clippy::too_many_arguments)]
    fn push_conv(
        &mut self,
        name: String,
        group: LayerGroup,
        cout: usize,
        k: usize,
        stride: usize,
        pad: Padding,
        squash_caps: usize,
        skip_reuse: bool,
    ) -> Result<()> {
        ensure!(cout > 0, "conv '{name}' with zero output channels");
        let shape = self.shape.ok_or_else(|| anyhow!("conv '{name}' before input()"))?;
        let (hout, wout) = self.conv_out(k, stride, pad)?;
        ensure!(hout > 0 && wout > 0, "conv '{name}' collapses the grid");
        self.ops.push(Operation {
            name,
            group,
            kind: OpKind::Conv2d {
                hin: shape.h,
                win: shape.w,
                cin: shape.c,
                hout,
                wout,
                cout,
                kh: k,
                kw: k,
                stride,
                squash_caps,
                skip_reuse,
            },
        });
        self.shape = Some(Shape {
            h: hout,
            w: wout,
            c: cout,
        });
        Ok(())
    }

    /// Sets the squash count of the just-pushed conv from its *own* output
    /// grid (used by `caps_cell`, whose squash depends on the conv's
    /// derived extent).
    fn fix_last_squash(&mut self, types: usize) {
        if let Some(Operation {
            kind:
                OpKind::Conv2d {
                    hout,
                    wout,
                    squash_caps,
                    ..
                },
            ..
        }) = self.ops.last_mut()
        {
            *squash_caps = *hout * *wout * types;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::seed;

    #[test]
    fn builder_capsnet_matches_seed_ops() {
        let built = crate::model::capsnet_mnist();
        let seed = seed::capsnet_mnist_seed();
        assert_eq!(built.name, seed.name);
        assert_eq!(built.dataset, seed.dataset);
        assert_eq!(built.ops, seed.ops);
        assert_eq!(built.paper_fps, seed.paper_fps);
    }

    #[test]
    fn builder_deepcaps_matches_seed_ops() {
        let built = crate::model::deepcaps_cifar10();
        let seed = seed::deepcaps_cifar10_seed();
        assert_eq!(built.ops.len(), 31);
        assert_eq!(built.ops, seed.ops);
    }

    #[test]
    fn padding_derivations() {
        assert_eq!(Padding::Valid.out(28, 9, 1).unwrap(), 20);
        assert_eq!(Padding::Valid.out(20, 9, 2).unwrap(), 6);
        assert_eq!(Padding::Same.out(64, 3, 1).unwrap(), 64);
        assert_eq!(Padding::Same.out(64, 3, 2).unwrap(), 32);
        assert!(Padding::Valid.out(5, 9, 1).is_err());
        assert!(Padding::parse("same").is_ok());
        assert!(Padding::parse("reflect").is_err());
    }

    #[test]
    fn chain_errors_surface_at_build() {
        // Capsule layer without capsules: deferred error, not a panic.
        let err = NetBuilder::new("bad", "x")
            .input(28, 28, 1)
            .class_caps("Class", 10, 16, 3)
            .build()
            .unwrap_err();
        assert!(format!("{err:#}").contains("capsule layer"), "{err:#}");

        // Kernel larger than the input under valid padding.
        let err = NetBuilder::new("bad2", "x")
            .input(5, 5, 1)
            .conv("C", 8, 9, 1, Padding::Valid)
            .build()
            .unwrap_err();
        assert!(format!("{err:#}").contains("exceeds input extent"), "{err:#}");

        // Missing input().
        assert!(NetBuilder::new("bad3", "x")
            .conv("C", 8, 3, 1, Padding::Same)
            .build()
            .is_err());
    }

    #[test]
    fn first_error_wins_and_later_layers_are_ignored() {
        let err = NetBuilder::new("bad", "x")
            .conv("C", 8, 3, 1, Padding::Same) // error: no input
            .input(28, 28, 1) // would otherwise succeed
            .build()
            .unwrap_err();
        assert!(format!("{err:#}").contains("input()"), "{err:#}");
    }

    #[test]
    fn explicit_routing_extends_last_votes() {
        let net = NetBuilder::new("r", "x")
            .input(28, 28, 1)
            .primary_caps("Prim", 8, 8, 9, 2, Padding::Valid)
            .class_caps("Class", 10, 16, 0)
            .routing("Class", 2)
            .build()
            .unwrap();
        assert_eq!(net.ops.iter().filter(|o| o.is_routing()).count(), 4);
        assert!(net.ops.last().unwrap().name.ends_with("Update+Softmax2"));
    }

    #[test]
    fn derived_capsule_counts_chain() {
        let net = NetBuilder::new("t", "x")
            .input(32, 32, 3)
            .conv("Conv1", 64, 3, 1, Padding::Same)
            .primary_caps("Prim", 16, 8, 5, 2, Padding::Same)
            .pool_caps(2)
            .class_caps("Class", 10, 16, 3)
            .build()
            .unwrap();
        // Prim grid: 16x16x16 types; pooled to 8x8 -> ni = 8*8*16 = 1024.
        match &net.op("Class").unwrap().kind {
            OpKind::Votes { ni, di, .. } => {
                assert_eq!(*ni, 1024);
                assert_eq!(*di, 8);
            }
            _ => unreachable!(),
        }
    }
}
