//! JSON workload-spec loader: arbitrary capsule networks (and multi-network
//! workload sets) described declaratively and built through
//! [`crate::model::builder::NetBuilder`].  Uses `util::json` — no serde.
//!
//! Single-network schema (all integers; `padding` defaults to `"same"`,
//! `stride` to 1, `iters` to 3):
//!
//! ```json
//! {
//!   "name": "smallcaps", "dataset": "synthetic", "paper_fps": 0,
//!   "input": [32, 32, 3],
//!   "layers": [
//!     {"type": "conv",         "name": "Conv1", "out_channels": 128,
//!      "kernel": 3, "stride": 1, "padding": "same"},
//!     {"type": "primary_caps", "name": "Prim", "types": 16, "caps_dim": 8,
//!      "kernel": 5, "stride": 2},
//!     {"type": "caps_cell",    "prefix": "Cell0", "types": 16,
//!      "caps_dim": 8, "stride": 2},
//!     {"type": "conv_caps2d",  "name": "Extra", "types": 16, "caps_dim": 8,
//!      "kernel": 3, "stride": 1, "skip_reuse": false},
//!     {"type": "conv_caps3d",  "name": "Caps3D", "types": 16, "iters": 3},
//!     {"type": "pool_caps",    "factor": 2},
//!     {"type": "class_caps",   "name": "Class", "classes": 10,
//!      "caps_dim": 16, "iters": 3},
//!     {"type": "routing",      "prefix": "Class2", "iters": 1}
//!   ]
//! }
//! ```
//!
//! Workload-set schema — a list of specs and/or builtins, with optional
//! serving-mix weights (normalized by `dse::multi::WorkloadSet`):
//!
//! ```json
//! {"networks": [{"builtin": "capsnet"}, {"builtin": "deepcaps"},
//!               {"name": "...", "input": [...], "layers": [...]}],
//!  "weights": [0.6, 0.3, 0.1]}
//! ```

use std::path::Path;

use anyhow::{anyhow, bail, ensure, Context, Result};

use super::builder::{NetBuilder, Padding};
use super::{capsnet_mnist, deepcaps_cifar10, Network};
use crate::util::json::Json;

/// A parsed workload file: one or more networks plus optional mix weights.
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    pub networks: Vec<Network>,
    pub weights: Option<Vec<f64>>,
}

/// Loads a workload file (single-network or workload-set schema).
pub fn load(path: &Path) -> Result<WorkloadSpec> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading workload spec {}", path.display()))?;
    let j = Json::parse(&text)
        .map_err(|e| anyhow!("{e}"))
        .with_context(|| format!("parsing workload spec {}", path.display()))?;
    workload_from_json(&j).with_context(|| format!("in workload spec {}", path.display()))
}

/// Parses either schema from an already-parsed JSON value.
pub fn workload_from_json(j: &Json) -> Result<WorkloadSpec> {
    if let Some(nets) = j.get("networks").as_arr() {
        ensure!(!nets.is_empty(), "'networks' list is empty");
        let networks = nets
            .iter()
            .enumerate()
            .map(|(i, n)| network_from_json(n).with_context(|| format!("networks[{i}]")))
            .collect::<Result<Vec<_>>>()?;
        let weights = match j.get("weights") {
            Json::Null => None,
            w => {
                let ws: Vec<f64> = w
                    .as_arr()
                    .ok_or_else(|| anyhow!("'weights' must be an array"))?
                    .iter()
                    .map(|v| v.as_f64().ok_or_else(|| anyhow!("non-numeric weight")))
                    .collect::<Result<Vec<_>>>()?;
                ensure!(
                    ws.len() == networks.len(),
                    "{} weights for {} networks",
                    ws.len(),
                    networks.len()
                );
                Some(ws)
            }
        };
        Ok(WorkloadSpec { networks, weights })
    } else {
        Ok(WorkloadSpec {
            networks: vec![network_from_json(j)?],
            weights: None,
        })
    }
}

/// Resolves a builtin network by name (the CLI's `--net` values).
pub fn builtin(name: &str) -> Result<Network> {
    match name {
        "capsnet" => Ok(capsnet_mnist()),
        "deepcaps" => Ok(deepcaps_cifar10()),
        other => bail!("unknown builtin network '{other}' (capsnet|deepcaps)"),
    }
}

/// Builds one network from its JSON spec (or `{"builtin": name}`).
pub fn network_from_json(j: &Json) -> Result<Network> {
    if let Some(name) = j.get("builtin").as_str() {
        return builtin(name);
    }
    let name = j
        .get("name")
        .as_str()
        .ok_or_else(|| anyhow!("missing network 'name'"))?;
    let dataset = j.get("dataset").as_str().unwrap_or("custom");
    let input = j
        .get("input")
        .usize_vec()
        .ok_or_else(|| anyhow!("'input' must be [h, w, c]"))?;
    ensure!(input.len() == 3, "'input' must be [h, w, c]");
    let layers = j
        .get("layers")
        .as_arr()
        .ok_or_else(|| anyhow!("missing 'layers' array"))?;

    let mut b = NetBuilder::new(name, dataset).input(input[0], input[1], input[2]);
    for (i, layer) in layers.iter().enumerate() {
        b = apply_layer(b, layer).with_context(|| format!("layers[{i}]"))?;
    }
    if let Some(fps) = j.get("paper_fps").as_f64() {
        b = b.paper_fps(fps);
    }
    b.build()
}

fn apply_layer(b: NetBuilder, j: &Json) -> Result<NetBuilder> {
    let kind = j
        .get("type")
        .as_str()
        .ok_or_else(|| anyhow!("layer missing 'type'"))?;
    let req = |key: &str| -> Result<usize> {
        j.get(key)
            .as_usize()
            .ok_or_else(|| anyhow!("{kind}: missing or non-integer '{key}'"))
    };
    let opt = |key: &str, default: usize| -> Result<usize> {
        match j.get(key) {
            Json::Null => Ok(default),
            v => v
                .as_usize()
                .ok_or_else(|| anyhow!("{kind}: non-integer '{key}'")),
        }
    };
    let name = |key: &str| -> Result<String> {
        j.get(key)
            .as_str()
            .map(str::to_string)
            .ok_or_else(|| anyhow!("{kind}: missing '{key}'"))
    };
    let padding = match j.get("padding") {
        Json::Null => Padding::Same,
        v => Padding::parse(
            v.as_str()
                .ok_or_else(|| anyhow!("{kind}: 'padding' must be a string"))?,
        )?,
    };

    Ok(match kind {
        "conv" => b.conv(
            name("name")?,
            req("out_channels")?,
            req("kernel")?,
            opt("stride", 1)?,
            padding,
        ),
        "primary_caps" => b.primary_caps(
            name("name")?,
            req("types")?,
            req("caps_dim")?,
            req("kernel")?,
            opt("stride", 1)?,
            padding,
        ),
        "conv_caps2d" => b.conv_caps2d(
            name("name")?,
            req("types")?,
            req("caps_dim")?,
            req("kernel")?,
            opt("stride", 1)?,
            padding,
            j.get("skip_reuse").as_bool().unwrap_or(false),
        ),
        "caps_cell" => b.caps_cell(
            name("prefix")?,
            req("types")?,
            req("caps_dim")?,
            opt("stride", 1)?,
        ),
        "conv_caps3d" => b.conv_caps3d(name("name")?, req("types")?, opt("iters", 3)?),
        "pool_caps" => b.pool_caps(req("factor")?),
        "class_caps" => b.class_caps(
            name("name")?,
            req("classes")?,
            req("caps_dim")?,
            opt("iters", 3)?,
        ),
        "routing" => b.routing(name("prefix")?, opt("iters", 3)?),
        other => bail!("unknown layer type '{other}'"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const CAPSNET_SPEC: &str = r#"{
      "name": "capsnet", "dataset": "mnist", "paper_fps": 116,
      "input": [28, 28, 1],
      "layers": [
        {"type": "conv", "name": "Conv1", "out_channels": 256,
         "kernel": 9, "stride": 1, "padding": "valid"},
        {"type": "primary_caps", "name": "Prim", "types": 32, "caps_dim": 8,
         "kernel": 9, "stride": 2, "padding": "valid"},
        {"type": "class_caps", "name": "Class", "classes": 10,
         "caps_dim": 16, "iters": 3}
      ]
    }"#;

    #[test]
    fn capsnet_spec_reproduces_builtin() {
        let j = Json::parse(CAPSNET_SPEC).unwrap();
        let net = network_from_json(&j).unwrap();
        let reference = capsnet_mnist();
        assert_eq!(net.ops, reference.ops);
        assert_eq!(net.paper_fps, reference.paper_fps);
    }

    #[test]
    fn builtin_references_resolve() {
        let j = Json::parse(r#"{"networks": [{"builtin": "capsnet"}, {"builtin": "deepcaps"}]}"#)
            .unwrap();
        let spec = workload_from_json(&j).unwrap();
        assert_eq!(spec.networks.len(), 2);
        assert_eq!(spec.networks[0].name, "capsnet");
        assert_eq!(spec.networks[1].ops.len(), 31);
        assert!(spec.weights.is_none());
    }

    #[test]
    fn weights_are_validated() {
        let j = Json::parse(
            r#"{"networks": [{"builtin": "capsnet"}], "weights": [0.5, 0.5]}"#,
        )
        .unwrap();
        let err = workload_from_json(&j).unwrap_err();
        assert!(format!("{err:#}").contains("weights"), "{err:#}");
    }

    #[test]
    fn malformed_specs_report_errors_not_panics() {
        for bad in [
            r#"{"name": "x", "input": [28, 28], "layers": []}"#,
            r#"{"name": "x", "input": [28, 28, 1], "layers": [{"type": "warp"}]}"#,
            r#"{"name": "x", "input": [28, 28, 1],
                "layers": [{"type": "conv", "name": "C", "kernel": 3}]}"#,
            r#"{"name": "x", "input": [28, 28, 1],
                "layers": [{"type": "class_caps", "name": "C", "classes": 10,
                            "caps_dim": 16}]}"#,
            r#"{"input": [28, 28, 1], "layers": []}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(network_from_json(&j).is_err(), "accepted: {bad}");
        }
    }

    #[test]
    fn single_network_file_wraps_into_spec() {
        let j = Json::parse(CAPSNET_SPEC).unwrap();
        let spec = workload_from_json(&j).unwrap();
        assert_eq!(spec.networks.len(), 1);
    }
}
