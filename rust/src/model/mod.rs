//! Network descriptions: the generalized workload layer.
//!
//! An [`Operation`] is the unit the paper profiles (Figs 1, 9, 10, 11).
//! Networks are no longer hand-inlined operation lists: the declarative
//! [`builder::NetBuilder`] IR derives geometry (extent chaining, capsule
//! counts, routing pairs) from chained layer constructors, and three
//! front-ends feed it:
//!
//! * [`capsnet_mnist`] / [`deepcaps_cifar10`] — the two paper benchmarks,
//!   re-expressed on the builder (pinned bit-identical to the frozen
//!   [`seed`] lists by `rust/tests/builder_golden.rs`);
//! * [`spec`] — a JSON workload-spec loader (NASCaps-style families via
//!   `descnet dse --workload FILE`);
//! * [`generator`] — a seeded random capsule-network generator
//!   (`descnet dse --random N`).
//!
//! The geometry here is the single source of truth for the dataflow model
//! (`crate::dataflow`), the energy rollups, and the python L2 models
//! (python/compile/model.py mirrors the paper pair; the
//! `tests/test_model.py` geometry assertions pin both sides).

pub mod builder;
pub mod capsnet;
pub mod deepcaps;
pub mod generator;
pub mod seed;
pub mod spec;

pub use builder::{NetBuilder, Padding};
pub use capsnet::capsnet_mnist;
pub use deepcaps::deepcaps_cifar10;
pub use generator::{random_network, random_networks};

/// Which half of a dynamic-routing iteration an op implements.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutingHalf {
    /// Weighted vote aggregation + squash (s_j = sum_i c_ij uhat_ij; v_j).
    SumSquash,
    /// Agreement update + coupling softmax (b += uhat.v; c = softmax(b)).
    UpdateSoftmax,
}

/// Layer-group tag used for grouping in figures (Fig 9/19/21 x-axes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerGroup {
    Conv,
    PrimaryCaps,
    ConvCaps2D,
    ConvCaps3D,
    ClassCaps,
    DynRouting,
}

impl LayerGroup {
    pub fn label(&self) -> &'static str {
        match self {
            LayerGroup::Conv => "Conv",
            LayerGroup::PrimaryCaps => "PrimaryCaps",
            LayerGroup::ConvCaps2D => "ConvCaps2D",
            LayerGroup::ConvCaps3D => "ConvCaps3D",
            LayerGroup::ClassCaps => "ClassCaps",
            LayerGroup::DynRouting => "DynRouting",
        }
    }
}

/// Operation kinds with full geometry (all sizes in elements, not bytes).
#[derive(Debug, Clone, PartialEq)]
pub enum OpKind {
    /// 2-D convolution (plain or capsule-typed; `squash_caps > 0` marks a
    /// ConvCaps layer squashing that many capsules).
    Conv2d {
        hin: usize,
        win: usize,
        cin: usize,
        hout: usize,
        wout: usize,
        cout: usize,
        kh: usize,
        kw: usize,
        stride: usize,
        /// number of capsules squashed at the output (0 = ReLU layer)
        squash_caps: usize,
        /// input feature map is re-read by a parallel skip branch (DeepCaps
        /// cells) — enables full-fmap residency in the data SPM
        skip_reuse: bool,
    },
    /// Capsule vote computation: uhat[i,j] = u[i] @ W[i,j].
    Votes {
        ni: usize,
        no: usize,
        di: usize,
        dout: usize,
        /// transforms are spatially shared and pinned in PE-local registers
        /// (DeepCaps 3D ConvCaps); the weight SPM is bypassed
        weights_in_pe_regs: bool,
        /// votes accumulate into the on-chip accumulator SPM instead of
        /// being drained off-chip (DeepCaps 3D ConvCaps ring buffer)
        votes_in_acc: bool,
    },
    /// One half of a dynamic-routing iteration.
    Routing {
        ni: usize,
        no: usize,
        dout: usize,
        iter: usize,
        total_iters: usize,
        half: RoutingHalf,
        /// votes were left resident in the accumulator SPM by a preceding
        /// `Votes { votes_in_acc: true }` op (3D ConvCaps routing)
        votes_in_acc: bool,
    },
}

/// One schedulable operation of a network's inference.
#[derive(Debug, Clone, PartialEq)]
pub struct Operation {
    pub name: String,
    pub group: LayerGroup,
    pub kind: OpKind,
}

impl Operation {
    /// Multiply-accumulate count of this op (the Fig 7 x-axis).
    pub fn macs(&self) -> u64 {
        match &self.kind {
            OpKind::Conv2d {
                hout,
                wout,
                cout,
                kh,
                kw,
                cin,
                ..
            } => (hout * wout * cout * kh * kw * cin) as u64,
            OpKind::Votes { ni, no, di, dout, .. } => (ni * no * di * dout) as u64,
            OpKind::Routing { ni, no, dout, half, .. } => match half {
                // s_j = sum_i c_ij * uhat_ij : one MAC per (pair, dim).
                RoutingHalf::SumSquash => (ni * no * dout) as u64,
                // b += <uhat, v> : one MAC per (pair, dim).
                RoutingHalf::UpdateSoftmax => (ni * no * dout) as u64,
            },
        }
    }

    /// Parameter bytes held by this op (weights + biases; routing has none).
    pub fn param_bytes(&self) -> u64 {
        match &self.kind {
            OpKind::Conv2d { kh, kw, cin, cout, .. } => (kh * kw * cin * cout + cout) as u64,
            OpKind::Votes {
                ni,
                no,
                di,
                dout,
                weights_in_pe_regs,
                ..
            } => {
                if *weights_in_pe_regs {
                    // spatially shared: one transform per (in-type, out-type)
                    // — ni here counts positions x types, so divide back out
                    // is the caller's concern; report the shared matrix.
                    (no * di * dout * 32) as u64 // 32 in-capsule types
                } else {
                    (ni * no * di * dout) as u64
                }
            }
            OpKind::Routing { .. } => 0,
        }
    }

    pub fn is_routing(&self) -> bool {
        matches!(self.kind, OpKind::Routing { .. })
    }
}

/// A network = named sequence of operations (+ benchmark metadata).
#[derive(Debug, Clone)]
pub struct Network {
    pub name: String,
    pub dataset: String,
    pub ops: Vec<Operation>,
    /// Paper-reported throughput on CapsAcc, for validation (fps).
    pub paper_fps: f64,
}

impl Network {
    pub fn total_macs(&self) -> u64 {
        self.ops.iter().map(|o| o.macs()).sum()
    }

    pub fn total_param_bytes(&self) -> u64 {
        self.ops.iter().map(|o| o.param_bytes()).sum()
    }

    pub fn op(&self, name: &str) -> Option<&Operation> {
        self.ops.iter().find(|o| o.name == name)
    }
}

/// Builds the standard 3-iteration routing-op tail shared by ClassCaps
/// layers (and the 3D ConvCaps): `[Sum+Squash_1, Update+Softmax_1, ...]`.
pub fn routing_ops(
    prefix: &str,
    ni: usize,
    no: usize,
    dout: usize,
    iters: usize,
    votes_in_acc: bool,
) -> Vec<Operation> {
    let mut ops = Vec::new();
    for it in 1..=iters {
        ops.push(Operation {
            name: format!("{prefix}-Sum+Squash{it}"),
            group: LayerGroup::DynRouting,
            kind: OpKind::Routing {
                ni,
                no,
                dout,
                iter: it,
                total_iters: iters,
                half: RoutingHalf::SumSquash,
                votes_in_acc,
            },
        });
        ops.push(Operation {
            name: format!("{prefix}-Update+Softmax{it}"),
            group: LayerGroup::DynRouting,
            kind: OpKind::Routing {
                ni,
                no,
                dout,
                iter: it,
                total_iters: iters,
                half: RoutingHalf::UpdateSoftmax,
                votes_in_acc,
            },
        });
    }
    ops
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routing_ops_structure() {
        let ops = routing_ops("Class", 1152, 10, 16, 3, false);
        assert_eq!(ops.len(), 6);
        assert!(ops[0].name.ends_with("Sum+Squash1"));
        assert!(ops[5].name.ends_with("Update+Softmax3"));
        assert!(ops.iter().all(|o| o.is_routing()));
        assert_eq!(ops[0].macs(), 1152 * 10 * 16);
    }

    #[test]
    fn conv_macs_and_params() {
        let op = Operation {
            name: "Conv1".into(),
            group: LayerGroup::Conv,
            kind: OpKind::Conv2d {
                hin: 28,
                win: 28,
                cin: 1,
                hout: 20,
                wout: 20,
                cout: 256,
                kh: 9,
                kw: 9,
                stride: 1,
                squash_caps: 0,
                skip_reuse: false,
            },
        };
        assert_eq!(op.macs(), 20 * 20 * 256 * 81);
        assert_eq!(op.param_bytes(), 81 * 256 + 256);
    }
}
