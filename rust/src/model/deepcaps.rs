//! DeepCaps [Rajasegaran et al. 2019] for CIFAR10, as the 31-operation
//! CapsAcc schedule the paper profiles (Figs 9b, 11, 20, 21, 25, 26, 28;
//! Tables II, III) — expressed on the declarative builder IR.
//!
//! Adapted geometry (DESIGN.md section 6): 64x64x3 input, Conv1 (3x3x128),
//! four ConvCaps2D cells of 4 layers each (3 sequential + 1 parallel skip,
//! strides 2/2/1/1, 32 capsule types x 8D = 256 channels), a 3-D ConvCaps
//! with dynamic routing on the final 16x16 grid (votes kept resident in the
//! accumulator SPM — the 8 MiB working set of Table II), 4:1 capsule
//! pooling, and a ClassCaps layer (2048 x 8D -> 10 x 32D) with routing.
//!
//! Op count: 1 conv + 16 ConvCaps + 1 vote op + 6 routing (3D) + 1 vote op
//! (ClassCaps) + 6 routing = 31.  The spatial pyramid (64 -> 32 -> 16), the
//! 8192-capsule 3-D grid and the 2048 ClassCaps inputs are all *derived* by
//! the builder from the cell strides and pooling — nothing is restated.
//!
//! The frozen hand-inlined list lives in `model::seed`;
//! `rust/tests/builder_golden.rs` pins this definition bit-identical to it.

use super::builder::{NetBuilder, Padding};
use super::Network;

pub const CAPS_TYPES: usize = 32;
pub const CAPS_DIM: usize = 8;
pub const CAPS_CHANNELS: usize = CAPS_TYPES * CAPS_DIM; // 256
pub const CELL_STRIDES: [usize; 4] = [2, 2, 1, 1];
pub const FINAL_HW: usize = 16;
pub const NUM_CLASSES: usize = 10;
pub const CLASS_CAPS_DIM: usize = 32;
pub const ROUTING_ITERS: usize = 3;
/// 4:1 spatial pooling of capsules before ClassCaps (16x16 -> 8x8 grid).
pub const CLASS_POOL: usize = 2;

/// Number of input capsules to ClassCaps: 8*8*32 = 2048.
pub const NUM_CLASS_IN_CAPS: usize =
    (FINAL_HW / CLASS_POOL) * (FINAL_HW / CLASS_POOL) * CAPS_TYPES;

pub fn deepcaps_cifar10() -> Network {
    let mut b = NetBuilder::new("deepcaps", "cifar10")
        .input(64, 64, 3)
        .conv("Conv1", 128, 3, 1, Padding::Same);
    for (cell, &stride) in CELL_STRIDES.iter().enumerate() {
        b = b.caps_cell(format!("Cell{cell}"), CAPS_TYPES, CAPS_DIM, stride);
    }
    b.conv_caps3d("Caps3D", CAPS_TYPES, ROUTING_ITERS)
        .pool_caps(CLASS_POOL)
        .class_caps("Class", NUM_CLASSES, CLASS_CAPS_DIM, ROUTING_ITERS)
        .paper_fps(9.7)
        .build()
        .expect("paper-pinned DeepCaps chain is valid")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{LayerGroup, OpKind, RoutingHalf};

    #[test]
    fn thirty_one_operations() {
        let net = deepcaps_cifar10();
        assert_eq!(net.ops.len(), 31);
        // 15 ConvCaps2D are sequential+skip minus... the paper counts 15
        // ConvCaps2D + 1 ConvCaps3D; our 16 2-D layers include the cell-0
        // head that the paper's Fig 5 draws as part of the first cell.
        let caps2d = net
            .ops
            .iter()
            .filter(|o| o.group == LayerGroup::ConvCaps2D)
            .count();
        assert_eq!(caps2d, 16);
        assert_eq!(net.ops.iter().filter(|o| o.is_routing()).count(), 12);
    }

    #[test]
    fn spatial_pyramid() {
        let net = deepcaps_cifar10();
        // Cell outputs: 32, 16, 16, 16.
        match &net.op("Cell0-Conv0").unwrap().kind {
            OpKind::Conv2d { hout, .. } => assert_eq!(*hout, 32),
            _ => unreachable!(),
        }
        match &net.op("Cell3-Conv2").unwrap().kind {
            OpKind::Conv2d { hout, .. } => assert_eq!(*hout, FINAL_HW),
            _ => unreachable!(),
        }
    }

    #[test]
    fn vote_buffer_is_8mib_class_of_table_ii() {
        // 16*16*32 caps x 32 types x 8D x 4B = 8 MiB: the accumulator
        // working set that drives Table II's 8 MiB accumulator SPM.
        let ni = FINAL_HW * FINAL_HW * CAPS_TYPES;
        let bytes = ni * CAPS_TYPES * CAPS_DIM * 4;
        assert_eq!(bytes, 8 * 1024 * 1024);
        // And the builder derived exactly that vote geometry.
        let net = deepcaps_cifar10();
        match &net.op("Caps3D-Votes").unwrap().kind {
            OpKind::Votes { ni: n, no, dout, votes_in_acc, .. } => {
                assert_eq!((*n, *no, *dout), (ni, CAPS_TYPES, CAPS_DIM));
                assert!(votes_in_acc);
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn classcaps_geometry() {
        let net = deepcaps_cifar10();
        match &net.op("Class").unwrap().kind {
            OpKind::Votes { ni, no, di, dout, .. } => {
                assert_eq!((*ni, *no, *di, *dout), (2048, 10, 8, 32));
            }
            _ => unreachable!(),
        }
        match &net.ops.last().unwrap().kind {
            OpKind::Routing { iter, half, .. } => {
                assert_eq!(*iter, 3);
                assert_eq!(*half, RoutingHalf::UpdateSoftmax);
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn convcaps_dominate_macs() {
        // Paper: ConvCaps2D ops are 73% of DeepCaps execution time; in MACs
        // they dominate even harder.
        let net = deepcaps_cifar10();
        let caps2d: u64 = net
            .ops
            .iter()
            .filter(|o| o.group == LayerGroup::ConvCaps2D)
            .map(|o| o.macs())
            .sum();
        assert!(caps2d as f64 > 0.9 * net.total_macs() as f64);
    }
}
