//! Frozen seed definitions of the two paper benchmarks, exactly as the
//! original hand-inlined operation lists shipped them (every geometry
//! constant restated per op).
//!
//! These are **golden references only**: the live `capsnet_mnist()` /
//! `deepcaps_cifar10()` constructors are now expressed on the declarative
//! [`crate::model::builder::NetBuilder`] IR, and
//! `rust/tests/builder_golden.rs` pins the builder output bit-identical
//! (operation-by-operation `PartialEq`, and `OpProfile`-by-`OpProfile`
//! through the dataflow model) against this module.  Do not edit the
//! numbers here; a builder change that diverges from them is a regression.

use super::{routing_ops, LayerGroup, Network, OpKind, Operation};

/// Seed CapsNet (MNIST): the 9-operation CapsAcc schedule, hand-inlined.
pub fn capsnet_mnist_seed() -> Network {
    const NUM_PRIMARY_CAPS: usize = 1152;
    const CAPS_DIM: usize = 8;
    const NUM_CLASSES: usize = 10;
    const CLASS_CAPS_DIM: usize = 16;
    const ROUTING_ITERS: usize = 3;

    let mut ops = vec![
        Operation {
            name: "Conv1".into(),
            group: LayerGroup::Conv,
            kind: OpKind::Conv2d {
                hin: 28,
                win: 28,
                cin: 1,
                hout: 20,
                wout: 20,
                cout: 256,
                kh: 9,
                kw: 9,
                stride: 1,
                squash_caps: 0,
                skip_reuse: false,
            },
        },
        Operation {
            name: "Prim".into(),
            group: LayerGroup::PrimaryCaps,
            kind: OpKind::Conv2d {
                hin: 20,
                win: 20,
                cin: 256,
                hout: 6,
                wout: 6,
                cout: 256,
                kh: 9,
                kw: 9,
                stride: 2,
                squash_caps: NUM_PRIMARY_CAPS,
                skip_reuse: false,
            },
        },
        Operation {
            name: "Class".into(),
            group: LayerGroup::ClassCaps,
            kind: OpKind::Votes {
                ni: NUM_PRIMARY_CAPS,
                no: NUM_CLASSES,
                di: CAPS_DIM,
                dout: CLASS_CAPS_DIM,
                weights_in_pe_regs: false,
                votes_in_acc: false,
            },
        },
    ];
    ops.extend(routing_ops(
        "Class",
        NUM_PRIMARY_CAPS,
        NUM_CLASSES,
        CLASS_CAPS_DIM,
        ROUTING_ITERS,
        false,
    ));
    Network {
        name: "capsnet".into(),
        dataset: "mnist".into(),
        ops,
        paper_fps: 116.0,
    }
}

/// Seed DeepCaps (CIFAR10): the 31-operation schedule, hand-inlined.
pub fn deepcaps_cifar10_seed() -> Network {
    const CAPS_TYPES: usize = 32;
    const CAPS_DIM: usize = 8;
    const CAPS_CHANNELS: usize = CAPS_TYPES * CAPS_DIM; // 256
    const CELL_STRIDES: [usize; 4] = [2, 2, 1, 1];
    const FINAL_HW: usize = 16;
    const NUM_CLASSES: usize = 10;
    const CLASS_CAPS_DIM: usize = 32;
    const ROUTING_ITERS: usize = 3;
    const CLASS_POOL: usize = 2;
    const NUM_CLASS_IN_CAPS: usize =
        (FINAL_HW / CLASS_POOL) * (FINAL_HW / CLASS_POOL) * CAPS_TYPES;

    fn convcaps(
        name: String,
        hin: usize,
        cin: usize,
        stride: usize,
        skip_reuse: bool,
    ) -> Operation {
        let hout = hin / stride;
        Operation {
            name,
            group: LayerGroup::ConvCaps2D,
            kind: OpKind::Conv2d {
                hin,
                win: hin,
                cin,
                hout,
                wout: hout,
                cout: CAPS_CHANNELS,
                kh: 3,
                kw: 3,
                stride,
                squash_caps: hout * hout * CAPS_TYPES,
                skip_reuse,
            },
        }
    }

    let mut ops = vec![Operation {
        name: "Conv1".into(),
        group: LayerGroup::Conv,
        kind: OpKind::Conv2d {
            hin: 64,
            win: 64,
            cin: 3,
            hout: 64,
            wout: 64,
            cout: 128,
            kh: 3,
            kw: 3,
            stride: 1,
            squash_caps: 0,
            skip_reuse: false,
        },
    }];

    let mut hw = 64;
    let mut cin = 128;
    for (cell, &stride) in CELL_STRIDES.iter().enumerate() {
        let hout = hw / stride;
        for conv in 0..3 {
            let (h_in, c_in, s) = if conv == 0 {
                (hw, cin, stride)
            } else {
                (hout, CAPS_CHANNELS, 1)
            };
            let reused = conv == 0;
            ops.push(convcaps(
                format!("Cell{cell}-Conv{conv}"),
                h_in,
                c_in,
                s,
                reused,
            ));
        }
        ops.push(convcaps(format!("Cell{cell}-Skip"), hw, cin, stride, true));
        hw = hout;
        cin = CAPS_CHANNELS;
    }
    debug_assert_eq!(hw, FINAL_HW);

    let ni_3d = FINAL_HW * FINAL_HW * CAPS_TYPES; // 8192
    ops.push(Operation {
        name: "Caps3D-Votes".into(),
        group: LayerGroup::ConvCaps3D,
        kind: OpKind::Votes {
            ni: ni_3d,
            no: CAPS_TYPES,
            di: CAPS_DIM,
            dout: CAPS_DIM,
            weights_in_pe_regs: true,
            votes_in_acc: true,
        },
    });
    ops.extend(routing_ops(
        "Caps3D",
        ni_3d,
        CAPS_TYPES,
        CAPS_DIM,
        ROUTING_ITERS,
        true,
    ));

    ops.push(Operation {
        name: "Class".into(),
        group: LayerGroup::ClassCaps,
        kind: OpKind::Votes {
            ni: NUM_CLASS_IN_CAPS,
            no: NUM_CLASSES,
            di: CAPS_DIM,
            dout: CLASS_CAPS_DIM,
            weights_in_pe_regs: false,
            votes_in_acc: false,
        },
    });
    ops.extend(routing_ops(
        "Class",
        NUM_CLASS_IN_CAPS,
        NUM_CLASSES,
        CLASS_CAPS_DIM,
        ROUTING_ITERS,
        false,
    ));

    Network {
        name: "deepcaps".into(),
        dataset: "cifar10".into(),
        ops,
        paper_fps: 9.7,
    }
}
