//! Google's CapsNet [Sabour et al. 2017] for MNIST, as the 9-operation
//! CapsAcc schedule the paper profiles (Figs 1, 9a, 10, 12, 18, 19, 23, 24,
//! 27; Tables I, III).
//!
//! Geometry (pinned against python/compile/model.py::CapsNetConfig.google):
//!   Conv1       : 28x28x1 -> 9x9x256 valid, ReLU -> 20x20x256
//!   PrimaryCaps : 9x9 conv stride 2 -> 6x6x256 = 1152 capsules x 8D, squash
//!   ClassCaps   : votes 1152x8 -> 10x16, then 3 routing iterations
//!                 (Sum+Squash / Update+Softmax pairs = 6 ops)

use super::{routing_ops, LayerGroup, Network, OpKind, Operation};

pub const NUM_PRIMARY_CAPS: usize = 1152;
pub const CAPS_DIM: usize = 8;
pub const NUM_CLASSES: usize = 10;
pub const CLASS_CAPS_DIM: usize = 16;
pub const ROUTING_ITERS: usize = 3;

pub fn capsnet_mnist() -> Network {
    let mut ops = vec![
        Operation {
            name: "Conv1".into(),
            group: LayerGroup::Conv,
            kind: OpKind::Conv2d {
                hin: 28,
                win: 28,
                cin: 1,
                hout: 20,
                wout: 20,
                cout: 256,
                kh: 9,
                kw: 9,
                stride: 1,
                squash_caps: 0,
                skip_reuse: false,
            },
        },
        Operation {
            name: "Prim".into(),
            group: LayerGroup::PrimaryCaps,
            kind: OpKind::Conv2d {
                hin: 20,
                win: 20,
                cin: 256,
                hout: 6,
                wout: 6,
                cout: 256,
                kh: 9,
                kw: 9,
                stride: 2,
                squash_caps: NUM_PRIMARY_CAPS,
                skip_reuse: false,
            },
        },
        Operation {
            name: "Class".into(),
            group: LayerGroup::ClassCaps,
            kind: OpKind::Votes {
                ni: NUM_PRIMARY_CAPS,
                no: NUM_CLASSES,
                di: CAPS_DIM,
                dout: CLASS_CAPS_DIM,
                weights_in_pe_regs: false,
                votes_in_acc: false,
            },
        },
    ];
    ops.extend(routing_ops(
        "Class",
        NUM_PRIMARY_CAPS,
        NUM_CLASSES,
        CLASS_CAPS_DIM,
        ROUTING_ITERS,
        false,
    ));
    Network {
        name: "capsnet".into(),
        dataset: "mnist".into(),
        ops,
        paper_fps: 116.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nine_operations_as_in_paper() {
        let net = capsnet_mnist();
        assert_eq!(net.ops.len(), 9); // Conv1, Prim, Class + 3x2 routing
        assert_eq!(net.ops[0].name, "Conv1");
        assert_eq!(net.ops[1].name, "Prim");
        assert_eq!(net.ops[2].name, "Class");
        assert_eq!(
            net.ops.iter().filter(|o| o.is_routing()).count(),
            6,
            "the paper's 'last six operations (dynamic routing)'"
        );
    }

    #[test]
    fn geometry_matches_python_model() {
        // Pinned against tests/test_model.py::test_google_config_matches_paper
        let net = capsnet_mnist();
        match &net.ops[1].kind {
            OpKind::Conv2d { hout, wout, cout, .. } => {
                assert_eq!(hout * wout * cout / CAPS_DIM, 1152);
            }
            _ => panic!("Prim must be a conv"),
        }
        match &net.ops[2].kind {
            OpKind::Votes { ni, no, di, dout, .. } => {
                assert_eq!((*ni, *no, *di, *dout), (1152, 10, 8, 16));
            }
            _ => panic!("Class must be votes"),
        }
    }

    #[test]
    fn parameter_count_close_to_published() {
        // Google's CapsNet (without the reconstruction decoder) has ~6.8M
        // parameters; conv1 21k + primary 5.31M + classcaps 1.47M.
        let net = capsnet_mnist();
        let params = net.total_param_bytes();
        assert!(
            (6_500_000..7_200_000).contains(&params),
            "params = {params}"
        );
    }

    #[test]
    fn macs_dominated_by_primarycaps() {
        let net = capsnet_mnist();
        let prim = net.op("Prim").unwrap().macs();
        assert!(prim * 2 > net.total_macs(), "Prim is the MAC hot-spot");
        assert_eq!(prim, 191_102_976); // 6*6*256 * 9*9*256
    }
}
