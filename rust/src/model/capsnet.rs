//! Google's CapsNet [Sabour et al. 2017] for MNIST, as the 9-operation
//! CapsAcc schedule the paper profiles (Figs 1, 9a, 10, 12, 18, 19, 23, 24,
//! 27; Tables I, III) — expressed on the declarative builder IR
//! (`model::builder`), which derives the geometry chain:
//!
//!   Conv1       : 28x28x1 --9x9 valid--> 20x20x256, ReLU
//!   PrimaryCaps : 9x9 stride 2 -> 6x6 x (32 types x 8D) = 1152 caps, squash
//!   ClassCaps   : votes 1152x8 -> 10x16, then 3 routing iterations
//!                 (Sum+Squash / Update+Softmax pairs = 6 ops)
//!
//! The frozen hand-inlined list lives in `model::seed`;
//! `rust/tests/builder_golden.rs` pins this definition bit-identical to it.

use super::builder::{NetBuilder, Padding};
use super::Network;

pub const PRIMARY_TYPES: usize = 32;
pub const NUM_PRIMARY_CAPS: usize = 1152;
pub const CAPS_DIM: usize = 8;
pub const NUM_CLASSES: usize = 10;
pub const CLASS_CAPS_DIM: usize = 16;
pub const ROUTING_ITERS: usize = 3;

pub fn capsnet_mnist() -> Network {
    NetBuilder::new("capsnet", "mnist")
        .input(28, 28, 1)
        .conv("Conv1", 256, 9, 1, Padding::Valid)
        .primary_caps("Prim", PRIMARY_TYPES, CAPS_DIM, 9, 2, Padding::Valid)
        .class_caps("Class", NUM_CLASSES, CLASS_CAPS_DIM, ROUTING_ITERS)
        .paper_fps(116.0)
        .build()
        .expect("paper-pinned CapsNet chain is valid")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::OpKind;

    #[test]
    fn nine_operations_as_in_paper() {
        let net = capsnet_mnist();
        assert_eq!(net.ops.len(), 9); // Conv1, Prim, Class + 3x2 routing
        assert_eq!(net.ops[0].name, "Conv1");
        assert_eq!(net.ops[1].name, "Prim");
        assert_eq!(net.ops[2].name, "Class");
        assert_eq!(
            net.ops.iter().filter(|o| o.is_routing()).count(),
            6,
            "the paper's 'last six operations (dynamic routing)'"
        );
    }

    #[test]
    fn geometry_matches_python_model() {
        // Pinned against tests/test_model.py::test_google_config_matches_paper
        let net = capsnet_mnist();
        match &net.ops[1].kind {
            OpKind::Conv2d { hout, wout, cout, .. } => {
                assert_eq!(hout * wout * cout / CAPS_DIM, NUM_PRIMARY_CAPS);
            }
            _ => panic!("Prim must be a conv"),
        }
        match &net.ops[2].kind {
            OpKind::Votes { ni, no, di, dout, .. } => {
                assert_eq!((*ni, *no, *di, *dout), (1152, 10, 8, 16));
            }
            _ => panic!("Class must be votes"),
        }
    }

    #[test]
    fn parameter_count_close_to_published() {
        // Google's CapsNet (without the reconstruction decoder) has ~6.8M
        // parameters; conv1 21k + primary 5.31M + classcaps 1.47M.
        let net = capsnet_mnist();
        let params = net.total_param_bytes();
        assert!(
            (6_500_000..7_200_000).contains(&params),
            "params = {params}"
        );
    }

    #[test]
    fn macs_dominated_by_primarycaps() {
        let net = capsnet_mnist();
        let prim = net.op("Prim").unwrap().macs();
        assert!(prim * 2 > net.total_macs(), "Prim is the MAC hot-spot");
        assert_eq!(prim, 191_102_976); // 6*6*256 * 9*9*256
    }
}
