//! Event-level NP-array simulator.
//!
//! The analytical model (`crate::dataflow`) computes per-op cycle counts in
//! closed form; this module *simulates* the same schedule at tile
//! granularity — weight-tile loads double-buffered against row streaming,
//! accumulator drains through the activation unit, and the per-output-
//! capsule serialization of dynamic routing — and reports where time goes
//! (compute / weight-stream / drain / normalization).
//!
//! Purpose (DESIGN.md inventory row "event-level simulator"):
//!   1. cross-validate the closed forms: `sim_op` must agree with
//!      `dataflow::profile_op` within a small tolerance for every op of
//!      both networks (asserted in tests and in `tests/paper_claims.rs`);
//!   2. expose the *phase breakdown* the closed form hides (used by the
//!      `descnet analyze --sim` view and the ablation bench).

use crate::config::Accelerator;
use crate::dataflow::{profile_op, OpProfile};
use crate::model::{Network, OpKind, Operation};

/// Where an operation's cycles went.
#[derive(Debug, Clone, Default)]
pub struct OpSim {
    pub name: String,
    /// MAC-array busy cycles.
    pub compute: u64,
    /// Cycles stalled on the weight-SPM stream (port-width bound).
    pub weight_stream: u64,
    /// Activation-unit drain cycles not hidden behind compute.
    pub drain: u64,
    /// Routing normalization serialization (per output capsule).
    pub normalization: u64,
    /// Fixed pipeline fill/drain overhead.
    pub overhead: u64,
}

impl OpSim {
    pub fn total(&self) -> u64 {
        self.compute + self.weight_stream + self.drain + self.normalization + self.overhead
    }

    /// Utilization of the MAC array over the op.
    pub fn utilization(&self) -> f64 {
        if self.total() == 0 {
            0.0
        } else {
            self.compute as f64 / self.total() as f64
        }
    }
}

/// Simulates one operation tile by tile.
pub fn sim_op(op: &Operation, accel: &Accelerator) -> OpSim {
    let pes = accel.pes() as u64;
    let cols = accel.array_cols as u64;
    match &op.kind {
        OpKind::Conv2d {
            hin: _,
            win: _,
            cin,
            hout,
            wout,
            cout,
            kh,
            kw,
            squash_caps,
            ..
        } => {
            // Tile loop: output-channel tiles of 16 x input-channel tiles of
            // 16; each tile's weights (kh*kw*16*16 bytes) stream at 16 B/cyc
            // double-buffered against the tile's MAC work.
            let co_tiles = cout.div_ceil(accel.array_cols);
            let ci_tiles = cin.div_ceil(accel.array_rows);
            let mut compute = 0u64;
            let mut weight_stream = 0u64;
            let mut pending_load = 0u64; // first tile load is exposed
            for _co in 0..co_tiles {
                for _ci in 0..ci_tiles {
                    let co_width = accel.array_cols.min(*cout) as u64;
                    let ci_width = accel.array_rows.min(*cin) as u64;
                    let tile_macs =
                        (hout * wout) as u64 * co_width * ci_width * (*kh as u64) * (*kw as u64);
                    let tile_cycles = tile_macs / pes;
                    let load_cycles = (kh * kw) as u64 * ci_width * co_width / cols;
                    // Double buffering: the *previous* pending load overlaps
                    // this tile's compute.
                    weight_stream += pending_load.saturating_sub(tile_cycles);
                    compute += tile_cycles.max(if pending_load > tile_cycles {
                        0
                    } else {
                        tile_cycles
                    });
                    pending_load = load_cycles;
                }
            }
            // First tile's load was never overlapped.
            let first_load = (kh * kw) as u64 * accel.array_rows.min(*cin) as u64
                * accel.array_cols.min(*cout) as u64
                / cols;
            weight_stream += first_load;
            let drain =
                (squash_caps * accel.squash_cycles_per_elem / accel.array_cols.max(1)) as u64;
            OpSim {
                name: op.name.clone(),
                compute,
                weight_stream,
                drain,
                normalization: 0,
                overhead: accel.op_overhead_cycles as u64,
            }
        }
        OpKind::Votes {
            ni,
            no,
            di,
            dout,
            weights_in_pe_regs,
            ..
        } => {
            // Per-(input-tile, output-capsule) vote matmuls; transform tiles
            // stream unless pinned in PE registers.
            let macs = (ni * no * di * dout) as u64;
            let compute = macs / pes;
            let stream = if *weights_in_pe_regs {
                0
            } else {
                op.param_bytes() / cols
            };
            OpSim {
                name: op.name.clone(),
                compute: compute.min(stream.max(compute)),
                weight_stream: stream.saturating_sub(compute),
                drain: 0,
                normalization: 0,
                overhead: accel.op_overhead_cycles as u64,
            }
        }
        OpKind::Routing {
            ni, no, dout, ..
        } => {
            // One 16-long dot per cycle on the PE row; per output capsule a
            // serialized normalization tail, overlapped past the
            // double-buffer depth.
            let pairs = (ni * no) as u64;
            let compute = pairs * (*dout as u64) / accel.array_rows as u64;
            let tail =
                (ni * accel.routing_act_serial_cycles).min(accel.routing_j_overhead_cap) as u64;
            let mut normalization = 0;
            for _j in 0..*no {
                normalization += tail;
            }
            OpSim {
                name: op.name.clone(),
                compute,
                weight_stream: 0,
                drain: 0,
                normalization,
                overhead: accel.op_overhead_cycles as u64,
            }
        }
    }
}

/// Simulates a whole network; returns per-op simulations.
pub fn sim_network(net: &Network, accel: &Accelerator) -> Vec<OpSim> {
    net.ops.iter().map(|op| sim_op(op, accel)).collect()
}

/// Cross-validation: relative disagreement between the event simulation and
/// the analytical closed form for one op.
pub fn rel_disagreement(sim: &OpSim, analytical: &OpProfile) -> f64 {
    let a = analytical.cycles as f64;
    (sim.total() as f64 - a).abs() / a
}

/// Convenience: validate a whole network; returns the max disagreement.
pub fn validate_network(net: &Network, accel: &Accelerator) -> f64 {
    net.ops
        .iter()
        .map(|op| {
            let sim = sim_op(op, accel);
            let ana = profile_op(op, accel);
            rel_disagreement(&sim, &ana)
        })
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{capsnet_mnist, deepcaps_cifar10};

    #[test]
    fn simulation_agrees_with_closed_form_capsnet() {
        let accel = Accelerator::default();
        let net = capsnet_mnist();
        for op in &net.ops {
            let sim = sim_op(op, &accel);
            let ana = profile_op(op, &accel);
            assert!(
                rel_disagreement(&sim, &ana) < 0.08,
                "{}: sim {} vs analytical {}",
                op.name,
                sim.total(),
                ana.cycles
            );
        }
    }

    #[test]
    fn simulation_agrees_with_closed_form_deepcaps() {
        let accel = Accelerator::default();
        assert!(validate_network(&deepcaps_cifar10(), &accel) < 0.08);
    }

    #[test]
    fn conv_utilization_is_high_routing_low() {
        // The architectural story of Fig 7/9: convolutions keep the array
        // busy; routing is serialization-bound.
        let accel = Accelerator::default();
        let net = capsnet_mnist();
        let sims = sim_network(&net, &accel);
        let prim = sims.iter().find(|s| s.name == "Prim").unwrap();
        assert!(prim.utilization() > 0.9, "{}", prim.utilization());
        let routing = sims
            .iter()
            .find(|s| s.name == "Class-Update+Softmax1")
            .unwrap();
        assert!(routing.utilization() < 0.15, "{}", routing.utilization());
        assert!(routing.normalization > routing.compute);
    }

    #[test]
    fn classcaps_is_weight_stream_bound() {
        let accel = Accelerator::default();
        let net = capsnet_mnist();
        let sims = sim_network(&net, &accel);
        let class = sims.iter().find(|s| s.name == "Class").unwrap();
        assert!(
            class.weight_stream > class.compute,
            "stream {} <= compute {}",
            class.weight_stream,
            class.compute
        );
    }

    #[test]
    fn phase_totals_are_consistent() {
        let accel = Accelerator::default();
        for net in [capsnet_mnist(), deepcaps_cifar10()] {
            for sim in sim_network(&net, &accel) {
                assert_eq!(
                    sim.total(),
                    sim.compute + sim.weight_stream + sim.drain + sim.normalization + sim.overhead
                );
                assert!(sim.utilization() <= 1.0);
            }
        }
    }
}
