//! Integration: the sharded fleet simulator (ISSUE 4).
//!
//! * golden fleet stats for a pinned seed/config (bless-on-first-run: the
//!   golden file is written when absent/pending — run once on a toolchain
//!   container to pin it, `DESCNET_BLESS=1` to re-pin deliberately);
//! * determinism: the full design+simulate pipeline is bit-identical for
//!   threads=1 vs threads=N (the DSE engine is order-deterministic and the
//!   event loop is serial);
//! * JSQ is never worse than round-robin on p99 under asymmetric shards;
//! * the SLO-constrained co-designed fleet never spends more energy per
//!   request than the homogeneous union-SMP baseline, at identical
//!   latency (the fleet-level "no performance loss" argument).

use std::path::PathBuf;

use descnet::config::SystemConfig;
use descnet::ctx::EvalCtx;
use descnet::fleet::{
    design_fleet, simulate, DesignOptions, FleetConfig, RoutingPolicy, ShardPlan,
};
use descnet::model::{capsnet_mnist, deepcaps_cifar10};

fn golden_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/goldens/fleet_seed7.txt")
}

/// Two synthetic shards (one at 70% speed) under JSQ — exercises routing,
/// padding, flush deadlines and the energy rollup without the DSE, so the
/// golden pins the event engine + PRNG alone.
fn golden_scenario() -> (Vec<ShardPlan>, FleetConfig) {
    let plans = vec![
        ShardPlan::synthetic("wl-a", vec![1, 2, 4], 10e-3, 5e-3, 1.0, 2e-3).unwrap(),
        ShardPlan::synthetic("wl-b", vec![1, 4], 12e-3, 3e-3, 0.7, 2e-3).unwrap(),
    ];
    let cfg = FleetConfig {
        rps: 150.0,
        requests: 500,
        seed: 7,
        policy: RoutingPolicy::Jsq,
        slo_s: Some(50e-3),
        fault: None,
    };
    (plans, cfg)
}

#[test]
fn golden_fleet_stats_for_pinned_seed() {
    let (plans, cfg) = golden_scenario();
    let mut stats = simulate(&plans, &cfg).expect("fleet simulation");
    let fingerprint = stats.fingerprint();
    let body = format!("{fingerprint}\n\n{}", stats.summary());

    let path = golden_path();
    let existing = std::fs::read_to_string(&path).unwrap_or_default();
    let bless = std::env::var_os("DESCNET_BLESS").is_some();
    if bless || existing.is_empty() || existing.starts_with("pending") {
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, &body).unwrap();
        eprintln!("blessed fleet golden at {}", path.display());
        return;
    }
    let pinned = existing.lines().next().unwrap_or("");
    assert_eq!(
        pinned,
        fingerprint,
        "fleet stats drifted from the pinned golden; if intentional, re-run \
         with DESCNET_BLESS=1 and commit {}",
        path.display()
    );
}

#[test]
fn fleet_pipeline_is_bit_identical_across_thread_counts() {
    let cfg = SystemConfig::default();
    let run = |threads: usize| {
        let ctx = EvalCtx::for_config(&cfg).threads(threads);
        let opts = DesignOptions {
            shards: 2,
            batch_sizes: vec![1, 2],
            slo_s: Some(20e-3),
            flush_deadline_s: 2e-3,
            homogeneous: false,
        };
        let design = design_fleet(&ctx, &[capsnet_mnist()], &opts).expect("fleet design");
        let fcfg = FleetConfig {
            rps: 120.0,
            requests: 150,
            seed: 9,
            policy: RoutingPolicy::Jsq,
            slo_s: Some(20e-3),
            fault: None,
        };
        let mut stats = simulate(&design.plans, &fcfg).expect("fleet simulation");
        let mut base = simulate(&design.baseline, &fcfg).expect("baseline simulation");
        (
            design
                .plans
                .iter()
                .map(|p| p.org.label())
                .collect::<Vec<_>>(),
            stats.fingerprint(),
            base.fingerprint(),
        )
    };
    let a = run(1);
    let b = run(4);
    assert_eq!(a.0, b.0, "selected organizations differ across threads");
    assert_eq!(a.1, b.1, "fleet report differs across threads");
    assert_eq!(a.2, b.2, "baseline report differs across threads");
}

#[test]
fn jsq_never_worse_than_round_robin_p99_on_asymmetric_shards() {
    // One shard at quarter speed: round-robin keeps feeding it half the
    // open-loop traffic (beyond its capacity), JSQ routes around the
    // backlog.  Holds across seeds, not just one lucky trace.
    for seed in [1u64, 7, 42] {
        let plans = vec![
            ShardPlan::synthetic("fast", vec![1, 2, 4], 10e-3, 5e-3, 1.0, 2e-3).unwrap(),
            ShardPlan::synthetic("slow", vec![1, 2, 4], 10e-3, 5e-3, 0.25, 2e-3).unwrap(),
        ];
        let p99 = |policy: RoutingPolicy| {
            let cfg = FleetConfig {
                rps: 120.0,
                requests: 600,
                seed,
                policy,
                slo_s: None,
                fault: None,
            };
            let mut stats = simulate(&plans, &cfg).expect("fleet simulation");
            stats.latency.p99()
        };
        let jsq = p99(RoutingPolicy::Jsq);
        let rr = p99(RoutingPolicy::RoundRobin);
        assert!(
            jsq <= rr * (1.0 + 1e-9),
            "seed {seed}: JSQ p99 {jsq} worse than RR p99 {rr}"
        );
    }
}

#[test]
fn codesigned_fleet_energy_beats_the_homogeneous_smp_baseline() {
    // The ISSUE 4 acceptance criterion: under the same SLO-admitted batch
    // sets and the same arrival trace, the per-shard co-designed fleet
    // must not spend more energy per request than the union-SMP baseline —
    // and must serve at identical latency (wakeups mask at the paper
    // constants, so the organizations cannot differ in schedule).
    let ctx = EvalCtx::for_config(&SystemConfig::default()).threads(4);
    let opts = DesignOptions {
        shards: 2,
        batch_sizes: vec![1, 2, 4],
        slo_s: Some(20e-3),
        flush_deadline_s: 2e-3,
        homogeneous: false,
    };
    let design = design_fleet(&ctx, &[capsnet_mnist()], &opts).expect("fleet design");

    // Pointwise: every admitted batch is cheaper (or equal) per inference
    // on the co-designed organization.
    for (plan, base) in design.plans.iter().zip(&design.baseline) {
        assert_eq!(plan.batcher.sizes(), base.batcher.sizes(), "batch sets differ");
        for b in plan.batcher.sizes() {
            assert!(
                plan.energy_per_inf[b] <= base.energy_per_inf[b] * (1.0 + 1e-12),
                "batch {b}: codesigned {} J vs baseline {} J",
                plan.energy_per_inf[b],
                base.energy_per_inf[b]
            );
            // "No performance loss": identical simulated batch latency.
            assert_eq!(
                plan.batch_latency_s[b].to_bits(),
                base.batch_latency_s[b].to_bits(),
                "batch {b} latency differs between organizations"
            );
        }
    }

    // End to end: the simulated fleet rollups agree.
    let fcfg = FleetConfig {
        rps: 100.0,
        requests: 300,
        seed: 7,
        policy: RoutingPolicy::Jsq,
        slo_s: Some(20e-3),
        fault: None,
    };
    let mut stats = simulate(&design.plans, &fcfg).expect("fleet simulation");
    let mut base = simulate(&design.baseline, &fcfg).expect("baseline simulation");
    assert!(
        stats.energy_per_request_j() <= base.energy_per_request_j() * (1.0 + 1e-12),
        "codesigned {} J/req vs baseline {} J/req",
        stats.energy_per_request_j(),
        base.energy_per_request_j()
    );
    // Identical schedules -> bit-identical latency percentiles.
    assert_eq!(stats.latency.p99().to_bits(), base.latency.p99().to_bits());
    assert_eq!(stats.requests, base.requests);
    // The SLO gates batch 4 out at 20 ms (batch-4 CapsNet simulates past
    // it), so every shard's executable set is a strict subset.
    for plan in &design.plans {
        assert!(plan.batcher.max_batch() <= 2, "{:?}", plan.batcher.sizes());
    }
}

#[test]
fn slo_infeasible_designs_error_with_context() {
    let ctx = EvalCtx::for_config(&SystemConfig::default()).threads(2);
    // DeepCaps simulates to ~103 ms/batch at batch 1: a 20 ms SLO is
    // unmeetable and must error out of the design pass, not panic or
    // silently drop the constraint.
    let opts = DesignOptions {
        shards: 1,
        batch_sizes: vec![1, 2],
        slo_s: Some(20e-3),
        flush_deadline_s: 2e-3,
        homogeneous: false,
    };
    let err = design_fleet(&ctx, &[deepcaps_cifar10()], &opts).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("SLO"), "{msg}");
    assert!(msg.contains("unmeetable"), "{msg}");
}

#[test]
fn homogeneous_codesign_shares_one_organization() {
    let ctx = EvalCtx::for_config(&SystemConfig::default()).threads(4);
    let opts = DesignOptions {
        shards: 3,
        batch_sizes: vec![1, 2],
        slo_s: None,
        flush_deadline_s: 2e-3,
        homogeneous: true,
    };
    let design =
        design_fleet(&ctx, &[capsnet_mnist(), deepcaps_cifar10()], &opts).expect("design");
    assert_eq!(design.plans.len(), 3);
    let first = design.plans[0].org.label();
    assert!(design.plans.iter().all(|p| p.org.label() == first));
    // Workloads alternate round-robin across shards.
    assert_eq!(design.plans[0].workload, "capsnet");
    assert_eq!(design.plans[1].workload, "deepcaps");
    assert_eq!(design.plans[2].workload, "capsnet");
}
