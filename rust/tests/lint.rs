//! Tier-1 gate for `descnet lint` (ISSUE 9, DESIGN.md section 16).
//!
//! Two layers:
//!
//! * `tree_is_clean` lints the repo's own sources and asserts zero
//!   findings — there is no baseline file, so any new violation fails this
//!   test (and the CI step) until it is fixed or annotated with a reason;
//! * one fixture pair per rule R1–R6: a positive fixture the rule must
//!   flag, a negative fixture it must leave alone, and checks that the
//!   `lint: allow(rule, reason)` annotation is the only working
//!   suppression (reason mandatory, malformed allows are themselves
//!   findings and suppress nothing).
//!
//! Fixture sources live in string literals, which the analyzer's own lexer
//! strips — so this file stays clean under the tree-wide scan above.

use descnet::analysis::{lint_source, lint_tree, Finding};

fn ids(findings: &[Finding]) -> Vec<&'static str> {
    findings.iter().map(|f| f.rule.id).collect()
}

/// Findings for `src` under module path `module`.
fn run(module: &str, src: &str) -> Vec<Finding> {
    lint_source(module, "fixture.rs", src).0
}

/// Suppression count for `src` under module path `module`.
fn suppressed(module: &str, src: &str) -> usize {
    lint_source(module, "fixture.rs", src).2
}

// ---------------------------------------------------------------- tree gate

#[test]
fn tree_is_clean() {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    let report = lint_tree(root).expect("lint walks the tree");
    assert!(
        report.is_clean(),
        "descnet lint found violations — fix them or annotate with \
         `lint: allow(rule, reason)`:\n{}",
        report.to_text()
    );
    // Sanity: the walk really covered the tree, and the bootstrap
    // annotations (PR 9) are being honored rather than ignored.
    assert!(report.files >= 30, "only {} files scanned", report.files);
    assert!(report.lines >= 10_000, "only {} lines lexed", report.lines);
    assert!(
        report.suppressed >= 10,
        "only {} suppressions honored — annotations not being parsed?",
        report.suppressed
    );
    let summary = report.summary();
    assert!(summary.starts_with("lint: 0 findings"), "summary was: {summary}");
    // The JSON report embeds the same summary line CI greps for.
    assert!(report.to_json().to_string_pretty().contains("lint: 0 findings"));
}

// ------------------------------------------------------------- R1: nan_cmp

#[test]
fn r1_nan_cmp_positive() {
    let f = run(
        "report",
        "pub fn sort(xs: &mut [f64]) { xs.sort_by(|a, b| a.partial_cmp(b).unwrap()); }\n",
    );
    assert_eq!(ids(&f), vec!["nan_cmp"], "findings: {f:?}");
    assert_eq!(f[0].line, 1);
}

#[test]
fn r1_nan_cmp_negative_total_cmp() {
    let f = run(
        "report",
        "pub fn sort(xs: &mut [f64]) { xs.sort_by(|a, b| a.total_cmp(b)); }\n",
    );
    assert!(f.is_empty(), "findings: {f:?}");
}

#[test]
fn r1_nan_cmp_negative_identifier_boundary() {
    // `my_partial_cmp_like` must not match the `partial_cmp` token.
    let f = run("report", "pub fn f() { my_partial_cmp_like(); }\n");
    assert!(f.is_empty(), "findings: {f:?}");
}

#[test]
fn r1_nan_cmp_allow_with_reason_suppresses() {
    let src = "// lint: allow(nan_cmp, \"delegates to a total Ord impl\")\n\
               fn partial_cmp(&self, o: &Self) -> Option<Ordering> { Some(self.cmp(o)) }\n";
    assert!(run("fleet", src).is_empty());
    assert_eq!(suppressed("fleet", src), 1);
}

// --------------------------------------------------------- R2: debug_guard

#[test]
fn r2_debug_guard_positive_in_dse() {
    let f = run("dse::evaluate", "debug_assert!(sh <= cap, \"must fit\");\n");
    assert_eq!(ids(&f), vec!["debug_guard"], "findings: {f:?}");
}

#[test]
fn r2_debug_guard_negative_outside_scope() {
    // The same guard in a reporting module is out of R2 scope.
    let f = run("report", "debug_assert!(sh <= cap, \"must fit\");\n");
    assert!(f.is_empty(), "findings: {f:?}");
}

#[test]
fn r2_debug_guard_negative_always_on_assert() {
    let f = run("dse::evaluate", "assert!(sh <= cap, \"must fit\");\n");
    assert!(f.is_empty(), "findings: {f:?}");
}

#[test]
fn r2_debug_guard_allow_with_reason_suppresses() {
    let src = "// lint: allow(debug_guard, \"re-checked by ensure! at the api boundary\")\n\
               debug_assert_eq!(a, b);\n";
    assert!(run("sim", src).is_empty());
    assert_eq!(suppressed("sim", src), 1);
}

// -------------------------------------------------------- R3: hash_collect

#[test]
fn r3_hash_collect_positive() {
    let f = run("util", "use std::collections::HashMap;\n");
    assert_eq!(ids(&f), vec!["hash_collect"], "findings: {f:?}");
}

#[test]
fn r3_hash_collect_negative_btreemap() {
    let f = run("util", "use std::collections::BTreeMap;\n");
    assert!(f.is_empty(), "findings: {f:?}");
}

#[test]
fn r3_hash_collect_allow_on_same_line() {
    let src = "use std::collections::HashMap; // lint: allow(hash_collect, \"memo, never iterated\")\n";
    assert!(run("cacti::cache", src).is_empty());
    assert_eq!(suppressed("cacti::cache", src), 1);
}

// ---------------------------------------------------------- R3: wall_clock

#[test]
fn r3_wall_clock_positive() {
    let f = run("coordinator::request", "let t = Instant::now();\n");
    assert_eq!(ids(&f), vec!["wall_clock"], "findings: {f:?}");
}

#[test]
fn r3_wall_clock_builtin_allowlist() {
    // util::bench and coordinator::server are the built-in timing sites.
    assert!(run("util::bench", "let t = Instant::now();\n").is_empty());
    assert!(run("coordinator::server", "let t = SystemTime::now();\n").is_empty());
}

#[test]
fn r3_wall_clock_negative_type_position() {
    // Naming the type (a field declaration) is fine; only reads are flagged.
    let f = run("coordinator::request", "pub enqueued: Instant,\n");
    assert!(f.is_empty(), "findings: {f:?}");
}

// -------------------------------------------------------- R3: ambient_rand

#[test]
fn r3_ambient_rand_positive() {
    let f = run("dse::heuristic", "let mut rng = rand::thread_rng();\n");
    assert_eq!(ids(&f), vec!["ambient_rand"], "findings: {f:?}");
}

#[test]
fn r3_ambient_rand_negative_boundary_and_prng() {
    // `operand::` must not match `rand::`; util::prng is the seeded home.
    assert!(run("energy", "let w = operand::width();\n").is_empty());
    assert!(run("util::prng", "pub fn from_rand_seed() {}\n").is_empty());
}

// ---------------------------------------------------------- R4: hot_unwrap

#[test]
fn r4_hot_unwrap_positive_in_fleet() {
    let f = run("fleet", "let x = v.last().unwrap();\n");
    assert_eq!(ids(&f), vec!["hot_unwrap"], "findings: {f:?}");
}

#[test]
fn r4_hot_unwrap_positive_expect() {
    let f = run("pmu", "let x = v.last().expect(\"non-empty\");\n");
    assert_eq!(ids(&f), vec!["hot_unwrap"], "findings: {f:?}");
}

#[test]
fn r4_hot_unwrap_negative_outside_scope() {
    let f = run("report", "let x = v.last().unwrap();\n");
    assert!(f.is_empty(), "findings: {f:?}");
}

#[test]
fn r4_hot_unwrap_allow_with_reason_suppresses() {
    let src = "// lint: allow(hot_unwrap, \"non-empty by construction: checked above\")\n\
               let x = v.last().unwrap();\n";
    assert!(run("fleet", src).is_empty());
    assert_eq!(suppressed("fleet", src), 1);
}

#[test]
fn r4_hot_unwrap_exempt_under_cfg_test() {
    let src = "#[cfg(test)]\n\
               mod tests {\n\
                   #[test]\n\
                   fn t() { let x = v.last().unwrap(); }\n\
               }\n";
    assert!(run("fleet", src).is_empty());
}

// ------------------------------------------------------ R5: unordered_fold

#[test]
fn r5_unordered_fold_positive_single_line() {
    let f = run("energy", "let total: f64 = map.values().sum();\n");
    assert_eq!(ids(&f), vec!["unordered_fold"], "findings: {f:?}");
}

#[test]
fn r5_unordered_fold_positive_multi_line_statement() {
    // The reduction split across lines still matches (statement buffer).
    let src = "let total: f64 = map\n    .values()\n    .map(|v| v.energy)\n    .sum();\n";
    let f = run("dse::evaluate", src);
    assert_eq!(ids(&f), vec!["unordered_fold"], "findings: {f:?}");
}

#[test]
fn r5_unordered_fold_negative_ordered_iterator() {
    let f = run("energy", "let total: f64 = xs.iter().sum();\n");
    assert!(f.is_empty(), "findings: {f:?}");
}

#[test]
fn r5_unordered_fold_negative_outside_scope() {
    // Only the accumulation-order-contracted modules are in R5 scope.
    let f = run("report", "let total: f64 = map.values().sum();\n");
    assert!(f.is_empty(), "findings: {f:?}");
}

#[test]
fn r5_unordered_fold_statement_boundary_resets() {
    // `.values()` in one statement, `.sum()` in the next: no match.
    let src = "let ks: Vec<_> = map.values().collect();\nlet t: f64 = ks.iter().map(|v| v.e).sum();\n";
    let f = run("energy", src);
    assert!(f.is_empty(), "findings: {f:?}");
}

// -------------------------------------------------------- R6: ctx_bypass

#[test]
fn r6_ctx_bypass_positive_in_dse() {
    let f = run("dse::stream", "let engine = Engine::new(threads);\n");
    assert_eq!(ids(&f), vec!["ctx_bypass"], "findings: {f:?}");
}

#[test]
fn r6_ctx_bypass_positive_auto_in_report() {
    let f = run("report", "let points = Engine::auto().map(&orgs, eval);\n");
    assert_eq!(ids(&f), vec!["ctx_bypass"], "findings: {f:?}");
}

#[test]
fn r6_ctx_bypass_negative_outside_scope() {
    // The context layer and the engine's own module construct engines by
    // design; so may anything outside the evaluation stack.
    assert!(run("ctx", "self.engine = Engine::new(n);\n").is_empty());
    assert!(run("util::exec", "let e = Engine::auto();\n").is_empty());
    assert!(run("coordinator::server", "let e = Engine::new(2);\n").is_empty());
}

#[test]
fn r6_ctx_bypass_negative_ctx_accessor() {
    // Going through the context is the sanctioned path.
    let f = run("dse", "let points = ctx.engine().map(&orgs, eval);\n");
    assert!(f.is_empty(), "findings: {f:?}");
}

#[test]
fn r6_ctx_bypass_allow_with_reason_suppresses() {
    let src = "// lint: allow(ctx_bypass, \"one-off probe engine, never fingerprinted\")\n\
               let engine = Engine::new(1);\n";
    assert!(run("fleet", src).is_empty());
    assert_eq!(suppressed("fleet", src), 1);
}

#[test]
fn r6_ctx_bypass_exempt_under_cfg_test() {
    let src = "#[cfg(test)]\n\
               mod tests {\n\
                   #[test]\n\
                   fn t() { let e = Engine::new(4); }\n\
               }\n";
    assert!(run("dse", src).is_empty());
}

// ------------------------------------------------- suppression grammar (R0)

#[test]
fn allow_without_reason_is_malformed_and_suppresses_nothing() {
    let src = "let x = v.last().unwrap(); // lint: allow(hot_unwrap)\n";
    let (f, _, s) = lint_source("fleet", "fixture.rs", src);
    let mut got = ids(&f);
    got.sort_unstable();
    // The original finding stands AND the malformed allow is reported.
    assert_eq!(got, vec!["allow_syntax", "hot_unwrap"], "findings: {f:?}");
    assert_eq!(s, 0);
}

#[test]
fn allow_for_wrong_rule_does_not_suppress() {
    let src = "// lint: allow(nan_cmp, \"wrong rule for this site\")\n\
               let x = v.last().unwrap();\n";
    let f = run("fleet", src);
    assert_eq!(ids(&f), vec!["hot_unwrap"], "findings: {f:?}");
}

#[test]
fn allow_in_string_literal_is_inert() {
    // Annotations only count inside comments; string contents are stripped.
    let src = "let s = \"lint: allow(hot_unwrap, reason)\";\nlet x = v.last().unwrap();\n";
    let f = run("fleet", src);
    assert_eq!(ids(&f), vec!["hot_unwrap"], "findings: {f:?}");
}
