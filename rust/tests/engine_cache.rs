//! Integration: the shared execution engine and the memoized CACTI cost
//! cache across layers (ISSUE 1 acceptance criteria).
//!
//! * `dse::run` output is identical — orgs and bit-exact (area, energy) —
//!   for threads=1 and threads=N through the engine;
//! * the cost cache is exercised by both the DSE fast path and the
//!   energy/pmu reporting path (hit counters observed to advance).

use descnet::cacti::cache;
use descnet::config::{Accelerator, Technology};
use descnet::ctx::EvalCtx;
use descnet::dataflow::{profile_network, NetworkProfile};
use descnet::dse;
use descnet::energy;
use descnet::memory::{MemSpec, Organization};
use descnet::model::capsnet_mnist;
use descnet::pmu;
use descnet::sim;
use descnet::util::units::KIB;

fn profile() -> NetworkProfile {
    profile_network(&capsnet_mnist(), &Accelerator::default())
}

fn timeline(p: &NetworkProfile) -> sim::Timeline {
    sim::Timeline::build(p, &Technology::default(), &Accelerator::default())
}

fn ctx(threads: usize) -> EvalCtx {
    EvalCtx::new(Technology::default(), Accelerator::default()).threads(threads)
}

#[test]
fn dse_points_bit_identical_across_thread_counts() {
    let p = profile();
    let orgs = dse::enumerate(&p).unwrap();
    let tl = timeline(&p);
    let serial = dse::evaluate_all(&ctx(1), &orgs, &p, &tl);
    for threads in [2usize, 5] {
        let parallel = dse::evaluate_all(&ctx(threads), &orgs, &p, &tl);
        assert_eq!(serial.len(), parallel.len());
        for (a, b) in serial.iter().zip(&parallel) {
            assert_eq!(a.org, b.org, "threads={threads}");
            assert_eq!(
                a.area_mm2.to_bits(),
                b.area_mm2.to_bits(),
                "area differs for {} at threads={threads}",
                a.org.label()
            );
            assert_eq!(
                a.energy_j.to_bits(),
                b.energy_j.to_bits(),
                "energy differs for {} at threads={threads}",
                a.org.label()
            );
            assert_eq!(
                a.latency_s.to_bits(),
                b.latency_s.to_bits(),
                "latency differs for {} at threads={threads}",
                a.org.label()
            );
        }
    }
}

#[test]
fn full_dse_pipeline_identical_across_engines() {
    let p = profile();
    let res1 = dse::run(&ctx(1), &p).unwrap();
    let res8 = dse::run(&ctx(8), &p).unwrap();
    assert_eq!(res1.points.len(), res8.points.len());
    assert_eq!(res1.pareto, res8.pareto);
    assert_eq!(res1.selected, res8.selected);
    for (a, b) in res1.points.iter().zip(&res8.points) {
        assert_eq!(a.org, b.org);
        assert_eq!(a.area_mm2.to_bits(), b.area_mm2.to_bits());
        assert_eq!(a.energy_j.to_bits(), b.energy_j.to_bits());
    }
}

#[test]
fn cost_cache_is_shared_by_dse_and_energy_pmu_layers() {
    let tech = Technology::default();
    let p = profile();
    // Table I SEP-PG geometries, warmed through the DSE fast path first.
    let org = Organization::sep(
        MemSpec::new(25 * KIB, 2),
        MemSpec::new(64 * KIB, 8),
        MemSpec::new(32 * KIB, 2),
    );
    let orgs = vec![org.clone()];
    let tl = timeline(&p);
    let touched_before = cache::global().hits() + cache::global().misses();
    let points = dse::evaluate_all(&ctx(1), &orgs, &p, &tl);
    let touched_after = cache::global().hits() + cache::global().misses();
    assert!(
        touched_after > touched_before,
        "DSE evaluation did not go through the cost cache"
    );
    assert!(!cache::global().is_empty());

    // The reporting layers must now *hit* the same entries (same geometry
    // keys), and their numbers must agree with the fast path's.
    let hits_before = cache::global().hits();
    let rollup = energy::evaluate_org(&org, &p, &tech).unwrap();
    let pmu_report = pmu::evaluate(&org, &p, &tech).unwrap();
    assert!(
        cache::global().hits() > hits_before,
        "energy/pmu reporting did not hit the shared cache"
    );
    assert!((rollup.energy_j() - points[0].energy_j).abs() <= points[0].energy_j * 1e-12);
    assert!(pmu_report.static_energy_j() > 0.0);
}
