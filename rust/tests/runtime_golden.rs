//! Integration: the rust PJRT execution path is pinned numerically against
//! the python session that lowered the artifacts (golden_capsnet.json), and
//! the per-stage artifacts compose to the fused full net.
//!
//! These tests are skipped (not failed) when `make artifacts` has not run.

use std::path::PathBuf;

use descnet::runtime::{argmax_per_row, Runtime};
use descnet::util::json::Json;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn runtime() -> Option<Runtime> {
    if !artifacts_dir().join("manifest.json").exists() {
        eprintln!("artifacts not built; skipping");
        return None;
    }
    Some(Runtime::new(&artifacts_dir()).expect("runtime"))
}

fn golden() -> Option<(Vec<f32>, Vec<f32>, f64, f64)> {
    let path = artifacts_dir().join("golden_capsnet.json");
    if !path.exists() {
        return None;
    }
    let j = Json::parse_file(&path).expect("golden json");
    let floats = |key: &str| -> Vec<f32> {
        j.get(key)
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap() as f32)
            .collect()
    };
    Some((
        floats("input"),
        floats("lengths"),
        j.get("poses_l2").as_f64().unwrap(),
        j.get("tolerance").as_f64().unwrap(),
    ))
}

#[test]
fn full_net_matches_python_golden() {
    let (Some(mut rt), Some((input, want_lengths, want_l2, tol))) = (runtime(), golden()) else {
        return;
    };
    let (lengths, poses) = rt.infer_full("capsnet", 1, &input).expect("infer");
    assert_eq!(lengths.len(), 10);
    for (i, (&got, &want)) in lengths.iter().zip(&want_lengths).enumerate() {
        assert!(
            (got - want).abs() < tol as f32,
            "class {i}: got {got}, python says {want}"
        );
    }
    let l2: f64 = poses.iter().map(|&v| (v as f64).powi(2)).sum::<f64>().sqrt();
    assert!(
        (l2 - want_l2).abs() / want_l2 < 1e-3,
        "pose L2 {l2} vs {want_l2}"
    );
}

#[test]
fn stage_composition_equals_full_net() {
    let Some(mut rt) = runtime() else { return };
    let Some((input, _, _, _)) = golden() else { return };

    let h = rt
        .load_stage("capsnet", "conv1", 1)
        .unwrap()
        .execute(&input)
        .unwrap()
        .remove(0);
    let u = rt
        .load_stage("capsnet", "primarycaps", 1)
        .unwrap()
        .execute(&h)
        .unwrap()
        .remove(0);
    assert_eq!(u.len(), 1152 * 8);
    let staged = rt
        .load_stage("capsnet", "classcaps", 1)
        .unwrap()
        .execute(&u)
        .unwrap()
        .remove(0);
    let (full, _) = rt.infer_full("capsnet", 1, &input).unwrap();
    for (i, (a, b)) in staged.iter().zip(&full).enumerate() {
        assert!((a - b).abs() < 5e-4, "class {i}: staged {a} vs full {b}");
    }
}

#[test]
fn batched_execution_is_row_consistent() {
    let Some(mut rt) = runtime() else { return };
    let Some((input, _, _, _)) = golden() else { return };
    let batches = rt.manifest.batches("capsnet", "full");
    let Some(&b) = batches.iter().find(|&&b| b > 1) else {
        return;
    };
    // Same image replicated across the batch -> identical rows.
    let mut batched = Vec::new();
    for _ in 0..b {
        batched.extend_from_slice(&input);
    }
    let (lengths, _) = rt.infer_full("capsnet", b, &batched).unwrap();
    assert_eq!(lengths.len(), b * 10);
    let first = &lengths[..10];
    for row in 1..b {
        for k in 0..10 {
            assert!(
                (lengths[row * 10 + k] - first[k]).abs() < 1e-5,
                "row {row} class {k}"
            );
        }
    }
    let classes = argmax_per_row(&lengths, 10);
    assert!(classes.windows(2).all(|w| w[0] == w[1]));
}

#[test]
fn deepcaps_lite_artifact_executes() {
    let Some(mut rt) = runtime() else { return };
    if rt.manifest.stage("deepcaps_lite", "full", 1).is_none() {
        return;
    }
    let entry = rt
        .manifest
        .stage("deepcaps_lite", "full", 1)
        .unwrap()
        .clone();
    let n = entry.inputs[0].elements();
    let input: Vec<f32> = (0..n).map(|i| (i % 255) as f32 / 255.0).collect();
    let (lengths, poses) = rt.infer_full("deepcaps_lite", 1, &input).unwrap();
    assert_eq!(lengths.len(), 10);
    assert!(lengths.iter().all(|v| v.is_finite() && *v >= 0.0));
    assert!(poses.iter().all(|v| v.is_finite()));
}

#[test]
fn executing_with_wrong_input_shape_fails_cleanly() {
    let Some(mut rt) = runtime() else { return };
    let stage = rt.load_stage("capsnet", "full", 1).unwrap();
    let err = stage.execute(&[0.0f32; 17]).unwrap_err();
    assert!(err.to_string().contains("expected"), "{err}");
}
