//! Golden + property tests for the generalized workload layer (ISSUE 2).
//!
//! * Golden: the builder-expressed `capsnet_mnist()` / `deepcaps_cifar10()`
//!   must be bit-identical to the frozen seed definitions — both at the
//!   `Operation` level and through the dataflow model (`OpProfile`
//!   sequences), and the batch-1 batched profile must equal the default
//!   profile exactly.
//! * Property: every generated random network satisfies the
//!   workload-invariant class — profiles are well-formed, working sets fit
//!   the Eq. 1 SMP bound (and the Eq. 2 SEP sizing), off-chip traffic is
//!   consistent with op geometry, and the whole DSE pipeline runs end to
//!   end on it.

use descnet::config::{Accelerator, Technology};
use descnet::ctx::EvalCtx;
use descnet::dataflow::{profile_network, profile_network_batched};
use descnet::dse;
use descnet::dse::multi::WorkloadSet;
use descnet::memory::{org_fits, MemSpec, Organization};
use descnet::model::seed::{capsnet_mnist_seed, deepcaps_cifar10_seed};
use descnet::model::{capsnet_mnist, deepcaps_cifar10, random_network, spec, OpKind};
use descnet::util::json::Json;

// --------------------------------------------------------------- golden

#[test]
fn builder_networks_match_seed_ops_bit_identically() {
    let pairs = [
        (capsnet_mnist(), capsnet_mnist_seed()),
        (deepcaps_cifar10(), deepcaps_cifar10_seed()),
    ];
    for (built, seed) in &pairs {
        assert_eq!(built.name, seed.name);
        assert_eq!(built.dataset, seed.dataset);
        assert_eq!(built.paper_fps, seed.paper_fps);
        assert_eq!(built.ops.len(), seed.ops.len());
        for (b, s) in built.ops.iter().zip(&seed.ops) {
            assert_eq!(b, s, "operation '{}' diverged from seed", s.name);
        }
    }
}

#[test]
fn builder_profiles_match_seed_profiles_bit_identically() {
    let accel = Accelerator::default();
    for (built, seed) in [
        (capsnet_mnist(), capsnet_mnist_seed()),
        (deepcaps_cifar10(), deepcaps_cifar10_seed()),
    ] {
        let pb = profile_network(&built, &accel);
        let ps = profile_network(&seed, &accel);
        assert_eq!(pb.ops.len(), ps.ops.len());
        for (a, b) in pb.ops.iter().zip(&ps.ops) {
            assert_eq!(a, b, "OpProfile '{}' diverged from seed", b.name);
        }
        assert_eq!(pb.total_cycles(), ps.total_cycles());
        assert_eq!(pb.fps().to_bits(), ps.fps().to_bits());
    }
}

#[test]
fn batch_one_profiles_bit_identical_to_seed_profiles() {
    let accel = Accelerator::default();
    for seed_net in [capsnet_mnist_seed(), deepcaps_cifar10_seed()] {
        let reference = profile_network(&seed_net, &accel);
        let batched = profile_network_batched(&seed_net, &accel, 1);
        assert_eq!(reference, batched, "{}", seed_net.name);
    }
}

#[test]
fn workload_spec_file_reproduces_builtin_capsnet() {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("configs/workloads/capsnet_mnist.json");
    let spec = spec::load(&path).unwrap();
    assert_eq!(spec.networks.len(), 1);
    assert_eq!(spec.networks[0].ops, capsnet_mnist().ops);
}

#[test]
fn edge_serving_mix_spec_loads_three_networks_with_weights() {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("configs/workloads/edge_serving_mix.json");
    let spec = spec::load(&path).unwrap();
    assert_eq!(spec.networks.len(), 3);
    let weights = spec.weights.clone().unwrap();
    assert_eq!(weights.len(), 3);
    // The set is usable end to end: union-sized enumeration is non-empty.
    let accel = Accelerator::default();
    let profiles = spec
        .networks
        .iter()
        .map(|n| profile_network(n, &accel))
        .collect();
    let set = WorkloadSet::with_weights(profiles, weights).unwrap();
    assert!(!dse::multi::enumerate(&set).unwrap().is_empty());
}

#[test]
fn malformed_spec_reports_error_with_path_context() {
    let dir = std::env::temp_dir().join("descnet_builder_golden");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("broken.json");
    std::fs::write(
        &path,
        r#"{"name": "broken", "input": [5, 5, 1],
           "layers": [{"type": "conv", "name": "C", "out_channels": 8,
                       "kernel": 9, "padding": "valid"}]}"#,
    )
    .unwrap();
    let err = spec::load(&path).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("broken.json"), "{msg}");
    assert!(msg.contains("exceeds input extent"), "{msg}");
}

// ------------------------------------------------------------- properties

#[test]
fn random_networks_satisfy_workload_invariants() {
    let accel = Accelerator::default();
    for seed in 0..40 {
        let net = random_network(seed);
        let p = profile_network(&net, &accel);
        assert!(p.total_cycles() > 0, "seed {seed}");
        assert!(p.fps() > 0.0 && p.fps().is_finite(), "seed {seed}");

        // Eq. 1 / Eq. 2 consistency.
        assert!(p.max_total() >= p.max_d().max(p.max_w()).max(p.max_a()));
        assert!(p.max_total() <= p.max_d() + p.max_w() + p.max_a());

        // Working sets fit the SMP bound: the Eq. 1-sized SMP organization
        // always holds every operation.
        let smp = Organization::smp(MemSpec::new(dse::smp_size(&p), 1));
        assert!(org_fits(&smp, &p), "seed {seed}: SMP bound violated");
        // ...and the Eq. 2-sized SEP organization holds every class.
        let (d, w, a) = dse::sep_sizes(&p);
        let sep = Organization::sep(
            MemSpec::new(d.max(1), 1),
            MemSpec::new(w.max(1), 1),
            MemSpec::new(a.max(1), 1),
        );
        assert!(org_fits(&sep, &p), "seed {seed}: SEP sizing violated");

        for (op, prof) in net.ops.iter().zip(&p.ops) {
            assert!(prof.cycles > 0, "seed {seed}: {} zero cycles", prof.name);
            // Off-chip traffic consistent with op geometry.
            match &op.kind {
                OpKind::Conv2d { .. } => {
                    // Eq. 3: conv reads = fmap fill + weight fill.
                    assert_eq!(
                        prof.off_rd,
                        prof.wr_d + prof.wr_w,
                        "seed {seed}: {}",
                        prof.name
                    );
                    assert!(prof.off_rd >= op.param_bytes(), "seed {seed}: {}", prof.name);
                }
                OpKind::Votes { votes_in_acc, .. } => {
                    assert!(prof.off_rd > 0, "seed {seed}: {}", prof.name);
                    if *votes_in_acc {
                        assert_eq!(prof.off_wr, 0, "seed {seed}: {}", prof.name);
                    }
                }
                OpKind::Routing { .. } => {
                    // Routing touches DRAM only at phase boundaries.
                    assert!(
                        prof.off_rd == 0 || prof.name.contains("Sum+Squash1"),
                        "seed {seed}: {} mid-routing DRAM read",
                        prof.name
                    );
                }
            }
        }
    }
}

#[test]
fn random_networks_run_through_the_full_dse_pipeline() {
    let accel = Accelerator::default();
    let ctx = EvalCtx::new(Technology::default(), accel.clone()).threads(4);
    for seed in [1u64, 11, 29] {
        let net = random_network(seed);
        let p = profile_network(&net, &accel);
        let res =
            dse::run(&ctx, &p).unwrap_or_else(|e| panic!("seed {seed}: {e:#}"));
        assert!(!res.points.is_empty(), "seed {seed}");
        assert!(!res.pareto.is_empty(), "seed {seed}");
        assert!(!res.selected.is_empty(), "seed {seed}");
        for (_, i) in &res.selected {
            assert!(org_fits(&res.points[*i].org, &p), "seed {seed}");
        }
    }
}

#[test]
fn random_networks_batch_profiles_amortize() {
    let accel = Accelerator::default();
    for seed in [2u64, 17] {
        let net = random_network(seed);
        let b1 = profile_network_batched(&net, &accel, 1);
        let b8 = profile_network_batched(&net, &accel, 8);
        assert!(b8.fps() >= b1.fps(), "seed {seed}");
        // Working sets stay batch-invariant, so the same orgs fit.
        assert_eq!(dse::sep_sizes(&b1), dse::sep_sizes(&b8), "seed {seed}");
        assert_eq!(dse::smp_size(&b1), dse::smp_size(&b8), "seed {seed}");
    }
}

#[test]
fn three_network_codesign_acceptance() {
    // The ISSUE 2 acceptance shape: a >= 3-network workload set emits a
    // single co-designed organization with per-network energy.
    let accel = Accelerator::default();
    let nets = [capsnet_mnist(), deepcaps_cifar10(), random_network(5)];
    let profiles = nets.iter().map(|n| profile_network(n, &accel)).collect();
    let set = WorkloadSet::new(profiles).unwrap();
    let ctx = EvalCtx::new(Technology::default(), accel).threads(4);
    let res = dse::multi::run(&ctx, &set).unwrap();
    let best = res.codesigned().expect("a co-designed organization");
    let org = &res.points[best].org;
    assert_eq!(res.per_net_j[best].len(), 3);
    for (p, &e) in set.profiles().iter().zip(&res.per_net_j[best]) {
        assert!(org_fits(org, p), "{} unfit for {}", org.label(), p.network);
        assert!(e > 0.0 && e.is_finite());
    }
}

#[test]
fn inline_spec_and_builder_agree_for_a_deepcaps_style_chain() {
    // The JSON front-end and the native builder must be the same IR.
    let text = r#"{
      "name": "mini-deepcaps", "dataset": "x",
      "input": [32, 32, 3],
      "layers": [
        {"type": "conv", "name": "Conv1", "out_channels": 64, "kernel": 3},
        {"type": "primary_caps", "name": "Prim", "types": 8, "caps_dim": 8,
         "kernel": 3, "stride": 2},
        {"type": "caps_cell", "prefix": "Cell0", "types": 8, "caps_dim": 8,
         "stride": 2},
        {"type": "class_caps", "name": "Class", "classes": 10,
         "caps_dim": 16, "iters": 2}
      ]
    }"#;
    let from_spec = spec::network_from_json(&Json::parse(text).unwrap()).unwrap();
    let from_builder = descnet::model::NetBuilder::new("mini-deepcaps", "x")
        .input(32, 32, 3)
        .conv("Conv1", 64, 3, 1, descnet::model::Padding::Same)
        .primary_caps("Prim", 8, 8, 3, 2, descnet::model::Padding::Same)
        .caps_cell("Cell0", 8, 8, 2)
        .class_caps("Class", 10, 16, 2)
        .build()
        .unwrap();
    assert_eq!(from_spec.ops, from_builder.ops);
}
