//! Bit-exactness of the subtree-factored evaluator (ISSUE 7).
//!
//! `dse::evaluate::SubtreeEval` prepares per-sector-option cost tables
//! once per subtree and evaluates each candidate in O(components); the
//! contract is that it returns **exactly the bits** of the per-point
//! reference `dse::evaluate::area_energy_latency` for every organization
//! drawn from the prepared subtree (strategy (a) of the ISSUE: the
//! reference's accumulation is structured into separable per-component
//! accumulators that the tables replay verbatim — see the
//! accumulation-order contract on `area_energy` and DESIGN.md section 14).
//!
//! Covered here:
//! * capsnet + deepcaps at batch 1, capsnet at batch 8 — every subtree,
//!   sampled candidates, all three objectives compared bit-wise;
//! * 20 seeded generator networks at batch 1;
//! * a slow-wakeup regime (`wakeup_latency_s = 0.5`) where exposure is
//!   nonzero and the factored path walks its wake-boundary bitsets;
//! * the `SweepStats` wall-time split: counts stay bit-deterministic
//!   across thread counts while `prep_s`/`eval_s` are merely sane
//!   (wall times are intentionally excluded from all fingerprints).

use descnet::config::{Accelerator, Technology};
use descnet::ctx::EvalCtx;
use descnet::dataflow::{profile_network, profile_network_batched, NetworkProfile};
use descnet::dse::{self, evaluate::SubtreeEval, stream};
use descnet::model::{capsnet_mnist, deepcaps_cifar10, random_networks};
use descnet::sim;

/// Compares the factored evaluation of every `stride`-th candidate of
/// every subtree against the per-point reference, bit-wise.
fn assert_factored_bitwise(p: &NetworkProfile, tech: &Technology, stride: usize, label: &str) {
    let accel = Accelerator::default();
    let tl = sim::Timeline::build(p, tech, &accel);
    let mut batch = Vec::new();
    let mut compared = 0usize;
    for st in stream::subtrees(p).expect("subtree derivation") {
        if st.count() == 0 {
            continue;
        }
        let prep = SubtreeEval::prepare(st.kind(), st.sizes(), st.pools(), p, tech, &tl);
        batch.clear();
        st.materialize_into(&mut batch);
        for (k, org) in batch.iter().enumerate() {
            if k % stride != 0 {
                continue;
            }
            let (fa, fe, fl) = prep.eval(org);
            let (ra, re, rl) = dse::evaluate::area_energy_latency(org, p, tech, &tl);
            assert_eq!(
                fa.to_bits(),
                ra.to_bits(),
                "{label} {}: factored area {fa} != reference {ra}",
                org.label()
            );
            assert_eq!(
                fe.to_bits(),
                re.to_bits(),
                "{label} {}: factored energy {fe} != reference {re}",
                org.label()
            );
            assert_eq!(
                fl.to_bits(),
                rl.to_bits(),
                "{label} {}: factored latency {fl} != reference {rl}",
                org.label()
            );
            compared += 1;
        }
    }
    assert!(compared > 0, "{label}: nothing compared");
}

#[test]
fn factored_matches_reference_bitwise_on_seed_networks() {
    let accel = Accelerator::default();
    let tech = Technology::default();
    let p = profile_network(&capsnet_mnist(), &accel);
    assert_factored_bitwise(&p, &tech, 1, "capsnet");
    let p = profile_network(&deepcaps_cifar10(), &accel);
    assert_factored_bitwise(&p, &tech, 3, "deepcaps");
}

#[test]
fn factored_matches_reference_bitwise_at_batch_8() {
    // The per-inference amortization (batch divisor) must factor
    // identically: the divisor is applied after the combine in both paths.
    let accel = Accelerator::default();
    let tech = Technology::default();
    for net in [capsnet_mnist(), deepcaps_cifar10()] {
        let p = profile_network_batched(&net, &accel, 8);
        assert_factored_bitwise(&p, &tech, 5, &format!("{}@batch8", net.name));
    }
}

#[test]
fn factored_matches_reference_bitwise_on_generated_networks() {
    let accel = Accelerator::default();
    let tech = Technology::default();
    for (k, net) in random_networks(20, 11).iter().enumerate() {
        let p = profile_network(net, &accel);
        assert_factored_bitwise(&p, &tech, 9, &format!("generated #{k} ({})", net.name));
    }
}

#[test]
fn factored_matches_reference_with_exposed_wakeups() {
    // At the paper's 0.072 ns wakeup every boundary charge is 0 and the
    // factored path short-circuits exposure; a 0.5 s wakeup makes every
    // boundary charge positive, so this exercises the wake-boundary
    // bitset union against the reference's per-op walk — including the
    // batch-8 divisor on top of a nonzero exposure.
    let accel = Accelerator::default();
    let mut tech = Technology::default();
    tech.wakeup_latency_s = 0.5;
    let p = profile_network(&capsnet_mnist(), &accel);
    assert_factored_bitwise(&p, &tech, 1, "capsnet-slow-wakeup");
    let p = profile_network_batched(&capsnet_mnist(), &accel, 8);
    assert_factored_bitwise(&p, &tech, 3, "capsnet-slow-wakeup@batch8");
}

#[test]
fn sweep_timing_split_is_sane_and_counts_stay_deterministic() {
    // The new SweepStats wall-time split must be populated and
    // non-negative, but carries no determinism guarantee — every *count*
    // field, by contrast, must stay bit-deterministic across thread
    // counts (the timing fields are deliberately excluded from the
    // comparison, mirroring prune_exact.rs).
    let tech = Technology::default();
    let accel = Accelerator::default();
    let p = profile_network(&capsnet_mnist(), &accel);
    let r1 = dse::run(&EvalCtx::new(tech.clone(), accel.clone()).threads(1), &p).unwrap();
    let r8 = dse::run(&EvalCtx::new(tech, accel).threads(8), &p).unwrap();
    for r in [&r1, &r8] {
        assert!(r.stats.prep_s.is_finite() && r.stats.prep_s >= 0.0);
        assert!(r.stats.eval_s.is_finite() && r.stats.eval_s >= 0.0);
    }
    assert_eq!(r1.stats.enumerated, r8.stats.enumerated);
    assert_eq!(r1.stats.pruned, r8.stats.pruned);
    assert_eq!(r1.stats.evaluated, r8.stats.evaluated);
    assert_eq!(r1.stats.subtrees, r8.stats.subtrees);
    assert_eq!(r1.stats.subtrees_pruned, r8.stats.subtrees_pruned);
    assert_eq!(r1.stats.archive_inserts, r8.stats.archive_inserts);
    assert_eq!(r1.stats.archive_len, r8.stats.archive_len);
    assert_eq!(r1.stats.bound_gap_sum.to_bits(), r8.stats.bound_gap_sum.to_bits());
    assert_eq!(r1.stats.bound_gap_count, r8.stats.bound_gap_count);
}
