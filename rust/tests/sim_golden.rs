//! Golden regression tests for the timeline performance simulator
//! (ISSUE 3): end-to-end latency and stall breakdown pinned for the two
//! paper networks on the paper's DESCNet configurations, the
//! "no performance loss" acceptance (gated == ungated latency), and the
//! structural monotonicities the model must obey (more SPM banks never
//! increase dma-stall cycles; batching never shrinks batch latency).

use descnet::config::{Accelerator, Technology};
use descnet::ctx::EvalCtx;
use descnet::dataflow::{profile_network, profile_network_batched, NetworkProfile};
use descnet::dse;
use descnet::memory::{MemSpec, Organization};
use descnet::model::{capsnet_mnist, deepcaps_cifar10};
use descnet::sim::{simulate, Bound, Timeline};
use descnet::util::units::KIB;

fn capsnet() -> NetworkProfile {
    profile_network(&capsnet_mnist(), &Accelerator::default())
}

fn deepcaps() -> NetworkProfile {
    profile_network(&deepcaps_cifar10(), &Accelerator::default())
}

/// Paper Table I SEP (ungated DESCNet selection for CapsNet).
fn table1_sep() -> Organization {
    Organization::sep(
        MemSpec::new(25 * KIB, 1),
        MemSpec::new(64 * KIB, 1),
        MemSpec::new(32 * KIB, 1),
    )
}

/// Paper Table I HY-PG row (the gated headline selection).
fn table1_hy_pg() -> Organization {
    Organization::hy(
        MemSpec::new(32 * KIB, 2),
        MemSpec::new(25 * KIB, 2),
        MemSpec::new(25 * KIB, 4),
        MemSpec::new(32 * KIB, 2),
        3,
    )
}

// ------------------------------------------------------------ golden pins

#[test]
fn golden_capsnet_latency_and_breakdown() {
    let tech = Technology::default();
    let accel = Accelerator::default();
    let p = capsnet();
    let lp = simulate(&p, &table1_hy_pg(), &tech, &accel).unwrap();
    // End-to-end: the timeline reproduces the analytical cycle count
    // exactly (zero stalls at the paper configuration)...
    assert_eq!(lp.timeline.total_cycles(), p.total_cycles());
    // ...which is the paper's ~116 fps / ~8.6 ms inference.
    let ms = lp.batch_latency_s() * 1e3;
    assert!((ms - 1e3 / 116.0).abs() / (1e3 / 116.0) < 0.05, "{ms} ms");
    // Stall breakdown: all busy, nothing dma- or wakeup-bound.
    let (compute, dma_stall, wakeup_stall) = lp.breakdown_cycles();
    assert_eq!(compute, p.total_cycles());
    assert_eq!(dma_stall, 0);
    assert_eq!(wakeup_stall, 0);
    // The DMA engine is exercised (nonzero trains) yet fully hidden.
    assert!(lp.timeline.ops.iter().any(|o| o.dma_cycles > 0));
    assert!(lp.timeline.ops.iter().all(|o| o.bound() == Bound::Compute));
}

#[test]
fn golden_deepcaps_latency_and_breakdown() {
    let tech = Technology::default();
    let accel = Accelerator::default();
    let p = deepcaps();
    // Table II-class SEP sizing derived from the profile itself.
    let (d, w, a) = dse::sep_sizes(&p);
    let sep = Organization::sep(MemSpec::new(d, 1), MemSpec::new(w, 1), MemSpec::new(a, 1));
    let lp = simulate(&p, &sep, &tech, &accel).unwrap();
    assert_eq!(lp.timeline.total_cycles(), p.total_cycles());
    let ms = lp.batch_latency_s() * 1e3;
    assert!((ms - 1e3 / 9.7).abs() / (1e3 / 9.7) < 0.12, "{ms} ms");
    let (_, dma_stall, wakeup_stall) = lp.breakdown_cycles();
    assert_eq!(dma_stall, 0);
    assert_eq!(wakeup_stall, 0);
}

#[test]
fn golden_no_performance_loss_gated_vs_ungated() {
    // The acceptance criterion: the DESCNet-style gated design shows its
    // energy reduction at *equal* latency to the ungated baseline.
    let tech = Technology::default();
    let accel = Accelerator::default();
    let p = capsnet();
    let ungated = simulate(&p, &table1_sep(), &tech, &accel).unwrap();
    let gated = simulate(&p, &table1_hy_pg(), &tech, &accel).unwrap();
    assert_eq!(
        gated.batch_latency_s().to_bits(),
        ungated.batch_latency_s().to_bits(),
        "gated {} s vs ungated {} s",
        gated.batch_latency_s(),
        ungated.batch_latency_s()
    );
    // And the gated design really does save energy at that equal latency.
    let tl = Timeline::build(&p, &tech, &accel);
    let ctx = EvalCtx::new(tech, accel).threads(2);
    let points = dse::evaluate_all(&ctx, &[table1_sep(), table1_hy_pg()], &p, &tl);
    assert!(points[1].energy_j < points[0].energy_j);
    assert_eq!(points[1].latency_s.to_bits(), points[0].latency_s.to_bits());
}

#[test]
fn golden_stall_breakdown_under_starved_bandwidth() {
    // Perturbed-configuration golden: at 1/128 of the paper bandwidth the
    // weight-heavy fetch stages become dma-bound while the routing body
    // (which never touches DRAM mid-phase) stays compute-bound.
    let mut tech = Technology::default();
    tech.dram_bandwidth_bps = 100e6;
    let accel = Accelerator::default();
    let p = capsnet();
    let tl = Timeline::build(&p, &tech, &accel);
    assert!(tl.total_cycles() > p.total_cycles());
    for name in ["Conv1", "Prim", "Class"] {
        assert_eq!(tl.op(name).unwrap().bound(), Bound::Dma, "{name}");
    }
    for name in ["Class-Sum+Squash2", "Class-Update+Softmax2"] {
        assert_eq!(tl.op(name).unwrap().bound(), Bound::Compute, "{name}");
    }
    // The stall total equals the sum of the per-op exposures, and the
    // per-op identity duration = compute + stall holds everywhere.
    let total_stall: u64 = tl.ops.iter().map(|o| o.dma_stall_cycles).sum();
    assert_eq!(tl.total_cycles(), p.total_cycles() + total_stall);
}

// -------------------------------------------------------- monotonicities

#[test]
fn more_spm_banks_never_increase_dma_stall() {
    // Effective fill bandwidth is min(DRAM, banks x width x clock):
    // adding banks can only relieve the on-chip bottleneck.
    let tech = Technology::default();
    let p = capsnet();
    let mut prev = u64::MAX;
    for banks in [1usize, 2, 4, 8, 16, 32, 64] {
        let mut accel = Accelerator::default();
        accel.spm_banks = banks;
        let tl = Timeline::build(&p, &tech, &accel);
        let stall = tl.dma_stall_cycles();
        assert!(stall <= prev, "banks={banks}: stall {stall} > prev {prev}");
        prev = stall;
    }
    // At very few banks the fill side must actually bottleneck...
    let mut starved = Accelerator::default();
    starved.spm_banks = 1;
    assert!(Timeline::build(&p, &tech, &starved).dma_stall_cycles() > 0);
    // ...and at the paper's 16 banks it never does.
    assert_eq!(Timeline::build(&p, &tech, &Accelerator::default()).dma_stall_cycles(), 0);
}

#[test]
fn batch_latency_is_monotone_and_amortizes_per_inference() {
    let tech = Technology::default();
    let accel = Accelerator::default();
    for net in [capsnet_mnist(), deepcaps_cifar10()] {
        let mut prev_batch_s = 0.0;
        let mut prev_inf_s = f64::INFINITY;
        for b in [1usize, 2, 4, 8] {
            let p = profile_network_batched(&net, &accel, b);
            let tl = Timeline::build(&p, &tech, &accel);
            assert!(
                tl.batch_latency_s() >= prev_batch_s,
                "{} batch {b}",
                net.name
            );
            assert!(
                tl.inference_latency_s() <= prev_inf_s,
                "{} batch {b}",
                net.name
            );
            prev_batch_s = tl.batch_latency_s();
            prev_inf_s = tl.inference_latency_s();
        }
    }
}

// ------------------------------------------- 3-D DSE acceptance criterion

#[test]
fn budgeted_dse_selects_gated_design_at_ungated_latency() {
    // `descnet dse --net capsnet --latency-budget <ms>` end to end at the
    // library layer: a budget just above the simulated inference admits
    // the full enumeration, the per-option selection still contains the
    // gated options, and every selected option reports the identical
    // latency (no performance loss) with HY-PG at the lowest energy.
    let tech = Technology::default();
    let accel = Accelerator::default();
    let p = capsnet();
    let tl = Timeline::build(&p, &tech, &accel);
    let budget = tl.inference_latency_s() * 1.05;
    let ctx = EvalCtx::new(tech, accel)
        .threads(4)
        .latency_budget_s(Some(budget))
        .expect("valid latency budget");
    let res = dse::run(&ctx, &p).unwrap();
    assert_eq!(res.excluded_by_budget, 0);
    let sel: std::collections::BTreeMap<_, _> = res.selected.iter().cloned().collect();
    let hy_pg = &res.points[sel["HY-PG"]];
    let sep = &res.points[sel["SEP"]];
    assert!(hy_pg.energy_j < sep.energy_j);
    for (name, &i) in &sel {
        let pt = &res.points[i];
        assert!(pt.latency_s <= budget, "{name} over budget");
        assert_eq!(
            pt.latency_s.to_bits(),
            hy_pg.latency_s.to_bits(),
            "{name} latency differs from HY-PG"
        );
    }
    // A budget below the simulated latency excludes everything.
    let tight = ctx
        .clone()
        .latency_budget_s(Some(budget / 1e6))
        .expect("valid latency budget");
    let err = dse::run(&tight, &p).unwrap_err();
    assert!(format!("{err:#}").contains("excludes all"));
}
