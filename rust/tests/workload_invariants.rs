//! Property-based invariants across the workload, memory, PMU and DSE
//! substrates, driven by the mini property-test framework
//! (`descnet::util::prop`) over randomized accelerator/technology
//! configurations and randomized organizations.

use descnet::cacti::{Sram, SramConfig};
use descnet::config::{Accelerator, Technology};
use descnet::ctx::EvalCtx;
use descnet::dataflow::profile_network;
use descnet::dse;
use descnet::energy;
use descnet::memory::{cover_op, org_fits, Component, MemSpec, Organization};
use descnet::model::{capsnet_mnist, deepcaps_cifar10};
use descnet::pmu;
use descnet::prop_assert;
use descnet::sim;
use descnet::util::prng::Prng;
use descnet::util::prop::check;

fn random_accel(rng: &mut Prng) -> Accelerator {
    let mut a = Accelerator::default();
    a.clock_hz = rng.f64_range(100e6, 500e6);
    a.window_tci = *rng.choose(&[32usize, 64, 128]);
    a.classcaps_w_tile_caps = *rng.choose(&[16usize, 32, 42, 64]);
    a.routing_act_serial_cycles = rng.range(4, 24) as usize;
    a.op_overhead_cycles = rng.range(0, 256) as usize;
    a
}

fn random_org(rng: &mut Prng, profile: &descnet::dataflow::NetworkProfile) -> Organization {
    // Random HY organization guaranteed to fit: dedicated sizes are random
    // fractions of the SEP sizes, shared takes the worst-case residual.
    let (d, w, a) = dse::sep_sizes(profile);
    let pick = |rng: &mut Prng, max: usize| -> usize {
        let pool = dse::pools::size_pool(max);
        *rng.choose(&pool)
    };
    let (dd, ww, aa) = (pick(rng, d), pick(rng, w), pick(rng, a));
    let shared = dse::hy_shared_size(profile, dd, ww, aa)
        .expect("paper profiles never overflow the probe")
        .max(8 * 1024);
    let sc = |rng: &mut Prng, size: usize| -> usize {
        let pool = dse::pools::sector_pool_with_off(size);
        if pool.is_empty() {
            1
        } else {
            *rng.choose(&pool)
        }
    };
    Organization::hy(
        MemSpec::new(shared, sc(rng, shared)),
        MemSpec::new(dd, sc(rng, dd)),
        MemSpec::new(ww, sc(rng, ww)),
        MemSpec::new(aa, sc(rng, aa)),
        3,
    )
}

#[test]
fn prop_profiles_are_wellformed_for_any_accelerator() {
    check("profiles-wellformed", 40, |rng| {
        let accel = random_accel(rng);
        for net in [capsnet_mnist(), deepcaps_cifar10()] {
            let p = profile_network(&net, &accel);
            prop_assert!(p.total_cycles() > 0);
            prop_assert!(p.fps() > 0.0 && p.fps().is_finite());
            for op in &p.ops {
                prop_assert!(op.cycles > 0, "{} zero cycles", op.name);
                // Accumulating ops (convs, votes, vote sums) must show at
                // least one accumulator transaction per 16-MAC row; the
                // Update+Softmax half works on the b/c state instead.
                if !op.name.contains("Update+Softmax") {
                    prop_assert!(
                        op.rd_a + op.wr_a >= op.macs / 16,
                        "{}: accumulator traffic below MAC floor",
                        op.name
                    );
                }
            }
            // Eq.1 >= max of Eq.2 components; <= their sum.
            prop_assert!(p.max_total() >= p.max_d().max(p.max_w()).max(p.max_a()));
            prop_assert!(p.max_total() <= p.max_d() + p.max_w() + p.max_a());
        }
        Ok(())
    });
}

#[test]
fn prop_random_hy_orgs_fit_and_conserve_coverage() {
    let accel = Accelerator::default();
    let profile = profile_network(&capsnet_mnist(), &accel);
    check("hy-orgs-fit", 60, |rng| {
        let org = random_org(rng, &profile);
        prop_assert!(org_fits(&org, &profile), "org {:?}", org.label());
        for op in &profile.ops {
            let cov = cover_op(&org, op).unwrap();
            prop_assert!(cov.ded_d + cov.sh_d == op.usage_d, "{}", op.name);
            prop_assert!(cov.ded_w + cov.sh_w == op.usage_w, "{}", op.name);
            prop_assert!(cov.ded_a + cov.sh_a == op.usage_a, "{}", op.name);
            prop_assert!(cov.shared_total() <= org.shared.unwrap().size);
        }
        Ok(())
    });
}

#[test]
fn prop_pmu_static_energy_bounded_by_no_pg() {
    let accel = Accelerator::default();
    let profile = profile_network(&capsnet_mnist(), &accel);
    let tech = Technology::default();
    check("pmu-bounds", 60, |rng| {
        let org = random_org(rng, &profile);
        let report = pmu::evaluate(&org, &profile, &tech).unwrap();
        let with_pg = report.static_energy_j();
        let without = report.static_no_pg_j();
        prop_assert!(with_pg > 0.0);
        prop_assert!(
            with_pg <= without * (1.0 + 1e-9),
            "PG increased static energy: {with_pg} > {without}"
        );
        // Lower bound: everything off at the off-leak fraction.
        prop_assert!(with_pg >= without * tech.powergate_off_leak_frac * 0.99);
        prop_assert!(report.wakeup_masked());
        Ok(())
    });
}

#[test]
fn prop_energy_monotone_in_leakage_constant() {
    let accel = Accelerator::default();
    let profile = profile_network(&capsnet_mnist(), &accel);
    check("energy-monotone-leak", 30, |rng| {
        let org = random_org(rng, &profile);
        let mut lo = Technology::default();
        let mut hi = Technology::default();
        let scale = rng.f64_range(1.1, 4.0);
        hi.sram_leak_w_per_byte = lo.sram_leak_w_per_byte * scale;
        lo.sram_leak_w_per_byte *= 0.9;
        let e_lo = energy::evaluate_org(&org, &profile, &lo).unwrap().static_j();
        let e_hi = energy::evaluate_org(&org, &profile, &hi).unwrap().static_j();
        prop_assert!(e_hi > e_lo, "{e_hi} <= {e_lo}");
        Ok(())
    });
}

#[test]
fn prop_sram_model_monotone_everywhere() {
    let tech = Technology::default();
    let sram = Sram::new(&tech);
    check("sram-monotone", 100, |rng| {
        let size = 1usize << rng.range(13, 22); // 8 kiB .. 4 MiB
        let ports = rng.range(1, 3) as usize;
        let a = sram.evaluate(&SramConfig::new(size, ports, 1));
        let bigger = sram.evaluate(&SramConfig::new(size * 2, ports, 1));
        prop_assert!(bigger.area_mm2 > a.area_mm2);
        prop_assert!(bigger.leak_on_w > a.leak_on_w);
        prop_assert!(bigger.access_energy_j > a.access_energy_j);
        let more_ports = sram.evaluate(&SramConfig::new(size, ports + 1, 1));
        prop_assert!(more_ports.area_mm2 > a.area_mm2);
        prop_assert!(more_ports.access_energy_j > a.access_energy_j);
        Ok(())
    });
}

#[test]
fn prop_dse_selection_is_lowest_energy_per_option() {
    let accel = Accelerator::default();
    let profile = profile_network(&capsnet_mnist(), &accel);
    let tech = Technology::default();
    let orgs = dse::enumerate(&profile).unwrap();
    let tl = sim::Timeline::build(&profile, &tech, &accel);
    let ctx = EvalCtx::new(tech, accel).threads(4);
    check("dse-selection", 3, |rng| {
        // Random subsample of the enumeration, selection must be minimal.
        let mut subset = Vec::new();
        for org in &orgs {
            if rng.f64() < 0.05 {
                subset.push(org.clone());
            }
        }
        if subset.is_empty() {
            return Ok(());
        }
        let points = dse::evaluate_all(&ctx, &subset, &profile, &tl);
        for (option, idx) in dse::select_per_option(&points) {
            for p in &points {
                if p.option().label() == option {
                    prop_assert!(
                        points[idx].energy_j <= p.energy_j + 1e-18,
                        "{option}: selected not minimal"
                    );
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_pareto_frontier_sound_and_complete() {
    let accel = Accelerator::default();
    let profile = profile_network(&capsnet_mnist(), &accel);
    let tech = Technology::default();
    let tl = sim::Timeline::build(&profile, &tech, &accel);
    let orgs: Vec<_> = dse::enumerate(&profile).unwrap().into_iter().take(600).collect();
    let ctx = EvalCtx::new(tech, accel).threads(4);
    let points = dse::evaluate_all(&ctx, &orgs, &profile, &tl);
    let front: std::collections::BTreeSet<usize> =
        dse::pareto_indices(&points).into_iter().collect();
    // Soundness: no frontier member dominated. Completeness: every
    // non-member dominated by someone.
    for i in 0..points.len() {
        let dominated = points.iter().enumerate().any(|(j, q)| {
            j != i
                && q.area_mm2 <= points[i].area_mm2
                && q.energy_j <= points[i].energy_j
                && (q.area_mm2 < points[i].area_mm2 || q.energy_j < points[i].energy_j)
        });
        if front.contains(&i) {
            assert!(!dominated, "frontier point {i} is dominated");
        } else {
            assert!(dominated, "non-frontier point {i} not dominated");
        }
    }
}

#[test]
fn prop_required_ports_never_exceed_three() {
    let accel = Accelerator::default();
    let profile = profile_network(&deepcaps_cifar10(), &accel);
    check("ports-bound", 30, |rng| {
        let org = random_org(rng, &profile);
        if !org_fits(&org, &profile) {
            return Ok(());
        }
        let ports = descnet::memory::required_shared_ports(&org, &profile);
        prop_assert!(ports <= 3, "{ports}");
        Ok(())
    });
}

#[test]
fn prop_component_access_split_is_conservative() {
    let accel = Accelerator::default();
    let profile = profile_network(&deepcaps_cifar10(), &accel);
    check("access-split", 30, |rng| {
        let org = random_org(rng, &profile);
        if !org_fits(&org, &profile) {
            return Ok(());
        }
        for op in profile.ops.iter().take(12) {
            let cov = cover_op(&org, op).unwrap();
            let total: f64 = Component::ALL
                .iter()
                .map(|&c| descnet::memory::component_accesses(op, &cov, c))
                .sum();
            let want = op.spm_accesses() as f64;
            prop_assert!(
                (total - want).abs() <= want.max(1.0) * 1e-9,
                "{}: {total} vs {want}",
                op.name
            );
        }
        Ok(())
    });
}
