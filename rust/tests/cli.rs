//! CLI integration tests (ISSUE 3): malformed arguments and workload specs
//! must exit non-zero with an error message — never panic — and the
//! latency-budget path must emit the 3-D Pareto artifacts.
//!
//! The image vendors no `assert_cmd`; `std::process::Command` over the
//! `CARGO_BIN_EXE_descnet` path cargo exports to integration tests is the
//! same harness without the dependency.

use std::path::PathBuf;
use std::process::{Command, Output};

fn descnet(args: &[&str]) -> Output {
    Command::new(env!("CARGO_BIN_EXE_descnet"))
        .args(args)
        .output()
        .expect("spawning the descnet binary")
}

fn stderr(out: &Output) -> String {
    String::from_utf8_lossy(&out.stderr).into_owned()
}

fn stdout(out: &Output) -> String {
    String::from_utf8_lossy(&out.stdout).into_owned()
}

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("descnet_cli_tests").join(name);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Exit code asserted non-zero with a diagnostic, and no panic backtrace.
fn assert_clean_failure(out: &Output, needle: &str) {
    assert!(
        !out.status.success(),
        "expected failure, got success: {}",
        stdout(out)
    );
    let err = stderr(out);
    assert!(err.contains(needle), "stderr missing '{needle}': {err}");
    assert!(!err.contains("panicked"), "CLI panicked: {err}");
    assert!(!err.contains("RUST_BACKTRACE"), "CLI panicked: {err}");
}

#[test]
fn malformed_latency_budget_value_exits_with_usage_error() {
    let out = descnet(&["dse", "--net", "capsnet", "--latency-budget", "fast"]);
    assert_eq!(out.status.code(), Some(2), "stderr: {}", stderr(&out));
    assert_clean_failure(&out, "--latency-budget expects a number");
}

#[test]
fn missing_latency_budget_value_exits_with_usage_error() {
    // `--latency-budget` with no operand parses as a bare switch ("true").
    let out = descnet(&["dse", "--net", "capsnet", "--latency-budget"]);
    assert_eq!(out.status.code(), Some(2));
    assert_clean_failure(&out, "--latency-budget expects a number");
}

#[test]
fn negative_latency_budget_exits_with_usage_error() {
    let out = descnet(&["dse", "--net", "capsnet", "--latency-budget", "-5"]);
    assert_eq!(out.status.code(), Some(2));
    assert_clean_failure(&out, "positive duration");
}

#[test]
fn net_typo_reports_unknown_builtin() {
    let out = descnet(&["dse", "--net", "capsnett", "--threads", "2"]);
    assert_eq!(out.status.code(), Some(2));
    assert_clean_failure(&out, "unknown builtin network 'capsnett'");
}

#[test]
fn malformed_batch_value_is_rejected_not_defaulted() {
    // A typo like `--batch many` must not silently run at batch 1.
    let out = descnet(&["analyze", "--net", "capsnet", "--batch", "many"]);
    assert_eq!(out.status.code(), Some(2));
    assert_clean_failure(&out, "--batch expects a non-negative integer");
}

#[test]
fn malformed_workload_spec_errors_with_context() {
    let dir = tmp_dir("bad_spec");
    let path = dir.join("broken.json");
    std::fs::write(
        &path,
        r#"{"name": "broken", "input": [5, 5, 1],
           "layers": [{"type": "conv", "name": "C", "out_channels": 8,
                       "kernel": 9, "padding": "valid"}]}"#,
    )
    .unwrap();
    let out = descnet(&["dse", "--workload", path.to_str().unwrap(), "--threads", "2"]);
    assert!(!out.status.success());
    assert_clean_failure(&out, "broken.json");
    assert!(stderr(&out).contains("exceeds input extent"), "{}", stderr(&out));
}

#[test]
fn unparseable_workload_json_errors_cleanly() {
    let dir = tmp_dir("bad_json");
    let path = dir.join("not_json.json");
    std::fs::write(&path, "{ this is not json").unwrap();
    let out = descnet(&["dse", "--workload", path.to_str().unwrap()]);
    assert!(!out.status.success());
    assert_clean_failure(&out, "dse failed");
}

#[test]
fn latency_budget_dse_emits_3d_pareto_artifacts() {
    // The acceptance-criterion command: a feasible budget runs the full
    // capsnet sweep, reports the budget, and writes the latency-bearing
    // CSV + selected table.
    let dir = tmp_dir("budget_ok");
    let out = descnet(&[
        "dse",
        "--net",
        "capsnet",
        "--latency-budget",
        "15",
        "--threads",
        "2",
        "--out",
        dir.to_str().unwrap(),
    ]);
    assert!(out.status.success(), "stderr: {}", stderr(&out));
    let text = stdout(&out);
    assert!(text.contains("latency budget 15"), "{text}");
    assert!(text.contains("3-D Pareto"), "{text}");
    assert!(text.contains("Latency [ms]"), "{text}");
    let csv = std::fs::read_to_string(dir.join("fig18_dse_capsnet.csv")).unwrap();
    let header = csv.lines().next().unwrap();
    assert!(header.contains("latency_ms"), "{header}");
    let table = std::fs::read_to_string(dir.join("table1_selected_capsnet.md")).unwrap();
    assert!(table.contains("Latency [ms]"), "{table}");
}

#[test]
fn fleet_smoke_writes_artifacts_and_reports_savings() {
    // The ISSUE 4 acceptance/CI command (request count trimmed for test
    // wall time): deterministic rollups + the baseline comparison line,
    // with fleet.csv/table_fleet.md written.
    let dir = tmp_dir("fleet_ok");
    let out = descnet(&[
        "fleet",
        "--shards",
        "2",
        "--rps",
        "100",
        "--slo-ms",
        "20",
        "--requests",
        "120",
        "--threads",
        "2",
        "--out",
        dir.to_str().unwrap(),
    ]);
    assert!(out.status.success(), "stderr: {}", stderr(&out));
    let text = stdout(&out);
    assert!(text.contains("fleet: 2 shards, policy jsq"), "{text}");
    assert!(text.contains("p99"), "{text}");
    assert!(text.contains("SLO"), "{text}");
    assert!(text.contains("baseline ["), "{text}");
    let csv = std::fs::read_to_string(dir.join("fleet.csv")).unwrap();
    let header = csv.lines().next().unwrap();
    for col in ["p99_ms", "slo_attainment", "energy_per_req_mj", "utilization"] {
        assert!(header.contains(col), "{header}");
    }
    assert!(csv.contains("fleet-baseline"), "{csv}");
    let table = std::fs::read_to_string(dir.join("table_fleet.md")).unwrap();
    assert!(table.contains("E/req [mJ]"), "{table}");
}

#[test]
fn fleet_rejects_unknown_policy_and_malformed_rps() {
    let out = descnet(&["fleet", "--policy", "p2c"]);
    assert_eq!(out.status.code(), Some(2), "stderr: {}", stderr(&out));
    assert_clean_failure(&out, "unknown routing policy");

    let out = descnet(&["fleet", "--rps", "fast"]);
    assert_eq!(out.status.code(), Some(2));
    assert_clean_failure(&out, "--rps expects a number");
}

#[test]
fn fleet_unmeetable_slo_fails_cleanly() {
    let out = descnet(&[
        "fleet",
        "--net",
        "deepcaps",
        "--slo-ms",
        "20",
        "--threads",
        "2",
    ]);
    assert_eq!(out.status.code(), Some(1), "stderr: {}", stderr(&out));
    assert_clean_failure(&out, "unmeetable");
}

#[test]
fn misspelled_flag_is_rejected_not_ignored() {
    // The ISSUE 10 bugfix: `--lateny-budget` used to be silently ignored,
    // running a full *unbudgeted* sweep instead of erroring.
    let out = descnet(&["dse", "--net", "capsnet", "--lateny-budget", "15"]);
    assert_eq!(out.status.code(), Some(2), "stderr: {}", stderr(&out));
    assert_clean_failure(&out, "unknown flag --lateny-budget");
    // The diagnostic lists the command's known set, including the flag
    // the user was reaching for.
    assert!(stderr(&out).contains("--latency-budget"), "{}", stderr(&out));
}

#[test]
fn unknown_flags_are_rejected_per_command() {
    for (cmd, bad) in [
        ("analyze", "--threds"),
        ("fleet", "--polcy"),
        ("report", "--nets"),
        ("headline", "--out"),
        ("serve", "--shards"),
    ] {
        let out = descnet(&[cmd, bad, "x"]);
        assert_eq!(out.status.code(), Some(2), "{cmd} {bad}: {}", stderr(&out));
        assert_clean_failure(&out, "unknown flag ");
        assert!(
            stderr(&out).contains("known flags:"),
            "{cmd} {bad}: {}",
            stderr(&out)
        );
    }
}

#[test]
fn known_flags_still_parse_after_the_unknown_flag_check() {
    // Regression guard: the rejection must not break ordinary flag use.
    let out = descnet(&["headline", "--threads", "2"]);
    assert!(out.status.success(), "stderr: {}", stderr(&out));
}

#[test]
fn infeasible_latency_budget_fails_with_fastest_achievable() {
    let dir = tmp_dir("budget_impossible");
    let out = descnet(&[
        "dse",
        "--net",
        "capsnet",
        "--latency-budget",
        "0.0001",
        "--threads",
        "2",
        "--out",
        dir.to_str().unwrap(),
    ]);
    assert_eq!(out.status.code(), Some(1));
    assert_clean_failure(&out, "excludes all");
    assert!(stderr(&out).contains("fastest achievable"), "{}", stderr(&out));
}
