//! Integration: fault injection in the fleet simulator (ISSUE 8).
//!
//! * conservation: every arrival is counted exactly once as completed or
//!   dropped, under every combination of crashes, timeouts, retries,
//!   hedging and crash policies; timeout retries never exceed the budget
//!   and hedges never exceed one per request;
//! * injection-off bit-identity: a `None` fault config, the inert default
//!   config and an explicit `--mtbf-s inf` config all produce the same
//!   fingerprint byte-for-byte — and it matches the pre-fault golden
//!   (`rust/tests/goldens/fleet_seed7.txt`) when that file is pinned;
//! * determinism: the full design+simulate pipeline with faults armed is
//!   bit-identical for threads=1 vs threads=4 (crash schedules come from
//!   dedicated PRNG streams, independent of the DSE engine);
//! * monotonicity: pinning down more shards never improves p99 or SLO
//!   attainment; granting more timeout retries never completes fewer
//!   requests (summed over seeds).

use std::path::PathBuf;

use descnet::config::SystemConfig;
use descnet::ctx::EvalCtx;
use descnet::fleet::fault::{CrashPolicy, FaultConfig};
use descnet::fleet::{
    design_fleet, simulate, DesignOptions, FleetConfig, RoutingPolicy, ShardPlan,
};
use descnet::model::capsnet_mnist;

/// The exact scenario of the pre-fault golden test (rust/tests/fleet.rs):
/// two synthetic shards, one at 70% speed, JSQ, seed 7.
fn golden_scenario() -> (Vec<ShardPlan>, FleetConfig) {
    let plans = vec![
        ShardPlan::synthetic("wl-a", vec![1, 2, 4], 10e-3, 5e-3, 1.0, 2e-3).unwrap(),
        ShardPlan::synthetic("wl-b", vec![1, 4], 12e-3, 3e-3, 0.7, 2e-3).unwrap(),
    ];
    let cfg = FleetConfig {
        rps: 150.0,
        requests: 500,
        seed: 7,
        policy: RoutingPolicy::Jsq,
        slo_s: Some(50e-3),
        fault: None,
    };
    (plans, cfg)
}

fn faulty_fleet() -> Vec<ShardPlan> {
    (0..4)
        .map(|i| {
            let speed = if i == 3 { 0.5 } else { 1.0 };
            ShardPlan::synthetic("wl", vec![1, 2, 4], 10e-3, 5e-3, speed, 2e-3)
                .unwrap()
                .with_wake_penalty(if i % 2 == 0 { 1e-3 } else { 0.0 })
                .unwrap()
        })
        .collect()
}

#[test]
fn conservation_under_every_fault_combination() {
    let plans = faulty_fleet();
    for seed in [1u64, 7, 23] {
        for policy in [RoutingPolicy::RoundRobin, RoutingPolicy::Jsq] {
            for crash_policy in [CrashPolicy::Requeue, CrashPolicy::Drop] {
                for (timeout_s, retries, hedge_s) in [
                    (None, 0u32, None),
                    (Some(60e-3), 0, None),
                    (Some(60e-3), 2, None),
                    (Some(60e-3), 2, Some(30e-3)),
                    (None, 0, Some(30e-3)),
                ] {
                    let cfg = FleetConfig {
                        rps: 250.0,
                        requests: 800,
                        seed,
                        policy,
                        slo_s: Some(50e-3),
                        fault: Some(FaultConfig {
                            mtbf_s: 0.5,
                            mttr_s: 0.1,
                            timeout_s,
                            retries,
                            hedge_s,
                            fault_seed: seed.wrapping_add(100),
                            crash_policy,
                            pinned_down: Vec::new(),
                        }),
                    };
                    let stats = simulate(&plans, &cfg).expect("fleet simulation");
                    let ctx = format!(
                        "seed {seed} policy {} crash {} timeout {timeout_s:?} \
                         retries {retries} hedge {hedge_s:?}",
                        policy.label(),
                        crash_policy.label(),
                    );
                    assert_eq!(
                        stats.requests + stats.dropped,
                        cfg.requests as u64,
                        "conservation violated ({ctx}): {} completed + {} dropped != {}",
                        stats.requests,
                        stats.dropped,
                        cfg.requests,
                    );
                    assert!(
                        stats.retries <= retries as u64 * cfg.requests as u64,
                        "retry budget exceeded ({ctx}): {} > {} x {}",
                        stats.retries,
                        retries,
                        cfg.requests,
                    );
                    assert!(
                        stats.hedges <= cfg.requests as u64,
                        "more than one hedge per request ({ctx}): {}",
                        stats.hedges,
                    );
                    if timeout_s.is_none() && crash_policy == CrashPolicy::Requeue {
                        assert_eq!(
                            stats.dropped, 0,
                            "requeue-without-timeout must never drop ({ctx})"
                        );
                    }
                    assert!(stats.faults_active, "faults should be active ({ctx})");
                    assert!(stats.crashes > 0, "MTBF 0.5 s drew no crashes ({ctx})");
                    assert!(
                        (0.0..=1.0).contains(&stats.availability),
                        "availability out of range ({ctx}): {}",
                        stats.availability,
                    );
                }
            }
        }
    }
}

#[test]
fn inert_configs_are_bit_identical_and_match_the_golden() {
    let (plans, cfg) = golden_scenario();
    let mut none = simulate(&plans, &cfg).expect("no fault config");
    let mut default = simulate(
        &plans,
        &FleetConfig {
            fault: Some(FaultConfig::default()),
            ..cfg.clone()
        },
    )
    .expect("inert default config");
    // `--mtbf-s inf` from the CLI with every other knob at a non-default
    // (but still inert) value: the gate is is_active(), not equality with
    // the default.
    let mut inf = simulate(
        &plans,
        &FleetConfig {
            fault: Some(FaultConfig {
                mtbf_s: f64::INFINITY,
                mttr_s: 9.0,
                retries: 7,
                fault_seed: 12345,
                crash_policy: CrashPolicy::Drop,
                ..FaultConfig::default()
            }),
            ..cfg.clone()
        },
    )
    .expect("inert inf config");

    let fp = none.fingerprint();
    assert_eq!(fp, default.fingerprint(), "default FaultConfig perturbed the run");
    assert_eq!(fp, inf.fingerprint(), "--mtbf-s inf perturbed the run");
    assert!(!none.faults_active);
    assert_eq!(none.availability, 1.0);
    assert_eq!((none.dropped, none.retries, none.hedges, none.crashes), (0, 0, 0, 0));

    // The fingerprint must also equal the pre-fault golden, when pinned
    // (the golden blesses on first toolchain run; skip while pending).
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/goldens/fleet_seed7.txt");
    let existing = std::fs::read_to_string(&path).unwrap_or_default();
    if existing.is_empty() || existing.starts_with("pending") {
        eprintln!("golden {} not pinned yet; skipping cross-check", path.display());
        return;
    }
    let pinned = existing.lines().next().unwrap_or("");
    assert_eq!(
        pinned, fp,
        "inert-fault run drifted from the pre-fault golden {}",
        path.display()
    );
}

#[test]
fn faulty_pipeline_is_bit_identical_across_thread_counts() {
    let cfg = SystemConfig::default();
    let run = |threads: usize| {
        let ctx = EvalCtx::for_config(&cfg).threads(threads);
        let opts = DesignOptions {
            shards: 2,
            batch_sizes: vec![1, 2],
            slo_s: Some(20e-3),
            flush_deadline_s: 2e-3,
            homogeneous: false,
        };
        let design = design_fleet(&ctx, &[capsnet_mnist()], &opts).expect("fleet design");
        let fcfg = FleetConfig {
            rps: 120.0,
            requests: 200,
            seed: 9,
            policy: RoutingPolicy::Jsq,
            slo_s: Some(20e-3),
            fault: Some(FaultConfig {
                mtbf_s: 1.0,
                mttr_s: 0.2,
                timeout_s: Some(80e-3),
                retries: 2,
                hedge_s: Some(40e-3),
                fault_seed: 5,
                ..FaultConfig::default()
            }),
        };
        let mut stats = simulate(&design.plans, &fcfg).expect("fleet simulation");
        stats.fingerprint()
    };
    assert_eq!(run(1), run(4), "faulty fleet report differs across thread counts");
}

#[test]
fn pinning_down_more_shards_never_improves_the_tail() {
    let plans = faulty_fleet();
    for seed in [1u64, 7] {
        let run = |pinned_down: Vec<usize>| {
            let cfg = FleetConfig {
                rps: 200.0,
                requests: 1_000,
                seed,
                policy: RoutingPolicy::Jsq,
                slo_s: Some(50e-3),
                fault: Some(FaultConfig {
                    pinned_down,
                    ..FaultConfig::default()
                }),
            };
            let mut stats = simulate(&plans, &cfg).expect("fleet simulation");
            (stats.latency.p99(), stats.slo_attainment())
        };
        let (p99_full, att_full) = run(vec![]);
        let (p99_one, att_one) = run(vec![0]);
        let (p99_two, att_two) = run(vec![0, 1]);
        assert!(
            p99_one >= p99_full * (1.0 - 1e-9) && p99_two >= p99_one * (1.0 - 1e-9),
            "seed {seed}: p99 improved as shards went down: {p99_full} -> {p99_one} -> {p99_two}"
        );
        assert!(
            att_one <= att_full + 1e-9 && att_two <= att_one + 1e-9,
            "seed {seed}: attainment improved as shards went down: \
             {att_full} -> {att_one} -> {att_two}"
        );
    }
}

#[test]
fn more_retries_never_complete_fewer_requests() {
    // Crash-heavy fleet with timeouts: retries=0 drops every request whose
    // first copy waits out the timeout; a retry budget re-dispatches them.
    // Compared as a sum over seeds (per-seed event orders legitimately
    // differ once retry events enter the heap).
    let plans = faulty_fleet();
    let completed = |retries: u32| -> u64 {
        [1u64, 7, 23]
            .iter()
            .map(|&seed| {
                let cfg = FleetConfig {
                    rps: 250.0,
                    requests: 600,
                    seed,
                    policy: RoutingPolicy::Jsq,
                    slo_s: Some(50e-3),
                    fault: Some(FaultConfig {
                        mtbf_s: 0.4,
                        mttr_s: 0.15,
                        timeout_s: Some(50e-3),
                        retries,
                        fault_seed: seed.wrapping_add(7),
                        ..FaultConfig::default()
                    }),
                };
                simulate(&plans, &cfg).expect("fleet simulation").requests
            })
            .sum()
    };
    let r0 = completed(0);
    let r2 = completed(2);
    let r5 = completed(5);
    assert!(r2 >= r0, "2 retries completed fewer requests than 0 ({r2} < {r0})");
    assert!(r5 >= r2, "5 retries completed fewer requests than 2 ({r5} < {r2})");
}
