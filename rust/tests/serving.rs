//! Integration: the serving stack end to end (requires artifacts; skipped
//! otherwise) plus threading-free coordinator logic under stress.

use std::path::PathBuf;

use descnet::coordinator::server::{ServeOptions, Server};
use descnet::coordinator::BatchPolicy;
use descnet::prop_assert;
use descnet::util::prop::check;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    artifacts_dir().join("manifest.json").exists()
}

#[test]
fn serve_small_batch_run() {
    if !have_artifacts() {
        eprintln!("artifacts not built; skipping");
        return;
    }
    let opts = ServeOptions {
        artifacts_dir: artifacts_dir(),
        requests: 10,
        batch_max: 4,
        stage_pipeline: false,
        seed: 11,
        slo_s: None,
    };
    let mut stats = Server::run_synthetic(&opts).expect("serve");
    assert_eq!(stats.requests, 10);
    assert!(stats.batches >= 3); // 10 requests with max batch 4
    assert!(stats.latency.p50() > 0.0);
    assert!(stats.energy_j > 0.0);
    assert_eq!(stats.class_histogram.iter().sum::<u64>(), 10);
    let text = stats.summary();
    assert!(text.contains("served 10 requests"));
}

#[test]
fn serve_stage_pipeline_matches_request_count() {
    if !have_artifacts() {
        return;
    }
    let opts = ServeOptions {
        artifacts_dir: artifacts_dir(),
        requests: 6,
        batch_max: 4,
        stage_pipeline: true,
        seed: 12,
        slo_s: None,
    };
    let stats = Server::run_synthetic(&opts).expect("serve staged");
    assert_eq!(stats.requests, 6);
    assert_eq!(
        stats.class_histogram.iter().sum::<u64>(),
        6,
        "every request classified"
    );
}

#[test]
fn serve_is_deterministic_in_classes_for_fixed_seed() {
    if !have_artifacts() {
        return;
    }
    let run = |seed| {
        let opts = ServeOptions {
            artifacts_dir: artifacts_dir(),
            requests: 8,
            batch_max: 4,
            stage_pipeline: false,
            seed,
            slo_s: None,
        };
        Server::run_synthetic(&opts).unwrap().class_histogram
    };
    assert_eq!(run(5), run(5));
}

#[test]
fn prop_batch_plans_never_starve() {
    // Any pending queue is fully drained within ceil(pending/min_size)
    // flush rounds.
    check("no-starvation", 100, |rng| {
        let sizes = vec![1 + rng.below(3) as usize, 4 + rng.below(5) as usize];
        let policy = BatchPolicy::new(sizes, 1e-3).expect("valid sizes");
        let mut pending = rng.below(200) as usize;
        let mut rounds = 0;
        while pending > 0 {
            let served = policy.planned_requests(pending, true);
            prop_assert!(served > 0, "starved with {pending} pending");
            pending -= served;
            rounds += 1;
            prop_assert!(rounds < 300, "too many rounds");
        }
        Ok(())
    });
}
