//! Integration: the paper's headline claims, end to end through the
//! analytical stack (EXPERIMENTS.md records the same numbers).
//!
//! Each test cites the claim it reproduces.

use descnet::config::{Accelerator, SystemConfig, Technology};
use descnet::ctx::EvalCtx;
use descnet::dataflow::profile_network;
use descnet::dse;
use descnet::energy::{self, system_with_org};
use descnet::memory::prefetch;
use descnet::model::{capsnet_mnist, deepcaps_cifar10, LayerGroup};
use descnet::report::{self, ReportCtx};
use descnet::util::units::{KIB, MIB};

fn selected(
    res: &dse::DseResult,
) -> std::collections::BTreeMap<String, descnet::dse::DsePoint> {
    res.selected
        .iter()
        .map(|(k, i)| (k.clone(), res.points[*i].clone()))
        .collect()
}

fn ctx8() -> EvalCtx {
    EvalCtx::new(Technology::default(), Accelerator::default()).threads(8)
}

#[test]
fn table_i_selected_configurations() {
    // "TABLE I: Selected memory configurations for the CapsNet": SEP =
    // 25/64/32 kiB, SMP = 108 kiB; HY shared+dedicated in the same ranges.
    let accel = Accelerator::default();
    let p = profile_network(&capsnet_mnist(), &accel);
    let res = dse::run(&ctx8(), &p).unwrap();
    let sel = selected(&res);

    let sep = &sel["SEP"].org;
    assert_eq!(sep.data.unwrap().size, 25 * KIB);
    assert_eq!(sep.weight.unwrap().size, 64 * KIB);
    assert_eq!(sep.acc.unwrap().size, 32 * KIB);

    let smp = &sel["SMP"].org;
    assert_eq!(smp.shared.unwrap().size, 108 * KIB);

    // Paper HY row: shared 25k, data 8k, weight 32k, acc 16k.  Our selection
    // rule reproduces the shared/data/weight sizes; acc may differ by one
    // pool step.
    let hy = &sel["HY"].org;
    assert_eq!(hy.shared.unwrap().size, 25 * KIB);
    assert_eq!(hy.data.unwrap().size, 8 * KIB);
    assert_eq!(hy.weight.unwrap().size, 32 * KIB);
    assert!(hy.acc.unwrap().size <= 16 * KIB);
}

#[test]
fn table_ii_selected_configurations() {
    // "TABLE II": SEP = 256 kiB / 128 kiB / 8 MiB (our weight pool admits
    // the 108 kiB random size below 128 kiB), SMP = 8 MiB.
    let accel = Accelerator::default();
    let p = profile_network(&deepcaps_cifar10(), &accel);
    let res = dse::run(&ctx8(), &p).unwrap();
    let sel = selected(&res);

    let sep = &sel["SEP"].org;
    assert_eq!(sep.data.unwrap().size, 256 * KIB);
    assert!(sep.weight.unwrap().size == 108 * KIB || sep.weight.unwrap().size == 128 * KIB);
    assert_eq!(sep.acc.unwrap().size, 8 * MIB);
    assert_eq!(sel["SMP"].org.shared.unwrap().size, 8 * MIB);
}

#[test]
fn fig18_frontier_membership() {
    // "while SEP, SEP-PG and HY-PG belong to the Pareto-frontier, HY, SMP
    // and SMP-PG are dominated" — we assert the SMP half strictly and the
    // presence of SEP/SEP-PG/HY-PG configurations on the frontier.
    let accel = Accelerator::default();
    let p = profile_network(&capsnet_mnist(), &accel);
    let res = dse::run(&ctx8(), &p).unwrap();
    let frontier_opts: std::collections::BTreeSet<String> =
        res.pareto.iter().map(|&i| res.points[i].option().to_string()).collect();
    assert!(!frontier_opts.contains("SMP"));
    assert!(!frontier_opts.contains("SMP-PG"));
    assert!(frontier_opts.contains("SEP") || frontier_opts.contains("SEP-PG"));
    assert!(frontier_opts.contains("HY-PG"));
}

#[test]
fn hy_pg_lowest_energy_sep_lowest_area() {
    // Section VI-B: "the HY-PG is the solution with the lowest energy
    // consumption, the SEP organization has the lowest area".  The paper
    // notes SEP-PG is only "slightly higher" than HY-PG; in our calibrated
    // model the two are within <1% on DeepCaps (ordering can flip), so the
    // assertion allows a 2% tie band — recorded in EXPERIMENTS.md.
    for net in [capsnet_mnist(), deepcaps_cifar10()] {
        let accel = Accelerator::default();
        let p = profile_network(&net, &accel);
        let res = dse::run(&ctx8(), &p).unwrap();
        let sel = selected(&res);
        for (name, point) in &sel {
            assert!(
                sel["HY-PG"].energy_j <= point.energy_j * 1.02,
                "{}: HY-PG not (near-)lowest energy vs {name}",
                net.name
            );
            assert!(
                sel["SEP"].area_mm2 <= point.area_mm2 + 1e-12,
                "{}: SEP not lowest area vs {name}",
                net.name
            );
        }
    }
}

#[test]
fn headline_energy_and_area_savings() {
    // Abstract: "no performance loss and an energy reduction of 79% for the
    // complete accelerator ... compared to the state-of-the-art design";
    // section VI-D: SEP 78% energy / 47% area; intro: memory hierarchy alone
    // saves 73%.
    let cfg = SystemConfig::default();
    let p = profile_network(&capsnet_mnist(), &cfg.accel);
    let a = energy::version_a(&p, &cfg.tech).unwrap();
    let b = energy::version_b(&p, &cfg.tech, dse::smp_size(&p)).unwrap();
    let res = dse::run(&EvalCtx::for_config(&cfg).threads(8), &p).unwrap();
    let sel = selected(&res);

    let b_saving = 1.0 - b.total_j() / a.total_j();
    assert!((0.60..0.92).contains(&b_saving), "version-b saving {b_saving:.3}");

    let sep = system_with_org(&p, &cfg.tech, &sel["SEP"].org, "DESCNet").unwrap();
    let hy = system_with_org(&p, &cfg.tech, &sel["HY-PG"].org, "DESCNet").unwrap();
    let sep_saving = 1.0 - sep.total_j() / a.total_j();
    let hy_saving = 1.0 - hy.total_j() / a.total_j();
    assert!((0.65..0.95).contains(&sep_saving), "SEP saving {sep_saving:.3}");
    assert!((0.65..0.95).contains(&hy_saving), "HY-PG saving {hy_saving:.3}");

    let sep_area_saving = 1.0 - sep.area_mm2 / a.area_mm2;
    assert!(
        (0.30..0.99).contains(&sep_area_saving),
        "SEP area saving {sep_area_saving:.3}"
    );

    // "without any performance loss"
    let stalls = prefetch::analyze(&p, &cfg.tech, &cfg.accel);
    assert!(stalls.no_performance_loss());
}

#[test]
fn performance_claims_both_networks() {
    // 116 fps CapsNet / 9.7 fps DeepCaps; routing > 50% (CapsNet);
    // ConvCaps2D ~73% (DeepCaps).
    let accel = Accelerator::default();
    let caps = profile_network(&capsnet_mnist(), &accel);
    let deep = profile_network(&deepcaps_cifar10(), &accel);
    assert!((caps.fps() - 116.0).abs() / 116.0 < 0.05, "{}", caps.fps());
    assert!((deep.fps() - 9.7).abs() / 9.7 < 0.12, "{}", deep.fps());
    assert!(caps.routing_cycle_share() > 0.5);
    let share = deep.group_cycle_share(LayerGroup::ConvCaps2D);
    assert!((0.66..0.80).contains(&share), "{share}");
}

#[test]
fn deepcaps_does_not_fit_version_a_but_fits_descnet() {
    // Section IV-C: "DeepCaps does not fit in the 8 MiB memory of [1]" as a
    // *monolithic all-on-chip* working store (weights alone exceed it once
    // the 21 MB of streamed parameters are counted), while the DESCNet
    // hierarchy serves it with < 9 MiB of on-chip SPM.
    let accel = Accelerator::default();
    let tech = Technology::default();
    let deep_net = deepcaps_cifar10();
    let p = profile_network(&deep_net, &accel);
    let weights: u64 = deep_net.total_param_bytes();
    assert!(
        weights as usize > 8 * MIB,
        "DeepCaps params {weights} should exceed the 8 MiB of [1]"
    );
    let res = dse::run(&ctx8(), &p).unwrap();
    let sel = selected(&res);
    assert!(sel["SEP"].org.total_size() < 9 * MIB);
    assert!(prefetch::analyze(&p, &tech, &accel).no_performance_loss());
}

#[test]
fn fig22_single_port_shared_improves_efficiency() {
    // Section VI-C: "the area and energy efficiency is improved by having a
    // lower P_S" — the best 1-port HY-PG config must dominate (or match)
    // the best 3-port one on both axes.
    let accel = Accelerator::default();
    let tech = Technology::default();
    let p = profile_network(&deepcaps_cifar10(), &accel);
    let tl = descnet::sim::Timeline::build(&p, &tech, &accel);

    let best = |ports: usize| -> (f64, f64) {
        let orgs = dse::enumerate_hy_ports(&p, ports).unwrap();
        let pts = dse::evaluate_all(&ctx8(), &orgs, &p, &tl);
        let front = dse::pareto_indices(&pts);
        let i = front
            .iter()
            .copied()
            .min_by(|&a, &b| pts[a].energy_j.total_cmp(&pts[b].energy_j))
            .unwrap();
        (pts[i].area_mm2, pts[i].energy_j)
    };
    let (_a1, e1) = best(1);
    let (_a3, e3) = best(3);
    assert!(e1 <= e3 * 1.001, "1-port best energy {e1} vs 3-port {e3}");
}

#[test]
fn report_all_regenerates_every_artifact() {
    let dir = std::env::temp_dir().join("descnet_report_integration");
    let _ = std::fs::remove_dir_all(&dir);
    let eval = EvalCtx::for_config(&SystemConfig::default()).threads(8);
    let ctx = ReportCtx::new(eval, &dir);
    let done = report::all(&ctx).unwrap();
    assert!(done.len() >= 19, "{done:?}");
    // Every generator produced its file.
    for file in [
        "dse_multi.csv",
        "table_multi_selected.md",
        "fleet.csv",
        "table_fleet.md",
        "fig01_memory_utilization.csv",
        "fig07_params_vs_time.csv",
        "fig09_cycles.csv",
        "fig10_capsnet_usage_accesses.csv",
        "fig11_deepcaps_usage_accesses.csv",
        "fig12_energy_versions.csv",
        "fig18_dse_capsnet.csv",
        "fig19_capsnet_breakdown.csv",
        "fig20_dse_deepcaps.csv",
        "fig21_deepcaps_breakdown.csv",
        "fig22_hy_pg_ports.csv",
        "fig23_24_capsnet_whole_accelerator.csv",
        "fig25_26_deepcaps_whole_accelerator.csv",
        "fig27_28_offchip_accesses.csv",
        "fig29_capsnet_memory_breakdown.csv",
        "fig30_hy_pg_schedule.csv",
        "fig31_deepcaps_memory_breakdown.csv",
        "table1_selected_capsnet.md",
        "table2_selected_deepcaps.md",
        "table3_area_energy.md",
        "headline.csv",
    ] {
        assert!(dir.join(file).exists(), "{file} missing");
    }
}
