//! Integration: the unified evaluation context (ISSUE 10).
//!
//! `EvalCtx` is the one bundle of shared evaluation state (engine,
//! technology, accelerator, cost-cache handle, budget) threaded through
//! every sweep entry point.  Covered here, at the public API level:
//!
//! * builder defaults equal the CLI's defaults (batch 1, no latency
//!   budget, stats off, the process-global cost cache);
//! * invalid budgets (NaN, infinite, zero, negative) are rejected at
//!   construction — not deep inside a sweep;
//! * threads=1 vs threads=N bit-identity of the full `dse::run` pipeline
//!   through the ctx path (the determinism contract of DESIGN.md
//!   section 14, restated over the new entry points);
//! * the context's budget flows into the sweep: a ctx-carried budget
//!   partitions the space exactly like the old explicit-argument path.

use descnet::cacti::cache;
use descnet::config::{Accelerator, SystemConfig, Technology};
use descnet::ctx::{Budget, EvalCtx};
use descnet::dataflow::profile_network;
use descnet::dse;
use descnet::model::capsnet_mnist;
use descnet::sim;

#[test]
fn builder_defaults_match_the_cli_defaults() {
    let ctx = EvalCtx::new(Technology::default(), Accelerator::default());
    assert_eq!(ctx.budget(), &Budget::default());
    assert_eq!(ctx.budget().batch, 1, "CLI --batch default");
    assert_eq!(ctx.budget().latency_budget_s, None, "no --latency-budget");
    assert!(!ctx.budget().stats, "CLI --stats default");
    assert_eq!(ctx.config(), &SystemConfig::default());
    assert!(
        std::ptr::eq(ctx.cache(), cache::global()),
        "the context must hand out the process-global cost cache"
    );
}

#[test]
fn for_config_carries_the_loaded_config() {
    let mut cfg = SystemConfig::default();
    cfg.tech.wakeup_latency_s = 0.25;
    cfg.accel.clock_hz = 123e6;
    let ctx = EvalCtx::for_config(&cfg);
    assert_eq!(ctx.tech(), &cfg.tech);
    assert_eq!(ctx.accel(), &cfg.accel);
    assert_eq!(ctx.config(), &cfg);
}

#[test]
fn invalid_budgets_are_rejected_at_construction() {
    let ctx = || EvalCtx::new(Technology::default(), Accelerator::default());
    for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY, 0.0, -1.0] {
        let err = ctx().latency_budget_s(Some(bad)).unwrap_err();
        assert!(
            format!("{err:#}").contains("positive duration"),
            "budget {bad}: {err:#}"
        );
    }
    // Valid budgets construct, and `None` clears a previous budget.
    let ok = ctx().latency_budget_s(Some(1e-3)).unwrap();
    assert_eq!(ok.budget().latency_budget_s, Some(1e-3));
    let cleared = ok.latency_budget_s(None).unwrap();
    assert_eq!(cleared.budget().latency_budget_s, None);
}

#[test]
fn knobs_set_every_budget_field() {
    let ctx = EvalCtx::new(Technology::default(), Accelerator::default())
        .threads(3)
        .batch(4)
        .stats(true)
        .latency_budget_s(Some(0.5))
        .unwrap();
    assert_eq!(ctx.budget().batch, 4);
    assert_eq!(ctx.budget().latency_budget_s, Some(0.5));
    assert!(ctx.budget().stats);
}

#[test]
fn dse_run_is_bit_identical_across_thread_counts_through_the_ctx() {
    let p = profile_network(&capsnet_mnist(), &Accelerator::default());
    let ctx = |n: usize| EvalCtx::new(Technology::default(), Accelerator::default()).threads(n);
    let r1 = dse::run(&ctx(1), &p).unwrap();
    for n in [2usize, 8] {
        let rn = dse::run(&ctx(n), &p).unwrap();
        assert_eq!(r1.points.len(), rn.points.len(), "threads={n}");
        for (a, b) in r1.points.iter().zip(&rn.points) {
            assert_eq!(a.org, b.org, "threads={n}");
            assert_eq!(a.area_mm2.to_bits(), b.area_mm2.to_bits(), "threads={n}");
            assert_eq!(a.energy_j.to_bits(), b.energy_j.to_bits(), "threads={n}");
            assert_eq!(a.latency_s.to_bits(), b.latency_s.to_bits(), "threads={n}");
        }
        assert_eq!(r1.pareto, rn.pareto, "threads={n}");
        assert_eq!(r1.selected, rn.selected, "threads={n}");
    }
}

#[test]
fn ctx_budget_flows_into_the_sweep() {
    // A mid budget in the slow-wakeup regime (where latency varies across
    // the space) must exclude some configurations but not all — proving
    // the sweep reads the budget off the context, not a vestigial
    // argument.
    let mut tech = Technology::default();
    tech.wakeup_latency_s = 0.5;
    let accel = Accelerator::default();
    let p = profile_network(&capsnet_mnist(), &accel);
    let tl = sim::Timeline::build(&p, &tech, &accel);
    let budget = tl.inference_latency_s() * 1.001;

    let unbounded = EvalCtx::new(tech.clone(), accel.clone()).threads(2);
    let full = dse::run(&unbounded, &p).unwrap();

    let bounded = unbounded
        .clone()
        .latency_budget_s(Some(budget))
        .unwrap();
    let res = dse::run(&bounded, &p).unwrap();
    assert!(res.excluded_by_budget > 0, "budget must exclude something");
    assert!(
        res.points.len() < full.points.len(),
        "budgeted sweep must keep fewer survivors"
    );
    assert!(res.points.iter().all(|pt| pt.latency_s <= budget));
}
