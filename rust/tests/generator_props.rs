//! Property-based tests over `model::generator` random networks (ISSUE 3),
//! driven by the mini proptest framework (`util::prop` — the image vendors
//! no `proptest` crate, see DESIGN.md Substitutions): builder geometry
//! invariants, profile well-formedness, batch-1 bit-identity, and the
//! timeline-simulator batch monotonicity.

use descnet::config::{Accelerator, Technology};
use descnet::dataflow::{profile_network, profile_network_batched};
use descnet::model::{random_network, OpKind};
use descnet::prop_assert;
use descnet::sim::Timeline;
use descnet::util::prop::check;

fn draw_seed(rng: &mut descnet::util::prng::Prng) -> u64 {
    rng.below(4096)
}

#[test]
fn prop_builder_geometry_invariants() {
    // Extent chains consistent (convolutions only ever preserve or shrink
    // the grid, every extent stays positive) and routing pairs well-formed
    // (each routing tail matches the geometry of the votes op that feeds
    // it, iterations count 1..=total in alternating halves).
    check("generator-geometry", 64, |rng| {
        let net = random_network(draw_seed(rng));
        prop_assert!(net.ops.len() >= 4, "{}: {} ops", net.name, net.ops.len());

        let mut last_votes: Option<(usize, usize, usize)> = None;
        let mut expected_iter = 1usize;
        let mut expect_sum_half = true;
        for op in &net.ops {
            match &op.kind {
                OpKind::Conv2d {
                    hin,
                    win,
                    cin,
                    hout,
                    wout,
                    cout,
                    kh,
                    kw,
                    stride,
                    ..
                } => {
                    prop_assert!(
                        *hin >= 1 && *win >= 1 && *cin >= 1,
                        "{}: empty input",
                        op.name
                    );
                    prop_assert!(
                        *hout >= 1 && *wout >= 1 && *cout >= 1,
                        "{}: empty output",
                        op.name
                    );
                    prop_assert!(*kh >= 1 && *kw >= 1 && *stride >= 1, "{}", op.name);
                    // Same/valid padding never grows the grid.
                    prop_assert!(
                        *hout <= *hin && *wout <= *win,
                        "{}: grid grew {hin}x{win} -> {hout}x{wout}",
                        op.name
                    );
                    // Stride-s output is the ceil-division chain (same) or
                    // tighter (valid).
                    prop_assert!(
                        *hout <= hin.div_ceil(*stride) && *wout <= win.div_ceil(*stride),
                        "{}: extent chain broken",
                        op.name
                    );
                }
                OpKind::Votes { ni, no, di, dout, .. } => {
                    prop_assert!(
                        *ni >= 1 && *no >= 1 && *di >= 1 && *dout >= 1,
                        "{}",
                        op.name
                    );
                    last_votes = Some((*ni, *no, *dout));
                    expected_iter = 1;
                    expect_sum_half = true;
                }
                OpKind::Routing {
                    ni,
                    no,
                    dout,
                    iter,
                    total_iters,
                    half,
                    ..
                } => {
                    let (vni, vno, vdout) = match last_votes {
                        Some(v) => v,
                        None => return Err(format!("{}: routing before votes", op.name)),
                    };
                    prop_assert!(
                        (*ni, *no, *dout) == (vni, vno, vdout),
                        "{}: routing pair ({ni},{no},{dout}) != votes ({vni},{vno},{vdout})",
                        op.name
                    );
                    prop_assert!(
                        *iter == expected_iter && *iter <= *total_iters,
                        "{}: iter {iter}/{total_iters}, expected {expected_iter}",
                        op.name
                    );
                    let is_sum = matches!(half, descnet::model::RoutingHalf::SumSquash);
                    prop_assert!(
                        is_sum == expect_sum_half,
                        "{}: halves out of order",
                        op.name
                    );
                    if !expect_sum_half {
                        expected_iter += 1;
                    }
                    expect_sum_half = !expect_sum_half;
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_op_profiles_are_wellformed() {
    // Every OpProfile field is finite/consistent: cycles positive, working
    // sets bounded by the op-wise total, access counts consistent with the
    // compute floor, off-chip traffic only where the op's geometry admits
    // it.  (Field types are unsigned, so "non-negative" is enforced by
    // construction — what can break is zero/overflowed/inconsistent.)
    let accel = Accelerator::default();
    check("generator-profiles", 48, |rng| {
        let net = random_network(draw_seed(rng));
        let p = profile_network(&net, &accel);
        prop_assert!(p.total_cycles() > 0);
        prop_assert!(p.fps().is_finite() && p.fps() > 0.0);
        for (op, prof) in net.ops.iter().zip(&p.ops) {
            prop_assert!(prof.cycles > 0, "{}", prof.name);
            prop_assert!(
                prof.usage_total() == prof.usage_d + prof.usage_w + prof.usage_a,
                "{}",
                prof.name
            );
            prop_assert!(prof.macs == op.macs(), "{}: macs diverge", prof.name);
            // MAC-carrying ops move accumulator traffic (16-MAC row floor).
            if !prof.name.contains("Update+Softmax") {
                prop_assert!(
                    prof.rd_a + prof.wr_a >= prof.macs / 16,
                    "{}: accumulator traffic below MAC floor",
                    prof.name
                );
            }
            // Off-chip reads are staged through some on-chip traffic: every
            // byte fetched lands in (or streams through) an SPM.
            prop_assert!(
                prof.off_rd <= prof.wr_d + prof.wr_w + prof.rd_d + prof.rd_a + op.param_bytes(),
                "{}: off_rd inconsistent",
                prof.name
            );
        }
        Ok(())
    });
}

#[test]
fn prop_batch_one_is_bit_identical_to_unbatched() {
    let accel = Accelerator::default();
    check("generator-batch1-identity", 48, |rng| {
        let net = random_network(draw_seed(rng));
        let unbatched = profile_network(&net, &accel);
        let batched = profile_network_batched(&net, &accel, 1);
        prop_assert!(unbatched == batched, "{}: batch-1 diverged", net.name);
        Ok(())
    });
}

#[test]
fn prop_sim_latency_monotone_in_batch() {
    // The timeline invariant the ISSUE pins: a batch can never finish
    // faster than a single inference, for any generated network.
    let accel = Accelerator::default();
    let tech = Technology::default();
    check("generator-sim-batch-monotone", 32, |rng| {
        let net = random_network(draw_seed(rng));
        let b = 2 + rng.below(7); // batch in 2..=8
        let t1 = Timeline::build(&profile_network_batched(&net, &accel, 1), &tech, &accel);
        let tb = Timeline::build(
            &profile_network_batched(&net, &accel, b as usize),
            &tech,
            &accel,
        );
        prop_assert!(
            tb.batch_latency_s() >= t1.batch_latency_s(),
            "{}: latency(batch={b}) {} < latency(batch=1) {}",
            net.name,
            tb.batch_latency_s(),
            t1.batch_latency_s()
        );
        Ok(())
    });
}
