//! Exactness of the branch-and-bound DSE (ISSUE 6 acceptance criteria).
//!
//! The streaming dominance-pruned sweep (`dse::stream`) must be a pure
//! performance optimization: for any workload, its Pareto frontier and
//! per-design-option selection are **bit-identical** to the exhaustive
//! materialize-then-evaluate pipeline it replaced.  The exhaustive oracle
//! is rebuilt here from the public pieces (`enumerate` → `evaluate_all` →
//! `pareto_indices` → `select_per_option`), which walk the exact same
//! enumeration order as the pruned sweep.
//!
//! Covered:
//! * bit-identical frontier + selection on capsnet and deepcaps;
//! * the same property over 20 seeded `model::generator` networks;
//! * nonzero pruned fraction on capsnet (the sweep actually prunes) with
//!   counter reconciliation (evaluated + pruned == enumerated);
//! * threads=1 vs threads=8 full determinism of the pruned sweep;
//! * budgeted sweep == budget-filtered exhaustive sweep in a regime where
//!   latency varies across organizations (slow wakeup);
//! * the multi-network co-design sweep against its own exhaustive oracle.

use descnet::config::{Accelerator, Technology};
use descnet::ctx::EvalCtx;
use descnet::dataflow::{profile_network, NetworkProfile};
use descnet::dse::{self, multi::WorkloadSet, DsePoint};
use descnet::memory::Organization;
use descnet::model::{capsnet_mnist, deepcaps_cifar10, random_networks};
use descnet::sim;

/// Frontier as *values* (org + bit patterns), independent of how the two
/// pipelines index their point vectors.
fn frontier_values(points: &[DsePoint], pareto: &[usize]) -> Vec<(Organization, u64, u64, u64)> {
    pareto
        .iter()
        .map(|&i| {
            let p = &points[i];
            (
                p.org.clone(),
                p.area_mm2.to_bits(),
                p.energy_j.to_bits(),
                p.latency_s.to_bits(),
            )
        })
        .collect()
}

/// Per-option selection as values: (label, org, energy bits).
fn selection_values(
    points: &[DsePoint],
    selected: &[(String, usize)],
) -> Vec<(String, Organization, u64)> {
    selected
        .iter()
        .map(|(label, i)| (label.clone(), points[*i].org.clone(), points[*i].energy_j.to_bits()))
        .collect()
}

/// The exhaustive pipeline the branch-and-bound sweep replaced.
fn exhaustive(
    ctx: &EvalCtx,
    p: &NetworkProfile,
) -> (Vec<DsePoint>, Vec<usize>, Vec<(String, usize)>) {
    let orgs = dse::enumerate(p).expect("enumeration");
    let tl = sim::Timeline::build(p, ctx.tech(), ctx.accel());
    let points = dse::evaluate_all(ctx, &orgs, p, &tl);
    let pareto = dse::pareto_indices(&points);
    let selected = dse::select_per_option(&points);
    (points, pareto, selected)
}

fn assert_pruned_matches_exhaustive(p: &NetworkProfile, label: &str) {
    let ctx = EvalCtx::new(Technology::default(), Accelerator::default()).threads(8);
    let res = dse::run(&ctx, p).expect("pruned sweep");
    let (all, pareto, selected) = exhaustive(&ctx, p);

    // Counter reconciliation: every enumerated candidate is either culled
    // by the bound or evaluated, and the survivors are exactly `points`.
    assert_eq!(res.stats.enumerated, all.len(), "{label}: enumerated count");
    assert_eq!(
        res.stats.evaluated + res.stats.pruned,
        res.stats.enumerated,
        "{label}: evaluated + pruned != enumerated"
    );
    assert_eq!(res.stats.evaluated, res.points.len(), "{label}: survivor count");
    assert!(res.points.len() <= all.len(), "{label}: more survivors than candidates");

    // Bit-identical frontier and per-option selection.
    assert_eq!(
        frontier_values(&res.points, &res.pareto),
        frontier_values(&all, &pareto),
        "{label}: frontier differs from exhaustive"
    );
    assert_eq!(
        selection_values(&res.points, &res.selected),
        selection_values(&all, &selected),
        "{label}: selection differs from exhaustive"
    );
}

#[test]
fn capsnet_pruned_sweep_is_bit_identical_and_actually_prunes() {
    let p = profile_network(&capsnet_mnist(), &Accelerator::default());
    assert_pruned_matches_exhaustive(&p, "capsnet");
    // Effectiveness: the bound must cull a nonzero fraction of the space.
    let ctx = EvalCtx::new(Technology::default(), Accelerator::default()).threads(8);
    let res = dse::run(&ctx, &p).unwrap();
    assert!(res.stats.pruned > 0, "no candidates pruned on capsnet");
    assert!(res.stats.subtrees_pruned > 0, "no whole subtree pruned on capsnet");
    assert!(res.stats.archive_inserts >= res.stats.archive_len);
    assert!(res.stats.mean_bound_gap() >= 0.0);
}

#[test]
fn deepcaps_pruned_sweep_is_bit_identical() {
    let p = profile_network(&deepcaps_cifar10(), &Accelerator::default());
    assert_pruned_matches_exhaustive(&p, "deepcaps");
}

#[test]
fn generator_networks_pruned_sweep_is_bit_identical() {
    let accel = Accelerator::default();
    for (k, net) in random_networks(20, 11).iter().enumerate() {
        let p = profile_network(net, &accel);
        assert_pruned_matches_exhaustive(&p, &format!("generated #{k} ({})", net.name));
    }
}

#[test]
fn pruned_sweep_is_deterministic_across_thread_counts() {
    let tech = Technology::default();
    let accel = Accelerator::default();
    let p = profile_network(&capsnet_mnist(), &accel);
    let r1 = dse::run(&EvalCtx::new(tech.clone(), accel.clone()).threads(1), &p).unwrap();
    let r8 = dse::run(&EvalCtx::new(tech, accel).threads(8), &p).unwrap();
    assert_eq!(r1.points.len(), r8.points.len());
    for (a, b) in r1.points.iter().zip(&r8.points) {
        assert_eq!(a.org, b.org);
        assert_eq!(a.area_mm2.to_bits(), b.area_mm2.to_bits());
        assert_eq!(a.energy_j.to_bits(), b.energy_j.to_bits());
        assert_eq!(a.latency_s.to_bits(), b.latency_s.to_bits());
    }
    assert_eq!(r1.pareto, r8.pareto);
    assert_eq!(r1.selected, r8.selected);
    // Pruning decisions are taken sequentially per subtree, so the
    // counters must agree exactly too.
    assert_eq!(r1.stats.enumerated, r8.stats.enumerated);
    assert_eq!(r1.stats.pruned, r8.stats.pruned);
    assert_eq!(r1.stats.evaluated, r8.stats.evaluated);
    assert_eq!(r1.stats.subtrees, r8.stats.subtrees);
    assert_eq!(r1.stats.subtrees_pruned, r8.stats.subtrees_pruned);
    assert_eq!(r1.stats.archive_inserts, r8.stats.archive_inserts);
    assert_eq!(r1.stats.archive_len, r8.stats.archive_len);
    assert_eq!(r1.stats.bound_gap_sum.to_bits(), r8.stats.bound_gap_sum.to_bits());
    assert_eq!(r1.stats.bound_gap_count, r8.stats.bound_gap_count);
    // The ISSUE 7 wall-time split (`prep_s`/`eval_s`) is the one
    // deliberately nondeterministic part of SweepStats — sanity-checked
    // here, never compared (rust/tests/factored_eval.rs pins the rest of
    // the factored-evaluator contract).
    assert!(r1.stats.prep_s >= 0.0 && r1.stats.eval_s >= 0.0);
    assert!(r8.stats.prep_s >= 0.0 && r8.stats.eval_s >= 0.0);
}

#[test]
fn budgeted_sweep_matches_filtered_exhaustive_when_latency_varies() {
    // At the paper's constants every organization has the same latency, so
    // a budget is all-or-nothing.  With an unmaskable wakeup latency the
    // gated organizations get slower, latency varies across the space, and
    // a mid budget partitions it — the interesting regime for exactness.
    let mut tech = Technology::default();
    tech.wakeup_latency_s = 0.5;
    let accel = Accelerator::default();
    let p = profile_network(&capsnet_mnist(), &accel);
    let tl = sim::Timeline::build(&p, &tech, &accel);
    // Budget just above the ungated latency: keeps every ungated org,
    // excludes every org with exposed wakeups.
    let budget = tl.inference_latency_s() * 1.001;

    let ctx = EvalCtx::new(tech, accel)
        .threads(8)
        .latency_budget_s(Some(budget))
        .expect("valid latency budget");
    let res = dse::run(&ctx, &p).expect("budgeted sweep");

    // Oracle: exhaustive evaluation, then the budget filter, then
    // Pareto/selection over the kept points.
    let orgs = dse::enumerate(&p).unwrap();
    let all = dse::evaluate_all(&ctx, &orgs, &p, &tl);
    let kept: Vec<DsePoint> = all
        .iter()
        .filter(|pt| pt.latency_s <= budget)
        .cloned()
        .collect();
    assert!(!kept.is_empty() && kept.len() < all.len(), "budget must partition the space");
    let pareto = dse::pareto_indices(&kept);
    let selected = dse::select_per_option(&kept);

    assert_eq!(
        frontier_values(&res.points, &res.pareto),
        frontier_values(&kept, &pareto),
        "budgeted frontier differs from filtered exhaustive"
    );
    assert_eq!(
        selection_values(&res.points, &res.selected),
        selection_values(&kept, &selected),
        "budgeted selection differs from filtered exhaustive"
    );
}

#[test]
fn multi_network_pruned_sweep_is_bit_identical() {
    let tech = Technology::default();
    let accel = Accelerator::default();
    let mut nets = vec![capsnet_mnist()];
    nets.extend(random_networks(2, 5));
    let profiles: Vec<_> = nets.iter().map(|n| profile_network(n, &accel)).collect();
    let set = WorkloadSet::new(profiles).unwrap();

    let ctx = EvalCtx::new(tech, accel).threads(8);
    let res = dse::multi::run(&ctx, &set).expect("pruned co-design sweep");

    let orgs = dse::multi::enumerate(&set).unwrap();
    let tls = dse::multi::timelines(&ctx, &set);
    let (all, _, _) = dse::multi::evaluate_all(&ctx, &orgs, &set, &tls);
    let pareto = dse::pareto_indices(&all);
    let selected = dse::select_per_option(&all);

    assert_eq!(res.stats.enumerated, all.len());
    assert_eq!(res.stats.evaluated + res.stats.pruned, res.stats.enumerated);
    assert_eq!(
        frontier_values(&res.points, &res.pareto),
        frontier_values(&all, &pareto),
        "co-design frontier differs from exhaustive"
    );
    assert_eq!(
        selection_values(&res.points, &res.selected),
        selection_values(&all, &selected),
        "co-design selection differs from exhaustive"
    );
}
