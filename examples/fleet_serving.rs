//! Fleet serving walkthrough: an SLO-constrained co-designed fleet of
//! accelerator shards serving a CapsNet/DeepCaps mix under open-loop
//! traffic, compared policy-by-policy and against the homogeneous
//! union-SMP baseline.
//!
//!   cargo run --release --example fleet_serving
//!
//! Equivalent CLI: `descnet fleet --shards 4 --rps 300 --policy jsq
//! --slo-ms 25 --net capsnet` (and `descnet report fleet` for the CSV/
//! markdown artifacts).

use descnet::config::SystemConfig;
use descnet::ctx::EvalCtx;
use descnet::fleet::{design_fleet, simulate, DesignOptions, FleetConfig, RoutingPolicy};
use descnet::model::capsnet_mnist;
use descnet::util::units::fmt_energy;

fn main() {
    let cfg = SystemConfig::default();
    let slo = 25e-3;

    // 1. Co-design the fleet: 4 CapsNet shards, each SPM organization
    //    selected under a 25 ms SLO hard constraint; the design carries the
    //    homogeneous union-SMP baseline for comparison.
    let opts = DesignOptions {
        shards: 4,
        batch_sizes: vec![1, 2, 4],
        slo_s: Some(slo),
        flush_deadline_s: 2e-3,
        homogeneous: false,
    };
    let ctx = EvalCtx::for_config(&cfg);
    let design = design_fleet(&ctx, &[capsnet_mnist()], &opts).expect("fleet co-design");
    for (i, p) in design.plans.iter().enumerate() {
        println!(
            "shard {i}: {} on {} (batches {:?}, {} per inference at b{})",
            p.workload,
            p.org.label(),
            p.batcher.sizes(),
            fmt_energy(p.best_energy_per_inf()),
            p.batcher.max_batch(),
        );
    }
    println!("baseline organization: {}\n", design.baseline_label);

    // 2. Same seeded arrival trace under each routing policy.
    for policy in [
        RoutingPolicy::RoundRobin,
        RoutingPolicy::Jsq,
        RoutingPolicy::EnergyAware,
    ] {
        let fcfg = FleetConfig {
            rps: 300.0,
            requests: 1_000,
            seed: 7,
            policy,
            slo_s: Some(slo),
            fault: None,
        };
        let mut stats = simulate(&design.plans, &fcfg).expect("fleet simulation");
        let base = simulate(&design.baseline, &fcfg).expect("baseline simulation");
        println!(
            "{:6}  p50 {:6.2} ms  p99 {:6.2} ms  SLO {:5.1}%  {} /request \
             (baseline {}, saves {:.1}%)",
            policy.label(),
            stats.latency.p50() * 1e3,
            stats.latency.p99() * 1e3,
            100.0 * stats.slo_attainment(),
            fmt_energy(stats.energy_per_request_j()),
            fmt_energy(base.energy_per_request_j()),
            100.0 * (1.0 - stats.energy_per_request_j() / base.energy_per_request_j()),
        );
    }
}
