//! End-to-end validation driver (EXPERIMENTS.md E19).
//!
//!   make artifacts && cargo run --release --example serve_mnist
//!
//! Loads the AOT CapsNet artifacts (Pallas kernels -> JAX stages -> HLO
//! text), serves batched synthetic-MNIST requests through the rust
//! coordinator on the PJRT CPU client, and reports latency/throughput plus
//! the co-simulated DESCNet energy — proving all three layers compose with
//! python nowhere on the request path.
//!
//! Runs both execution modes (fused full-net and 3-stage pipeline) and
//! writes results/serve_mnist.csv.

use std::path::PathBuf;

use descnet::coordinator::server::{ServeOptions, Server};
use descnet::util::csv::{f, s, u, Csv};

fn main() {
    let artifacts = PathBuf::from(
        std::env::args()
            .nth(1)
            .unwrap_or_else(|| "artifacts".to_string()),
    );
    if !artifacts.join("manifest.json").exists() {
        eprintln!(
            "no artifacts under {} — run `make artifacts` first",
            artifacts.display()
        );
        std::process::exit(2);
    }

    let mut csv = Csv::new(&[
        "mode",
        "requests",
        "batches",
        "mean_batch",
        "throughput_rps",
        "p50_ms",
        "p95_ms",
        "p99_ms",
        "batch_exec_ms",
        "energy_per_inference_mj",
    ]);

    for (mode, staged) in [("full", false), ("staged", true)] {
        let opts = ServeOptions {
            artifacts_dir: artifacts.clone(),
            requests: 64,
            batch_max: 4,
            stage_pipeline: staged,
            seed: 7,
            slo_s: None,
        };
        println!("== serving 64 synthetic MNIST requests ({mode} mode) ==");
        let mut stats = Server::run_synthetic(&opts).expect("serving failed");
        println!("{}\n", stats.summary());
        csv.row(vec![
            s(mode),
            u(stats.requests as usize),
            u(stats.batches as usize),
            f(stats.mean_batch()),
            f(stats.throughput_rps()),
            f(stats.latency.p50() * 1e3),
            f(stats.latency.p95() * 1e3),
            f(stats.latency.p99() * 1e3),
            f(stats.batch_exec.mean() * 1e3),
            f(stats.energy_j / stats.requests.max(1) as f64 * 1e3),
        ]);
    }

    let out = PathBuf::from("results/serve_mnist.csv");
    csv.write_file(&out).expect("writing results");
    println!("wrote {}", out.display());
}
