//! Power-gating deep dive: sector-count sweep, break-even analysis and the
//! Fig 30-style ON/OFF schedule for the CapsNet weight memory.
//!
//!   cargo run --release --example powergate_explorer
//!
//! Shows, per sector count, the static-energy saving vs the area overhead —
//! the exact trade-off Algorithm 2 explores — and prints the PMU schedule
//! that masks the 0.072 ns wakeup latency.

use descnet::cacti::{cache, powergate, SramConfig};
use descnet::config::SystemConfig;
use descnet::dataflow::profile_network;
use descnet::dse;
use descnet::energy;
use descnet::memory::{Component, MemSpec, Organization};
use descnet::model::capsnet_mnist;
use descnet::pmu;
use descnet::util::csv::{f, u, Csv};
use descnet::util::table::Table;
use descnet::util::units::{fmt_energy, fmt_size, fmt_time, KIB};

fn main() {
    let cfg = SystemConfig::default();
    let profile = profile_network(&capsnet_mnist(), &cfg.accel);
    let (d_sz, w_sz, a_sz) = dse::sep_sizes(&profile);

    // --- sector sweep on the SEP weight memory (64 kiB).
    println!("== sector sweep: SEP weight memory ({}) ==", fmt_size(w_sz));
    let mut csv = Csv::new(&[
        "sectors",
        "static_mj",
        "saving_frac",
        "area_mm2",
        "area_overhead_frac",
        "wakeups",
        "wakeup_nj",
    ]);
    let base_area = cache::costs(&cfg.tech, &SramConfig::new(w_sz, 1, 1)).area_mm2;
    let mut base_static = 0.0;
    for sc in [1usize, 2, 4, 8, 16] {
        let org = Organization::sep(
            MemSpec::new(d_sz, 1),
            MemSpec::new(w_sz, sc),
            MemSpec::new(a_sz, 1),
        );
        let report = pmu::evaluate(&org, &profile, &cfg.tech).expect("PMU evaluation");
        let w = report
            .components
            .iter()
            .find(|c| c.component == Component::Weight)
            .unwrap();
        if sc == 1 {
            base_static = w.static_energy_j;
        }
        let area = cache::costs(&cfg.tech, &SramConfig::new(w_sz, 1, sc)).area_mm2;
        println!(
            "  SC={sc:2}  static {}  (saves {:5.1}%)  area {:.3} mm² (+{:4.1}%)  wakeups {} ({})",
            fmt_energy(w.static_energy_j),
            100.0 * (1.0 - w.static_energy_j / base_static),
            area,
            100.0 * (area / base_area - 1.0),
            w.wakeups,
            fmt_energy(w.wakeup_energy_j),
        );
        csv.row(vec![
            u(sc),
            f(w.static_energy_j * 1e3),
            f(1.0 - w.static_energy_j / base_static),
            f(area),
            f(area / base_area - 1.0),
            u(w.wakeups as usize),
            f(w.wakeup_energy_j * 1e9),
        ]);
    }

    // --- break-even: how long must a sector sleep to amortize its wakeup?
    let costs = cache::costs(&cfg.tech, &SramConfig::new(w_sz, 1, 8));
    println!(
        "\nbreak-even sleep time: {} (average op duration: {})",
        fmt_time(powergate::break_even_s(&costs)),
        fmt_time(profile.inference_s() / profile.ops.len() as f64),
    );

    // --- Fig 30: the HY-PG schedule.
    println!("\n== Fig 30: HY-PG sector schedule (Table I configuration) ==");
    let hy_pg = Organization::hy(
        MemSpec::new(32 * KIB, 2),
        MemSpec::new(25 * KIB, 2),
        MemSpec::new(25 * KIB, 4),
        MemSpec::new(32 * KIB, 2),
        3,
    );
    let report = pmu::evaluate(&hy_pg, &profile, &cfg.tech).expect("PMU evaluation");
    let mut table = Table::new(&["op", "shared", "data", "weight", "acc"]);
    for (i, op) in profile.ops.iter().enumerate() {
        let cell = |c: Component| {
            let s = report.schedule(c).unwrap();
            format!("{}/{}", s.on[i], s.sectors)
        };
        table.row(vec![
            op.name.to_string(),
            cell(Component::Shared),
            cell(Component::Data),
            cell(Component::Weight),
            cell(Component::Acc),
        ]);
    }
    println!("{}", table.to_ascii());
    println!(
        "HY-PG static {} vs un-gated {}  (wakeup latency masked: {})",
        fmt_energy(report.static_energy_j()),
        fmt_energy(report.static_no_pg_j()),
        report.wakeup_masked(),
    );
    let e = energy::evaluate_org(&hy_pg, &profile, &cfg.tech).expect("energy rollup");
    println!(
        "HY-PG on-chip total: {} ({} dynamic, {} static, {} wakeup)",
        fmt_energy(e.energy_j()),
        fmt_energy(e.dyn_j()),
        fmt_energy(e.static_j()),
        fmt_energy(e.wakeup_j()),
    );

    let out = std::path::PathBuf::from("results/powergate_sweep.csv");
    csv.write_file(&out).expect("writing results");
    println!("wrote {}", out.display());
}
