//! Fault-tolerant fleet serving walkthrough (ISSUE 8): deterministic
//! crash/recover injection, timeout/retry/hedging, and N+1 provisioning.
//!
//!   cargo run --release --example fleet_faults
//!
//! Equivalent CLI: `descnet fleet --shards 4 --mtbf-s 5 --mttr-s 1
//! --timeout-ms 100 --retries 2 --hedge-ms 50 --fault-seed 11` (and
//! `descnet fleet --fault-budget 1 --slo-ms 25` for the N+1 pass).
//!
//! Three parts:
//!   1. availability-vs-energy: sweep MTBF on a synthetic 4-shard fleet —
//!      the same arrival trace every time (injection never perturbs it) —
//!      and watch availability, p99 and energy/request degrade together;
//!   2. mitigation: the worst MTBF point re-run with timeouts+retries and
//!      then hedging on top, recovering tail latency at an energy cost;
//!   3. N+1 provisioning: co-design a CapsNet fleet that still meets its
//!      attainment target with its biggest shard down.

use descnet::config::SystemConfig;
use descnet::ctx::EvalCtx;
use descnet::fleet::fault::FaultConfig;
use descnet::fleet::{
    design_fleet_n_plus, simulate, DesignOptions, FleetConfig, NPlusOptions, RoutingPolicy,
    ShardPlan,
};
use descnet::model::capsnet_mnist;
use descnet::util::units::fmt_energy;

fn main() {
    // Part 1: availability vs energy under an MTBF sweep.  Four synthetic
    // shards, open-loop traffic; crash schedules come from a dedicated
    // PRNG stream, so every row sees the identical arrival trace.
    let plans: Vec<ShardPlan> = (0..4)
        .map(|_| {
            ShardPlan::synthetic("wl", vec![1, 2, 4], 10e-3, 5e-3, 1.0, 2e-3)
                .expect("synthetic plan")
        })
        .collect();
    let base_cfg = FleetConfig {
        rps: 200.0,
        requests: 2_000,
        seed: 7,
        policy: RoutingPolicy::Jsq,
        slo_s: Some(50e-3),
        fault: None,
    };

    println!("MTBF sweep (MTTR 0.5 s, crash policy requeue, no retries/hedging):");
    println!("  mtbf_s   avail    p99_ms  slo%   energy/req  crashes  dropped");
    for mtbf_s in [f64::INFINITY, 20.0, 5.0, 1.0] {
        let cfg = FleetConfig {
            fault: Some(FaultConfig {
                mtbf_s,
                mttr_s: 0.5,
                fault_seed: 11,
                ..FaultConfig::default()
            }),
            ..base_cfg.clone()
        };
        let mut stats = simulate(&plans, &cfg).expect("fleet simulation");
        println!(
            "  {:>6}  {:6.2}%  {:8.2}  {:4.1}  {:>10}  {:>7}  {:>7}",
            if mtbf_s.is_finite() {
                format!("{mtbf_s:.0}")
            } else {
                "inf".to_string()
            },
            100.0 * stats.availability,
            stats.latency.p99() * 1e3,
            100.0 * stats.slo_attainment(),
            fmt_energy(stats.energy_per_request_j()),
            stats.crashes,
            stats.dropped,
        );
    }

    // Part 2: mitigation at the worst point.  Timeouts pull requests off
    // dead queues; hedging duplicates slow ones onto a second shard (the
    // first copy to start service wins).
    println!("\nmitigation at MTBF 1 s:");
    let variants: [(&str, Option<f64>, u32, Option<f64>); 3] = [
        ("none", None, 0, None),
        ("timeout 100 ms x2 retries", Some(100e-3), 2, None),
        ("  + hedge 50 ms", Some(100e-3), 2, Some(50e-3)),
    ];
    for (label, timeout_s, retries, hedge_s) in variants {
        let cfg = FleetConfig {
            fault: Some(FaultConfig {
                mtbf_s: 1.0,
                mttr_s: 0.5,
                timeout_s,
                retries,
                hedge_s,
                fault_seed: 11,
                ..FaultConfig::default()
            }),
            ..base_cfg.clone()
        };
        let mut stats = simulate(&plans, &cfg).expect("fleet simulation");
        println!(
            "  {label:<28} p99 {:7.2} ms  retries {:>4}  hedges {:>4}  \
             dropped {:>4}  {} /req",
            stats.latency.p99() * 1e3,
            stats.retries,
            stats.hedges,
            stats.dropped,
            fmt_energy(stats.energy_per_request_j()),
        );
    }

    // Part 3: N+1 provisioning.  Escalate the shard count until the fleet
    // meets 95% SLO attainment with its highest-capacity shard pinned
    // down (the adversarial worst case of losing any one shard).
    let cfg = SystemConfig::default();
    let slo = 25e-3;
    let opts = DesignOptions {
        shards: 2,
        batch_sizes: vec![1, 2, 4],
        slo_s: Some(slo),
        flush_deadline_s: 2e-3,
        homogeneous: false,
    };
    let probe = FleetConfig {
        rps: 150.0,
        requests: 600,
        seed: 7,
        policy: RoutingPolicy::Jsq,
        slo_s: Some(slo),
        fault: None,
    };
    let np = NPlusOptions {
        fault_budget: 1,
        attainment_target: 0.95,
        max_extra: 4,
    };
    let nd = design_fleet_n_plus(&EvalCtx::for_config(&cfg), &[capsnet_mnist()], &opts, &probe, &np)
        .expect("N+1 provisioning");
    println!(
        "\nN+1 provisioning: {} shards (requested 2 + budget 1), degraded \
         attainment {:.1}% with shards {:?} down",
        nd.shards,
        100.0 * nd.degraded.slo_attainment(),
        nd.pinned,
    );

    // The provisioned fleet under live crash/recover injection.
    let live = FleetConfig {
        fault: Some(FaultConfig {
            mtbf_s: 10.0,
            mttr_s: 1.0,
            timeout_s: Some(4.0 * slo),
            retries: 2,
            fault_seed: 11,
            ..FaultConfig::default()
        }),
        ..probe.clone()
    };
    let mut stats = simulate(&nd.design.plans, &live).expect("fleet simulation");
    print!("{}", stats.summary());
}
