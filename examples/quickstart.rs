//! Quickstart: the DESCNet public API in ~60 lines.
//!
//!   cargo run --release --example quickstart
//!
//! Profiles Google's CapsNet on the CapsAcc model, sizes the three DESCNet
//! organizations, runs the DSE and prints the Pareto selections with the
//! headline savings vs the all-on-chip baseline of [1].

use descnet::config::SystemConfig;
use descnet::ctx::EvalCtx;
use descnet::dataflow::profile_network;
use descnet::dse;
use descnet::energy;
use descnet::model::capsnet_mnist;
use descnet::util::units::{fmt_energy, fmt_size};

fn main() {
    let cfg = SystemConfig::default();

    // 1. Profile the workload on the accelerator (Figs 1/9/10).
    let profile = profile_network(&capsnet_mnist(), &cfg.accel);
    println!(
        "CapsNet on CapsAcc: {} ops, {:.1} fps, routing share {:.1}%",
        profile.ops.len(),
        profile.fps(),
        100.0 * profile.routing_cycle_share()
    );

    // 2. Size the organizations from the usage maxima (Eqs 1-2, Table I).
    let (d, w, a) = dse::sep_sizes(&profile);
    println!(
        "SEP sizes: data {}, weight {}, acc {}; SMP: {}",
        fmt_size(d),
        fmt_size(w),
        fmt_size(a),
        fmt_size(dse::smp_size(&profile))
    );

    // 3. Exhaustive DSE (Algorithms 1-2) on the shared engine + Pareto
    //    selection (Fig 18).
    let result = dse::run(&EvalCtx::for_config(&cfg), &profile)
        .expect("DSE over the paper profile");
    println!(
        "DSE: {} configurations, {} on the Pareto frontier",
        result.points.len(),
        result.pareto.len()
    );
    for (option, idx) in &result.selected {
        let p = &result.points[*idx];
        println!(
            "  {:7}  area {:6.3} mm²  energy {}",
            option,
            p.area_mm2,
            fmt_energy(p.energy_j)
        );
    }

    // 4. Headline: complete accelerator vs the baseline of [1] (Fig 23/24).
    let baseline = energy::version_a(&profile, &cfg.tech).expect("baseline rollup");
    let selected: std::collections::BTreeMap<_, _> = result.selected.iter().cloned().collect();
    let hy_pg = &result.points[selected["HY-PG"]];
    let system = energy::system_with_org(&profile, &cfg.tech, &hy_pg.org, "DESCNet")
        .expect("system rollup");
    println!(
        "HY-PG complete accelerator: {} vs baseline {} -> {:.0}% energy saved (paper: 79%)",
        fmt_energy(system.total_j()),
        fmt_energy(baseline.total_j()),
        100.0 * (1.0 - system.total_j() / baseline.total_j())
    );
}
